"""Distributed runtime: checkpoint roundtrip/resharding, fault tolerance,
compression, partitioning rules, search engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.compression import (ErrorFeedbackState, ef_init,
                                           int8_compress, int8_decompress)
from repro.distributed.fault_tolerance import (SimulatedFailure,
                                               StragglerWatchdog,
                                               TrainingSupervisor)
from repro.distributed.partitioning import (ParamDef, default_rules,
                                            spec_for, usable_axes)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.asarray(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    t = _tree()
    cm.save(10, t)
    r = cm.restore_into(10, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree())
    cm.wait()
    assert cm.all_steps() == [3, 4]


def test_checkpoint_skips_corrupt(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    cm.save(1, _tree())
    cm.save(2, _tree())
    # corrupt step 2
    d = os.path.join(str(tmp_path), "step_00000002")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, victim), "wb") as f:
        f.write(b"garbage")
    r = cm.restore_latest()
    assert r is not None and r["step"] == 1


def test_checkpoint_restore_latest_empty(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    assert cm.restore_latest() is None


def test_checkpoint_atomicity_tmp_never_visible(tmp_path):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    cm.save(5, _tree())
    assert not any(n.startswith(".tmp") for n in os.listdir(str(tmp_path)))


# ---------------------------------------------------------------------------
# fault tolerance: crash + resume replays the same stream
# ---------------------------------------------------------------------------
def _quadratic_problem():
    """Minimize ||w - target||^2 with per-step deterministic 'batches'."""
    target = jnp.asarray(np.arange(8.0, dtype=np.float32))

    @jax.jit
    def step(w, n_done, batch):
        g = 2 * (w - target) + 0.01 * batch
        w = w - 0.05 * g
        return w, n_done + 1, {"loss": jnp.sum((w - target) ** 2)}

    def batch_fn(s):
        return jnp.asarray(np.random.default_rng(s).normal(size=8),
                           jnp.float32)

    return step, (jnp.zeros(8), jnp.asarray(0)), batch_fn


def test_supervisor_crash_resume_bitwise(tmp_path):
    step, init, batch_fn = _quadratic_problem()
    # uninterrupted run
    sup_ref = TrainingSupervisor(step, init, batch_fn)
    ref = sup_ref.run(60)
    w_ref = sup_ref.state[0]

    # crashed + resumed run
    ckdir = str(tmp_path / "ck")
    sup1 = TrainingSupervisor(step, init, batch_fn, checkpoint_dir=ckdir,
                              save_every=20)
    with pytest.raises(SimulatedFailure):
        sup1.run(60, fail_at_step=45)
    sup1.ckpt.wait()
    sup2 = TrainingSupervisor(step, init, batch_fn, checkpoint_dir=ckdir,
                              save_every=20)
    assert sup2.start_step == 40
    sup2.run(60)
    np.testing.assert_allclose(np.asarray(sup2.state[0]), np.asarray(w_ref),
                               rtol=1e-6)


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=3.0, warmup=5)
    for s in range(20):
        wd.observe(s, 0.01)
    assert wd.observe(20, 0.2)  # 20x slower -> flagged
    assert len(wd.report.slow_steps) == 1
    # the straggler didn't poison the EWMA
    assert wd.report.ewma_s < 0.02


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------
def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    c = int8_compress(g)
    back = int8_decompress(c)
    # max quantization error is scale/2
    assert float(jnp.abs(back - g).max()) <= float(c.scale) * 0.51


def test_error_feedback_residual_bounded():
    """EF residual stays bounded over repeated compression (convergence
    prerequisite, Karimireddy'19)."""
    rng = np.random.default_rng(1)
    state = ef_init({"g": jnp.zeros(128)})
    res_norms = []
    from repro.distributed.compression import int8_compress, int8_decompress
    r = state.residual["g"]
    for step in range(50):
        g = jnp.asarray(rng.normal(size=128), jnp.float32)
        corrected = g + r
        c = int8_compress(corrected)
        r = corrected - int8_decompress(c)
        res_norms.append(float(jnp.linalg.norm(r)))
    assert max(res_norms[10:]) < 1.0  # quantization error scale, not growing


# ---------------------------------------------------------------------------
# partitioning rules
# ---------------------------------------------------------------------------
def test_spec_progressive_fallback():
    import os
    # fake mesh via jax.make_mesh on 1 device won't have 16-way axes; use
    # pure logic through usable_axes with a stub mesh-like object
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    rules = default_rules(multi_pod=True)
    assert usable_axes(128, "batch", rules, FakeMesh()) == ("pod", "data")
    assert usable_axes(1, "batch", rules, FakeMesh()) == ()
    assert usable_axes(1_048_576, "tokens", rules, FakeMesh()) == \
        ("pod", "data", "model")
    assert usable_axes(128, "tokens", rules, FakeMesh()) == ("pod", "data")


def test_spec_for_no_duplicate_axes():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rules = default_rules()
    spec = spec_for((128, 4096, 4096), ("experts", "tokens", None), rules,
                    FakeMesh())
    # experts takes model; tokens then can only use data
    assert spec[0] == "model"
    flat = spec[1]
    assert flat == "data" or flat == ("data",)


def test_schema_init_deterministic_and_order_independent():
    from repro.distributed.partitioning import init_from_schema

    schema_a = {"x": ParamDef((4, 4), (None, None)),
                "y": ParamDef((4,), (None,), init="zeros")}
    schema_b = {"y": ParamDef((4,), (None,), init="zeros"),
                "x": ParamDef((4, 4), (None, None))}
    k = jax.random.PRNGKey(0)
    a = init_from_schema(schema_a, k)
    b = init_from_schema(schema_b, k)
    np.testing.assert_array_equal(np.asarray(a["x"]), np.asarray(b["x"]))


# ---------------------------------------------------------------------------
# search engine (local path; mesh path covered by dry-run)
# ---------------------------------------------------------------------------
def test_search_exact(rng=np.random.default_rng(0)):
    from repro.models.common import NULL_CTX
    from repro.search import search

    q = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    db = jnp.asarray(rng.normal(size=(200, 16)), jnp.float32)
    _, idx = search(q, db, 5, NULL_CTX)
    d = np.linalg.norm(np.asarray(q)[:, None] - np.asarray(db)[None], axis=-1)
    ref = np.argsort(d, 1)[:, :5]
    np.testing.assert_array_equal(np.sort(np.asarray(idx), 1), np.sort(ref, 1))


def test_two_stage_recall_better_with_rerank():
    from repro.configs import RAEConfig
    from repro.core import rae as rae_lib, trainer
    from repro.data import synthetic
    from repro.models.common import NULL_CTX
    from repro.search import encode_corpus, recall_vs_exact

    data = synthetic.embedding_corpus(1200, 32, n_clusters=4, intrinsic=10,
                                      seed=0)
    cfg = RAEConfig(in_dim=32, out_dim=8, steps=200, batch_size=64)
    res = trainer.train(cfg, data, log_every=999)
    db = jnp.asarray(data)
    db_red = encode_corpus(res.params, db, NULL_CTX)
    q = db[:64] + 0.01
    r1 = recall_vs_exact(q, db, db_red, res.params, 10, NULL_CTX,
                         rerank_factor=1)
    r4 = recall_vs_exact(q, db, db_red, res.params, 10, NULL_CTX,
                         rerank_factor=4)
    assert r4 >= r1
    assert r4 > 0.6
