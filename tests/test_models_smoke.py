"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + finite values. One test per assigned arch
(deliverable f). Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, get_shapes
from repro.configs.reduce import reduce_cell, reduce_config
from repro.launch.train import build_cell_with, init_for, make_batch_fn
from repro.models.common import NULL_CTX

jax.config.update("jax_platform_name", "cpu")

TRAIN_KINDS = ("train", "full_graph", "minibatch", "batched_graphs")


def _first_train_cell(arch_id, family):
    for c in get_shapes(arch_id):
        if c.kind in TRAIN_KINDS:
            return c
    raise AssertionError(arch_id)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_train_step(arch_id):
    cfg, family = get_arch(arch_id)
    cfg = reduce_config(cfg, family)
    cell = reduce_cell(_first_train_cell(arch_id, family), family)
    prog = build_cell_with(cfg, family, arch_id, cell, NULL_CTX)
    params = init_for(cfg, family, cell, jax.random.PRNGKey(0), NULL_CTX)
    opt_state = prog.meta["opt"].init(params)
    batch = make_batch_fn(arch_id, cfg, family, cell, seed=0)(0)
    step = jax.jit(prog.fn)
    p2, o2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), (arch_id, metrics)
    # shapes preserved
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.shape == b.shape
    # a second step decreases or at least moves the loss
    p3, o3, m3 = step(p2, o2, make_batch_fn(arch_id, cfg, family, cell,
                                            seed=0)(1))
    assert np.isfinite(float(m3["loss"]))


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if get_arch(a)[1] == "lm"])
def test_lm_smoke_decode_cell(arch_id):
    """Reduced decode cell: one serve step, finite logits, cache updated."""
    from repro.models.transformer import model as tm

    cfg, family = get_arch(arch_id)
    cfg = reduce_config(cfg, family)
    params = tm.init(cfg, jax.random.PRNGKey(0))
    b, smax = 2, 32
    state = tm.DecodeState(
        k=jnp.zeros((cfg.n_layers, b, smax, cfg.n_kv_heads, cfg.d_head),
                    jnp.bfloat16),
        v=jnp.zeros((cfg.n_layers, b, smax, cfg.n_kv_heads, cfg.d_head),
                    jnp.bfloat16),
        length=jnp.asarray(0, jnp.int32))
    toks = jnp.asarray([1, 2], jnp.int32)
    logits, embed, state2 = jax.jit(
        lambda p, s, t: tm.decode_step(p, s, t, cfg, NULL_CTX))(
            params, state, toks)
    assert logits.shape[0] == b and np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(np.asarray(embed)).all()
    assert int(state2.length) == 1
    # the write landed at position 0
    assert float(jnp.abs(state2.k[:, :, 0]).sum()) > 0
    assert float(jnp.abs(state2.k[:, :, 1:]).sum()) == 0


@pytest.mark.parametrize("arch_id", ["two-tower-retrieval", "mind", "bst",
                                     "autoint"])
def test_recsys_smoke_retrieval(arch_id):
    from repro.models import registry as reg

    cfg, family = get_arch(arch_id)
    cfg = reduce_config(cfg, family)
    cells = {c.name: c for c in get_shapes(arch_id)}
    cell = reduce_cell(cells["retrieval_cand"], family)
    mod = reg._RECSYS_MODULES[cfg.kind]
    params = mod.init(cfg, jax.random.PRNGKey(0))
    batch = reg._recsys_batch(cfg, 1, with_label=False)
    rng = np.random.default_rng(0)
    batch = {k: jnp.asarray(rng.integers(1, 100, v.shape), jnp.int32)
             for k, v in batch.items()}
    batch["candidates"] = jnp.arange(cell.n_candidates, dtype=jnp.int32) % 500
    scores = jax.jit(lambda p, b: mod.retrieval_scores(p, b, cfg, NULL_CTX))(
        params, batch)
    assert scores.shape == (cell.n_candidates,)
    assert np.isfinite(np.asarray(scores)).all()


def test_all_40_cells_enumerated():
    from repro.configs import all_cells

    cells = all_cells()
    assert len(cells) == 40
    by_family = {}
    for arch_id, cell in cells:
        fam = get_arch(arch_id)[1]
        by_family[fam] = by_family.get(fam, 0) + 1
    assert by_family == {"lm": 20, "gnn": 4, "recsys": 16}
