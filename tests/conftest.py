"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (1-device) host; only launch/dryrun.py fakes 512 devices.

The 20k x 256 acceptance setup (corpus + queries + exact ground truth) is
session-scoped so the slow split synthesizes and brute-force-scans it ONCE
— tests/test_api.py, tests/test_quantized.py, and tests/test_graph.py all
assert against the same fixture instead of recomputing ground truth per
module."""
import jax
import numpy as np
import pytest

ACCEPTANCE_N = 20000
ACCEPTANCE_DIM = 256
ACCEPTANCE_K = 10


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def acceptance_corpus():
    """The 20k x 256 corpus every slow acceptance test searches."""
    from repro.data import synthetic

    return synthetic.embedding_corpus(ACCEPTANCE_N, ACCEPTANCE_DIM,
                                      n_clusters=16, intrinsic=64, seed=0)


@pytest.fixture(scope="session")
def acceptance_queries(acceptance_corpus):
    """64 perturbed corpus rows (the historical acceptance protocol)."""
    rng = np.random.default_rng(1)
    picks = rng.integers(0, ACCEPTANCE_N, 64)
    noise = 0.01 * rng.standard_normal(
        (64, ACCEPTANCE_DIM)).astype(np.float32)
    return acceptance_corpus[picks] + noise


@pytest.fixture(scope="session")
def acceptance_gt(acceptance_corpus, acceptance_queries):
    """Exact full-space top-10 ids [64, 10] from the brute-force scan."""
    from repro import api

    exact = api.FlatIndex().build(acceptance_corpus)
    return exact.search(acceptance_queries, ACCEPTANCE_K).indices
