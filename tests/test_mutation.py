"""Live mutation: streaming inserts, tombstone deletes, hot swap.

Three contracts, tested at every tier the factory can wrap in ``Mut``:

* **insert immediacy** — a row returned by ``add`` answers the very next
  ``search`` (its own vector must retrieve its new external id);
* **tombstone exactness** — a deleted id never surfaces again, not even
  when the query IS the deleted vector (the adversarial case), at flat,
  IVF, HNSW, quantized and sharded tiers alike, because the alive mask
  rides into the fused kernels as ``db_mask`` rather than being filtered
  after the fact;
* **serving atomicity** — ``SearchEngine.mutate`` / ``hot_swap`` never
  drop or corrupt an in-flight query and always retire stale cache
  entries (the mutation epoch is fingerprint state — the invariant the
  ``mutation-epoch`` lint rule pins for every mutable index class).

The corpus is small random integers cast to f32 (same trick as
``test_serve``): distances accumulate exactly, so self-hit assertions
are deterministic, not a numerics lottery.
"""
import threading

import jax
import numpy as np
import pytest

from repro import api
from repro.api.factory import parse_index_spec
from repro.core.theory import DriftTracker
from repro.kernels.common import NEG_INF, PAD_ID
from repro.kernels.graph_beam.ref import graph_beam_ref
from repro.kernels.l2_topk.ref import l2_topk_ref
from repro.search import hnsw as hnsw_lib
from repro.serve import SearchEngine

jax.config.update("jax_platform_name", "cpu")

N, DIM, K = 200, 16, 10

#: (spec, exact) — exact tiers must self-hit at top-1; quantized tiers
#: get top-8 slack (codes can collide on an integer corpus)
SPECS = [
    ("Mut,Flat", True),
    ("Mut,IVF16", True),
    ("Mut,HNSW8", True),
    ("Mut,Shard2,Flat", True),
    ("Mut,SQ8", False),
    ("Mut,PQ4x4", False),
    ("Mut,IVF16,SQ8", False),
    ("Mut,IVF16,PQ4x4", False),
    ("Mut,HNSW8,SQ8", False),
]
SPEC_IDS = [s for s, _ in SPECS]


def _int_rows(seed: int, n: int, dim: int = DIM) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(-8, 8, (n, dim)).astype(np.float32)


@pytest.fixture(scope="module")
def corpus():
    return _int_rows(0, N)


def _build(spec: str, corpus: np.ndarray) -> api.MutableIndex:
    ix = api.index_factory(spec, index_kw={"ef_construction": 40}
                           if "HNSW" in spec else None)
    return ix.build(corpus)


# ---------------------------------------------------------------------------
# factory grammar
# ---------------------------------------------------------------------------
def test_mut_spec_roundtrip():
    for spec in ("Mut,Flat", "Mut,RAE8,IVF16,Rerank4", "Mut,HNSW8,SQ8"):
        assert str(parse_index_spec(spec)) == spec
    assert parse_index_spec("Mut,Flat").mutable
    assert not parse_index_spec("Flat").mutable


def test_mut_spec_errors():
    with pytest.raises(ValueError):
        parse_index_spec("IVF16,Mut")       # must come first
    with pytest.raises(ValueError):
        parse_index_spec("Mut,Mut,Flat")    # no duplicates
    with pytest.raises(ValueError):
        parse_index_spec("Mut")             # needs a wrapped stack


def test_factory_returns_mutable_wrapper(corpus):
    ix = _build("Mut,Flat", corpus)
    assert isinstance(ix, api.MutableIndex)
    assert ix.ntotal == N
    # sharded children must not be re-wrapped: one mutation owner
    sh = _build("Mut,Shard2,Flat", corpus)
    assert isinstance(sh, api.MutableIndex)
    assert not isinstance(sh._inner._shards[0], api.MutableIndex)


# ---------------------------------------------------------------------------
# insert immediacy + tombstone exactness, every tier
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec,exact", SPECS, ids=SPEC_IDS)
def test_insert_visible_immediately(spec, exact, corpus):
    ix = _build(spec, corpus)
    new = _int_rows(7, 8)
    ext = ix.add(new)
    assert np.array_equal(ext, np.arange(N, N + 8))
    assert ix.ntotal == N + 8
    assert ix.epoch >= 1
    r = ix.search(new, 8)
    for row, eid in enumerate(ext):
        got = np.asarray(r.indices)[row]
        if exact:
            assert got[0] == eid, f"{spec}: row {row} top-1 {got[0]}"
        else:
            assert eid in got, f"{spec}: row {row} not in top-8 {got}"


@pytest.mark.parametrize("spec,exact", SPECS, ids=SPEC_IDS)
def test_delete_never_surfaces(spec, exact, corpus):
    ix = _build(spec, corpus)
    rng = np.random.default_rng(3)
    dead = np.sort(rng.choice(N, 20, replace=False)).astype(np.int64)
    assert ix.delete(dead) == 20
    assert ix.ntotal == N - 20
    # adversarial queries: the tombstoned vectors themselves
    r = ix.search(corpus[dead], K)
    idx = np.asarray(r.indices)
    assert not np.isin(idx, dead).any(), \
        f"{spec}: tombstoned id surfaced: {idx[np.isin(idx, dead)]}"
    # enough alive rows remain: no padded slots either
    assert (idx >= 0).all()


def test_delete_all_but_a_few_pads_result(corpus):
    ix = _build("Mut,Flat", corpus)
    keep = np.array([4, 9, 44], np.int64)
    dead = np.setdiff1d(np.arange(N, dtype=np.int64), keep)
    assert ix.delete(dead) == N - 3
    assert ix.ntotal == 3
    r = ix.search(corpus[:5], K)
    idx = np.asarray(r.indices)
    # k is clamped to the alive count: only real, alive ids come back
    assert idx.shape == (5, 3)
    assert np.isin(idx, keep).all()


def test_delete_everything_returns_empty(corpus):
    ix = _build("Mut,Flat", corpus)
    ix.delete(np.arange(N))
    r = ix.search(corpus[:4], K)
    assert np.asarray(r.indices).shape == (4, 0)
    assert np.asarray(r.scores).shape == (4, 0)


def test_delete_unknown_raises_redelete_noop(corpus):
    ix = _build("Mut,Flat", corpus)
    with pytest.raises(KeyError):
        ix.delete([N + 5])
    assert ix.delete([3, 5]) == 2
    epoch = ix.epoch
    assert ix.delete([3, 5]) == 0          # re-delete: no-op...
    assert ix.epoch == epoch               # ...and no identity churn
    with pytest.raises(ValueError):
        ix.search(corpus[:1], K, alive=np.ones(N, bool))  # mask is owned


# ---------------------------------------------------------------------------
# identity: epoch + fingerprint move on every mutation
# ---------------------------------------------------------------------------
def test_fingerprint_moves_on_every_mutation(corpus):
    ix = _build("Mut,Flat", corpus)
    prints = {ix.fingerprint()}
    ix.add(_int_rows(11, 2))
    prints.add(ix.fingerprint())
    ix.delete([0])
    prints.add(ix.fingerprint())
    ix.rebuild()
    prints.add(ix.fingerprint())
    assert len(prints) == 4, "a mutation failed to move the fingerprint"
    assert ix.epoch == 3 and ix.n_rebuilds == 1


def test_ids_stable_across_rebuild(corpus):
    ix = _build("Mut,IVF16", corpus)
    ext = ix.add(_int_rows(13, 4))
    ix.delete(np.arange(0, 60, 2))
    before = np.asarray(ix.search(corpus[1:2], K).indices)
    ix.rebuild()
    assert ix.mutation_stats()["tombstones"] == 0.0
    after = np.asarray(ix.search(corpus[1:2], K).indices)
    assert np.array_equal(before, after), \
        "compaction renamed external ids"
    # the post-rebuild index still speaks pre-rebuild ids
    r = ix.search(_int_rows(13, 4), 1)
    assert np.array_equal(np.asarray(r.indices)[:, 0], ext)


def test_imbalance_triggers_ivf_rebuild(corpus):
    ix = api.MutableIndex(api.IVFFlatIndex(n_cells=8, kmeans_iters=4),
                          imbalance_trigger=2.5)
    ix.build(corpus)
    assert ix.n_rebuilds == 0
    # hammer one region: every insert lands in the same (fixed) cell
    # until the imbalance trip re-clusters with fresh centroids
    hot = np.tile(corpus[0], (120, 1)) + _int_rows(17, 120) * 0.25
    ix.add(hot.astype(np.float32))
    assert ix.n_rebuilds >= 1, \
        f"imbalance {ix._imbalance():.2f} never tripped a re-cluster"
    r = ix.search(corpus[5:6], 1)
    assert np.asarray(r.indices)[0, 0] == 5


def test_hnsw_entry_reassigned_when_tombstoned(corpus):
    ix = _build("Mut,HNSW8", corpus)
    g = ix._graph_index()._g
    entry_ext = int(ix._row_ids[g.entry])
    ix.delete([entry_ext])
    assert ix._alive[g.entry], "entry still points at a tombstone"
    r = ix.search(corpus[2:3], K)
    assert np.asarray(r.indices)[0, 0] == 2
    assert entry_ext not in np.asarray(r.indices)


# ---------------------------------------------------------------------------
# re-pack neutrality (the HNSW insert/pack contract)
# ---------------------------------------------------------------------------
def test_compact_pads_bitwise_neutral_without_holes():
    rng = np.random.default_rng(5)
    links0 = rng.integers(0, 50, (12, 8)).astype(np.int32)
    links0[:6, 5:] = -1                      # trailing pads: already dense
    holey = links0.copy()
    holey[8, [1, 4]] = -1                    # interior holes in row 8
    dense_before = holey[:8].copy()
    hnsw_lib._compact_pads(holey, np.empty((0, 12, 4), np.int32))
    assert np.array_equal(holey[:8], dense_before), \
        "re-pack touched a hole-free row"
    row = holey[8]
    assert (row[-2:] == -1).all() and (row[:-2] >= 0).all()
    # survivors keep their relative order (stable compaction)
    want = [x for j, x in enumerate(links0[8]) if j not in (1, 4)]
    assert row[:-2].tolist() == want


def test_insert_batch_only_touches_neighbor_rows(corpus):
    g = hnsw_lib.build(corpus, M=8, ef_construction=40, seed=0)
    before0 = g.links0.copy()
    new_ids = hnsw_lib.insert_batch(g, _int_rows(19, 6),
                                    ef_construction=40, seed=0)
    assert np.array_equal(new_ids, np.arange(N, N + 6))
    changed = np.flatnonzero((g.links0[:N] != before0).any(axis=1))
    # the insert rewires a bounded neighborhood, not the whole graph:
    # untouched rows stay bitwise identical through the re-pack
    assert 0 < changed.size < N // 2
    assert g.packed is None, "insert must invalidate the packed cache"
    g.pack()
    assert np.array_equal(g.packed.nbrs0[:N][~np.isin(np.arange(N), changed)],
                          before0[~np.isin(np.arange(N), changed)])


# ---------------------------------------------------------------------------
# kernel db_mask semantics (the operand the alive mask lowers into)
# ---------------------------------------------------------------------------
def test_l2_topk_ref_mask_semantics(corpus):
    q = jax.numpy.asarray(corpus[:6])
    db = jax.numpy.asarray(corpus)
    mask = np.ones(N, bool)
    mask[::3] = False
    vals, idx = l2_topk_ref(q, db, K, db_mask=jax.numpy.asarray(mask))
    idx = np.asarray(idx)
    assert not np.isin(idx, np.flatnonzero(~mask)).any()
    # equals the brute-force scan over only the alive rows (compare
    # scores, not ids — an integer corpus has genuine distance ties)
    alive_rows = np.flatnonzero(mask)
    d = ((corpus[:6, None, :] - corpus[None, alive_rows, :]) ** 2).sum(-1)
    want_d = np.sort(d, axis=1)[:, :K]
    assert np.array_equal(-np.asarray(vals), want_d)
    # an all-alive mask is bitwise the unmasked scan
    v0, i0 = l2_topk_ref(q, db, K)
    v1, i1 = l2_topk_ref(q, db, K, db_mask=jax.numpy.ones(N, bool))
    assert np.array_equal(np.asarray(v0), np.asarray(v1))
    assert np.array_equal(np.asarray(i0), np.asarray(i1))


def test_l2_topk_ref_mask_pads_when_starved():
    db = np.arange(8, dtype=np.float32)[:, None] * np.ones((8, 4), np.float32)
    mask = np.zeros(8, bool)
    mask[2] = True
    vals, idx = l2_topk_ref(jax.numpy.asarray(db[:1]), jax.numpy.asarray(db),
                            4, db_mask=jax.numpy.asarray(mask))
    idx = np.asarray(idx)
    vals = np.asarray(vals)
    assert idx[0, 0] == 2 and (idx[0, 1:] == PAD_ID).all()
    assert (vals[0, 1:] <= NEG_INF / 2).all()


def test_graph_beam_ref_mask_equals_slot_masking(corpus):
    rng = np.random.default_rng(23)
    q = corpus[:4]
    nbr = rng.integers(0, N, (4, 8)).astype(np.int32)
    beam_v = np.full((4, 6), NEG_INF, np.float32)
    beam_i = np.full((4, 6), -1, np.int32)
    mask = np.ones(N, bool)
    mask[nbr[0, 2]] = False
    mask[nbr[3, 5]] = False
    got_v, got_i = graph_beam_ref(q, corpus, nbr, beam_v, beam_i,
                                  db_mask=mask)
    # masking a db row == never offering that candidate slot at all
    nbr2 = np.where(mask[np.where(nbr >= 0, nbr, 0)] | (nbr < 0), nbr, -1)
    want_v, want_i = graph_beam_ref(q, corpus, nbr2, beam_v, beam_i)
    assert np.array_equal(got_v, want_v) and np.array_equal(got_i, want_i)
    assert not np.isin(got_i, [nbr[0, 2], nbr[3, 5]]).any()


def test_alive_none_is_the_static_path(corpus):
    """alive=None and an all-True mask agree at the API tier too."""
    flat = api.FlatIndex().build(corpus)
    r0 = flat.search(corpus[:8], K)
    r1 = flat.search(corpus[:8], K, alive=np.ones(N, bool))
    assert np.array_equal(np.asarray(r0.indices), np.asarray(r1.indices))
    assert np.array_equal(np.asarray(r0.scores), np.asarray(r1.scores))


# ---------------------------------------------------------------------------
# drift monitor (Eq. 15 band) + reducer retrain policy
# ---------------------------------------------------------------------------
def test_drift_tracker_band_and_trigger():
    w = 2.0 * np.eye(4, 8, dtype=np.float32)   # every singular value = 2
    t = DriftTracker.from_weights(jax.numpy.asarray(w), tol=0.1,
                                  threshold=0.2, min_observed=16)
    assert t.sigma_min == pytest.approx(2.0) == t.sigma_max
    # Eq. 15's lower half is exact on row(W): keep xs on the first 4 dims
    xs = np.zeros((32, 8), np.float32)
    xs[:, :4] = _int_rows(29, 32, 4) + 0.5     # no zero rows
    assert t.observe(xs, 2.0 * xs[:, :4]) == 0.0
    assert not t.should_retrain
    assert t.observe(xs, 5.0 * xs[:, :4]) == 1.0   # off-band: all violate
    assert t.observed == 64 and t.violation_rate == pytest.approx(0.5)
    assert t.should_retrain
    t.reset()
    assert t.observed == 0 and not t.should_retrain


def test_drift_tracker_skips_zero_norm_rows():
    t = DriftTracker(sigma_min=1.0, sigma_max=1.0, tol=0.5)
    xs = np.zeros((4, 3), np.float32)
    xs[0] = 1.0
    assert t.observe(xs, xs) == 0.0
    assert t.observed == 1                      # only the nonzero row


def test_drift_retrain_swaps_reducer_and_index_together():
    rng = np.random.default_rng(31)
    data = rng.standard_normal((160, DIM)).astype(np.float32)
    ix = api.index_factory("Mut,RAE8,Flat",
                           reducer_kw={"steps": 200, "seed": 0})
    ix.build(data)
    assert ix._drift is not None, "RAE stack must arm the Eq. 15 monitor"
    old_params = ix._inner.reducer.params_
    ix._drift.observed, ix._drift.violations = 500, 400   # force the trip
    ix.add(data[:1] * 3.0)
    assert ix.n_reducer_retrains == 1
    assert ix._inner.reducer.params_ is not None
    assert ix._inner.reducer.params_ is not old_params, \
        "retrain must produce fresh encoder weights"
    assert ix._drift.observed == 0              # fresh band, fresh stream
    r = ix.search(data[5:6], 1)
    assert np.asarray(r.indices)[0, 0] == 5


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------
def test_save_load_roundtrip_keeps_tombstones(tmp_path, corpus):
    ix = _build("Mut,IVF16", corpus)
    ix.add(_int_rows(37, 3))
    ix.delete([7, 8])
    ix.save(str(tmp_path / "mut"))
    back = api.load_index(str(tmp_path / "mut"))
    assert isinstance(back, api.MutableIndex)
    assert back.fingerprint() == ix.fingerprint()
    assert back.epoch == ix.epoch and back.ntotal == ix.ntotal
    r = back.search(corpus[7:9], K)
    assert not np.isin(np.asarray(r.indices), [7, 8]).any()
    back.delete([9])                            # still mutable after load
    assert back.ntotal == ix.ntotal - 1


# ---------------------------------------------------------------------------
# serving: atomic mutation + zero-downtime swap
# ---------------------------------------------------------------------------
def test_engine_mutate_is_atomic_and_retires_cache(corpus):
    ix = _build("Mut,Flat", corpus)
    with SearchEngine(ix, max_batch=8, max_wait_ms=2.0,
                      cache_size=32) as eng:
        assert eng.search_one(corpus[5], K).indices[0, 0] == 5
        assert eng.search_one(corpus[5], K).indices[0, 0] == 5  # cached
        assert eng.mutate(lambda i: i.delete([5])) == 1
        after = eng.search_one(corpus[5], K)    # same key, new epoch
        assert 5 not in after.indices
        ext = eng.mutate(lambda i: i.add(_int_rows(41, 2)))
        assert np.array_equal(ext, [N, N + 1])  # mutate returns fn's result
        st = eng.stats()["mutation"]
        assert st["mutations"] == 2
        assert st["index"]["epoch"] == 2.0 and st["index"]["deleted"] == 1.0


def test_hot_swap_under_concurrent_load_drops_nothing(corpus):
    """Clients hammer their own rows while the index is swapped for a
    superset rebuild: every reply must be the exact self-hit (entirely
    old or entirely new index — never a torn read), none dropped."""
    flat = api.FlatIndex().build(corpus)
    bigger = np.concatenate([corpus, _int_rows(43, 16)])
    n_clients, reps = 12, 6
    out = [[None] * reps for _ in range(n_clients)]
    start = threading.Barrier(n_clients + 1)

    def client(i):
        start.wait()
        for j in range(reps):
            out[i][j] = eng.search_one(corpus[i], K)

    with SearchEngine(flat, max_batch=8, max_wait_ms=2.0,
                      cache_size=0) as eng:
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        start.wait()
        promoted = eng.hot_swap(lambda: api.FlatIndex().build(bigger),
                                ks=(K,))
        for t in threads:
            t.join()
        assert promoted is eng.index and eng.index.ntotal == N + 16
        st = eng.stats()
        assert st["mutation"]["swaps"] == 1
        assert st["requests"] == n_clients * reps
    for i in range(n_clients):
        for r in out[i]:
            assert r is not None, "a query was dropped during the swap"
            assert r.indices[0, 0] == i and r.scores[0, 0] == 0.0
