"""Self-tuning serving: knob ladder, autotuner, adaptive escalation.

What is pinned here, per ISSUE 10's acceptance criteria:

* the :data:`KNOB_LADDER` / ``SearchParams`` snapping algebra;
* per-call ``nprobe``/``ef_search``/``rerank_k1`` overrides answer
  differently (more knob = more work) WITHOUT recompiling once each
  rung's jit entry is warm — the compile-budget-zero regression test;
* operating-curve monotonicity: IVF recall is non-decreasing along the
  nprobe ladder (probed cell sets are nested), and a swept
  ``OperatingCurve`` is Pareto by construction (recall strictly
  increases with cost);
* escalation determinism: a query escalated solo is bitwise identical
  to the same query escalated inside a coalesced batch (the serving
  row-invariance contract, extended to the two-pass path) at compile
  budget zero. Parity tests use scan tiers (IVF) on integer corpora —
  exact arithmetic, and the HNSW ``batched="auto"`` lone-vs-batched
  engine split documented in ``api.graph`` does not apply;
* the PR-10 cache bugfix: the serving-cache key carries the resolved
  operating point, so ``set_operating_point`` can never replay answers
  computed under the old knobs;
* curve persistence is fingerprint-keyed: loading a curve against a
  different build raises.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro import api
from repro.analysis.runtime import no_retrace
from repro.api import KNOB_LADDER, SearchParams, next_rung, snap_knob
from repro.serve.engine import SearchEngine, _Request
from repro.tune import (EscalationPolicy, OperatingCurve, OperatingPoint,
                        load_curve, pareto, save_curve, sweep, topk_margin,
                        unstable_rows)

N, DIM, K = 2048, 16, 10


def _int_corpus(seed: int, n: int = N, dim: int = DIM) -> np.ndarray:
    """Integer-valued f32 vectors: exact arithmetic, so batched and
    per-query scans agree bitwise. Rows are distinct w.p. ~1."""
    rng = np.random.default_rng(seed)
    return rng.integers(-8, 8, (n, dim)).astype(np.float32)


@pytest.fixture(scope="module")
def corpus():
    return _int_corpus(0)


@pytest.fixture(scope="module")
def queries(corpus):
    rng = np.random.default_rng(7)
    return corpus[rng.choice(len(corpus), 32, replace=False)].copy()


@pytest.fixture(scope="module")
def ivf(corpus):
    return api.IVFFlatIndex(n_cells=32, seed=0).build(corpus)


@pytest.fixture(scope="module")
def ground_truth(corpus, queries):
    return np.asarray(api.FlatIndex().build(corpus)
                      .search(queries, K).indices)


# ---------------------------------------------------------------------------
# ladder + SearchParams algebra
# ---------------------------------------------------------------------------
def test_ladder_is_strictly_increasing_geometricish():
    steps = np.diff(np.asarray(KNOB_LADDER))
    assert (steps > 0).all()
    ratios = np.asarray(KNOB_LADDER[1:]) / np.asarray(KNOB_LADDER[:-1])
    assert ratios.max() <= 2.0  # no rung more than doubles the work


def test_snap_rounds_up_and_clamps():
    assert snap_knob(1) == KNOB_LADDER[0]
    for r in KNOB_LADDER:
        assert snap_knob(r) == r           # rungs are fixed points
    assert snap_knob(9) == 12
    assert snap_knob(KNOB_LADDER[-1] + 1) == KNOB_LADDER[-1]


def test_next_rung_steps_and_saturates():
    assert next_rung(8) == 12
    assert next_rung(9) == 16              # snap(9)=12, next is 16
    assert next_rung(KNOB_LADDER[-1]) == KNOB_LADDER[-1]


def test_search_params_snap_merge_escalate():
    p = SearchParams(nprobe=9, ef_search=100)
    assert (p.nprobe, p.ef_search, p.rerank_k1) == (12, 128, None)
    assert p == SearchParams(nprobe=12, ef_search=128)  # snapped == equal
    assert p.merged(SearchParams(nprobe=48)).nprobe == 48
    assert p.merged(SearchParams()).ef_search == 128
    e = p.escalated()
    assert (e.nprobe, e.ef_search, e.rerank_k1) == (16, 192, None)
    assert SearchParams.from_dict(p.to_dict()) == p
    with pytest.raises(ValueError, match="must be >= 1"):
        SearchParams(nprobe=0)


# ---------------------------------------------------------------------------
# per-call knobs: behavior + the no-recompile regression
# ---------------------------------------------------------------------------
def test_ivf_per_call_nprobe_changes_work(ivf, queries):
    lo = ivf.search(queries, K, params=SearchParams(nprobe=8))
    hi = ivf.search(queries, K, params=SearchParams(nprobe=32))
    assert hi.distance_evals > lo.distance_evals
    # per-call override does NOT move the fingerprint (no state changed)
    fp = ivf.fingerprint()
    ivf.search(queries, K, params=SearchParams(nprobe=16))
    assert ivf.fingerprint() == fp


def test_ivf_laddered_calls_do_not_recompile(ivf, queries):
    """ISSUE 10 satellite: repeated per-call laddered nprobe overrides
    must reuse the cached static-arg jit — zero recompiles once warm."""
    rungs = [SearchParams(nprobe=r) for r in (8, 12, 16, 32)]
    for p in rungs:  # warm every rung once at the serving shape
        ivf.search(queries, K, params=p)
    with no_retrace(budget=0, what="laddered nprobe storm"):
        for _ in range(3):
            for p in rungs:
                ivf.search(queries, K, params=p)


def test_two_stage_rerank_k1_override(corpus, queries):
    ts = api.TwoStageIndex(api.make_reducer("pca", 8),
                           api.IVFFlatIndex(n_cells=32),
                           rerank_factor=4).build(corpus)
    r = ts.search(queries, K, params=SearchParams(rerank_k1=16))
    assert r.stats["rerank_evals"] == 16.0
    # k1 never drops below k: the rerank can't return unfetched rows
    r2 = ts.search(queries, 24, params=SearchParams(rerank_k1=8))
    assert r2.stats["rerank_evals"] == 24.0


def test_set_params_moves_fingerprint(corpus):
    # local builds: set_params mutates serving state (and the
    # fingerprint with it), so never touch the shared fixtures here
    ix_ivf = api.IVFFlatIndex(n_cells=16, seed=0).build(corpus[:512])
    h = api.HNSWIndex(m=8, ef_search=32, seed=0).build(corpus[:512])
    for ix, p in [(ix_ivf, SearchParams(nprobe=24)),
                  (h, SearchParams(ef_search=96))]:
        fp = ix.fingerprint()
        ix.set_params(p)
        assert ix.fingerprint() != fp, type(ix).__name__


# ---------------------------------------------------------------------------
# operating curve: monotonicity + persistence
# ---------------------------------------------------------------------------
def test_ivf_recall_monotone_along_ladder(ivf, queries, ground_truth):
    """Probed cell sets are nested as nprobe grows, so recall along the
    ladder is non-decreasing — the property the autotuner's 'cheapest
    point meeting the SLO' selection rests on."""
    from repro.core.metrics import recall_at_k

    recalls = [recall_at_k(
        ivf.search(queries, K, params=SearchParams(nprobe=r)).indices,
        ground_truth) for r in (8, 12, 16, 24, 32)]
    assert all(b >= a - 1e-12 for a, b in zip(recalls, recalls[1:])), recalls


def test_sweep_returns_pareto_curve(ivf, queries, ground_truth):
    curve = sweep(ivf, queries, ground_truth, K)
    assert curve.fingerprint == ivf.fingerprint() and curve.k == K
    evals = [p.distance_evals for p in curve.points]
    recalls = [p.recall for p in curve.points]
    assert evals == sorted(evals)
    assert all(b > a for a, b in zip(recalls, recalls[1:]))  # strict
    # select: cheapest point covering the target; best-effort at the top
    cheap = curve.select(0.0)
    assert cheap is curve.points[0]
    assert curve.select(2.0) is curve.points[-1]


def test_pareto_drops_dominated_points():
    mk = lambda r, c: OperatingPoint(params=SearchParams(nprobe=8),
                                     recall=r, distance_evals=c, qps=1.0)
    front = pareto([mk(0.9, 100), mk(0.8, 200), mk(0.95, 300)])
    assert [(p.recall, p.distance_evals) for p in front] == \
        [(0.9, 100), (0.95, 300)]


def test_curve_roundtrip_and_fingerprint_pinning(tmp_path, ivf, queries,
                                                 ground_truth, corpus):
    curve = sweep(ivf, queries, ground_truth, K,
                  candidates=[SearchParams(nprobe=8),
                              SearchParams(nprobe=16)])
    path = str(tmp_path / "curve.json")
    save_curve(curve, path)
    assert load_curve(path, ivf) == curve
    other = api.IVFFlatIndex(n_cells=16).build(corpus[:512])
    with pytest.raises(ValueError, match="tuned for fingerprint"):
        load_curve(path, other)


# ---------------------------------------------------------------------------
# margin signal
# ---------------------------------------------------------------------------
def test_topk_margin_separates_stable_from_unstable():
    s = np.array([[10.0, 9, 8, 7, 1, 0.9, 0.8],     # insulated top-4
                  [10.0, 9, 8, 7, 6.99, 6.98, 6.97]])  # razor-thin
    m = topk_margin(s, k=4, delta=3)
    assert m[0] > 0.5 and m[1] < 0.05
    u = unstable_rows(s, 4, 3, threshold=0.15, ntotal=10_000)
    assert list(u) == [False, True]


def test_unstable_rows_short_probe_policy():
    short = np.array([[5.0, 4, 3, -np.inf, -np.inf, -np.inf, -np.inf]])
    # a short probe escalates when the corpus holds more...
    assert unstable_rows(short, 4, 3, 0.15, ntotal=10_000)[0]
    # ...but not when the corpus simply has nothing else to offer
    assert not unstable_rows(short, 4, 3, 0.15, ntotal=3)[0]


def test_threshold_extremes_force_none_and_all():
    s = np.array([[10.0, 9, 8, 7, 1, 0.9, 0.8]])
    assert not unstable_rows(s, 4, 3, threshold=0.0, ntotal=100)[0]
    assert unstable_rows(s, 4, 3, threshold=1.5, ntotal=100)[0]


def test_escalation_policy_validation():
    with pytest.raises(ValueError, match="delta"):
        EscalationPolicy(delta=0)
    with pytest.raises(ValueError, match="threshold"):
        EscalationPolicy(threshold=-0.1)
    with pytest.raises(ValueError, match="recall_slack"):
        EscalationPolicy(recall_slack=-0.01)


# ---------------------------------------------------------------------------
# engine: escalation determinism + compile budget + the cache bugfix
# ---------------------------------------------------------------------------
def _reqs(qs):
    return [_Request(q=q, k=K, future=None) for q in qs]


def test_escalated_solo_bitwise_equals_escalated_in_batch(ivf, queries):
    """ISSUE 10 acceptance: a query escalated solo must return bitwise
    identical ids/scores to the same query escalated inside a coalesced
    batch — pass 1 AND pass 2 ride the tiers' row-invariance contract —
    and the whole two-pass path stays at compile budget zero once
    warmup() has compiled both rungs at every bucket."""
    eng = SearchEngine(ivf, max_batch=4, cache_size=0,
                       params=SearchParams(nprobe=8),
                       escalation=EscalationPolicy(delta=3, threshold=1.5))
    eng.warmup(ks=(K,))
    qs = queries[:4]
    with no_retrace(budget=0, what="escalated solo-vs-batch parity"):
        batch = eng._run_batch(K, _reqs(qs))
        solos = [eng._run_batch(K, _reqs(qs[i:i + 1]))[0]
                 for i in range(len(qs))]
    for i, solo in enumerate(solos):
        assert solo.stats["escalated"] and batch[i].stats["escalated"]
        np.testing.assert_array_equal(solo.indices, batch[i].indices)
        assert solo.scores.tobytes() == batch[i].scores.tobytes()
    assert eng.metrics.snapshot()["escalation_rate"] == 1.0


def test_escalation_off_rows_untouched(ivf, queries):
    """threshold=0 never escalates: answers must equal the plain
    single-pass answers at the base params, bitwise."""
    eng = SearchEngine(ivf, max_batch=4, cache_size=0,
                       params=SearchParams(nprobe=8),
                       escalation=EscalationPolicy(delta=3, threshold=0.0))
    eng.warmup(ks=(K,))
    base = ivf.search(queries[:4], K + 3, params=SearchParams(nprobe=8))
    out = eng._run_batch(K, _reqs(queries[:4]))
    for i, r in enumerate(out):
        assert not r.stats["escalated"]
        np.testing.assert_array_equal(
            r.indices[0], np.asarray(base.indices)[i, :K])
    assert eng.metrics.snapshot()["escalation_rate"] == 0.0


def test_escalated_rows_pay_both_passes_in_stats(ivf, queries):
    eng = SearchEngine(ivf, max_batch=4, cache_size=0,
                       params=SearchParams(nprobe=8),
                       escalation=EscalationPolicy(delta=3, threshold=1.5))
    out = eng._run_batch(K, _reqs(queries[:2]))
    for r in out:
        e1 = r.stats["pass1_distance_evals"]
        e2 = r.stats["pass2_distance_evals"]
        assert e2 > 0 and r.stats["distance_evals"] == pytest.approx(e1 + e2)


def test_cache_key_includes_operating_point(ivf, queries):
    """The PR-10 bugfix: a knob change on the SAME fingerprint must not
    replay cached answers computed under the old knobs."""
    with SearchEngine(ivf, max_batch=2, max_wait_ms=0.5,
                      cache_size=64) as eng:
        q = queries[0]
        eng.search_one(q, K)
        eng.search_one(q, K)
        assert eng.cache.hits == 1
        eng.set_operating_point(params=SearchParams(nprobe=32))
        eng.search_one(q, K)          # same query, new knobs: MUST miss
        assert eng.cache.hits == 1
        eng.search_one(q, K)          # same knobs again: hits again
        assert eng.cache.hits == 2


def test_engine_target_recall_selects_cheapest_point(ivf):
    mk = lambda r, c, np_: OperatingPoint(
        params=SearchParams(nprobe=np_), recall=r, distance_evals=c,
        qps=1.0)
    curve = OperatingCurve(points=(mk(0.9, 100, 8), mk(0.97, 200, 12),
                                   mk(0.999, 400, 24)),
                           fingerprint=ivf.fingerprint(), k=K)
    eng = SearchEngine(ivf, target_recall=0.95, curve=curve)
    assert eng._params.nprobe == 12
    # recall_slack discounts the selection: escalation is trusted to
    # close the gap, so the engine starts a rung cheaper and derives
    # pass 2 one ladder rung up from there
    eng2 = SearchEngine(ivf, target_recall=0.95, curve=curve,
                        escalation=EscalationPolicy(recall_slack=0.08))
    assert eng2._params.nprobe == 8        # 0.90 >= 0.95 - 0.08
    assert eng2._esc_params.nprobe == 12
    with pytest.raises(ValueError, match="needs an OperatingCurve"):
        SearchEngine(ivf, target_recall=0.9)
    with pytest.raises(ValueError, match="pass-2 operating point"):
        SearchEngine(ivf, escalation=EscalationPolicy())


def test_engine_rejects_foreign_curve(corpus, ivf):
    other = api.IVFFlatIndex(n_cells=16).build(corpus[:512])
    curve = OperatingCurve(
        points=(OperatingPoint(params=SearchParams(nprobe=8), recall=0.99,
                               distance_evals=1.0, qps=1.0),),
        fingerprint=other.fingerprint(), k=K)
    with pytest.raises(ValueError, match="tuned for fingerprint"):
        SearchEngine(ivf, target_recall=0.9, curve=curve)
