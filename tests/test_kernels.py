"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import embedding_bag, flash_decode, l2_topk, rae_encode
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_decode.ref import flash_decode_ref
from repro.kernels.l2_topk.ref import l2_topk_ref
from repro.kernels.rae_encode.ref import rae_encode_ref

jax.config.update("jax_platform_name", "cpu")


def _arr(seed, shape, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# l2_topk
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q,n,d,k", [
    (32, 256, 32, 5), (100, 1000, 64, 10), (17, 513, 48, 7),
    (128, 2048, 128, 32),
])
@pytest.mark.parametrize("metric", ["euclidean", "cosine"])
def test_l2_topk_sweep(q, n, d, k, metric):
    qs = _arr(q + n, (q, d))
    db = _arr(n, (n, d))
    v, i = l2_topk(qs, db, k, metric=metric, impl="pallas", bq=32, bn=128,
                   interpret=True)
    if metric == "cosine":
        qn = qs / jnp.linalg.norm(qs, axis=-1, keepdims=True)
        dn = db / jnp.linalg.norm(db, axis=-1, keepdims=True)
        vr, ir = l2_topk_ref(qn, dn, k, metric)
    else:
        vr, ir = l2_topk_ref(qs, db, k, metric)
    assert float((i == ir).mean()) > 0.999  # ties may swap, values must match
    np.testing.assert_allclose(np.sort(v, 1), np.sort(vr, 1),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_topk_dtypes(dtype):
    qs = _arr(1, (32, 64), dtype)
    db = _arr(2, (512, 64), dtype)
    v, i = l2_topk(qs, db, 8, impl="pallas", bq=32, bn=128, interpret=True)
    vr, ir = l2_topk_ref(qs, db, 8)
    assert float((i == ir).mean()) > 0.97  # bf16 rounding can reorder ties


def test_l2_topk_matches_search_engine():
    from repro.models.common import NULL_CTX
    from repro.search import search

    qs = _arr(5, (16, 32))
    db = _arr(6, (300, 32))
    v, i = l2_topk(qs, db, 5, impl="ref")
    sv, si = search(qs, db, 5, NULL_CTX)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(si))


# ---------------------------------------------------------------------------
# rae_encode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rows,n,m", [(256, 512, 128), (300, 768, 96),
                                      (64, 384, 192), (1000, 1024, 256)])
@pytest.mark.parametrize("normalize", [True, False])
def test_rae_encode_sweep(rows, n, m, normalize):
    x = _arr(rows, (rows, n))
    w = _arr(n, (n, m)) * 0.05
    z = rae_encode(x, w, normalize=normalize, impl="pallas", br=64, bk=128,
                   interpret=True)
    zr = rae_encode_ref(x, w, normalize)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-4,
                               atol=1e-5)


def test_rae_encode_matches_model_encode():
    from repro.configs import RAEConfig
    from repro.core import rae as rae_lib

    cfg = RAEConfig(in_dim=64, out_dim=16)
    params = rae_lib.init(cfg, jax.random.PRNGKey(0))
    x = _arr(9, (128, 64))
    z_kernel = rae_encode(x, params["w_e"], normalize=False, impl="pallas",
                          br=64, bk=64, interpret=True)
    z_model = rae_lib.encode(params, x)
    np.testing.assert_allclose(np.asarray(z_kernel), np.asarray(z_model),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,kh,g,dh,s,cur", [
    (2, 2, 4, 16, 64, 37), (4, 4, 1, 32, 128, 128), (1, 1, 8, 64, 256, 1),
    (3, 8, 2, 16, 96, 50),
])
def test_flash_decode_sweep(b, kh, g, dh, s, cur):
    q = _arr(b, (b, kh, g, dh))
    kc = _arr(b + 1, (b, s, kh, dh))
    vc = _arr(b + 2, (b, s, kh, dh))
    o = flash_decode(q, kc, vc, cur, impl="pallas", bs=32, interpret=True)
    orf = flash_decode_ref(q, kc, vc, cur)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-4,
                               atol=2e-5)


def test_flash_decode_matches_model_decode_attention():
    """Kernel == the shard-local math of attention.decode_attention."""
    from repro.models.common import NULL_CTX
    from repro.models.transformer import attention as attn

    b, kh, g, dh, s = 2, 2, 3, 16, 32
    h = kh * g
    q = _arr(0, (b, h, dh))
    kc = _arr(1, (b, s, kh, dh))
    vc = _arr(2, (b, s, kh, dh))
    kn = _arr(3, (b, kh, dh))
    vn = _arr(4, (b, kh, dh))
    cur = jnp.asarray(20, jnp.int32)
    out, k2, v2 = attn.decode_attention(q, kc, vc, kn, vn, cur, NULL_CTX)
    # reference: write new kv at position cur, then kernel over cur+1
    kc2 = kc.at[:, 20].set(kn)
    vc2 = vc.at[:, 20].set(vn)
    o_k = flash_decode(q.reshape(b, kh, g, dh), kc2, vc2, 21, impl="pallas",
                       bs=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out).reshape(b, kh, g, dh),
                               np.asarray(o_k), rtol=3e-3, atol=3e-4)
    np.testing.assert_allclose(np.asarray(k2), np.asarray(kc2), atol=1e-6)


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("v,d,b,l", [(50, 32, 8, 6), (1000, 16, 32, 20),
                                     (128, 64, 4, 3)])
@pytest.mark.parametrize("mode", ["mean", "sum"])
def test_embedding_bag_sweep(v, d, b, l, mode):
    tbl = _arr(v, (v, d))
    rng = np.random.default_rng(v + b)
    ids = jnp.asarray(rng.integers(0, v, (b, l)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, l + 1, (b,)), jnp.int32)
    eb = embedding_bag(tbl, ids, lens, mode=mode, impl="pallas",
                       interpret=True)
    ebr = embedding_bag_ref(tbl, ids, lens, mode)
    np.testing.assert_allclose(np.asarray(eb), np.asarray(ebr), rtol=1e-5,
                               atol=1e-5)


def test_embedding_bag_matches_model_path():
    from repro.models.common import NULL_CTX, embedding_bag as model_bag

    tbl = _arr(7, (64, 8))
    rng = np.random.default_rng(3)
    ids = jnp.asarray(rng.integers(0, 64, (16, 5)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, 6, (16,)), jnp.int32)
    a = embedding_bag(tbl, ids, lens, impl="pallas", interpret=True)
    bq = model_bag(tbl, ids, lens, NULL_CTX, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bq), rtol=1e-5,
                               atol=1e-5)
