"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode).

Two layers of coverage:
* per-kernel happy-path sweeps + equivalence with the model/engine code
  that the kernel replaces (the original suite);
* a shared PARITY HARNESS (bottom of file) that drives EVERY kernel triple
  through its ragged/odd shapes — row counts not divisible by the block
  size, k larger than the candidate pool, degenerate d=1 — in both f32 and
  bf16. Kernels historically break exactly at those pad/edge paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (embedding_bag, flash_decode, graph_beam,
                           graph_beam_q, l2_topk, pq_adc, rae_encode,
                           topk_merge)
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_decode.ref import flash_decode_ref
from repro.kernels.graph_beam.ref import NEG_INF, graph_beam_ref
from repro.kernels.graph_beam_q.ref import graph_beam_q_ref
from repro.kernels.l2_topk.ref import l2_topk_ref
from repro.kernels.pq_adc.ref import pq_adc_ref
from repro.kernels.rae_encode.ref import rae_encode_ref
from repro.kernels.topk_merge.ref import topk_merge_ref

jax.config.update("jax_platform_name", "cpu")


def _arr(seed, shape, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# l2_topk
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q,n,d,k", [
    (32, 256, 32, 5), (100, 1000, 64, 10), (17, 513, 48, 7),
    (128, 2048, 128, 32),
])
@pytest.mark.parametrize("metric", ["euclidean", "cosine"])
def test_l2_topk_sweep(q, n, d, k, metric):
    qs = _arr(q + n, (q, d))
    db = _arr(n, (n, d))
    v, i = l2_topk(qs, db, k, metric=metric, impl="pallas", bq=32, bn=128,
                   interpret=True)
    if metric == "cosine":
        qn = qs / jnp.linalg.norm(qs, axis=-1, keepdims=True)
        dn = db / jnp.linalg.norm(db, axis=-1, keepdims=True)
        vr, ir = l2_topk_ref(qn, dn, k, metric)
    else:
        vr, ir = l2_topk_ref(qs, db, k, metric)
    assert float((i == ir).mean()) > 0.999  # ties may swap, values must match
    np.testing.assert_allclose(np.sort(v, 1), np.sort(vr, 1),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_topk_dtypes(dtype):
    qs = _arr(1, (32, 64), dtype)
    db = _arr(2, (512, 64), dtype)
    v, i = l2_topk(qs, db, 8, impl="pallas", bq=32, bn=128, interpret=True)
    vr, ir = l2_topk_ref(qs, db, 8)
    assert float((i == ir).mean()) > 0.97  # bf16 rounding can reorder ties


def test_l2_topk_matches_search_engine():
    from repro.models.common import NULL_CTX
    from repro.search import search

    qs = _arr(5, (16, 32))
    db = _arr(6, (300, 32))
    v, i = l2_topk(qs, db, 5, impl="ref")
    sv, si = search(qs, db, 5, NULL_CTX)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(si))


# ---------------------------------------------------------------------------
# rae_encode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rows,n,m", [(256, 512, 128), (300, 768, 96),
                                      (64, 384, 192), (1000, 1024, 256)])
@pytest.mark.parametrize("normalize", [True, False])
def test_rae_encode_sweep(rows, n, m, normalize):
    x = _arr(rows, (rows, n))
    w = _arr(n, (n, m)) * 0.05
    z = rae_encode(x, w, normalize=normalize, impl="pallas", br=64, bk=128,
                   interpret=True)
    zr = rae_encode_ref(x, w, normalize)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-4,
                               atol=1e-5)


def test_rae_encode_matches_model_encode():
    from repro.configs import RAEConfig
    from repro.core import rae as rae_lib

    cfg = RAEConfig(in_dim=64, out_dim=16)
    params = rae_lib.init(cfg, jax.random.PRNGKey(0))
    x = _arr(9, (128, 64))
    z_kernel = rae_encode(x, params["w_e"], normalize=False, impl="pallas",
                          br=64, bk=64, interpret=True)
    z_model = rae_lib.encode(params, x)
    np.testing.assert_allclose(np.asarray(z_kernel), np.asarray(z_model),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,kh,g,dh,s,cur", [
    (2, 2, 4, 16, 64, 37), (4, 4, 1, 32, 128, 128), (1, 1, 8, 64, 256, 1),
    (3, 8, 2, 16, 96, 50),
])
def test_flash_decode_sweep(b, kh, g, dh, s, cur):
    q = _arr(b, (b, kh, g, dh))
    kc = _arr(b + 1, (b, s, kh, dh))
    vc = _arr(b + 2, (b, s, kh, dh))
    o = flash_decode(q, kc, vc, cur, impl="pallas", bs=32, interpret=True)
    orf = flash_decode_ref(q, kc, vc, cur)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-4,
                               atol=2e-5)


def test_flash_decode_matches_model_decode_attention():
    """Kernel == the shard-local math of attention.decode_attention."""
    from repro.models.common import NULL_CTX
    from repro.models.transformer import attention as attn

    b, kh, g, dh, s = 2, 2, 3, 16, 32
    h = kh * g
    q = _arr(0, (b, h, dh))
    kc = _arr(1, (b, s, kh, dh))
    vc = _arr(2, (b, s, kh, dh))
    kn = _arr(3, (b, kh, dh))
    vn = _arr(4, (b, kh, dh))
    cur = jnp.asarray(20, jnp.int32)
    out, k2, v2 = attn.decode_attention(q, kc, vc, kn, vn, cur, NULL_CTX)
    # reference: write new kv at position cur, then kernel over cur+1
    kc2 = kc.at[:, 20].set(kn)
    vc2 = vc.at[:, 20].set(vn)
    o_k = flash_decode(q.reshape(b, kh, g, dh), kc2, vc2, 21, impl="pallas",
                       bs=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out).reshape(b, kh, g, dh),
                               np.asarray(o_k), rtol=3e-3, atol=3e-4)
    np.testing.assert_allclose(np.asarray(k2), np.asarray(kc2), atol=1e-6)


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("v,d,b,l", [(50, 32, 8, 6), (1000, 16, 32, 20),
                                     (128, 64, 4, 3)])
@pytest.mark.parametrize("mode", ["mean", "sum"])
def test_embedding_bag_sweep(v, d, b, l, mode):
    tbl = _arr(v, (v, d))
    rng = np.random.default_rng(v + b)
    ids = jnp.asarray(rng.integers(0, v, (b, l)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, l + 1, (b,)), jnp.int32)
    eb = embedding_bag(tbl, ids, lens, mode=mode, impl="pallas",
                       interpret=True)
    ebr = embedding_bag_ref(tbl, ids, lens, mode)
    np.testing.assert_allclose(np.asarray(eb), np.asarray(ebr), rtol=1e-5,
                               atol=1e-5)


def test_embedding_bag_matches_model_path():
    from repro.models.common import NULL_CTX, embedding_bag as model_bag

    tbl = _arr(7, (64, 8))
    rng = np.random.default_rng(3)
    ids = jnp.asarray(rng.integers(0, 64, (16, 5)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, 6, (16,)), jnp.int32)
    a = embedding_bag(tbl, ids, lens, impl="pallas", interpret=True)
    bq = model_bag(tbl, ids, lens, NULL_CTX, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bq), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# pq_adc
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("q,n,m,ksub,dsub,k", [
    (32, 512, 8, 256, 4, 10), (16, 200, 4, 16, 8, 5), (8, 1024, 2, 64, 16, 32),
])
def test_pq_adc_sweep(q, n, m, ksub, dsub, k):
    rng = np.random.default_rng(q + n)
    qs = jnp.asarray(rng.normal(size=(q, m * dsub)), jnp.float32)
    cb = jnp.asarray(rng.normal(size=(m, ksub, dsub)), jnp.float32)
    codes = jnp.asarray(rng.integers(0, ksub, (n, m)), jnp.int32)
    v, i = pq_adc(qs, cb, codes, k, impl="pallas", bq=32, bn=128,
                  interpret=True)
    vr, ir = pq_adc_ref(qs, cb, codes, k)
    assert float((i == ir).mean()) > 0.999  # ties may swap
    np.testing.assert_allclose(np.sort(v, 1), np.sort(vr, 1), rtol=2e-4,
                               atol=2e-4)


def test_pq_adc_matches_engine_ivfpq_on_one_cell():
    """Kernel == the engine's LUT-gather math (search.quantize) when the
    'IVF' is a single cell holding the whole corpus."""
    from repro.search import quantize as qz

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(300, 16)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(9, 16)), jnp.float32)
    pq = qz.pq_train(x, m=4, bits=6, iters=6, seed=0)
    codes = qz.pq_encode(pq, x)
    v, i = pq_adc(q, pq.codebooks, codes, 7, impl="pallas", bq=16, bn=64,
                  interpret=True)
    dist = qz.pq_adc_gather(qz.pq_adc_lut(pq, q), codes)
    ve, ie = jax.lax.top_k(-dist, 7)
    np.testing.assert_allclose(np.asarray(v), np.asarray(ve), rtol=1e-4,
                               atol=1e-4)
    assert float((i == ie).mean()) > 0.999


# ---------------------------------------------------------------------------
# graph_beam
# ---------------------------------------------------------------------------
def _beam_case(seed, q_n, n, d, w, ef, dtype=jnp.float32, seed_beam=2):
    """Random hop inputs: queries, db, ids (some masked -1), and a sorted-
    descending beam with ``seed_beam`` live entries."""
    rng = np.random.default_rng(seed)
    qs = jnp.asarray(rng.standard_normal((q_n, d)), dtype)
    db = jnp.asarray(rng.standard_normal((n, d)), dtype)
    ids = jnp.asarray(rng.integers(-1, n, (q_n, w)), jnp.int32)
    bv = np.full((q_n, ef), NEG_INF, np.float32)
    bi = np.full((q_n, ef), -1, np.int32)
    for s in range(min(seed_beam, ef)):
        bv[:, s] = -0.25 * (s + 1)   # sorted descending
        bi[:, s] = s
    return qs, db, ids, jnp.asarray(bv), jnp.asarray(bi)


@pytest.mark.parametrize("q_n,n,d,w,ef", [
    (8, 64, 16, 9, 7), (1, 40, 8, 5, 12), (16, 128, 32, 16, 10),
])
def test_graph_beam_sweep(q_n, n, d, w, ef):
    qs, db, ids, bv, bi = _beam_case(q_n + n, q_n, n, d, w, ef)
    got = graph_beam(qs, db, ids, bv, bi, impl="pallas", interpret=True)
    want = graph_beam_ref(qs, db, ids, bv, bi)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=2e-4, atol=2e-4)
    # merged beam stays sorted descending with pads at the tail
    v = np.asarray(want[0])
    assert np.all(np.diff(v, axis=1) <= 1e-6)
    assert np.all(v[np.asarray(want[1]) < 0] == NEG_INF)


# ---------------------------------------------------------------------------
# graph_beam_q: the quantized hop (SQ8 / PQ payloads)
# ---------------------------------------------------------------------------
def _beam_q_case(seed, mode, q_n, n, cdim, ksub, w, ef, dtype=jnp.float32,
                 seed_beam=2):
    """Random quantized hop inputs. ``cdim`` = stored code width (sq8: d;
    pq: m), ``ksub`` = LUT stride (pq only; codes stay < ksub, modelling
    the tiny-corpus clamp when ksub < 256)."""
    rng = np.random.default_rng(seed)
    hi = 256 if mode == "sq8" else ksub
    codes = jnp.asarray(rng.integers(0, hi, (n, cdim)), jnp.uint8)
    dop = cdim if mode == "sq8" else cdim * ksub
    q_op = jnp.asarray(0.1 * rng.standard_normal((q_n, dop)), dtype)
    q_bias = jnp.asarray(rng.standard_normal(q_n), dtype)
    node_bias = jnp.asarray(np.abs(rng.standard_normal(n)), dtype)
    ids = jnp.asarray(rng.integers(-1, n, (q_n, w)), jnp.int32)
    bv = np.full((q_n, ef), NEG_INF, np.float32)
    bi = np.full((q_n, ef), -1, np.int32)
    for s in range(min(seed_beam, ef)):
        bv[:, s] = -0.25 * (s + 1)   # sorted descending
        bi[:, s] = s
    return q_op, q_bias, codes, node_bias, ids, jnp.asarray(bv), \
        jnp.asarray(bi)


def test_graph_beam_q_rejects_bad_mode_and_ksub():
    a = _beam_q_case(0, "sq8", 2, 10, 4, 0, 3, 4)
    with pytest.raises(ValueError, match="mode"):
        graph_beam_q(*a, mode="fp4")
    with pytest.raises(ValueError, match="ksub"):
        graph_beam_q(*a, mode="pq", ksub=0)


def test_graph_beam_q_sq8_matches_decoded_f32_hop():
    """The dequant-free affine form == the f32 hop on decoded rows: build
    real SQ8 operands from a real codec and cross-check against
    graph_beam over decode(codes)."""
    from repro.search import hnsw as hnsw_lib

    rng = np.random.default_rng(11)
    x = rng.standard_normal((60, 12)).astype(np.float32)
    cdx = hnsw_lib.make_graph_codes(x, "sq8")
    q = rng.standard_normal((5, 12)).astype(np.float32)
    q_sq = (q * q).sum(1).astype(np.float32)
    q_op, q_bias = cdx.query_operands(q, q_sq)
    ids = jnp.asarray(rng.integers(-1, 60, (5, 7)), jnp.int32)
    bv = jnp.full((5, 6), NEG_INF, jnp.float32)
    bi = jnp.full((5, 6), -1, jnp.int32)
    got = graph_beam_q(q_op, q_bias, cdx.codes, cdx.node_bias, ids, bv, bi,
                       mode="sq8", impl="np")
    from repro.search.quantize import ScalarQuantizer, sq8_decode
    dec = np.asarray(sq8_decode(
        ScalarQuantizer(vmin=jnp.asarray(cdx.vmin),
                        step=jnp.asarray(cdx.step)),
        jnp.asarray(cdx.codes)))
    want = graph_beam_ref(jnp.asarray(q), jnp.asarray(dec), ids, bv, bi)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=1e-4, atol=1e-4)


def test_graph_beam_merge_matches_traversal_semantics():
    """A full-corpus hop against an empty beam is exact top-ef — pin the
    merge to l2_topk's ordering (same branchless merge, same tie rule)."""
    rng = np.random.default_rng(3)
    qs = jnp.asarray(rng.standard_normal((4, 12)), jnp.float32)
    db = jnp.asarray(rng.standard_normal((50, 12)), jnp.float32)
    ids = jnp.tile(jnp.arange(50, dtype=jnp.int32), (4, 1))
    bv = jnp.full((4, 8), NEG_INF, jnp.float32)
    bi = jnp.full((4, 8), -1, jnp.int32)
    v, i = graph_beam(qs, db, ids, bv, bi, impl="np")
    lv, li = l2_topk(qs, db, 8, impl="ref")
    np.testing.assert_array_equal(np.asarray(i), np.asarray(li))
    np.testing.assert_allclose(np.asarray(v), np.asarray(lv), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# Shared ragged/odd-shape parity harness: every kernel triple, both dtypes
# ---------------------------------------------------------------------------
def _tol(dtype):
    """(rtol, atol, min index agreement). All refs compute in f32 after
    casting, so bf16 slack only covers input rounding + reassociation."""
    return (2e-4, 2e-4, 0.999) if dtype == jnp.float32 else (3e-2, 3e-2, 0.9)


def _topk_parity(got, want, dtype, k_valid=None):
    """Compare (scores, indices) pairs; ties may swap, values must match."""
    rtol, atol, imatch = _tol(dtype)
    v, i = np.asarray(got[0]), np.asarray(got[1])
    vr, ir = np.asarray(want[0]), np.asarray(want[1])
    if k_valid is not None:  # the k > n tail must be -inf / -1 padding
        assert np.all(np.isneginf(v[:, k_valid:]))
        assert np.all(i[:, k_valid:] == -1)
        v, i, vr, ir = v[:, :k_valid], i[:, :k_valid], vr[:, :k_valid], \
            ir[:, :k_valid]
    assert float((i == ir).mean()) >= imatch
    np.testing.assert_allclose(np.sort(v, 1), np.sort(vr, 1), rtol=rtol,
                               atol=atol)


def _parity_l2_topk(case, dtype):
    q_n, n, d, k, bq, bn = case
    qs = _arr(q_n + n, (q_n, d), dtype)
    db = _arr(n, (n, d), dtype)
    got = l2_topk(qs, db, k, impl="pallas", bq=bq, bn=bn, interpret=True)
    _topk_parity(got, l2_topk_ref(qs, db, k), dtype)


def _parity_rae_encode(case, dtype):
    rows, n, m, br, bk = case
    x = _arr(rows, (rows, n), dtype)
    w = _arr(n, (n, m), dtype) * 0.05
    z = rae_encode(x, w, normalize=True, impl="pallas", br=br, bk=bk,
                   interpret=True)
    rtol, atol, _ = _tol(dtype)
    np.testing.assert_allclose(np.asarray(z),
                               np.asarray(rae_encode_ref(x, w, True)),
                               rtol=rtol, atol=atol)


def _parity_flash_decode(case, dtype):
    b, kh, g, dh, s, cur, bs = case
    q = _arr(b, (b, kh, g, dh), dtype)
    kc = _arr(b + 1, (b, s, kh, dh), dtype)
    vc = _arr(b + 2, (b, s, kh, dh), dtype)
    o = flash_decode(q, kc, vc, cur, impl="pallas", bs=bs, interpret=True)
    rtol, atol, _ = _tol(dtype)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(flash_decode_ref(q, kc, vc, cur),
                                          np.float32),
                               rtol=max(rtol, 3e-3), atol=max(atol, 3e-4))


def _parity_embedding_bag(case, dtype):
    v_n, d, b, l = case
    tbl = _arr(v_n, (v_n, d), dtype)
    rng = np.random.default_rng(v_n + b)
    ids = jnp.asarray(rng.integers(0, v_n, (b, l)), jnp.int32)
    lens = jnp.asarray(rng.integers(1, l + 1, (b,)), jnp.int32)
    eb = embedding_bag(tbl, ids, lens, mode="mean", impl="pallas",
                       interpret=True)
    rtol, atol, _ = _tol(dtype)
    np.testing.assert_allclose(np.asarray(eb, np.float32),
                               np.asarray(embedding_bag_ref(tbl, ids, lens,
                                                            "mean"),
                                          np.float32),
                               rtol=rtol, atol=atol)


def _parity_graph_beam(case, dtype):
    q_n, n, d, w, ef = case
    qs, db, ids, bv, bi = _beam_case(q_n + n + d, q_n, n, d, w, ef, dtype)
    got = graph_beam(qs, db, ids, bv, bi, impl="pallas", interpret=True)
    want = graph_beam_ref(qs, db, ids, bv, bi)
    rtol, atol, imatch = _tol(dtype)
    assert float((np.asarray(got[1]) == np.asarray(want[1])).mean()) >= imatch
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=rtol, atol=atol)


def _parity_graph_beam_q(case, dtype):
    mode, q_n, n, cdim, ksub, w, ef = case
    a = _beam_q_case(q_n * 7 + n + cdim, mode, q_n, n, cdim, ksub, w, ef,
                     dtype)
    kw = {"mode": mode, "ksub": ksub if mode == "pq" else 0}
    got = graph_beam_q(*a, impl="pallas", interpret=True, **kw)
    want = graph_beam_q_ref(*a, **kw)
    rtol, atol, imatch = _tol(dtype)
    assert float((np.asarray(got[1]) == np.asarray(want[1])).mean()) >= imatch
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]),
                               rtol=rtol, atol=atol)


def _parity_pq_adc(case, dtype):
    q_n, n, m, ksub, dsub, k, bq, bn = case
    rng = np.random.default_rng(q_n + n)
    qs = jnp.asarray(rng.normal(size=(q_n, m * dsub)), dtype)
    cb = jnp.asarray(rng.normal(size=(m, ksub, dsub)), dtype)
    codes = jnp.asarray(rng.integers(0, ksub, (n, m)), jnp.int32)
    got = pq_adc(qs, cb, codes, k, impl="pallas", bq=bq, bn=bn,
                 interpret=True)
    want = pq_adc_ref(qs, cb, codes, min(k, n))
    _topk_parity(got, want, dtype, k_valid=min(k, n) if k > n else None)


def _parity_topk_merge(case, dtype):
    q_n, c, k, bq = case
    rng = np.random.default_rng(q_n + c + k)
    vals = jnp.asarray(rng.integers(-4, 4, (q_n, c)), dtype)  # dense ties
    ids = np.stack([rng.permutation(4 * c)[:c].astype(np.int32)
                    for _ in range(q_n)])  # unique per row (merge contract)
    ids[rng.random((q_n, c)) < 0.15] = -1  # scattered pad slots
    ids[0] = -1                            # fully drained row
    ids = jnp.asarray(ids)
    got = topk_merge(vals, ids, k, impl="pallas", bq=bq, interpret=True)
    want = topk_merge_ref(jnp.asarray(vals, jnp.float32), ids, k)
    # the id tie-break makes the merge a total order: bitwise, not
    # tolerance, parity — and exactly the shard-count-invariance contract
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    v, i = np.asarray(got[0]), np.asarray(got[1])
    kv = min(k, c)  # the k > c tail (and drained rows) is canonical padding
    assert np.all(v[:, kv:] == NEG_INF) and np.all(i[:, kv:] == -1)
    assert np.all(i[0] == -1) and np.all(v[0] == NEG_INF)
    assert np.all(v[i >= 0] > NEG_INF)  # live slots never carry pad scores


# case ids name the edge they exercise; every kernel gets n-not-divisible-
# by-block, a k/cur overflow variant where meaningful, and d=1.
PARITY_CASES = [
    ("l2_topk", "ragged_n", (32, 333, 16, 5, 32, 128), _parity_l2_topk),
    ("l2_topk", "ragged_q", (19, 256, 16, 5, 32, 128), _parity_l2_topk),
    ("l2_topk", "d1", (16, 100, 1, 3, 16, 32), _parity_l2_topk),
    ("rae_encode", "ragged_rows", (77, 64, 16, 64, 64), _parity_rae_encode),
    ("rae_encode", "ragged_k", (64, 129, 16, 64, 128), _parity_rae_encode),
    ("rae_encode", "d1", (32, 1, 8, 32, 128), _parity_rae_encode),
    ("flash_decode", "ragged_s", (2, 2, 2, 8, 50, 37, 32),
     _parity_flash_decode),
    ("flash_decode", "cur1", (1, 1, 4, 8, 64, 1, 32), _parity_flash_decode),
    ("flash_decode", "dh1", (2, 1, 2, 1, 33, 20, 16), _parity_flash_decode),
    ("embedding_bag", "odd_shapes", (13, 5, 7, 3), _parity_embedding_bag),
    ("embedding_bag", "d1", (10, 1, 4, 5), _parity_embedding_bag),
    ("pq_adc", "ragged_n", (17, 337, 4, 16, 4, 5, 32, 128), _parity_pq_adc),
    ("pq_adc", "k_gt_n", (4, 6, 2, 4, 2, 10, 8, 8), _parity_pq_adc),
    ("pq_adc", "d1", (8, 64, 1, 8, 1, 3, 8, 32), _parity_pq_adc),
    # (q_n, n, d, w, ef): ragged q (pow2 row pad), 1-wide hop (the greedy-
    # descent shape), ef wider than the candidate pool, d=1
    ("graph_beam", "ragged_q", (7, 60, 16, 9, 8), _parity_graph_beam),
    ("graph_beam", "w1", (5, 30, 8, 1, 6), _parity_graph_beam),
    ("graph_beam", "ef_gt_w", (3, 20, 4, 3, 15), _parity_graph_beam),
    ("graph_beam", "d1", (4, 25, 1, 5, 4), _parity_graph_beam),
    # (mode, q_n, n, cdim, ksub, w, ef): quantized hop — same edges as
    # graph_beam per codec, plus ksub < 2**bits (the tiny-corpus clamp)
    # and the pq m=1 single-subspace shape
    ("graph_beam_q", "sq8_ragged_q", ("sq8", 7, 60, 16, 0, 9, 8),
     _parity_graph_beam_q),
    ("graph_beam_q", "sq8_w1", ("sq8", 5, 30, 8, 0, 1, 6),
     _parity_graph_beam_q),
    ("graph_beam_q", "sq8_ef_gt_w", ("sq8", 3, 20, 4, 0, 3, 15),
     _parity_graph_beam_q),
    ("graph_beam_q", "sq8_d1", ("sq8", 4, 25, 1, 0, 5, 4),
     _parity_graph_beam_q),
    ("graph_beam_q", "pq_ragged_q", ("pq", 7, 60, 8, 16, 9, 8),
     _parity_graph_beam_q),
    ("graph_beam_q", "pq_ef_gt_w", ("pq", 3, 20, 4, 256, 3, 15),
     _parity_graph_beam_q),
    ("graph_beam_q", "pq_m1_tiny_ksub", ("pq", 5, 9, 1, 7, 4, 6),
     _parity_graph_beam_q),
    # (q_n, c, k, bq): q not divisible by bq + non-lane-aligned pool,
    # k wider than the candidate pool, single-candidate pool
    ("topk_merge", "ragged_q", (19, 96, 8, 16), _parity_topk_merge),
    ("topk_merge", "k_gt_c", (4, 6, 10, 8), _parity_topk_merge),
    ("topk_merge", "c1", (5, 1, 3, 8), _parity_topk_merge),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("kernel,case,params,fn", PARITY_CASES,
                         ids=[f"{k}-{c}" for k, c, _, _ in PARITY_CASES])
def test_kernel_parity(kernel, case, params, fn, dtype):
    fn(params, dtype)
