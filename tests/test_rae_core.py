"""RAE model + trainer + metrics unit/integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RAEConfig
from repro.core import baselines, metrics, rae, spectral, trainer
from repro.data import synthetic

jax.config.update("jax_platform_name", "cpu")


def small_cfg(**kw):
    base = dict(in_dim=48, out_dim=12, steps=120, batch_size=32, seed=0)
    base.update(kw)
    return RAEConfig(**base)


@pytest.fixture(scope="module")
def corpus():
    data = synthetic.embedding_corpus(768, 48, n_clusters=6, intrinsic=16,
                                      seed=3)
    return synthetic.train_test_split(data)


def test_loss_decreases(corpus):
    # 240 steps: at the paper's lr schedule (cosine 1e-3 -> 1e-5, verified
    # correctly stepped: lr(0)=lr_max, lr(T-1)~lr_min) the default 120-step
    # budget only reaches 0.52x; 240 reaches 0.36x — safely under the bound.
    tr, _ = corpus
    res = trainer.train(small_cfg(steps=240), tr, log_every=20)
    assert res.history[-1]["loss"] < 0.5 * res.history[0]["loss"]


def test_explicit_frobenius_equals_weight_decay_direction(corpus):
    """Paper Eq. 7 vs the AdamW realization: both shrink ||W||_F relative to
    the unregularized run."""
    tr, _ = corpus
    res_noreg = trainer.train(small_cfg(weight_decay=0.0), tr, log_every=999)
    res_wd = trainer.train(small_cfg(weight_decay=5e-2), tr, log_every=999)
    res_fro = trainer.train(
        small_cfg(weight_decay=5e-2, explicit_frobenius=True), tr,
        log_every=999)
    f0 = float(rae.frobenius_sq(res_noreg.params))
    fw = float(rae.frobenius_sq(res_wd.params))
    ff = float(rae.frobenius_sq(res_fro.params))
    assert fw < f0 and ff < f0


def test_encode_decode_shapes(corpus):
    tr, te = corpus
    cfg = small_cfg()
    params = rae.init(cfg, jax.random.PRNGKey(0))
    z = rae.encode(params, jnp.asarray(te))
    assert z.shape == (te.shape[0], cfg.out_dim)
    xh = rae.decode(params, z)
    assert xh.shape == te.shape
    w = rae.encoder_matrix(params)
    assert w.shape == (cfg.out_dim, cfg.in_dim)


def test_preservation_accuracy_identity():
    x = np.random.default_rng(0).normal(size=(100, 16)).astype(np.float32)
    assert metrics.preservation_accuracy(x, x, k=5) == pytest.approx(1.0)


def test_preservation_accuracy_matches_bruteforce_numpy():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(60, 24)).astype(np.float32)
    z = rng.normal(size=(60, 8)).astype(np.float32)
    # numpy brute force (Definition 2)
    def knn_np(a, k):
        d = np.linalg.norm(a[:, None] - a[None, :], axis=-1)
        np.fill_diagonal(d, np.inf)
        return np.argsort(d, 1)[:, :k]
    k = 5
    ia, ib = knn_np(x, k), knn_np(z, k)
    expect = np.mean([len(set(ia[i]) & set(ib[i])) / k for i in range(60)])
    got = metrics.preservation_accuracy(x, z, k=k)
    assert got == pytest.approx(expect, abs=1e-6)


def test_rae_beats_random_projection(corpus):
    """Sanity floor: the trained encoder must beat an untrained JL map."""
    tr, te = corpus
    z, _ = trainer.fit_transform(small_cfg(steps=400), tr, te)
    acc_rae = metrics.preservation_accuracy(te, z, k=5)
    rp = baselines.GaussianRP(12).fit(tr)
    acc_rp = metrics.preservation_accuracy(te, rp.transform(te), k=5)
    assert acc_rae > acc_rp


def test_unregularized_linear_ae_approaches_pca_subspace(corpus):
    """Baldi & Hornik: the lambda=0 optimum spans the PCA subspace. With the
    CPU-budget step count the AE hasn't fully converged, so we assert it is
    *approaching* the PCA optimum (within 3x; ratio shrinks with steps —
    measured 3.7@400, 2.4@800)."""
    tr, te = corpus
    res = trainer.train(small_cfg(steps=800, weight_decay=0.0), tr,
                        log_every=999)
    xh = np.asarray(rae.reconstruct(res.params, jnp.asarray(te)))
    err_ae = np.mean(np.sum((xh - te) ** 2, -1))
    p = baselines.PCA(12).fit(tr)
    recon = p.transform(te) @ p.components_.T + p.mean_
    err_pca = np.mean(np.sum((recon - te) ** 2, -1))
    assert err_ae < 3.0 * err_pca


def test_batch_sampler_deterministic(corpus):
    tr, _ = corpus
    s1 = trainer._batch_sampler(tr, 16, seed=7)
    s2 = trainer._batch_sampler(tr, 16, seed=7)
    np.testing.assert_array_equal(s1(123), s2(123))
    assert not np.array_equal(s1(123), s1(124))


def test_spectral_analyze_consistency():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(10, 30)).astype(np.float32)
    st = spectral.analyze(jnp.asarray(w))
    s_np = np.linalg.svd(w, compute_uv=False)
    assert float(st.sigma_max) == pytest.approx(s_np[0], rel=1e-4)
    assert float(st.sigma_min) == pytest.approx(s_np[-1], rel=1e-4)
    assert float(st.condition_number) == pytest.approx(s_np[0] / s_np[-1],
                                                       rel=1e-3)
