"""Optional-hypothesis shim.

The container may not ship ``hypothesis``. Importing ``given / settings /
st`` from here instead of from ``hypothesis`` keeps every non-property test
in a module runnable: when hypothesis is missing, ``@given`` marks the test
skipped (with a reason) instead of the whole module erroring at collection.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _Strategies:
        """Accepts any ``st.<strategy>(...)`` call at decoration time."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None

            return strategy

    st = _Strategies()
