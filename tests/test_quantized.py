"""Quantized index tier: codecs, factory grammar, persistence, acceptance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core.metrics import recall_at_k
from repro.data import synthetic
from repro.search import quantize as qz

jax.config.update("jax_platform_name", "cpu")

QUANT_SPECS = ["SQ8", "PQ4x8", "IVF32,SQ8", "IVF32,PQ4x8"]


@pytest.fixture(scope="module")
def corpus():
    return synthetic.embedding_corpus(2000, 32, n_clusters=8, intrinsic=12,
                                      seed=7)


@pytest.fixture(scope="module")
def queries(corpus):
    rng = np.random.default_rng(2)
    picks = rng.integers(0, corpus.shape[0], 32)
    return corpus[picks] + 0.01 * rng.standard_normal(
        (32, corpus.shape[1])).astype(np.float32)


@pytest.fixture(scope="module")
def exact(corpus, queries):
    return api.FlatIndex().build(corpus).search(queries, 10)


# ---------------------------------------------------------------------------
# Spec grammar
# ---------------------------------------------------------------------------
def test_parse_quant_stages():
    s = api.parse_index_spec("RAE64,IVF256,PQ8x8,Rerank4")
    assert s == api.IndexSpec(reducer="rae", out_dim=64, base="ivf",
                              n_cells=256, quant="pq", pq_m=8, pq_bits=8,
                              rerank_factor=4)
    s = api.parse_index_spec("RAE32,SQ8")
    assert s.reducer == "rae" and s.base == "flat" and s.quant == "sq8"
    assert api.parse_index_spec("sq8").quant == "sq8"
    assert api.parse_index_spec("pq4x6") == api.IndexSpec(
        quant="pq", pq_m=4, pq_bits=6)
    assert api.parse_index_spec("Flat,SQ8").quant == "sq8"
    # plain specs are untouched (back-compat with PR 1)
    assert api.parse_index_spec("Flat") == api.IndexSpec()


@pytest.mark.parametrize("bad", [
    "SQ4", "SQ8x8", "PQ8", "PQx8", "PQ0x8", "PQ4x9", "PQ4x0",
    "SQ8,Flat", "SQ8,IVF32", "PQ4x8,SQ8", "SQ8,SQ8", "IVF8,SQ8,PQ4x8",
    "SQ8,Rerank2", "PQ4x8,Rerank2", "SQ8,PCA8",
])
def test_parse_rejects_bad_quant(bad):
    with pytest.raises(ValueError, match="bad index spec"):
        api.parse_index_spec(bad)


def test_factory_maps_quant_to_classes():
    for spec, cls in [("SQ8", api.SQ8Index), ("PQ4x8", api.PQIndex),
                      ("IVF32,SQ8", api.IVFSQ8Index),
                      ("IVF32,PQ4x8", api.IVFPQIndex)]:
        assert isinstance(api.index_factory(spec), cls), spec


def test_factory_quant_euclidean_only():
    with pytest.raises(ValueError, match="euclidean only"):
        api.index_factory("SQ8", metric="cosine")


# ---------------------------------------------------------------------------
# Codecs
# ---------------------------------------------------------------------------
def test_sq8_roundtrip_bound(corpus):
    sq = qz.sq8_train(corpus)
    codes = qz.sq8_encode(sq, corpus)
    assert np.asarray(codes).dtype == np.uint8
    err = np.abs(np.asarray(qz.sq8_decode(sq, codes)) - corpus)
    bound = np.asarray(sq.step)[None, :] / 2
    assert np.all(err <= bound + 1e-6)


def test_pq_dim_not_divisible_raises():
    with pytest.raises(ValueError, match="not divisible"):
        qz.pq_train(np.zeros((64, 30), np.float32), m=4)


def test_pq_encode_decode_shrinks_error_with_ksub(corpus):
    """More centroids per subspace -> strictly better reconstruction."""
    errs = []
    for bits in (2, 4, 8):
        pq = qz.pq_train(corpus, m=4, bits=bits, iters=10, seed=0)
        codes = qz.pq_encode(pq, corpus)
        assert np.asarray(codes).dtype == np.uint8
        rec = np.asarray(qz.pq_decode(pq, codes))
        errs.append(float(np.mean(np.sum((rec - corpus) ** 2, -1))))
    assert errs[0] > errs[1] > errs[2]


def test_pq_adc_lut_gather_equals_decoded_distance(corpus, queries):
    pq = qz.pq_train(corpus, m=4, bits=6, iters=8, seed=1)
    codes = qz.pq_encode(pq, corpus[:300])
    lut = qz.pq_adc_lut(pq, queries)
    adc = np.asarray(qz.pq_adc_gather(lut, codes))
    rec = np.asarray(qz.pq_decode(pq, codes))
    exact = ((queries[:, None, :] - rec[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(adc, exact, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Index behaviour
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", QUANT_SPECS)
def test_quant_index_search_and_roundtrip(spec, corpus, queries, tmp_path):
    idx = api.index_factory(spec).build(corpus)
    assert idx.ntotal == corpus.shape[0]
    res = idx.search(queries, 10)
    assert res.indices.shape == (32, 10)
    assert res.latency_s > 0
    valid = res.indices >= 0
    assert np.all(valid)  # 2000 rows, nprobe*cap >> 10: no pads expected
    idx.save(str(tmp_path / "q"))
    idx2 = api.load_index(str(tmp_path / "q"))
    assert type(idx2) is type(idx)
    res2 = idx2.search(queries, 10)
    np.testing.assert_array_equal(res2.indices, res.indices)
    np.testing.assert_allclose(res2.scores, res.scores, rtol=1e-5)


@pytest.mark.parametrize("spec,bound", [("SQ8", 36), ("PQ4x8", 4),
                                        ("IVF32,SQ8", 40),
                                        ("IVF32,PQ4x8", 8)])
def test_bytes_per_vector(spec, bound, corpus):
    idx = api.index_factory(spec).build(corpus)
    assert idx.bytes_per_vector == bound
    # every quantized tier beats f32 flat storage (32 dims * 4 bytes)
    assert idx.bytes_per_vector < 32 * 4 + 1


def test_sq8_recall_near_exact(corpus, queries, exact):
    """SQ8 error (step/2 per dim) barely perturbs the ranking."""
    res = api.index_factory("SQ8").build(corpus).search(queries, 10)
    rec = recall_at_k(res.indices, exact.indices)
    assert rec >= 0.95, rec


def test_sq8_scan_matches_decoded_flat_scan(corpus, queries):
    """The dequant-free form must equal brute force on decoded codes."""
    idx = api.index_factory("SQ8").build(corpus)
    res = idx.search(queries, 10)
    dec = np.asarray(qz.sq8_decode(idx._sq, idx._codes))
    ref = api.FlatIndex().build(dec).search(queries, 10)
    np.testing.assert_allclose(res.scores, ref.scores, rtol=1e-3, atol=1e-3)
    same = (res.indices == ref.indices).mean()
    assert same > 0.99  # ties may swap


def test_pq_index_uses_adc_not_decode(corpus, queries):
    """PQIndex scores == the pq_adc kernel ref on its own codes."""
    from repro.kernels.pq_adc.ref import pq_adc_ref

    idx = api.index_factory("PQ4x8").build(corpus)
    res = idx.search(queries, 10)
    vr, ir = pq_adc_ref(jnp.asarray(queries), idx._pq.codebooks,
                        idx._codes, 10)
    np.testing.assert_allclose(res.scores, np.asarray(vr), rtol=1e-4,
                               atol=1e-4)


def test_ivfpq_short_probe_pads(queries):
    """k beyond the probed capacity pads with -1/-inf (FAISS semantics)."""
    tiny = synthetic.embedding_corpus(200, 32, n_clusters=8, intrinsic=12,
                                      seed=5)
    idx = api.IVFPQIndex(n_cells=64, m=4, nprobe=2, cell_cap=4)
    idx.build(tiny)
    res = idx.search(queries, 20)  # probed capacity = 2*4 = 8 < 20
    assert res.indices.shape == (32, 20)
    assert np.all(res.indices[:, 8:] == -1)
    assert np.all(np.isneginf(res.scores[:, 8:]))
    valid = res.indices >= 0
    assert np.all(np.isfinite(res.scores[valid]))


@pytest.mark.parametrize("spec", ["SQ8", "PQ8x8", "PQ8x4"])
def test_bytes_per_vector_matches_persisted_payload(spec, corpus, tmp_path):
    """``bytes_per_vector`` is an *accounting claim* about stored state —
    pin it to the ground truth: the per-row arrays actually persisted in
    arrays.npz (leading axis == ntotal), in bytes, divided by N. Catches
    both directions of drift: a codec growing a per-row array without
    reporting it, and an accounting formula (e.g. a bit-packed m*bits/8
    for PQ) that flatters storage the codes don't actually achieve."""
    idx = api.index_factory(spec).build(corpus)
    idx.save(str(tmp_path / "q"))
    n = idx.ntotal
    with np.load(tmp_path / "q" / "arrays.npz") as arrays:
        payload = sum(a.nbytes for a in arrays.values()
                      if a.ndim >= 1 and a.shape[0] == n)
    assert payload > 0
    assert idx.bytes_per_vector == payload / n


def test_pq_trains_and_serves_on_tiny_corpus(tmp_path):
    """n=7 < 2**bits: pq_train clamps ksub to n, and every downstream
    consumer (encode, ADC scan, save/load, fingerprint) must derive ksub
    from the codebook shape — never from 2**bits."""
    rng = np.random.default_rng(3)
    tiny = rng.normal(size=(7, 16)).astype(np.float32)
    idx = api.index_factory("PQ4x8").build(tiny)
    assert idx._pq.ksub == 7  # clamped, not 256
    res = idx.search(tiny, 3)
    assert res.indices.shape == (7, 3)
    assert np.all(res.indices >= 0)
    # each row's own reconstruction is its nearest: self-recall holds even
    # with a 7-centroid codebook (every row is near a centroid)
    assert (res.indices[:, 0] == np.arange(7)).mean() >= 0.7
    idx.save(str(tmp_path / "tiny"))
    idx2 = api.load_index(str(tmp_path / "tiny"))
    assert idx2._pq.ksub == 7
    assert idx2.fingerprint() == idx.fingerprint()
    res2 = idx2.search(tiny, 3)
    np.testing.assert_array_equal(res2.indices, res.indices)


def test_twostage_over_pq_base(corpus, queries, exact):
    """Reducer + PQ base + full-space rerank — the compounding story."""
    idx = api.index_factory("PCA8,PQ4x8,Rerank8")
    idx.build(corpus)
    res = idx.search(queries, 10)
    rec = recall_at_k(res.indices, exact.indices)
    assert rec >= 0.5, rec
    assert idx.bytes_per_vector == 4  # stage-1 payload: 4 PQ bytes


# ---------------------------------------------------------------------------
# Acceptance: the ISSUE 2 criterion
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.timeout(900)
def test_acceptance_20k_ivfpq_recall_and_memory(tmp_path, acceptance_corpus,
                                                acceptance_queries,
                                                acceptance_gt):
    """``RAE64,IVF256,PQ8x8,Rerank4`` builds, saves, reloads, reaches
    recall@10 >= 0.85 vs the exact scan on the shared 20k x 256 acceptance
    fixture, at <= 1/8 the bytes-per-vector of ``RAE64,Flat``."""
    idx = api.index_factory("RAE64,IVF256,PQ8x8,Rerank4",
                            reducer_kw={"steps": 1000, "seed": 0})
    idx.build(acceptance_corpus)
    res = idx.search(acceptance_queries, 10)
    recall = recall_at_k(res.indices, acceptance_gt)
    assert recall >= 0.85, recall

    # memory: reuse the SAME fitted reducer for the uncompressed reference
    ref = api.TwoStageIndex(idx.reducer, api.FlatIndex(), rerank_factor=4)
    ref.build(acceptance_corpus)
    assert idx.bytes_per_vector <= ref.bytes_per_vector / 8, (
        idx.bytes_per_vector, ref.bytes_per_vector)

    idx.save(str(tmp_path / "ivfpq"))
    res2 = api.load_index(str(tmp_path / "ivfpq")).search(acceptance_queries,
                                                          10)
    np.testing.assert_array_equal(res2.indices, res.indices)
