"""HNSW graph tier: structural invariants, ef monotonicity, stats,
factory/persistence integration, 20k acceptance.

Invariants follow the construction contract in ``repro.search.hnsw``:
degree caps (M upper / 2M layer 0), symmetric links *after* pruning,
entry point on the top layer, layer-0 reachability, layer membership.
Each property runs as a deterministic seed sweep (always on) plus a
``hypothesis`` fuzz variant via the optional-dependency shim.
"""
import jax
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro import api
from repro.data import synthetic
from repro.search import hnsw

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def corpus():
    return synthetic.embedding_corpus(2000, 32, n_clusters=8, intrinsic=12,
                                      seed=13)


@pytest.fixture(scope="module")
def queries(corpus):
    rng = np.random.default_rng(4)
    picks = rng.integers(0, corpus.shape[0], 48)
    return corpus[picks] + 0.01 * rng.standard_normal(
        (48, corpus.shape[1])).astype(np.float32)


@pytest.fixture(scope="module")
def graph(corpus):
    return hnsw.build(corpus, M=8, ef_construction=60, seed=0)


# ---------------------------------------------------------------------------
# Structural invariants
# ---------------------------------------------------------------------------
def check_graph_invariants(g: hnsw.HNSWGraph):
    n = g.ntotal
    # entry point sits on the top layer; no node exceeds it
    assert int(g.levels[g.entry]) == int(g.levels.max())
    assert np.all(g.levels <= g.levels[g.entry])
    for layer in range(g.max_level + 1):
        adj = g.adjacency(layer)
        cap = 2 * g.M if layer == 0 else g.M
        deg = (adj >= 0).sum(axis=1)
        # degree cap
        assert deg.max() <= cap, (layer, int(deg.max()), cap)
        src, slot = np.nonzero(adj >= 0)
        dst = adj[src, slot]
        # links stay inside the corpus and never self-loop
        assert np.all((dst >= 0) & (dst < n))
        assert np.all(src != dst)
        # both endpoints are members of this layer
        assert np.all(g.levels[src] >= layer)
        assert np.all(g.levels[dst] >= layer)
        # no duplicate slots
        assert len(set(zip(src.tolist(), dst.tolist()))) == len(src)
        # bidirectional after pruning: edge set equals its transpose
        edges = set(zip(src.tolist(), dst.tolist()))
        assert all((b, a) in edges for a, b in edges), f"layer {layer}"
    # layer 0 is reachable from the entry point
    assert hnsw._bfs_layer0(g.links0, g.entry).all()


def test_graph_invariants_deterministic(graph):
    check_graph_invariants(graph)


@pytest.mark.parametrize("seed,n,m", [(1, 50, 2), (2, 300, 4), (3, 777, 6),
                                      (4, 120, 16), (5, 1, 4), (6, 2, 4)])
def test_graph_invariants_sweep(seed, n, m):
    x = synthetic.embedding_corpus(max(n, 8), 16, n_clusters=4, intrinsic=8,
                                   seed=seed)[:n]
    check_graph_invariants(hnsw.build(x, M=m, ef_construction=30, seed=seed))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 250),
       m=st.integers(2, 12), efc=st.integers(4, 60))
def test_graph_invariants_fuzz(seed, n, m, efc):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 8)).astype(np.float32)
    check_graph_invariants(hnsw.build(x, M=m, ef_construction=efc, seed=seed))


def test_level_sampling_geometric():
    """Levels follow the floor(-ln(U)/ln(M)) law: P(level >= L) ~ M^-L."""
    lv = hnsw.sample_levels(200_000, 16, seed=0)
    frac1 = float((lv >= 1).mean())
    assert abs(frac1 - 1 / 16) < 0.005
    frac2 = float((lv >= 2).mean())
    assert abs(frac2 - 1 / 256) < 0.002


# ---------------------------------------------------------------------------
# Search behaviour: ef monotonicity + beam padding
# ---------------------------------------------------------------------------
def test_ef_recall_monotone_deterministic(graph, corpus, queries):
    recalls = [hnsw.recall_vs_exact(graph, corpus, queries, 10, ef)
               for ef in (10, 20, 40, 80, 160)]
    for lo, hi in zip(recalls, recalls[1:]):
        assert hi >= lo, recalls
    assert recalls[-1] >= 0.95, recalls


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_ef_recall_monotone_fuzz(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((400, 12)).astype(np.float32)
    g = hnsw.build(x, M=6, ef_construction=40, seed=seed)
    q = x[:16] + 0.01 * rng.standard_normal((16, 12)).astype(np.float32)
    recalls = [hnsw.recall_vs_exact(g, x, q, 5, ef) for ef in (5, 20, 80)]
    # greedy beams are not *theoretically* monotone query-by-query; allow
    # a hair of noise pairwise but require the sweep to end at least as
    # high as it starts
    for lo, hi in zip(recalls, recalls[1:]):
        assert hi >= lo - 0.02, recalls
    assert recalls[-1] >= recalls[0], recalls


def test_search_pads_when_beam_short(corpus):
    """k beyond the beam/corpus pads with -1/-inf (FAISS convention)."""
    g = hnsw.build(corpus[:6], M=4, ef_construction=20, seed=0)
    scores, ids, _ = hnsw.search(g, corpus[:3], 10)
    assert ids.shape == (3, 10)
    assert np.all(ids[:, 6:] == -1)
    assert np.all(np.isneginf(scores[:, 6:]))
    valid = ids >= 0
    assert np.all(np.isfinite(scores[valid]))


def test_candidate_distances_fused_matches_np():
    """The TPU-routed form (fused kernel; jnp ref off-TPU) must equal the
    host ref, scattered back to input order."""
    rng = np.random.default_rng(7)
    q = rng.standard_normal(24).astype(np.float32)
    vecs = rng.standard_normal((33, 24)).astype(np.float32)
    a = hnsw.candidate_distances(q, vecs, impl="np")
    b = hnsw.candidate_distances(q, vecs, impl="fused")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# distance_evals stats: the sublinearity contract, asserted per tier
# ---------------------------------------------------------------------------
def test_distance_evals_flat_is_n(corpus, queries):
    res = api.FlatIndex().build(corpus).search(queries, 10)
    assert res.distance_evals == corpus.shape[0]


def test_distance_evals_ivf_is_probed_sizes(corpus, queries):
    idx = api.IVFFlatIndex(n_cells=32, nprobe=4).build(corpus)
    res = idx.search(queries, 10)
    # probed sizes: more than k, far less than the full corpus
    assert 10 <= res.distance_evals < corpus.shape[0]
    assert res.stats["centroid_evals"] == 32
    # probing more cells evaluates more distances
    more = api.IVFFlatIndex(n_cells=32, nprobe=16).build(corpus)
    assert more.search(queries, 10).distance_evals > res.distance_evals


def test_distance_evals_hnsw_is_visited_and_sublinear(graph, corpus,
                                                      queries):
    idx = api.HNSWIndex(m=8, ef_construction=60)
    idx._g = graph  # reuse the module-scoped build
    res = idx.search(queries, 10)
    assert 10 <= res.distance_evals < corpus.shape[0]
    # widening the beam visits more
    wide = api.HNSWIndex(m=8, ef_search=256)
    wide._g = graph
    assert wide.search(queries, 10).distance_evals > res.distance_evals


def test_distance_evals_two_stage_composes(corpus, queries):
    idx = api.TwoStageIndex(api.make_reducer("pca", 8),
                            api.HNSWIndex(m=8, ef_construction=60),
                            rerank_factor=4)
    idx.build(corpus)
    res = idx.search(queries, 10)
    k1 = 10 * 4 * api.HNSWIndex.stage1_oversample
    assert res.stats["rerank_evals"] == k1
    assert res.distance_evals == (res.stats["stage1_distance_evals"] + k1)


# ---------------------------------------------------------------------------
# Factory + persistence integration
# ---------------------------------------------------------------------------
def test_factory_hnsw_knobs_flow_through():
    idx = api.index_factory("HNSW16", index_kw={"ef_construction": 33,
                                                "ef_search": 44, "seed": 5})
    assert isinstance(idx, api.HNSWIndex)
    assert (idx.m, idx.ef_construction, idx.ef_search, idx.seed) == \
        (16, 33, 44, 5)
    stack = api.index_factory("RAE64,HNSW32,Rerank4")
    assert isinstance(stack, api.TwoStageIndex)
    assert isinstance(stack.base, api.HNSWIndex)
    assert stack.rerank_factor == 4


def test_factory_hnsw_rejects_cosine_and_quant():
    with pytest.raises(ValueError, match="euclidean only"):
        api.index_factory("HNSW32", metric="cosine")
    with pytest.raises(ValueError, match="bad index spec"):
        api.parse_index_spec("HNSW32,SQ8")


def test_hnsw_save_load_roundtrip_with_upper_layers(tmp_path):
    """Force a multi-layer graph (small M -> tall hierarchy) and check the
    adjacency stack round-trips bit-exact."""
    x = synthetic.embedding_corpus(600, 16, n_clusters=4, intrinsic=8,
                                   seed=21)
    idx = api.HNSWIndex(m=4, ef_construction=40, seed=3).build(x)
    assert idx._g.max_level >= 1  # the point of the test
    res = idx.search(x[:16], 5)
    idx.save(str(tmp_path / "g"))
    idx2 = api.load_index(str(tmp_path / "g"))
    assert isinstance(idx2, api.HNSWIndex)
    np.testing.assert_array_equal(idx2._g.links0, idx._g.links0)
    np.testing.assert_array_equal(idx2._g.links, idx._g.links)
    np.testing.assert_array_equal(idx2._g.levels, idx._g.levels)
    assert idx2._g.entry == idx._g.entry
    res2 = idx2.search(x[:16], 5)
    np.testing.assert_array_equal(res2.indices, res.indices)
    check_graph_invariants(idx2._g)


def test_bytes_per_vector_accounts_links(corpus):
    idx = api.HNSWIndex(m=8, ef_construction=40).build(corpus)
    d = corpus.shape[1]
    # vector + layer-0 slots at least; strictly more than flat storage
    assert idx.bytes_per_vector >= d * 4 + 4 * 2 * 8
    flat = api.FlatIndex().build(corpus)
    assert idx.bytes_per_vector > flat.bytes_per_vector


# ---------------------------------------------------------------------------
# Acceptance: the ISSUE 3 criterion, on the shared 20k fixture
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.timeout(900)
def test_acceptance_20k_hnsw_recall_and_sublinearity(tmp_path,
                                                     acceptance_corpus,
                                                     acceptance_queries,
                                                     acceptance_gt):
    """``RAE64,HNSW32,Rerank4`` reaches recall@10 >= 0.9 vs the exact scan
    while evaluating distances on < 10% of the corpus per query (the
    ``distance_evals`` stat), and survives save -> load bit-exact."""
    idx = api.index_factory("RAE64,HNSW32,Rerank4",
                            reducer_kw={"steps": 1000, "seed": 0})
    idx.build(acceptance_corpus)
    res = idx.search(acceptance_queries, 10)
    recall = (acceptance_gt[:, :, None] ==
              res.indices[:, None, :]).any(-1).mean()
    assert recall >= 0.9, recall

    n = acceptance_corpus.shape[0]
    assert res.distance_evals < 0.10 * n, (res.distance_evals, n)
    check_graph_invariants(idx.base._g)

    idx.save(str(tmp_path / "hnsw"))
    res2 = api.load_index(str(tmp_path / "hnsw")).search(acceptance_queries,
                                                         10)
    np.testing.assert_array_equal(res2.indices, res.indices)
