"""HNSW graph tier: structural invariants, ef monotonicity, stats,
factory/persistence integration, 20k acceptance.

Invariants follow the construction contract in ``repro.search.hnsw``:
degree caps (M upper / 2M layer 0), symmetric links *after* pruning,
entry point on the top layer, layer-0 reachability, layer membership.
Each property runs as a deterministic seed sweep (always on) plus a
``hypothesis`` fuzz variant via the optional-dependency shim.
"""
import jax
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro import api
from repro.data import synthetic
from repro.search import hnsw

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def corpus():
    return synthetic.embedding_corpus(2000, 32, n_clusters=8, intrinsic=12,
                                      seed=13)


@pytest.fixture(scope="module")
def queries(corpus):
    rng = np.random.default_rng(4)
    picks = rng.integers(0, corpus.shape[0], 48)
    return corpus[picks] + 0.01 * rng.standard_normal(
        (48, corpus.shape[1])).astype(np.float32)


@pytest.fixture(scope="module")
def graph(corpus):
    return hnsw.build(corpus, M=8, ef_construction=60, seed=0)


# ---------------------------------------------------------------------------
# Structural invariants
# ---------------------------------------------------------------------------
def check_graph_invariants(g: hnsw.HNSWGraph):
    n = g.ntotal
    # entry point sits on the top layer; no node exceeds it
    assert int(g.levels[g.entry]) == int(g.levels.max())
    assert np.all(g.levels <= g.levels[g.entry])
    for layer in range(g.max_level + 1):
        adj = g.adjacency(layer)
        cap = 2 * g.M if layer == 0 else g.M
        deg = (adj >= 0).sum(axis=1)
        # degree cap
        assert deg.max() <= cap, (layer, int(deg.max()), cap)
        src, slot = np.nonzero(adj >= 0)
        dst = adj[src, slot]
        # links stay inside the corpus and never self-loop
        assert np.all((dst >= 0) & (dst < n))
        assert np.all(src != dst)
        # both endpoints are members of this layer
        assert np.all(g.levels[src] >= layer)
        assert np.all(g.levels[dst] >= layer)
        # no duplicate slots
        assert len(set(zip(src.tolist(), dst.tolist()))) == len(src)
        # bidirectional after pruning: edge set equals its transpose
        edges = set(zip(src.tolist(), dst.tolist()))
        assert all((b, a) in edges for a, b in edges), f"layer {layer}"
    # layer 0 is reachable from the entry point
    assert hnsw._bfs_layer0(g.links0, g.entry).all()


def test_graph_invariants_deterministic(graph):
    check_graph_invariants(graph)


@pytest.mark.parametrize("seed,n,m", [(1, 50, 2), (2, 300, 4), (3, 777, 6),
                                      (4, 120, 16), (5, 1, 4), (6, 2, 4)])
def test_graph_invariants_sweep(seed, n, m):
    x = synthetic.embedding_corpus(max(n, 8), 16, n_clusters=4, intrinsic=8,
                                   seed=seed)[:n]
    check_graph_invariants(hnsw.build(x, M=m, ef_construction=30, seed=seed))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 250),
       m=st.integers(2, 12), efc=st.integers(4, 60))
def test_graph_invariants_fuzz(seed, n, m, efc):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, 8)).astype(np.float32)
    check_graph_invariants(hnsw.build(x, M=m, ef_construction=efc, seed=seed))


def test_level_sampling_geometric():
    """Levels follow the floor(-ln(U)/ln(M)) law: P(level >= L) ~ M^-L."""
    lv = hnsw.sample_levels(200_000, 16, seed=0)
    frac1 = float((lv >= 1).mean())
    assert abs(frac1 - 1 / 16) < 0.005
    frac2 = float((lv >= 2).mean())
    assert abs(frac2 - 1 / 256) < 0.002


# ---------------------------------------------------------------------------
# Search behaviour: ef monotonicity + beam padding
# ---------------------------------------------------------------------------
def test_ef_recall_monotone_deterministic(graph, corpus, queries):
    recalls = [hnsw.recall_vs_exact(graph, corpus, queries, 10, ef)
               for ef in (10, 20, 40, 80, 160)]
    for lo, hi in zip(recalls, recalls[1:]):
        assert hi >= lo, recalls
    assert recalls[-1] >= 0.95, recalls


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_ef_recall_monotone_fuzz(seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((400, 12)).astype(np.float32)
    g = hnsw.build(x, M=6, ef_construction=40, seed=seed)
    q = x[:16] + 0.01 * rng.standard_normal((16, 12)).astype(np.float32)
    recalls = [hnsw.recall_vs_exact(g, x, q, 5, ef) for ef in (5, 20, 80)]
    # greedy beams are not *theoretically* monotone query-by-query; allow
    # a hair of noise pairwise but require the sweep to end at least as
    # high as it starts
    for lo, hi in zip(recalls, recalls[1:]):
        assert hi >= lo - 0.02, recalls
    assert recalls[-1] >= recalls[0], recalls


def test_search_pads_when_beam_short(corpus):
    """k beyond the beam/corpus pads with -1/-inf (FAISS convention)."""
    g = hnsw.build(corpus[:6], M=4, ef_construction=20, seed=0)
    scores, ids, _ = hnsw.search(g, corpus[:3], 10)
    assert ids.shape == (3, 10)
    assert np.all(ids[:, 6:] == -1)
    assert np.all(np.isneginf(scores[:, 6:]))
    valid = ids >= 0
    assert np.all(np.isfinite(scores[valid]))


def test_candidate_distances_fused_matches_np():
    """The TPU-routed form (fused kernel; jnp ref off-TPU) must equal the
    host ref, scattered back to input order."""
    rng = np.random.default_rng(7)
    q = rng.standard_normal(24).astype(np.float32)
    vecs = rng.standard_normal((33, 24)).astype(np.float32)
    a = hnsw.candidate_distances(q, vecs, impl="np")
    b = hnsw.candidate_distances(q, vecs, impl="fused")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Batched (array-native) traversal vs the sequential heapq beam
# ---------------------------------------------------------------------------
def test_batched_frontier1_matches_sequential_exactly(graph, queries):
    """At frontier=1 the batched loop expands in the identical best-first
    order: neighbor sets AND eval counters match the heapq engine
    query-for-query, at every beam width."""
    for ef in (10, 40, 128):
        s_sc, s_id, s_ev = hnsw.search(graph, queries, 10, ef_search=ef)
        b_sc, b_id, b_ev, hops = hnsw.search_batched(
            graph, queries, 10, ef_search=ef, impl="np", frontier=1)
        np.testing.assert_array_equal(s_id, b_id)
        np.testing.assert_array_equal(s_ev, b_ev)
        assert hops > 0


def test_batched_default_frontier_recall_and_evals_bound(graph, corpus,
                                                         queries):
    """The default multi-expansion frontier (E=8): recall at equal
    efSearch identical to sequential within 0.01, >= 99% of returned
    neighbor sets identical, eval counters within the documented 10%."""
    import jax.numpy as jnp

    from repro.core.metrics import knn_indices
    gt = np.asarray(knn_indices(jnp.asarray(queries), jnp.asarray(corpus),
                                10))
    s_sc, s_id, s_ev = hnsw.search(graph, queries, 10, ef_search=80)
    b_sc, b_id, b_ev, _ = hnsw.search_batched(graph, queries, 10,
                                              ef_search=80, impl="np")
    rec = lambda ids: np.mean([len(set(a) & set(b)) / 10
                               for a, b in zip(gt, ids)])
    assert abs(rec(s_id) - rec(b_id)) <= 0.01
    same = np.mean([set(a.tolist()) == set(b.tolist())
                    for a, b in zip(s_id, b_id)])
    assert same >= 0.99, same
    ratio = b_ev.mean() / s_ev.mean()
    assert 0.9 <= ratio <= 1.1, ratio


def test_batched_drivers_agree(graph, queries):
    """The one-dispatch jitted driver returns the same neighbors and the
    same eval counters as the host-driven numpy driver (both at the exact
    best-first order the jit driver always uses)."""
    n_sc, n_id, n_ev, _ = hnsw.search_batched(graph, queries[:8], 10,
                                              ef_search=64, impl="np",
                                              frontier=1)
    j_sc, j_id, j_ev, _ = hnsw.search_batched(graph, queries[:8], 10,
                                              ef_search=64, impl="jit")
    np.testing.assert_array_equal(n_id, j_id)
    np.testing.assert_array_equal(n_ev, j_ev)
    np.testing.assert_allclose(n_sc, j_sc, rtol=1e-5, atol=1e-5)


def test_batched_deterministic_and_row_independent(graph, queries):
    """Fixed batch -> bitwise-identical reruns; and every row's answer is
    independent of its batch-mates (the serving-cache contract: a query
    answers the same alone and coalesced)."""
    q = queries[:12]
    r1 = hnsw.search_batched(graph, q, 10, ef_search=64, impl="np")
    r2 = hnsw.search_batched(graph, q, 10, ef_search=64, impl="np")
    for a, b in zip(r1[:3], r2[:3]):
        np.testing.assert_array_equal(a, b)
    for i in (0, 5, 11):
        solo = hnsw.search_batched(graph, q[i:i + 1], 10, ef_search=64,
                                   impl="np")
        np.testing.assert_array_equal(solo[0][0], r1[0][i])  # scores bitwise
        np.testing.assert_array_equal(solo[1][0], r1[1][i])


def test_batched_ragged_shapes(corpus):
    """q=1, q not a power of two, k > efSearch, and k > ntotal all follow
    the sequential engine's shape/padding contract."""
    g = hnsw.build(corpus[:300], M=6, ef_construction=40, seed=1)
    for nq in (1, 5):
        q = corpus[:nq]
        s = hnsw.search(g, q, 7, ef_search=3)   # ef < k -> ef = k
        b = hnsw.search_batched(g, q, 7, ef_search=3, impl="np")
        assert b[0].shape == (nq, 7) and b[1].shape == (nq, 7)
        np.testing.assert_array_equal(s[1], b[1])
    # k beyond the corpus: FAISS pad convention, same as sequential
    tiny = hnsw.build(corpus[:6], M=4, ef_construction=20, seed=0)
    sc, ids, ev, _ = hnsw.search_batched(tiny, corpus[:3], 10, impl="np")
    assert ids.shape == (3, 10)
    assert np.all(ids[:, 6:] == -1)
    assert np.all(np.isneginf(sc[:, 6:]))
    assert np.all(np.isfinite(sc[ids >= 0]))


def test_batched_disconnected_node(corpus):
    """A node unreachable from the entry point is never returned, and the
    short beam pads instead of crashing (graph hand-mutated: the build
    path guarantees connectivity, so sever it manually)."""
    g = hnsw.build(corpus[:8], M=4, ef_construction=20, seed=0)
    # sever node furthest from entry: drop all its links, both directions
    victim = max(range(8), key=lambda i: 0 if i == g.entry else
                 float(((g.vecs[i] - g.vecs[g.entry]) ** 2).sum()))
    g.links0[victim] = -1
    g.links0[g.links0 == victim] = -1
    g.links[g.links == victim] = -1
    g.packed = None  # graph mutated after pack: recompile
    sc, ids, ev, _ = hnsw.search_batched(g, corpus[:4], 8, impl="np")
    assert not np.any(ids == victim)
    assert np.all(ids[:, 7:] == -1)          # only 7 reachable nodes
    assert np.all(np.isneginf(sc[:, 7:]))


def test_hnsw_index_engine_routing(corpus):
    """``batched='auto'`` serves lone queries on the sequential engine
    and batches on the array-native one (``beam_hops`` in stats marks the
    batched path); True/False pin either engine."""
    idx = api.HNSWIndex(m=8, ef_construction=40).build(corpus[:500])
    assert idx._g.packed is not None         # build packs eagerly
    lone = idx.search(corpus[:1], 5)
    assert "beam_hops" not in lone.stats
    batch = idx.search(corpus[:4], 5)
    assert batch.stats.get("beam_hops", 0) > 0
    pinned = api.HNSWIndex(m=8, ef_construction=40, batched=True)
    pinned._g = idx._g
    assert "beam_hops" in pinned.search(corpus[:1], 5).stats
    seq = api.HNSWIndex(m=8, ef_construction=40, batched=False)
    seq._g = idx._g
    assert "beam_hops" not in seq.search(corpus[:4], 5).stats
    # both engines return the same neighbors either way
    np.testing.assert_array_equal(batch.indices,
                                  seq.search(corpus[:4], 5).indices)


# ---------------------------------------------------------------------------
# Quantized graph payloads: SQ8/PQ codes inside the batched traversal
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module", params=["sq8", "pq"])
def quant_graph(request, corpus):
    kw = {"quant": request.param}
    if request.param == "pq":
        kw.update(pq_m=8, pq_bits=8)
    idx = api.HNSWIndex(m=8, ef_construction=60, seed=0, **kw)
    return idx.build(corpus[:800])


def test_quant_graph_drivers_agree(quant_graph, queries):
    """np and jit drivers score the same code payload: identical neighbor
    ids and eval counters at frontier=1, scores allclose."""
    g = quant_graph._g
    assert g.codec is not None and g.codec.kind == quant_graph.quant
    n_sc, n_id, n_ev, _ = hnsw.search_batched(g, queries[:8], 10,
                                              ef_search=64, impl="np",
                                              frontier=1)
    j_sc, j_id, j_ev, _ = hnsw.search_batched(g, queries[:8], 10,
                                              ef_search=64, impl="jit")
    np.testing.assert_array_equal(n_id, j_id)
    np.testing.assert_array_equal(n_ev, j_ev)
    np.testing.assert_allclose(n_sc, j_sc, rtol=1e-4, atol=1e-4)


def test_quant_graph_deterministic_and_row_independent(quant_graph, queries):
    """The serving-cache contract holds over codes too: bitwise-stable
    reruns, and each row answers the same alone and coalesced."""
    g = quant_graph._g
    q = queries[:12]
    r1 = hnsw.search_batched(g, q, 10, ef_search=64, impl="np")
    r2 = hnsw.search_batched(g, q, 10, ef_search=64, impl="np")
    for a, b in zip(r1[:3], r2[:3]):
        np.testing.assert_array_equal(a, b)
    for i in (0, 11):
        solo = hnsw.search_batched(g, q[i:i + 1], 10, ef_search=64,
                                   impl="np")
        np.testing.assert_array_equal(solo[0][0], r1[0][i])
        np.testing.assert_array_equal(solo[1][0], r1[1][i])


def test_quant_graph_ragged_shapes(corpus):
    """q=1, ef < k, and k > ntotal keep the sequential engine's
    shape/padding contract when the hop reads codes."""
    for quant in ("sq8", "pq"):
        kw = {"pq_m": 8} if quant == "pq" else {}
        idx = api.HNSWIndex(m=6, ef_construction=40, seed=1, quant=quant,
                            **kw).build(corpus[:300])
        for nq in (1, 5):
            sc, ids, ev, _ = hnsw.search_batched(idx._g, corpus[:nq], 7,
                                                 ef_search=3, impl="np")
            assert sc.shape == (nq, 7) and ids.shape == (nq, 7)
            assert np.all(ids >= 0)
        tiny = api.HNSWIndex(m=4, ef_construction=20, seed=0,
                             quant=quant, **kw).build(corpus[:6])
        sc, ids, ev, _ = hnsw.search_batched(tiny._g, corpus[:3], 10,
                                             impl="np")
        assert ids.shape == (3, 10)
        assert np.all(ids[:, 6:] == -1)
        assert np.all(np.isneginf(sc[:, 6:]))
        assert np.all(np.isfinite(sc[ids >= 0]))


def test_quant_graph_recall_close_to_f32(quant_graph, corpus, queries):
    """Pre-rerank neighbor quality over codes tracks the f32 traversal:
    recall@10 vs exact within the codec's documented slack (SQ8 is
    near-exact; raw PQ8x8 ordering is noisy — the Rerank stage recovers
    it, see the acceptance test)."""
    import jax.numpy as jnp

    from repro.core.metrics import knn_indices
    x = corpus[:800]
    gt = np.asarray(knn_indices(jnp.asarray(queries), jnp.asarray(x), 10))
    f32 = api.HNSWIndex(m=8, ef_construction=60, seed=0).build(x)
    rec = lambda idx: np.mean([len(set(a) & set(b)) / 10 for a, b in zip(
        gt, idx.search(queries, 10).indices)])
    slack = 0.02 if quant_graph.quant == "sq8" else 0.35
    assert rec(quant_graph) >= rec(f32) - slack


def test_quant_graph_lone_query_pins_batched(quant_graph, queries):
    """quant pins ALL queries to the batched engine — the sequential heapq
    scores f32 rows, which would break row-independent caching."""
    res = quant_graph.search(queries[:1], 5)
    assert res.stats.get("beam_hops", 0) > 0
    assert "gather_bytes_per_hop" in res.stats


def test_quant_graph_gather_bytes_stat(corpus, queries):
    """The traversal-traffic accounting: bytes/hop scales with the codec's
    per-row gather width (f32: 4d+4, sq8: d+4, pq: m+4)."""
    x = corpus[:500]
    d = x.shape[1]
    widths = {}
    for quant, width in ((None, 4 * d + 4), ("sq8", d + 4), ("pq", 8 + 4)):
        kw = {"pq_m": 8} if quant == "pq" else {}
        idx = api.HNSWIndex(m=8, ef_construction=40, seed=0, quant=quant,
                            **kw).build(x)
        res = idx.search(queries[:8], 10)
        per_eval = res.stats["gather_bytes_per_hop"] * \
            res.stats["beam_hops"] / res.distance_evals / 8
        widths[quant] = per_eval
        np.testing.assert_allclose(per_eval, width, rtol=1e-6)
    assert widths[None] / widths["sq8"] >= 3.0
    assert widths[None] / widths["pq"] >= 4.0


def test_quant_graph_save_load_and_fingerprints(quant_graph, corpus,
                                                queries, tmp_path):
    """Codec state round-trips (same neighbors, same fingerprint after
    reload), and the fingerprint separates f32 / SQ8 / PQ builds of the
    same graph — the serving cache must never alias them."""
    res = quant_graph.search(queries[:8], 10)
    quant_graph.save(str(tmp_path / "qg"))
    idx2 = api.load_index(str(tmp_path / "qg"))
    assert isinstance(idx2, api.HNSWIndex)
    assert idx2.quant == quant_graph.quant
    assert idx2._g.codec is not None
    assert idx2.fingerprint() == quant_graph.fingerprint()
    res2 = idx2.search(queries[:8], 10)
    np.testing.assert_array_equal(res2.indices, res.indices)
    np.testing.assert_allclose(res2.scores, res.scores, rtol=1e-5)
    f32 = api.HNSWIndex(m=8, ef_construction=60, seed=0).build(corpus[:800])
    assert f32.fingerprint() != quant_graph.fingerprint()


def test_quant_graph_fingerprints_distinct_across_codecs(corpus):
    x = corpus[:300]
    fps = {q: api.HNSWIndex(m=6, ef_construction=40, seed=0, quant=q)
           .build(x).fingerprint() for q in (None, "sq8", "pq")}
    assert len(set(fps.values())) == 3


def test_quant_graph_bytes_per_vector_accounts_codec(corpus):
    x = corpus[:300]
    d = x.shape[1]
    base = api.HNSWIndex(m=6, ef_construction=40, seed=0).build(x)
    sq8 = api.HNSWIndex(m=6, ef_construction=40, seed=0,
                        quant="sq8").build(x)
    pq = api.HNSWIndex(m=6, ef_construction=40, seed=0, quant="pq",
                       pq_m=8).build(x)
    assert sq8.bytes_per_vector == base.bytes_per_vector + d + 4
    assert pq.bytes_per_vector == base.bytes_per_vector + 8 + 4


# ---------------------------------------------------------------------------
# distance_evals stats: the sublinearity contract, asserted per tier
# ---------------------------------------------------------------------------
def test_distance_evals_flat_is_n(corpus, queries):
    res = api.FlatIndex().build(corpus).search(queries, 10)
    assert res.distance_evals == corpus.shape[0]


def test_distance_evals_ivf_is_probed_sizes(corpus, queries):
    idx = api.IVFFlatIndex(n_cells=32, nprobe=4).build(corpus)
    res = idx.search(queries, 10)
    # probed sizes: more than k, far less than the full corpus
    assert 10 <= res.distance_evals < corpus.shape[0]
    assert res.stats["centroid_evals"] == 32
    # probing more cells evaluates more distances
    more = api.IVFFlatIndex(n_cells=32, nprobe=16).build(corpus)
    assert more.search(queries, 10).distance_evals > res.distance_evals


def test_distance_evals_hnsw_is_visited_and_sublinear(graph, corpus,
                                                      queries):
    idx = api.HNSWIndex(m=8, ef_construction=60)
    idx._g = graph  # reuse the module-scoped build
    res = idx.search(queries, 10)
    assert 10 <= res.distance_evals < corpus.shape[0]
    # widening the beam visits more
    wide = api.HNSWIndex(m=8, ef_search=256)
    wide._g = graph
    assert wide.search(queries, 10).distance_evals > res.distance_evals


def test_distance_evals_two_stage_composes(corpus, queries):
    idx = api.TwoStageIndex(api.make_reducer("pca", 8),
                            api.HNSWIndex(m=8, ef_construction=60),
                            rerank_factor=4)
    idx.build(corpus)
    res = idx.search(queries, 10)
    k1 = 10 * 4 * api.HNSWIndex.stage1_oversample
    assert res.stats["rerank_evals"] == k1
    assert res.distance_evals == (res.stats["stage1_distance_evals"] + k1)


# ---------------------------------------------------------------------------
# Factory + persistence integration
# ---------------------------------------------------------------------------
def test_factory_hnsw_knobs_flow_through():
    idx = api.index_factory("HNSW16", index_kw={"ef_construction": 33,
                                                "ef_search": 44, "seed": 5})
    assert isinstance(idx, api.HNSWIndex)
    assert (idx.m, idx.ef_construction, idx.ef_search, idx.seed) == \
        (16, 33, 44, 5)
    stack = api.index_factory("RAE64,HNSW32,Rerank4")
    assert isinstance(stack, api.TwoStageIndex)
    assert isinstance(stack.base, api.HNSWIndex)
    assert stack.rerank_factor == 4


def test_factory_hnsw_rejects_cosine():
    with pytest.raises(ValueError, match="euclidean only"):
        api.index_factory("HNSW32", metric="cosine")


@pytest.mark.parametrize("spec", ["HNSW32,SQ8", "HNSW16,PQ8x8",
                                  "RAE64,HNSW32,SQ8,Rerank4",
                                  "RAE64,HNSW32,PQ8x8,Rerank4"])
def test_factory_quant_graph_specs_parse_and_roundtrip(spec):
    """Quantized payloads compose with the graph base (the ISSUE 8 grammar
    opening), and parse(str(spec)) round-trips."""
    parsed = api.parse_index_spec(spec)
    assert parsed.base == "hnsw"
    assert api.parse_index_spec(str(parsed)) == parsed


def test_factory_quant_graph_knobs_flow_through():
    idx = api.index_factory("HNSW16,SQ8")
    assert isinstance(idx, api.HNSWIndex)
    assert (idx.m, idx.quant) == (16, "sq8")
    pq = api.index_factory("HNSW16,PQ4x6")
    assert (pq.quant, pq.pq_m, pq.pq_bits) == ("pq", 4, 6)
    # PQ navigation is noisy: the instance over-fetches harder under a
    # rerank, without touching the class-level default
    assert pq.stage1_oversample == 8
    assert api.HNSWIndex.stage1_oversample == 2


def test_hnsw_save_load_roundtrip_with_upper_layers(tmp_path):
    """Force a multi-layer graph (small M -> tall hierarchy) and check the
    adjacency stack round-trips bit-exact."""
    x = synthetic.embedding_corpus(600, 16, n_clusters=4, intrinsic=8,
                                   seed=21)
    idx = api.HNSWIndex(m=4, ef_construction=40, seed=3).build(x)
    assert idx._g.max_level >= 1  # the point of the test
    res = idx.search(x[:16], 5)
    idx.save(str(tmp_path / "g"))
    idx2 = api.load_index(str(tmp_path / "g"))
    assert isinstance(idx2, api.HNSWIndex)
    np.testing.assert_array_equal(idx2._g.links0, idx._g.links0)
    np.testing.assert_array_equal(idx2._g.links, idx._g.links)
    np.testing.assert_array_equal(idx2._g.levels, idx._g.levels)
    assert idx2._g.entry == idx._g.entry
    res2 = idx2.search(x[:16], 5)
    np.testing.assert_array_equal(res2.indices, res.indices)
    check_graph_invariants(idx2._g)


def test_packed_saved_and_loaded_without_repack(tmp_path):
    """Persistence carries the packed dense adjacency + norms: a reloaded
    index has the packed form in hand (no repack) and answers the batched
    path bitwise-identically."""
    x = synthetic.embedding_corpus(400, 16, n_clusters=4, intrinsic=8,
                                   seed=7)
    idx = api.HNSWIndex(m=6, ef_construction=40, seed=2).build(x)
    res = idx.search(x[:8], 5)
    idx.save(str(tmp_path / "g"))
    idx2 = api.load_index(str(tmp_path / "g"))
    p, p2 = idx._g.pack(), idx2._g.packed
    assert p2 is not None, "load must restore the packed form"
    np.testing.assert_array_equal(p2.nbrs0, p.nbrs0)
    np.testing.assert_array_equal(p2.upper, p.upper)
    np.testing.assert_array_equal(p2.vecs_sq, p.vecs_sq)
    res2 = idx2.search(x[:8], 5)
    np.testing.assert_array_equal(res2.indices, res.indices)
    np.testing.assert_array_equal(res2.scores, res.scores)


def test_fingerprint_covers_packed_form_and_engine(corpus):
    """The serving cache keys on fingerprint(): an index serving the
    packed/batched path can never alias one pinned to the ragged
    sequential engine — and packing (a pure derivation of arrays already
    hashed) can never shift an index's identity as a side effect."""
    x = corpus[:400]
    auto = api.HNSWIndex(m=8, ef_construction=40).build(x)
    seq = api.HNSWIndex(m=8, ef_construction=40, batched=False).build(x)
    assert auto.fingerprint() != seq.fingerprint()
    before = seq.fingerprint()
    seq._g.pack()   # e.g. save() packs a sequential-pinned index
    assert seq.fingerprint() == before


def test_pack_is_idempotent_and_correct(graph):
    p1 = graph.pack()
    assert graph.pack() is p1
    np.testing.assert_array_equal(p1.nbrs0, graph.links0)
    np.testing.assert_allclose(
        p1.vecs_sq, (graph.vecs.astype(np.float32) ** 2).sum(1),
        rtol=1e-6)


def test_bytes_per_vector_accounts_links(corpus):
    idx = api.HNSWIndex(m=8, ef_construction=40).build(corpus)
    d = corpus.shape[1]
    # vector + layer-0 slots at least; strictly more than flat storage
    assert idx.bytes_per_vector >= d * 4 + 4 * 2 * 8
    flat = api.FlatIndex().build(corpus)
    assert idx.bytes_per_vector > flat.bytes_per_vector


# ---------------------------------------------------------------------------
# Acceptance: the ISSUE 3 criterion, on the shared 20k fixture
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.timeout(900)
def test_acceptance_20k_hnsw_recall_and_sublinearity(tmp_path,
                                                     acceptance_corpus,
                                                     acceptance_queries,
                                                     acceptance_gt):
    """``RAE64,HNSW32,Rerank4`` reaches recall@10 >= 0.9 vs the exact scan
    while evaluating distances on < 10% of the corpus per query (the
    ``distance_evals`` stat), and survives save -> load bit-exact."""
    idx = api.index_factory("RAE64,HNSW32,Rerank4",
                            reducer_kw={"steps": 1000, "seed": 0})
    idx.build(acceptance_corpus)
    res = idx.search(acceptance_queries, 10)
    recall = (acceptance_gt[:, :, None] ==
              res.indices[:, None, :]).any(-1).mean()
    assert recall >= 0.9, recall

    n = acceptance_corpus.shape[0]
    assert res.distance_evals < 0.10 * n, (res.distance_evals, n)
    check_graph_invariants(idx.base._g)

    idx.save(str(tmp_path / "hnsw"))
    res2 = api.load_index(str(tmp_path / "hnsw")).search(acceptance_queries,
                                                         10)
    np.testing.assert_array_equal(res2.indices, res.indices)


@pytest.mark.slow
@pytest.mark.timeout(900)
@pytest.mark.parametrize("quant,floor", [("SQ8", 3.0), ("PQ8x8", 4.0)])
def test_acceptance_20k_quant_graph_recall_and_bytes(tmp_path, quant, floor,
                                                     acceptance_corpus,
                                                     acceptance_queries,
                                                     acceptance_gt):
    """The ISSUE 8 criterion: ``RAE64,HNSW32,<quant>,Rerank4`` holds
    post-rerank recall@10 within 0.01 of the f32 graph twin while the
    traversal gathers >= 3x (SQ8) / >= 4x (PQ8x8) fewer payload bytes per
    hop, and survives save -> load bit-exact."""
    f32 = api.index_factory("RAE64,HNSW32,Rerank4",
                            reducer_kw={"steps": 1000, "seed": 0})
    f32.build(acceptance_corpus)
    f32_res = f32.search(acceptance_queries, 10)
    f32_recall = (acceptance_gt[:, :, None] ==
                  f32_res.indices[:, None, :]).any(-1).mean()

    idx = api.index_factory(f"RAE64,HNSW32,{quant},Rerank4",
                            reducer_kw={"steps": 1000, "seed": 0})
    idx.build(acceptance_corpus)
    res = idx.search(acceptance_queries, 10)
    recall = (acceptance_gt[:, :, None] ==
              res.indices[:, None, :]).any(-1).mean()
    assert recall >= f32_recall - 0.01, (recall, f32_recall)

    ratio = f32_res.stats["gather_bytes_per_hop"] / \
        res.stats["gather_bytes_per_hop"]
    assert ratio >= floor, ratio

    idx.save(str(tmp_path / "qg"))
    idx2 = api.load_index(str(tmp_path / "qg"))
    assert idx2.fingerprint() == idx.fingerprint()
    res2 = idx2.search(acceptance_queries, 10)
    np.testing.assert_array_equal(res2.indices, res.indices)
