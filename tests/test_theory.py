"""Property tests for the paper's theory (Section 3.3 + Appendix A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import spectral, theory

jax.config.update("jax_platform_name", "cpu")


def _rand_w(seed, m, n, scale):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, scale, (m, n)).astype(np.float32))


def _rand_x(seed, b, n):
    rng = np.random.default_rng(seed + 1)
    return jnp.asarray(rng.normal(0, 1, (b, n)).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       m=st.integers(2, 24), extra=st.integers(1, 40),
       scale=st.floats(0.01, 3.0))
def test_rayleigh_quotient_bounds(seed, m, extra, scale):
    """lambda_min <= R(M, x) <= lambda_max (Eq. 13, Appendix A)."""
    n = m + extra
    w = _rand_w(seed, m, n, scale)
    mtm = w.T @ w  # symmetric PSD [n, n]
    x = _rand_x(seed, 16, n)
    r = theory.rayleigh_quotient(mtm, x)
    evals = jnp.linalg.eigvalsh(mtm)
    assert jnp.all(r >= evals[0] - 1e-3 * jnp.abs(evals[-1]) - 1e-5)
    assert jnp.all(r <= evals[-1] * (1 + 1e-4) + 1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       m=st.integers(2, 24), extra=st.integers(1, 40),
       scale=st.floats(0.01, 3.0))
def test_norm_upper_bound_always_holds(seed, m, extra, scale):
    """||Wx|| <= sigma_max ||x|| for all x (Eq. 15 upper half)."""
    n = m + extra
    w = _rand_w(seed, m, n, scale)
    x = _rand_x(seed, 64, n)
    assert bool(theory.norm_upper_bound_holds(w, x))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       m=st.integers(2, 24), extra=st.integers(1, 40),
       scale=st.floats(0.05, 3.0))
def test_norm_bounds_on_row_space(seed, m, extra, scale):
    """Both Eq. 15 bounds hold for x in row(W) (see theory.py docstring:
    the lower bound needs the row-space restriction when m < n)."""
    n = m + extra
    w = _rand_w(seed, m, n, scale)
    x = _rand_x(seed, 64, n)
    assert bool(theory.norm_bounds_hold(w, x))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(2, 16),
       extra=st.integers(1, 30))
def test_nullspace_violates_naive_lower_bound(seed, m, extra):
    """Counterexample documenting the paper's implicit restriction: a
    nullspace vector has ||Wx|| = 0 < sigma_min ||x||."""
    n = m + extra
    w = _rand_w(seed, m, n, 1.0)
    _, _, vt = jnp.linalg.svd(w, full_matrices=True)
    null = vt[m:]  # [n-m, n] basis of the nullspace
    x = null[0:1]
    s = spectral.singular_values(w)
    wx = jnp.linalg.norm(x @ w.T)
    assert float(wx) < float(s[-1] * jnp.linalg.norm(x)) + 1e-4


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), scale=st.floats(0.1, 2.0))
def test_frobenius_dominates_spectral(seed, scale):
    """sigma_max = ||W||_2 <= ||W||_F (Eq. 8) — the paper's control lever."""
    w = _rand_w(seed, 12, 48, scale)
    st_ = spectral.analyze(w)
    assert float(st_.sigma_max) <= float(st_.frobenius) + 1e-5


def test_certified_fraction_monotone_in_kappa():
    """Better-conditioned W certifies at least as many kNN relations
    (Eq. 16: relation certified iff d_far/d_near > kappa)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32))
    w_good = jnp.eye(8, 32)  # kappa = 1
    w_bad = jnp.diag(jnp.array([4.0, 1, 1, 1, 1, 1, 1, 0.25])) @ w_good
    f_good = float(theory.certified_fraction(w_good, x, k=5))
    f_bad = float(theory.certified_fraction(w_bad, x, k=5))
    assert f_good >= f_bad
    assert f_good > 0.5


def test_isometry_preserves_knn_exactly():
    """kappa(W) = 1 (orthogonal rows) => P_overall = 1 within the row space."""
    from repro.core import metrics

    rng = np.random.default_rng(1)
    basis, _ = np.linalg.qr(rng.normal(size=(32, 8)).astype(np.float32))
    z = rng.normal(size=(200, 8)).astype(np.float32)
    x = z @ basis.T  # data lies in an 8-dim subspace of R^32
    w = basis.T      # the exact isometry onto that subspace
    acc = metrics.preservation_accuracy(x, x @ w.T, k=5)
    assert acc == pytest.approx(1.0)
