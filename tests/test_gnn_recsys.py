"""GNN + recsys substrate correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.data.synthetic import random_graph
from repro.models.common import NULL_CTX, embedding_bag, sharded_embedding_lookup
from repro.models.gnn import graphsage, sampler

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def graph():
    return random_graph(300, 6, 16, 4, seed=5)


def test_segment_aggregate_equals_dense_adjacency(graph):
    g = graph
    h = jnp.asarray(g.features)
    agg = graphsage.mean_aggregate(h, jnp.asarray(g.edge_src),
                                   jnp.asarray(g.edge_dst), g.n_nodes,
                                   NULL_CTX)
    a = np.zeros((g.n_nodes, g.n_nodes), np.float32)
    np.add.at(a, (g.edge_dst, g.edge_src), 1.0)
    ref = (a @ g.features) / np.maximum(a.sum(1, keepdims=True), 1)
    np.testing.assert_allclose(np.asarray(agg), ref, rtol=1e-4, atol=1e-4)


def test_sampler_returns_true_neighbors(graph):
    sm = sampler.NeighborSampler(graph, (5, 3), seed=2)
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, graph.n_nodes, 64).astype(np.int64)
    nbrs = sm._sample_neighbors(seeds, 5, np.random.default_rng(1))
    for i, s in enumerate(seeds):
        true = set(sm.neighbors_of(int(s)).tolist()) | {int(s)}
        assert set(nbrs[i].tolist()) <= true


def test_sampler_deterministic(graph):
    s1 = sampler.NeighborSampler(graph, (5, 3), seed=2)
    s2 = sampler.NeighborSampler(graph, (5, 3), seed=2)
    b1, b2 = s1.sample_batch(7, 16), s2.sample_batch(7, 16)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])


def test_full_batch_training_learns(graph):
    from repro.configs.base import GNNConfig
    from repro.optim import AdamW

    cfg = GNNConfig(name="t", n_layers=2, d_hidden=32, aggregator="mean",
                    sample_sizes=(5, 3), n_classes=4)
    params = graphsage.init(cfg, 16, 4, jax.random.PRNGKey(0))
    g = graph
    batch = {"features": jnp.asarray(g.features),
             "src": jnp.asarray(g.edge_src), "dst": jnp.asarray(g.edge_dst),
             "labels": jnp.asarray(g.labels),
             "node_mask": jnp.ones(g.n_nodes, jnp.float32)}
    opt = AdamW(lr=1e-2)
    step = jax.jit(graphsage.make_train_step(cfg, NULL_CTX, opt, "full_graph"))
    o = opt.init(params)
    for _ in range(40):
        params, o, m = step(params, o, batch)
    assert float(m["acc"]) > 0.9


def test_node_mask_excludes_padding(graph):
    from repro.configs.base import GNNConfig

    cfg = GNNConfig(name="t", n_layers=2, d_hidden=8, aggregator="mean",
                    sample_sizes=(5, 3), n_classes=4)
    g = graph
    params = graphsage.init(cfg, 16, 4, jax.random.PRNGKey(0))
    base = {"features": jnp.asarray(g.features), "src": jnp.asarray(g.edge_src),
            "dst": jnp.asarray(g.edge_dst), "labels": jnp.asarray(g.labels),
            "node_mask": jnp.ones(g.n_nodes, jnp.float32)}
    l1, _ = graphsage.full_batch_loss(params, base, cfg, NULL_CTX)
    # pad 50 junk nodes; mask must make the loss identical
    padded = {
        "features": jnp.concatenate([base["features"],
                                     jnp.ones((50, 16)) * 99], 0),
        "src": base["src"], "dst": base["dst"],
        "labels": jnp.concatenate([base["labels"],
                                   jnp.zeros(50, jnp.int32)]),
        "node_mask": jnp.concatenate([base["node_mask"],
                                      jnp.zeros(50, jnp.float32)]),
    }
    l2, _ = graphsage.full_batch_loss(params, padded, cfg, NULL_CTX)
    assert float(l1) == pytest.approx(float(l2), rel=1e-5)


# ---------------------------------------------------------------------------
# Embedding engine properties
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), v=st.integers(4, 200),
       d=st.sampled_from([4, 8, 16]), b=st.integers(1, 16),
       l=st.integers(1, 8))
def test_embedding_bag_property(seed, v, d, b, l):
    """EmbeddingBag == explicit python loop for arbitrary bags/lengths."""
    rng = np.random.default_rng(seed)
    tbl = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    ids = rng.integers(0, v, (b, l)).astype(np.int32)
    lens = rng.integers(1, l + 1, (b,)).astype(np.int32)
    out = embedding_bag(tbl, jnp.asarray(ids), jnp.asarray(lens), NULL_CTX,
                        mode="mean", compute_dtype=jnp.float32)
    for i in range(b):
        ref = np.asarray(tbl)[ids[i, :lens[i]]].mean(0)
        np.testing.assert_allclose(np.asarray(out[i]), ref, rtol=1e-4,
                                   atol=1e-5)


def test_sharded_lookup_local_fallback():
    rng = np.random.default_rng(1)
    tbl = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 64, (4, 3)), jnp.int32)
    out = sharded_embedding_lookup(tbl, ids, NULL_CTX,
                                   compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(tbl)[np.asarray(ids)],
                               rtol=1e-6)


def test_two_tower_inbatch_loss_gradient_sane():
    from repro.configs.base import EmbeddingTableSpec, RecsysConfig
    from repro.models.recsys import two_tower

    cfg = RecsysConfig(
        name="tt", kind="two_tower", embed_dim=8, mlp_dims=(16, 8),
        hist_len=4,
        tables=(EmbeddingTableSpec("user", 50, 8),
                EmbeddingTableSpec("item", 100, 8),
                EmbeddingTableSpec("hist_item", 100, 8, bag_size=4)))
    params = two_tower.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"user": jnp.asarray(rng.integers(0, 50, 16), jnp.int32),
             "hist": jnp.asarray(rng.integers(0, 100, (16, 4)), jnp.int32),
             "hist_len": jnp.asarray(rng.integers(1, 5, 16), jnp.int32),
             "item": jnp.asarray(rng.integers(0, 100, 16), jnp.int32)}
    (loss, _), grads = jax.value_and_grad(two_tower.loss_fn, has_aux=True)(
        params, batch, cfg, NULL_CTX)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert gn > 0


def test_mind_capsule_squash_norm_bounded():
    from repro.configs.base import EmbeddingTableSpec, RecsysConfig
    from repro.models.recsys import mind

    cfg = RecsysConfig(
        name="mi", kind="mind", embed_dim=8, n_interests=3, capsule_iters=3,
        hist_len=6, mlp_dims=(16, 8),
        tables=(EmbeddingTableSpec("item", 100, 8),
                EmbeddingTableSpec("category", 10, 8)))
    params = mind.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"hist": jnp.asarray(rng.integers(0, 100, (8, 6)), jnp.int32),
             "hist_len": jnp.asarray(rng.integers(1, 7, 8), jnp.int32)}
    caps = mind.interests(params, batch, cfg, NULL_CTX)
    assert caps.shape == (8, 3, 8)
    norms = np.linalg.norm(np.asarray(caps), axis=-1)
    np.testing.assert_allclose(norms, 1.0, rtol=1e-3)  # l2norm'd output
