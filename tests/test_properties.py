"""Property-based invariants for the quantizers + the paper's theory bound.

Every invariant runs twice: a deterministic seed sweep (always on, so the
container without ``hypothesis`` still exercises the property) and a
``hypothesis`` randomized variant via ``hypothesis_compat`` (skipped when
the package is absent, live fuzzing when present).

Invariants:
* SQ8 round-trip error <= half a quantization step per dim, any data range.
* PQ ADC distance == exact distance on the dequantized codes (the ADC LUT
  is exact, not an approximation — PQ's only error is reconstruction).
* recall@k is monotone non-decreasing in ``nprobe`` (probing more cells
  scans a superset; with exact in-cell distances a true neighbor can only
  be displaced by another true neighbor).
* the Eq. 15 norm-distortion bound sigma_min||x|| <= ||Wx|| <= sigma_max
  ||x|| holds on random RAE-style weights and on actually-trained RAE
  encoders.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from hypothesis_compat import given, settings, st

from repro.core import theory
from repro.search import ivf as ivf_lib
from repro.search import quantize as qz

jax.config.update("jax_platform_name", "cpu")


def _corpus(seed, n, d, scale=1.0, offset=0.0):
    rng = np.random.default_rng(seed)
    return (offset + scale * rng.standard_normal((n, d))).astype(np.float32)


# ---------------------------------------------------------------------------
# SQ8 round-trip
# ---------------------------------------------------------------------------
def _check_sq8_roundtrip(seed, n, d, scale, offset):
    x = _corpus(seed, n, d, scale, offset)
    sq = qz.sq8_train(x)
    rec = np.asarray(qz.sq8_decode(sq, qz.sq8_encode(sq, x)))
    err = np.abs(rec - x)
    bound = np.asarray(sq.step)[None, :] / 2
    assert np.all(err <= bound * (1 + 1e-4) + 1e-6), float(
        (err - bound).max())


@pytest.mark.parametrize("seed", range(8))
def test_sq8_roundtrip_half_step(seed):
    scale = 10.0 ** ((seed % 5) - 2)          # 1e-2 .. 1e2
    _check_sq8_roundtrip(seed, 200, 3 + seed * 5, scale, offset=seed - 4.0)


def test_sq8_roundtrip_constant_dim():
    """A zero-range dim must round-trip exactly (step floor, no div-by-0)."""
    x = np.ones((50, 4), np.float32) * 3.25
    x[:, 1] = np.linspace(-1, 1, 50)
    sq = qz.sq8_train(x)
    rec = np.asarray(qz.sq8_decode(sq, qz.sq8_encode(sq, x)))
    np.testing.assert_allclose(rec[:, 0], x[:, 0], atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 300),
       d=st.integers(1, 48), scale=st.floats(1e-3, 1e3),
       offset=st.floats(-100.0, 100.0))
def test_sq8_roundtrip_half_step_fuzz(seed, n, d, scale, offset):
    _check_sq8_roundtrip(seed, n, d, scale, offset)


# ---------------------------------------------------------------------------
# PQ ADC exactness on dequantized codes
# ---------------------------------------------------------------------------
def _check_pq_adc_exact(seed, n, m, dsub, bits):
    x = _corpus(seed, n, m * dsub)
    q = _corpus(seed + 1, 8, m * dsub)
    pq = qz.pq_train(x, m=m, bits=bits, iters=4, seed=seed)
    codes = qz.pq_encode(pq, x)
    adc = np.asarray(qz.pq_adc_gather(qz.pq_adc_lut(pq, q), codes))
    rec = np.asarray(qz.pq_decode(pq, codes))
    exact = ((q[:, None, :] - rec[None, :, :]) ** 2).sum(-1)
    np.testing.assert_allclose(adc, exact, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("seed,m,dsub,bits", [
    (0, 1, 1, 1), (1, 2, 3, 2), (2, 4, 8, 4), (3, 8, 4, 8), (4, 3, 5, 6),
])
def test_pq_adc_matches_exact(seed, m, dsub, bits):
    _check_pq_adc_exact(seed, 150, m, dsub, bits)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 200),
       m=st.integers(1, 8), dsub=st.integers(1, 8), bits=st.integers(1, 8))
def test_pq_adc_matches_exact_fuzz(seed, n, m, dsub, bits):
    _check_pq_adc_exact(seed, n, m, dsub, bits)


# ---------------------------------------------------------------------------
# nprobe monotonicity
# ---------------------------------------------------------------------------
def _recalls_vs_nprobe(seed, quant):
    x = jnp.asarray(_corpus(seed, 600, 16))
    q = x[:32] + 0.01
    index = ivf_lib.build(x, n_cells=16, kmeans_iters=5, seed=seed)
    probes = (1, 2, 4, 8, 16)
    if quant == "flat":
        return [ivf_lib.recall_vs_exact(index, x, q, 10, p) for p in probes]
    from repro.core.metrics import knn_indices, set_overlap

    pq = qz.pq_train(x, m=4, bits=8, iters=8, seed=seed)
    c, cap, d = index.list_vecs.shape
    codes = qz.pq_encode(pq, index.list_vecs.reshape(c * cap, d)) \
        .reshape(c, cap, 4)
    exact = knn_indices(q, x, 10)
    out = []
    for p in probes:
        _, got = qz.ivf_pq_search(index.centroids, index.lists, codes,
                                  index.list_mask, pq.codebooks, q, 10, p)
        out.append(float(set_overlap(exact, got)))
    return out


@pytest.mark.parametrize("seed", range(4))
def test_ivf_flat_recall_monotone_in_nprobe(seed):
    rec = _recalls_vs_nprobe(seed, "flat")
    assert all(b >= a for a, b in zip(rec, rec[1:])), rec
    assert rec[-1] == 1.0  # probing every cell == exact scan


@pytest.mark.parametrize("seed", range(4))
def test_ivf_pq_recall_monotone_in_nprobe(seed):
    """ADC ranking is approximate, so allow a hair of non-monotonicity."""
    rec = _recalls_vs_nprobe(seed, "pq")
    assert all(b >= a - 0.02 for a, b in zip(rec, rec[1:])), rec
    assert rec[-1] >= rec[0]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_ivf_flat_recall_monotone_in_nprobe_fuzz(seed):
    rec = _recalls_vs_nprobe(seed, "flat")
    assert all(b >= a for a, b in zip(rec, rec[1:])), rec


# ---------------------------------------------------------------------------
# Theory: Eq. 15 norm-distortion bound
# ---------------------------------------------------------------------------
def _check_norm_bound(seed, m, n, scale):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, scale, (m, n)).astype(np.float32))
    xs = jnp.asarray(rng.normal(0, 1, (64, n)).astype(np.float32))
    assert bool(theory.norm_bounds_hold(w, xs))
    d = theory.empirical_distortion(w, xs)
    assert float(d["ratio_max"]) <= float(d["sigma_max"]) * (1 + 1e-4) + 1e-6
    assert float(d["kappa"]) >= 1.0 - 1e-5


@pytest.mark.parametrize("seed", range(6))
def test_norm_bound_random_weights(seed):
    _check_norm_bound(seed, 4 + seed * 3, 16 + seed * 8,
                      scale=10.0 ** ((seed % 3) - 1))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), m=st.integers(2, 32),
       extra=st.integers(1, 64), scale=st.floats(1e-2, 10.0))
def test_norm_bound_random_weights_fuzz(seed, m, extra, scale):
    _check_norm_bound(seed, m, m + extra, scale)


def test_norm_bound_trained_rae_encoder():
    """The bound is not just for gaussian W: it holds for the encoder the
    trainer actually produces (weight decay keeps kappa small — that IS the
    paper's mechanism)."""
    from repro.configs import RAEConfig
    from repro.core import trainer
    from repro.data import synthetic

    data = synthetic.embedding_corpus(400, 24, n_clusters=4, intrinsic=8,
                                      seed=3)
    cfg = RAEConfig(in_dim=24, out_dim=8, steps=120, weight_decay=0.1)
    res = trainer.train(cfg, data, log_every=10 ** 9)
    w = res.params["w_e"].T  # encode is x @ w_e; theory wants W [m, n]
    assert bool(theory.norm_bounds_hold(w, jnp.asarray(data)))
