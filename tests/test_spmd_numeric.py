"""SPMD numerical correctness: sharded programs == single-device math.

jax locks the device count at first init, so these tests run in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 and a
(2, 2, 2) (pod, data, model) mesh, comparing against the local (mesh=None)
path. This is the numerical counterpart of the structural dry-run.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import AxisType, make_mesh
from repro.distributed.partitioning import default_rules
from repro.models.common import MeshCtx, NULL_CTX, sharded_embedding_lookup, embedding_bag

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"),
                 axis_types=(AxisType.Auto,) * 3)
ctx = MeshCtx(mesh=mesh, rules=default_rules(multi_pod=True))
rng = np.random.default_rng(0)

# --- sharded embedding lookup == local ---
tbl = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
ids = jnp.asarray(rng.integers(0, 64, (8, 5)), jnp.int32)
with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
    out = jax.jit(lambda t, i: sharded_embedding_lookup(
        t, i, ctx, row_logical="table_rows", ids_logical=("batch", None),
        compute_dtype=jnp.float32))(tbl, ids)
ref = np.asarray(tbl)[np.asarray(ids)]
np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)
print("lookup OK")

# --- embedding bag ---
lens = jnp.asarray(rng.integers(1, 6, (8,)), jnp.int32)
with mesh:
    bag = jax.jit(lambda t, i, l: embedding_bag(
        t, i, l, ctx, mode="mean", compute_dtype=jnp.float32))(tbl, ids, lens)
bag_ref = embedding_bag(tbl, ids, lens, NULL_CTX, compute_dtype=jnp.float32)
np.testing.assert_allclose(np.asarray(bag), np.asarray(bag_ref), rtol=1e-5)
print("bag OK")

# --- decode attention (seq-sharded cache) ---
from repro.models.transformer import attention as attn
b, kh, g, dh, smax = 4, 2, 2, 8, 16
h = kh * g
q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
kc = jnp.asarray(rng.normal(size=(b, smax, kh, dh)), jnp.float32)
vc = jnp.asarray(rng.normal(size=(b, smax, kh, dh)), jnp.float32)
kn = jnp.asarray(rng.normal(size=(b, kh, dh)), jnp.float32)
vn = jnp.asarray(rng.normal(size=(b, kh, dh)), jnp.float32)
cur = jnp.asarray(9, jnp.int32)
with mesh:
    out_s, k2s, v2s = jax.jit(lambda *a: attn.decode_attention(
        *a, ctx, "kv_seq"))(q, kc, vc, kn, vn, cur)
out_l, k2l, v2l = attn.decode_attention(q, kc, vc, kn, vn, cur, NULL_CTX)
np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_l), rtol=2e-5, atol=2e-5)
np.testing.assert_allclose(np.asarray(k2s), np.asarray(k2l), rtol=1e-6)
print("decode attn OK")

# --- MoE block (tokens sharded over all axes; experts over model) ---
from repro.configs.base import TransformerConfig
from repro.models.transformer import moe as moe_lib
cfg = TransformerConfig(name="m", family="moe", n_layers=1, d_model=16,
    n_heads=2, n_kv_heads=2, d_head=8, d_ff=8, vocab_size=64, n_experts=4,
    moe_top_k=2, capacity_factor=64.0, compute_dtype="float32")
t, d = 32, 16
x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
router = jnp.asarray(rng.normal(size=(d, 4)) * 0.3, jnp.float32)
wg = jnp.asarray(rng.normal(size=(4, d, 8)) * 0.2, jnp.float32)
wu = jnp.asarray(rng.normal(size=(4, d, 8)) * 0.2, jnp.float32)
wd_ = jnp.asarray(rng.normal(size=(4, 8, d)) * 0.2, jnp.float32)
with mesh:
    y_s, aux_s = jax.jit(lambda *a: moe_lib.moe_block(*a, cfg, ctx))(
        x, router, wg, wu, wd_)
y_l, aux_l = moe_lib.moe_block(x, router, wg, wu, wd_, cfg, NULL_CTX)
np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_l), rtol=5e-4, atol=5e-4)
print("moe OK")

# --- full tiny-LM train step: sharded loss == local loss ---
from repro.models.transformer import model as tm
cfg2 = TransformerConfig(name="t", family="dense", n_layers=2, d_model=32,
    n_heads=4, n_kv_heads=2, d_head=8, d_ff=64, vocab_size=199,
    compute_dtype="float32", param_dtype="float32", remat=True,
    scan_layers=True, kv_chunk=8, xent_chunk=8)
params = tm.init(cfg2, jax.random.PRNGKey(0))
batch = {"tokens": jnp.asarray(rng.integers(0, 199, (8, 16)), jnp.int32),
         "targets": jnp.asarray(rng.integers(0, 199, (8, 16)), jnp.int32)}
loss_l, _ = tm.loss_fn(params, batch, cfg2, NULL_CTX)
with mesh:
    loss_s, _ = jax.jit(lambda p, b: tm.loss_fn(p, b, cfg2, ctx))(params, batch)
np.testing.assert_allclose(float(loss_s), float(loss_l), rtol=2e-4)
print("lm loss OK", float(loss_l), float(loss_s))

# --- distributed search == local ---
from repro.search import search
qq = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
db = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
with mesh:
    vs, is_ = jax.jit(lambda a, b: search(a, b, 5, ctx))(qq, db)
vl, il = search(qq, db, 5, NULL_CTX)
np.testing.assert_array_equal(np.asarray(is_), np.asarray(il))
print("search OK")

# --- GNN full-batch aggregate == local ---
from repro.models.gnn import graphsage
n, e = 32, 96
hh = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)
src = jnp.asarray(rng.integers(0, n, e), jnp.int32)
dst = jnp.asarray(rng.integers(0, n, e), jnp.int32)
with mesh:
    agg_s = jax.jit(lambda *a: graphsage.mean_aggregate(*a, n, ctx))(hh, src, dst)
agg_l = graphsage.mean_aggregate(hh, src, dst, n, NULL_CTX)
np.testing.assert_allclose(np.asarray(agg_s), np.asarray(agg_l), rtol=1e-5, atol=1e-5)
print("gnn OK")
print("ALL SPMD NUMERIC OK")
"""


@pytest.mark.timeout(900)
def test_spmd_numeric_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=".",
                       capture_output=True, text=True, timeout=850)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "ALL SPMD NUMERIC OK" in r.stdout
