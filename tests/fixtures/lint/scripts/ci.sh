#!/usr/bin/env bash
# Fixture CI: registers "offkern" only, so `badkern` trips unregistered-ci.
REQUIRED_KERNELS=(offkern)
