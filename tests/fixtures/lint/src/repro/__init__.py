# Seeded-violation package for tests/test_analysis.py. Named `repro` so
# the checkers' package-rooted conventions (repro.kernels.* triples,
# VectorIndex subclasses) apply verbatim. Never imported — analyzed only.
