"""Every fingerprint-coverage rule, seeded once."""


class VectorIndex:
    """Stand-in root: the checker matches base classes by name."""

    kind = "abstract"

    @property
    def ntotal(self):
        raise NotImplementedError

    def _fingerprint_state(self):
        raise NotImplementedError

    def save(self, directory):
        raise NotImplementedError


class BadIndex(VectorIndex):
    # "ghost" is never assigned anywhere -> stale-exemption
    _fp_exempt = {"ghost": "left over from a deleted attribute"}

    def __init__(self):
        self.metric = "euclidean"
        self.mystery = 3           # never hashed/exempt -> fingerprint-missing
        self._db = None

    @property
    def ntotal(self):
        return 0 if self._db is None else len(self._db)

    def _fingerprint_state(self):
        return [self.metric, self._db]

    def save(self, directory):
        return {"db": self._db}    # metric hashed, not saved -> save-coverage


class WeirdIndex(VectorIndex):
    _fp_exempt = ["nope"]          # not {str: str} -> unknown-exemption

    def __init__(self):
        self.x = 1

    @property
    def ntotal(self):
        return 1

    def _fingerprint_state(self):
        return [self.x]


class StreamyIndex(VectorIndex):
    """Mutable index whose ``insert`` bumps an epoch counter that the
    fingerprint never hashes -> mutation-epoch (and nothing else: the
    stored corpus IS hashed, so only the epoch omission fires)."""

    def __init__(self):
        self._db = []

    def build(self, corpus):
        self._db = list(corpus)
        return self

    def insert(self, rows):
        self._db = self._db + list(rows)
        self.epoch = getattr(self, "epoch", 0) + 1   # never fingerprinted

    @property
    def ntotal(self):
        return len(self._db)

    def _fingerprint_state(self):
        return [self._db]

    def save(self, directory):
        return {"db": self._db}


class TunedIndex(VectorIndex):
    """Self-tuning index whose ``set_params`` applies a knob the
    fingerprint never hashes -> tuned-policy (and nothing else: the knob
    is not stored in __init__/build/_load, so fingerprint-missing stays
    quiet, and the stored corpus IS hashed)."""

    def __init__(self):
        self._db = []

    def build(self, corpus):
        self._db = list(corpus)
        return self

    def set_params(self, params):
        self.nprobe = params       # applied knob, never fingerprinted

    @property
    def ntotal(self):
        return len(self._db)

    def _fingerprint_state(self):
        return [self._db]

    def save(self, directory):
        return {"db": self._db}


class ShardyIndex(VectorIndex):
    """Composite that reads its children but never hashes their
    fingerprints -> child-fingerprint (and nothing else: the attribute
    itself IS read by ntotal, so fingerprint-missing stays quiet)."""

    def __init__(self):
        self.children = []

    def build(self, corpus):
        self.children = [BadIndex() for _ in range(2)]
        return self

    @property
    def ntotal(self):
        return sum(c.ntotal for c in self.children)

    def search(self, queries, k):
        # loop-alias delegation: child.search handed off uncalled
        return [child.search for child in self.children]

    def _fingerprint_state(self):
        return [len(self.children)]   # counts shards, not their content

    def save(self, directory):
        return {"n": len(self.children)}
