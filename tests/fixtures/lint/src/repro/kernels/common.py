"""The one legal home for pad sentinels (mirrors the real common.py)."""
NEG_INF = -1e30
PAD_PENALTY = 1e30
