# Drops `mode`/`ksub` as if they were tuning knobs -> signature-mismatch:
# codec-algebra params select WHICH function the kernel computes, so the
# oracle must take them (only impl/interpret and b<letter> block sizes
# are strippable).
def quantkern_ref(q_op, codes):
    return q_op, codes
