def quantkern(q_op, codes, mode="sq8", ksub=0, impl="auto", bq=128,
              interpret=False):
    return q_op, codes, mode, ksub, impl, bq, interpret
