from .ops import quantkern

__all__ = ["quantkern"]
