# Correct public symbol: the planted violations live in ref.py (dropped
# codec params) and the registration files (parity/ci lists).
def quantkern_pallas(q_op, codes, mode, ksub):
    return q_op, codes, mode, ksub
