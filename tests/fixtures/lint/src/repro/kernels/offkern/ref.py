# Renamed second parameter -> signature-mismatch (ops says `db`).
def offkern_ref(q, database, k):
    return q, database, k
