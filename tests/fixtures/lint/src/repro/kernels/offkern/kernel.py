# Defines the wrong public symbol -> missing-symbol (wants offkern_pallas).
def offkern_kernel_impl(q, db, k):
    return q, db, k
