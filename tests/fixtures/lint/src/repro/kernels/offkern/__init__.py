from .ops import offkern

__all__ = ["offkern"]
