def offkern(q, db, k, impl="auto", bq=128, interpret=False):
    return q, db, k, impl, bq, interpret
