# Deliberately re-exports nothing -> missing-reexport for every triple.
