# Deliberately empty -> missing-reexport. The triple also ships no ref.py.
