"""pad-sentinel redefinition + a host construct inside a pallas root."""
import functools

import jax.experimental.pallas as pl

NEG_INF = -1e30  # local redefinition -> pad-sentinel


def _body(x_ref, o_ref):
    print("kernel trace")  # host-print, reached via the pallas_call root
    o_ref[...] = x_ref[...] * 2.0


def badkern_pallas(x):
    kern = functools.partial(_body)
    return pl.pallas_call(kern, out_shape=x)(x)
