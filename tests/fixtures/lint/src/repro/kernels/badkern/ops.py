"""Raw sentinel literal -> pad-sentinel."""
from .kernel import badkern_pallas


def badkern(x, k, impl="auto"):
    penalty = 1e30  # raw literal -> pad-sentinel
    return badkern_pallas(x), penalty, k, impl
