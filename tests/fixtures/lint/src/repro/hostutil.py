"""Cross-module purity bait: reached from a jit root in impure.py."""
import numpy as np


def to_host(x):
    return np.array(x)  # host-numpy, two call-graph hops from the root
