"""Every jit-purity rule, seeded once (plus one suppressed finding)."""
import functools
import random
import time

import jax
import numpy as np

from . import hostutil


@jax.jit
def impure_decorated(x):
    print("tracing", x)            # host-print
    t = time.time()                # host-time
    r = random.random()            # host-random
    v = x.sum().item()             # host-concretize
    for s in {1, 2, 3}:            # set-iteration
        v += s
    return hostutil.to_host(x) + t + r + v


def _inner(x):
    return np.asarray(x)           # host-numpy, via the call site below


def make_jitted():
    return jax.jit(functools.partial(_inner))


@jax.jit
def pragma_escape(x):
    print("dbg")  # lint: ignore[host-print]
    return x
