# Fixture parity harness: registers "offkern" only, so `badkern` trips
# unregistered-parity. Never collected (tests/fixtures is norecursedirs).
PARITY_CASES = [
    ("offkern", "base", {}, None),
]
