"""Baseline DR implementations (PCA / MDS / Isomap / UMAP-lite / RP)."""
import numpy as np
import pytest

from repro.core import baselines, metrics
from repro.data import synthetic


@pytest.fixture(scope="module")
def data():
    x = synthetic.embedding_corpus(600, 40, n_clusters=5, intrinsic=10, seed=1)
    return synthetic.train_test_split(x)


def test_pca_orthonormal_components(data):
    tr, _ = data
    p = baselines.PCA(8).fit(tr)
    gram = p.components_.T @ p.components_
    np.testing.assert_allclose(gram, np.eye(8), atol=1e-4)


def test_pca_matches_svd_variance(data):
    tr, _ = data
    p = baselines.PCA(8).fit(tr)
    z = p.transform(tr)
    var = z.var(axis=0)
    assert np.all(np.diff(var) <= 1e-3)  # decreasing variance order


def test_mds_recovers_euclidean_config():
    """Classical MDS on exact euclidean distances reproduces the config up
    to rotation: pairwise distances must match."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(100, 6)).astype(np.float32)
    sq = np.sum(x * x, 1)
    d2 = sq[:, None] - 2 * x @ x.T + sq[None, :]
    y = baselines._classical_mds_from_d2(d2, 6)
    dy2 = (np.sum(y * y, 1)[:, None] - 2 * y @ y.T + np.sum(y * y, 1)[None, :])
    np.testing.assert_allclose(d2, dy2, atol=1e-2 * d2.max())


def test_mds_linear_out_of_sample(data):
    tr, te = data
    m = baselines.MDSLinear(8, max_train=400).fit(tr)
    z = m.transform(te)
    assert z.shape == (te.shape[0], 8)
    assert np.isfinite(z).all()


def test_isomap_runs_and_beats_nothing(data):
    tr, te = data
    iso = baselines.Isomap(8, n_neighbors=8, max_train=300).fit(tr)
    z = iso.transform(te)
    assert z.shape == (te.shape[0], 8)
    assert np.isfinite(z).all()


def test_umap_lite_runs(data):
    tr, te = data
    u = baselines.UMAPLite(4, n_neighbors=10, n_epochs=20,
                           max_train=300).fit(tr)
    z = u.transform(te)
    assert z.shape == (te.shape[0], 4)
    assert np.isfinite(z).all()


def test_pca_beats_rp_on_anisotropic(data):
    """Ordering sanity used by Table 1: PCA > random projection here."""
    tr, te = data
    p = baselines.PCA(8).fit(tr)
    r = baselines.GaussianRP(8).fit(tr)
    acc_p = metrics.preservation_accuracy(te, p.transform(te), k=5)
    acc_r = metrics.preservation_accuracy(te, r.transform(te), k=5)
    assert acc_p > acc_r


def test_make_baseline_factory():
    for name in ("pca", "rp", "mds", "isomap", "umap"):
        b = baselines.make_baseline(name, 4)
        assert b.out_dim == 4
