"""Unified retrieval API: registry, factory parsing, persistence, recall."""
import jax
import numpy as np
import pytest

from repro import api
from repro.core import rae as rae_lib
from repro.data import synthetic
from repro.models.common import NULL_CTX
from repro.search import twostage

jax.config.update("jax_platform_name", "cpu")

ALL_REDUCERS = ("pca", "rp", "mds", "isomap", "umap", "rae")


@pytest.fixture(scope="module")
def small_corpus():
    return synthetic.embedding_corpus(1500, 32, n_clusters=8, intrinsic=12,
                                      seed=11)


@pytest.fixture(scope="module")
def queries(small_corpus):
    rng = np.random.default_rng(1)
    picks = rng.integers(0, small_corpus.shape[0], 32)
    return small_corpus[picks] + 0.01 * rng.standard_normal(
        (32, small_corpus.shape[1])).astype(np.float32)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def test_registry_lists_all_six():
    assert set(ALL_REDUCERS) <= set(api.list_reducers())


@pytest.mark.parametrize("name", ALL_REDUCERS)
def test_registry_constructs_and_reduces(name, small_corpus):
    kw = {"steps": 40} if name == "rae" else {}
    red = api.make_reducer(name, 8, **kw)
    assert red.kind == name
    assert red.out_dim == 8
    assert not red.fitted
    tr = small_corpus[:400]
    red.fit(tr)
    assert red.fitted
    z = red.transform(small_corpus[400:464])
    assert z.shape == (64, 8)
    assert np.all(np.isfinite(z))


def test_registry_unknown_name():
    with pytest.raises(KeyError, match="unknown reducer"):
        api.make_reducer("tsne", 8)


def test_transform_before_fit_raises():
    with pytest.raises(RuntimeError, match="before fit"):
        api.make_reducer("pca", 4).transform(np.zeros((2, 8), np.float32))


# ---------------------------------------------------------------------------
# Factory spec parsing
# ---------------------------------------------------------------------------
def test_parse_full_stack():
    s = api.parse_index_spec("RAE64,IVF256,Rerank4")
    assert s == api.IndexSpec(reducer="rae", out_dim=64, base="ivf",
                              n_cells=256, rerank_factor=4)


def test_parse_case_insensitive_and_defaults():
    s = api.parse_index_spec("pca32,flat")
    assert s.reducer == "pca" and s.out_dim == 32
    assert s.base == "flat" and s.rerank_factor == 1
    assert api.parse_index_spec("Flat") == api.IndexSpec()
    assert api.parse_index_spec("IVF64").n_cells == 64


@pytest.mark.parametrize("bad", [
    "", " ,Flat", "RAE64", "Rerank4", "Flat,Flat", "IVF", "Flat9",
    "Bogus64,Flat", "Flat,Rerank4", "Flat,PCA32", "RAE64,PCA32,Flat",
    "RAE64,Rerank4,Flat", "RAE64,Flat,Rerank4,Rerank2", "RAE,Flat",
])
def test_parse_rejects_invalid(bad):
    with pytest.raises(ValueError, match="bad index spec"):
        api.parse_index_spec(bad)


# ---------------------------------------------------------------------------
# Spec round-trip: every registered grammar form renders back canonically
# ---------------------------------------------------------------------------
# One spec per registered grammar form (base x quant x reducer x rerank).
ALL_SPEC_FORMS = [
    "Flat", "IVF32", "HNSW8", "SQ8", "PQ4x8", "Flat,SQ8",
    "IVF32,SQ8", "IVF32,PQ4x8",
    "PCA8,Flat", "PCA8,IVF32,Rerank2", "PCA8,HNSW8,Rerank2",
    "PCA8,SQ8,Rerank2", "PCA8,PQ4x8,Rerank2", "PCA8,IVF32,PQ4x8,Rerank2",
    "RAE8,Flat,Rerank2",
]


@pytest.mark.parametrize("spec", ALL_SPEC_FORMS)
def test_parse_str_roundtrip_idempotent(spec):
    """``str(parsed)`` is a canonical spec: re-parsing it is a fixed
    point, in both the parsed and the rendered domain."""
    parsed = api.parse_index_spec(spec)
    assert api.parse_index_spec(str(parsed)) == parsed
    assert str(api.parse_index_spec(str(parsed))) == str(parsed)


def test_factory_builds_each_shape(small_corpus, queries):
    for spec, cls in [("Flat", api.FlatIndex),
                      ("IVF32", api.IVFFlatIndex),
                      ("HNSW8", api.HNSWIndex),
                      ("PCA8,Flat", api.TwoStageIndex)]:
        idx = api.index_factory(spec, index_kw={"ef_construction": 40}
                                if "HNSW" in spec else None)
        assert isinstance(idx, cls)
        idx.build(small_corpus)
        res = idx.search(queries, 5)
        assert isinstance(res, api.SearchResult)
        assert res.indices.shape == (32, 5) and res.k == 5
        assert res.latency_s > 0


# ---------------------------------------------------------------------------
# Persistence round-trips
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ALL_REDUCERS)
def test_reducer_save_load_roundtrip(name, small_corpus, queries, tmp_path):
    kw = {"steps": 40} if name == "rae" else {}
    red = api.make_reducer(name, 6, **kw).fit(small_corpus[:400])
    z = red.transform(queries)
    red.save(str(tmp_path / name))
    red2 = api.load_reducer(str(tmp_path / name))
    assert red2.kind == name and red2.fitted
    np.testing.assert_allclose(red2.transform(queries), z, rtol=1e-6)


@pytest.mark.parametrize("spec", [
    "Flat", "IVF32", "HNSW8", "SQ8", "PQ4x8", "IVF32,SQ8", "IVF32,PQ4x8",
    "RAE8,IVF32,Rerank2", "PCA8,HNSW8,Rerank2",
])
def test_index_save_load_roundtrip(spec, small_corpus, queries, tmp_path):
    """Every registered spec form: save -> load -> search returns
    identical ids (and scores) on a fixed corpus."""
    reducer_kw = {"steps": 40} if spec.startswith("RAE") else None
    index_kw = {"ef_construction": 60} if "HNSW" in spec else None
    idx = api.index_factory(spec, reducer_kw=reducer_kw, index_kw=index_kw)
    idx.build(small_corpus)
    res = idx.search(queries, 5)
    idx.save(str(tmp_path / "idx"))
    idx2 = api.load_index(str(tmp_path / "idx"))
    assert idx2.ntotal == idx.ntotal
    res2 = idx2.search(queries, 5)
    np.testing.assert_array_equal(res2.indices, res.indices)
    np.testing.assert_allclose(res2.scores, res.scores, rtol=1e-6)


def test_twostage_ivf_padding_never_outranks_real(queries):
    """IVF pads short results with id -1; the rerank must pin those to
    -inf so a pad can never beat a real candidate."""
    tiny = synthetic.embedding_corpus(200, 32, n_clusters=8, intrinsic=12,
                                      seed=3)
    # cap = ceil(2.5 * 200 / 64) = 8 per cell; nprobe=8 probes hold at most
    # 64 rows < k1 = 10 * 16 = 160, so stage 1 is guaranteed to pad.
    idx = api.TwoStageIndex(api.make_reducer("pca", 8),
                            api.IVFFlatIndex(n_cells=64, nprobe=8),
                            rerank_factor=16)
    idx.build(tiny)
    res = idx.search(queries, 10)
    valid = res.indices >= 0
    assert np.all(np.isfinite(res.scores[valid]))
    assert np.all(np.isneginf(res.scores[~valid]))
    # every real neighbor in the probed cells must rank above every pad
    assert not np.any(valid[:, 1:] & ~valid[:, :-1])


def test_twostage_fits_reducer_without_fitted_attr(small_corpus, queries):
    """A minimal third-party Reducer (no `fitted` attribute) must be fitted
    by build, not silently skipped."""

    class Halver:
        kind = "halver"
        out_dim = 16

        def __init__(self):
            self.fit_calls = 0

        def fit(self, x):
            self.fit_calls += 1
            return self

        def transform(self, x):
            return np.asarray(x, np.float32)[:, :self.out_dim]

        def save(self, directory):
            raise NotImplementedError

    red = Halver()
    idx = api.TwoStageIndex(red, api.FlatIndex(), rerank_factor=2)
    idx.build(small_corpus)
    assert red.fit_calls == 1
    assert idx.search(queries, 5).indices.shape == (32, 5)


def test_pretrained_reducer_plugs_in(small_corpus, queries):
    """A reducer fitted elsewhere is NOT refit by TwoStageIndex.build."""
    red = api.make_reducer("pca", 8).fit(small_corpus[:500])
    w_before = red._impl.components_.copy()
    idx = api.TwoStageIndex(red, api.FlatIndex(), rerank_factor=2)
    idx.build(small_corpus)
    np.testing.assert_array_equal(red._impl.components_, w_before)
    assert idx.search(queries, 5).indices.shape == (32, 5)


# ---------------------------------------------------------------------------
# Recall parity with the legacy two-stage path
# ---------------------------------------------------------------------------
def test_twostage_matches_legacy_two_stage_search(small_corpus, queries):
    import jax.numpy as jnp

    red = api.make_reducer("rae", 8, steps=120, seed=0).fit(small_corpus)
    idx = api.TwoStageIndex(red, api.FlatIndex(), rerank_factor=4)
    idx.build(small_corpus)
    res = idx.search(queries, 10)

    db = jnp.asarray(small_corpus)
    db_red = twostage.encode_corpus(red.params_, db, NULL_CTX)
    _, legacy_idx = twostage.two_stage_search(
        jnp.asarray(queries), db, db_red, red.params_, 10, NULL_CTX,
        rerank_factor=4)
    overlap = (res.indices[:, :, None] ==
               np.asarray(legacy_idx)[:, None, :]).any(-1).mean()
    assert overlap >= 0.999


def test_rae_reducer_encode_matches_core(small_corpus, queries):
    import jax.numpy as jnp

    red = api.make_reducer("rae", 8, steps=40).fit(small_corpus[:400])
    z_api = red.transform(queries)
    z_core = np.asarray(rae_lib.encode(red.params_, jnp.asarray(queries)))
    np.testing.assert_allclose(z_api, z_core, rtol=1e-6)


# ---------------------------------------------------------------------------
# Acceptance: 20k x 256, both factory stacks, recall@10 >= 0.9, save+reload
# (corpus/queries/ground truth are the session-scoped conftest fixtures,
# shared with the quantized and graph acceptance tests)
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.timeout(900)
@pytest.mark.parametrize("spec", ["RAE64,Flat,Rerank4", "RAE64,IVF256,Rerank4"])
def test_acceptance_20k_recall(spec, tmp_path, acceptance_corpus,
                               acceptance_queries, acceptance_gt):
    idx = api.index_factory(spec, reducer_kw={"steps": 1000, "seed": 0})
    idx.build(acceptance_corpus)
    res = idx.search(acceptance_queries, 10)
    recall = (acceptance_gt[:, :, None] ==
              res.indices[:, None, :]).any(-1).mean()
    assert recall >= 0.9, (spec, recall)

    idx.save(str(tmp_path / "acc"))
    res2 = api.load_index(str(tmp_path / "acc")).search(acceptance_queries, 10)
    np.testing.assert_array_equal(res2.indices, res.indices)
