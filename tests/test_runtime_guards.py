"""Runtime guards: retrace budgets and transfer traps on the hot path.

Two dynamic invariants the static checkers can't prove:

1. **Warm means warm.** After ``SearchEngine.warmup()``, a mixed-size
   concurrent query storm triggers ZERO additional XLA compiles — the
   pow2 bucket padding really does confine the jit cache to the warmed
   shapes. Guarded by :func:`repro.analysis.runtime.no_retrace`, which
   counts backend-compile monitoring events (fires per compile incl.
   retraces, never on a cache hit).

2. **No implicit h2d traffic.** Off-TPU, the scan tiers' hot path runs
   under ``jax.transfer_guard_host_to_device("disallow")``: staging
   queries via an explicit ``jnp.asarray`` is legal, but a numpy array
   leaking directly into a jitted call (a silent per-call copy) raises.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.runtime import (RetraceError, compile_count,
                                    no_host_to_device, no_retrace)
from repro.api.index import FlatIndex
from repro.api.quantized import SQ8Index
from repro.serve.engine import SearchEngine

pytestmark = pytest.mark.timeout(120)


def _corpus(n=256, d=16, seed=0):
    return np.random.default_rng(seed).standard_normal((n, d)) \
        .astype(np.float32)


# ---------------------------------------------------------------------------
# no_retrace primitive
# ---------------------------------------------------------------------------
def test_counter_observes_compiles():
    f = jax.jit(lambda x: x * 2 + 1)
    before = compile_count()
    f(jnp.ones((3, 7)))
    assert compile_count() > before


def test_no_retrace_passes_warm_and_counts():
    f = jax.jit(lambda x: x * 3)
    x = jnp.ones((2, 5))
    y = x + 1  # eager ops compile tiny executables too — stage outside
    f(x)  # warm
    with no_retrace(budget=0) as used:
        f(x)
        f(y)  # same shape/dtype: cache hit
        assert used() == 0


def test_no_retrace_raises_over_budget():
    f = jax.jit(lambda x: x - 1)
    with pytest.raises(RetraceError, match="budget 0"):
        with no_retrace(budget=0, what="cold call"):
            f(jnp.ones((4, 9)))  # first call must compile


def test_no_retrace_budget_allows_expected_compiles():
    f = jax.jit(lambda x: x / 2)
    x = jnp.ones((5, 11))  # jnp.ones compiles a fill — stage outside
    with no_retrace(budget=1):
        f(x)  # exactly the budgeted compile


# ---------------------------------------------------------------------------
# the serving invariant: warmup covers every bucket the storm can hit
# ---------------------------------------------------------------------------
def test_engine_storm_zero_compiles_after_warmup():
    index = FlatIndex().build(_corpus())
    engine = SearchEngine(index, max_batch=8, max_wait_ms=1.0)
    engine.start().warmup(ks=(5,))
    rng = np.random.default_rng(1)
    queries = rng.standard_normal((40, 16)).astype(np.float32)
    try:
        # 8 threads x distinct queries: the scheduler coalesces them into
        # whatever batch sizes timing produces; every padded bucket (pow2
        # up to max_batch) must already be compiled
        with no_retrace(budget=0, what="warm mixed-size storm"):
            with ThreadPoolExecutor(max_workers=8) as pool:
                results = list(pool.map(
                    lambda q: engine.search_one(q, k=5), queries))
        assert len(results) == 40
        assert all(r.indices.shape == (1, 5) for r in results)
    finally:
        engine.stop()


# ---------------------------------------------------------------------------
# transfer guard: explicit staging legal, implicit per-call copies not
# ---------------------------------------------------------------------------
def test_transfer_guard_blocks_implicit_h2d():
    f = jax.jit(lambda x: x + 0.0)
    f(jnp.ones(4))  # warm, so the failure below is the transfer, not trace
    with pytest.raises(Exception, match="[Dd]isallow"):
        with no_host_to_device():
            f(np.ones(4, np.float32))


@pytest.mark.parametrize("make", [FlatIndex, SQ8Index],
                         ids=["flat", "sq8"])
def test_scan_hot_path_clean_under_transfer_guard(make):
    corpus = _corpus()
    index = make().build(corpus)
    q = _corpus(6, 16, seed=2)
    index.search(q, 5)  # warm outside the guard
    with no_host_to_device():
        res = index.search(q, 5)
    assert res.indices.shape == (6, 5)
    # exact tier sanity: nearest neighbor of a corpus row is itself
    if isinstance(index, FlatIndex):
        with no_host_to_device():
            self_hit = index.search(corpus[:3], 1)
        assert list(self_hit.indices[:, 0]) == [0, 1, 2]


def test_engine_serving_clean_under_transfer_guard():
    index = FlatIndex().build(_corpus())
    engine = SearchEngine(index, max_batch=4, max_wait_ms=1.0)
    engine.start().warmup(ks=(5,))
    try:
        with no_host_to_device():
            res = engine.search_one(np.asarray(_corpus(1, 16, seed=3)[0]),
                                    k=5)
        assert res.indices.shape == (1, 5)
    finally:
        engine.stop()
