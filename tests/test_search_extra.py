"""Extra search-tier coverage: IVF index, pipeline, parser units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic
from repro.search import ivf

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def corpus():
    return jnp.asarray(synthetic.embedding_corpus(2000, 32, n_clusters=8,
                                                  intrinsic=12, seed=0))


def test_ivf_build_covers_corpus(corpus):
    idx = ivf.build(corpus, n_cells=16, seed=0)
    ids = np.asarray(idx.lists)
    got = np.sort(ids[ids >= 0])
    assert idx.spill == 0
    assert len(got) == corpus.shape[0]
    assert np.array_equal(np.unique(got), np.arange(corpus.shape[0]))


def test_ivf_full_probe_equals_exact(corpus):
    """nprobe = n_cells must reproduce the exact scan."""
    from repro.core.metrics import knn_indices

    idx = ivf.build(corpus, n_cells=8, seed=0)
    q = corpus[:32] + 0.01
    _, got = ivf.search(idx, q, 10, nprobe=8)
    exact = knn_indices(q, corpus, 10)
    inter = (np.asarray(exact)[:, :, None] ==
             np.asarray(got)[:, None, :]).any(-1).mean()
    assert inter == pytest.approx(1.0)


def test_ivf_recall_monotone_in_nprobe(corpus):
    idx = ivf.build(corpus, n_cells=32, seed=0)
    q = corpus[:64] + 0.01
    recalls = [ivf.recall_vs_exact(idx, corpus, q, 10, p) for p in (1, 4, 16)]
    assert recalls[0] <= recalls[1] + 1e-6 <= recalls[2] + 2e-6
    assert recalls[-1] > 0.9


def test_ivf_composes_with_rae(corpus):
    """IVF over the RAE-reduced corpus + full-space rerank (beyond-paper)."""
    from repro.configs import RAEConfig
    from repro.core import rae as rae_lib, trainer
    from repro.core.metrics import knn_indices

    res = trainer.train(RAEConfig(in_dim=32, out_dim=8, steps=200,
                                  weight_decay=0.3),
                        np.asarray(corpus), log_every=10**9)
    reduced = rae_lib.encode(res.params, corpus)
    idx = ivf.build(reduced, n_cells=16, seed=0)
    q = corpus[:32] + 0.01
    zq = rae_lib.encode(res.params, q)
    # 4x-compressed 8-dim stage 1 (kappa(W) bounds the recall loss, Eq. 16)
    _, cand = ivf.search(idx, zq, 80, nprobe=16)
    cvecs = jnp.take(corpus, cand, axis=0)
    s = -jnp.sum(jnp.square(cvecs - q[:, None, :]), -1)
    _, sel = jax.lax.top_k(s, 10)
    got = jnp.take_along_axis(cand, sel, axis=1)
    exact = knn_indices(q, corpus, 10)
    inter = (np.asarray(exact)[:, :, None] ==
             np.asarray(got)[:, None, :]).any(-1).mean()
    assert inter > 0.8  # measured 0.88


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_prefetcher_order_and_close():
    from repro.data.pipeline import Prefetcher, StepIndexedSource

    src = StepIndexedSource(lambda step: step * step, seed=0)
    it = Prefetcher(iter([src.batch_at(i) for i in range(10)]), depth=2)
    assert list(it) == [i * i for i in range(10)]


def test_prefetcher_propagates_errors():
    from repro.data.pipeline import Prefetcher

    def gen():
        yield 1
        raise ValueError("boom")

    it = Prefetcher(gen(), depth=2)
    assert next(it) == 1
    with pytest.raises(ValueError):
        for _ in it:
            pass


def test_step_indexed_source_resumable():
    from repro.data.pipeline import StepIndexedSource

    src = StepIndexedSource(
        lambda step: np.random.default_rng(step).normal(size=4), seed=0)
    a = list(x.sum() for x in [src.batch_at(i) for i in range(3, 6)])
    it = src.iterate(start_step=3)
    b = [next(it).sum() for _ in range(3)]
    assert a == b


# ---------------------------------------------------------------------------
# HLO analysis units (the roofline's collective accounting)
# ---------------------------------------------------------------------------
HLO_SAMPLE = """
HloModule test

%cond.1 (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %c = s32[] constant(10)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body.1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), channel_id=1, replica_groups={{0,1}}
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8] parameter(0)
  %ag = f32[16]{0} all-gather(%a), channel_id=2, replica_groups={{0,1}}
  %init = (s32[], f32[8]) tuple(s32[] constant(0), %a)
  %w = (s32[], f32[8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[8] get-tuple-element(%w), index=1
}
"""


def test_hlo_collective_bytes_loop_adjusted():
    from repro.launch.hlo_analysis import collective_bytes, count_collectives

    coll = collective_bytes(HLO_SAMPLE)
    # all-gather at entry: 16 * 4 = 64 bytes; all-reduce in the 10-trip
    # loop: 8 * 4 * 2(ring) * 10 = 640
    assert coll["all-gather"] == 64
    assert coll["all-reduce"] == 640
    counts = count_collectives(HLO_SAMPLE)
    assert counts == {"all-gather": 1, "all-reduce": 1}


def test_reduce_config_all_archs_valid():
    from repro.configs import ARCH_IDS, get_arch
    from repro.configs.reduce import reduce_cell, reduce_config
    from repro.configs.registry import get_shapes

    for arch in ARCH_IDS:
        cfg, family = get_arch(arch)
        r = reduce_config(cfg, family)
        for cell in get_shapes(arch):
            rc = reduce_cell(cell, family)
            assert rc.name == cell.name
        if family == "lm":
            assert r.n_layers <= 2 and r.vocab_size <= 1024
