"""Serving engine: scheduler parity, cache semantics, flush timing, HTTP.

The parity contract is BITWISE: a request answered inside a coalesced
padded batch must match the result the same query gets from a direct
``index.search`` call. The corpus here is small random integers cast to
f32, so every distance accumulates exactly in float32 regardless of how
XLA tiles the batched matmul — bitwise equality is well-defined, not a
numerics lottery.
"""
import json
import threading
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro import api
from repro.serve import LRUCache, SearchEngine, start_http_server
from repro.serve.engine import _buckets

jax.config.update("jax_platform_name", "cpu")

N, DIM, K = 512, 32, 5


def _int_corpus(seed: int, n: int = N, dim: int = DIM) -> np.ndarray:
    """Integer-valued f32 vectors: exact arithmetic, so batched and
    per-query scans agree bitwise. Rows are distinct w.p. ~1."""
    rng = np.random.default_rng(seed)
    return rng.integers(-8, 8, (n, dim)).astype(np.float32)


@pytest.fixture(scope="module")
def corpus():
    return _int_corpus(0)


@pytest.fixture(scope="module")
def flat(corpus):
    return api.FlatIndex().build(corpus)


@pytest.fixture()
def engine(flat):
    eng = SearchEngine(flat, max_batch=8, max_wait_ms=5.0, cache_size=64)
    with eng:
        yield eng


# ---------------------------------------------------------------------------
# LRU cache unit
# ---------------------------------------------------------------------------
def test_lru_eviction_order():
    c = LRUCache(2)
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1      # refresh a
    c.put("c", 3)               # evicts b (LRU)
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    s = c.stats()
    assert s["size"] == 2 and s["hits"] == 3 and s["misses"] == 1


def test_lru_size_zero_disables():
    c = LRUCache(0)
    c.put("a", 1)
    assert c.get("a") is None and len(c) == 0


def test_buckets_cover_max_batch():
    assert _buckets(32) == [1, 2, 4, 8, 16, 32]
    assert _buckets(24) == [1, 2, 4, 8, 16, 24]
    assert _buckets(1) == [1]


# ---------------------------------------------------------------------------
# Scheduler: parity, ordering, flush timing
# ---------------------------------------------------------------------------
def test_batched_matches_sequential_bitwise(engine, flat, corpus):
    """Coalesced answers == per-query index.search, scores and ids."""
    n_clients = 24  # 3x max_batch: several padded batches
    results = [None] * n_clients
    barrier = threading.Barrier(n_clients)

    def client(i):
        barrier.wait()  # maximal overlap -> real coalescing
        results[i] = engine.search_one(corpus[i], K)

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i in range(n_clients):
        ref = flat.search(corpus[i:i + 1], K)
        assert np.array_equal(results[i].indices, ref.indices)
        assert np.array_equal(results[i].scores, ref.scores)
    stats = engine.stats()
    assert stats["requests"] == n_clients
    # coalescing actually happened: fewer searches than requests
    assert stats["batches"] < n_clients
    assert sum(s * c for s, c in
               ((int(k), v) for k, v in stats["batch_size_hist"].items())
               ) == n_clients


def test_interleaved_clients_get_their_own_results(engine, corpus):
    """Each client queries ITS exact corpus row; top-1 must be that row."""
    rows = list(range(0, 64, 2))
    out = {}

    def client(row):
        out[row] = engine.search_one(corpus[row], K)

    threads = [threading.Thread(target=client, args=(r,)) for r in rows]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for row in rows:
        assert out[row].indices[0, 0] == row
        assert out[row].scores[0, 0] == 0.0  # exact row: distance 0


def test_lone_request_flushes_at_max_wait(flat, corpus):
    """A single request must not wait for a full batch: the scheduler
    flushes after max_wait_ms."""
    with SearchEngine(flat, max_batch=64, max_wait_ms=20.0,
                      cache_size=0) as eng:
        eng.warmup(ks=(K,))
        t0 = time.perf_counter()
        res = eng.search_one(corpus[3], K)
        dt = time.perf_counter() - t0
    assert res.indices[0, 0] == 3
    # generous bound: wait (20ms) + a warm small search + scheduling slack
    assert dt < 5.0
    assert eng.stats()["batch_size_hist"] == {"1": 1}


def test_mixed_k_requests_grouped_correctly(engine, corpus):
    out = {}

    def client(i, k):
        out[(i, k)] = engine.search_one(corpus[i], k)

    threads = [threading.Thread(target=client, args=(i, k))
               for i in range(8) for k in (3, 7)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for (i, k), res in out.items():
        assert res.indices.shape == (1, k)
        assert res.indices[0, 0] == i


def test_search_batch_passthrough_counts_metrics(engine, flat, corpus):
    q = corpus[:16]
    res = engine.search(q, K)
    ref = flat.search(q, K)
    assert np.array_equal(res.indices, ref.indices)
    assert engine.stats()["requests"] == 16


def test_engine_requires_built_index():
    with pytest.raises(RuntimeError, match="before build"):
        SearchEngine(api.FlatIndex())


def test_engine_rejects_batch_on_single_path(engine, corpus):
    with pytest.raises(ValueError, match="ONE query"):
        engine.search_one(corpus[:4], K)


def test_engine_rejects_wrong_dim_before_batching(engine):
    """A wrong-dim request must fail alone, never poison a shared batch."""
    with pytest.raises(ValueError, match="takes 32-d"):
        engine.search_one(np.zeros(DIM + 1, np.float32), K)


def test_stopped_engine_rejects_instead_of_hanging(flat, corpus):
    eng = SearchEngine(flat, max_batch=4, max_wait_ms=1.0)
    eng.start()
    eng.stop()
    # auto-restart via search_one is allowed; but a direct asearch on a
    # stopping engine errors instead of wedging the caller
    assert not eng._accepting


# ---------------------------------------------------------------------------
# Cache: hits, fingerprint invalidation
# ---------------------------------------------------------------------------
def test_cache_hit_on_repeat_query(engine, corpus):
    q = corpus[9]
    r1 = engine.search_one(q, K)
    h0 = engine.cache.hits
    r2 = engine.search_one(q, K)
    assert engine.cache.hits == h0 + 1
    assert np.array_equal(r1.indices, r2.indices)
    assert engine.stats()["cache"]["hit_rate"] > 0


def test_cached_results_are_frozen(engine, corpus):
    """A caller mutating its result must not poison future cache hits."""
    q = corpus[21]
    r1 = engine.search_one(q, K)
    with pytest.raises(ValueError, match="read-only"):
        r1.indices[0, 0] = -99
    r2 = engine.search_one(q, K)  # hit: still the true answer
    assert r2.indices[0, 0] == 21


def test_cache_distinguishes_k(engine, corpus):
    q = corpus[11]
    engine.search_one(q, 3)
    m0 = engine.cache.misses
    engine.search_one(q, 4)  # same bytes, different k -> miss
    assert engine.cache.misses == m0 + 1


def test_cache_invalidated_by_index_swap(corpus):
    other = api.FlatIndex().build(_int_corpus(1))
    with SearchEngine(api.FlatIndex().build(corpus), max_batch=4,
                      max_wait_ms=1.0) as eng:
        q = corpus[7]
        before = eng.search_one(q, K)
        eng.search_one(q, K)
        assert eng.cache.hits == 1
        fp0 = eng.stats()["index"]["fingerprint"]
        eng.set_index(other)
        assert eng.stats()["index"]["fingerprint"] != fp0
        after = eng.search_one(q, K)  # must MISS: old entry is stale
        assert eng.cache.hits == 1 and eng.cache.misses == 2
        assert not np.array_equal(before.indices, after.indices)
        ref = other.search(q[None], K)
        assert np.array_equal(after.indices, ref.indices)


def test_fingerprint_stable_across_identical_builds(corpus):
    a = api.FlatIndex().build(corpus)
    b = api.FlatIndex().build(corpus)
    assert a.fingerprint() == b.fingerprint()
    assert a.fingerprint() != api.FlatIndex().build(_int_corpus(2)
                                                    ).fingerprint()


def test_fingerprint_covers_composite_stages(corpus):
    i1 = api.index_factory("PCA8,Flat,Rerank2").build(corpus)
    i2 = api.index_factory("PCA8,Flat,Rerank4").build(corpus)
    assert i1.fingerprint() != i2.fingerprint()


# ---------------------------------------------------------------------------
# Stats / warmup / lifecycle
# ---------------------------------------------------------------------------
def test_stats_surface_shape(engine, corpus):
    engine.search_one(corpus[0], K)
    s = engine.stats()
    for key in ("uptime_s", "requests", "batches", "qps", "batch_size_hist",
                "latency_ms", "cache", "index", "scheduler"):
        assert key in s, key
    assert s["latency_ms"]["p50"] <= s["latency_ms"]["p99"]
    assert s["index"]["ntotal"] == N
    assert s["scheduler"]["max_batch"] == 8
    assert s["distance_evals"] == N  # flat scan touches everything


def test_warmup_does_not_touch_metrics(flat):
    with SearchEngine(flat, max_batch=4) as eng:
        eng.warmup(ks=(K,))
        assert eng.stats()["requests"] == 0


def test_engine_restartable(flat, corpus):
    eng = SearchEngine(flat, max_batch=4, max_wait_ms=1.0)
    assert eng.search_one(corpus[1], K).indices[0, 0] == 1  # auto-start
    eng.stop()
    assert not eng.running
    assert eng.search_one(corpus[2], K).indices[0, 0] == 2  # restart
    eng.stop()


# ---------------------------------------------------------------------------
# HTTP front-end
# ---------------------------------------------------------------------------
@pytest.fixture()
def http_engine(flat):
    # 10ms wait: wide enough that staggered HTTP handler threads still
    # coalesce on a loaded CI box
    eng = SearchEngine(flat, max_batch=8, max_wait_ms=10.0)
    eng.start()
    server, thread = start_http_server(eng, port=0)
    port = server.server_address[1]
    yield eng, port
    server.shutdown()
    eng.stop()


def _get(port, path):
    with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}",
                                timeout=30) as r:
        return r.status, json.loads(r.read())


def _post(port, path, payload):
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}",
        data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=60) as r:
        return r.status, json.loads(r.read())


def test_http_healthz(http_engine):
    _, port = http_engine
    status, body = _get(port, "/healthz")
    assert status == 200 and body["status"] == "ok"
    assert body["ntotal"] == N


def test_http_search_single_and_batch(http_engine, flat, corpus):
    _, port = http_engine
    status, body = _post(port, "/search",
                         {"query": corpus[5].tolist(), "k": 3})
    assert status == 200
    assert body["indices"][0] == 5
    assert body["distance_evals"] == N
    ref = flat.search(corpus[:2], 3)
    status, batch = _post(port, "/search",
                          {"queries": corpus[:2].tolist(), "k": 3})
    assert status == 200
    assert batch["indices"] == ref.indices.tolist()


def test_http_stats_reflects_traffic(http_engine, corpus):
    eng, port = http_engine
    _post(port, "/search", {"query": corpus[0].tolist(), "k": K})
    _, stats = _get(port, "/stats")
    assert stats["requests"] >= 1
    assert stats["index"]["fingerprint"] == eng.stats()["index"]["fingerprint"]


def test_http_bad_requests(http_engine):
    _, port = http_engine
    with pytest.raises(urllib.error.HTTPError) as e:
        _post(port, "/search", {"k": 3})
    assert e.value.code == 400
    with pytest.raises(urllib.error.HTTPError) as e:
        _get(port, "/nope")
    assert e.value.code == 404


def _expect_400(port, payload, fragment):
    with pytest.raises(urllib.error.HTTPError) as e:
        _post(port, "/search", payload)
    assert e.value.code == 400
    body = json.loads(e.value.read())
    assert fragment in body["error"], body["error"]


def test_http_rejects_bad_k(http_engine, corpus):
    """k=0 and negative k must bounce with 400, not slice to an empty or
    reversed result deep inside the index."""
    _, port = http_engine
    q = corpus[3].tolist()
    _expect_400(port, {"query": q, "k": 0}, "k must be >= 1")
    _expect_400(port, {"query": q, "k": -3}, "k must be >= 1")


def test_http_rejects_wrong_dim(http_engine):
    _, port = http_engine
    _expect_400(port, {"query": [1.0] * (DIM + 3), "k": 3},
                f"query dim {DIM + 3} != index dim {DIM}")
    _expect_400(port, {"queries": [[1.0] * (DIM - 1)] * 2, "k": 3},
                f"query dim {DIM - 1} != index dim {DIM}")
    # a batch posted to the single-query field (and vice versa) is a
    # shape error, not a silent reinterpretation
    _expect_400(port, {"query": [[1.0] * DIM] * 2, "k": 3}, "dimension")
    _expect_400(port, {"queries": [1.0] * DIM, "k": 3}, "dimension")


def test_http_rejects_non_finite_query(http_engine, corpus):
    """A NaN query must never reach the engine: the result cache keys on
    query bytes, so a poisoned entry would keep serving garbage."""
    eng, port = http_engine
    q = corpus[3].astype(float).tolist()
    q[0] = float("nan")
    _expect_400(port, {"query": q, "k": 3}, "NaN")
    _expect_400(port, {"queries": [q], "k": 3}, "NaN")
    # the good twin of the poisoned query still answers 200 afterwards
    status, body = _post(port, "/search",
                         {"query": corpus[3].tolist(), "k": 3})
    assert status == 200 and body["indices"][0] == 3


def test_http_concurrent_clients_coalesce(http_engine, flat, corpus):
    eng, port = http_engine
    rows = list(range(16))
    out, errors = {}, {}

    def client(row):
        # a transient connection failure (thundering-herd connect on a
        # loaded box) is retried once; a real error is surfaced below
        for attempt in (0, 1):
            try:
                out[row] = _post(port, "/search",
                                 {"query": corpus[row].tolist(),
                                  "k": K})[1]
                return
            except Exception as e:  # noqa: BLE001 - recorded, re-raised
                errors[row] = e
                time.sleep(0.05)

    threads = [threading.Thread(target=client, args=(r,)) for r in rows]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    missing = [r for r in rows if r not in out]
    assert not missing, f"rows {missing} failed: " \
                        f"{ {r: repr(errors.get(r)) for r in missing} }"
    for row in rows:
        assert out[row]["indices"][0] == row
    assert eng.stats()["batches"] < len(rows)
