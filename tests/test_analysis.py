"""The static-analysis suite, tested both ways.

Against ``tests/fixtures/lint`` — a seeded-violation tree where every
checker rule fires exactly once (twice where the fixture plants two) —
the checkers must report precisely the planted set: no misses, no
extras. Against the real repo, they must report *nothing*: that test is
the pytest binding of the lint gate, so a PR that introduces an impure
jit function, an incomplete kernel triple, or an unhashed index
attribute fails the plain test run even before ``scripts/ci.sh`` runs
``scripts/lint.py``.

The CLI's exit-code contract (0 clean / 1 findings / 2 usage) and
``--format json`` shape are pinned via subprocess, same style as
``scripts/check_bench.py``'s tests.
"""
from __future__ import annotations

import json
import subprocess
import sys
from collections import Counter
from pathlib import Path

import pytest

from repro.analysis import CHECKERS, run_checks

REPO = Path(__file__).resolve().parents[1]
FIXTURE = REPO / "tests" / "fixtures" / "lint"
LINT = REPO / "scripts" / "lint.py"

#: every (checker, rule, path) the fixture plants — exact, with counts
EXPECTED = Counter({
    ("jit-purity", "host-print", "src/repro/impure.py"): 1,
    ("jit-purity", "host-time", "src/repro/impure.py"): 1,
    ("jit-purity", "host-random", "src/repro/impure.py"): 1,
    ("jit-purity", "host-concretize", "src/repro/impure.py"): 1,
    ("jit-purity", "set-iteration", "src/repro/impure.py"): 1,
    # np.asarray in _inner, reached through a jax.jit(partial(...)) site
    ("jit-purity", "host-numpy", "src/repro/impure.py"): 1,
    # np.array two call-graph hops away, in another module
    ("jit-purity", "host-numpy", "src/repro/hostutil.py"): 1,
    # print inside a pl.pallas_call kernel body
    ("jit-purity", "host-print", "src/repro/kernels/badkern/kernel.py"): 1,
    ("fingerprint", "child-fingerprint", "src/repro/indexes.py"): 1,
    ("fingerprint", "fingerprint-missing", "src/repro/indexes.py"): 1,
    # StreamyIndex.insert bumps self.epoch but never fingerprints it
    ("fingerprint", "mutation-epoch", "src/repro/indexes.py"): 1,
    # TunedIndex.set_params applies a knob it never fingerprints
    ("fingerprint", "tuned-policy", "src/repro/indexes.py"): 1,
    ("fingerprint", "save-coverage", "src/repro/indexes.py"): 1,
    ("fingerprint", "stale-exemption", "src/repro/indexes.py"): 1,
    ("fingerprint", "unknown-exemption", "src/repro/indexes.py"): 1,
    ("kernel-contract", "missing-file",
     "src/repro/kernels/badkern/ref.py"): 1,
    ("kernel-contract", "missing-symbol",
     "src/repro/kernels/offkern/kernel.py"): 1,
    ("kernel-contract", "signature-mismatch",
     "src/repro/kernels/offkern/ref.py"): 1,
    # quantkern's ref drops mode/ksub — codec-algebra params are not
    # tuning knobs (the quantized-hop contract)
    ("kernel-contract", "signature-mismatch",
     "src/repro/kernels/quantkern/ref.py"): 1,
    ("kernel-contract", "missing-reexport",
     "src/repro/kernels/badkern/__init__.py"): 1,
    # the kernels package re-exports none of the three triples
    ("kernel-contract", "missing-reexport",
     "src/repro/kernels/__init__.py"): 3,
    # NEG_INF = -1e30 trips both the redefinition and the raw literal
    ("kernel-contract", "pad-sentinel",
     "src/repro/kernels/badkern/kernel.py"): 2,
    ("kernel-contract", "pad-sentinel",
     "src/repro/kernels/badkern/ops.py"): 1,
    ("kernel-contract", "unregistered-parity", "tests/test_kernels.py"): 2,
    ("kernel-contract", "unregistered-ci", "scripts/ci.sh"): 2,
})


@pytest.fixture(scope="module")
def fixture_findings():
    return run_checks(str(FIXTURE / "src"), repo_root=str(FIXTURE))


def _lint(*args):
    return subprocess.run([sys.executable, str(LINT), *args],
                          capture_output=True, text=True, cwd=REPO)


# ---------------------------------------------------------------------------
# checkers vs the seeded fixture
# ---------------------------------------------------------------------------
def test_fixture_findings_exact(fixture_findings):
    got = Counter((f.checker, f.rule, f.path) for f in fixture_findings)
    assert got == EXPECTED


def test_pragma_suppresses_only_its_line(fixture_findings):
    prints = [f for f in fixture_findings
              if f.path == "src/repro/impure.py" and f.rule == "host-print"]
    # two prints are planted; the one tagged `lint: ignore[host-print]`
    # (in pragma_escape) must not survive
    assert len(prints) == 1
    src = (FIXTURE / "src/repro/impure.py").read_text().splitlines()
    assert "print" in src[prints[0].line - 1]
    assert "ignore" not in src[prints[0].line - 1]


def test_findings_carry_root_context(fixture_findings):
    by_line = {(f.path, f.rule): f for f in fixture_findings}
    deep = by_line[("src/repro/hostutil.py", "host-numpy")]
    # the report names the jit root, not just the construct, so the
    # reader knows WHY host code two modules away is traced
    assert "impure_decorated" in deep.message
    pallas = by_line[("src/repro/kernels/badkern/kernel.py", "host-print")]
    assert "pallas_call" in pallas.message


def test_checker_selection(fixture_findings):
    only_fp = run_checks(str(FIXTURE / "src"), repo_root=str(FIXTURE),
                         checkers=["fingerprint"])
    assert {f.checker for f in only_fp} == {"fingerprint"}
    assert len(only_fp) == sum(
        1 for f in fixture_findings if f.checker == "fingerprint")


def test_unknown_checker_rejected():
    with pytest.raises(ValueError, match="unknown checker"):
        run_checks(str(FIXTURE / "src"), repo_root=str(FIXTURE),
                   checkers=["typo"])


# ---------------------------------------------------------------------------
# the gate itself: this repo must lint clean
# ---------------------------------------------------------------------------
def test_repo_is_clean():
    findings = run_checks(str(REPO / "src"), repo_root=str(REPO))
    assert findings == [], "\n".join(f.format() for f in findings)


# ---------------------------------------------------------------------------
# CLI contract (exit codes + JSON shape)
# ---------------------------------------------------------------------------
def test_cli_clean_repo_exits_0():
    proc = _lint("--format", "json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["count"] == 0 and payload["findings"] == []
    assert payload["checkers"] == list(CHECKERS)


def test_cli_findings_exit_1_with_json():
    proc = _lint("--root", str(FIXTURE), "--format", "json")
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert payload["count"] == sum(EXPECTED.values()) == \
        len(payload["findings"])
    f = payload["findings"][0]
    assert set(f) >= {"path", "line", "checker", "rule", "message"}


def test_cli_text_output_lists_findings():
    proc = _lint("--root", str(FIXTURE))
    assert proc.returncode == 1
    assert "[kernel-contract/missing-file]" in proc.stdout
    assert proc.stdout.strip().endswith(
        f"lint: {sum(EXPECTED.values())} finding(s) "
        "[jit-purity, kernel-contract, fingerprint]")


def test_cli_usage_errors_exit_2():
    assert _lint("--checker", "bogus").returncode == 2
    assert _lint("--root", "/nonexistent/place").returncode == 2


# ---------------------------------------------------------------------------
# check_bench.py shares the exit-code + --format json convention
# ---------------------------------------------------------------------------
CHECK_BENCH = REPO / "scripts" / "check_bench.py"


def _bench_dirs(tmp_path, cand_recall):
    rows = [{"name": "flat", "recall@10": 0.95, "qps": 120.0}]
    for side, recall in (("base", 0.95), ("cand", cand_recall)):
        d = tmp_path / side
        d.mkdir()
        (d / "BENCH_toy.json").write_text(json.dumps(
            {"rows": [dict(rows[0], **{"recall@10": recall})]}))
    return tmp_path / "base", tmp_path / "cand"


def _check_bench(*args):
    return subprocess.run([sys.executable, str(CHECK_BENCH), *args],
                          capture_output=True, text=True, cwd=REPO)


def test_check_bench_json_clean_exits_0(tmp_path):
    base, cand = _bench_dirs(tmp_path, cand_recall=0.95)
    proc = _check_bench("--baseline", str(base), "--candidate", str(cand),
                        "--format", "json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["count"] == 0 and payload["failures"] == []
    assert payload["benches"] == [{"name": "toy", "baseline_rows": 1,
                                   "candidate_rows": 1, "failures": []}]


def test_check_bench_json_regression_exits_1(tmp_path):
    base, cand = _bench_dirs(tmp_path, cand_recall=0.80)
    proc = _check_bench("--baseline", str(base), "--candidate", str(cand),
                        "--format", "json")
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert payload["count"] == 1 == len(payload["failures"])
    assert "recall@10" in payload["failures"][0]


def _graph_bench_dirs(tmp_path, ratio, quant_recall):
    """Identical base/cand BENCH_graph.json: isolates the candidate-side
    quantized-graph gates from the baseline-diff gates."""
    rows = [{"spec": "RAE64,HNSW32,Rerank4", "space": "rae64",
             "recall_at_k": 0.99,
             "traversal_gather_bytes_per_hop": 400000.0},
            {"spec": "RAE64,HNSW32,SQ8,Rerank4", "space": "rae64",
             "recall_at_k": quant_recall,
             "traversal_gather_bytes_per_hop": 400000.0 / ratio}]
    for side in ("base", "cand"):
        d = tmp_path / side
        d.mkdir(parents=True)
        (d / "BENCH_graph.json").write_text(json.dumps({"rows": rows}))
    return tmp_path / "base", tmp_path / "cand"


def test_check_bench_graph_quant_gates(tmp_path):
    """The quantized-graph block: a healthy SQ8 row passes; too little
    gather-bytes saving or post-rerank recall leakage each fail on their
    own message."""
    base, cand = _graph_bench_dirs(tmp_path / "ok", ratio=4.0,
                                   quant_recall=0.99)
    assert _check_bench("--baseline", str(base), "--candidate",
                        str(cand)).returncode == 0
    base, cand = _graph_bench_dirs(tmp_path / "bytes", ratio=2.0,
                                   quant_recall=0.99)
    proc = _check_bench("--baseline", str(base), "--candidate", str(cand))
    assert proc.returncode == 1 and "gather traffic" in proc.stdout
    base, cand = _graph_bench_dirs(tmp_path / "recall", ratio=4.0,
                                   quant_recall=0.90)
    proc = _check_bench("--baseline", str(base), "--candidate", str(cand))
    assert proc.returncode == 1 and "rerank" in proc.stdout


def _churn_bench_dirs(tmp_path, **overrides):
    """Identical base/cand BENCH_churn.json: isolates the candidate-side
    live-mutation gates from the baseline-diff gates."""
    row = {"spec": "Mut,HNSW16", "turnover_frac": 0.08,
           "recall_at_k": 0.99, "recall_ratio_vs_static": 0.998,
           "tombstone_violations": 0, "dropped_queries": 0,
           "qps_under_churn": 100.0}
    row.update(overrides)
    payload = {"rows": [row], "config": {"churn_qps_floor": 25.0,
                                         "churn_recall_ratio_floor": 0.95}}
    for side in ("base", "cand"):
        d = tmp_path / side
        d.mkdir(parents=True)
        (d / "BENCH_churn.json").write_text(json.dumps(payload))
    return tmp_path / "base", tmp_path / "cand"


def test_check_bench_churn_gates(tmp_path):
    """The live-mutation block: a healthy soak passes; thin turnover, a
    recall collapse, a single tombstone violation, a dropped query, or a
    QPS miss each fail on their own message."""
    base, cand = _churn_bench_dirs(tmp_path / "ok")
    assert _check_bench("--baseline", str(base),
                        "--candidate", str(cand)).returncode == 0
    for sub, overrides, fragment in [
            ("thin", {"turnover_frac": 0.02}, "soak floor"),
            ("ratio", {"recall_ratio_vs_static": 0.90}, "collapsing"),
            ("tomb", {"tombstone_violations": 1}, "tombstone"),
            ("drop", {"dropped_queries": 3}, "dropped"),
            ("qps", {"qps_under_churn": 10.0}, "sustained-QPS")]:
        base, cand = _churn_bench_dirs(tmp_path / sub, **overrides)
        proc = _check_bench("--baseline", str(base), "--candidate",
                            str(cand), "--qps-tol", "0.99",
                            "--recall-tol", "1.0")
        assert proc.returncode == 1 and fragment in proc.stdout, \
            (sub, proc.stdout)


def _autotune_bench_dirs(tmp_path, **overrides):
    """Identical base/cand BENCH_autotune.json: isolates the
    candidate-side self-tuning gates from the baseline-diff gates."""
    row = {"spec": "RAE64,IVF256,Rerank4", "space": "slo0.95",
           "target_recall": 0.95, "recall_holdout": 0.98,
           "default_recall": 0.99, "evals_ratio": 0.55,
           "escalation_rate": 0.06}
    row.update(overrides)
    row = {k: v for k, v in row.items() if v is not None}  # None = drop key
    payload = {"rows": [row],
               "config": {"autotune_recall_slack": 0.01,
                          "autotune_evals_ratio_max": 0.70,
                          "autotune_required_specs":
                              ["RAE64,IVF256,Rerank4"]}}
    for side in ("base", "cand"):
        d = tmp_path / side
        d.mkdir(parents=True)
        (d / "BENCH_autotune.json").write_text(json.dumps(payload))
    return tmp_path / "base", tmp_path / "cand"


def test_check_bench_autotune_gates(tmp_path):
    """The self-tuning block: a healthy tuned row passes; an SLO miss on
    the holdout split, a thin evals saving at equal recall, a missing or
    saturated escalation rate, and a dropped required stack each fail on
    their own message."""
    base, cand = _autotune_bench_dirs(tmp_path / "ok")
    assert _check_bench("--baseline", str(base),
                        "--candidate", str(cand)).returncode == 0
    # defaults below the SLO: the equal-recall cost gate is waived
    base, cand = _autotune_bench_dirs(tmp_path / "waived",
                                      default_recall=0.90,
                                      evals_ratio=1.3)
    assert _check_bench("--baseline", str(base),
                        "--candidate", str(cand)).returncode == 0
    for sub, overrides, fragment in [
            ("slo", {"recall_holdout": 0.92}, "missed the"),
            ("ratio", {"evals_ratio": 0.85}, "evals_ratio"),
            ("noesc", {"escalation_rate": None}, "escalation_rate missing"),
            ("allesc", {"escalation_rate": 1.0}, "margin signal"),
            ("spec", {"spec": "RAE64,Flat"}, "required stack")]:
        base, cand = _autotune_bench_dirs(tmp_path / sub, **overrides)
        proc = _check_bench("--baseline", str(base), "--candidate",
                            str(cand), "--recall-tol", "1.0")
        assert proc.returncode == 1 and fragment in proc.stdout, \
            (sub, proc.stdout)


def test_check_bench_usage_errors_exit_2(tmp_path):
    assert _check_bench("--baseline", str(tmp_path / "nope"),
                        "--candidate", str(tmp_path / "nope"),
                        "--format", "json").returncode == 2
    assert _check_bench("--baseline", ".", "--format", "bogus") \
        .returncode == 2
