"""Sharded serving: partitioning, scatter-gather merge, shard-count
invariance, and the legacy distributed-layer regressions.

The contract under test (docs/sharded_serving.md): sharded search is
**bitwise invariant to the shard count**. Integer-valued f32 corpora make
the per-shard arithmetic exact, so any S in {1, 2, 8} must produce the
identical (scores, indices) a FlatIndex over the whole corpus produces —
including on score ties (broken by the smaller global id) and ragged
(prime-sized) corpora. The three regression groups mirror the bugs the
rewrite of ``search/distributed.py`` fixed: dropped tail rows when
``n % n_shards != 0``, ``lax.top_k`` crashes when ``k > n_loc``, and
gather-order-dependent tie resolution.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (FlatIndex, ShardedIndex, index_factory, load_index,
                       parse_index_spec)
from repro.distributed.partitioning import (partition_ivf_cells,
                                            partition_rows)

jax.config.update("jax_platform_name", "cpu")


def _int_corpus(n, d, seed=0):
    """Integer-valued f32: exact arithmetic, dense score ties."""
    rng = np.random.default_rng(seed)
    x = rng.integers(-8, 8, (n, d)).astype(np.float32)
    x[n // 2] = x[n // 3]  # planted duplicate rows -> guaranteed ties
    return x


def _queries(n, d, seed=1):
    rng = np.random.default_rng(seed)
    return rng.integers(-8, 8, (n, d)).astype(np.float32)


# ---------------------------------------------------------------------------
# partitioning
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,s", [(101, 4), (509, 8), (7, 7), (5, 8), (0, 3)])
def test_partition_rows_disjoint_cover(n, s):
    parts = partition_rows(n, s)
    cat = np.concatenate(parts) if parts else np.empty(0, np.int32)
    np.testing.assert_array_equal(np.sort(cat), np.arange(n))
    # balanced: sizes differ by at most one — the ragged tail is spread,
    # not dumped on (or dropped from) the last shard
    sizes = [len(p) for p in parts]
    if sizes:
        assert max(sizes) - min(sizes) <= 1
    for p in parts:
        assert np.all(np.diff(p) > 0) if len(p) > 1 else True


def test_partition_rows_rejects_bad_count():
    with pytest.raises(ValueError):
        partition_rows(10, 0)


@pytest.mark.parametrize("n,s", [(101, 4), (64, 8)])
def test_partition_ivf_cells_disjoint_cover(n, s):
    corpus = _int_corpus(n, 8)
    parts = partition_ivf_cells(corpus, s, seed=3)
    cat = np.concatenate([p for p in parts if len(p)])
    np.testing.assert_array_equal(np.sort(cat), np.arange(n))
    for p in parts:
        if len(p) > 1:
            assert np.all(np.diff(p) > 0)  # ascending within each shard


# ---------------------------------------------------------------------------
# shard-count invariance (the tentpole contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [101, 509])  # primes: every split is ragged
@pytest.mark.parametrize("s", [1, 2, 8])
def test_sharded_bitwise_matches_flat(n, s):
    corpus = _int_corpus(n, 16)
    q = _queries(9, 16)
    ref = FlatIndex().build(corpus).search(q, 10)
    got = ShardedIndex(n_shards=s).build(corpus).search(q, 10)
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_array_equal(np.asarray(got.scores),
                                  np.asarray(ref.scores))


def test_sharded_invariant_across_shard_counts():
    corpus = _int_corpus(257, 12, seed=5)
    q = _queries(6, 12, seed=6)
    outs = [ShardedIndex(n_shards=s).build(corpus).search(q, 7)
            for s in (1, 2, 8)]
    for other in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0].indices),
                                      np.asarray(other.indices))
        np.testing.assert_array_equal(np.asarray(outs[0].scores),
                                      np.asarray(other.scores))


def test_ivf_partition_matches_flat():
    corpus = _int_corpus(150, 16, seed=7)
    q = _queries(5, 16, seed=8)
    ref = FlatIndex().build(corpus).search(q, 10)
    got = ShardedIndex(n_shards=4, partition="ivf",
                       seed=11).build(corpus).search(q, 10)
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(ref.indices))


def test_ragged_tail_rows_are_searchable():
    # legacy bug: n // n_shards slabs silently dropped the tail rows —
    # a query sitting exactly on a tail row must get it back as top-1
    corpus = _int_corpus(101, 16, seed=9)
    idx = ShardedIndex(n_shards=8).build(corpus)
    for row in (100, 97, 96):  # the 101 % 8 = 5 tail region and beyond
        r = idx.search(corpus[row:row + 1], 1)
        assert int(r.indices[0, 0]) == row


def test_k_larger_than_shard_size():
    # legacy bug: lax.top_k(s_l, k) crashed when k > rows-per-shard
    corpus = _int_corpus(101, 8, seed=10)
    q = _queries(4, 8, seed=11)
    ref = FlatIndex().build(corpus).search(q, 50)
    got = ShardedIndex(n_shards=8).build(corpus).search(q, 50)  # n_loc=13
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(ref.indices))


def test_k_larger_than_corpus():
    corpus = _int_corpus(11, 8, seed=12)
    got = ShardedIndex(n_shards=4).build(corpus).search(_queries(3, 8), 64)
    assert got.indices.shape == (3, 11)  # clamped to ntotal, no pad columns
    assert np.all(got.indices >= 0)


# ---------------------------------------------------------------------------
# factory grammar
# ---------------------------------------------------------------------------
def test_factory_parse_shard_round_trip():
    for s in ("Shard8,Flat", "RAE64,Shard8,IVF256,Rerank4",
              "PCA8,Shard4,IVF16,Rerank2", "Shard2,Flat,SQ8"):
        assert str(parse_index_spec(s)) == s
    # implicit stages canonicalize ("SQ8" alone means a flat SQ8 scan)
    assert str(parse_index_spec("Shard8")) == "Shard8,Flat"
    assert str(parse_index_spec("Shard2,SQ8")) == "Shard2,Flat,SQ8"
    assert parse_index_spec("Shard8").shards == 8


@pytest.mark.parametrize("bad", ["Shard", "Shard0", "Flat,Shard2",
                                 "Shard2,Shard4", "Shard2,RAE8,Flat"])
def test_factory_rejects_bad_shard_specs(bad):
    with pytest.raises(ValueError):
        parse_index_spec(bad)


def test_factory_builds_sharded_stack():
    corpus = _int_corpus(220, 16, seed=13)
    q = _queries(5, 16, seed=14)
    idx = index_factory("PCA8,Shard4,IVF16,Rerank2").build(corpus)
    base = idx.base
    assert isinstance(base, ShardedIndex) and base.shard_count == 4
    r = idx.search(q, 5)
    assert r.indices.shape == (5, 5) and np.all(r.indices >= 0)


def test_sharded_rejects_nested_wrappers_in_child_spec():
    with pytest.raises(ValueError):
        ShardedIndex(child_spec="Shard2,Flat").build(_int_corpus(20, 4))
    with pytest.raises(ValueError):
        ShardedIndex(child_spec="PCA4,Flat").build(_int_corpus(20, 4))


# ---------------------------------------------------------------------------
# persistence + fingerprint
# ---------------------------------------------------------------------------
def test_save_load_fingerprint_round_trip(tmp_path):
    corpus = _int_corpus(101, 8, seed=15)
    q = _queries(4, 8, seed=16)
    idx = ShardedIndex(n_shards=3, child_spec="IVF4").build(corpus)
    d = os.path.join(str(tmp_path), "idx")
    idx.save(d)
    idx2 = load_index(d)
    assert idx2.fingerprint() == idx.fingerprint()
    np.testing.assert_array_equal(np.asarray(idx.search(q, 5).indices),
                                  np.asarray(idx2.search(q, 5).indices))


def test_fingerprint_sensitive_to_sharding():
    corpus = _int_corpus(60, 8, seed=17)
    a = ShardedIndex(n_shards=2).build(corpus)
    b = ShardedIndex(n_shards=3).build(corpus)
    c = ShardedIndex(n_shards=2).build(_int_corpus(60, 8, seed=18))
    assert a.fingerprint() != b.fingerprint()  # layout differs
    assert a.fingerprint() != c.fingerprint()  # content differs


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def test_sharded_serves_through_engine():
    from repro.serve.engine import SearchEngine

    corpus = _int_corpus(101, 8, seed=19)
    q = _queries(6, 8, seed=20)
    idx = ShardedIndex(n_shards=4).build(corpus)
    ref = FlatIndex().build(corpus).search(q, 5)
    with SearchEngine(idx) as eng:
        res = eng.search(q, k=5)
        st = eng.stats()
    np.testing.assert_array_equal(np.asarray(res.indices),
                                  np.asarray(ref.indices))
    assert st["index"]["shards"] == 4


# ---------------------------------------------------------------------------
# device-parallel path (nightly: ci.sh forces 8 XLA host devices)
# ---------------------------------------------------------------------------
needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.mark.slow
@needs_mesh
def test_mesh_search_matches_flat_ragged():
    from repro.launch.mesh import make_mesh
    from repro.models.common import MeshCtx
    from repro.search.distributed import search as dist_search

    mesh = make_mesh((8,), ("data",))
    ctx = MeshCtx(mesh=mesh, rules={"db_rows": ("data",)})
    corpus = _int_corpus(101, 16, seed=21)  # ragged: 101 % 8 != 0
    q = _queries(7, 16, seed=22)
    ref = FlatIndex().build(corpus).search(q, 10)
    v, i = dist_search(jnp.asarray(q), jnp.asarray(corpus), 10, ctx)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ref.indices))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(ref.scores))


@pytest.mark.slow
@needs_mesh
def test_mesh_distributed_topk_small_shards():
    from repro.launch.mesh import make_mesh
    from repro.models.common import MeshCtx
    from repro.search.distributed import distributed_topk

    mesh = make_mesh((8,), ("data",))
    ctx = MeshCtx(mesh=mesh, rules={"db_rows": ("data",)})
    rng = np.random.default_rng(23)
    scores = jnp.asarray(rng.integers(-100, 100, (37,)), jnp.float32)
    k = 20  # > ceil(37 / 8) = 5 rows per shard: the legacy crash shape
    v, i = distributed_topk(scores, k, ctx)
    order = np.lexsort((np.arange(37), -np.asarray(scores)))[:k]
    np.testing.assert_array_equal(np.asarray(i), order)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(scores)[order])


@pytest.mark.slow
@needs_mesh
def test_mesh_sharded_index_matches_threads():
    from repro.launch.mesh import make_mesh
    from repro.models.common import MeshCtx

    mesh = make_mesh((8,), ("data",))
    ctx = MeshCtx(mesh=mesh, rules={"db_rows": ("data",)})
    corpus = _int_corpus(509, 16, seed=24)
    q = _queries(6, 16, seed=25)
    threads = ShardedIndex(n_shards=8).build(corpus).search(q, 10)
    meshed = ShardedIndex(n_shards=8, ctx=ctx,
                          workers="mesh").build(corpus).search(q, 10)
    np.testing.assert_array_equal(np.asarray(meshed.indices),
                                  np.asarray(threads.indices))
    np.testing.assert_array_equal(np.asarray(meshed.scores),
                                  np.asarray(threads.scores))
