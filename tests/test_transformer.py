"""Transformer correctness: decode==forward consistency, MoE conservation,
RoPE/GQA invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TransformerConfig
from repro.models.common import NULL_CTX
from repro.models.transformer import attention as attn
from repro.models.transformer import model as tm
from repro.models.transformer import moe as moe_lib

jax.config.update("jax_platform_name", "cpu")


def dense_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_head=16, d_ff=128, vocab_size=97,
                qkv_bias=True, qk_norm=True, remat=False, scan_layers=True,
                kv_chunk=8)
    base.update(kw)
    return TransformerConfig(**base)


def moe_cfg(**kw):
    base = dict(name="m", family="moe", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=4, d_head=16, d_ff=32, vocab_size=97, n_experts=8,
                moe_top_k=2, remat=True, scan_layers=True, kv_chunk=8,
                capacity_factor=64.0)
    base.update(kw)
    return TransformerConfig(**base)


@pytest.mark.parametrize("make_cfg", [dense_cfg, moe_cfg])
def test_decode_matches_full_forward(make_cfg):
    cfg = make_cfg()
    params = tm.init(cfg, jax.random.PRNGKey(1))
    b = 2
    toks = (jnp.arange(b * 16).reshape(b, 16) * 7) % cfg.vocab_size
    _, _, state = jax.jit(lambda p, t: tm.prefill(p, t, cfg, NULL_CTX))(
        params, toks[:, :8])
    smax = 16
    kh, dh = cfg.n_kv_heads, cfg.d_head
    padk = jnp.zeros((cfg.n_layers, b, smax, kh, dh), state.k.dtype
                     ).at[:, :, :8].set(state.k)
    padv = jnp.zeros_like(padk).at[:, :, :8].set(state.v)
    st = tm.DecodeState(k=padk, v=padv, length=state.length)
    logits = []
    for pos in range(8, 12):
        ld, _, st = jax.jit(
            lambda p, s, t: tm.decode_step(p, s, t, cfg, NULL_CTX))(
                params, st, toks[:, pos])
        logits.append(ld)
    fh, _, _ = tm.forward_hidden(params, toks[:, :13], cfg, NULL_CTX)
    w = tm._head_matrix(params, cfg, jnp.bfloat16)
    for i, pos in enumerate(range(8, 12)):
        ref = (fh[:, pos] @ w).astype(jnp.float32)
        err = float(jnp.abs(logits[i] - ref).max() / jnp.abs(ref).max())
        assert err < 0.06, (pos, err)


def test_flash_attention_matches_naive():
    b, s, h, kh, dh = 2, 24, 6, 2, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, dh)), jnp.float32)
    out = attn.flash_attention(q, k, v, causal=True, kv_chunk=8)
    # naive reference with kh-major repeat
    g = h // kh
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q, kr) * dh ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    ref = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(scores, -1), vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_q_offset_chunked_prefill():
    """Chunked prefill: attention over [q_offset, q_offset+S) vs full KV."""
    b, s, t, h, dh = 1, 8, 24, 2, 16
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, h, dh)), jnp.float32)
    out = attn.flash_attention(q, k, v, causal=True, q_offset=16, kv_chunk=8)
    scores = jnp.einsum("bshd,bthd->bhst", q, k) * dh ** -0.5
    qp = 16 + jnp.arange(s)
    mask = qp[:, None] >= jnp.arange(t)[None, :]
    scores = jnp.where(mask[None, None], scores, -1e30)
    ref = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)


def test_rope_preserves_norm_and_relativity():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 8, 2, 32)), jnp.float32)
    pos = jnp.arange(8)[None]
    y = attn.apply_rope(x, pos, theta=10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-4)
    # relative property: <R_m q, R_n k> depends only on m - n
    q = x[:, 0:1]
    k = x[:, 1:2]
    def dot_at(m, n):
        qm = attn.apply_rope(q, jnp.asarray([[m]]), 10_000.0)
        kn = attn.apply_rope(k, jnp.asarray([[n]]), 10_000.0)
        return float(jnp.sum(qm * kn))
    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), rel=1e-4)


def test_moe_dispatch_conservation():
    """Every non-dropped assignment lands in exactly one buffer slot, and
    combine reproduces the gate-weighted expert mixture exactly (dense ref)."""
    cfg = moe_cfg()
    rng = np.random.default_rng(3)
    t, d, e, k = 32, 64, cfg.n_experts, cfg.moe_top_k
    x = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(d, e)) * 0.1, jnp.float32)
    wg = jnp.asarray(rng.normal(size=(e, d, cfg.d_ff)) * 0.05, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(e, d, cfg.d_ff)) * 0.05, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(e, cfg.d_ff, d)) * 0.05, jnp.float32)

    route = moe_lib._route_and_slot(x, router, e, k, capacity=t)
    assert float(route.aux["frac_dropped"]) == 0.0
    # slot uniqueness for non-dropped entries
    slots = np.asarray(route.slot).reshape(-1)
    real = slots[slots < e * t]
    assert len(np.unique(real)) == len(real)
    # gates renormalized
    np.testing.assert_allclose(np.asarray(route.gates.sum(-1)), 1.0,
                               rtol=1e-5)

    y, aux = moe_lib.moe_block(x, router, wg, wu, wd, cfg, NULL_CTX,
                               capacity_override=t)
    # dense reference: weighted sum over selected experts
    logits = x @ router
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, k)
    gates = gates / gates.sum(-1, keepdims=True)
    h = jnp.einsum("td,edf->tef", x, wg)
    u = jnp.einsum("td,edf->tef", x, wu)
    eo = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, wd)
    ref = jnp.einsum("tkd,tk->td",
                     jnp.take_along_axis(eo, eidx[..., None], 1), gates)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)  # bf16 expert GEMMs


def test_moe_capacity_drops_counted():
    cfg = moe_cfg(capacity_factor=0.25)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    router = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)  # skewed
    route = moe_lib._route_and_slot(x, router, 8, 2, capacity=2)
    assert float(route.aux["frac_dropped"]) > 0.0


def test_padded_head_layout():
    hp, kp = attn.padded_head_layout(40, 10, 16)
    assert hp % 16 == 0 and kp >= 10 and hp // kp >= 1 and hp >= 40
    hp2, kp2 = attn.padded_head_layout(28, 4, 16)
    assert hp2 == 32 and kp2 == 4


def test_vocab_padding_masked_in_loss():
    cfg = dense_cfg(vocab_size=97)  # padded to 256
    params = tm.init(cfg, jax.random.PRNGKey(0))
    b = {"tokens": jnp.zeros((2, 16), jnp.int32),
         "targets": jnp.zeros((2, 16), jnp.int32)}
    loss, m = tm.loss_fn(params, b, cfg, NULL_CTX)
    # xent can't exceed log(V_real) much at init; padded cols are -inf
    assert float(m["xent"]) < np.log(97) + 1.0
