#!/usr/bin/env python
"""Bench regression gate: fresh ``BENCH_*.json`` vs committed baselines.

Usage::

    python scripts/check_bench.py --baseline <dir> --candidate results \\
        [--benches serve,graph] [--recall-tol 0.01] [--qps-tol 0.20]

For every ``BENCH_<name>.json`` present in BOTH directories (restricted to
``--benches`` when given, which are then REQUIRED on both sides), rows are
matched by their identity fields (``spec`` + ``space`` when present, else
``name``, else position) and gated per metric:

* recall-like metrics (any key starting with ``recall`` or
  ``seq_recall``): candidate may not drop more than ``--recall-tol``
  (absolute, default 0.01) below baseline — the paper's k-NN preservation
  guarantee is the product; it never silently erodes.
* throughput metrics (``qps``, ``seq_qps``, ``engine_qps``): candidate
  may not drop more than ``--qps-tol`` (relative, default 20%) below
  baseline — wide enough for shared-runner noise, tight enough to catch
  a real regression.

Extra candidate rows/metrics pass silently (growth is fine); a baseline
row or gated metric MISSING from the candidate fails (silent coverage
loss is a regression too).

Bench files are discovered by glob (``BENCH_*.json``) on both sides —
never by a hardcoded name list — so a new table CLI is gated the moment
its baseline is committed. The ``BENCH_summary.json`` aggregate (an index
of the per-bench files, see ``benchmarks.run.write_summary``) is skipped:
gating it would double-count every row.

Serve-specific floors: when ``BENCH_serve`` is checked, the candidate's
best ``speedup`` must be >= 3.0 regardless of what the baseline says —
micro-batching that stops paying for itself is a failure even if it
regressed "within tolerance". Additionally (ISSUE 5, now that the batched
HNSW traversal landed) every HNSW-stack row must clear ``speedup`` >= 2.5
on its own: the graph tier is the paper's flagship reduce-then-graph
deployment and is gated per-tier, not sheltered by the scan tiers'
best-of.

Graph-specific gates (ISSUE 8): when ``BENCH_graph`` is checked, every
quantized HNSW row (spec carrying an ``SQ8`` / ``PQ<m>x<b>`` stage) must
(a) report ``traversal_gather_bytes_per_hop`` at least 3x (SQ8) / 4x (PQ)
below its f32 twin's — the same spec with the quant stage stripped, IN
THE SAME candidate file — and (b) when the spec also carries a ``Rerank``
stage, keep ``recall_at_k`` within 0.01 of that twin: the codes shrink
hop traffic, the exact rerank restores ordering, and both halves of that
bargain are gated.

Sharded-specific gates: when ``BENCH_sharded`` is checked, every
``Shard<S>`` row must (a) stay within ``SHARDED_RECALL_TOL`` (absolute)
of its unsharded twin's ``recall_at_k`` IN THE SAME candidate file — the
scatter-gather merge is supposed to be lossless, so cross-spec drift is
a correctness bug, not noise; (b) keep ``latency_ms_p99`` under the
file's ``config["p99_budget_ms"]``; and (c) keep ``bytes_per_shard``
under ``config["shard_bytes_budget"]`` — the whole point of sharding a
million-vector corpus is bounding per-worker memory.

Churn-specific gates (live mutation): when ``BENCH_churn`` is checked,
every ``Mut``-spec row must (a) have actually churned —
``turnover_frac`` >= 5% of the corpus inserted AND deleted during the
soak; (b) keep ``recall_ratio_vs_static`` (mutated index vs the same
spec rebuilt fresh on the surviving corpus) at or above the file's
``config["churn_recall_ratio_floor"]`` (default 0.95) — incremental
inserts may degrade gracefully, never collapse; (c) report EXACTLY zero
``tombstone_violations`` and zero ``dropped_queries`` — a deleted row
surfacing, or a query failing during a mutation, is a correctness bug
with no tolerance; and (d) sustain ``qps_under_churn`` at or above
``config["churn_qps_floor"]`` when the file records one.

Autotune-specific gates (self-tuning serving): when ``BENCH_autotune``
is checked, every tuned row must (a) reach its recall SLO on the
held-out split — ``recall_holdout >= target_recall -
config["autotune_recall_slack"]`` (default 0.01; the tuner fit the
curve on a DISJOINT split, so this is a generalization gate); (b) when
the hand-picked defaults already met the SLO (``default_recall >=
target_recall``, i.e. the comparison is at equal recall), spend at most
``config["autotune_evals_ratio_max"]`` (default 0.70) of the defaults'
mean distance evaluations — the tuner must find a >= 30% cheaper
operating point, not just a different one; and (c) report an
``escalation_rate`` in [0, 1] — the adaptive second pass must be
measured, and escalating (almost) every query means the margin signal
is not splitting the batch. Every spec named in
``config["autotune_required_specs"]`` must appear among the tuned rows
— the flagship IVF and HNSW deployment stacks cannot silently drop out.

Exit status: 0 = all gates pass, 1 = regression (details on stdout),
2 = usage/schema error. Wired into scripts/ci.sh behind ``CI_BENCH=1``.
``--format json`` emits the same verdict machine-readably (one object
with per-bench row counts and the failure list) under the same exit
codes — the convention shared with ``scripts/lint.py``.

Baseline hygiene: the gate is one-sided (only drops fail), so commit a
CONSERVATIVE baseline — the per-metric minimum over a few runs, not one
hot outlier (a too-fast baseline turns normal variance into false
alarms). The committed ``BENCH_serve.json`` notes this in its config.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional

RECALL_PREFIXES = ("recall", "seq_recall")
# speedup is deliberately NOT tolerance-gated: it is the ratio of two
# keys that already are, and ratios double the noise; the serve floor
# below still enforces its absolute bar
QPS_KEYS = ("qps", "seq_qps", "engine_qps")
SERVE_SPEEDUP_FLOOR = 3.0
# per-tier floor for the graph stack: the batched traversal must keep
# paying for itself on ITS row, not hide behind the scan tiers' best-of
HNSW_SPEEDUP_FLOOR = 2.5
# sharded vs unsharded twin-spec recall drift: the merge is lossless by
# contract, so this is tighter than runner noise would ever need
SHARDED_RECALL_TOL = 0.01
# quantized graph tier (ISSUE 8): each quantized HNSW row must beat its
# f32 twin's traversal gather traffic by its codec's floor, and — when a
# Rerank stage restores exact ordering — match the twin's recall within
# the same 0.01 the rest of the gate uses
GRAPH_QUANT_BYTES_FLOORS = {"sq8": 3.0, "pq": 4.0}
GRAPH_QUANT_RECALL_TOL = 0.01
# churn soak (live mutation): the soak must turn over at least this
# corpus fraction for its gates to mean anything, and the mutated index
# must keep this fraction of its static twin's recall (overridable per
# file via config["churn_recall_ratio_floor"])
CHURN_TURNOVER_FLOOR = 0.05
CHURN_RECALL_RATIO_FLOOR = 0.95
# self-tuning serving: tuned points must hit their recall SLO on the
# held-out split (within the slack) and beat the hand-picked defaults'
# distance-eval spend by >= 30% at equal recall; an escalation rate at
# (or above) this ceiling means the margin signal stopped discriminating
AUTOTUNE_RECALL_SLACK = 0.01
AUTOTUNE_EVALS_RATIO_MAX = 0.70
AUTOTUNE_ESCALATION_CEIL = 0.95


def _load(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    if "rows" not in data:
        raise ValueError(f"{path}: no 'rows' key — not a write_bench file")
    return data


def _bench_files(directory: str) -> dict[str, str]:
    """Glob-discover ``BENCH_<name>.json`` files. The summary aggregate is
    excluded: it mirrors every other file's rows (gating it would report
    each regression twice) and has no row list of its own."""
    out = {}
    for fn in sorted(os.listdir(directory)):
        if (fn.startswith("BENCH_") and fn.endswith(".json")
                and fn != "BENCH_summary.json"):
            out[fn[len("BENCH_"):-len(".json")]] = os.path.join(directory, fn)
    return out


def _row_key(row: dict, position: int) -> str:
    if "spec" in row:
        return f"{row.get('space', '')}/{row['spec']}"
    if "name" in row:
        return str(row["name"])
    return f"#{position}"


def _gated_metrics(row: dict) -> dict[str, tuple[float, str]]:
    """{metric: (value, kind)} for every metric this gate watches."""
    out = {}
    for key, val in row.items():
        if not isinstance(val, (int, float)):
            continue
        if any(key.startswith(p) for p in RECALL_PREFIXES):
            out[key] = (float(val), "recall")
        elif key in QPS_KEYS:
            out[key] = (float(val), "qps")
    return out


def _unsharded_twin(spec: str) -> str:
    """Factory spec with the Shard<S> stage stripped — the row it must
    match recall against."""
    return ",".join(t for t in spec.split(",")
                    if not t.strip().lower().startswith("shard"))


def _quant_token(spec: str) -> Optional[str]:
    """'sq8' / 'pq' when the spec carries a quantizer stage, else None."""
    for t in spec.split(","):
        t = t.strip().lower()
        if t == "sq8":
            return "sq8"
        if t.startswith("pq"):
            return "pq"
    return None


def _unquant_twin(spec: str) -> str:
    """Factory spec with the SQ8/PQ<m>x<b> stage stripped — the f32 graph
    row a quantized row is gated against."""
    return ",".join(t for t in spec.split(",")
                    if _quant_token(t) is None)


def check_bench(name: str, baseline: dict, candidate: dict,
                recall_tol: float, qps_tol: float) -> list[str]:
    """Returns human-readable failure strings (empty = pass)."""
    failures = []
    cand_rows = {_row_key(r, i): r
                 for i, r in enumerate(candidate["rows"])}
    for i, base_row in enumerate(baseline["rows"]):
        key = _row_key(base_row, i)
        cand_row = cand_rows.get(key)
        if cand_row is None:
            failures.append(f"{name}: row {key!r} missing from candidate")
            continue
        for metric, (base_val, kind) in _gated_metrics(base_row).items():
            if metric not in cand_row:
                failures.append(
                    f"{name}/{key}: metric {metric!r} missing from candidate")
                continue
            cand_val = float(cand_row[metric])
            if kind == "recall":
                floor, desc = base_val - recall_tol, f"-{recall_tol} abs"
            else:
                floor, desc = base_val * (1 - qps_tol), f"-{qps_tol:.0%} rel"
            if cand_val < floor:
                failures.append(
                    f"{name}/{key}: {metric} regressed "
                    f"{base_val:g} -> {cand_val:g} "
                    f"(floor {floor:g}, tolerance {desc})")
    if name == "serve":
        speedups = [float(r["speedup"]) for r in candidate["rows"]
                    if "speedup" in r]
        if not speedups or max(speedups) < SERVE_SPEEDUP_FLOOR:
            failures.append(
                f"serve: best micro-batching speedup "
                f"{max(speedups) if speedups else 0:.2f}x is below the "
                f"{SERVE_SPEEDUP_FLOOR}x acceptance floor")
        hnsw_rows = [r for r in candidate["rows"]
                     if "HNSW" in str(r.get("spec", "")) and "speedup" in r]
        if not hnsw_rows:
            failures.append(
                "serve: no HNSW-stack row with a speedup — the per-tier "
                f"{HNSW_SPEEDUP_FLOOR}x gate has nothing to read")
        for r in hnsw_rows:
            if float(r["speedup"]) < HNSW_SPEEDUP_FLOOR:
                failures.append(
                    f"serve/{r['spec']}: batched-traversal speedup "
                    f"{float(r['speedup']):.2f}x is below the per-tier "
                    f"{HNSW_SPEEDUP_FLOOR}x floor")
    if name == "graph":
        by_spec = {str(r.get("spec", "")): r for r in candidate["rows"]}
        quant_rows = [r for r in candidate["rows"]
                      if "HNSW" in str(r.get("spec", ""))
                      and _quant_token(str(r.get("spec", "")))]
        if not quant_rows:
            failures.append(
                "graph: no quantized HNSW row — the gather-bytes and "
                "rerank-recall gates have nothing to read")
        for r in quant_rows:
            spec = str(r["spec"])
            codec = _quant_token(spec)
            floor = GRAPH_QUANT_BYTES_FLOORS[codec]
            twin = by_spec.get(_unquant_twin(spec))
            if twin is None:
                failures.append(
                    f"graph/{spec}: f32 twin row {_unquant_twin(spec)!r} "
                    "missing — the quantized gates have nothing to diff "
                    "against")
                continue
            mine = float(r.get("traversal_gather_bytes_per_hop", 0.0))
            theirs = float(twin.get("traversal_gather_bytes_per_hop", 0.0))
            if mine <= 0 or theirs <= 0:
                failures.append(
                    f"graph/{spec}: traversal_gather_bytes_per_hop missing "
                    "on the quantized row or its f32 twin")
            elif theirs / mine < floor:
                failures.append(
                    f"graph/{spec}: gather traffic only "
                    f"{theirs / mine:.2f}x below the f32 twin "
                    f"({theirs:g} -> {mine:g} bytes/hop); the {codec} "
                    f"payload must save >= {floor}x")
            if "rerank" in spec.lower():
                rec, twin_rec = (float(r.get("recall_at_k", 0.0)),
                                 float(twin.get("recall_at_k", 0.0)))
                if rec < twin_rec - GRAPH_QUANT_RECALL_TOL:
                    failures.append(
                        f"graph/{spec}: post-rerank recall_at_k {rec:g} "
                        f"fell more than {GRAPH_QUANT_RECALL_TOL} below "
                        f"the f32 twin's {twin_rec:g} — the codec noise "
                        "is leaking past the exact rerank")
    if name == "churn":
        cfg = candidate.get("config", {})
        ratio_floor = float(cfg.get("churn_recall_ratio_floor",
                                    CHURN_RECALL_RATIO_FLOOR))
        qps_floor = cfg.get("churn_qps_floor")
        mut_rows = [r for r in candidate["rows"]
                    if str(r.get("spec", "")).startswith("Mut")]
        if not mut_rows:
            failures.append(
                "churn: no Mut-spec row — the live-mutation gates have "
                "nothing to read")
        for r in mut_rows:
            spec = str(r["spec"])
            turn = float(r.get("turnover_frac", 0.0))
            if turn < CHURN_TURNOVER_FLOOR:
                failures.append(
                    f"churn/{spec}: turnover_frac {turn:g} is below the "
                    f"{CHURN_TURNOVER_FLOOR:.0%} soak floor — the churn "
                    "gates measured a nearly-static index")
            ratio = float(r.get("recall_ratio_vs_static", 0.0))
            if ratio < ratio_floor:
                failures.append(
                    f"churn/{spec}: recall_ratio_vs_static {ratio:g} is "
                    f"below the {ratio_floor:g} floor — incremental "
                    "mutation is collapsing recall vs a fresh build")
            if int(r.get("tombstone_violations", 1)) != 0:
                failures.append(
                    f"churn/{spec}: {int(r.get('tombstone_violations', 1))}"
                    " tombstone violation(s) — a deleted row surfaced in "
                    "an answer; the db_mask contract has no tolerance")
            if int(r.get("dropped_queries", 1)) != 0:
                failures.append(
                    f"churn/{spec}: {int(r.get('dropped_queries', 1))} "
                    "dropped quer(ies) during mutation — engine.mutate "
                    "must serialize, never shed load")
            if qps_floor is not None and float(
                    r.get("qps_under_churn", 0.0)) < float(qps_floor):
                failures.append(
                    f"churn/{spec}: qps_under_churn "
                    f"{float(r.get('qps_under_churn', 0.0)):g} is below "
                    f"the {float(qps_floor):g} sustained-QPS floor")
    if name == "autotune":
        cfg = candidate.get("config", {})
        slack = float(cfg.get("autotune_recall_slack",
                              AUTOTUNE_RECALL_SLACK))
        ratio_max = float(cfg.get("autotune_evals_ratio_max",
                                  AUTOTUNE_EVALS_RATIO_MAX))
        tuned_rows = [r for r in candidate["rows"]
                      if "target_recall" in r]
        if not tuned_rows:
            failures.append(
                "autotune: no tuned row with a target_recall — the "
                "SLO and evals-saving gates have nothing to read")
        have_specs = {str(r.get("spec", "")) for r in tuned_rows}
        for spec in cfg.get("autotune_required_specs", []):
            if spec not in have_specs:
                failures.append(
                    f"autotune: required stack {spec!r} missing from "
                    "the tuned rows — the flagship deployment stacks "
                    "must stay covered")
        for r in tuned_rows:
            key = f"{r.get('spec', '?')}@slo{r['target_recall']}"
            target = float(r["target_recall"])
            rec = float(r.get("recall_holdout", 0.0))
            if rec < target - slack:
                failures.append(
                    f"autotune/{key}: recall_holdout {rec:g} missed the "
                    f"{target:g} SLO by more than the {slack:g} slack — "
                    "the tuned operating point does not generalize off "
                    "the tune split")
            if "escalation_rate" not in r:
                failures.append(
                    f"autotune/{key}: escalation_rate missing — the "
                    "adaptive second pass must be measured")
            else:
                esc = float(r["escalation_rate"])
                if not 0.0 <= esc <= 1.0:
                    failures.append(
                        f"autotune/{key}: escalation_rate {esc:g} is "
                        "outside [0, 1]")
                elif esc >= AUTOTUNE_ESCALATION_CEIL:
                    failures.append(
                        f"autotune/{key}: escalation_rate {esc:g} — "
                        "(almost) every query re-ran the expensive "
                        "pass; the margin signal is not splitting the "
                        "batch and the cheap rung is pure overhead")
            if float(r.get("default_recall", 0.0)) >= target:
                # equal-recall comparison: the defaults met the SLO too,
                # so the tuned point must win on cost
                ratio = float(r.get("evals_ratio", float("inf")))
                if ratio > ratio_max:
                    failures.append(
                        f"autotune/{key}: evals_ratio {ratio:g} exceeds "
                        f"{ratio_max:g} — the tuned point must spend "
                        f"<= {ratio_max:.0%} of the hand-picked "
                        "defaults' distance evals at equal recall")
    if name == "sharded":
        cfg = candidate.get("config", {})
        by_spec = {str(r.get("spec", "")): r for r in candidate["rows"]}
        shard_rows = [r for r in candidate["rows"]
                      if "shard" in str(r.get("spec", "")).lower()]
        if not shard_rows:
            failures.append(
                "sharded: no Shard<S> row — the lossless-merge and "
                "budget gates have nothing to read")
        for r in shard_rows:
            spec = str(r["spec"])
            twin = by_spec.get(_unsharded_twin(spec))
            if twin is None or "recall_at_k" not in twin:
                failures.append(
                    f"sharded/{spec}: unsharded twin row "
                    f"{_unsharded_twin(spec)!r} missing — the "
                    "lossless-merge gate has nothing to diff against")
            elif float(r.get("recall_at_k", 0.0)) \
                    < float(twin["recall_at_k"]) - SHARDED_RECALL_TOL:
                failures.append(
                    f"sharded/{spec}: recall_at_k "
                    f"{float(r.get('recall_at_k', 0.0)):g} fell more than "
                    f"{SHARDED_RECALL_TOL} below its unsharded twin's "
                    f"{float(twin['recall_at_k']):g} — the scatter-gather "
                    "merge is dropping candidates")
            p99_budget = cfg.get("p99_budget_ms")
            if p99_budget is not None and float(
                    r.get("latency_ms_p99", float("inf"))) > float(p99_budget):
                failures.append(
                    f"sharded/{spec}: latency_ms_p99 "
                    f"{float(r.get('latency_ms_p99', float('inf'))):g} "
                    f"exceeds the {float(p99_budget):g} ms budget")
            byte_budget = cfg.get("shard_bytes_budget")
            if byte_budget is not None and float(
                    r.get("bytes_per_shard", float("inf"))) > float(byte_budget):
                failures.append(
                    f"sharded/{spec}: bytes_per_shard "
                    f"{float(r.get('bytes_per_shard', float('inf'))):g} "
                    f"exceeds the {float(byte_budget):g}-byte budget")
    return failures


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Gate fresh BENCH_*.json against committed baselines")
    ap.add_argument("--baseline", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--candidate", default="results",
                    help="directory holding the fresh BENCH_*.json")
    ap.add_argument("--benches", default=None,
                    help="comma-separated bench names to check (default: "
                         "every bench present in both directories)")
    ap.add_argument("--recall-tol", type=float, default=0.01,
                    help="max absolute recall drop (default 0.01)")
    ap.add_argument("--qps-tol", type=float, default=0.20,
                    help="max relative QPS drop (default 0.20)")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="output format (json: one object with per-bench "
                         "row counts and the failure list)")
    args = ap.parse_args(argv)

    try:
        base_files = _bench_files(args.baseline)
        cand_files = _bench_files(args.candidate)
    except FileNotFoundError as e:
        print(f"FATAL: {e}")
        return 2

    if args.benches:
        names = [b.strip() for b in args.benches.split(",") if b.strip()]
        missing = [b for b in names
                   if b not in base_files or b not in cand_files]
        if missing:
            print(f"FATAL: requested benches missing a side: {missing} "
                  f"(baseline has {sorted(base_files)}, "
                  f"candidate has {sorted(cand_files)})")
            return 2
    else:
        names = sorted(set(base_files) & set(cand_files))
        if not names:
            print(f"FATAL: no common BENCH_*.json between {args.baseline} "
                  f"and {args.candidate}")
            return 2

    all_failures = []
    report = []
    for name in names:
        try:
            baseline = _load(base_files[name])
            candidate = _load(cand_files[name])
        except (ValueError, json.JSONDecodeError) as e:
            # schema errors stay plain text in both formats, like argparse
            # usage errors: exit 2 means "the verdict never happened"
            print(f"FATAL: {e}")
            return 2
        failures = check_bench(name, baseline, candidate,
                               args.recall_tol, args.qps_tol)
        report.append({"name": name,
                       "baseline_rows": len(baseline["rows"]),
                       "candidate_rows": len(candidate["rows"]),
                       "failures": failures})
        all_failures.extend(failures)

    if args.format == "json":
        print(json.dumps({"benches": report, "count": len(all_failures),
                          "failures": all_failures}, indent=1))
        return 1 if all_failures else 0

    for entry in report:
        status = "FAIL" if entry["failures"] else "ok"
        print(f"[{status}] {entry['name']}: {entry['baseline_rows']} "
              f"baseline rows vs {entry['candidate_rows']} candidate rows")
        for f in entry["failures"]:
            print(f"  {f}")
    if all_failures:
        print(f"\nREGRESSION: {len(all_failures)} gate(s) failed")
        return 1
    print(f"\nall bench gates passed ({', '.join(names)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
