#!/usr/bin/env bash
# CI entry point.
#
# Default = fast split: collection sanity check, then everything not marked
# `slow` (the 20k-point acceptance runs). Tier-1 verify (see ROADMAP.md)
# remains the FULL suite: run with CI_MARKERS="" or call pytest directly.
#
#   scripts/ci.sh                 # fast: -m "not slow" (graph/quant unit +
#                                 #   property tests included)
#   CI_MARKERS="slow" scripts/ci.sh  # slow split only: the 20k acceptance
#                                 #   runs (api, quantized, graph)
#   CI_MARKERS="" scripts/ci.sh   # full suite (tier-1 equivalent)
#   scripts/ci.sh -k quant        # extra pytest args pass through
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Import errors must fail loudly before any test runs — a module that
# doesn't collect is a broken build, not 0 skipped tests. pytest writes
# collection errors to stdout, so capture and replay them on failure
# (quiet on success).
if ! collect_out=$(python -m pytest --collect-only -q 2>&1); then
    echo "$collect_out"
    echo "FATAL: test collection failed (import error?)" >&2
    exit 1
fi

# The graph-invariant suite guards the HNSW tier's correctness contract;
# a rename/deselection that silently drops it must fail the gate.
if ! grep -q "test_graph" <<<"$collect_out"; then
    echo "FATAL: tests/test_graph.py not collected" >&2
    exit 1
fi

MARKERS="${CI_MARKERS-not slow}"
if [ -n "$MARKERS" ]; then
    exec python -m pytest -x -q -m "$MARKERS" "$@"
fi
exec python -m pytest -x -q "$@"
