#!/usr/bin/env bash
# CI entry point.
#
# Default = fast split: collection sanity check, then everything not marked
# `slow` (the 20k-point acceptance runs). Tier-1 verify (see ROADMAP.md)
# remains the FULL suite: run with CI_MARKERS="" or call pytest directly.
#
#   scripts/ci.sh                 # fast: -m "not slow" (graph/quant/serve
#                                 #   unit + property tests included)
#   CI_MARKERS="slow" scripts/ci.sh  # slow split only: the 20k acceptance
#                                 #   runs (api, quantized, graph)
#   CI_MARKERS="" scripts/ci.sh   # full suite (tier-1 equivalent)
#   CI_BENCH=1 scripts/ci.sh      # + bench regression gate: rerun the
#                                 #   serving bench, compare against the
#                                 #   committed results/BENCH_*.json via
#                                 #   scripts/check_bench.py
#   CI_CHURN=1 scripts/ci.sh      # + churn soak: live mutation under
#                                 #   load (benchmarks/table7_churn.py),
#                                 #   gated by check_bench's churn block
#                                 #   (tombstones, drops, recall ratio)
#   CI_AUTOTUNE=1 scripts/ci.sh   # + self-tuning gate: re-sweep the
#                                 #   operating curves and verify tuned
#                                 #   points hit their recall SLOs with
#                                 #   >= 30% fewer distance evals than
#                                 #   the hand-picked defaults
#                                 #   (benchmarks/table8_autotune.py)
#   CI_SKIP_TESTS=1 CI_BENCH=1 scripts/ci.sh   # bench gate only
#   CI_SKIP_LINT=1 scripts/ci.sh  # skip the static-analysis gate
#   scripts/ci.sh -k quant        # extra pytest args pass through
#
# Every invocation (unless CI_SKIP_LINT=1) starts with the static-analysis
# gate: scripts/lint.py runs the repro.analysis checkers (jit-purity,
# kernel-contract, fingerprint) over src/ and fails the build on any
# finding.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Static analysis first: pure-AST (no jax import), so it verdicts in
# ~a second — an impure jit function, broken kernel triple, or unhashed
# index attribute fails CI before a single test runs. CI_SKIP_LINT=1
# opts out (e.g. the bench-only invocation on a box without the repo's
# scripts on PATH).
if [ "${CI_SKIP_LINT:-0}" != "1" ]; then
    python scripts/lint.py
fi

# Import errors must fail loudly before any test runs — a module that
# doesn't collect is a broken build, not 0 skipped tests. pytest writes
# collection errors to stdout, so capture and replay them on failure
# (quiet on success).
if ! collect_out=$(python -m pytest --collect-only -q 2>&1); then
    echo "$collect_out"
    echo "FATAL: test collection failed (import error?)" >&2
    exit 1
fi

# Every suite that guards a subsystem contract must stay collected: a
# rename/deselection that silently drops one is a coverage regression,
# not a green build.
REQUIRED_SUITES=(api properties kernels quantized graph serve sharded
                 mutation autotune)
for suite in "${REQUIRED_SUITES[@]}"; do
    if ! grep -q "test_${suite}" <<<"$collect_out"; then
        echo "FATAL: tests/test_${suite}.py not collected" >&2
        exit 1
    fi
done

# Every Pallas kernel triple must keep its parity cases collected (the
# shared harness parametrizes test ids by kernel name) — dropping one
# silently un-gates that kernel's pad/edge paths.
REQUIRED_KERNELS=(l2_topk rae_encode flash_decode embedding_bag pq_adc
                  graph_beam graph_beam_q topk_merge)
for kern in "${REQUIRED_KERNELS[@]}"; do
    if ! grep -q "${kern}" <<<"$collect_out"; then
        echo "FATAL: kernel-parity cases for ${kern} not collected" >&2
        exit 1
    fi
done

if [ "${CI_SKIP_TESTS:-0}" != "1" ]; then
    MARKERS="${CI_MARKERS-not slow}"
    # The slow (nightly) split exercises the device-parallel sharded path:
    # force 8 host devices so mesh tests run on CPU-only runners. Exact
    # match on purpose — the default "not slow" must NOT trip this.
    if [ "${CI_MARKERS-}" = "slow" ]; then
        export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"
    fi
    if [ -n "$MARKERS" ]; then
        python -m pytest -x -q -m "$MARKERS" "$@"
    else
        python -m pytest -x -q "$@"
    fi
fi

# Bench regression gate: snapshot the committed baselines, rerun the
# selected benches (CPU-budget), and fail on recall/QPS regression.
# check_bench discovers BENCH_*.json by glob on both sides — benches not
# rerun here compare equal to their own snapshot, so no hardcoded list.
# CI_BENCH reruns the serving bench; CI_CHURN additionally soaks the
# mutable tiers under concurrent insert/delete/query load (its gates —
# zero tombstone violations, zero dropped queries, recall ratio vs the
# static twin — are correctness, not perf, so they hold on any box).
# The machine-readable verdict lands in results/check_bench_report.json
# for CI to upload alongside the fresh BENCH_*.json files.
if [ "${CI_BENCH:-0}" = "1" ] || [ "${CI_CHURN:-0}" = "1" ] \
        || [ "${CI_AUTOTUNE:-0}" = "1" ]; then
    baseline_dir=$(mktemp -d)
    trap 'rm -rf "$baseline_dir"' EXIT
    cp results/BENCH_*.json "$baseline_dir"/
    if [ "${CI_BENCH:-0}" = "1" ]; then
        python -m benchmarks.table5_serve --quick
    fi
    if [ "${CI_CHURN:-0}" = "1" ]; then
        python -m benchmarks.table7_churn --quick
    fi
    if [ "${CI_AUTOTUNE:-0}" = "1" ]; then
        python -m benchmarks.table8_autotune --quick
    fi
    python scripts/check_bench.py --baseline "$baseline_dir" \
        --candidate results --format json \
        | tee results/check_bench_report.json
fi
