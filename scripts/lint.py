#!/usr/bin/env python
"""Static-analysis gate: run the repro.analysis checkers over src/.

Usage:
    python scripts/lint.py                     # all checkers, text output
    python scripts/lint.py --format json       # machine-readable
    python scripts/lint.py --checker fingerprint --checker jit-purity

Exit codes (same convention as scripts/check_bench.py):
    0  clean
    1  findings
    2  usage error

Pure AST analysis — never imports repo code, so it runs in ~a second
with no jax startup and is safe to gate CI's fast job on.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.analysis import CHECKERS, run_checks  # noqa: E402


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="lint.py",
        description="jax/Pallas-aware static analysis over src/repro")
    parser.add_argument("--format", choices=("text", "json"),
                        default="text", help="output format")
    parser.add_argument("--checker", action="append", choices=CHECKERS,
                        metavar="NAME", dest="checkers",
                        help=f"run only NAME (repeatable); "
                             f"one of: {', '.join(CHECKERS)}")
    parser.add_argument("--root", default=REPO, metavar="DIR",
                        help="repo root to analyze (expects DIR/src/repro; "
                             "default: this repo)")
    args = parser.parse_args(argv)

    root = os.path.abspath(args.root)
    src = os.path.join(root, "src")
    if not os.path.isdir(src):
        parser.error(f"no src/ directory under {root}")
    findings = run_checks(src, repo_root=root, checkers=args.checkers)
    ran = list(args.checkers) if args.checkers else list(CHECKERS)
    if args.format == "json":
        print(json.dumps({"checkers": ran, "count": len(findings),
                          "findings": [f.to_dict() for f in findings]},
                         indent=1))
    else:
        for f in findings:
            print(f.format())
        label = ", ".join(ran)
        if findings:
            print(f"lint: {len(findings)} finding(s) [{label}]")
        else:
            print(f"lint: clean [{label}]")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
