"""Table 8 (beyond paper): self-tuning serving — recall-SLO autotuning
plus per-query adaptive escalation.

For each flagship deployment stack (``RAE<m>,IVF<c>,Rerank4`` and
``RAE<m>,HNSW<M>,Rerank4``) the bench:

1. measures the **hand-picked defaults** (the constructor knobs every
   prior table used: IVF's nprobe = n_cells/16, HNSW's ef_search, the
   k * rerank_factor * oversample stage-1 budget) on a held-out query
   split — ``default_recall`` / ``default_distance_evals``;
2. runs the offline autotuner (``repro.tune.sweep``) over the
   :data:`~repro.api.KNOB_LADDER` on a DISJOINT tune split, persisting
   the fingerprint-keyed Pareto ``OperatingCurve`` under ``results/``;
3. serves the held-out split through a :class:`SearchEngine` pinned to
   ``target_recall`` in {0.95, 0.99} with the curve plus an
   :class:`EscalationPolicy` — the engine picks the cheapest rung
   meeting the SLO and re-runs only margin-unstable rows one rung up —
   and reports ``recall_holdout``, mean ``tuned_distance_evals`` (pass-1
   + amortized pass-2), ``evals_ratio`` vs the defaults, and the
   ``escalation_rate``.

``scripts/check_bench.py``'s autotune block gates the result: every
tuned row must hit its SLO on the held-out split (within
``autotune_recall_slack``), and — whenever the hand-picked defaults
already met the SLO, i.e. at EQUAL recall — the tuned operating point
must spend at most ``autotune_evals_ratio_max`` (70%) of the defaults'
distance evaluations. Both flagship stacks are required rows.

Writes ``results/BENCH_autotune.json`` (schema:
``benchmarks.run.write_bench``).

CPU-budget default: ``python -m benchmarks.table8_autotune --quick``
finishes in a few minutes at n=8192.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import api
from repro.core.metrics import recall_at_k
from repro.data import synthetic
from repro.serve import SearchEngine
from repro.tune import (EscalationPolicy, curve_path, save_curve, sweep)

from .run import write_bench

# gate knobs recorded in the config block for scripts/check_bench.py:
# tuned rows must reach target_recall - SLACK on held-out queries, and
# cost at most RATIO_MAX of the hand-picked defaults at equal recall
AUTOTUNE_RECALL_SLACK = 0.01
AUTOTUNE_EVALS_RATIO_MAX = 0.70


def _serve_tuned(index: "api.VectorIndex", curve, target: float,
                 hold_q: np.ndarray, hold_gt: np.ndarray, k: int,
                 max_batch: int, escalation: EscalationPolicy
                 ) -> dict:
    """Serve the holdout split through an SLO-pinned engine; returns
    recall / mean evals / escalation rate / wall-clock QPS."""
    engine = SearchEngine(index, max_batch=max_batch, cache_size=0,
                          target_recall=target, curve=curve,
                          escalation=escalation)
    engine.warmup(ks=(k,))
    nq = hold_q.shape[0]
    got = np.zeros((nq, k), np.int64)
    evals = 0.0
    t0 = time.perf_counter()
    for i in range(0, nq, max_batch):
        res = engine.search(hold_q[i:i + max_batch], k)
        got[i:i + max_batch] = np.asarray(res.indices)
        evals += res.stats["distance_evals"] * (res.indices.shape[0])
    wall = time.perf_counter() - t0
    snap = engine.metrics.snapshot()
    return {"recall": recall_at_k(got, hold_gt),
            "evals": evals / nq,
            "escalation_rate": snap.get("escalation_rate", 0.0),
            "qps": nq / wall,
            "params": engine.stats()["operating_point"]["params"]}


def run(n: int = 20000, dim: int = 128, m_reduce: int = 64,
        n_cells: int = 256, hnsw_m: int = 32, k: int = 10,
        rae_steps: int = 600, n_tune: int = 256, n_holdout: int = 512,
        targets: tuple = (0.95, 0.99), delta: int = 3,
        threshold: float = 0.02, recall_slack: float = 0.01,
        max_batch: int = 32, seed: int = 0,
        quick: bool = False) -> list[dict]:
    if quick:
        n, rae_steps = 8192, 300
    corpus = synthetic.embedding_corpus(n, dim, n_clusters=64,
                                        intrinsic=32, seed=seed)
    rng = np.random.default_rng(seed + 1)
    # disjoint tune/holdout splits: the curve is FIT on one and the SLO
    # is VERIFIED on the other, so the gate reads generalization, not fit
    pick = rng.choice(n, n_tune + n_holdout, replace=False)
    qs = corpus[pick] + 0.05 * rng.standard_normal(
        (n_tune + n_holdout, dim)).astype(np.float32)
    tune_q, hold_q = qs[:n_tune], qs[n_tune:]

    exact = api.FlatIndex().build(corpus)
    tune_gt = np.asarray(exact.search(tune_q, k).indices)
    hold_gt = np.asarray(exact.search(hold_q, k).indices)

    print(f"fitting RAE {dim}->{m_reduce} ({rae_steps} steps) once, "
          f"shared across both stacks")
    reducer = api.make_reducer("rae", m_reduce, steps=rae_steps, seed=seed)
    reducer.fit(corpus)

    stacks = [
        (f"RAE{m_reduce},IVF{n_cells},Rerank4",
         lambda: api.index_factory(f"IVF{n_cells}")),
        (f"RAE{m_reduce},HNSW{hnsw_m},Rerank4",
         lambda: api.index_factory(f"HNSW{hnsw_m}",
                                   index_kw={"batched": True})),
    ]
    escalation = EscalationPolicy(delta=delta, threshold=threshold,
                                  recall_slack=recall_slack)
    rows = []
    for spec, make_base in stacks:
        index = api.TwoStageIndex(reducer, make_base(), rerank_factor=4)
        t0 = time.perf_counter()
        index.build(corpus)
        build_s = time.perf_counter() - t0

        # hand-picked defaults on the holdout split (warm first)
        index.search(hold_q[:max_batch], k)
        d_res = index.search(hold_q, k)
        d_recall = recall_at_k(np.asarray(d_res.indices), hold_gt)
        d_evals = d_res.stats["distance_evals"]

        curve = sweep(index, tune_q, tune_gt, k)
        cpath = curve_path("results", curve.fingerprint, k)
        save_curve(curve, cpath)
        print(f"{spec}: defaults recall@{k}={d_recall:.4f} "
              f"evals/q={d_evals:.0f}; swept {len(curve.points)} Pareto "
              f"points -> {cpath}")

        for target in targets:
            t = _serve_tuned(index, curve, target, hold_q, hold_gt, k,
                             max_batch, escalation)
            row = {"spec": spec, "space": f"slo{target}",
                   "target_recall": target, "k": k, "n": n,
                   "recall_holdout": round(t["recall"], 4),
                   "default_recall": round(d_recall, 4),
                   "tuned_distance_evals": round(t["evals"], 1),
                   "default_distance_evals": round(d_evals, 1),
                   "evals_ratio": round(t["evals"] / max(d_evals, 1e-9),
                                        4),
                   "escalation_rate": round(t["escalation_rate"], 4),
                   "tuned_qps": round(t["qps"], 1),
                   "tuned_params": t["params"],
                   "build_s": round(build_s, 2)}
            rows.append(row)
            print(f"  slo={target}: recall={row['recall_holdout']:.4f} "
                  f"evals/q={row['tuned_distance_evals']:.0f} "
                  f"(defaults {row['default_distance_evals']:.0f}, "
                  f"ratio {row['evals_ratio']:.2f}) "
                  f"escalated={row['escalation_rate']:.1%} "
                  f"params={row['tuned_params']}")
    write_bench("autotune", rows,
                config={"n": n, "dim": dim, "m_reduce": m_reduce,
                        "n_cells": n_cells, "hnsw_m": hnsw_m, "k": k,
                        "rae_steps": rae_steps, "n_tune": n_tune,
                        "n_holdout": n_holdout,
                        "targets": list(targets), "delta": delta,
                        "threshold": threshold,
                        "recall_slack": recall_slack,
                        "max_batch": max_batch,
                        "autotune_recall_slack": AUTOTUNE_RECALL_SLACK,
                        "autotune_evals_ratio_max":
                            AUTOTUNE_EVALS_RATIO_MAX,
                        "autotune_required_specs":
                            [s for s, _ in stacks],
                        "seed": seed, "quick": quick})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--m-reduce", type=int, default=64)
    ap.add_argument("--n-cells", type=int, default=256)
    ap.add_argument("--hnsw-m", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--rae-steps", type=int, default=600)
    ap.add_argument("--tune", type=int, default=256,
                    help="queries the curve is fit on")
    ap.add_argument("--holdout", type=int, default=512,
                    help="disjoint queries the SLO is verified on")
    ap.add_argument("--delta", type=int, default=3)
    ap.add_argument("--threshold", type=float, default=0.02,
                    help="normalized-margin escalation cut")
    ap.add_argument("--recall-slack", type=float, default=0.01,
                    help="recall deficit escalation is trusted to close")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="CPU-budget run: n=8192, 300 RAE steps")
    a = ap.parse_args(argv)
    run(n=a.n, dim=a.dim, m_reduce=a.m_reduce, n_cells=a.n_cells,
        hnsw_m=a.hnsw_m, k=a.k, rae_steps=a.rae_steps, n_tune=a.tune,
        n_holdout=a.holdout, delta=a.delta, threshold=a.threshold,
        recall_slack=a.recall_slack, max_batch=a.max_batch, seed=a.seed,
        quick=a.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
