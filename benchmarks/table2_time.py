"""Table 2 reproduction: average train + inference wall time per method at
target dim = 50% of the original dim (paper protocol), averaged over the
four dataset analogues. MDS capped at its max_train (paper capped at 5000)."""
from __future__ import annotations

import time

import numpy as np

from .table1_knn import GRID, run_method


def run(n: int = 4096, rae_steps: int = 3000, methods=("pca", "rae", "umap",
                                                       "isomap", "mds")):
    from repro.data import synthetic

    agg = {m: {"train": [], "infer": []} for m in methods}
    for ds_name, (dim, _) in GRID.items():
        data = synthetic.paper_dataset(ds_name, n)
        tr, te = synthetic.train_test_split(data)
        m_target = dim // 2
        for method in methods:
            _, t_train, t_infer = run_method(method, tr, te, m_target,
                                             rae_steps, 1e-2)
            agg[method]["train"].append(t_train)
            agg[method]["infer"].append(t_infer)
            print(f"  {ds_name} {method:7s} train={t_train:8.2f}s "
                  f"infer={t_infer:.4f}s")
    rows = [dict(method=m, train_s=round(float(np.mean(v["train"])), 2),
                 infer_s=round(float(np.mean(v["infer"])), 4))
            for m, v in agg.items()]
    return rows


def main():
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--rae-steps", type=int, default=3000)
    ap.add_argument("--out", default="results/table2.json")
    args = ap.parse_args()
    rows = run(n=args.n, rae_steps=args.rae_steps)
    os.makedirs("results", exist_ok=True)
    json.dump(rows, open(args.out, "w"), indent=1)
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
