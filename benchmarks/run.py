"""Benchmark harness: one function per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows. Full-scale variants of the
paper tables live in table1_knn.py / table2_time.py / fig1_weight_decay.py
/ table3_quant.py / table4_graph.py / table5_serve.py (separate CLIs);
this harness runs CPU-budget versions of each so ``python -m
benchmarks.run`` finishes in minutes and covers every artifact.

Machine-readable output: every run also writes ``results/BENCH_run.json``
(and each table CLI writes its own ``results/BENCH_<name>.json`` via
:func:`write_bench`) with a stable schema — ``{bench, schema_version,
created_unix, config, rows}`` — so the perf trajectory (recall, QPS,
bytes/vector, wall-clock) is diffable across PRs. Every ``write_bench``
also refreshes ``results/BENCH_summary.json``, the cross-bench aggregate
(:func:`write_summary`) that merges all per-bench files under one schema
version, so one file answers "what did every bench last measure".
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

#: Bump when the shape of BENCH_*.json / BENCH_summary.json changes;
#: scripts/check_bench.py and any cross-PR trajectory tooling key on it.
BENCH_SCHEMA_VERSION = 1

ROWS: list[dict] = []


def write_bench(name: str, rows: list[dict], config: dict | None = None,
                results_dir: str = "results") -> str:
    """Write ``results/BENCH_<name>.json``: the one machine-readable schema
    every benchmark emits. ``rows`` are flat dicts (recall/qps/bytes
    keys where applicable); ``config`` records the knobs that produced
    them. Also re-aggregates ``BENCH_summary.json`` so the summary can
    never go stale relative to the file that just changed."""
    os.makedirs(results_dir, exist_ok=True)
    path = os.path.join(results_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump({"bench": name, "schema_version": BENCH_SCHEMA_VERSION,
                   "created_unix": time.time(),
                   "config": config or {}, "rows": rows}, f, indent=1)
    print(f"# wrote {path} ({len(rows)} rows)")
    write_summary(results_dir)
    return path


def write_summary(results_dir: str = "results") -> str:
    """Merge every ``results/BENCH_*.json`` into ``BENCH_summary.json``:
    ``{bench: {schema_version, created_unix, config, rows}}`` keyed by
    bench name, discovered by glob (no hardcoded bench list — a new table
    CLI shows up here for free). Files without a ``rows`` key (foreign or
    pre-schema artifacts) are skipped rather than fatal."""
    benches: dict[str, dict] = {}
    for fn in sorted(os.listdir(results_dir)):
        if (not fn.startswith("BENCH_") or not fn.endswith(".json")
                or fn == "BENCH_summary.json"):
            continue
        try:
            with open(os.path.join(results_dir, fn)) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if "rows" not in data:
            continue
        name = data.get("bench", fn[len("BENCH_"):-len(".json")])
        benches[name] = {
            "schema_version": data.get("schema_version", 0),
            "created_unix": data.get("created_unix"),
            "config": data.get("config", {}),
            "rows": data["rows"],
        }
    path = os.path.join(results_dir, "BENCH_summary.json")
    with open(path, "w") as f:
        json.dump({"bench": "summary",
                   "schema_version": BENCH_SCHEMA_VERSION,
                   "created_unix": time.time(),
                   "benches": benches}, f, indent=1)
    return path


def emit(name: str, us: float, derived: str = "", **extra):
    """One benchmark data point. ``extra`` keys (recall, qps,
    bytes_per_vector, ...) land verbatim in BENCH_run.json."""
    row = {"name": name, "us_per_call": us, "derived": derived}
    if us > 0:
        row["qps"] = 1e6 / us
    row.update(extra)
    ROWS.append(row)
    print(f"{name},{us:.2f},{derived}", flush=True)


def _timeit(fn, *args, warmup=2, iters=5):
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kernels():
    import jax

    from repro.kernels import l2_topk, rae_encode

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (256, 768))
    db = jax.random.normal(jax.random.PRNGKey(1), (65536, 768))

    fused = jax.jit(lambda a, b: l2_topk(a, b, 10, impl="ref"))
    us = _timeit(fused, q, db)
    emit("l2_topk_ref_256x65536x768", us,
         f"{2*256*65536*768/us*1e6/1e12:.2f}TFLOPs_eff")

    w = jax.random.normal(jax.random.PRNGKey(2), (768, 128)) * 0.05
    enc = jax.jit(lambda a: rae_encode(a, w, impl="ref"))
    us = _timeit(enc, db)
    emit("rae_encode_65536x768to128", us,
         f"{65536*768*128*2/us*1e6/1e12:.2f}TFLOPs_eff")

    # reduced-space scan speedup (the paper's payoff): 768d vs 128d corpus
    dbr = enc(db)
    qr = jax.jit(lambda a: rae_encode(a, w, impl="ref"))(q)
    red = jax.jit(lambda a, b: l2_topk(a, b, 10, impl="ref"))
    us_red = _timeit(red, qr, dbr)
    emit("l2_topk_reduced_256x65536x128", us_red,
         f"speedup_vs_full={_timeit(fused, q, db)/us_red:.2f}x")


def bench_rae_train():
    from repro.configs import RAEConfig
    from repro.core import trainer
    from repro.data import synthetic

    data = synthetic.paper_dataset("imdb_like", 2000)
    cfg = RAEConfig(in_dim=768, out_dim=384, steps=200)
    t0 = time.perf_counter()
    res = trainer.train(cfg, data, log_every=10**9)
    us = (time.perf_counter() - t0) / cfg.steps * 1e6
    emit("rae_train_step_768to384_b128", us,
         f"loss={res.history[-1]['loss']:.3f}")


def bench_two_stage_search():
    import jax
    import jax.numpy as jnp

    from repro.configs import RAEConfig
    from repro.core import trainer
    from repro.data import synthetic
    from repro.models.common import NULL_CTX
    from repro.search import (encode_corpus, recall_vs_exact, search,
                              two_stage_search)

    data = synthetic.embedding_corpus(32768, 512, n_clusters=16,
                                      intrinsic=128, seed=0)
    cfg = RAEConfig(in_dim=512, out_dim=128, steps=600, weight_decay=0.3)
    res = trainer.train(cfg, data, log_every=10**9)
    db = jnp.asarray(data)
    db_red = encode_corpus(res.params, db, NULL_CTX)
    q = db[:128] + 0.01

    exact = jax.jit(lambda a: search(a, db, 10, NULL_CTX))
    ts = jax.jit(lambda a: two_stage_search(a, db, db_red, res.params, 10,
                                            NULL_CTX, rerank_factor=4))
    us_exact = _timeit(exact, q)
    us_ts = _timeit(ts, q)
    recall = recall_vs_exact(q, db, db_red, res.params, 10, NULL_CTX, 4)
    emit("search_exact_128q_32k_512d", us_exact, "")
    emit("search_two_stage_128q_32k_512to128d", us_ts,
         f"recall@10={recall:.4f};speedup={us_exact/us_ts:.2f}x")


def bench_ivf():
    import jax.numpy as jnp

    from repro.data import synthetic
    from repro.search import ivf

    corpus = jnp.asarray(synthetic.embedding_corpus(32768, 128,
                                                    n_clusters=16,
                                                    intrinsic=48, seed=1))
    t0 = time.perf_counter()
    idx = ivf.build(corpus, n_cells=64, kmeans_iters=6)
    build_s = time.perf_counter() - t0
    q = corpus[:128] + 0.01
    import jax

    srch = jax.jit(lambda a: ivf.search(idx, a, 10, nprobe=8))
    us = _timeit(srch, q)
    rec = ivf.recall_vs_exact(idx, corpus, q, 10, 8)
    emit("ivf_search_128q_32k_nprobe8", us,
         f"recall@10={rec:.3f};build={build_s:.1f}s;scan_frac={8/64:.2f}")


def bench_quant_quick():
    """CPU-budget slice of table3_quant: the quantized tier's
    memory-vs-recall-vs-QPS rows (also writes BENCH_quant.json)."""
    from .table3_quant import run

    rows = run(quick=True)
    for r in rows:
        emit(f"table3.{r['space']}.{r['spec']}",
             r["latency_ms_p50"] * 1e3,
             f"recall@{r['k']}={r['recall_at_k']};"
             f"bytes={r['bytes_per_vector']:.0f}",
             recall=r["recall_at_k"], qps=r["qps"],
             bytes_per_vector=r["bytes_per_vector"],
             build_s=r["build_s"])


def bench_graph_quick():
    """CPU-budget slice of table4_graph: the graph tier's
    recall-vs-QPS-vs-visited-fraction rows (also writes BENCH_graph.json)."""
    from .table4_graph import run

    rows = run(quick=True)
    for r in rows:
        emit(f"table4.{r['space']}.{r['spec']}",
             r["latency_ms_p50"] * 1e3,
             f"recall@{r['k']}={r['recall_at_k']};"
             f"evals={r['distance_evals']:.0f};"
             f"visited={r['visited_frac']:.1%}",
             recall=r["recall_at_k"], qps=r["qps"],
             distance_evals=r["distance_evals"],
             visited_frac=r["visited_frac"], build_s=r["build_s"])


def bench_serve_quick():
    """CPU-budget slice of table5_serve: micro-batched engine QPS vs the
    sequential q=1 loop (also writes BENCH_serve.json)."""
    from .table5_serve import run

    rows = run(quick=True)
    for r in rows:
        emit(f"table5.{r['spec']}", r["latency_ms_p50"] * 1e3,
             f"recall@{r['k']}={r['recall_at_k']};"
             f"speedup={r['speedup']}x;"
             f"batch={r['batch_size_mean']}",
             recall=r["recall_at_k"], qps=r["engine_qps"],
             seq_qps=r["seq_qps"], speedup=r["speedup"],
             batch_size_mean=r["batch_size_mean"], build_s=r["build_s"])


def bench_autotune_quick():
    """CPU-budget slice of table8_autotune: recall-SLO-tuned operating
    points vs hand-picked defaults (also writes BENCH_autotune.json)."""
    from .table8_autotune import run

    rows = run(quick=True)
    for r in rows:
        emit(f"table8.{r['spec']}.slo{r['target_recall']}",
             0.0,
             f"recall={r['recall_holdout']};"
             f"evals_ratio={r['evals_ratio']};"
             f"escalated={r['escalation_rate']:.1%}",
             recall_holdout=r["recall_holdout"],
             tuned_distance_evals=r["tuned_distance_evals"],
             default_distance_evals=r["default_distance_evals"],
             evals_ratio=r["evals_ratio"],
             escalation_rate=r["escalation_rate"])


def bench_table1_quick():
    from .table1_knn import run

    rows = run(n=2048, rae_steps=900, datasets=("imdb_like",),
               methods=("pca", "rae"), quick=True)
    for r in rows:
        emit(f"table1.{r['dataset']}.m{r['m']}.{r['method']}.{r['metric']}",
             r["train_s"] * 1e6, f"top5={r['top5']}")


def bench_fig1_quick():
    from .fig1_weight_decay import run

    rows = run(n=1500, m=256, steps=600,
               lambdas=(0.0, 1e-2, 1e-1, 1.0, 10.0))
    best = max(rows, key=lambda r: r["acc@5"])
    for r in rows:
        emit(f"fig1.lambda{r['weight_decay']}", 0.0,
             f"acc5={r['acc@5']};kappa={r['kappa']:.2f}")
    emit("fig1.best_lambda", 0.0,
         f"lambda={best['weight_decay']};acc5={best['acc@5']};"
         f"kappa={best['kappa']:.2f}")


def bench_roofline_summary():
    if not os.path.exists("results/dryrun.json"):
        emit("roofline", 0.0, "skipped(no results/dryrun.json)")
        return
    from .roofline import build_table

    rows = build_table("results/dryrun.json")
    single = [r for r in rows if r.mesh == "16x16"]
    emit("dryrun.cells_compiled", 0.0,
         f"{len(rows)}/80 across both meshes")
    for bound in ("compute", "memory", "collective"):
        n = sum(1 for r in single if r.dominant == bound)
        emit(f"roofline.single_pod.{bound}_bound_cells", 0.0, f"count={n}")
    best = max(single, key=lambda r: r.util_vs_dominant)
    emit("roofline.best_cell", 0.0,
         f"{best.arch}/{best.shape};util={best.util_vs_dominant:.3f}")
    tr = [r for r in single if r.shape in ("train_4k",)]
    for r in tr:
        emit(f"roofline.{r.arch}.train_4k", 0.0,
             f"useful_ratio={r.useful_ratio:.2f};bound={r.dominant};"
             f"peak_gib={r.peak_gib:.1f}")


def main() -> None:
    print("name,us_per_call,derived")
    t0 = time.time()
    bench_kernels()
    bench_rae_train()
    bench_two_stage_search()
    bench_ivf()
    bench_quant_quick()
    bench_graph_quick()
    bench_serve_quick()
    bench_autotune_quick()
    bench_fig1_quick()
    bench_table1_quick()
    bench_roofline_summary()
    wall = time.time() - t0
    os.makedirs("results", exist_ok=True)
    json.dump(ROWS, open("results/bench.json", "w"), indent=1)  # legacy path
    write_bench("run", ROWS, config={"wall_clock_s": round(wall, 1)})
    print(f"# total {wall:.1f}s -> results/bench.json")


if __name__ == "__main__":
    main()
