"""Figure 1 reproduction: weight-decay (lambda) sweep.

For each lambda: k-NN accuracy at k in {1, 5, 10}, sigma_max / sigma_min of
the trained encoder, and kappa(W). The paper's claim (validated here):
accuracy peaks where the condition number is minimal, and large lambda blows
kappa up while accuracy collapses.
"""
from __future__ import annotations

import numpy as np

from repro.configs import RAEConfig
from repro.core import metrics, spectral, trainer
from repro.core import rae as rae_lib
from repro.data import synthetic

LAMBDAS = (0.0, 1e-3, 1e-2, 1e-1, 3e-1, 1.0, 3.0, 10.0)


def run(dataset: str = "imdb_like", n: int = 3000, m: int = 256,
        steps: int = 1500, metric: str = "euclidean", lambdas=LAMBDAS):
    import jax.numpy as jnp

    data = synthetic.paper_dataset(dataset, n)
    tr, te = synthetic.train_test_split(data)
    dim = tr.shape[1]
    rows = []
    for lam in lambdas:
        cfg = RAEConfig(in_dim=dim, out_dim=m, steps=steps, weight_decay=lam)
        res = trainer.train(cfg, tr, log_every=10**9)
        w = rae_lib.encoder_matrix(res.params)
        st = spectral.analyze(w)
        z = np.asarray(rae_lib.encode(res.params, jnp.asarray(te)))
        row = dict(weight_decay=lam,
                   sigma_max=float(st.sigma_max),
                   sigma_min=float(st.sigma_min),
                   kappa=float(st.condition_number))
        for k in (1, 5, 10):
            row[f"acc@{k}"] = round(
                100 * metrics.preservation_accuracy(te, z, k=k,
                                                    metric=metric), 2)
        rows.append(row)
        print(f"  lambda={lam:<8g} acc@5={row['acc@5']:6.2f} "
              f"kappa={row['kappa']:8.2f} "
              f"sigma=[{row['sigma_min']:.3f},{row['sigma_max']:.3f}]")
    return rows


def main():
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="imdb_like")
    ap.add_argument("--n", type=int, default=3000)
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--steps", type=int, default=1500)
    ap.add_argument("--metric", default="euclidean")
    ap.add_argument("--out", default="results/fig1.json")
    args = ap.parse_args()
    rows = run(args.dataset, args.n, args.m, args.steps, args.metric)
    os.makedirs("results", exist_ok=True)
    json.dump(rows, open(args.out, "w"), indent=1)
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
