"""Table 4 (beyond paper): graph traversal vs scan — the sublinearity axis.

Sweeps {Flat, IVF<c>, HNSW<M>} x {raw, RAE<m>} and reports recall@k against
the exact full-space scan, queries-per-second, and *distance evaluations
per query* — the work metric that separates a graph index from every scan
tier: beam search visits a few hundred nodes where Flat touches all N and
IVF still scans nprobe full cells. The RAE space runs every base behind a
``TwoStageIndex`` with full-space rerank (the paper's deployment story,
told on graph indexes like GleanVec's), reusing ONE fitted reducer so
differences are purely the candidate-generation tier. The RAE space also
carries the quantized graph stacks (``...,HNSW<M>,SQ8,...`` /
``...,HNSW<M>,PQ8x8,...``) whose hops gather codes instead of f32 rows;
their ``traversal_gather_bytes_per_hop`` column vs the f32 twin's is the
bandwidth win ``scripts/check_bench.py`` gates (>= 3x SQ8, >= 4x PQ).

Writes ``results/BENCH_graph.json`` (schema: ``benchmarks.run.write_bench``)
so the recall/QPS/visited-fraction trajectory is tracked across PRs.

CPU-budget default: ``python -m benchmarks.table4_graph --quick`` finishes
in a few minutes at n=4096; the full 20k x 256 run mirrors the acceptance
test in tests/test_graph.py.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import api
from repro.core.metrics import recall_at_k
from repro.data import synthetic

from .run import write_bench


def _qps(index: "api.VectorIndex", q: np.ndarray, k: int,
         repeats: int = 3) -> tuple[float, float]:
    """(queries/s, p50 latency ms); first call warms the jit cache."""
    index.search(q, k)
    lat = [index.search(q, k).latency_s for _ in range(repeats)]
    p50 = float(np.percentile(lat, 50))
    return q.shape[0] / p50, p50 * 1e3


def run(n: int = 20000, dim: int = 256, m_reduce: int = 64,
        n_cells: int = 256, hnsw_m: int = 32, ef_construction: int = 100,
        ef_search: int = 64, n_queries: int = 256, k: int = 10,
        rae_steps: int = 1000, rerank_factor: int = 4, seed: int = 0,
        quick: bool = False) -> list[dict]:
    if quick:
        n, rae_steps, n_cells, n_queries = 4096, 300, 64, 64
    corpus = synthetic.embedding_corpus(n, dim, n_clusters=16,
                                        intrinsic=dim // 4, seed=seed)
    rng = np.random.default_rng(seed + 1)
    q = corpus[rng.integers(0, n, n_queries)] + \
        0.01 * rng.standard_normal((n_queries, dim)).astype(np.float32)

    exact = api.FlatIndex().build(corpus)
    exact_res = exact.search(q, k)

    print(f"fitting RAE {dim}->{m_reduce} ({rae_steps} steps) once, "
          f"shared across the RAE-space bases")
    reducer = api.make_reducer("rae", m_reduce, steps=rae_steps, seed=seed)
    reducer.fit(corpus)

    bases = ["Flat", f"IVF{n_cells}", f"HNSW{hnsw_m}"]
    # quantized graph payloads ride the full deployment stack (reduce ->
    # quantized traversal -> exact rerank): the Rerank stage is what makes
    # the within-0.01-of-f32 recall gate meaningful for PQ
    quant_bases = [f"HNSW{hnsw_m},SQ8", f"HNSW{hnsw_m},PQ8x8"]
    index_kw = {"ef_construction": ef_construction, "ef_search": ef_search}
    rows = []
    for space in ("raw", f"rae{m_reduce}"):
        specs = bases if space == "raw" else bases + quant_bases
        for base in specs:
            kw = index_kw if base.startswith("HNSW") else None
            if space == "raw":
                spec = base
                index = api.index_factory(base, index_kw=kw)
            else:
                spec = f"RAE{m_reduce},{base},Rerank{rerank_factor}"
                index = api.TwoStageIndex(reducer,
                                          api.index_factory(base,
                                                            index_kw=kw),
                                          rerank_factor=rerank_factor)
            t0 = time.perf_counter()
            index.build(corpus)
            build_s = time.perf_counter() - t0
            qps, p50_ms = _qps(index, q, k)
            res = index.search(q, k)
            rec = recall_at_k(res.indices, exact_res.indices)
            evals = res.distance_evals
            row = {"space": space, "spec": spec,
                   "recall_at_k": round(rec, 4), "k": k,
                   "distance_evals": round(evals, 1),
                   "visited_frac": round(evals / n, 4),
                   "bytes_per_vector": index.bytes_per_vector,
                   "qps": round(qps, 1), "latency_ms_p50": round(p50_ms, 3),
                   "build_s": round(build_s, 2)}
            if "gather_bytes_per_hop" in res.stats:
                # payload bytes each fused hop streams (codes vs f32 rows)
                # — the bandwidth axis check_bench's graph block gates
                row["traversal_gather_bytes_per_hop"] = round(
                    res.stats["gather_bytes_per_hop"], 1)
            rows.append(row)
            print(f"{space:8s} {spec:28s} recall@{k}={rec:.4f} "
                  f"evals/q={evals:8.1f} ({row['visited_frac']:.1%}) "
                  f"qps={qps:8.1f} build={build_s:.1f}s")
    write_bench("graph", rows,
                config={"n": n, "dim": dim, "m_reduce": m_reduce,
                        "n_cells": n_cells, "hnsw_m": hnsw_m,
                        "ef_construction": ef_construction,
                        "ef_search": ef_search, "n_queries": n_queries,
                        "k": k, "rae_steps": rae_steps,
                        "rerank_factor": rerank_factor, "seed": seed,
                        "quick": quick})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--m-reduce", type=int, default=64)
    ap.add_argument("--n-cells", type=int, default=256)
    ap.add_argument("--hnsw-m", type=int, default=32)
    ap.add_argument("--ef-construction", type=int, default=100)
    ap.add_argument("--ef-search", type=int, default=64)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--rae-steps", type=int, default=1000)
    ap.add_argument("--rerank-factor", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="CPU-budget run: n=4096, 300 RAE steps")
    a = ap.parse_args(argv)
    run(n=a.n, dim=a.dim, m_reduce=a.m_reduce, n_cells=a.n_cells,
        hnsw_m=a.hnsw_m, ef_construction=a.ef_construction,
        ef_search=a.ef_search, n_queries=a.queries, k=a.k,
        rae_steps=a.rae_steps, rerank_factor=a.rerank_factor, seed=a.seed,
        quick=a.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
