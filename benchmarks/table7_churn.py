"""Table 7 (beyond paper): the churn soak — live mutation under load.

Serves a ``Mut``-wrapped index through :class:`repro.serve.SearchEngine`
while a sustained insert/delete stream turns over >= 5% of the corpus,
with closed-loop query clients running CONCURRENTLY with every mutation
(``engine.mutate`` applies each one atomically on the search executor).
Three invariants are measured, and ``scripts/check_bench.py`` gates all
of them:

* **tombstone exactness** — ``tombstone_violations`` counts answers
  containing any id that was deleted before the answering round began.
  Must be exactly 0: the alive mask rides into the fused kernels as
  ``db_mask``, so this is a correctness gate, not a recall knob.
* **no dropped queries** — every request issued during the soak must be
  answered (``dropped_queries == 0``); mutations wait their turn on the
  executor instead of failing queries.
* **recall parity with a static twin** — after the soak, the mutated
  index's recall@k (vs the exact scan over the surviving corpus) must be
  >= 0.95x the recall of the SAME spec built fresh on that corpus: the
  incrementally-grown graph / appended IVF cells may degrade gracefully,
  never collapse. ``qps_under_churn`` must also clear the
  ``churn_qps_floor`` recorded in the config block.

Sweeps {Mut,Flat; Mut,IVF<c>; Mut,HNSW<M>} — scan, cell-append and
graph-insert mutation paths — and writes ``results/BENCH_churn.json``.

CPU-budget default: ``python -m benchmarks.table7_churn --quick``
finishes in a few minutes at n=2048.
"""
from __future__ import annotations

import argparse
import asyncio
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro import api
from repro.data import synthetic
from repro.serve import SearchEngine

from .run import write_bench


def _recall(got_ext: np.ndarray, gt_ext: np.ndarray) -> float:
    """recall@k over EXTERNAL ids (rowwise set intersection)."""
    hits = sum(len(set(g.tolist()) & set(t.tolist()))
               for g, t in zip(got_ext, gt_ext))
    return hits / float(gt_ext.size)


def _drive_queries(engine: SearchEngine, queries: np.ndarray, k: int,
                   n_clients: int) -> tuple[float, np.ndarray, int]:
    """Closed-loop client pool (same model as table5_serve): returns
    (wall seconds, per-request external ids [R, k] with -1 for failed or
    padded slots, dropped count)."""
    out = np.full((queries.shape[0], k), -1, np.int64)
    dropped = 0

    async def drive():
        nonlocal dropped
        cursor = iter(range(queries.shape[0]))

        async def client():
            nonlocal dropped
            for i in cursor:
                try:
                    res = await engine.asearch(queries[i], k)
                    ids = np.asarray(res.indices)[0]
                    out[i, :ids.shape[0]] = ids
                except Exception:
                    dropped += 1

        await asyncio.gather(*[client() for _ in range(n_clients)])

    t0 = time.perf_counter()
    asyncio.run_coroutine_threadsafe(drive(), engine.loop).result()
    return time.perf_counter() - t0, out, dropped


def _soak(spec: str, corpus: np.ndarray, rounds: int, batch: int,
          n_queries: int, n_clients: int, k: int, max_batch: int,
          max_wait_ms: float, seed: int) -> dict:
    n, dim = corpus.shape
    rng = np.random.default_rng(seed + 17)
    by_ext = {i: corpus[i] for i in range(n)}   # external id -> vector
    dead_before: set[int] = set()               # deleted in a PRIOR round

    index = api.index_factory(spec)
    t0 = time.perf_counter()
    index.build(corpus)
    build_s = time.perf_counter() - t0

    violations = dropped = 0
    query_s = 0.0
    answered = 0
    with SearchEngine(index, max_batch=max_batch, max_wait_ms=max_wait_ms,
                      cache_size=0) as engine:
        engine.warmup(dim=dim, ks=(k,))
        for r in range(rounds):
            alive_ext = np.fromiter(
                (e for e in by_ext if e not in dead_before), np.int64)
            # fresh rows from the corpus distribution + doomed picks
            new_rows = synthetic.embedding_corpus(
                batch, dim, n_clusters=16, intrinsic=dim // 4,
                seed=seed + 100 + r)
            doomed = rng.choice(alive_ext, batch, replace=False)
            qpick = rng.choice(np.setdiff1d(alive_ext, doomed), n_queries)
            queries = np.stack([by_ext[int(e)] for e in qpick]) + \
                0.01 * rng.standard_normal((n_queries, dim)) \
                .astype(np.float32)

            # mutations land WHILE the clients are in flight: the engine
            # serializes them against batches, so answers are never torn
            with ThreadPoolExecutor(1) as tp:
                fut = tp.submit(_drive_queries, engine, queries, k,
                                n_clients)
                new_ext = engine.mutate(
                    lambda ix: ix.add(new_rows.astype(np.float32)))
                engine.mutate(lambda ix: ix.delete(doomed))
                secs, got, drop = fut.result()
            query_s += secs
            answered += n_queries - drop
            dropped += drop
            # exactness: ids tombstoned before this round may never appear
            if dead_before:
                dead_arr = np.fromiter(dead_before, np.int64)
                violations += int(np.isin(got, dead_arr).sum())
            for e, v in zip(new_ext, new_rows):
                by_ext[int(e)] = v.astype(np.float32)
            dead_before |= {int(e) for e in doomed}

        # -- post-soak recall vs the static twin ---------------------------
        alive_ext = np.fromiter(
            (e for e in by_ext if e not in dead_before), np.int64)
        alive_ext.sort()
        alive_mat = np.stack([by_ext[int(e)] for e in alive_ext])
        q_eval = alive_mat[rng.integers(0, alive_ext.size, n_queries)] + \
            0.01 * rng.standard_normal((n_queries, dim)).astype(np.float32)
        gt_pos = api.FlatIndex().build(alive_mat).search(q_eval, k).indices
        gt_ext = alive_ext[np.asarray(gt_pos)]

        mut_ids = np.zeros((n_queries, k), np.int64)
        for i in range(0, n_queries, max_batch):
            res = engine.search(q_eval[i:i + max_batch], k)
            mut_ids[i:i + max_batch] = np.asarray(res.indices)
        mut_recall = _recall(mut_ids, gt_ext)
        if dead_before:
            violations += int(np.isin(
                mut_ids, np.fromiter(dead_before, np.int64)).sum())
        stats = engine.stats()

    static = api.index_factory(spec.split("Mut,", 1)[1])
    static.build(alive_mat)
    st_pos = np.asarray(static.search(q_eval, k).indices)
    st_ext = np.where(st_pos >= 0, alive_ext[np.clip(st_pos, 0, None)], -1)
    static_recall = _recall(st_ext, gt_ext)

    turnover = rounds * batch / float(n)
    qps = answered / max(query_s, 1e-9)
    ms = stats["mutation"]["index"]
    return {"spec": spec, "k": k, "n": n,
            "turnover_frac": round(turnover, 4),
            "recall_at_k": round(mut_recall, 4),
            "static_recall_at_k": round(static_recall, 4),
            "recall_ratio_vs_static": round(
                mut_recall / max(static_recall, 1e-9), 4),
            "tombstone_violations": int(violations),
            "dropped_queries": int(dropped),
            "qps_under_churn": round(qps, 1),
            "latency_ms_p50": stats["latency_ms"]["p50"],
            "latency_ms_p99": stats["latency_ms"]["p99"],
            "epochs": int(ms["epoch"]), "rebuilds": int(ms["rebuilds"]),
            "tombstones_live": int(ms["tombstones"]),
            "build_s": round(build_s, 2)}


def run(n: int = 16384, dim: int = 64, n_cells: int = 64, hnsw_m: int = 16,
        rounds: int = 6, n_queries: int = 128, n_clients: int = 16,
        k: int = 10, max_batch: int = 16, max_wait_ms: float = 4.0,
        turnover: float = 0.08, qps_floor: float = 25.0, seed: int = 0,
        quick: bool = False) -> list[dict]:
    if quick:
        n = 2048
    batch = max(1, int(round(n * turnover / rounds)))
    corpus = synthetic.embedding_corpus(n, dim, n_clusters=16,
                                        intrinsic=dim // 4, seed=seed)
    specs = ["Mut,Flat", f"Mut,IVF{n_cells}", f"Mut,HNSW{hnsw_m}"]
    rows = []
    for spec in specs:
        row = _soak(spec, corpus, rounds, batch, n_queries, n_clients, k,
                    max_batch, max_wait_ms, seed)
        rows.append(row)
        print(f"{spec:12s} turnover={row['turnover_frac']:.1%} "
              f"recall@{k}={row['recall_at_k']:.4f} "
              f"(static {row['static_recall_at_k']:.4f}, "
              f"ratio {row['recall_ratio_vs_static']:.3f}) "
              f"violations={row['tombstone_violations']} "
              f"dropped={row['dropped_queries']} "
              f"qps={row['qps_under_churn']:.1f}")
    write_bench("churn", rows,
                config={"n": n, "dim": dim, "n_cells": n_cells,
                        "hnsw_m": hnsw_m, "rounds": rounds, "batch": batch,
                        "n_queries": n_queries, "n_clients": n_clients,
                        "k": k, "max_batch": max_batch,
                        "max_wait_ms": max_wait_ms,
                        "turnover_target": turnover,
                        "churn_qps_floor": qps_floor,
                        "churn_recall_ratio_floor": 0.95,
                        "seed": seed, "quick": quick})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--n-cells", type=int, default=64)
    ap.add_argument("--hnsw-m", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--queries", type=int, default=128)
    ap.add_argument("--clients", type=int, default=16)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=4.0)
    ap.add_argument("--turnover", type=float, default=0.08,
                    help="total corpus fraction inserted AND deleted")
    ap.add_argument("--qps-floor", type=float, default=25.0,
                    help="sustained-QPS gate recorded for check_bench")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="CPU-budget run: n=2048")
    a = ap.parse_args(argv)
    run(n=a.n, dim=a.dim, n_cells=a.n_cells, hnsw_m=a.hnsw_m,
        rounds=a.rounds, n_queries=a.queries, n_clients=a.clients, k=a.k,
        max_batch=a.max_batch, max_wait_ms=a.max_wait_ms,
        turnover=a.turnover, qps_floor=a.qps_floor, seed=a.seed,
        quick=a.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
