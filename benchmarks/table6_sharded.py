"""Table 6 (beyond paper): sharded million-vector serving — the scale axis.

The corpus the paper's 20k-point tables never touch: >= 1M vectors, RAE
128->64 reduced, partitioned across IVF shards and served scatter-gather
through ``ShardedIndex`` + ``SearchEngine``. The deliberately ragged
default (``n = 1_000_003``, prime) means every shard count hits the
tail-row path the legacy distributed layer used to drop.

What each row reports (and scripts/check_bench.py gates):

* ``recall_at_k`` vs the exact full-space scan — every ``Shard<S>`` row
  must stay within 0.01 of its unsharded twin in the SAME file: the
  deterministic merge is lossless by contract, so sharding may not cost
  recall beyond IVF's own approximation.
* ``engine_qps`` / ``latency_ms_p50`` / ``latency_ms_p99`` through the
  micro-batching engine; p99 must stay under ``config["p99_budget_ms"]``.
* ``bytes_per_shard`` — the largest single-shard payload, the number
  that must fit one worker; gated under ``config["shard_bytes_budget"]``.

The committed ``results/BENCH_sharded.json`` is the full-scale run (this
bench is NOT rerun by ``CI_BENCH=1``'s quick gate — at 1M rows it is a
release-cadence bench; reruns compare equal to their own snapshot).

CPU-budget smoke: ``python -m benchmarks.table6_sharded --quick``
(n=20003, a few hundred RAE steps) finishes in minutes.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import api
from repro.core.metrics import recall_at_k
from repro.data import synthetic
from repro.serve import SearchEngine

from .run import write_bench
from .table5_serve import _client_pool


def _build_stack(reducer, spec: str, rerank_factor: int,
                 index_kw: dict) -> api.VectorIndex:
    """Materialize ``RAE..,{Shard<S>,}IVF..,Rerank..`` around the shared
    pre-fitted reducer (index_factory would refit RAE per spec)."""
    parsed = api.parse_index_spec(spec)
    if parsed.shards:
        base: api.VectorIndex = api.ShardedIndex(
            n_shards=parsed.shards, child_spec=f"IVF{parsed.n_cells}",
            index_kw=dict(index_kw))
    else:
        base = api.IVFFlatIndex(n_cells=parsed.n_cells, **index_kw)
    return api.TwoStageIndex(reducer, base, rerank_factor=rerank_factor)


def run(n: int = 1_000_003, dim: int = 128, m_reduce: int = 64,
        n_cells: int = 256, shard_counts: tuple = (2, 8),
        n_requests: int = 256, n_clients: int = 32, k: int = 10,
        max_batch: int = 16, max_wait_ms: float = 4.0,
        rae_steps: int = 600, fit_rows: int = 100_000,
        rerank_factor: int = 4, kmeans_iters: int = 6, seed: int = 0,
        repeats: int = 2, p99_budget_ms: float = 0.0,
        shard_bytes_budget: float = 0.0, quick: bool = False) -> list[dict]:
    if quick:
        n, rae_steps, n_cells = 20_003, 300, 64
        fit_rows = min(fit_rows, n)
        repeats = max(repeats, 3)
    t0 = time.perf_counter()
    corpus = synthetic.embedding_corpus(n, dim, n_clusters=64,
                                        intrinsic=dim // 4, seed=seed)
    rng = np.random.default_rng(seed + 1)
    queries = corpus[rng.integers(0, n, n_requests)] + \
        0.01 * rng.standard_normal((n_requests, dim)).astype(np.float32)
    print(f"corpus [{n}, {dim}] in {time.perf_counter() - t0:.1f}s "
          f"({corpus.nbytes / 2**20:.0f} MiB)")

    t0 = time.perf_counter()
    gt = api.FlatIndex().build(corpus).search(queries, k).indices
    print(f"exact ground truth in {time.perf_counter() - t0:.1f}s")

    print(f"fitting RAE {dim}->{m_reduce} ({rae_steps} steps on "
          f"{min(fit_rows, n)} rows) once, shared across stacks")
    reducer = api.make_reducer("rae", m_reduce, steps=rae_steps, seed=seed)
    reducer.fit(corpus[:fit_rows])

    specs = [f"RAE{m_reduce},IVF{n_cells},Rerank{rerank_factor}"] + \
        [f"RAE{m_reduce},Shard{s},IVF{n_cells},Rerank{rerank_factor}"
         for s in shard_counts]
    index_kw = {"kmeans_iters": kmeans_iters}
    rows = []
    for spec in specs:
        index = _build_stack(reducer, spec, rerank_factor, index_kw)
        t0 = time.perf_counter()
        index.build(corpus)
        build_s = time.perf_counter() - t0
        sharded = getattr(index, "base", None)
        if isinstance(sharded, api.ShardedIndex):
            bytes_per_shard = float(sharded.bytes_per_shard)
            shard_count = sharded.shard_count
        else:  # unsharded twin: the whole reduced corpus is one "shard"
            bytes_per_shard = float(index.base.ntotal
                                    * index.base.bytes_per_vector)
            shard_count = 1

        engine = SearchEngine(index, max_batch=max_batch,
                              max_wait_ms=max_wait_ms, cache_size=0)
        with engine:
            engine.warmup(dim=dim, ks=(k,))
            eng_s, eng_idx = min((_client_pool(engine, queries, k,
                                               n_clients)
                                  for _ in range(repeats)),
                                 key=lambda r: r[0])
            stats = engine.stats()
        eng_qps = n_requests / eng_s
        recall = recall_at_k(eng_idx, gt)

        row = {"spec": spec, "k": k, "n": n,
               "recall_at_k": round(recall, 4),
               "engine_qps": round(eng_qps, 1),
               "latency_ms_p50": stats["latency_ms"]["p50"],
               "latency_ms_p99": stats["latency_ms"]["p99"],
               "bytes_per_shard": bytes_per_shard,
               "shard_count": shard_count,
               "build_s": round(build_s, 1)}
        rows.append(row)
        print(f"{spec:34s} recall@{k}={recall:.4f} "
              f"engine={eng_qps:7.1f} qps  p99={row['latency_ms_p99']:.1f} ms"
              f"  {bytes_per_shard / 2**20:.0f} MiB/shard "
              f"(S={shard_count}, build {build_s:.0f}s)")

    # budgets default to measured-with-headroom so the committed snapshot
    # gates itself: 3x p99 absorbs runner noise, 1.5x bytes catches a
    # partitioner that silently stops balancing
    shard_rows = [r for r in rows if r["shard_count"] > 1]
    if not p99_budget_ms:
        p99_budget_ms = round(3.0 * max(r["latency_ms_p99"]
                                        for r in shard_rows), 1)
    if not shard_bytes_budget:
        shard_bytes_budget = float(int(1.5 * max(r["bytes_per_shard"]
                                                 for r in shard_rows)))
    print(f"budgets: p99 <= {p99_budget_ms} ms, "
          f"<= {shard_bytes_budget / 2**20:.0f} MiB/shard")
    write_bench("sharded", rows,
                config={"n": n, "dim": dim, "m_reduce": m_reduce,
                        "n_cells": n_cells,
                        "shard_counts": list(shard_counts),
                        "n_requests": n_requests, "n_clients": n_clients,
                        "k": k, "max_batch": max_batch,
                        "max_wait_ms": max_wait_ms,
                        "rae_steps": rae_steps, "fit_rows": fit_rows,
                        "rerank_factor": rerank_factor,
                        "kmeans_iters": kmeans_iters, "seed": seed,
                        "repeats": repeats,
                        "p99_budget_ms": p99_budget_ms,
                        "shard_bytes_budget": shard_bytes_budget,
                        "quick": quick})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_003,
                    help="corpus rows (prime default: always ragged)")
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--m-reduce", type=int, default=64)
    ap.add_argument("--n-cells", type=int, default=256)
    ap.add_argument("--shards", type=str, default="2,8",
                    help="comma-separated shard counts to bench")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=4.0)
    ap.add_argument("--rae-steps", type=int, default=600)
    ap.add_argument("--fit-rows", type=int, default=100_000,
                    help="corpus subsample the RAE fits on")
    ap.add_argument("--rerank-factor", type=int, default=4)
    ap.add_argument("--kmeans-iters", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--p99-budget-ms", type=float, default=0.0,
                    help="0 = derive 3x measured p99")
    ap.add_argument("--shard-bytes-budget", type=float, default=0.0,
                    help="0 = derive 1.5x measured bytes_per_shard")
    ap.add_argument("--quick", action="store_true",
                    help="CPU-budget smoke: n=20003, 300 RAE steps")
    a = ap.parse_args(argv)
    run(n=a.n, dim=a.dim, m_reduce=a.m_reduce, n_cells=a.n_cells,
        shard_counts=tuple(int(s) for s in a.shards.split(",")),
        n_requests=a.requests, n_clients=a.clients, k=a.k,
        max_batch=a.max_batch, max_wait_ms=a.max_wait_ms,
        rae_steps=a.rae_steps, fit_rows=a.fit_rows,
        rerank_factor=a.rerank_factor, kmeans_iters=a.kmeans_iters,
        seed=a.seed, repeats=a.repeats, p99_budget_ms=a.p99_budget_ms,
        shard_bytes_budget=a.shard_bytes_budget, quick=a.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
