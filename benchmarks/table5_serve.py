"""Table 5 (beyond paper): micro-batched serving throughput — the QPS axis.

Compares, on the SAME built index, two ways of answering a stream of
single-query requests:

* ``seq``: the naive serving loop — one ``index.search(q[i:i+1])`` per
  request. Every request pays full dispatch overhead and runs the fused
  scan at its least efficient shape (q=1).
* ``engine``: ``repro.serve.SearchEngine`` — N closed-loop client threads
  (each fires its next request only after the previous answer returns)
  whose requests the scheduler coalesces into padded batches of up to
  ``max_batch``.

Recall is reported for BOTH paths against the exact scan; answers are
row-independent (parity-tested in tests/test_serve.py), so ``speedup =
engine_qps / seq_qps`` measures scheduling plus whatever the index's
batched path adds. Gates (scripts/check_bench.py): best speedup >= 3x,
AND the HNSW-stack row >= 2.5x on its own — since the batched
array-native traversal (ISSUE 5) the graph tier earns its speedup
per-tier instead of hiding behind the scan tiers' best-of. ``speedup``
is reported PER ROW (each row is one tier) so the per-tier gate always
has a stable ``spec``-keyed value to read.

The engine config is also per-tier: scan tiers saturate this box's
2 cores around q=16 (past that the fused scan goes memory-bound), while
the batched graph traversal amortizes a fixed per-hop cost across the
whole batch and keeps gaining — so the HNSW stack serves with
``2 * max_batch`` (and twice the clients), recorded per row.

Sweeps {Flat, RAE<m>,IVF<c>,Rerank4, RAE<m>,HNSW<M>,Rerank4,
RAE<m>,HNSW<M>,SQ8,Rerank4} and writes ``results/BENCH_serve.json``
(schema: ``benchmarks.run.write_bench``). The SQ8-graph stack serves
every request — including q=1 — on the batched traversal (quantized
hops have no sequential engine), so its ``seq`` column measures the
q=1-batched loop the engine replaces.

CPU-budget default: ``python -m benchmarks.table5_serve --quick`` finishes
in a few minutes at n=4096.
"""
from __future__ import annotations

import argparse
import asyncio
import time

import numpy as np

from repro import api
from repro.core.metrics import recall_at_k
from repro.data import synthetic
from repro.serve import SearchEngine

from .run import write_bench


def _client_pool(engine: SearchEngine, queries: np.ndarray, k: int,
                 n_clients: int) -> tuple[float, np.ndarray]:
    """Closed-loop clients: a shared cursor hands out requests; each
    client awaits its answer before taking the next. Clients are
    coroutines on the engine loop (the async-client serving model) rather
    than OS threads, so a small-core bench box measures the scheduler,
    not GIL thrash — the threaded `search_one` path is covered by
    tests/test_serve.py and the HTTP front-end. Returns (wall seconds,
    per-request indices [R, k])."""
    indices = np.zeros((queries.shape[0], k), np.int64)

    async def drive():
        cursor = iter(range(queries.shape[0]))

        async def client():
            # shared iterator is safe: single loop thread, no await in next
            for i in cursor:
                res = await engine.asearch(queries[i], k)
                indices[i] = res.indices[0]

        await asyncio.gather(*[client() for _ in range(n_clients)])

    engine.start()
    t0 = time.perf_counter()
    asyncio.run_coroutine_threadsafe(drive(), engine.loop).result()
    return time.perf_counter() - t0, indices


def _sequential(index: api.VectorIndex, queries: np.ndarray, k: int
                ) -> tuple[float, np.ndarray]:
    """The q=1 loop the engine replaces. Warmed before timing."""
    index.search(queries[:1], k)
    indices = np.zeros((queries.shape[0], k), np.int64)
    t0 = time.perf_counter()
    for i in range(queries.shape[0]):
        indices[i] = index.search(queries[i:i + 1], k).indices[0]
    return time.perf_counter() - t0, indices


def run(n: int = 20000, dim: int = 256, m_reduce: int = 64,
        n_cells: int = 256, hnsw_m: int = 32, n_requests: int = 512,
        n_clients: int = 64, k: int = 10, max_batch: int = 32,
        max_wait_ms: float = 4.0, rae_steps: int = 600,
        rerank_factor: int = 4, seed: int = 0, repeats: int = 3,
        quick: bool = False) -> list[dict]:
    if quick:
        n, rae_steps, n_cells = 4096, 300, 64
        n_requests = 256
        # shared/2-core boxes swing +-30% minute to minute; more best-of
        # passes keep the committed baseline out of the noise floor
        repeats = max(repeats, 5)
        # 2-core CPU sweet spot: past q=16 the scan tiers go memory-bound
        # and batching stops amortizing, so cap the batch and offer
        # 2 x max_batch clients (pipelined batching double-buffers
        # closed-loop clients: one cohort in flight, one queued)
        max_batch = min(max_batch, 16)
        n_clients = 2 * max_batch
    corpus = synthetic.embedding_corpus(n, dim, n_clusters=16,
                                        intrinsic=dim // 4, seed=seed)
    rng = np.random.default_rng(seed + 1)
    queries = corpus[rng.integers(0, n, n_requests)] + \
        0.01 * rng.standard_normal((n_requests, dim)).astype(np.float32)

    exact = api.FlatIndex().build(corpus)
    gt = exact.search(queries, k).indices

    print(f"fitting RAE {dim}->{m_reduce} ({rae_steps} steps) once, "
          f"shared across the reduced-space stacks")
    reducer = api.make_reducer("rae", m_reduce, steps=rae_steps, seed=seed)
    reducer.fit(corpus)

    specs = ["Flat",
             f"RAE{m_reduce},IVF{n_cells},Rerank{rerank_factor}",
             f"RAE{m_reduce},HNSW{hnsw_m},Rerank{rerank_factor}",
             # the quantized graph stack (ISSUE 8): hops gather SQ8 codes;
             # q=1 requests ride the batched engine too (sequential heapq
             # scores f32 — see api.graph), so serving parity holds
             f"RAE{m_reduce},HNSW{hnsw_m},SQ8,Rerank{rerank_factor}"]
    rows = []
    for spec in specs:
        if spec == "Flat":
            index = api.FlatIndex()
        else:
            # base = everything between the reducer and the Rerank stage
            # (possibly multi-token, e.g. "HNSW32,SQ8")
            base = api.index_factory(",".join(spec.split(",")[1:-1]))
            index = api.TwoStageIndex(reducer, base,
                                      rerank_factor=rerank_factor)
        t0 = time.perf_counter()
        index.build(corpus)
        build_s = time.perf_counter() - t0

        # both paths are deterministic pass-to-pass, so best-of-`repeats`
        # measures the serving path, not OS scheduling noise (the bench
        # gate's 20% QPS tolerance needs stable numbers to be meaningful)
        seq_s, seq_idx = min((_sequential(index, queries, k)
                              for _ in range(repeats)),
                             key=lambda r: r[0])
        seq_qps = n_requests / seq_s
        seq_recall = recall_at_k(seq_idx, gt)

        # per-tier engine shape: the batched graph traversal keeps
        # amortizing past the scan tiers' sweet spot (module docstring)
        mb = 2 * max_batch if "HNSW" in spec else max_batch
        nc = 2 * n_clients if "HNSW" in spec else n_clients
        engine = SearchEngine(index, max_batch=mb,
                              max_wait_ms=max_wait_ms,
                              cache_size=0)  # distinct queries: measure
                                             # scheduling, not caching
        with engine:
            engine.warmup(dim=dim, ks=(k,))
            eng_s, eng_idx = min((_client_pool(engine, queries, k, nc)
                                  for _ in range(repeats)),
                                 key=lambda r: r[0])
            stats = engine.stats()
        eng_qps = n_requests / eng_s
        eng_recall = recall_at_k(eng_idx, gt)

        row = {"spec": spec, "k": k, "recall_at_k": round(eng_recall, 4),
               "seq_recall_at_k": round(seq_recall, 4),
               "seq_qps": round(seq_qps, 1),
               "engine_qps": round(eng_qps, 1),
               "speedup": round(eng_qps / seq_qps, 2),
               "max_batch": mb, "n_clients": nc,
               "batch_size_mean": stats["batch_size_mean"],
               "latency_ms_p50": stats["latency_ms"]["p50"],
               "latency_ms_p99": stats["latency_ms"]["p99"],
               "build_s": round(build_s, 2)}
        rows.append(row)
        print(f"{spec:28s} recall@{k}={eng_recall:.4f} "
              f"seq={seq_qps:8.1f} qps  engine={eng_qps:8.1f} qps "
              f"({row['speedup']:.2f}x, mean batch "
              f"{row['batch_size_mean']:.1f}, "
              f"p50 {row['latency_ms_p50']:.1f} ms)")
        if eng_recall != seq_recall:
            print(f"  WARNING: engine recall {eng_recall:.4f} != "
                  f"sequential {seq_recall:.4f} — parity broken?")
    best = max(r["speedup"] for r in rows)
    print(f"best speedup: {best:.2f}x (bar: >= 3x)")
    for r in rows:
        if "HNSW" in r["spec"]:
            print(f"HNSW-tier speedup: {r['speedup']:.2f}x "
                  f"(per-tier bar: >= 2.5x)")
    write_bench("serve", rows,
                config={"n": n, "dim": dim, "m_reduce": m_reduce,
                        "n_cells": n_cells, "hnsw_m": hnsw_m,
                        "n_requests": n_requests, "n_clients": n_clients,
                        "k": k, "max_batch": max_batch,
                        "max_wait_ms": max_wait_ms, "rae_steps": rae_steps,
                        "rerank_factor": rerank_factor, "seed": seed,
                        "repeats": repeats, "quick": quick})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--m-reduce", type=int, default=64)
    ap.add_argument("--n-cells", type=int, default=256)
    ap.add_argument("--hnsw-m", type=int, default=32)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--max-wait-ms", type=float, default=4.0)
    ap.add_argument("--rae-steps", type=int, default=600)
    ap.add_argument("--rerank-factor", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed passes per path; best-of wins (noise guard)")
    ap.add_argument("--quick", action="store_true",
                    help="CPU-budget run: n=4096, 300 RAE steps")
    a = ap.parse_args(argv)
    run(n=a.n, dim=a.dim, m_reduce=a.m_reduce, n_cells=a.n_cells,
        hnsw_m=a.hnsw_m, n_requests=a.requests, n_clients=a.clients,
        k=a.k, max_batch=a.max_batch, max_wait_ms=a.max_wait_ms,
        rae_steps=a.rae_steps, rerank_factor=a.rerank_factor, seed=a.seed,
        repeats=a.repeats, quick=a.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
