"""Table 1 reproduction: top-5 k-NN preservation accuracy, methods x
datasets x target dims x {euclidean, cosine}.

Datasets are offline analogues of the paper's four (DESIGN.md §6), at the
paper's embedding dims (384/512/768/1024); N defaults to a CPU-budget 4096
(paper: 10k-20k). Validation target = orderings/trends, not absolute values:
RAE/PCA >> MDS/Isomap/UMAP everywhere; RAE > PCA on cosine; RAE ~ PCA on
euclidean (paper §4.2).
"""
from __future__ import annotations

import time

import numpy as np

from repro import api
from repro.core import metrics
from repro.data import synthetic

# paper's (dataset, dims) grid
GRID = {
    "imagenet_like": (384, (128, 192, 256)),
    "celeba_like": (512, (128, 256, 384)),
    "imdb_like": (768, (256, 384, 512)),
    "flickr_like": (1024, (256, 512, 768)),
}

# registry names from repro.api; the paper's Table 1 omits "rp"
METHODS = ("mds", "isomap", "umap", "pca", "rae")
assert set(METHODS) <= set(api.list_reducers()), api.list_reducers()


RAE_LAMBDA_GRID = (0.1, 0.3, 1.0)


def make_tuned_reducer(name: str, tr: np.ndarray, m: int, rae_steps: int,
                       wd: float, seed: int = 0) -> "api.Reducer":
    """Construct (and for RAE, lambda-tune) a registry reducer, unfitted.

    RAE's wd is tuned on a held-out validation split via the paper's
    Figure-1 protocol (lambda is its stated hyperparameter); every method
    comes out of ``api.make_reducer`` so the sweep loop below never
    special-cases."""
    if name != "rae":
        return api.make_reducer(name, m)
    n_val = max(len(tr) // 10, 64)
    tr2, val = tr[n_val:], tr[:n_val]
    best, best_acc = wd, -1.0
    for lam in RAE_LAMBDA_GRID:
        red = api.make_reducer("rae", m, steps=max(rae_steps // 3, 300),
                               weight_decay=lam, seed=seed)
        red.fit(tr2)
        acc = metrics.preservation_accuracy(val, red.transform(val), k=5)
        if acc > best_acc:
            best, best_acc = lam, acc
    return api.make_reducer("rae", m, steps=rae_steps, weight_decay=best,
                            seed=seed)


def run_method(name: str, tr: np.ndarray, te: np.ndarray, m: int,
               rae_steps: int, wd: float, seed: int = 0):
    """Returns (reduced test vectors, train time, infer time). Tuning time
    is counted into train time."""
    t0 = time.perf_counter()
    red = make_tuned_reducer(name, tr, m, rae_steps, wd, seed)
    red.fit(tr)
    train_t = time.perf_counter() - t0
    t1 = time.perf_counter()
    z = red.transform(te)
    infer_t = time.perf_counter() - t1
    return z, train_t, infer_t


def run(n: int = 4096, k: int = 5, rae_steps: int = 3000, wd: float = 1e-2,
        datasets=None, methods=METHODS, quick: bool = False):
    """Returns list of row dicts; also used by benchmarks.run."""
    rows = []
    grid = {k_: v for k_, v in GRID.items()
            if datasets is None or k_ in datasets}
    for ds_name, (dim, target_dims) in grid.items():
        data = synthetic.paper_dataset(ds_name, n)
        tr, te = synthetic.train_test_split(data)
        if quick:
            target_dims = target_dims[:1]
        for m in target_dims:
            for method in methods:
                z, train_t, infer_t = run_method(method, tr, te, m,
                                                 rae_steps, wd)
                for metric in ("euclidean", "cosine"):
                    acc = metrics.preservation_accuracy(te, z, k=k,
                                                        metric=metric)
                    rows.append(dict(dataset=ds_name, dim=dim, m=m,
                                     method=method, metric=metric,
                                     top5=round(100 * acc, 2),
                                     train_s=round(train_t, 2),
                                     infer_s=round(infer_t, 4)))
                print(f"  {ds_name}({dim}d) m={m} {method:7s} "
                      f"E={rows[-2]['top5']:6.2f} C={rows[-1]['top5']:6.2f} "
                      f"(train {train_t:.1f}s)")
    return rows


def main():
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--rae-steps", type=int, default=3000)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="results/table1.json")
    args = ap.parse_args()
    rows = run(n=args.n, rae_steps=args.rae_steps, quick=args.quick)
    import os

    os.makedirs("results", exist_ok=True)
    json.dump(rows, open(args.out, "w"), indent=1)
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
