"""Table 3 (beyond paper): memory vs recall vs QPS for the quantized tier.

Sweeps {Flat, SQ8, PQ8x8, IVF<c>,PQ8x8} x {raw, RAE<m>} and reports
recall@k against the exact full-space scan, bytes-per-vector of the stage-1
structure, and queries-per-second — the three axes the quantized tier
trades against each other. The RAE space runs every base behind a
``TwoStageIndex`` with full-space rerank (the paper's deployment), reusing
ONE fitted reducer across all bases so differences are purely storage-tier.

Writes ``results/BENCH_quant.json`` (schema: ``benchmarks.run.write_bench``)
so the memory/recall/QPS trajectory is tracked across PRs.

CPU-budget default: ``python -m benchmarks.table3_quant --quick`` finishes
in a few minutes at n=4096; the full 20k x 256 run mirrors the acceptance
test in tests/test_quantized.py.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import api
from repro.core.metrics import recall_at_k
from repro.data import synthetic

from .run import write_bench


def _qps(index: "api.VectorIndex", q: np.ndarray, k: int,
         repeats: int = 3) -> tuple[float, float]:
    """(queries/s, p50 latency ms); first call warms the jit cache."""
    index.search(q, k)
    lat = [index.search(q, k).latency_s for _ in range(repeats)]
    p50 = float(np.percentile(lat, 50))
    return q.shape[0] / p50, p50 * 1e3


def run(n: int = 20000, dim: int = 256, m_reduce: int = 64, pq_m: int = 8,
        n_cells: int = 256, n_queries: int = 256, k: int = 10,
        rae_steps: int = 1000, rerank_factor: int = 4, seed: int = 0,
        quick: bool = False) -> list[dict]:
    if quick:
        n, rae_steps, n_cells, n_queries = 4096, 300, 64, 64
    corpus = synthetic.embedding_corpus(n, dim, n_clusters=16,
                                        intrinsic=dim // 4, seed=seed)
    rng = np.random.default_rng(seed + 1)
    q = corpus[rng.integers(0, n, n_queries)] + \
        0.01 * rng.standard_normal((n_queries, dim)).astype(np.float32)

    exact = api.FlatIndex().build(corpus)
    exact_res = exact.search(q, k)

    print(f"fitting RAE {dim}->{m_reduce} ({rae_steps} steps) once, "
          f"shared across the RAE-space bases")
    reducer = api.make_reducer("rae", m_reduce, steps=rae_steps, seed=seed)
    reducer.fit(corpus)

    bases = ["Flat", "SQ8", f"PQ{pq_m}x8", f"IVF{n_cells},PQ{pq_m}x8"]
    rows = []
    for space in ("raw", f"rae{m_reduce}"):
        for base in bases:
            if space == "raw":
                spec = base
                index = api.index_factory(base)
            else:
                spec = f"RAE{m_reduce},{base},Rerank{rerank_factor}"
                index = api.TwoStageIndex(reducer,
                                          api.index_factory(base),
                                          rerank_factor=rerank_factor)
            t0 = time.perf_counter()
            index.build(corpus)
            build_s = time.perf_counter() - t0
            qps, p50_ms = _qps(index, q, k)
            rec = recall_at_k(index.search(q, k).indices, exact_res.indices)
            row = {"space": space, "spec": spec,
                   "recall_at_k": round(rec, 4), "k": k,
                   "bytes_per_vector": index.bytes_per_vector,
                   "qps": round(qps, 1), "latency_ms_p50": round(p50_ms, 3),
                   "build_s": round(build_s, 2)}
            rows.append(row)
            print(f"{space:8s} {spec:28s} recall@{k}={rec:.4f} "
                  f"bytes/vec={row['bytes_per_vector']:6.1f} "
                  f"qps={qps:8.1f} build={build_s:.1f}s")
    write_bench("quant", rows,
                config={"n": n, "dim": dim, "m_reduce": m_reduce,
                        "pq_m": pq_m, "n_cells": n_cells,
                        "n_queries": n_queries, "k": k,
                        "rae_steps": rae_steps,
                        "rerank_factor": rerank_factor, "seed": seed,
                        "quick": quick})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--m-reduce", type=int, default=64)
    ap.add_argument("--pq-m", type=int, default=8)
    ap.add_argument("--n-cells", type=int, default=256)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--rae-steps", type=int, default=1000)
    ap.add_argument("--rerank-factor", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="CPU-budget run: n=4096, 300 RAE steps")
    a = ap.parse_args(argv)
    run(n=a.n, dim=a.dim, m_reduce=a.m_reduce, pq_m=a.pq_m,
        n_cells=a.n_cells, n_queries=a.queries, k=a.k,
        rae_steps=a.rae_steps, rerank_factor=a.rerank_factor, seed=a.seed,
        quick=a.quick)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
