import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Hillclimb harness: lower variant programs for the three chosen cells and
record the three roofline terms (EXPERIMENTS.md §Perf iteration log).

Cells (selection rationale in EXPERIMENTS.md):
  A. qwen3-moe-235b-a22b x train_4k  — worst memory fit + largest compute
  B. two-tower x serve_bulk          — most collective-bound
  C. two-tower x retrieval_cand      — most representative of the paper
     (RAE two-stage retrieval integrates here)

Usage: PYTHONPATH=src python -m benchmarks.hillclimb --cell A --variant a1
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, get_shapes
from repro.distributed.partitioning import default_rules
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.common import MeshCtx
from repro.launch.train import build_cell_with
from repro.models.registry import build_cell


def measure(lowered, label):
    t0 = time.time()
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = hlo_analysis.collective_bytes(text)
    rec = {
        "label": label,
        "peak_gib": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
        "temp_gib": ma.temp_size_in_bytes / 2**30,
        "hlo_flops_dev": ca.get("flops", 0.0),
        "coll_gib": {k: round(v / 2**30, 4) for k, v in coll.items()},
        "compile_s": round(time.time() - t0, 1),
    }
    print(json.dumps(rec))
    return rec


def cell_a(variant: str):
    mesh = make_production_mesh(multi_pod=False)
    ctx = MeshCtx(mesh=mesh, rules=default_rules(multi_pod=False))
    cfg, family = get_arch("qwen3-moe-235b-a22b")
    cell = {c.name: c for c in get_shapes("qwen3-moe-235b-a22b")}["train_4k"]
    if variant in ("a2", "a2a3"):
        cfg = dataclasses.replace(cfg, grad_accum=2)
    if variant in ("a3", "a2a3"):
        cfg = dataclasses.replace(cfg, capacity_factor=1.0)
    prog = build_cell_with(cfg, family, "qwen3-moe-235b-a22b", cell, ctx)
    return measure(prog.lower(mesh), f"A.{variant}")


def cell_b(variant: str):
    mesh = make_production_mesh(multi_pod=False)
    ctx = MeshCtx(mesh=mesh, rules=default_rules(multi_pod=False))
    prog = build_cell("two-tower-retrieval", "serve_bulk", ctx)
    return measure(prog.lower(mesh), f"B.{variant}")


def cell_c(variant: str):
    from jax.sharding import NamedSharding
    from repro.core import rae as rae_lib
    from repro.configs import RAEConfig
    from repro.search import distributed_topk, search as dsearch

    mesh = make_production_mesh(multi_pod=False)
    ctx = MeshCtx(mesh=mesh, rules=default_rules(multi_pod=False))
    n, d, m, k = 1_000_000, 256, 64, 100

    if variant == "c0":
        prog = build_cell("two-tower-retrieval", "retrieval_cand", ctx)
        return measure(prog.lower(mesh), "C.c0")

    if variant == "c1":
        # precomputed item-corpus scoring (production serving shape)
        def fn(corpus, q):
            scores = corpus @ q[0]
            scores = ctx.constrain(scores, "db_rows")
            return distributed_topk(scores, k, ctx)

        args = (jax.ShapeDtypeStruct((n, d), jnp.bfloat16),
                jax.ShapeDtypeStruct((1, d), jnp.float32))
        shard = (NamedSharding(mesh, ctx.pspec((n, d), "db_rows", None)),
                 NamedSharding(mesh, ctx.pspec((1, d))))
        return measure(jax.jit(fn, in_shardings=shard).lower(*args), "C.c1")

    # c2: RAE-reduced scan + full-space rerank (the paper's technique)
    rcfg = RAEConfig(in_dim=d, out_dim=m)

    def fn(corpus_full, corpus_red, w_e, q):
        zq = (q.astype(jnp.float32) @ w_e)
        s_red = corpus_red @ zq[0]
        s_red = ctx.constrain(s_red, "db_rows")
        _, cand = distributed_topk(s_red, 4 * k, ctx)  # stage 1 in R^m
        cvecs = jnp.take(corpus_full, cand, axis=0).astype(jnp.float32)
        s = cvecs @ q[0]                                # stage 2 rerank
        v, sel = jax.lax.top_k(s, k)
        return v, jnp.take(cand, sel)

    args = (jax.ShapeDtypeStruct((n, d), jnp.bfloat16),
            jax.ShapeDtypeStruct((n, m), jnp.bfloat16),
            jax.ShapeDtypeStruct((d, m), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32))
    shard = (NamedSharding(mesh, ctx.pspec((n, d), "db_rows", None)),
             NamedSharding(mesh, ctx.pspec((n, m), "db_rows", None)),
             NamedSharding(mesh, ctx.pspec((d, m))),
             NamedSharding(mesh, ctx.pspec((1, d))))
    return measure(jax.jit(fn, in_shardings=shard).lower(*args), "C.c2")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=["A", "B", "C"])
    ap.add_argument("--variant", required=True)
    args = ap.parse_args()
    {"A": cell_a, "B": cell_b, "C": cell_c}[args.cell](args.variant)


if __name__ == "__main__":
    main()
