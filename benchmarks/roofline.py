"""Three-term roofline analysis per (arch x shape x mesh) cell.

Terms (per device, TPU v5e targets):
    T_comp = FLOPs_dev / 197e12       (bf16 peak per chip)
    T_mem  = bytes_dev / 819e9        (HBM bandwidth per chip)
    T_coll = coll_bytes_dev / 50e9    (ICI per link)

FLOPs/bytes: ``compiled.cost_analysis()`` counts ``lax.scan`` bodies ONCE
(verified empirically — EXPERIMENTS.md §Dry-run), and LMs scan over layers,
so HLO counts undercount by ~n_layers. This module therefore computes
*analytic* FLOPs/bytes in closed form from the configs — counting what the
program actually executes (e.g. full masked-causal attention chunks, MoE
capacity slots including padding) — and cross-validates against an UNROLLED
lowering of the smallest LM (scripts in EXPERIMENTS.md §Roofline show
raw-vs-analytic agreement there). Collective bytes come from the compiled
HLO with while-loop trip-count multipliers (launch/hlo_analysis.py) — those
are loop-exact.

MODEL_FLOPS (the "useful work" yardstick): 6·N·D for dense training,
6·N_active·D for MoE, 2·N_active (+ exact attention term) per decoded token.
The ratio MODEL_FLOPS / ANALYTIC_FLOPS surfaces causal-mask waste, MoE
capacity padding and remat recompute.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Optional

PEAK_FLOPS = 197e12   # bf16 / chip
HBM_BW = 819e9        # bytes/s / chip
LINK_BW = 50e9        # bytes/s / ICI link

from repro.configs import get_arch, get_shapes  # noqa: E402
from repro.models.transformer.model import padded_vocab  # noqa: E402


def _mesh_devices(mesh: str) -> int:
    return 512 if mesh == "2x16x16" else 256


# ---------------------------------------------------------------------------
# Analytic FLOPs (global, per step) — counts executed ops, not ideal ops
# ---------------------------------------------------------------------------
def lm_flops(cfg, cell, mesh_devices: int) -> dict:
    v = padded_vocab(cfg)
    d, L = cfg.d_model, cfg.n_layers
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    qd, kvd = h * dh, kh * dh

    if cell.kind in ("train", "prefill"):
        b, s = cell.global_batch, cell.seq_len
        t = b * s
        proj = 2 * d * (qd + 2 * kvd + qd)                 # per token/layer
        attn = 4 * s * h * dh                              # full (masked) chunks
        # input-embedding rows are looked up, not matmul'd: exclude them from
        # the 6·N·D yardstick (the untied output head does execute)
        embed_discount = cfg.vocab_size * d * (1 if not cfg.tie_embeddings
                                               else 0)
        if cfg.family == "moe":
            cf = cfg.capacity_factor
            mlp = 2 * 3 * d * cfg.d_ff * cfg.moe_top_k * cf \
                + 2 * d * cfg.n_experts
            active = cfg.n_active_params() - embed_discount
        else:
            mlp = 2 * 3 * d * cfg.d_ff
            active = cfg.n_params() - embed_discount
        head = 2 * d * v
        fwd = t * (L * (proj + attn + mlp) + head)
        mult = 3.0 if cell.kind == "train" else 1.0        # bwd = 2x fwd
        model = (6.0 if cell.kind == "train" else 2.0) * active * t \
            + mult * t * L * 2 * s * h * dh                # causal-half attn
        return {"flops": mult * fwd, "model_flops": model}

    # decode: one token, cache length = cell.seq_len
    b, s = cell.global_batch, cell.seq_len
    proj = 2 * d * (qd + 2 * kvd + qd)
    attn = 4 * s * h * dh
    embed_discount = cfg.vocab_size * d * (1 if not cfg.tie_embeddings else 0)
    if cfg.family == "moe":
        # drop-free capacity C = t_loc: the grouped GEMM runs E x t_loc rows
        # per data shard -> E/topk x padding over ideal (flagged in §Perf)
        mlp = 2 * 3 * d * cfg.d_ff * cfg.n_experts + 2 * d * cfg.n_experts
        active = cfg.n_active_params() - embed_discount
    else:
        mlp = 2 * 3 * d * cfg.d_ff
        active = cfg.n_params() - embed_discount
    head = 2 * d * v
    fwd = b * (L * (proj + attn + mlp) + head)
    model = 2 * active * b + b * L * 4 * s * h * dh
    return {"flops": fwd, "model_flops": model}


def lm_bytes(cfg, cell, mesh_devices: int) -> float:
    """Per-device HBM traffic per step (closed form, documented terms)."""
    v = padded_vocab(cfg)
    p_total = cfg.n_params()
    p_local = p_total / mesh_devices * (16 / mesh_devices if False else 1)
    d, L = cfg.d_model, cfg.n_layers
    if cell.kind == "train":
        b, s = cell.global_batch, cell.seq_len
        t_dev = b * s / mesh_devices
        # weights: bf16 stack r/w once + gathered-read fwd, recompute, bwd (3x)
        w = p_total / mesh_devices * 2 * (1 + 3)
        # optimizer: fp32 master r/w + m/v (bf16) r/w + fp32 grads r/w
        opt = p_total / mesh_devices * (4 * 2 + 2 * 2 + 2 * 2 + 4 * 2)
        # activations: ~12 residual-width tensors per layer, x3 (fwd/rc/bwd)
        act = t_dev * d * L * 2 * 12 * 3
        # logits chunks: fwd+bwd reads of [t, V/shards]
        logits = t_dev * (v / min(16, mesh_devices)) * 2 * 3
        return w + opt + act + logits
    if cell.kind == "prefill":
        b, s = cell.global_batch, cell.seq_len
        t_dev = b * s / mesh_devices
        w = p_total / mesh_devices * 2
        act = t_dev * d * L * 2 * 12
        cache = (L * b * s * cfg.kv_dim * 2 * 2) / mesh_devices
        return w + act + cache
    # decode
    b, s = cell.global_batch, cell.seq_len
    w = p_total / mesh_devices * 2          # every (local) weight read once
    cache = (L * b * s * cfg.kv_dim * 2 * 2) / mesh_devices  # k+v read
    act = b * d * L * 2 * 12 / max(mesh_devices / 16, 1)
    return w + cache + act


def gnn_flops(cfg, cell, mesh_devices: int) -> dict:
    d_h = cfg.d_hidden
    if cell.kind == "full_graph":
        n, e, d0 = cell.n_nodes, cell.n_edges, cell.d_feat
        dims = [d0] + [d_h] * cfg.n_layers
        f = 0.0
        for i in range(cfg.n_layers):
            f += 2 * e * dims[i]                    # segment-sum adds
            f += 2 * n * 2 * dims[i] * dims[i + 1]  # concat-matmul
        f += 2 * n * d_h * cfg.n_classes
        return {"flops": 3 * f, "model_flops": 3 * f}
    if cell.kind == "minibatch":
        bsz = cell.batch_nodes
        f1, f2 = cell.fanout or cfg.sample_sizes
        d0 = cell.d_feat
        f = (bsz * f1 * f2 * d0                      # layer-2 means
             + bsz * (1 + f1) * 2 * 2 * d0 * d_h     # layer-1 matmuls
             + bsz * f1 * d_h                        # layer-2 mean
             + bsz * 2 * 2 * d_h * d_h
             + bsz * 2 * d_h * cfg.n_classes)
        return {"flops": 3 * f, "model_flops": 3 * f}
    # batched_graphs
    g, nn_, ne, d0 = (cell.graphs_per_batch, cell.n_nodes, cell.n_edges,
                      cell.d_feat)
    dims = [d0] + [d_h] * cfg.n_layers
    f = 0.0
    for i in range(cfg.n_layers):
        f += 2 * g * ne * dims[i]
        f += 2 * g * nn_ * 2 * dims[i] * dims[i + 1]
    f += 2 * g * d_h * cfg.n_classes
    return {"flops": 3 * f, "model_flops": 3 * f}


def gnn_bytes(cfg, cell, mesh_devices: int) -> float:
    if cell.kind == "full_graph":
        n, e, d0 = cell.n_nodes, cell.n_edges, cell.d_feat
        # gathered features per layer (all-gathered h on each device!) + edges
        per_dev = (n * d0 * 4 + n * cfg.d_hidden * 4 * (cfg.n_layers - 1)
                   + 2 * e / mesh_devices * (d0 + cfg.d_hidden) * 4
                   + 2 * e * 4 / mesh_devices)
        return per_dev * 3
    if cell.kind == "minibatch":
        bsz = cell.batch_nodes
        f1, f2 = cell.fanout or cfg.sample_sizes
        return bsz * (1 + f1 + f1 * f2) * cell.d_feat * 4 * 3 / mesh_devices
    g, nn_, ne = cell.graphs_per_batch, cell.n_nodes, cell.n_edges
    return g * (nn_ * cell.d_feat + ne * 8) * 4 * 3 / mesh_devices


def recsys_flops(cfg, cell, mesh_devices: int) -> dict:
    kind = cfg.kind
    b = cell.global_batch if cell.kind != "retrieval" else cell.n_candidates
    d = cfg.embed_dim

    def mlp_flops(dims, d_in):
        f, cur = 0.0, d_in
        for dd in dims:
            f += 2 * cur * dd
            cur = dd
        return f

    if kind == "bst":
        s = cfg.seq_len + 1
        blk = 2 * s * (4 * d * d) + 4 * s * s * d + 2 * s * (8 * d * d)
        f = b * (blk + mlp_flops(cfg.mlp_dims + (1,), d * s + 2 * d))
    elif kind == "two_tower":
        f = b * (mlp_flops(cfg.mlp_dims, 2 * d) + mlp_flops(cfg.mlp_dims, d))
        if cell.kind == "train":
            f += 2 * b * b * cfg.mlp_dims[-1]  # in-batch logits
        if cell.kind == "retrieval":
            f = cell.n_candidates * mlp_flops(cfg.mlp_dims, d) \
                + mlp_flops(cfg.mlp_dims, 2 * d) \
                + 2 * cell.n_candidates * cfg.mlp_dims[-1]
    elif kind == "autoint":
        nf, da = cfg.n_fields, cfg.d_attn
        per = 0.0
        d_in = d
        for _ in range(cfg.n_attn_layers):
            per += 2 * nf * (3 * d_in * da + d_in * da) + 4 * nf * nf * da
            d_in = da
        f = b * (per + 2 * nf * da)
    else:  # mind
        L = cfg.hist_len
        k = cfg.n_interests
        per = 2 * L * d * d + cfg.capsule_iters * (2 * L * k * d * 2) \
            + k * mlp_flops(cfg.mlp_dims, d)
        f = b * per if cell.kind != "retrieval" else (
            cell.n_candidates * (2 * d * d + mlp_flops(cfg.mlp_dims, d)
                                 + 2 * k * cfg.mlp_dims[-1]) + per)
    mult = 3.0 if cell.kind == "train" else 1.0
    return {"flops": mult * f, "model_flops": mult * f}


def recsys_bytes(cfg, cell, mesh_devices: int) -> float:
    b = cell.global_batch if cell.kind != "retrieval" else cell.n_candidates
    d = cfg.embed_dim
    lookups = {"bst": cfg.seq_len + 3, "two_tower": 2 + cfg.hist_len,
               "autoint": cfg.n_fields, "mind": cfg.hist_len + 1}[cfg.kind]
    emb = b * lookups * d * 4
    act = b * d * 16 * 4
    mult = 3.0 if cell.kind == "train" else 1.0
    if cell.kind == "train":
        # optimizer touches every table row (dense Adam on tables)
        emb += cfg.n_params() * 16 / mult  # counted once, not x3
    return mult * (emb + act) / mesh_devices


# ---------------------------------------------------------------------------
# Table assembly
# ---------------------------------------------------------------------------
@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    t_comp: float
    t_mem: float
    t_coll: float
    dominant: str
    model_flops: float
    analytic_flops: float
    hlo_flops_dev: float
    useful_ratio: float
    peak_gib: float
    util_vs_dominant: float

    def as_dict(self):
        return self.__dict__.copy()


def analyze_record(rec: dict) -> Optional[RooflineRow]:
    if not rec.get("ok"):
        return None
    cfg, family = get_arch(rec["arch"])
    cell = {c.name: c for c in get_shapes(rec["arch"])}[rec["shape"]]
    ndev = _mesh_devices(rec["mesh"])
    if family == "lm":
        fl = lm_flops(cfg, cell, ndev)
        by = lm_bytes(cfg, cell, ndev)
    elif family == "gnn":
        fl = gnn_flops(cfg, cell, ndev)
        by = gnn_bytes(cfg, cell, ndev)
    else:
        fl = recsys_flops(cfg, cell, ndev)
        by = recsys_bytes(cfg, cell, ndev)
    flops_dev = fl["flops"] / ndev
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = by / HBM_BW
    coll = rec["collectives_bytes"].get("total", 0.0)
    t_coll = coll / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_dom = terms[dominant]
    useful = fl["model_flops"] / max(fl["flops"], 1.0)
    util = (fl["model_flops"] / ndev / PEAK_FLOPS) / max(t_dom, 1e-30)
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        t_comp=t_comp, t_mem=t_mem, t_coll=t_coll, dominant=dominant,
        model_flops=fl["model_flops"], analytic_flops=fl["flops"],
        hlo_flops_dev=rec["cost"]["hlo_flops_per_device"],
        useful_ratio=useful,
        peak_gib=rec["memory"]["peak_est_bytes"] / 2**30,
        util_vs_dominant=util)


def build_table(dryrun_json: str) -> list[RooflineRow]:
    rows = []
    for rec in json.load(open(dryrun_json)):
        r = analyze_record(rec)
        if r is not None:
            rows.append(r)
    return rows


def format_markdown(rows: list[RooflineRow]) -> str:
    out = ["| arch | shape | mesh | T_comp (ms) | T_mem (ms) | T_coll (ms) | "
           "bound | useful/executed | roofline util | peak GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.t_comp*1e3:.2f} | "
            f"{r.t_mem*1e3:.2f} | {r.t_coll*1e3:.2f} | {r.dominant} | "
            f"{r.useful_ratio:.2f} | {r.util_vs_dominant:.2f} | "
            f"{r.peak_gib:.2f} |")
    return "\n".join(out)


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.json")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--markdown", default="results/roofline.md")
    args = ap.parse_args(argv)
    rows = build_table(args.dryrun)
    json.dump([r.as_dict() for r in rows], open(args.out, "w"), indent=1)
    md = format_markdown(rows)
    open(args.markdown, "w").write(md + "\n")
    print(md)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
