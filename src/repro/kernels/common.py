"""Shared pad-sentinel convention for every kernel triple.

Every fused scan/traversal kernel in this package pads — short candidate
lists, k > N, masked adjacency slots, pow2 row pads — and every pad slot
must look the same on the way out: score ``NEG_INF``, id ``PAD_ID``. The
serving cache compares results byte-for-byte across batch sizes and the
two-stage rerank pins pad slots by id, so two kernels disagreeing on the
sentinel (or one drifting to ``-inf`` vs ``-1e30``) is a correctness bug,
not a cosmetic one.

This module is the single definition site. Kernel modules import from
here; ``scripts/lint.py`` (the ``kernel-contract`` checker) rejects any
module under ``repro.kernels`` that re-defines ``NEG_INF`` or spells the
raw ``1e30`` literal.

``NEG_INF`` is a large finite negative instead of ``-inf`` because the
branchless top-k merges run max/argmax sweeps over candidate tiles on the
VPU: with ``-inf`` candidates, a padded tile produces ``inf - inf = nan``
in the ``2qv - v^2 - q^2`` distance form the kernels use, and bf16 inputs
overflow to ``-inf`` earlier than f32. A finite sentinel keeps every
lane's arithmetic defined while still losing every comparison against a
real score.
"""
from __future__ import annotations

#: Pad-slot score: loses every max/merge against any real similarity.
NEG_INF = -1e30

#: Pad-slot id (FAISS convention: index -1 = "no result in this slot").
PAD_ID = -1

#: Additive distance penalty for padded *rows* in positive-distance forms
#: (ops-layer row padding: a padded db/code row must never win the scan).
PAD_PENALTY = 1e30


def canonicalize_pads(vals, ids):
    """Pin every pad slot of a merged (vals, ids) pair to the canonical
    ``(NEG_INF, PAD_ID)`` sentinel, numpy or jax alike.

    Pad slots are identified by ``ids < 0`` — the one invariant every
    producer (beam merge, probe scan, k > N tail) already guarantees.
    Works on numpy and jax arrays (dispatches on the module of ``vals``);
    numpy inputs are canonicalized in place and returned.
    """
    import numpy as np

    if isinstance(vals, np.ndarray):
        vals[ids < 0] = NEG_INF
        return vals, ids
    import jax.numpy as jnp

    return jnp.where(ids < 0, NEG_INF, vals), ids
