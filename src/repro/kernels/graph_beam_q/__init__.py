from .ops import graph_beam_q

__all__ = ["graph_beam_q"]
