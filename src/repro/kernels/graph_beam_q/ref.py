"""Vectorized numpy oracle for the quantized gather+score+beam-merge hop.

Like ``graph_beam/ref.py`` this is deliberately numpy, not jnp: off-TPU
the quantized batched HNSW traversal is a host-driven hop loop and this
ref IS the production path — a jitted jnp ref would pay one dispatch per
hop. Per-row determinism matters for the serving cache (a query answers
identically at q=1 and inside a coalesced batch): gather, contraction and
stable argsort all reduce row-by-row with no cross-row reassociation.

The hop is codec-agnostic by design. Both supported payloads reduce to
"contract a per-query operand against the gathered code row, then shift
by per-query / per-node constants"::

    score[q, w] = contract(q_op[q], codes[id]) + q_bias[q] - node_bias[id]

* ``mode="sq8"`` — dequant-free asymmetric L2 (the ``sq8_scan`` form from
  ``repro.search.quantize``): callers pass ``q_op = 2 q * step``,
  ``q_bias = 2 q . vmin - ||q||^2`` and ``node_bias = ||decode(c)||^2``,
  so the contraction is a plain dot against the raw uint8 codes and the
  score comes out as ``-||q - decode(c)||^2`` without ever materializing
  a dequantized row.
* ``mode="pq"`` — ADC: callers pass ``q_op`` = the NEGATED per-query LUT
  (``-adc_lut(codebooks, q)`` flattened to ``[Q, m*ksub]``) and zero
  biases; the contraction sums ``m`` LUT entries selected by the code
  row, yielding ``-ADC distance``. ``ksub`` names the LUT stride (the
  codebook width, which may be < 2**bits on tiny corpora).
"""
from __future__ import annotations

import numpy as np

from ..common import NEG_INF, canonicalize_pads


def graph_beam_q_ref(q_op: np.ndarray, q_bias: np.ndarray,
                     codes: np.ndarray, node_bias: np.ndarray,
                     nbr_ids: np.ndarray, beam_v: np.ndarray,
                     beam_i: np.ndarray, db_mask: np.ndarray | None = None,
                     mode: str = "sq8", ksub: int = 0
                     ) -> tuple[np.ndarray, np.ndarray]:
    """One batched quantized beam hop: score candidate ids against code
    payloads and merge into the beam.

    q_op [Q, Dop] f32 per-query operand (sq8: Dop = d; pq: Dop = m*ksub);
    q_bias [Q] f32; codes [N, C] uint8 stored payload (sq8: C = d; pq:
    C = m); node_bias [N] f32 per-node constant (sq8: recon ||.||^2; pq:
    zeros); nbr_ids [Q, W] int32 with -1 = masked slot; beam_v/beam_i
    [Q, ef] the running beam, sorted descending. ``db_mask`` (bool [N])
    tombstones code rows: a masked candidate is treated exactly like a
    -1 slot, so a deleted row can never enter the beam. Returns the merged
    (values, ids), ef wide, sorted descending, pads canonicalized to
    (NEG_INF, -1) — identical merge semantics (stable ties toward the
    beam, then lower candidate slot) to ``graph_beam_ref``, so the f32
    and quantized hops are drop-in interchangeable for the traversal.
    """
    if mode not in ("sq8", "pq"):
        raise ValueError(f"graph_beam_q: mode must be 'sq8' or 'pq', "
                         f"got {mode!r}")
    if mode == "pq" and ksub < 1:
        raise ValueError("graph_beam_q: pq mode needs ksub >= 1 (the LUT "
                         "stride)")
    q_op = np.asarray(q_op, np.float32)
    q_bias = np.asarray(q_bias, np.float32)
    codes = np.asarray(codes)
    nb = np.asarray(node_bias, np.float32)
    ids = np.asarray(nbr_ids, np.int32)
    bv = np.asarray(beam_v, np.float32)
    bi = np.asarray(beam_i, np.int32)
    ef = bv.shape[1]
    valid = ids >= 0
    safe = np.where(valid, ids, 0)
    if db_mask is not None:
        valid = valid & np.asarray(db_mask, bool)[safe]
    g = codes[safe]                                      # [Q, W, C]
    if mode == "sq8":
        if q_op.shape[1] != codes.shape[1]:
            raise ValueError(f"graph_beam_q: sq8 operand dim "
                             f"{q_op.shape[1]} != code dim {codes.shape[1]}")
        s = np.einsum("qwd,qd->qw", g.astype(np.float32), q_op)
    else:
        m = codes.shape[1]
        if q_op.shape[1] != m * ksub:
            raise ValueError(f"graph_beam_q: pq operand dim {q_op.shape[1]}"
                             f" != m*ksub = {m * ksub}")
        offs = g.astype(np.int64) + np.arange(m, dtype=np.int64) * ksub
        rq = np.arange(q_op.shape[0])[:, None, None]
        s = q_op[rq, offs].sum(-1)                       # [Q, W]
    s = (s + q_bias[:, None] - nb[safe]).astype(np.float32, copy=False)
    s[~valid] = NEG_INF
    allv = np.concatenate([bv, s], axis=1)
    alli = np.concatenate([bi, np.where(valid, ids, -1)], axis=1)
    order = np.argsort(-allv, axis=1, kind="stable")[:, :ef]
    rr = np.arange(bv.shape[0])[:, None]
    out_v = allv[rr, order]
    out_i = alli[rr, order]
    return canonicalize_pads(out_v, out_i)
