"""Public wrapper: platform dispatch + row padding for the quantized hop.

Same shape as ``graph_beam/ops.py``: the off-TPU path is *pure numpy*
(the batched traversal calls this once per hop from a host-driven loop;
a jit dispatch per hop would dominate), the pallas path is jitted and
pads the query-row count to a power of two (ids -1, beams -inf) so the
shrinking live-row count hits a handful of compile-cache entries.

One codec-specific chore lives here: stored codes are uint8 (that is the
payload whose size the whole tier exists to shrink), but the TPU kernel
gathers (1, C) blocks and sub-byte/int8 tiling is not worth fighting for
a C-wide row — the pallas path widens codes to int32 on device (same
convention as ``pq_adc``, whose kernel also takes int32 codes). The
numpy ref reads the uint8 array directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import NEG_INF, graph_beam_q_pallas
from .ref import graph_beam_q_ref


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@functools.partial(jax.jit, static_argnames=("mode", "ksub", "interpret"))
def _pallas_padded(q_op, q_bias, codes, node_bias, nbr_ids, beam_v, beam_i,
                   mode, ksub, interpret):
    return graph_beam_q_pallas(q_op, q_bias, codes.astype(jnp.int32),
                               node_bias, nbr_ids, beam_v, beam_i,
                               mode=mode, ksub=ksub, interpret=interpret)


def graph_beam_q(q_op, q_bias, codes, node_bias, nbr_ids, beam_v, beam_i,
                 db_mask=None, mode: str = "sq8", ksub: int = 0,
                 impl: str = "auto", interpret: bool = False
                 ) -> tuple[np.ndarray, np.ndarray]:
    """One fused quantized traversal hop: gather ``nbr_ids`` rows of the
    stored ``codes``, score them via the unified affine form
    ``contract(q_op, code_row) + q_bias - node_bias`` (SQ8 dequant-free
    asymmetric L2 / PQ negated-ADC-LUT — see ``ref.py`` for the operand
    contracts), and merge into the running ``(beam_v, beam_i)`` top-ef
    beam.

    q_op [Q, Dop] f32; q_bias [Q] f32; codes [N, C] uint8; node_bias [N]
    f32; nbr_ids [Q, W] int32, -1 = masked; beam_v/beam_i [Q, ef] sorted
    descending. ``mode`` = "sq8" | "pq" (``ksub`` = LUT stride, pq only).
    ``db_mask`` (bool [N]) tombstones code rows: masked candidate ids are
    demoted to -1 before the hop so a deleted row never enters the beam.
    Returns the merged beam (numpy), sorted descending, pads at the tail
    — byte-compatible with ``graph_beam``'s output, so the traversal
    drivers swap the two hops freely.
    """
    if mode not in ("sq8", "pq"):
        raise ValueError(f"graph_beam_q: mode must be 'sq8' or 'pq', "
                         f"got {mode!r}")
    if mode == "pq" and ksub < 1:
        raise ValueError("graph_beam_q: pq mode needs ksub >= 1 (the LUT "
                         "stride)")
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "np"
    if impl == "np":
        return graph_beam_q_ref(q_op, q_bias, codes, node_bias, nbr_ids,
                                beam_v, beam_i, db_mask, mode, ksub)
    if db_mask is not None:
        # demote tombstoned candidates to pad slots pre-kernel (same
        # convention as graph_beam): no mask operand inside the kernel
        ids_np = np.asarray(nbr_ids, np.int32)
        safe = np.where(ids_np >= 0, ids_np, 0)
        nbr_ids = np.where((ids_np >= 0) & np.asarray(db_mask, bool)[safe],
                           ids_np, -1)
    qo = jnp.asarray(q_op, jnp.float32)
    qb = jnp.asarray(q_bias, jnp.float32)
    nq = qo.shape[0]
    pad = _next_pow2(nq) - nq
    ids = jnp.asarray(nbr_ids, jnp.int32)
    bv = jnp.asarray(beam_v, jnp.float32)
    bi = jnp.asarray(beam_i, jnp.int32)
    if pad:
        qo = jnp.pad(qo, ((0, pad), (0, 0)))
        qb = jnp.pad(qb, ((0, pad),))
        ids = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
        bv = jnp.pad(bv, ((0, pad), (0, 0)), constant_values=NEG_INF)
        bi = jnp.pad(bi, ((0, pad), (0, 0)), constant_values=-1)
    vals, idx = _pallas_padded(qo, qb, jnp.asarray(codes),
                               jnp.asarray(node_bias, jnp.float32), ids, bv,
                               bi, mode, ksub, interpret)
    return np.asarray(vals[:nq]), np.asarray(idx[:nq])
