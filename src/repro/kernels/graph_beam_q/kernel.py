"""Quantized gather + asymmetric-score + beam-merge Pallas TPU kernel.

The quantized twin of ``graph_beam/kernel.py``: one HNSW hop whose
neighbor gather reads stored *codes* (SQ8 or PQ payloads) instead of f32
corpus rows — at d=64 that is 68 gathered bytes per neighbor for SQ8 and
12 for PQ8x8 versus 260 for the f32 row+norm, which is the whole point:
at million-vector scale the hop is bandwidth-bound on exactly this DMA.

Same house idioms as the f32 hop, plus the codec algebra:

* *scalar-prefetch gather*: neighbor ids prefetched into SMEM drive the
  code-row BlockSpec index map, so each grid step DMAs exactly one code
  row HBM->VMEM — the [Q, W, C] gather never exists;
* scoring is the unified affine form ``contract(q_op, code_row) +
  q_bias - node_bias`` (see ``ref.py``): SQ8 contracts the pre-scaled
  query against the raw codes (dequant-free asymmetric L2, the
  ``sq8_scan`` rearrangement); PQ contracts the per-query negated ADC
  LUT against a one-hot expansion of the code row — the same
  iota-compare one-hot-matmul gather as ``pq_adc`` (TPUs have no fast
  arbitrary gather; they do have an MXU);
* the beam merge reuses ``l2_topk``'s branchless ``_topk_update``;
  masked slots (id -1) score ``NEG_INF`` and keep their -1 id.

``mode``/``ksub`` are static: the sq8/pq branch is resolved at trace
time, so each compiled kernel contains exactly one scoring form.

Grid (Q, W), neighbor-slot axis innermost: TPU grids iterate
sequentially, so the per-query candidate scratch accumulates across the
W sweep and the merge runs once per query on the last slot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import NEG_INF
from ..l2_topk.kernel import _set_col, _topk_update


def _kernel(safe_ref, raw_ref, qop_ref, qb_ref, code_ref, nb_ref, bv_ref,
            bi_ref, vout_ref, iout_ref, cv_ref, ci_ref, *, w_slots: int,
            ef: int, mode: str, ksub: int):
    i = pl.program_id(0)
    w = pl.program_id(1)
    raw = raw_ref[i * w_slots + w]
    qop = qop_ref[...].astype(jnp.float32)               # [1, Dop]
    c = code_ref[...]                                    # [1, C] int32
    if mode == "sq8":
        contrib = jnp.sum(qop * c.astype(jnp.float32))
    else:
        # one-hot row [m, ksub]: oh[mm, j] = (codes[mm] == j); contracting
        # it against the flat LUT operand IS the per-subspace LUT gather
        m = c.shape[1]
        oh = (c.reshape(m, 1)
              == jax.lax.broadcasted_iota(jnp.int32, (m, ksub), 1))
        contrib = jnp.sum(qop * oh.astype(jnp.float32).reshape(1, m * ksub))
    s = contrib + qb_ref[0] - nb_ref[0]
    s = jnp.where(raw < 0, NEG_INF, s)
    cv_ref[...] = _set_col(cv_ref[...], w, s.reshape(1))
    ci_ref[...] = _set_col(ci_ref[...], w, raw.reshape(1))

    @pl.when(w == w_slots - 1)
    def _():
        nv, ni = _topk_update(bv_ref[...].astype(jnp.float32), bi_ref[...],
                              cv_ref[...], ci_ref[...], ef)
        # exhausted slots re-pick the first NEG_INF tie; canonicalize them
        # to (NEG_INF, -1) exactly like the ref
        ni = jnp.where(nv <= NEG_INF, -1, ni)
        nv = jnp.where(ni >= 0, nv, NEG_INF)
        vout_ref[...] = nv
        iout_ref[...] = ni


def graph_beam_q_pallas(q_op: jax.Array, q_bias: jax.Array, codes: jax.Array,
                        node_bias: jax.Array, nbr_ids: jax.Array,
                        beam_v: jax.Array, beam_i: jax.Array, *, mode: str,
                        ksub: int = 0, interpret: bool = False
                        ) -> tuple[jax.Array, jax.Array]:
    """q_op [Q, Dop] f32, q_bias [Q] f32, codes [N, C] int32 (ops.py
    widens the stored uint8 — TPU tiling), node_bias [N] f32, nbr_ids
    [Q, W] int32 (-1 = masked), beam_v/beam_i [Q, ef]. Returns the merged
    beam, sorted descending. ``ops.py`` pads Q; W and ef ride as-is
    (sub-tile blocks, same as the f32 hop)."""
    qn, dop = q_op.shape
    cw = codes.shape[1]
    w_slots = nbr_ids.shape[1]
    ef = beam_v.shape[1]
    ids = nbr_ids.reshape(-1)
    safe = jnp.clip(ids, 0, codes.shape[0] - 1)
    kernel = functools.partial(_kernel, w_slots=w_slots, ef=ef, mode=mode,
                               ksub=ksub)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # clamped ids (drive the DMA) + raw ids
        grid=(qn, w_slots),
        in_specs=[
            pl.BlockSpec((1, dop), lambda i, w, safe, raw: (i, 0)),
            pl.BlockSpec((1,), lambda i, w, safe, raw: (i,)),
            # one code row + its bias per grid step, id-selected
            pl.BlockSpec((1, cw),
                         lambda i, w, safe, raw: (safe[i * w_slots + w], 0)),
            pl.BlockSpec((1,),
                         lambda i, w, safe, raw: (safe[i * w_slots + w],)),
            pl.BlockSpec((1, ef), lambda i, w, safe, raw: (i, 0)),
            pl.BlockSpec((1, ef), lambda i, w, safe, raw: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ef), lambda i, w, safe, raw: (i, 0)),
            pl.BlockSpec((1, ef), lambda i, w, safe, raw: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, w_slots), jnp.float32),
            pltpu.VMEM((1, w_slots), jnp.int32),
        ],
    )
    vals, idx = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, ef), jnp.float32),
            jax.ShapeDtypeStruct((qn, ef), jnp.int32),
        ],
        interpret=interpret,
    )(safe, ids, q_op, q_bias, codes, node_bias, beam_v, beam_i)
    return vals, idx
