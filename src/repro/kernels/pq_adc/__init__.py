from .ops import *  # noqa
