from .ops import pq_adc

__all__ = ["pq_adc"]
