"""Fused PQ ADC Pallas TPU kernel: LUT build + code gather + online top-k.

The ADC scan is the hot path of a PQ index: per query build an [m, ksub]
LUT of exact query-to-centroid distances, then for every stored code sum m
LUT entries and keep the running top-k. Three fusions keep it on-chip:

* the LUT is built ONCE per query block (first DB-tile step) from the query
  tile and the full codebooks — both resident in VMEM — and parked in VMEM
  scratch for the whole N sweep;
* the gather is reformulated as a one-hot matmul: a [bn, m*ksub] 0/1 matrix
  built from the code tile by iota-compare, contracted against the flat LUT
  on the MXU — TPUs have no fast arbitrary gather, but they do have a
  128x128 systolic array (same trick as embedding lookups via one-hot);
* the per-query running top-k reuses the branchless iterative max-mask
  merge of ``l2_topk`` (heaps don't vectorize; k max-reductions do).

Grid (Q/bq, N/bn), DB-tile axis innermost — TPU grids iterate sequentially,
so LUT + top-k scratch carry across the N sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import NEG_INF
from ..l2_topk.kernel import _topk_update


def _kernel(q_ref, cb_ref, codes_ref, pen_ref, vals_ref, idx_ref,
            lut_ref, acc_v, acc_i, *, k: int, m: int, ksub: int, dsub: int,
            bn: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_v[...] = jnp.full_like(acc_v, NEG_INF)
        acc_i[...] = jnp.full_like(acc_i, -1)
        q = q_ref[...]                                  # [bq, m*dsub]
        for mm in range(m):                             # m is small + static
            qs = q[:, mm * dsub:(mm + 1) * dsub]        # [bq, dsub]
            cbm = cb_ref[mm * ksub:(mm + 1) * ksub, :]  # [ksub, dsub]
            lut_m = (jnp.sum(qs * qs, 1)[:, None]
                     - 2.0 * jnp.dot(qs, cbm.T,
                                     preferred_element_type=jnp.float32)
                     + jnp.sum(cbm * cbm, 1)[None, :])
            lut_ref[:, mm * ksub:(mm + 1) * ksub] = lut_m

    codes = codes_ref[...]                              # [bn, m] int32
    # one-hot [bn, m*ksub]: oh[n, mm*ksub + c] = (codes[n, mm] == c)
    oh = (codes[:, :, None]
          == jax.lax.broadcasted_iota(jnp.int32, (bn, m, ksub), 2))
    oh = oh.astype(jnp.float32).reshape(bn, m * ksub)
    dist = jnp.dot(lut_ref[...], oh.T,
                   preferred_element_type=jnp.float32)  # [bq, bn]
    s = -dist - pen_ref[...][None, :]
    cand_i = j * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    nv, ni = _topk_update(acc_v[...], acc_i[...], s, cand_i, k)
    acc_v[...] = nv
    acc_i[...] = ni

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        vals_ref[...] = acc_v[...]
        idx_ref[...] = acc_i[...]


def pq_adc_pallas(queries: jax.Array, codebooks_flat: jax.Array,
                  codes: jax.Array, penalty: jax.Array, k: int, *,
                  m: int, ksub: int, dsub: int, bq: int = 128, bn: int = 512,
                  interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """queries [Q, m*dsub] f32, codebooks_flat [m*ksub, dsub] f32, codes
    [N, m] int32, penalty [N] f32 (1e30 on padded rows so they never win).
    Q % bq == 0 and N % bn == 0 (ops.py pads)."""
    qn, d = queries.shape
    n = codes.shape[0]
    grid = (qn // bq, n // bn)
    kernel = functools.partial(_kernel, k=k, m=m, ksub=ksub, dsub=dsub, bn=bn)
    vals, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((m * ksub, dsub), lambda i, j: (0, 0)),
            pl.BlockSpec((bn, m), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, m * ksub), jnp.float32),
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, codebooks_flat, codes, penalty)
    return vals, idx
