"""Pure-jnp oracle for the fused PQ ADC scan (LUT build + gather + top-k)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_adc_ref(queries: jax.Array, codebooks: jax.Array, codes: jax.Array,
               k: int) -> tuple[jax.Array, jax.Array]:
    """ADC top-k. queries [Q, d] (d = m * dsub), codebooks [m, ksub, dsub],
    codes [N, m] integer. Returns (scores [Q, k], indices [Q, k]); scores
    are negative squared asymmetric distances (higher = closer), i.e.
    ``-||q - decode(codes)||^2`` computed through the LUT, never through a
    materialized reconstruction."""
    q = queries.astype(jnp.float32)
    cb = codebooks.astype(jnp.float32)
    m, ksub, dsub = cb.shape
    qn = q.shape[0]
    n = codes.shape[0]
    qs = q.reshape(qn, m, dsub)
    lut = (jnp.sum(qs * qs, -1)[:, :, None]
           - 2 * jnp.einsum("qms,mjs->qmj", qs, cb)
           + jnp.sum(cb * cb, -1)[None, :, :])          # [Q, m, ksub]
    lut_flat = lut.reshape(qn, m * ksub)
    offs = (codes.astype(jnp.int32)
            + (jnp.arange(m, dtype=jnp.int32) * ksub)[None, :])  # [N, m]
    g = jnp.take(lut_flat, offs.reshape(-1), axis=1)    # [Q, N*m]
    dist = g.reshape(qn, n, m).sum(-1)
    return jax.lax.top_k(-dist, k)
