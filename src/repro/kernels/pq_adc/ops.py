"""Jit'd public wrapper: platform dispatch + padding + k-overflow handling."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import PAD_PENALTY
from .kernel import pq_adc_pallas
from .ref import pq_adc_ref


def _pad_rows(x, mult):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, pad


@functools.partial(jax.jit,
                   static_argnames=("k", "impl", "bq", "bn", "interpret"))
def pq_adc(queries: jax.Array, codebooks: jax.Array, codes: jax.Array,
           k: int, impl: str = "auto", bq: int = 128, bn: int = 512,
           interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Fused PQ ADC top-k scan.

    queries [Q, d] (d = m * dsub), codebooks [m, ksub, dsub], codes [N, m]
    integer. Returns (scores [Q, k], indices [Q, k]); scores are negative
    squared asymmetric distances (higher = closer). ``k > N`` is legal: the
    tail pads with score -inf / index -1 (FAISS convention, matching the
    IVF tiers).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    q = queries.astype(jnp.float32)
    cb = codebooks.astype(jnp.float32)
    m, ksub, dsub = cb.shape
    if q.shape[1] != m * dsub:
        raise ValueError(f"pq_adc: query dim {q.shape[1]} != m*dsub "
                         f"({m}*{dsub})")
    n = codes.shape[0]
    k_eff = min(k, n)
    if impl == "ref":
        vals, idx = pq_adc_ref(q, cb, codes, k_eff)
    else:
        qp, _ = _pad_rows(q, bq)
        cp, npad = _pad_rows(codes.astype(jnp.int32), bn)
        penalty = jnp.where(jnp.arange(cp.shape[0]) < n, 0.0, PAD_PENALTY)
        vals, idx = pq_adc_pallas(qp, cb.reshape(m * ksub, dsub), cp,
                                  penalty.astype(jnp.float32), k_eff,
                                  m=m, ksub=ksub, dsub=dsub, bq=bq, bn=bn,
                                  interpret=interpret)
        vals = vals[: q.shape[0]]
        idx = idx[: q.shape[0]]
    if k_eff < k:
        pad = k - k_eff
        vals = jnp.concatenate(
            [vals, jnp.full((vals.shape[0], pad), -jnp.inf, vals.dtype)], 1)
        idx = jnp.concatenate(
            [idx, jnp.full((idx.shape[0], pad), -1, idx.dtype)], 1)
    return vals, idx
