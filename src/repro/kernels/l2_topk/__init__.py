from .ops import l2_topk

__all__ = ["l2_topk"]
