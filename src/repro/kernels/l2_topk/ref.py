"""Pure-jnp oracle for the fused distance+top-k scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import NEG_INF, PAD_ID, canonicalize_pads


def l2_topk_ref(queries: jax.Array, db: jax.Array, k: int,
                metric: str = "euclidean", db_mask: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """Exact k-NN scores/indices. Scores are similarities (higher = closer):
    euclidean -> negative squared distance; cosine -> cosine similarity on
    pre-normalized inputs (the caller normalizes). ``db_mask`` (bool [N])
    tombstones rows: masked rows never appear in the result — their slots
    come back as (NEG_INF, -1) when fewer than k rows survive."""
    q = queries.astype(jnp.float32)
    d = db.astype(jnp.float32)
    if metric == "euclidean":
        s = 2.0 * q @ d.T - jnp.sum(d * d, -1)[None, :] \
            - jnp.sum(q * q, -1)[:, None]
    elif metric == "cosine":
        s = q @ d.T
    else:
        raise ValueError(metric)
    if db_mask is None:
        return jax.lax.top_k(s, k)
    s = jnp.where(db_mask[None, :], s, NEG_INF)
    vals, idx = jax.lax.top_k(s, k)
    idx = jnp.where(vals <= NEG_INF / 2, PAD_ID, idx)
    return canonicalize_pads(vals, idx)
