"""Pure-jnp oracle for the fused distance+top-k scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_topk_ref(queries: jax.Array, db: jax.Array, k: int,
                metric: str = "euclidean") -> tuple[jax.Array, jax.Array]:
    """Exact k-NN scores/indices. Scores are similarities (higher = closer):
    euclidean -> negative squared distance; cosine -> cosine similarity on
    pre-normalized inputs (the caller normalizes)."""
    q = queries.astype(jnp.float32)
    d = db.astype(jnp.float32)
    if metric == "euclidean":
        s = 2.0 * q @ d.T - jnp.sum(d * d, -1)[None, :] \
            - jnp.sum(q * q, -1)[:, None]
    elif metric == "cosine":
        s = q @ d.T
    else:
        raise ValueError(metric)
    return jax.lax.top_k(s, k)
