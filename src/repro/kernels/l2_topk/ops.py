"""Jit'd public wrapper: platform dispatch + padding + metric handling."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import NEG_INF, PAD_ID, PAD_PENALTY
from .kernel import l2_topk_pallas
from .ref import l2_topk_ref


def _pad_rows(x, mult):
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, pad


@functools.partial(jax.jit,
                   static_argnames=("k", "metric", "impl", "bq", "bn",
                                    "interpret"))
def l2_topk(queries: jax.Array, db: jax.Array, k: int,
            metric: str = "euclidean", db_mask: jax.Array | None = None,
            impl: str = "auto", bq: int = 128, bn: int = 512,
            interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Fused exact top-k scan. Returns (scores [Q, k], indices [Q, k]);
    scores are similarities (euclidean -> -||q-d||^2, cosine -> cos sim).
    ``db_mask`` (bool [N]) tombstones db rows: a masked row never appears
    in the output, its slot canonicalizes to ``(NEG_INF, PAD_ID)``."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    q = queries.astype(jnp.float32)
    d = db.astype(jnp.float32)
    if metric == "cosine":
        q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        d = d / jnp.maximum(jnp.linalg.norm(d, axis=-1, keepdims=True), 1e-12)
    if impl == "ref":
        return l2_topk_ref(q, d, k, metric, db_mask)

    qp, qpad = _pad_rows(q, bq)
    dp, dpad = _pad_rows(d, bn)
    if metric == "euclidean":
        d_sq = jnp.sum(dp * dp, axis=-1)
    else:  # cosine on normalized vectors = euclidean order; reuse the kernel
        d_sq = jnp.sum(dp * dp, axis=-1)
    if dpad:  # padded rows must never win
        n_real = d.shape[0]
        d_sq = jnp.where(jnp.arange(dp.shape[0]) < n_real, d_sq, PAD_PENALTY)
    if db_mask is not None:
        # tombstoned rows ride the same never-wins lane as the row pads
        mp, _ = _pad_rows(db_mask, bn)
        d_sq = jnp.where(mp[: dp.shape[0]], d_sq, PAD_PENALTY)
    vals, idx = l2_topk_pallas(qp, dp, d_sq, k, bq=bq, bn=bn,
                               interpret=interpret)
    vals = vals[: q.shape[0]]
    idx = idx[: q.shape[0]]
    if metric == "euclidean":
        vals = vals - jnp.sum(q * q, axis=-1, keepdims=True)
    else:
        # kernel computed 2 q·d - ||d||^2 with ||d||=1 -> cos = (v + 1) / 2
        vals = (vals + 1.0) / 2.0
    if db_mask is not None:
        # canonicalize slots the penalty lane produced (post score remap)
        dead = vals <= NEG_INF / 2
        vals = jnp.where(dead, NEG_INF, vals)
        idx = jnp.where(dead, PAD_ID, idx)
    return vals, idx
