"""Fused distance + online top-k Pallas TPU kernel (the retrieval hot path).

TPU adaptation of the FAISS CPU scan (DESIGN.md §4): the database is tiled
into VMEM blocks; Q·Dᵀ runs on the MXU; the per-query running top-k lives in
VMEM scratch and is maintained with a *branchless iterative max-mask* pass
(k sweeps over the candidate tile — heaps don't vectorize, k max-reductions
do). Streaming across DB tiles mirrors FlashAttention's online softmax, but
the merged statistic is a top-k set instead of (m, l).

Grid: (Q/bq, N/bn) with the DB-tile axis innermost (TPU grids iterate
sequentially, so the scratch carry is valid across the N sweep).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import NEG_INF


def _topk_update(run_v, run_i, cand_v, cand_i, k: int):
    """Merge [bq, k] running with [bq, bn] candidates -> new [bq, k].
    Branchless: k sweeps of (max, argmax, mask) over the concatenation."""
    allv = jnp.concatenate([run_v, cand_v], axis=1)  # [bq, k+bn]
    alli = jnp.concatenate([run_i, cand_i], axis=1)
    outv = jnp.zeros_like(run_v)
    outi = jnp.zeros_like(run_i)
    width = allv.shape[1]
    col = jax.lax.broadcasted_iota(jnp.int32, allv.shape, 1)

    def body(j, carry):
        allv, outv, outi = carry
        m = jnp.max(allv, axis=1)                      # [bq]
        am = jnp.argmax(allv, axis=1)                  # [bq]
        outv = outv.at[:, j].set(m) if False else _set_col(outv, j, m)
        gi = jnp.take_along_axis(alli, am[:, None], axis=1)[:, 0]
        outi = _set_col(outi, j, gi)
        # mask the selected entry
        allv = jnp.where(col == am[:, None], NEG_INF, allv)
        return allv, outv, outi

    allv, outv, outi = jax.lax.fori_loop(0, k, body, (allv, outv, outi))
    return outv, outi


def _set_col(x, j, v):
    """x[:, j] = v without scatter (TPU-friendly select on iota)."""
    col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    return jnp.where(col == j, v[:, None].astype(x.dtype), x)


def _kernel(q_ref, db_ref, d2_ref, vals_ref, idx_ref, acc_v, acc_i, *,
            k: int, bn: int, n_total: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_v[...] = jnp.full_like(acc_v, NEG_INF)
        acc_i[...] = jnp.full_like(acc_i, -1)

    q = q_ref[...]
    d = db_ref[...]
    # similarity = 2 q·d - ||d||^2 (the -||q||^2 constant is added by ops.py)
    s = 2.0 * jnp.dot(q, d.T, preferred_element_type=jnp.float32) \
        - d2_ref[...][None, :]
    base = j * bn
    cand_i = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    nv, ni = _topk_update(acc_v[...], acc_i[...], s, cand_i, k)
    acc_v[...] = nv
    acc_i[...] = ni

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        vals_ref[...] = acc_v[...]
        idx_ref[...] = acc_i[...]


def l2_topk_pallas(queries: jax.Array, db: jax.Array, db_sq: jax.Array,
                   k: int, *, bq: int = 128, bn: int = 512,
                   interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """queries [Q, d], db [N, d], db_sq [N] = ||d||^2 (precomputed once per
    corpus). Q % bq == 0 and N % bn == 0 (ops.py pads)."""
    qn, d = queries.shape
    n, _ = db.shape
    grid = (qn // bq, n // bn)
    kernel = functools.partial(_kernel, k=k, bn=bn, n_total=n)
    vals, idx = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, k), jnp.float32),
            pltpu.VMEM((bq, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries, db, db_sq)
    return vals, idx
