"""Fused scatter-gather top-k merge Pallas TPU kernel.

The global merge of sharded search: each of S shards contributes its local
top-k ``(values, global ids)``; this kernel reduces the gathered [Q, S*k]
candidate slab to the global [Q, k] *deterministically* — ties broken by
the smaller global id, never by gather order — so the answer is invariant
to the shard count (the serving-layer contract, docs/sharded_serving.md).

Same branchless structure as ``l2_topk``'s ``_topk_update`` (k sweeps of
max/select/mask on the VPU — heaps don't vectorize, k reductions do), with
one extra min-reduction per sweep for the id tie-break: the sweep first
takes the max value m, then the smallest id among candidates at m, then
masks exactly that entry. Pad slots (id < 0) are pinned to ``NEG_INF`` /
``_ID_MAX`` up front so they lose both reductions.

Grid: (Q/bq,) — the candidate width S*k is small (hundreds), so each block
holds its whole row slab in VMEM; no streaming axis needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import NEG_INF, PAD_ID
from ..l2_topk.kernel import _set_col

#: selected/pad tie-break id: loses every "smaller id wins" min-reduction
_ID_MAX = jnp.iinfo(jnp.int32).max


def _merge_rows(v, tb, k: int):
    """[bq, C] candidates -> ([bq, k] vals, [bq, k] tie-break ids).
    ``tb`` must already have pads pinned to ``_ID_MAX`` (and their values
    to ``NEG_INF``); live ids unique per row."""
    bq = v.shape[0]
    outv = jnp.full((bq, k), NEG_INF, jnp.float32)
    outi = jnp.full((bq, k), _ID_MAX, jnp.int32)

    def body(j, carry):
        v, tb, outv, outi = carry
        m = jnp.max(v, axis=1)                              # [bq]
        cand = jnp.where(v == m[:, None], tb, _ID_MAX)
        sel = jnp.min(cand, axis=1)                         # smallest id at m
        outv = _set_col(outv, j, m)
        outi = _set_col(outi, j, sel)
        hit = (v == m[:, None]) & (tb == sel[:, None])      # exactly one live
        v = jnp.where(hit, NEG_INF, v)
        tb = jnp.where(hit, _ID_MAX, tb)
        return v, tb, outv, outi

    _, _, outv, outi = jax.lax.fori_loop(0, k, body, (v, tb, outv, outi))
    return outv, outi


def _kernel(v_ref, i_ref, out_v_ref, out_i_ref, *, k: int):
    v = v_ref[...]
    i = i_ref[...]
    pad = i < 0
    v = jnp.where(pad, NEG_INF, v)
    tb = jnp.where(pad, _ID_MAX, i)
    outv, outi = _merge_rows(v, tb, k)
    # a sweep that drained the live pool emits the canonical pad sentinel
    exhausted = outi == _ID_MAX
    out_v_ref[...] = jnp.where(exhausted, NEG_INF, outv)
    out_i_ref[...] = jnp.where(exhausted, PAD_ID, outi)


def topk_merge_pallas(vals: jax.Array, ids: jax.Array, k: int, *,
                      bq: int = 128, interpret: bool = False
                      ) -> tuple[jax.Array, jax.Array]:
    """vals [Q, C] f32, ids [Q, C] int32 (C >= k; ops.py pads), Q % bq == 0.
    Returns ([Q, k] vals, [Q, k] global ids) in deterministic order."""
    qn, c = vals.shape
    grid = (qn // bq,)
    kernel = functools.partial(_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, c), lambda i: (i, 0)),
            pl.BlockSpec((bq, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, k), lambda i: (i, 0)),
            pl.BlockSpec((bq, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((qn, k), jnp.float32),
            jax.ShapeDtypeStruct((qn, k), jnp.int32),
        ],
        interpret=interpret,
    )(vals, ids)
