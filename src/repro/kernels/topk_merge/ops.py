"""Jit'd public wrapper: platform dispatch + row/width padding."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import NEG_INF, PAD_ID
from .kernel import topk_merge_pallas
from .ref import topk_merge_ref


@functools.partial(jax.jit, static_argnames=("k", "impl", "bq", "interpret"))
def topk_merge(vals: jax.Array, ids: jax.Array, k: int, impl: str = "auto",
               bq: int = 128, interpret: bool = False
               ) -> tuple[jax.Array, jax.Array]:
    """Deterministic scatter-gather top-k merge.

    ``vals``/``ids`` are the [Q, C] gathered per-shard candidates (C is
    typically k * n_shards; ``ids < 0`` = pad; live ids unique per row —
    shards are disjoint). Returns (vals [Q, k], ids [Q, k]) ordered by
    (value desc, global id asc); exhausted slots are ``(NEG_INF, PAD_ID)``.
    The id tie-break makes the result invariant to how candidates were
    scattered across shards — see docs/sharded_serving.md.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    v = vals.astype(jnp.float32)
    i = ids.astype(jnp.int32)
    if impl == "ref":
        return topk_merge_ref(v, i, k)

    qn, c = v.shape
    qpad = (-qn) % bq
    cpad = (-max(c, k)) % 128 + max(0, k - c)  # lane multiple AND >= k wide
    if qpad or cpad:
        v = jnp.pad(v, ((0, qpad), (0, cpad)), constant_values=NEG_INF)
        i = jnp.pad(i, ((0, qpad), (0, cpad)), constant_values=PAD_ID)
    out_v, out_i = topk_merge_pallas(v, i, k, bq=bq, interpret=interpret)
    return out_v[:qn], out_i[:qn]
