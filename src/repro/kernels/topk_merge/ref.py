"""Pure-jnp oracle for the scatter-gather top-k merge.

The deterministic order is lexicographic: value descending, then global id
ascending. A per-row ``lexsort`` over ``(tie-break id, -value)`` realizes
exactly that, so the oracle is independent of ``lax.top_k``'s (unspecified
across backends) tie behavior.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..common import NEG_INF, PAD_ID

#: tie-break id for pad slots: loses every "smaller id wins" comparison
_ID_MAX = jnp.iinfo(jnp.int32).max


def topk_merge_ref(vals: jnp.ndarray, ids: jnp.ndarray, k: int
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Merge per-shard candidates into the global top-k.

    ``vals``/``ids`` are [Q, C] (C = k * n_shards candidates per query);
    ``ids < 0`` marks pad slots. Live ids must be unique per row (shards
    are disjoint). Returns (vals [Q, k], ids [Q, k]) sorted by
    (value desc, id asc); slots past the live candidates come back as
    ``(NEG_INF, PAD_ID)``.
    """
    v = jnp.asarray(vals, jnp.float32)
    i = jnp.asarray(ids, jnp.int32)
    pad = i < 0
    v = jnp.where(pad, NEG_INF, v)
    tb = jnp.where(pad, _ID_MAX, i)
    if v.shape[1] < k:  # fewer candidates than requested: pad the pool
        extra = k - v.shape[1]
        v = jnp.pad(v, ((0, 0), (0, extra)), constant_values=NEG_INF)
        tb = jnp.pad(tb, ((0, 0), (0, extra)), constant_values=_ID_MAX)
    order = jnp.lexsort((tb, -v), axis=1)[:, :k]
    out_v = jnp.take_along_axis(v, order, axis=1)
    out_tb = jnp.take_along_axis(tb, order, axis=1)
    out_i = jnp.where(out_tb == _ID_MAX, PAD_ID, out_tb)
    out_v = jnp.where(out_tb == _ID_MAX, NEG_INF, out_v)
    return out_v, out_i
