from .ops import topk_merge

__all__ = ["topk_merge"]
