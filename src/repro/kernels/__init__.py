"""Pallas TPU kernels (validated via interpret=True on CPU; ref.py oracles).

l2_topk       — fused distance + online top-k scan (the retrieval hot path)
rae_encode    — RAE encoder GEMM + fused L2-normalize epilogue
flash_decode  — split-KV online-softmax decode attention
embedding_bag — scalar-prefetch gather-reduce (torch EmbeddingBag on TPU)
pq_adc        — fused PQ ADC scan: LUT build + one-hot code gather + top-k
graph_beam    — fused neighbor gather + L2 + beam merge (one batched HNSW hop)
graph_beam_q  — the quantized hop: SQ8/PQ code gather + asymmetric score + merge
topk_merge    — deterministic scatter-gather top-k merge (sharded search)
"""
from .common import NEG_INF, PAD_ID, PAD_PENALTY, canonicalize_pads
from .embedding_bag.ops import embedding_bag
from .flash_decode.ops import flash_decode
from .graph_beam.ops import graph_beam
from .graph_beam_q.ops import graph_beam_q
from .l2_topk.ops import l2_topk
from .pq_adc.ops import pq_adc
from .rae_encode.ops import rae_encode
from .topk_merge.ops import topk_merge

__all__ = ["NEG_INF", "PAD_ID", "PAD_PENALTY", "canonicalize_pads",
           "embedding_bag", "flash_decode", "graph_beam", "graph_beam_q",
           "l2_topk", "pq_adc", "rae_encode", "topk_merge"]
