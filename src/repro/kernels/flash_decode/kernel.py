"""Flash-decode Pallas kernel: split-KV online softmax for one new token.

The per-chip decode hot loop (the local compute inside
``attention.decode_attention``): stream the KV cache slab through VMEM in
``bs``-sized chunks, maintaining (m, l, o) online-softmax stats in scratch.
Grid (B, S/bs) — the KV axis is innermost, so scratch carries across it.
``cur_len`` arrives via scalar prefetch and masks dead cache positions.

Decode is HBM-bandwidth-bound (arithmetic intensity ~= 1 flop/byte): the
win vs the XLA path is a single pass over the cache with no materialized
[S] score vector in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import NEG_INF


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bs: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]          # [kh, g, dh] (one batch row per grid-i)
    k = k_ref[...]          # [bs, kh, dh]
    v = v_ref[...]
    kh, g, dh = q.shape
    scale = dh ** -0.5
    s = jnp.einsum("kgd,skd->kgs", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (kh, g, bs), 2)
    mask = pos < len_ref[0]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[..., None]) * mask
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "kgs,skd->kgd", p, v.astype(jnp.float32))
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _():
        o_ref[...] = (acc_ref[...] /
                      jnp.maximum(l_ref[...][..., None], 1e-30)
                      ).astype(o_ref.dtype)


def flash_decode_pallas(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                        cur_len: jax.Array, *, bs: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q [B, kh, g, dh]; caches [B, S, kh, dh]; S % bs == 0."""
    b, kh, g, dh = q.shape
    _, s, _, _ = k_cache.shape
    assert s % bs == 0, (s, bs)
    kernel = functools.partial(_kernel, bs=bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, s // bs),
        in_specs=[
            pl.BlockSpec((1, kh, g, dh), lambda i, j, L: (i, 0, 0, 0)),
            pl.BlockSpec((1, bs, kh, dh), lambda i, j, L: (i, j, 0, 0)),
            pl.BlockSpec((1, bs, kh, dh), lambda i, j, L: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, kh, g, dh), lambda i, j, L: (i, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kh, g), jnp.float32),
            pltpu.VMEM((kh, g), jnp.float32),
            pltpu.VMEM((kh, g, dh), jnp.float32),
        ],
    )

    def body(len_ref, q_r, k_r, v_r, o_r, m_s, l_s, a_s):
        _kernel(len_ref,
                q_r.at[0], k_r.at[0], v_r.at[0], o_r.at[0],
                m_s, l_s, a_s, bs=bs)

    return pl.pallas_call(
        body, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kh, g, dh), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(cur_len, jnp.int32).reshape(1), q, k_cache, v_cache)
