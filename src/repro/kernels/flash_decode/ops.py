"""Jit'd wrapper with platform dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_decode_pallas
from .ref import flash_decode_ref


@functools.partial(jax.jit, static_argnames=("impl", "bs", "interpret"))
def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 cur_len, impl: str = "auto", bs: int = 512,
                 interpret: bool = False) -> jax.Array:
    """Single-token decode attention. q [B, kh, g, dh] (kh-major grouped);
    caches [B, S, kh, dh]; attends to cache positions < cur_len."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return flash_decode_ref(q, k_cache, v_cache, cur_len)
    s = k_cache.shape[1]
    bs_ = min(bs, s)
    if s % bs_:
        pad = bs_ - s % bs_
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return flash_decode_pallas(q, k_cache, v_cache, cur_len, bs=bs_,
                               interpret=interpret)
