from .ops import flash_decode

__all__ = ["flash_decode"]
