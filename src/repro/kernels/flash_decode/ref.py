"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..common import NEG_INF


def flash_decode_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cur_len: jax.Array | int) -> jax.Array:
    """q [B, kh, g, dh]; caches [B, S, kh, dh]; attends to positions < cur_len.
    Returns [B, kh, g, dh]."""
    b, kh, g, dh = q.shape
    s = k_cache.shape[1]
    scale = dh ** -0.5
    qs = q.astype(jnp.float32) * scale
    scores = jnp.einsum("bkgd,bskd->bkgs", qs, k_cache.astype(jnp.float32))
    mask = jnp.arange(s)[None, None, None, :] < cur_len
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
