"""Fused gather + L2 + beam-merge Pallas TPU kernel (one HNSW hop).

The batched graph traversal expands one frontier node per live query per
hop; the work of a hop is "score W gathered neighbors against each query
and fold them into that query's running top-ef beam". Done naively that is
a [Q, W, d] gather materialized in HBM, a distance reduce, and a top-k —
three dispatches and triple traffic. This kernel fuses all of it using the
house idioms:

* *scalar-prefetch gather* (same trick as ``embedding_bag``): the neighbor
  ids are prefetched into SMEM and drive the DB BlockSpec index map, so
  each grid step DMAs exactly one corpus row HBM->VMEM — the [Q, W, d]
  gather never exists;
* the squared-L2 score uses the same ``2 q.v - ||v||^2 - ||q||^2`` form as
  ``l2_topk``, with ``||v||^2`` prefetch-gathered from the packed graph's
  precomputed norms;
* the beam merge reuses ``l2_topk``'s branchless iterative max-mask
  ``_topk_update`` — masked slots (id -1: pad links, already-visited
  nodes) score ``NEG_INF`` and keep their -1 id, so the merged beam stays
  sorted descending with pads at the tail.

Grid (Q, W), neighbor-slot axis innermost: TPU grids iterate sequentially,
so the per-query candidate scratch accumulates across the W sweep and the
merge runs once per query on the last slot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..common import NEG_INF
from ..l2_topk.kernel import _set_col, _topk_update


def _kernel(safe_ref, raw_ref, q_ref, row_ref, rsq_ref, bv_ref, bi_ref,
            vout_ref, iout_ref, cv_ref, ci_ref, *, w_slots: int, ef: int):
    i = pl.program_id(0)
    w = pl.program_id(1)
    raw = raw_ref[i * w_slots + w]
    q = q_ref[...].astype(jnp.float32)                   # [1, d]
    r = row_ref[...].astype(jnp.float32)                 # [1, d]
    s = (2.0 * jnp.sum(q * r) - rsq_ref[0]
         - jnp.sum(q * q))                               # -||q - v||^2
    s = jnp.where(raw < 0, NEG_INF, s)
    cv_ref[...] = _set_col(cv_ref[...], w, s.reshape(1))
    ci_ref[...] = _set_col(ci_ref[...], w, raw.reshape(1))

    @pl.when(w == w_slots - 1)
    def _():
        nv, ni = _topk_update(bv_ref[...].astype(jnp.float32), bi_ref[...],
                              cv_ref[...], ci_ref[...], ef)
        # once every remaining entry ties at NEG_INF the iterative argmax
        # re-picks the first exhausted slot; those slots are pads, so
        # canonicalize them to (NEG_INF, -1) exactly like the ref
        ni = jnp.where(nv <= NEG_INF, -1, ni)
        nv = jnp.where(ni >= 0, nv, NEG_INF)
        vout_ref[...] = nv
        iout_ref[...] = ni


def graph_beam_pallas(queries: jax.Array, db: jax.Array, db_sq: jax.Array,
                      nbr_ids: jax.Array, beam_v: jax.Array,
                      beam_i: jax.Array, *,
                      interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """queries [Q, d], db [N, d], db_sq [N] = ||v||^2, nbr_ids [Q, W] int32
    (-1 = masked), beam_v/beam_i [Q, ef]. Returns the merged beam, sorted
    descending. ``ops.py`` pads Q; W and ef ride as-is (sub-tile blocks,
    same as l2_topk's k)."""
    qn, d = queries.shape
    w_slots = nbr_ids.shape[1]
    ef = beam_v.shape[1]
    ids = nbr_ids.reshape(-1)
    safe = jnp.clip(ids, 0, db.shape[0] - 1)
    kernel = functools.partial(_kernel, w_slots=w_slots, ef=ef)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # clamped ids (drive the DMA) + raw ids
        grid=(qn, w_slots),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, w, safe, raw: (i, 0)),
            # one corpus row + its norm per grid step, id-selected
            pl.BlockSpec((1, d),
                         lambda i, w, safe, raw: (safe[i * w_slots + w], 0)),
            pl.BlockSpec((1,),
                         lambda i, w, safe, raw: (safe[i * w_slots + w],)),
            pl.BlockSpec((1, ef), lambda i, w, safe, raw: (i, 0)),
            pl.BlockSpec((1, ef), lambda i, w, safe, raw: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ef), lambda i, w, safe, raw: (i, 0)),
            pl.BlockSpec((1, ef), lambda i, w, safe, raw: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, w_slots), jnp.float32),
            pltpu.VMEM((1, w_slots), jnp.int32),
        ],
    )
    vals, idx = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, ef), jnp.float32),
            jax.ShapeDtypeStruct((qn, ef), jnp.int32),
        ],
        interpret=interpret,
    )(safe, ids, queries, db, db_sq, beam_v, beam_i)
    return vals, idx
