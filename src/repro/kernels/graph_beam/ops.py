"""Public wrapper: platform dispatch + row padding for the beam-hop kernel.

Unlike the scan kernels, the off-TPU path here is *pure numpy*, not a
jitted jnp ref: the batched HNSW traversal calls this once per hop from a
host-driven loop, and on CPU a jit dispatch per hop would cost more than
the hop itself. The pallas path IS jitted and pads the query-row count up
to a power of two (ids -1, beams -inf) so the per-hop live-row count —
which shrinks as queries finish — hits a handful of compile-cache entries
instead of one per distinct batch size; ``SearchEngine.warmup`` visits the
same pow2 buckets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import NEG_INF, graph_beam_pallas
from .ref import graph_beam_ref


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@functools.partial(jax.jit, static_argnames=("interpret",))
def _pallas_padded(queries, db, db_sq, nbr_ids, beam_v, beam_i, interpret):
    return graph_beam_pallas(queries, db, db_sq, nbr_ids, beam_v, beam_i,
                             interpret=interpret)


def graph_beam(queries, db, nbr_ids, beam_v, beam_i, db_sq=None, q_sq=None,
               db_mask=None, impl: str = "auto", interpret: bool = False
               ) -> tuple[np.ndarray, np.ndarray]:
    """One fused traversal hop: gather ``nbr_ids`` rows of ``db``, score
    them against ``queries`` (-squared-L2), and merge into the running
    ``(beam_v, beam_i)`` top-ef beam.

    queries [Q, d]; db [N, d]; nbr_ids [Q, W] int32, -1 = masked (pad link
    or visited node — scores ``NEG_INF``, keeps id -1); beam_v/beam_i
    [Q, ef] sorted descending. Returns the merged beam (numpy), sorted
    descending, pads at the tail. ``db_sq``/``q_sq`` = optional
    precomputed squared norms (the packed graph supplies the former, the
    hop loop hoists the latter; the pallas kernel computes ``q_sq``
    on-chip and ignores the hint). ``db_mask`` (bool [N]) tombstones db
    rows: masked candidate ids are demoted to -1 before the hop, so a
    deleted row can never enter the beam on either impl.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "np"
    if impl == "np":
        return graph_beam_ref(queries, db, nbr_ids, beam_v, beam_i, db_sq,
                              q_sq, db_mask)
    if db_mask is not None:
        # demote tombstoned candidates to pad slots pre-kernel: the pallas
        # hop then needs no mask operand of its own
        ids_np = np.asarray(nbr_ids, np.int32)
        safe = np.where(ids_np >= 0, ids_np, 0)
        nbr_ids = np.where((ids_np >= 0) & np.asarray(db_mask, bool)[safe],
                           ids_np, -1)
    q = jnp.asarray(queries, jnp.float32)
    if db_sq is None:
        db_sq = jnp.sum(jnp.asarray(db, jnp.float32) ** 2, axis=-1)
    nq = q.shape[0]
    pad = _next_pow2(nq) - nq
    ids = jnp.asarray(nbr_ids, jnp.int32)
    bv = jnp.asarray(beam_v, jnp.float32)
    bi = jnp.asarray(beam_i, jnp.int32)
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        ids = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
        bv = jnp.pad(bv, ((0, pad), (0, 0)), constant_values=NEG_INF)
        bi = jnp.pad(bi, ((0, pad), (0, 0)), constant_values=-1)
    vals, idx = _pallas_padded(q, jnp.asarray(db), jnp.asarray(db_sq,
                                                              jnp.float32),
                               ids, bv, bi, interpret)
    return np.asarray(vals[:nq]), np.asarray(idx[:nq])
