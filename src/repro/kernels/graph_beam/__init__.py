from .ops import graph_beam

__all__ = ["graph_beam"]
