"""Vectorized numpy oracle for the fused gather+L2+beam-merge hop.

Deliberately numpy, not jnp: off-TPU the batched HNSW traversal is a
host-driven hop loop and this ref IS the production path — a jitted jnp
ref would pay one dispatch per hop, which is exactly the overhead the
batched engine exists to remove. Per-row determinism matters (the serving
cache relies on a query answering identically at q=1 and inside a
coalesced batch): every op below — gather, einsum contraction, stable
argsort — reduces row-by-row with no cross-row reassociation.
"""
from __future__ import annotations

import numpy as np

from ..common import NEG_INF, canonicalize_pads


def graph_beam_ref(queries: np.ndarray, db: np.ndarray, nbr_ids: np.ndarray,
                   beam_v: np.ndarray, beam_i: np.ndarray,
                   db_sq: np.ndarray | None = None,
                   q_sq: np.ndarray | None = None,
                   db_mask: np.ndarray | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
    """One batched beam hop: score candidate ids and merge into the beam.

    queries [Q, d]; db [N, d]; nbr_ids [Q, W] int32 with -1 = masked slot
    (pad link or already-visited node); beam_v/beam_i [Q, ef] the running
    beam, sorted descending by score (-squared-L2; higher = closer), with
    (NEG_INF, -1) or (-inf, -1) in empty slots. ``db_sq``/``q_sq`` =
    precomputed squared norms (the packed graph carries the former, the
    traversal hoists the latter out of its hop loop; both recomputed here
    when absent). ``db_mask`` (bool [N]) tombstones db rows: a masked
    candidate is treated exactly like a -1 slot, so a deleted row can
    never enter the beam. Returns the merged (values, ids), again sorted
    descending, ef wide, pads canonicalized to (NEG_INF, -1). Masked
    candidates score ``NEG_INF`` so they can never displace a real entry;
    ties resolve stably toward the beam (then lower candidate slot),
    matching the kernel's iterative first-argmax merge bit-for-bit.

    This runs once per traversal hop on the serving path, so it is written
    for low constant overhead: float32 inputs pass through untouched and
    the merge gathers index directly rather than via take_along_axis.
    """
    q = np.asarray(queries, np.float32)
    d = np.asarray(db, np.float32)
    ids = np.asarray(nbr_ids, np.int32)
    bv = np.asarray(beam_v, np.float32)
    bi = np.asarray(beam_i, np.int32)
    ef = bv.shape[1]
    valid = ids >= 0
    safe = np.where(valid, ids, 0)
    if db_mask is not None:
        valid = valid & np.asarray(db_mask, bool)[safe]
    g = d[safe]                                          # [Q, W, d]
    if db_sq is None:
        db_sq = np.einsum("nd,nd->n", d, d)
    if q_sq is None:
        q_sq = np.einsum("qd,qd->q", q, q)
    # same 2 q.v - ||v||^2 - ||q||^2 form as the kernel (and l2_topk)
    s = 2.0 * np.einsum("qwd,qd->qw", g, q)
    s -= np.asarray(db_sq, np.float32)[safe]
    s -= np.asarray(q_sq, np.float32)[:, None]
    s[~valid] = NEG_INF
    allv = np.concatenate([bv, s], axis=1)
    alli = np.concatenate([bi, np.where(valid, ids, -1)], axis=1)
    order = np.argsort(-allv, axis=1, kind="stable")[:, :ef]
    rr = np.arange(q.shape[0])[:, None]
    out_v = allv[rr, order]
    out_i = alli[rr, order]
    # canonical pad slots: (NEG_INF, -1) — empty beam slots arrive as -inf
    # and masked candidates as NEG_INF; emitting one sentinel keeps the two
    # impls (and repeated merges of the same beam) bitwise aligned
    return canonicalize_pads(out_v, out_i)
