"""RAE encoder Pallas kernel: tiled GEMM + fused L2-normalize epilogue.

Encoding a billion-row corpus through W_e [n, m] is a skinny GEMM whose
output is immediately re-read for normalization (cosine retrieval). Fusing
the row-norm into the GEMM epilogue removes one full HBM round trip of the
reduced corpus — at m=128..512 the op is output-bandwidth-bound, so this is
a ~2x bytes saving on the encode pass.

Grid (rows/br, n/bk): the contraction axis is innermost; the [br, m]
accumulator lives in VMEM scratch; the epilogue normalizes on the last step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, normalize: bool):
    @pl.when(pl.program_id(1) == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _():
        z = acc_ref[...]
        if normalize:
            norm = jnp.sqrt(jnp.sum(z * z, axis=-1, keepdims=True))
            z = z / jnp.maximum(norm, 1e-12)
        o_ref[...] = z.astype(o_ref.dtype)


def rae_encode_pallas(x: jax.Array, w_e: jax.Array, *, normalize: bool = True,
                      br: int = 256, bk: int = 512,
                      interpret: bool = False) -> jax.Array:
    rows, n = x.shape
    _, m = w_e.shape
    assert rows % br == 0 and n % bk == 0, (rows, n, br, bk)
    kernel = functools.partial(_kernel, normalize=normalize)
    return pl.pallas_call(
        kernel,
        grid=(rows // br, n // bk),
        in_specs=[
            pl.BlockSpec((br, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((br, m), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((br, m), jnp.float32)],
        interpret=interpret,
    )(x, w_e)
