"""Pure-jnp oracle: RAE encode (x @ W_e) with fused L2-normalize epilogue."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rae_encode_ref(x: jax.Array, w_e: jax.Array,
                   normalize: bool = True) -> jax.Array:
    z = x.astype(jnp.float32) @ w_e.astype(jnp.float32)
    if normalize:
        z = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-12)
    return z
