"""Jit'd public wrapper with padding + platform dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import rae_encode_pallas
from .ref import rae_encode_ref


@functools.partial(jax.jit, static_argnames=("normalize", "impl", "br", "bk",
                                             "interpret"))
def rae_encode(x: jax.Array, w_e: jax.Array, normalize: bool = True,
               impl: str = "auto", br: int = 256, bk: int = 512,
               interpret: bool = False) -> jax.Array:
    """z = (x @ W_e), optionally L2-normalized per row. x [R, n], w_e [n, m]."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return rae_encode_ref(x, w_e, normalize)
    rows, n = x.shape
    br_ = min(br, rows) if rows % br else br
    rpad = (-rows) % br
    kpad = (-n) % bk
    xp = jnp.pad(x, ((0, rpad), (0, kpad)))
    wp = jnp.pad(w_e, ((0, kpad), (0, 0)))
    z = rae_encode_pallas(xp.astype(jnp.float32), wp.astype(jnp.float32),
                          normalize=normalize, br=br, bk=bk,
                          interpret=interpret)
    return z[:rows]
