from .ops import rae_encode

__all__ = ["rae_encode"]
