"""EmbeddingBag Pallas kernel via scalar-prefetch row gather.

JAX has no torch.nn.EmbeddingBag / FBGEMM TBE; the framework's jnp fallback
is take + segment_sum (models.common). On TPU the idiomatic kernel uses
*scalar prefetch*: the bag indices are prefetched into SMEM and drive the
BlockSpec index_map, so each grid step DMAs exactly one embedding row
HBM->VMEM — no [B, L, d] gather ever materializes (the jnp path writes and
re-reads it, tripling HBM traffic for the dominant op of every recsys cell).

Grid (B, L): bag-position axis innermost; the [d] accumulator lives in VMEM
scratch; masked positions (l >= lengths[b]) still DMA a (clamped) row but
contribute zero — branchless, fixed schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, len_ref, row_ref, o_ref, acc_ref, *, l: int,
            mode: str):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = (j < len_ref[b]).astype(jnp.float32)
    acc_ref[...] += w * row_ref[...].astype(jnp.float32)

    @pl.when(j == l - 1)
    def _():
        acc = acc_ref[...]
        if mode == "mean":
            acc = acc / jnp.maximum(len_ref[b].astype(jnp.float32), 1.0)
        o_ref[...] = acc.astype(o_ref.dtype)


def embedding_bag_pallas(table: jax.Array, ids: jax.Array, lengths: jax.Array,
                         *, mode: str = "mean",
                         interpret: bool = False) -> jax.Array:
    bsz, l = ids.shape
    v, d = table.shape
    kernel = functools.partial(_kernel, l=l, mode=mode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # ids (flattened) + lengths
        grid=(bsz, l),
        in_specs=[
            # one table row per grid step, selected by the prefetched id
            pl.BlockSpec((1, d), lambda b, j, ids, lens: (ids[b * l + j], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, j, ids, lens: (b, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, d), jnp.float32),
        interpret=interpret,
    )(ids.reshape(-1), lengths, table)
