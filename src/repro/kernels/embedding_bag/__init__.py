from .ops import embedding_bag

__all__ = ["embedding_bag"]
