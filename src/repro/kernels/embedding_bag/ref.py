"""Pure-jnp oracle for EmbeddingBag (gather + masked segment reduce)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jax.Array, ids: jax.Array, lengths: jax.Array,
                      mode: str = "mean") -> jax.Array:
    """table [V, d]; ids [B, L]; lengths [B] -> [B, d]."""
    e = jnp.take(table, ids, axis=0).astype(jnp.float32)  # [B, L, d]
    mask = (jnp.arange(ids.shape[1])[None, :] < lengths[:, None])
    s = jnp.sum(e * mask[..., None], axis=1)
    if mode == "sum":
        return s
    return s / jnp.maximum(lengths[:, None].astype(jnp.float32), 1.0)
