"""Jit'd wrapper with platform dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import embedding_bag_pallas
from .ref import embedding_bag_ref


@functools.partial(jax.jit, static_argnames=("mode", "impl", "interpret"))
def embedding_bag(table: jax.Array, ids: jax.Array, lengths: jax.Array,
                  mode: str = "mean", impl: str = "auto",
                  interpret: bool = False) -> jax.Array:
    """torch.nn.EmbeddingBag equivalent: [B, L] ids -> [B, d] reduced rows."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return embedding_bag_ref(table, ids, lengths, mode)
    return embedding_bag_pallas(table, jnp.clip(ids, 0, table.shape[0] - 1),
                                lengths, mode=mode, interpret=interpret)
