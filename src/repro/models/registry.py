"""Model registry: builds the lowerable program for every (arch x shape) cell.

``build_cell`` returns a :class:`CellProgram` — the step function, abstract
arguments (ShapeDtypeStructs: weak-type-correct, shardable, no allocation)
and their PartitionSpecs — which launch/dryrun.py feeds straight into
``jax.jit(...).lower(...).compile()``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs import ShapeCell, get_arch, get_shapes
from ..distributed.partitioning import pspecs_from_schema
from ..optim import AdamW, AdamWState, cosine_annealing
from .common import MeshCtx, pad_to_multiple
from .gnn import graphsage
from .recsys import autoint as autoint_m
from .recsys import bst as bst_m
from .recsys import mind as mind_m
from .recsys import two_tower as tt_m
from .transformer import model as tm

I32 = jnp.int32
F32 = jnp.float32


@dataclass
class CellProgram:
    arch_id: str
    cell: ShapeCell
    family: str
    fn: Callable
    abstract_args: tuple
    arg_pspecs: tuple
    donate: tuple[int, ...] = ()
    meta: dict = field(default_factory=dict)

    def lower(self, mesh):
        from jax.sharding import NamedSharding

        shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), self.arg_pspecs,
            is_leaf=lambda x: isinstance(x, P))
        with mesh:
            jitted = jax.jit(self.fn, in_shardings=shardings,
                             donate_argnums=self.donate)
            return jitted.lower(*self.abstract_args)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _pspec_like(ctx: MeshCtx, abstract, *logical):
    return ctx.pspec(abstract.shape, *logical)


def _opt_abstract(params_abs, moment_dtype: Optional[str] = None):
    def md(p):
        dt = jnp.dtype(moment_dtype) if moment_dtype else p.dtype
        return jax.ShapeDtypeStruct(p.shape, dt)

    mo = jax.tree.map(md, params_abs)
    return AdamWState(step=_sds((), I32), m=mo, v=mo)


def _opt_pspecs(params_pspecs):
    return AdamWState(step=P(), m=params_pspecs, v=params_pspecs)


def _lm_opt(cfg):
    return AdamW(lr=cosine_annealing(3e-4, 3e-5, 50_000, warmup_steps=500),
                 weight_decay=0.1, clip_norm=1.0,
                 moment_dtype=cfg.moment_dtype)


def _small_opt():
    return AdamW(lr=cosine_annealing(1e-3, 1e-5, 50_000), weight_decay=1e-4,
                 clip_norm=1.0)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------
def _lm_cell(arch_id: str, cfg, cell: ShapeCell, ctx: MeshCtx) -> CellProgram:
    import dataclasses

    s, b = cell.seq_len, cell.global_batch
    if cell.kind != "train":
        # serving keeps bf16 weights (production practice; halves decode HBM)
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    params_abs = tm.abstract_params(cfg, ctx)
    pps = pspecs_from_schema(tm.schema(cfg, ctx), ctx.rules, ctx.mesh) \
        if ctx.mesh is not None else jax.tree.map(lambda _: P(), params_abs)
    meta = {"kind": cell.kind, "seq": s, "batch": b}

    if cell.kind == "train":
        opt = _lm_opt(cfg)
        fn = tm.make_train_step(cfg, ctx, opt)
        batch_abs = {"tokens": _sds((b, s), I32), "targets": _sds((b, s), I32)}
        bspec = {k: ctx.pspec((b, s), "batch", None) for k in batch_abs}
        return CellProgram(arch_id, cell, "lm", fn,
                           (params_abs,
                            _opt_abstract(params_abs, cfg.moment_dtype),
                            batch_abs),
                           (pps, _opt_pspecs(pps), bspec),
                           donate=(0, 1), meta=meta)

    if cell.kind == "prefill":
        def fn(params, tokens):
            return tm.prefill(params, tokens, cfg, ctx)

        return CellProgram(arch_id, cell, "lm", fn,
                           (params_abs, _sds((b, s), I32)),
                           (pps, ctx.pspec((b, s), "batch", None)), meta=meta)

    # decode (decode_32k / long_500k): one new token vs a seq_len KV cache.
    # long-context decode (batch 1) spreads the cache over data AND model
    # axes (256/512-way); batched decode shards batch over data, cache seq
    # over model.
    seq_logical = "kv_seq_all" if b < ctx.axis_size("batch") else "kv_seq"
    state_abs = tm.abstract_decode_state(cfg, b, s, ctx)
    cache_spec = ctx.pspec(state_abs.k.shape, None, "batch", seq_logical,
                           None, None)
    state_pspecs = tm.DecodeState(k=cache_spec, v=cache_spec, length=P())

    def fn(params, state, tokens):
        return tm.decode_step(params, state, tokens, cfg, ctx,
                              seq_logical=seq_logical)

    return CellProgram(arch_id, cell, "lm", fn,
                       (params_abs, state_abs, _sds((b,), I32)),
                       (pps, state_pspecs, ctx.pspec((b,), "batch")),
                       donate=(1,), meta={**meta, "seq_logical": seq_logical})


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------
def _gnn_cell(arch_id: str, cfg, cell: ShapeCell, ctx: MeshCtx) -> CellProgram:
    n_cls = cell.extras.get("n_classes", cfg.n_classes)
    sch = graphsage.schema(cfg, cell.d_feat, n_cls)
    from ..distributed.partitioning import abstract_from_schema

    params_abs = abstract_from_schema(sch)
    pps = pspecs_from_schema(sch, ctx.rules, ctx.mesh) \
        if ctx.mesh is not None else jax.tree.map(lambda _: P(), params_abs)
    opt = _small_opt()
    meta = {"kind": cell.kind}

    if cell.kind == "full_graph":
        n = pad_to_multiple(cell.n_nodes, 512)
        e = pad_to_multiple(cell.n_edges, 512)
        batch_abs = {
            "features": _sds((n, cell.d_feat), F32),
            "src": _sds((e,), I32), "dst": _sds((e,), I32),
            "labels": _sds((n,), I32), "node_mask": _sds((n,), F32),
        }
        bspec = {
            "features": ctx.pspec((n, cell.d_feat), "db_rows", None),
            "src": ctx.pspec((e,), "db_rows"),
            "dst": ctx.pspec((e,), "db_rows"),
            "labels": ctx.pspec((n,), "db_rows"),
            "node_mask": ctx.pspec((n,), "db_rows"),
        }
        fn = graphsage.make_train_step(cfg, ctx, opt, "full_graph")
        meta.update(n_padded=n, e_padded=e)
    elif cell.kind == "minibatch":
        bsz = cell.batch_nodes
        f1, f2 = cell.fanout or cfg.sample_sizes
        d = cell.d_feat
        batch_abs = {
            "x_seed": _sds((bsz, d), F32),
            "x_n1": _sds((bsz, f1, d), F32),
            "x_n2": _sds((bsz, f1, f2, d), F32),
            "labels": _sds((bsz,), I32),
        }
        bspec = {k: ctx.pspec(v.shape, "batch",
                              *([None] * (len(v.shape) - 1)))
                 for k, v in batch_abs.items()}
        fn = graphsage.make_train_step(cfg, ctx, opt, "minibatch")
    else:  # batched_graphs
        g, nn, ne = cell.graphs_per_batch, cell.n_nodes, cell.n_edges
        batch_abs = {
            "features": _sds((g, nn, cell.d_feat), F32),
            "edges": _sds((g, ne, 2), I32),
            "edge_mask": _sds((g, ne), F32),
            "labels": _sds((g,), I32),
        }
        bspec = {k: ctx.pspec(v.shape, "batch",
                              *([None] * (len(v.shape) - 1)))
                 for k, v in batch_abs.items()}
        fn = graphsage.make_train_step(cfg, ctx, opt, "batched_graphs")

    return CellProgram(arch_id, cell, "gnn", fn,
                       (params_abs, _opt_abstract(params_abs), batch_abs),
                       (pps, _opt_pspecs(pps), bspec), donate=(0, 1),
                       meta=meta)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------
_RECSYS_MODULES = {"bst": bst_m, "two_tower": tt_m, "autoint": autoint_m,
                   "mind": mind_m}


def _recsys_batch(cfg, b: int, with_label: bool) -> dict:
    kind = cfg.kind
    out: dict[str, Any] = {}
    if kind == "bst":
        out = {"hist": _sds((b, cfg.seq_len), I32), "item": _sds((b,), I32),
               "user": _sds((b,), I32), "category": _sds((b,), I32)}
    elif kind == "two_tower":
        out = {"user": _sds((b,), I32), "hist": _sds((b, cfg.hist_len), I32),
               "hist_len": _sds((b,), I32), "item": _sds((b,), I32)}
    elif kind == "autoint":
        out = {"fields": _sds((b, cfg.n_fields), I32)}
    elif kind == "mind":
        out = {"hist": _sds((b, cfg.hist_len), I32),
               "hist_len": _sds((b,), I32), "item": _sds((b,), I32)}
    if with_label:
        out["label"] = _sds((b,), F32)
    return out


def _recsys_cell(arch_id: str, cfg, cell: ShapeCell, ctx: MeshCtx
                 ) -> CellProgram:
    mod = _RECSYS_MODULES[cfg.kind]
    sch = mod.schema(cfg)
    from ..distributed.partitioning import abstract_from_schema

    params_abs = abstract_from_schema(sch)
    pps = pspecs_from_schema(sch, ctx.rules, ctx.mesh) \
        if ctx.mesh is not None else jax.tree.map(lambda _: P(), params_abs)
    meta = {"kind": cell.kind}

    def bspecs(batch_abs):
        return {k: ctx.pspec(v.shape, "batch",
                             *([None] * (len(v.shape) - 1)))
                for k, v in batch_abs.items()}

    if cell.kind == "train":
        b = cell.global_batch
        opt = _small_opt()

        def fn(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                mod.loss_fn, has_aux=True)(params, batch, cfg, ctx)
            params, opt_state, om = opt.update(grads, opt_state, params)
            return params, opt_state, {"loss": loss, **metrics, **om}

        batch_abs = _recsys_batch(cfg, b, with_label=True)
        return CellProgram(arch_id, cell, "recsys", fn,
                           (params_abs, _opt_abstract(params_abs), batch_abs),
                           (pps, _opt_pspecs(pps), bspecs(batch_abs)),
                           donate=(0, 1), meta=meta)

    if cell.kind == "serve":
        b = cell.global_batch

        def fn(params, batch):
            return mod.serve(params, batch, cfg, ctx)

        batch_abs = _recsys_batch(cfg, b, with_label=False)
        return CellProgram(arch_id, cell, "recsys", fn,
                           (params_abs, batch_abs),
                           (pps, bspecs(batch_abs)), meta=meta)

    # retrieval_cand: one query vs n_candidates, fused with distributed top-k
    nc = cell.n_candidates
    from ..search import distributed_topk

    def fn(params, batch):
        scores = mod.retrieval_scores(params, batch, cfg, ctx)
        return distributed_topk(scores, 100, ctx)

    batch_abs = _recsys_batch(cfg, 1, with_label=False)
    batch_abs["candidates"] = _sds((nc,), I32)
    bsp = bspecs({k: v for k, v in batch_abs.items() if k != "candidates"})
    bsp["candidates"] = ctx.pspec((nc,), "db_rows")
    return CellProgram(arch_id, cell, "recsys", fn,
                       (params_abs, batch_abs), (pps, bsp),
                       meta={**meta, "top_k": 100})


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def build_cell(arch_id: str, cell: ShapeCell | str, ctx: MeshCtx
               ) -> CellProgram:
    cfg, family = get_arch(arch_id)
    if isinstance(cell, str):
        cells = {c.name: c for c in get_shapes(arch_id)}
        cell = cells[cell]
    if family == "lm":
        return _lm_cell(arch_id, cfg, cell, ctx)
    if family == "gnn":
        return _gnn_cell(arch_id, cfg, cell, ctx)
    if family == "recsys":
        return _recsys_cell(arch_id, cfg, cell, ctx)
    raise ValueError(family)


def input_specs(arch_id: str, cell: ShapeCell | str, ctx: MeshCtx) -> tuple:
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    return build_cell(arch_id, cell, ctx).abstract_args
