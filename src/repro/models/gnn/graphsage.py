"""GraphSAGE (Hamilton et al., arXiv:1706.02216) in JAX.

JAX has no sparse-adjacency SpMM (BCOO only) — message passing is built from
``jnp.take`` (gather along the edge list) + ``jax.ops.segment_sum`` (scatter
by destination), which IS the system's GNN kernel (kernel_taxonomy §GNN).

Three execution regimes, matching the assigned shape cells:
  * full-batch (cora / ogbn-products): node features row-sharded over the
    whole mesh; per-shard edge gather + segment-sum partials; explicit
    all-gather(h) -> local scatter -> reduce-scatter(out) via shard_map so
    GSPMD can never fall back to gathering the edge tensors.
  * sampled minibatch (reddit, fanout 15-10): the *host-side* CSR uniform
    sampler (sampler.py) emits fixed-shape [B, f1, (f2), d] feature tensors;
    the device program is dense (GSPMD batch-shards it).
  * batched small graphs (molecule): graphs flattened with node-index
    offsets so one segment_sum serves the whole batch.

Layer: h' = relu(W · [h_v ; agg_{u in N(v)} h_u]) (concat form, mean agg),
followed by L2 normalization as in the paper.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from ...configs.base import GNNConfig, ShapeCell
from ...distributed.partitioning import ParamDef, init_from_schema
from ..common import MeshCtx, NULL_CTX


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------
def schema(cfg: GNNConfig, d_feat: int, n_classes: int) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    dims = [d_feat] + [cfg.d_hidden] * cfg.n_layers
    out: dict[str, Any] = {}
    for i in range(cfg.n_layers):
        out[f"w{i}"] = ParamDef((2 * dims[i], dims[i + 1]), (None, None), pdt)
        out[f"b{i}"] = ParamDef((dims[i + 1],), (None,), pdt, init="zeros")
    out["w_out"] = ParamDef((cfg.d_hidden, n_classes), (None, None), pdt)
    out["b_out"] = ParamDef((n_classes,), (None,), pdt, init="zeros")
    return out


def init(cfg: GNNConfig, d_feat: int, n_classes: int, key: jax.Array):
    return init_from_schema(schema(cfg, d_feat, n_classes), key)


def _l2norm(x):
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def _sage_combine(h_self, h_agg, w, b, aggregator: str, last: bool):
    z = jnp.concatenate([h_self, h_agg], -1) @ w + b
    z = jax.nn.relu(z)
    return z if last else _l2norm(z)


# ---------------------------------------------------------------------------
# Full-batch message passing (sharded)
# ---------------------------------------------------------------------------
def mean_aggregate(h: jax.Array, src: jax.Array, dst: jax.Array,
                   n_nodes: int, ctx: MeshCtx, aggregator: str = "mean"
                   ) -> jax.Array:
    """agg[v] = reduce_{(u,v) in E} h[u]. h row-sharded, edges sharded."""
    if ctx.mesh is None or ctx.shards_for(n_nodes, "db_rows") == 1:
        msg = jnp.take(h, src, axis=0)
        s = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
        deg = jax.ops.segment_sum(jnp.ones_like(dst, h.dtype), dst,
                                  num_segments=n_nodes)
        if aggregator == "sum":
            return s
        return s / jnp.maximum(deg, 1.0)[:, None]

    mesh = ctx.mesh
    axes = ctx.used_axes(n_nodes, "db_rows")
    h_spec = ctx.pspec(h.shape, "db_rows", None)
    e_spec = ctx.pspec(src.shape, "db_rows")

    def f(h_l, src_l, dst_l):
        h_full = jax.lax.all_gather(h_l, axes, axis=0, tiled=True)  # [N, d]
        msg = jnp.take(h_full, src_l, axis=0)
        partial = jax.ops.segment_sum(msg, dst_l, num_segments=n_nodes)
        deg = jax.ops.segment_sum(jnp.ones_like(dst_l, h_l.dtype), dst_l,
                                  num_segments=n_nodes)
        out = jax.lax.psum_scatter(partial, axes, scatter_dimension=0,
                                   tiled=True)
        deg = jax.lax.psum_scatter(deg, axes, scatter_dimension=0, tiled=True)
        if aggregator == "sum":
            return out
        return out / jnp.maximum(deg, 1.0)[:, None]

    fn = shard_map(f, mesh=mesh, in_specs=(h_spec, e_spec, e_spec),
                   out_specs=h_spec, check_rep=False)
    return fn(h, src, dst)


def full_batch_logits(params, feats, src, dst, cfg: GNNConfig, ctx: MeshCtx):
    n = feats.shape[0]
    h = ctx.constrain(feats, "db_rows", None)
    for i in range(cfg.n_layers):
        agg = mean_aggregate(h, src, dst, n, ctx, cfg.aggregator)
        h = _sage_combine(h, agg, params[f"w{i}"], params[f"b{i}"],
                          cfg.aggregator, last=(i == cfg.n_layers - 1))
        h = ctx.constrain(h, "db_rows", None)
    return h @ params["w_out"] + params["b_out"], h


def full_batch_loss(params, batch, cfg: GNNConfig, ctx: MeshCtx):
    logits, _ = full_batch_logits(params, batch["features"], batch["src"],
                                  batch["dst"], cfg, ctx)
    labels = batch["labels"]
    # node_mask excludes rows added by padding N to a mesh multiple
    mask = batch.get("node_mask", jnp.ones_like(labels, jnp.float32))
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = jnp.sum((lse - gold) * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / denom
    return loss, {"acc": acc}


# ---------------------------------------------------------------------------
# Sampled minibatch (fixed fanout tensors from the host sampler)
# ---------------------------------------------------------------------------
def minibatch_logits(params, batch, cfg: GNNConfig, ctx: MeshCtx):
    """batch: x_seed [B,d], x_n1 [B,f1,d], x_n2 [B,f1,f2,d] (2-layer case)."""
    assert cfg.n_layers == 2, "fanout pipeline built for the 2-layer config"
    x0, x1, x2 = batch["x_seed"], batch["x_n1"], batch["x_n2"]
    x0 = ctx.constrain(x0, "batch", None)
    # layer 1 applied at depth-1 nodes (aggregate their depth-2 samples)...
    agg1 = x2.mean(axis=2)
    h1_n1 = _sage_combine(x1, agg1, params["w0"], params["b0"],
                          cfg.aggregator, last=False)
    # ...and at the seeds (aggregate depth-1 samples)
    h1_seed = _sage_combine(x0, x1.mean(axis=1), params["w0"], params["b0"],
                            cfg.aggregator, last=False)
    # layer 2 at the seeds
    h2 = _sage_combine(h1_seed, h1_n1.mean(axis=1), params["w1"], params["b1"],
                       cfg.aggregator, last=True)
    return h2 @ params["w_out"] + params["b_out"], h2


def minibatch_loss(params, batch, cfg: GNNConfig, ctx: MeshCtx):
    logits, _ = minibatch_logits(params, batch, cfg, ctx)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    loss = jnp.mean(lse - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"acc": acc}


# ---------------------------------------------------------------------------
# Batched small graphs (molecule): flatten + offset segment ids
# ---------------------------------------------------------------------------
def batched_graphs_logits(params, batch, cfg: GNNConfig, ctx: MeshCtx):
    """features [G, n, d]; edges [G, e, 2] (+ edge_mask [G, e]); per-graph
    classification via mean readout."""
    feats, edges = batch["features"], batch["edges"]
    emask = batch["edge_mask"]
    g, n, d = feats.shape
    _, e, _ = edges.shape
    h = ctx.constrain(feats, "batch", None, None).reshape(g * n, d)
    offs = (jnp.arange(g) * n)[:, None]
    src = (edges[..., 0] + offs).reshape(-1)
    dst = (edges[..., 1] + offs).reshape(-1)
    # masked edges scatter to a dummy segment
    dst = jnp.where(emask.reshape(-1) > 0, dst, g * n)
    for i in range(cfg.n_layers):
        msg = jnp.take(h, src, axis=0)
        s = jax.ops.segment_sum(msg, dst, num_segments=g * n + 1)[: g * n]
        deg = jax.ops.segment_sum(emask.reshape(-1).astype(h.dtype), dst,
                                  num_segments=g * n + 1)[: g * n]
        agg = s / jnp.maximum(deg, 1.0)[:, None]
        h = _sage_combine(h, agg, params[f"w{i}"], params[f"b{i}"],
                          cfg.aggregator, last=(i == cfg.n_layers - 1))
    readout = h.reshape(g, n, -1).mean(axis=1)
    return readout @ params["w_out"] + params["b_out"], readout


def batched_graphs_loss(params, batch, cfg: GNNConfig, ctx: MeshCtx):
    logits, _ = batched_graphs_logits(params, batch, cfg, ctx)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    loss = jnp.mean(lse - gold)
    return loss, {}


def make_train_step(cfg: GNNConfig, ctx: MeshCtx, opt, kind: str):
    loss_map = {"full_graph": full_batch_loss, "minibatch": minibatch_loss,
                "batched_graphs": batched_graphs_loss}
    lf = loss_map[kind]

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            params, batch, cfg, ctx)
        params, opt_state, om = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step
