from . import graphsage, sampler
