"""Host-side CSR uniform neighbor sampler (GraphSAGE §3.1, fanout sampling).

Runs on the host data pipeline (numpy), like production GNN systems: the
device program only ever sees fixed-shape, pre-gathered feature tensors.
Sampling is uniform WITH replacement (the paper's estimator), so outputs are
always exactly [B, f1] / [B, f1, f2] — no masks. Zero-degree nodes fall back
to self-loops. Batches are a pure function of (seed, step): resumable and
elastic-safe (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ...data.synthetic import Graph


class NeighborSampler:
    def __init__(self, graph: Graph, fanouts: tuple[int, ...], seed: int = 0):
        self.g = graph
        self.fanouts = tuple(fanouts)
        self.seed = seed
        self._root = np.random.SeedSequence(seed)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence(entropy=self._root.entropy,
                                   spawn_key=(step,)))

    def _sample_neighbors(self, nodes: np.ndarray, fanout: int,
                          rng: np.random.Generator) -> np.ndarray:
        """Uniform-with-replacement neighbor sample. nodes [M] -> [M, fanout]."""
        indptr, dst = self.g.indptr, self.g.edge_dst
        start = indptr[nodes]
        deg = indptr[nodes + 1] - start
        r = rng.integers(0, 1 << 31, size=(len(nodes), fanout))
        safe_deg = np.maximum(deg, 1)
        idx = start[:, None] + (r % safe_deg[:, None])
        nbrs = dst[np.minimum(idx, len(dst) - 1 if len(dst) else 0)]
        # zero-degree -> self loop
        return np.where(deg[:, None] > 0, nbrs, nodes[:, None]).astype(np.int32)

    def sample_batch(self, step: int, batch_size: int) -> dict[str, np.ndarray]:
        rng = self._rng(step)
        g = self.g
        seeds = rng.integers(0, g.n_nodes, batch_size).astype(np.int32)
        f1, f2 = self.fanouts[0], (self.fanouts[1] if len(self.fanouts) > 1 else 0)
        n1 = self._sample_neighbors(seeds, f1, rng)  # [B, f1]
        out = {
            "seeds": seeds,
            "x_seed": g.features[seeds],
            "x_n1": g.features[n1.reshape(-1)].reshape(batch_size, f1, -1),
            "labels": g.labels[seeds].astype(np.int32),
        }
        if f2:
            n2 = self._sample_neighbors(n1.reshape(-1), f2, rng)
            out["x_n2"] = g.features[n2.reshape(-1)].reshape(
                batch_size, f1, f2, -1)
        return out

    def neighbors_of(self, node: int) -> np.ndarray:
        """True neighbor set (for tests: sampled nbrs must be real nbrs)."""
        return self.g.edge_dst[self.g.indptr[node]: self.g.indptr[node + 1]]
