"""Shared model primitives: norms, sharded embedding lookup, mesh context.

``MeshCtx`` carries (mesh, rules) through model code. When ``mesh is None``
(unit tests, single-device smoke runs) every collective helper degrades to
its local pure-jnp equivalent — same math, no shard_map — so correctness
tests never depend on device topology.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..distributed.partitioning import MeshAxes, default_rules, spec_for, usable_axes


@dataclass(frozen=True)
class MeshCtx:
    mesh: Optional[Mesh] = None
    rules: dict[str, MeshAxes] = field(default_factory=default_rules)

    @property
    def batch_axes(self) -> tuple[str, ...]:
        r = self.rules.get("batch", ())
        if r is None:
            return ()
        return (r,) if isinstance(r, str) else tuple(r)

    def axis_size(self, logical: str) -> int:
        """Product of mesh-axis sizes a logical name maps to (1 if unmapped)."""
        if self.mesh is None:
            return 1
        r = self.rules.get(logical)
        if r is None:
            return 1
        axes = (r,) if isinstance(r, str) else r
        out = 1
        for a in axes:
            out *= self.mesh.shape.get(a, 1)
        return out

    def used_axes(self, dim: int, logical: str) -> tuple[str, ...]:
        """Mesh axes that actually shard a dim of this size (after fallback)."""
        if self.mesh is None:
            return ()
        return usable_axes(dim, logical, self.rules, self.mesh)

    def shards_for(self, dim: int, logical: str) -> int:
        out = 1
        for a in self.used_axes(dim, logical):
            out *= self.mesh.shape[a]
        return out

    def constrain(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        """with_sharding_constraint by logical axis names (no-op without mesh)."""
        if self.mesh is None:
            return x
        spec = spec_for(x.shape, tuple(logical), self.rules, self.mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def pspec(self, shape: tuple[int, ...], *logical: Optional[str]) -> P:
        if self.mesh is None:
            return P()
        return spec_for(shape, tuple(logical), self.rules, self.mesh)


NULL_CTX = MeshCtx(mesh=None)


# ---------------------------------------------------------------------------
# Explicit Megatron-SP boundaries (hillclimb A5, EXPERIMENTS.md §Perf):
# GSPMD resolves the seq-parallel <-> tensor-parallel transitions as fp32
# all-reduce + slice (observed 16x the minimal traffic); these shard_map
# helpers pin the exact collective (bf16 all-gather / psum_scatter on the
# sequence dim) and transpose correctly under AD.
# ---------------------------------------------------------------------------
def sp_all_gather(x: jax.Array, ctx: "MeshCtx") -> jax.Array:
    """[B, S(seq_sp-sharded), d] -> [B, S, d] gathered, in x.dtype."""
    if ctx.mesh is None or ctx.axis_size("seq_sp") == 1:
        return x
    mesh = ctx.mesh
    in_spec = ctx.pspec(x.shape, "batch", "seq_sp", None)
    out_spec = ctx.pspec(x.shape, "batch", None, None)

    def f(xl):
        return jax.lax.all_gather(xl, "model", axis=1, tiled=True)

    return shard_map(f, mesh=mesh, in_specs=(in_spec,), out_specs=out_spec,
                     check_rep=False)(x)


def row_parallel_out_proj(x: jax.Array, w: jax.Array, ctx: "MeshCtx",
                          in_logical: str = "qkv_out") -> jax.Array:
    """y = x @ w with the contraction dim sharded over ``model``: partial
    products psum_scatter (bf16) straight into the seq-sharded layout.

    x: [B, S, K] (K sharded over model); w: [K(model), d(data-FSDP)].
    Returns [B, S(seq_sp), d].
    """
    if ctx.mesh is None or ctx.axis_size("seq_sp") == 1:
        return x @ w
    mesh = ctx.mesh
    b, s, k = x.shape
    d = w.shape[1]
    x_spec = ctx.pspec(x.shape, "batch", None, in_logical)
    w_spec = ctx.pspec(w.shape, in_logical, "embed_fsdp")
    out_spec = ctx.pspec((b, s, d), "batch", "seq_sp", None)
    fsdp_axes = ctx.used_axes(d, "embed_fsdp")

    def f(xl, wl):
        if fsdp_axes:
            wl = jax.lax.all_gather(wl, fsdp_axes, axis=1, tiled=True)
        part = jnp.einsum("bsk,kd->bsd", xl, wl,
                          preferred_element_type=jnp.float32)
        return jax.lax.psum_scatter(part.astype(xl.dtype), "model",
                                    scatter_dimension=1, tiled=True)

    return shard_map(f, mesh=mesh, in_specs=(x_spec, w_spec),
                     out_specs=out_spec, check_rep=False)(x, w)


# optimization_barrier has no differentiation rule on older jax (< 0.5);
# this custom_vjp applies the barrier on both the primal and the cotangent,
# which is also what newer jax's built-in rule does.
@jax.custom_vjp
def opt_barrier(x: jax.Array) -> jax.Array:
    return jax.lax.optimization_barrier(x)


def _opt_barrier_fwd(x):
    return opt_barrier(x), None


def _opt_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


opt_barrier.defvjp(_opt_barrier_fwd, _opt_barrier_bwd)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def pad_to_multiple(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# Vocab/row-sharded embedding lookup (JAX has no EmbeddingBag / sharded gather
# primitive — this masked-psum lookup IS the system's embedding engine, used
# by the LM input embedding and every recsys table.)
# ---------------------------------------------------------------------------
def sharded_embedding_lookup(
    table: jax.Array,          # [V, d], rows sharded over `row_axes`
    ids: jax.Array,            # int32 [...], sharded over batch axes on dim 0
    ctx: MeshCtx,
    row_logical: str = "vocab",
    ids_logical: tuple[Optional[str], ...] = ("batch",),
    compute_dtype: Any = jnp.bfloat16,
    scatter_dim_logical: Optional[str] = None,
) -> jax.Array:
    """out[..., :] = table[ids] with the table row-sharded.

    Every row shard looks up the ids that fall in its range (clipped take +
    validity mask) and the partial results are psum'd over the row axes —
    the standard TPU vocab-parallel embedding pattern. Row axes must be
    disjoint from the ids' batch axes (enforced by the "table_rows"/"vocab"
    rules mapping to "model" only).

    ``scatter_dim_logical`` (hillclimb A1): when the consumer wants dim 1 of
    the output sharded over the SAME axes (e.g. the LM residual stream is
    seq-sharded over "model" = the vocab axes), a psum_scatter delivers it
    directly — 16x less reduce traffic than psum + slice.
    """
    if ctx.mesh is None or ctx.axis_size(row_logical) == 1:
        # clip like production embedding engines (hash collisions fold into
        # the last row rather than poisoning the batch with NaN fills)
        return jnp.take(table, ids, axis=0, mode="clip").astype(compute_dtype)

    mesh = ctx.mesh
    row_rule = ctx.rules[row_logical]
    row_axes = (row_rule,) if isinstance(row_rule, str) else tuple(row_rule)
    row_axes = tuple(a for a in row_axes if a in mesh.shape)
    n_shards = 1
    for a in row_axes:
        n_shards *= mesh.shape[a]
    assert table.shape[0] % n_shards == 0, (table.shape, n_shards)

    scatter = (scatter_dim_logical is not None and ids.ndim >= 2
               and ids.shape[1] % n_shards == 0
               and ctx.used_axes(ids.shape[1], scatter_dim_logical) == row_axes)

    table_spec = ctx.pspec(table.shape, row_logical, *([None] * (table.ndim - 1)))
    ids_spec = ctx.pspec(ids.shape, *ids_logical, *([None] * (ids.ndim - len(ids_logical))))
    out_shape = ids.shape + table.shape[1:]
    out_logical = list(ids_logical) + [None] * (len(out_shape) - len(ids_logical))
    if scatter:
        out_logical[1] = scatter_dim_logical
    out_spec = ctx.pspec(out_shape, *out_logical)

    def local(tbl, ids_l):
        vloc = tbl.shape[0]
        # linear shard index over the row axes
        shard = jnp.zeros((), jnp.int32)
        for a in row_axes:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        start = shard * vloc
        rel = ids_l - start
        valid = (rel >= 0) & (rel < vloc)
        rel = jnp.clip(rel, 0, vloc - 1)
        out = jnp.take(tbl.astype(compute_dtype), rel, axis=0, mode="clip")
        out = jnp.where(valid[..., None], out, 0)
        if scatter:
            return jax.lax.psum_scatter(out, row_axes, scatter_dimension=1,
                                        tiled=True)
        return jax.lax.psum(out, row_axes)

    fn = shard_map(local, mesh=mesh, in_specs=(table_spec, ids_spec),
                   out_specs=out_spec, check_rep=False)
    return fn(table, ids)


def embedding_bag(
    table: jax.Array,           # [V, d]
    ids: jax.Array,             # [B, L] int32 multi-hot bags (padded)
    lengths: jax.Array,         # [B] valid prefix length per bag
    ctx: MeshCtx,
    mode: str = "mean",
    row_logical: str = "table_rows",
    compute_dtype: Any = jnp.bfloat16,
) -> jax.Array:
    """torch.nn.EmbeddingBag equivalent: gather + masked segment reduce.

    Returns [B, d]. The bag reduction commutes with the cross-shard psum,
    so it runs INSIDE the lookup shard_map: the collective moves [B, d]
    instead of [B, L, d] — an L-fold traffic cut (hillclimb B1, measured
    ~15x on two-tower serve_bulk; EXPERIMENTS.md §Perf)."""
    b, l = ids.shape
    if ctx.mesh is None or ctx.axis_size(row_logical) == 1:
        e = jnp.take(table, ids, axis=0, mode="clip").astype(compute_dtype)
        mask = (jnp.arange(l)[None, :] < lengths[:, None]).astype(e.dtype)
        s = jnp.sum(e * mask[..., None], axis=1)
    else:
        mesh = ctx.mesh
        row_rule = ctx.rules[row_logical]
        row_axes = (row_rule,) if isinstance(row_rule, str) else tuple(row_rule)
        row_axes = tuple(a for a in row_axes if a in mesh.shape)
        table_spec = ctx.pspec(table.shape, row_logical, None)
        ids_spec = ctx.pspec(ids.shape, "batch", None)
        len_spec = ctx.pspec(lengths.shape, "batch")
        out_spec = ctx.pspec((b, table.shape[1]), "batch", None)

        def local(tbl, ids_l, len_l):
            vloc = tbl.shape[0]
            shard = jnp.zeros((), jnp.int32)
            for a in row_axes:
                shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
            rel = ids_l - shard * vloc
            valid = (rel >= 0) & (rel < vloc)
            rel = jnp.clip(rel, 0, vloc - 1)
            e = jnp.take(tbl.astype(compute_dtype), rel, axis=0, mode="clip")
            mask = valid & (jnp.arange(ids_l.shape[1])[None, :]
                            < len_l[:, None])
            partial = jnp.einsum("bld,bl->bd", e,
                                 mask.astype(e.dtype))
            return jax.lax.psum(partial, row_axes)  # [B_loc, d] only

        fn = shard_map(local, mesh=mesh,
                       in_specs=(table_spec, ids_spec, len_spec),
                       out_specs=out_spec, check_rep=False)
        s = fn(table, ids, lengths)
    if mode == "sum":
        return s
    if mode == "mean":
        return s / jnp.maximum(lengths[:, None].astype(s.dtype), 1)
    raise ValueError(mode)
