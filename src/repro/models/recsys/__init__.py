from . import autoint, bst, common, mind, two_tower
