"""Two-tower retrieval (Yi et al., RecSys'19) with in-batch sampled softmax.

User tower: user embedding + history EmbeddingBag -> MLP -> L2-norm.
Item tower: item embedding -> MLP -> L2-norm. Training uses in-batch
negatives; serving scores dot products; ``retrieval_cand`` pushes one user
against 1M candidate ids through the sharded scan + top-k engine — the
paper's RAE slots in right there (encode both sides, scan in R^m).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...configs.base import RecsysConfig
from ...distributed.partitioning import ParamDef, init_from_schema
from ..common import MeshCtx
from . import common as rc


def schema(cfg: RecsysConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    d = cfg.embed_dim
    s = dict(rc.table_schema(cfg))
    u_dims = (2 * d,) + cfg.mlp_dims  # user id emb + hist bag
    i_dims = (d,) + cfg.mlp_dims
    s.update(rc.mlp_schema("user_mlp", u_dims, pdt))
    s.update(rc.mlp_schema("item_mlp", i_dims, pdt))
    return s


def init(cfg: RecsysConfig, key: jax.Array):
    return init_from_schema(schema(cfg), key)


def user_tower(params, batch, cfg: RecsysConfig, ctx: MeshCtx) -> jax.Array:
    cdt = jnp.bfloat16
    ue = rc.lookup(params, "user", batch["user"], ctx, cdt)
    hb = rc.bag_lookup(params, "hist_item", batch["hist"], batch["hist_len"],
                       ctx, mode="mean", compute_dtype=cdt)
    x = jnp.concatenate([ue, hb], axis=-1)
    x = rc.apply_mlp(params, "user_mlp", x, len(cfg.mlp_dims))
    return rc.l2norm(x.astype(jnp.float32))


def item_tower(params, item_ids, cfg: RecsysConfig, ctx: MeshCtx) -> jax.Array:
    cdt = jnp.bfloat16
    ie = rc.lookup(params, "item", item_ids, ctx, cdt)
    x = rc.apply_mlp(params, "item_mlp", ie, len(cfg.mlp_dims))
    return rc.l2norm(x.astype(jnp.float32))


def loss_fn(params, batch, cfg: RecsysConfig, ctx: MeshCtx):
    u = user_tower(params, batch, cfg, ctx)
    v = item_tower(params, batch["item"], cfg, ctx)
    loss = rc.in_batch_softmax_loss(u, v, ctx)
    return loss, {}


def serve(params, batch, cfg: RecsysConfig, ctx: MeshCtx) -> jax.Array:
    """Pairwise scores for a (user, item) batch."""
    u = user_tower(params, batch, cfg, ctx)
    v = item_tower(params, batch["item"], cfg, ctx)
    return jnp.einsum("bd,bd->b", u, v)


def retrieval_scores(params, batch, cfg: RecsysConfig, ctx: MeshCtx
                     ) -> jax.Array:
    """One user vs n_candidates item ids -> [n_candidates] scores."""
    u = user_tower(params, batch, cfg, ctx)  # [1, d]
    cands = item_tower(params, batch["candidates"], cfg, ctx)  # [N, d]
    cands = ctx.constrain(cands, "db_rows", None)
    return cands @ u[0]
