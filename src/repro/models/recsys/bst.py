"""BST — Behavior Sequence Transformer (arXiv:1905.06874).

Target item is appended to the behavior sequence; one transformer block
(8 heads over embed_dim=32) models target-aware interactions; all outputs
concat with user/context embeddings feed the 1024-512-256 MLP -> CTR logit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...configs.base import RecsysConfig
from ...distributed.partitioning import ParamDef, init_from_schema
from ..common import MeshCtx, rms_norm
from . import common as rc


def schema(cfg: RecsysConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    d = cfg.embed_dim
    s = dict(rc.table_schema(cfg))
    s["pos_embed"] = ParamDef((cfg.seq_len + 1, d), (None, None), pdt,
                              init="embed", scale=0.01)
    for blk in range(cfg.n_blocks):
        for nm in ("wq", "wk", "wv", "wo"):
            s[f"blk{blk}_{nm}"] = ParamDef((d, d), (None, None), pdt)
        s[f"blk{blk}_ln1"] = ParamDef((d,), (None,), pdt, init="ones")
        s[f"blk{blk}_ln2"] = ParamDef((d,), (None,), pdt, init="ones")
        s[f"blk{blk}_ffn_w1"] = ParamDef((d, 4 * d), (None, None), pdt)
        s[f"blk{blk}_ffn_w2"] = ParamDef((4 * d, d), (None, None), pdt)
    mlp_in = d * (cfg.seq_len + 1) + 2 * d  # seq outputs + user + category
    dims = (mlp_in,) + cfg.mlp_dims + (1,)
    s.update(rc.mlp_schema("mlp", dims, pdt))
    return s


def init(cfg: RecsysConfig, key: jax.Array):
    return init_from_schema(schema(cfg), key)


def _block(params, blk: int, x, n_heads: int):
    b, s, d = x.shape
    dh = d // n_heads
    h = rms_norm(x, params[f"blk{blk}_ln1"])
    q = (h @ params[f"blk{blk}_wq"].astype(x.dtype)).reshape(b, s, n_heads, dh)
    k = (h @ params[f"blk{blk}_wk"].astype(x.dtype)).reshape(b, s, n_heads, dh)
    v = (h @ params[f"blk{blk}_wv"].astype(x.dtype)).reshape(b, s, n_heads, dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * dh ** -0.5
    p = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, s, d)
    x = x + o @ params[f"blk{blk}_wo"].astype(x.dtype)
    h2 = rms_norm(x, params[f"blk{blk}_ln2"])
    y = jax.nn.relu(h2 @ params[f"blk{blk}_ffn_w1"].astype(x.dtype))
    return x + y @ params[f"blk{blk}_ffn_w2"].astype(x.dtype)


def forward(params, batch, cfg: RecsysConfig, ctx: MeshCtx) -> jax.Array:
    """batch: hist [B,S], item [B], user [B], category [B] -> logit [B]."""
    cdt = jnp.bfloat16
    hist, item = batch["hist"], batch["item"]
    b = item.shape[0]
    if hist.shape[0] == 1 and b > 1:  # retrieval: shared history, many items
        hist = jnp.broadcast_to(hist, (b,) + hist.shape[1:])
    seq_ids = jnp.concatenate([hist, item[:, None]], axis=1)  # [B, S+1]
    x = rc.lookup(params, "item", seq_ids, ctx, cdt)
    x = x + params["pos_embed"].astype(cdt)[None]
    x = ctx.constrain(x, "batch", None, None)
    for blk in range(cfg.n_blocks):
        x = _block(params, blk, x, cfg.n_heads)
    user = rc.lookup(params, "user", batch["user"], ctx, cdt)
    if user.shape[0] == 1 and b > 1:
        user = jnp.broadcast_to(user, (b, user.shape[1]))
    cat = rc.lookup(params, "category", batch["category"], ctx, cdt)
    if cat.shape[0] == 1 and b > 1:
        cat = jnp.broadcast_to(cat, (b, cat.shape[1]))
    feat = jnp.concatenate([x.reshape(b, -1), user, cat], axis=-1)
    logit = rc.apply_mlp(params, "mlp", feat, len(cfg.mlp_dims) + 1)
    return logit[:, 0]


def loss_fn(params, batch, cfg: RecsysConfig, ctx: MeshCtx):
    logit = forward(params, batch, cfg, ctx)
    return rc.bce_loss(logit, batch["label"]), {}


def serve(params, batch, cfg: RecsysConfig, ctx: MeshCtx) -> jax.Array:
    return jax.nn.sigmoid(forward(params, batch, cfg, ctx).astype(jnp.float32))


def retrieval_scores(params, batch, cfg: RecsysConfig, ctx: MeshCtx
                     ) -> jax.Array:
    """Target-aware retrieval: the candidate item is appended to the (shared)
    behavior sequence, so all 1M candidates run the full transformer —
    batched over the mesh, not looped."""
    cands = ctx.constrain(batch["candidates"], "db_rows")
    b = {"hist": batch["hist"], "user": batch["user"],
         "category": batch["category"], "item": cands}
    return forward(params, b, cfg, ctx)
