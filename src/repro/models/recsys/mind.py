"""MIND (arXiv:1904.08030): multi-interest extraction via dynamic-routing
capsules over the user behavior sequence.

Behavior-to-Interest routing (3 iterations, squash nonlinearity, shared
bilinear map), label-aware attention for training (pow-2 softmax over
interests), in-batch sampled softmax loss. Serving scores max over the K=4
interest vectors — ``retrieval_cand`` maxes interests against 1M items.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...configs.base import RecsysConfig
from ...distributed.partitioning import ParamDef, init_from_schema
from ..common import MeshCtx
from . import common as rc


def schema(cfg: RecsysConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    d = cfg.embed_dim
    s = dict(rc.table_schema(cfg))
    s["bilinear"] = ParamDef((d, d), (None, None), pdt)
    # fixed (non-trainable in the paper; trainable-initialized here) routing priors
    s["routing_init"] = ParamDef((cfg.hist_len, cfg.n_interests), (None, None),
                                 pdt, init="normal", scale=1.0)
    dims = (d,) + cfg.mlp_dims
    s.update(rc.mlp_schema("interest_mlp", dims, pdt))
    return s


def init(cfg: RecsysConfig, key: jax.Array):
    return init_from_schema(schema(cfg), key)


def _squash(x):
    n2 = jnp.sum(jnp.square(x), -1, keepdims=True)
    return (n2 / (1 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def interests(params, batch, cfg: RecsysConfig, ctx: MeshCtx) -> jax.Array:
    """hist [B, L], hist_len [B] -> [B, K, d] interest capsules."""
    cdt = jnp.bfloat16
    hist = batch["hist"]
    b, L = hist.shape
    e = rc.lookup(params, "item", hist, ctx, cdt).astype(jnp.float32)
    mask = (jnp.arange(L)[None, :] < batch["hist_len"][:, None])
    u_hat = e @ params["bilinear"].astype(jnp.float32)  # [B, L, d]
    logits = jnp.broadcast_to(params["routing_init"].astype(jnp.float32)[None],
                              (b, L, cfg.n_interests))
    caps = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(logits, axis=-1)
        w = w * mask[..., None]
        z = jnp.einsum("blk,bld->bkd", w, u_hat)
        caps = _squash(z)
        logits = logits + jnp.einsum("bld,bkd->blk", u_hat, caps)
    caps = rc.apply_mlp(params, "interest_mlp", caps, len(cfg.mlp_dims))
    return rc.l2norm(caps)  # [B, K, d_out]


def loss_fn(params, batch, cfg: RecsysConfig, ctx: MeshCtx):
    caps = interests(params, batch, cfg, ctx)  # [B, K, d]
    tgt = rc.lookup(params, "item", batch["item"], ctx).astype(jnp.float32)
    tgt = rc.l2norm(rc.apply_mlp(params, "interest_mlp", tgt,
                                 len(cfg.mlp_dims)))
    # label-aware attention, pow p=2 (paper Eq. 6)
    att = jax.nn.softmax(jnp.square(jnp.einsum("bkd,bd->bk", caps, tgt)) * 16.0,
                         axis=-1)
    u = jnp.einsum("bk,bkd->bd", att, caps)
    loss = rc.in_batch_softmax_loss(rc.l2norm(u), tgt, ctx)
    return loss, {}


def serve(params, batch, cfg: RecsysConfig, ctx: MeshCtx) -> jax.Array:
    """Pairwise max-over-interests scores for a (user, item) batch."""
    caps = interests(params, batch, cfg, ctx)
    tgt = rc.lookup(params, "item", batch["item"], ctx).astype(jnp.float32)
    tgt = rc.l2norm(rc.apply_mlp(params, "interest_mlp", tgt,
                                 len(cfg.mlp_dims)))
    return jnp.max(jnp.einsum("bkd,bd->bk", caps, tgt), axis=-1)


def retrieval_scores(params, batch, cfg: RecsysConfig, ctx: MeshCtx
                     ) -> jax.Array:
    caps = interests(params, batch, cfg, ctx)[0]  # [K, d] one user
    items = rc.lookup(params, "item", batch["candidates"], ctx).astype(jnp.float32)
    items = rc.l2norm(rc.apply_mlp(params, "interest_mlp", items,
                                   len(cfg.mlp_dims)))
    items = ctx.constrain(items, "db_rows", None)
    return jnp.max(items @ caps.T, axis=-1)  # [N]
