"""AutoInt (arXiv:1810.11921): multi-head self-attention over field embeddings.

39 sparse fields (Criteo-style) share one *fused* table with per-field row
offsets — one sharded lookup instead of 39 (the quotient of a real TBE-style
embedding engine). 3 interacting layers, 2 heads, d_attn=32, residual
projections, then flatten -> logit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...configs.base import RecsysConfig
from ...distributed.partitioning import ParamDef, init_from_schema
from ..common import MeshCtx, pad_to_multiple, sharded_embedding_lookup
from . import common as rc


def _field_vocab(cfg: RecsysConfig) -> int:
    # all fields share the hashed per-field vocab in this config
    return pad_to_multiple(cfg.tables[0].vocab, rc.ROW_PAD)


def schema(cfg: RecsysConfig) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    d = cfg.embed_dim
    da = cfg.d_attn
    vp = _field_vocab(cfg)
    s: dict = {
        "table_fields": ParamDef((cfg.n_fields * vp, d), ("table_rows", None),
                                 pdt, init="embed", scale=0.01),
    }
    d_in = d
    for layer in range(cfg.n_attn_layers):
        for nm in ("wq", "wk", "wv"):
            s[f"l{layer}_{nm}"] = ParamDef((d_in, da), (None, None), pdt)
        s[f"l{layer}_wres"] = ParamDef((d_in, da), (None, None), pdt)
        d_in = da
    s["w_out"] = ParamDef((cfg.n_fields * da, 1), (None, None), pdt)
    s["b_out"] = ParamDef((1,), (None,), pdt, init="zeros")
    return s


def init(cfg: RecsysConfig, key: jax.Array):
    return init_from_schema(schema(cfg), key)


def forward(params, batch, cfg: RecsysConfig, ctx: MeshCtx) -> jax.Array:
    """batch: fields [B, 39] int32 -> logit [B]."""
    cdt = jnp.bfloat16
    fields = batch["fields"]
    b = fields.shape[0]
    vp = _field_vocab(cfg)
    fused_ids = fields + (jnp.arange(cfg.n_fields, dtype=fields.dtype) * vp)[None, :]
    x = sharded_embedding_lookup(
        params["table_fields"], fused_ids, ctx, row_logical="table_rows",
        ids_logical=("batch", None), compute_dtype=cdt)  # [B, F, d]
    x = ctx.constrain(x, "batch", None, None)
    nh = cfg.n_heads
    for layer in range(cfg.n_attn_layers):
        da = cfg.d_attn
        dh = da // nh
        q = (x @ params[f"l{layer}_wq"].astype(cdt)).reshape(b, -1, nh, dh)
        k = (x @ params[f"l{layer}_wk"].astype(cdt)).reshape(b, -1, nh, dh)
        v = (x @ params[f"l{layer}_wv"].astype(cdt)).reshape(b, -1, nh, dh)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=jnp.float32) * dh ** -0.5
        p = jax.nn.softmax(scores, -1).astype(cdt)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(b, -1, da)
        x = jax.nn.relu(o + x @ params[f"l{layer}_wres"].astype(cdt))
    flat = x.reshape(b, -1)
    logit = flat @ params["w_out"].astype(cdt) + params["b_out"].astype(cdt)
    return logit[:, 0]


def loss_fn(params, batch, cfg: RecsysConfig, ctx: MeshCtx):
    logit = forward(params, batch, cfg, ctx)
    return rc.bce_loss(logit, batch["label"]), {}


def serve(params, batch, cfg: RecsysConfig, ctx: MeshCtx) -> jax.Array:
    return jax.nn.sigmoid(forward(params, batch, cfg, ctx).astype(jnp.float32))


def retrieval_scores(params, batch, cfg: RecsysConfig, ctx: MeshCtx
                     ) -> jax.Array:
    """Candidate field (field 0 = item) varies; the other 38 are one user's
    context broadcast across 1M candidate rows."""
    fixed = batch["fields"]  # [1, 39]
    cands = batch["candidates"]  # [N]
    n = cands.shape[0]
    fields = jnp.broadcast_to(fixed, (n, cfg.n_fields))
    fields = jnp.concatenate([cands[:, None], fields[:, 1:]], axis=1)
    fields = ctx.constrain(fields, "db_rows", None)
    return forward(params, {"fields": fields}, cfg, ctx)
