"""Shared recsys building blocks: sharded tables, MLP towers.

Embedding tables are the hot path (kernel_taxonomy §RecSys): rows are
sharded over the whole mesh ("table_rows" -> (pod, data, model)-resolved
axes) and looked up with the masked-psum engine in ``models.common`` —
JAX's replacement for torch.nn.EmbeddingBag / FBGEMM TBE.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ...configs.base import EmbeddingTableSpec, RecsysConfig
from ...distributed.partitioning import ParamDef
from ..common import (MeshCtx, embedding_bag, pad_to_multiple,
                      sharded_embedding_lookup)

ROW_PAD = 512  # table rows padded so every mesh (256/512 chips) divides them


def table_schema(cfg: RecsysConfig) -> dict[str, ParamDef]:
    pdt = jnp.dtype(cfg.param_dtype)
    out = {}
    for t in cfg.tables:
        out[f"table_{t.name}"] = ParamDef(
            (pad_to_multiple(t.vocab, ROW_PAD), t.dim), ("table_rows", None),
            pdt, init="embed", scale=0.01)
    return out


def mlp_schema(prefix: str, dims: tuple[int, ...], pdt) -> dict[str, ParamDef]:
    out = {}
    for i in range(len(dims) - 1):
        out[f"{prefix}_w{i}"] = ParamDef((dims[i], dims[i + 1]), (None, None), pdt)
        out[f"{prefix}_b{i}"] = ParamDef((dims[i + 1],), (None,), pdt, init="zeros")
    return out


def apply_mlp(params, prefix: str, x: jax.Array, n_layers: int,
              final_act: bool = False) -> jax.Array:
    for i in range(n_layers):
        x = x @ params[f"{prefix}_w{i}"].astype(x.dtype) + \
            params[f"{prefix}_b{i}"].astype(x.dtype)
        if i < n_layers - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def lookup(params, name: str, ids: jax.Array, ctx: MeshCtx,
           compute_dtype=jnp.bfloat16) -> jax.Array:
    ids_logical = ("batch",) + (None,) * (ids.ndim - 1)
    return sharded_embedding_lookup(
        params[f"table_{name}"], ids, ctx, row_logical="table_rows",
        ids_logical=ids_logical, compute_dtype=compute_dtype)


def bag_lookup(params, name: str, ids: jax.Array, lengths: jax.Array,
               ctx: MeshCtx, mode: str = "mean",
               compute_dtype=jnp.bfloat16) -> jax.Array:
    return embedding_bag(params[f"table_{name}"], ids, lengths, ctx,
                         mode=mode, row_logical="table_rows",
                         compute_dtype=compute_dtype)


def bce_loss(logit: jax.Array, label: jax.Array) -> jax.Array:
    logit = logit.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * label
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


def l2norm(x: jax.Array) -> jax.Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-6)


def in_batch_softmax_loss(u: jax.Array, v: jax.Array, ctx: MeshCtx,
                          temp: float = 0.05) -> jax.Array:
    """Sampled-softmax with in-batch negatives: diag(U V^T) are positives.

    Logits [B, B] are sharded (rows over data axes, cols over model) so the
    65536-batch training cell keeps ~70MB/device.
    """
    logits = (u @ v.T).astype(jnp.float32) / temp
    logits = ctx.constrain(logits, "batch", "inbatch_col")
    lse = jax.nn.logsumexp(logits, axis=-1)
    pos = jnp.einsum("bd,bd->b", u.astype(jnp.float32),
                     v.astype(jnp.float32)) / temp
    return jnp.mean(lse - pos)
