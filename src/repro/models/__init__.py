from . import common
