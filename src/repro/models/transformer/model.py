"""Decoder-only transformer LM: dense + MoE, GQA, RoPE, SwiGLU, KV-cache decode.

Sharding (DESIGN.md §5, Megatron-SP style under GSPMD):
  * residual stream between blocks is sequence-sharded over ``model``
    ("seq_sp") — required for qwen3-235B activation memory to fit;
  * projections are TP-sharded on their qkv/mlp feature dims; FSDP shards
    every weight's d_model dim over ``data``; GSPMD inserts the AG/RS pairs;
  * attention runs head-TP or context-parallel (``resolve_scheme``);
  * decode uses the sequence-sharded KV cache (attention.decode_attention);
  * MoE uses scatter dispatch + expert-parallel all-to-all (moe.moe_block).

Layers are scanned (94-layer qwen3 compiles in seconds, not hours); remat
policy is full recompute per layer, so only the per-layer residual stream
(seq-sharded) is retained for backward.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ...configs.base import TransformerConfig
from ...distributed.partitioning import (ParamDef, abstract_from_schema,
                                         init_from_schema)
from ..common import (MeshCtx, NULL_CTX, opt_barrier, pad_to_multiple,
                      rms_norm, row_parallel_out_proj,
                      sharded_embedding_lookup, sp_all_gather)
from . import attention as attn_lib
from . import moe as moe_lib

VOCAB_PAD = 256


def padded_vocab(cfg: TransformerConfig) -> int:
    return pad_to_multiple(cfg.vocab_size, VOCAB_PAD)


def effective_heads(cfg: TransformerConfig, ctx: MeshCtx) -> tuple[int, int]:
    tp = ctx.axis_size("heads")
    h, kh = cfg.n_heads, cfg.n_kv_heads
    if cfg.pad_heads_to_tp and tp > 1 and h % tp != 0:
        return attn_lib.padded_head_layout(h, kh, tp)
    return h, kh


def resolve_scheme(cfg: TransformerConfig, ctx: MeshCtx) -> str:
    if cfg.attention_scheme != "auto":
        return cfg.attention_scheme
    tp = ctx.axis_size("heads")
    h, _ = effective_heads(cfg, ctx)
    return "tp" if (tp <= 1 or h % tp == 0) else "cp"


# ---------------------------------------------------------------------------
# Schema / init
# ---------------------------------------------------------------------------
def schema(cfg: TransformerConfig, ctx: MeshCtx = NULL_CTX) -> dict:
    L, d, dh = cfg.n_layers, cfg.d_model, cfg.d_head
    h, kh = effective_heads(cfg, ctx)
    pdt = jnp.dtype(cfg.param_dtype)
    v = padded_vocab(cfg)
    layers: dict[str, ParamDef] = {
        "ln1": ParamDef((L, d), ("stack", None), pdt, init="ones"),
        "wq": ParamDef((L, d, h * dh), ("stack", "embed_fsdp", "qkv_out"), pdt),
        "wk": ParamDef((L, d, kh * dh), ("stack", "embed_fsdp", "qkv_out"), pdt),
        "wv": ParamDef((L, d, kh * dh), ("stack", "embed_fsdp", "qkv_out"), pdt),
        "wo": ParamDef((L, h * dh, d), ("stack", "qkv_out", "embed_fsdp"), pdt),
        "ln2": ParamDef((L, d), ("stack", None), pdt, init="ones"),
    }
    if cfg.qkv_bias:
        layers["bq"] = ParamDef((L, h * dh), ("stack", "qkv_out"), pdt, init="zeros")
        layers["bk"] = ParamDef((L, kh * dh), ("stack", "qkv_out"), pdt, init="zeros")
        layers["bv"] = ParamDef((L, kh * dh), ("stack", "qkv_out"), pdt, init="zeros")
    if cfg.qk_norm:
        layers["q_norm"] = ParamDef((L, dh), ("stack", None), pdt, init="ones")
        layers["k_norm"] = ParamDef((L, dh), ("stack", None), pdt, init="ones")
    if cfg.family == "moe":
        e, f = cfg.n_experts, cfg.d_ff
        layers["router"] = ParamDef((L, d, e), ("stack", None, None), pdt)
        layers["wg_e"] = ParamDef((L, e, d, f), ("stack", "experts", "embed_fsdp", None), pdt)
        layers["wu_e"] = ParamDef((L, e, d, f), ("stack", "experts", "embed_fsdp", None), pdt)
        layers["wd_e"] = ParamDef((L, e, f, d), ("stack", "experts", None, "embed_fsdp"), pdt)
    else:
        f = cfg.d_ff
        layers["wg"] = ParamDef((L, d, f), ("stack", "embed_fsdp", "mlp"), pdt)
        layers["wu"] = ParamDef((L, d, f), ("stack", "embed_fsdp", "mlp"), pdt)
        layers["wd"] = ParamDef((L, f, d), ("stack", "mlp", "embed_fsdp"), pdt)
    out = {
        "layers": layers,
        "embed": ParamDef((v, d), ("vocab", None), pdt, init="embed"),
        "final_ln": ParamDef((d,), (None,), pdt, init="ones"),
    }
    if not cfg.tie_embeddings:
        # same scale as the tied path (embed.T, std 0.02): init logits stay
        # O(0.02*sqrt(d)) so init xent ~ log(vocab_size) either way.
        out["head"] = ParamDef((d, v), ("embed_fsdp", "vocab"), pdt,
                               init="normal")
    return out


def init(cfg: TransformerConfig, key: jax.Array, ctx: MeshCtx = NULL_CTX):
    return init_from_schema(schema(cfg, ctx), key)


def abstract_params(cfg: TransformerConfig, ctx: MeshCtx = NULL_CTX):
    return abstract_from_schema(schema(cfg, ctx))


# ---------------------------------------------------------------------------
# Layer
# ---------------------------------------------------------------------------
def _project_qkv(h_ln, lp, cfg, ctx, scheme, cdt, h, kh, dh):
    """QKV projections + per-scheme activation sharding constraints."""
    b, s, _ = h_ln.shape
    q = h_ln @ lp["wq"].astype(cdt)
    k = h_ln @ lp["wk"].astype(cdt)
    v = h_ln @ lp["wv"].astype(cdt)
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(cdt)
        k = k + lp["bk"].astype(cdt)
        v = v + lp["bv"].astype(cdt)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, kh, dh)
    v = v.reshape(b, s, kh, dh)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = rms_norm(k, lp["k_norm"], cfg.norm_eps)
    return q, k, v


def decoder_layer(x, lp, cfg: TransformerConfig, ctx: MeshCtx, scheme: str,
                  positions, *, emit_cache: bool = False):
    """One pre-norm block. x: [B, S, d] (seq-sharded between blocks)."""
    # Barrier: without it XLA hoists the rms_norm bf16->f32 convert of the
    # *saved residual stack* out of the backward while loop, materializing a
    # full-precision [L, B, S, d] copy (+6 GiB/dev on qwen3-235B).
    x = opt_barrier(x)
    b, s, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    h, kh = effective_heads(cfg, ctx)
    dh = cfg.d_head

    # Megatron-SP boundary (hillclimb A5): norm in the sequence-sharded
    # region, then an EXPLICIT bf16 all-gather into the TP region. Leaving
    # this to GSPMD resolved the boundary as fp32 all-reduce + slice
    # (~16x the minimal traffic; A4 restructuring was refuted — the fix is
    # pinning the collectives via shard_map).
    h_ln = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if scheme == "tp":
        h_ln = sp_all_gather(h_ln, ctx)
    else:
        h_ln = ctx.constrain(h_ln, "batch", "seq_sp", None)
    q, k, v = _project_qkv(h_ln, lp, cfg, ctx, scheme, cdt, h, kh, dh)
    q = attn_lib.apply_rope(q, positions[None, :], cfg.rope_theta)
    k = attn_lib.apply_rope(k, positions[None, :], cfg.rope_theta)
    o = attn_lib.flash_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk,
                                 ctx=ctx, scheme=scheme)
    o = o.reshape(b, s, h * dh)
    if scheme == "tp":
        # row-parallel wo with explicit bf16 psum_scatter into seq_sp
        o = row_parallel_out_proj(o, lp["wo"].astype(cdt), ctx, "qkv_out")
    else:
        o = o @ lp["wo"].astype(cdt)
        o = ctx.constrain(o, "batch", "seq_sp", None)
    x = x + o

    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        t = ctx.constrain(h2, "batch", "seq_sp", None).reshape(b * s, d)
        t = ctx.constrain(t, "tokens", None)
        y, aux = moe_lib.moe_block(
            t, lp["router"], lp["wg_e"], lp["wu_e"], lp["wd_e"], cfg, ctx)
        y = ctx.constrain(y, "tokens", None).reshape(b, s, d)
        y = ctx.constrain(y, "batch", "seq_sp", None)
    else:
        if scheme == "tp":
            h2 = sp_all_gather(h2, ctx)
        else:
            h2 = ctx.constrain(h2, "batch", None, None)
        g = h2 @ lp["wg"].astype(cdt)
        u = h2 @ lp["wu"].astype(cdt)
        g = ctx.constrain(g, "batch", None, "mlp")
        u = ctx.constrain(u, "batch", None, "mlp")
        hmid = jax.nn.silu(g.astype(jnp.float32)).astype(cdt) * u
        if scheme == "tp":
            y = row_parallel_out_proj(hmid, lp["wd"].astype(cdt), ctx, "mlp")
        else:
            y = hmid @ lp["wd"].astype(cdt)
            y = ctx.constrain(y, "batch", "seq_sp", None)
        aux = {}
    x = x + y
    if emit_cache:
        kc = ctx.constrain(k, "batch", "kv_seq", None, None)
        vc = ctx.constrain(v, "batch", "kv_seq", None, None)
        return x, aux, (kc.astype(cdt), vc.astype(cdt))
    return x, aux, None


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------
def _cast_layer_stack(layers: dict, cfg: TransformerConfig) -> dict:
    """One-time bf16 cast of the stacked layer weights before the scan: the
    per-layer FSDP all-gathers then move bf16 instead of fp32 (halves the
    dominant collective traffic + gather transients). The router stays fp32
    for routing stability; fp32 masters are untouched (grads flow back
    through the cast)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    if cdt == jnp.float32:
        return layers
    keep = {"router"}
    return {k: (v if (k in keep or v.dtype != jnp.float32) else v.astype(cdt))
            for k, v in layers.items()}


def _aux_zero():
    return {"load_balance": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32),
            "frac_dropped": jnp.zeros((), jnp.float32)}


def forward_hidden(params, tokens, cfg: TransformerConfig, ctx: MeshCtx,
                   *, emit_cache: bool = False):
    """tokens [B, S] -> hidden [B, S, d] (+ per-layer aux means, + cache)."""
    b, s = tokens.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    x = sharded_embedding_lookup(params["embed"], tokens, ctx,
                                 row_logical="vocab",
                                 ids_logical=("batch", None),
                                 compute_dtype=cdt,
                                 scatter_dim_logical="seq_sp")
    x = ctx.constrain(x, "batch", "seq_sp", None)
    positions = jnp.arange(s)
    scheme = resolve_scheme(cfg, ctx)
    layers = _cast_layer_stack(params["layers"], cfg)

    def body(xc, lp):
        y, aux, cache = decoder_layer(xc, lp, cfg, ctx, scheme, positions,
                                      emit_cache=emit_cache)
        if not aux:
            aux = _aux_zero()
        return y, (aux, cache)

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.scan_layers:
        x, (aux_l, cache) = jax.lax.scan(body, x, layers)
        aux = {k_: v.mean() for k_, v in aux_l.items()}
    else:
        auxes, caches_k, caches_v = [], [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], layers)
            x, (aux_i, cache_i) = body(x, lp)
            auxes.append(aux_i)
            if emit_cache:
                caches_k.append(cache_i[0])
                caches_v.append(cache_i[1])
        aux = {k_: jnp.mean(jnp.stack([a[k_] for a in auxes]))
               for k_ in auxes[0]}
        cache = (jnp.stack(caches_k), jnp.stack(caches_v)) if emit_cache else None

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    return x, aux, cache


def _head_matrix(params, cfg, cdt):
    if cfg.tie_embeddings:
        return params["embed"].astype(cdt).T  # [d, Vp]
    return params["head"].astype(cdt)


def loss_fn(params, batch, cfg: TransformerConfig, ctx: MeshCtx):
    """Token-chunked causal-LM cross entropy (+ MoE aux losses)."""
    tokens, targets = batch["tokens"], batch["targets"]
    b, s = tokens.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    hidden, aux, _ = forward_hidden(params, tokens, cfg, ctx)
    hidden = ctx.constrain(hidden, "batch", None, None)
    w = _head_matrix(params, cfg, cdt)  # [d, Vp]
    vp = w.shape[1]
    vr = cfg.vocab_size

    c = cfg.xent_chunk or min(s, 512)
    nc = s // c
    assert nc * c == s, (s, c)
    hs = jnp.moveaxis(hidden.reshape(b, nc, c, -1), 1, 0)
    ts = jnp.moveaxis(targets.reshape(b, nc, c), 1, 0)

    def body(tot, inp):
        h_c, t_c = inp
        logits = (h_c @ w).astype(jnp.float32)  # [B, C, Vp]
        logits = ctx.constrain(logits, "batch", None, "vocab")
        col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        logits = jnp.where(col < vr, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.sum(jnp.where(col == t_c[..., None], logits, 0.0), axis=-1)
        return tot + jnp.sum(lse - gold), None

    # remat: recompute each chunk's logits in backward instead of saving
    # [B, C, V/16] blocks per chunk
    total, _ = jax.lax.scan(
        jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable),
        jnp.zeros((), jnp.float32), (hs, ts))
    xent = total / (b * s)
    loss = xent
    if cfg.family == "moe":
        loss = (loss + cfg.router_aux_weight * aux["load_balance"]
                + cfg.router_z_weight * aux["router_z"])
    metrics = {"xent": xent, **aux}
    return loss, metrics


def make_train_step(cfg: TransformerConfig, ctx: MeshCtx, opt):
    ga = max(cfg.grad_accum, 1)

    if ga == 1:
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, cfg, ctx)
            params, opt_state, om = opt.update(grads, opt_state, params)
            return params, opt_state, {"loss": loss, **metrics, **om}

        return train_step

    def train_step(params, opt_state, batch):
        """Gradient accumulation over ga microbatches (hillclimb A2):
        activation stacks shrink by ga; grads accumulate in bf16."""
        micro = jax.tree.map(
            lambda x: x.reshape((ga, x.shape[0] // ga) + x.shape[1:]), batch)

        def body(carry, mb):
            gsum, lsum = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb, cfg, ctx)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(a.dtype), gsum, g)
            return (gsum, lsum + loss), metrics

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
        (gsum, lsum), metrics_l = jax.lax.scan(
            body, (g0, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) / ga), gsum)
        params, opt_state, om = opt.update(grads, opt_state, params)
        metrics = {k: v.mean() for k, v in metrics_l.items()}
        return params, opt_state, {"loss": lsum / ga, **metrics, **om}

    return train_step


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------
class DecodeState(NamedTuple):
    k: jax.Array  # [L, B, Smax, kh, dh]
    v: jax.Array
    length: jax.Array  # scalar int32


def prefill(params, tokens, cfg: TransformerConfig, ctx: MeshCtx):
    """Returns (last-token logits, pooled embedding, DecodeState)."""
    hidden, _, cache = forward_hidden(params, tokens, cfg, ctx,
                                      emit_cache=True)
    cdt = jnp.dtype(cfg.compute_dtype)
    last = hidden[:, -1, :]
    logits = (last @ _head_matrix(params, cfg, cdt)).astype(jnp.float32)
    pooled = hidden.mean(axis=1).astype(jnp.float32)
    embed = pooled / jnp.maximum(
        jnp.linalg.norm(pooled, axis=-1, keepdims=True), 1e-6)
    ks, vs = cache
    state = DecodeState(k=ks, v=vs,
                        length=jnp.asarray(tokens.shape[1], jnp.int32))
    return logits, embed, state


def decode_layer(x, lp, k_cache, v_cache, cur_len, cfg, ctx, seq_logical):
    """Single-token decode block. x: [B, d]."""
    b, d = x.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    h, kh = effective_heads(cfg, ctx)
    dh = cfg.d_head

    h_ln = rms_norm(x, lp["ln1"], cfg.norm_eps)[:, None, :]  # [B, 1, d]
    q, k, v = _project_qkv(h_ln, lp, cfg, ctx, "decode", cdt, h, kh, dh)
    pos = jnp.full((1, 1), cur_len, jnp.int32)
    q = attn_lib.apply_rope(q, pos, cfg.rope_theta)
    k = attn_lib.apply_rope(k, pos, cfg.rope_theta)
    q, k_new, v_new = q[:, 0], k[:, 0].astype(cdt), v[:, 0].astype(cdt)

    o, k2, v2 = attn_lib.decode_attention(
        q, k_cache, v_cache, k_new, v_new, cur_len, ctx, seq_logical)
    o = o.reshape(b, h * dh) @ lp["wo"].astype(cdt)
    x = x + ctx.constrain(o, "batch", None)

    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        t = ctx.constrain(h2, "tokens", None)
        t_shards = max(ctx.shards_for(b, "tokens"), 1)
        y, aux = moe_lib.moe_block(
            t, lp["router"], lp["wg_e"], lp["wu_e"], lp["wd_e"], cfg, ctx,
            capacity_override=max(b // t_shards, 1))  # drop-free at decode
    else:
        g = h2 @ lp["wg"].astype(cdt)
        u = h2 @ lp["wu"].astype(cdt)
        g = ctx.constrain(g, "batch", "mlp")
        u = ctx.constrain(u, "batch", "mlp")
        y = (jax.nn.silu(g.astype(jnp.float32)).astype(cdt) * u) @ lp["wd"].astype(cdt)
        aux = {}
    x = x + ctx.constrain(y, "batch", None)
    return x, (k2, v2)


def decode_step(params, state: DecodeState, tokens, cfg: TransformerConfig,
                ctx: MeshCtx, seq_logical: str = "kv_seq"):
    """One decode step: tokens [B] -> (logits [B, Vp], embed, new state)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = sharded_embedding_lookup(params["embed"], tokens, ctx,
                                 row_logical="vocab", ids_logical=("batch",),
                                 compute_dtype=cdt)
    x = ctx.constrain(x, "batch", None)
    cur_len = state.length
    layers = _cast_layer_stack(params["layers"], cfg)

    def body(xc, inp):
        lp, kc, vc = inp
        y, (k2, v2) = decode_layer(xc, lp, kc, vc, cur_len, cfg, ctx,
                                   seq_logical)
        return y, (k2, v2)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(body, x, (layers, state.k, state.v))
    else:
        ks_l, vs_l = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], layers)
            x, (k2, v2) = body(x, (lp, state.k[i], state.v[i]))
            ks_l.append(k2)
            vs_l.append(v2)
        ks, vs = jnp.stack(ks_l), jnp.stack(vs_l)

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = (x @ _head_matrix(params, cfg, cdt)).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    embed = xf / jnp.maximum(jnp.linalg.norm(xf, axis=-1, keepdims=True), 1e-6)
    return logits, embed, DecodeState(k=ks, v=vs, length=cur_len + 1)


def abstract_decode_state(cfg: TransformerConfig, batch: int, max_len: int,
                          ctx: MeshCtx = NULL_CTX) -> DecodeState:
    _, kh = effective_heads(cfg, ctx)
    cdt = jnp.dtype(cfg.compute_dtype)
    shape = (cfg.n_layers, batch, max_len, kh, cfg.d_head)
    return DecodeState(
        k=jax.ShapeDtypeStruct(shape, cdt),
        v=jax.ShapeDtypeStruct(shape, cdt),
        length=jax.ShapeDtypeStruct((), jnp.int32),
    )
