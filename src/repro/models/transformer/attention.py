"""Attention: RoPE, flash-style blockwise attention, seq-sharded decode.

Three schemes (DESIGN.md §5):

* head-TP (``tp``)       — heads sharded over ``model`` (train/prefill when
                           divisible); KV heads broadcast-repeated to the
                           full head count so every tensor in the attention
                           core stays 4D with a clean (batch, _, heads, _)
                           sharding. (A 5D (g, kh) grouping is NOT
                           PartitionSpec-expressible when one mesh axis must
                           split across both dims — GSPMD then falls back to
                           "involuntary full rematerialization" reshards,
                           observed as +GBs of collectives in the dry-run.)
* context-parallel (``cp``) — q-sequence sharded over ``model``, K/V
                           gathered (phi3 40H / qwen2 28H: 16 ∤ H).
* decode               — KV cache *sequence*-sharded across chips; grouped
                           (kh-major) per-shard partial softmax stats
                           (m, l, o) merged with pmax/psum inside shard_map.
                           Works for any head count and keeps a 500k-token
                           cache at ~GB/chip.

Flat head index convention (weights are initialized, never imported, so we
define it): h = k_idx * g + g_idx (kh-major) — jnp.repeat(kv, g, axis=2)
produces exactly this order, and the decode path's (kh, g) reshape matches.
The train/prefill kernel is an online-softmax scan over KV chunks — the
scanned dim is always unsharded under either scheme. Softmax stats are fp32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from ..common import MeshCtx, NULL_CTX

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(d_head: int, theta: float) -> jax.Array:
    half = d_head // 2
    return theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, d_head]; positions: broadcastable to [..., S]."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)  # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Train / prefill attention: online-softmax scan over KV chunks
# ---------------------------------------------------------------------------
def flash_attention(
    q: jax.Array,  # [B, S, H, dh]
    k: jax.Array,  # [B, T, K, dh]
    v: jax.Array,  # [B, T, K, dh]
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    kv_chunk: int = 256,
    ctx: MeshCtx = NULL_CTX,
    scheme: str = "tp",
) -> jax.Array:
    b, s, h, dh = q.shape
    _, t, kh, _ = k.shape
    g = h // kh
    assert g * kh == h, (h, kh)
    scale = dh ** -0.5

    if scheme == "tp":
        sp3 = ("batch", None, "heads")          # [B, S, H]
        sp4 = ("batch", None, "heads", None)    # [B, S, H, dh]
        spk = (None, "batch", None, "heads", None)  # chunked KV
    else:  # context parallel: q-seq sharded, kv replicated
        sp3 = ("batch", "seq_sp", None)
        sp4 = ("batch", "seq_sp", None, None)
        spk = (None, "batch", None, None, None)

    if g > 1:  # broadcast KV heads to kh-major full head count
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qs = ctx.constrain((q * scale), *sp4)

    ck = min(kv_chunk, t)
    t_real = t
    if t % ck:  # pad KV to a chunk multiple; padding masked below
        pad = ck - t % ck
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t = t + pad
    nkv = t // ck
    # [nkv, B, ck, H, dh] so scan slices chunks along dim 0
    ks = ctx.constrain(jnp.moveaxis(k.reshape(b, nkv, ck, h, dh), 1, 0), *spk)
    vs = ctx.constrain(jnp.moveaxis(v.reshape(b, nkv, ck, h, dh), 1, 0), *spk)

    q_pos = q_offset + jnp.arange(s)

    def body(carry, inputs):
        m, l, o = carry
        i, kc, vc = inputs
        sblk = jnp.einsum("bshd,bchd->bshc", qs, kc,
                          preferred_element_type=jnp.float32)
        sblk = ctx.constrain(sblk, *sp3, None)
        kv_pos = i * ck + jnp.arange(ck)
        if causal:
            mask = (q_pos[:, None] >= kv_pos[None, :])  # [S, ck]
            if t_real < t:
                mask = mask & (kv_pos[None, :] < t_real)
            sblk = jnp.where(mask[None, :, None, :], sblk, NEG_INF)
        elif t_real < t:
            mask = jnp.broadcast_to(kv_pos[None, :] < t_real, (s, ck))
            sblk = jnp.where(mask[None, :, None, :], sblk, NEG_INF)
        m_new = ctx.constrain(jnp.maximum(m, sblk.max(-1)), *sp3)
        p = jnp.exp(sblk - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = ctx.constrain(l * alpha + p.sum(-1), *sp3)
        o = o * alpha[..., None] + jnp.einsum(
            "bshc,bchd->bshd", p, vc, preferred_element_type=jnp.float32)
        o = ctx.constrain(o, *sp4)
        return (m_new, l, o), None

    m0 = ctx.constrain(jnp.full((b, s, h), NEG_INF, jnp.float32), *sp3)
    l0 = ctx.constrain(jnp.zeros((b, s, h), jnp.float32), *sp3)
    o0 = ctx.constrain(jnp.zeros((b, s, h, dh), jnp.float32), *sp4)
    # remat the chunk body: without it the scan's backward saves every
    # chunk's [S, ck] score block — O(S*T) memory, defeating flash entirely
    # (observed +4 GiB/dev on llama train_4k).
    (m, l, o), _ = jax.lax.scan(
        jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable),
        (m0, l0, o0), (jnp.arange(nkv), ks, vs))
    out = o / jnp.maximum(l[..., None], 1e-30)
    return ctx.constrain(out.astype(q.dtype), *sp4)


# ---------------------------------------------------------------------------
# Decode attention: sequence-sharded KV cache, partial-softmax merge
# (grouped kh-major: q [B, kh, g, dh] so the unrepeated cache is reused)
# ---------------------------------------------------------------------------
def _decode_local(q, k_loc, v_loc, k_new, v_new, cur_len, pos_base, s_loc):
    """Per-shard decode attention. Returns (o, l, m) partial stats and the
    locally-updated cache slabs. ``pos_base`` is this shard's first global
    cache position. q: [B, kh, g, dh]."""
    b, kh, g, dh = q.shape
    scale = dh ** -0.5
    qs = q.astype(jnp.float32) * scale

    # -- masked local scores over the cache slab
    gpos = pos_base + jnp.arange(s_loc)  # [s_loc] global positions
    mask = gpos[None, :] < cur_len  # [1, s_loc]
    s_blk = jnp.einsum("bkgd,bskd->bkgs", qs, k_loc.astype(jnp.float32))
    s_blk = jnp.where(mask[:, None, None, :], s_blk, NEG_INF)
    m = jnp.maximum(s_blk.max(-1), NEG_INF)  # [b, kh, g]
    p = jnp.exp(s_blk - m[..., None]) * mask[:, None, None, :]
    l = p.sum(-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_loc.astype(jnp.float32))

    # -- write the new token's KV into the owning shard's slab
    wpos = cur_len - pos_base  # local write index (may be out of range)
    owner = (wpos >= 0) & (wpos < s_loc)
    wclip = jnp.clip(wpos, 0, s_loc - 1)
    cur_k = jax.lax.dynamic_slice_in_dim(k_loc, wclip, 1, axis=1)
    cur_v = jax.lax.dynamic_slice_in_dim(v_loc, wclip, 1, axis=1)
    sel_k = jnp.where(owner, k_new[:, None].astype(k_loc.dtype), cur_k)
    sel_v = jnp.where(owner, v_new[:, None].astype(v_loc.dtype), cur_v)
    k_loc = jax.lax.dynamic_update_slice_in_dim(k_loc, sel_k, wclip, axis=1)
    v_loc = jax.lax.dynamic_update_slice_in_dim(v_loc, sel_v, wclip, axis=1)
    return (o, l, m), (k_loc, v_loc)


def _merge_with_new_token(o, l, m, q, k_new, v_new):
    """Fold the new token's self-attention into merged (o, l, m)."""
    b, kh, g, dh = q.shape
    scale = dh ** -0.5
    qs = q.astype(jnp.float32) * scale
    s_self = jnp.einsum("bkgd,bkd->bkg", qs, k_new.astype(jnp.float32))
    m2 = jnp.maximum(m, s_self)
    alpha = jnp.exp(m - m2)
    beta = jnp.exp(s_self - m2)
    l2 = l * alpha + beta
    # v_new [b, kh, dh] broadcasts over the g dim of o [b, kh, g, dh]
    o2 = o * alpha[..., None] + beta[..., None] * v_new[:, :, None].astype(jnp.float32)
    return o2 / jnp.maximum(l2[..., None], 1e-30)


def decode_attention(
    q: jax.Array,        # [B, H, dh] current-token queries (kh-major heads)
    k_cache: jax.Array,  # [B, Smax, K, dh]
    v_cache: jax.Array,
    k_new: jax.Array,    # [B, K, dh]
    v_new: jax.Array,
    cur_len: jax.Array,  # scalar int32: number of tokens already cached
    ctx: MeshCtx,
    seq_logical: str = "kv_seq",
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out [B, H, dh], new_k_cache, new_v_cache)."""
    b, h, dh = q.shape
    _, smax, kh, _ = k_cache.shape
    g = h // kh
    qg = q.reshape(b, kh, g, dh)  # kh-major, matching the repeat() layout

    n_shards = ctx.axis_size(seq_logical) if ctx.mesh is not None else 1
    if ctx.mesh is None or n_shards == 1:
        (o, l, m), (k2, v2) = _decode_local(
            qg, k_cache, v_cache, k_new, v_new, cur_len, 0, smax)
        out = _merge_with_new_token(o, l, m, qg, k_new, v_new)
        return out.reshape(b, h, dh).astype(q.dtype), k2, v2

    mesh = ctx.mesh
    rule = ctx.rules[seq_logical]
    seq_axes = (rule,) if isinstance(rule, str) else tuple(rule)
    seq_axes = tuple(a for a in seq_axes if a in mesh.shape)
    s_loc = smax
    for a in seq_axes:
        s_loc //= mesh.shape[a]

    c_spec = ctx.pspec(k_cache.shape, "batch", seq_logical, None, None)
    n_spec = ctx.pspec(k_new.shape, "batch", None, None)

    def fn(qg_l, kc, vc, kn, vn, clen):
        shard = jnp.zeros((), jnp.int32)
        for a in seq_axes:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        pos_base = shard * s_loc
        (o, l, m), (k2, v2) = _decode_local(
            qg_l, kc, vc, kn, vn, clen, pos_base, s_loc)
        # merge partial stats across the sequence shards
        m_g = jax.lax.pmax(m, seq_axes)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, seq_axes)
        o_g = jax.lax.psum(o * corr[..., None], seq_axes)
        out = _merge_with_new_token(o_g, l_g, m_g, qg_l, kn, vn)
        return out, k2, v2

    qg_spec = ctx.pspec(qg.shape, "batch", None, None, None)
    fn_sm = shard_map(
        fn, mesh=mesh,
        in_specs=(qg_spec, c_spec, c_spec, n_spec, n_spec, ctx.pspec(())),
        out_specs=(qg_spec, c_spec, c_spec), check_rep=False)
    out, k2, v2 = fn_sm(qg, k_cache, v_cache, k_new, v_new, cur_len)
    return out.reshape(b, h, dh).astype(q.dtype), k2, v2


# ---------------------------------------------------------------------------
# Head-count padding solver (beyond-paper hillclimb: switch cp -> tp)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def padded_head_layout(n_heads: int, n_kv: int, tp: int) -> tuple[int, int]:
    """Smallest (H', K') with K' >= n_kv, H'/K' >= n_heads/n_kv integral,
    and H' % tp == 0 — makes head-TP legal for awkward head counts."""
    g = n_heads // n_kv
    best: Optional[tuple[int, int]] = None
    for kp in range(n_kv, 4 * n_kv + 1):
        for gp in range(g, 4 * g + 1):
            hp = kp * gp
            if hp >= n_heads and hp % tp == 0:
                if best is None or hp < best[0]:
                    best = (hp, kp)
    assert best is not None
    return best
