from . import attention, model, moe
