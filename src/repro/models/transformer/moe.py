"""Mixture-of-Experts: capacity-based scatter dispatch + expert parallelism.

TPU-native design (DESIGN.md §5): the O(T·E·C) one-hot dispatch einsum of
GShard is memory/FLOP-infeasible at 128 experts, so dispatch is a *local*
sort-free scatter (argsort by expert id -> rank-in-expert -> scatter into a
[E, C_dev, d] buffer with capacity drops), followed by an explicit
all-to-all over the ``model`` axis (expert parallelism). Expert weights are
additionally FSDP-sharded on d_model over ``data`` and gathered by GSPMD at
use. The grouped GEMMs run as plain einsums over the expert-sharded buffer.

Everything is differentiable: gates flow through take_along_axis on the
router probs; scatter/gather transpose to gather/scatter-add; all_to_all
transposes to all_to_all.

Routing: softmax router, top-k with renormalized gates (Qwen3-style),
Switch-style load-balance aux loss + router z-loss.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from ...configs.base import TransformerConfig
from ..common import MeshCtx


class RouteResult(NamedTuple):
    slot: jax.Array    # [T, k] int32 flat slot in the (E*C_dev [+overflow]) buffer
    gates: jax.Array   # [T, k] float32 renormalized top-k gates
    aux: dict[str, jax.Array]


def _route_and_slot(x, router_w, n_experts: int, top_k: int, capacity: int):
    """Local routing + slot assignment for a shard's tokens. x: [t, d]."""
    t = x.shape[0]
    # routing logits accumulate in f32 on the MXU without materializing an
    # f32 copy of the token stream (which the outer scan would then save)
    logits = jnp.einsum("td,de->te", x, router_w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, top_k)  # [t, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)  # [t*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    ranks = jnp.arange(t * top_k) - jnp.searchsorted(sorted_e, sorted_e,
                                                     side="left")
    pos = jnp.zeros_like(ranks).at[order].set(ranks)  # rank within expert
    pos = pos.reshape(t, top_k)
    dropped = pos >= capacity
    slot = jnp.where(dropped, n_experts * capacity, eidx * capacity + pos)

    # aux losses (Switch LB + z-loss), per-token so the caller can mean() them
    me = probs.mean(0)  # [E] mean router prob
    assign = jnp.zeros((n_experts,), jnp.float32).at[flat_e].add(1.0)
    ce = assign / (t * top_k)  # fraction of assignments per expert
    lb = n_experts * jnp.sum(me * ce)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    frac_dropped = dropped.mean()
    aux = {"load_balance": lb, "router_z": z, "frac_dropped": frac_dropped}
    return RouteResult(slot=slot, gates=gates, aux=aux)


def _dispatch_local(x, slot, capacity: int, n_experts: int):
    """Scatter tokens into the [E*C (+1 overflow), d] buffer. x: [t, d]."""
    t, d = x.shape
    k = slot.shape[1]
    token_of = jnp.arange(t * k) // k
    x_rep = jnp.take(x, token_of, axis=0)  # [t*k, d]
    buf = jnp.zeros((n_experts * capacity + 1, d), x.dtype)
    buf = buf.at[slot.reshape(-1)].set(x_rep, mode="drop")
    return buf[: n_experts * capacity].reshape(n_experts, capacity, d)


def _combine_local(y_buf, slot, gates, t: int):
    """Gather expert outputs back to tokens. y_buf: [E, C, d] -> [t, d]."""
    e, c, d = y_buf.shape
    flat = jnp.concatenate(
        [y_buf.reshape(e * c, d), jnp.zeros((1, d), y_buf.dtype)], 0)
    yk = jnp.take(flat, slot.reshape(-1), axis=0).reshape(t, -1, d)
    return jnp.einsum("tkd,tk->td", yk, gates.astype(y_buf.dtype))


def _expert_ffn(buf, wg, wu, wd, compute_dtype):
    """Grouped SwiGLU over the expert dim: buf [E, R, d]; w* [E, d, f]/[E, f, d]."""
    h = jnp.einsum("erd,edf->erf", buf, wg.astype(compute_dtype))
    u = jnp.einsum("erd,edf->erf", buf, wu.astype(compute_dtype))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(compute_dtype) * u
    return jnp.einsum("erf,efd->erd", h, wd.astype(compute_dtype))


def moe_block(
    x: jax.Array,                 # [T, d] tokens (flattened batch*seq)
    router_w: jax.Array,          # [d, E]
    wg: jax.Array, wu: jax.Array, wd: jax.Array,  # [E, d, f] / [E, f, d]
    cfg: TransformerConfig,
    ctx: MeshCtx,
    capacity_override: Optional[int] = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Returns (y [T, d], aux losses)."""
    e, k = cfg.n_experts, cfg.moe_top_k
    t_global, d = x.shape
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    x = x.astype(compute_dtype)

    tok_axes = ctx.used_axes(t_global, "tokens") if ctx.mesh is not None else ()
    n_tok_shards = 1
    for a in tok_axes:
        n_tok_shards *= ctx.mesh.shape[a]
    ep = ctx.axis_size("experts")  # expert-parallel degree (model axis)
    t_loc = t_global // max(n_tok_shards, 1)
    if capacity_override is not None:
        cap = capacity_override
    else:
        cap = max(int(t_loc * k / e * cfg.capacity_factor), 1)
        cap = min(cap, t_loc)  # an expert can get at most t_loc local tokens

    if ctx.mesh is None or (n_tok_shards == 1 and ep == 1):
        route = _route_and_slot(x, router_w, e, k, cap)
        buf = _dispatch_local(x, route.slot, cap, e)
        y_buf = _expert_ffn(buf, wg, wu, wd, compute_dtype)
        y = _combine_local(y_buf, route.slot, route.gates, t_global)
        return y.astype(compute_dtype), {k_: v for k_, v in route.aux.items()}

    mesh = ctx.mesh
    assert e % ep == 0, (e, ep)
    e_loc = e // ep
    tokens_on_model = "model" in tok_axes
    tok_spec = ctx.pspec(x.shape, "tokens", None)
    slot_spec = ctx.pspec((t_global, k), "tokens", None)
    # global buffer: [E, rows, d]; rows dim carries the (pod,data) shards,
    # E carries the model (expert-parallel) shards.
    n_pd = max(n_tok_shards // (ep if tokens_on_model else 1), 1)
    rows_per_shard = (ep * cap) if tokens_on_model else cap
    buf_shape = (e, n_pd * rows_per_shard, d)
    buf_spec = ctx.pspec(buf_shape, "experts", "batch", None)
    aux_keys = ("load_balance", "router_z", "frac_dropped")

    def dispatch(x_l, rw):
        route = _route_and_slot(x_l, rw, e, k, cap)
        buf = _dispatch_local(x_l, route.slot, cap, e)  # [E, cap, d]
        if tokens_on_model:
            # expert-parallel all-to-all: send expert block j to model-peer j
            buf = buf.reshape(ep, e_loc, cap, d)
            recv = jax.lax.all_to_all(buf, "model", split_axis=0,
                                      concat_axis=0, tiled=True)
            recv = jnp.moveaxis(recv, 0, 1).reshape(e_loc, ep * cap, d)
        else:
            # tokens replicated over model: each shard just takes its block
            me = jax.lax.axis_index("model")
            recv = jax.lax.dynamic_slice_in_dim(buf, me * e_loc, e_loc, 0)
        # per-token aux values, broadcast so the outer mean is global
        aux_tok = {k_: jnp.full((x_l.shape[0],), v)
                   for k_, v in route.aux.items()}
        return recv, route.slot, route.gates, aux_tok

    aux_spec = ctx.pspec((t_global,), "tokens")
    disp = shard_map(
        dispatch, mesh=mesh,
        in_specs=(tok_spec, ctx.pspec(router_w.shape, None, None)),
        out_specs=(buf_spec, slot_spec, slot_spec,
                   {k_: aux_spec for k_ in aux_keys}),
        check_rep=False)
    buf, slot, gates, aux_tok = disp(x, router_w)

    # expert GEMMs under GSPMD: E sharded over model; FSDP d gathered on use
    buf = jax.lax.with_sharding_constraint(
        buf, jax.sharding.NamedSharding(mesh, buf_spec))
    y_buf = _expert_ffn(buf, wg, wu, wd, compute_dtype)
    y_buf = jax.lax.with_sharding_constraint(
        y_buf, jax.sharding.NamedSharding(mesh, buf_spec))

    def combine(yb, slot_l, gates_l):
        if tokens_on_model:
            yb = yb.reshape(e_loc, ep, cap, d)
            back = jax.lax.all_to_all(jnp.moveaxis(yb, 1, 0), "model",
                                      split_axis=0, concat_axis=0, tiled=True)
            # back: [ep(expert-shard), e_loc, cap, d] -> [E, cap, d]
            y_full = back.reshape(e, cap, d)
        else:
            # every shard holds outputs for its e_loc experts; sum the rest
            y_full = jax.lax.all_gather(yb, "model", axis=0,
                                        tiled=True)  # [E, cap, d]
        return _combine_local(y_full, slot_l, gates_l, slot_l.shape[0])

    comb = shard_map(
        combine, mesh=mesh,
        in_specs=(buf_spec, slot_spec, slot_spec),
        out_specs=tok_spec, check_rep=False)
    y = comb(y_buf, slot, gates)
    aux = {k_: v.mean() for k_, v in aux_tok.items()}
    return y.astype(compute_dtype), aux
