"""Fingerprint-coverage checker for ``VectorIndex`` subclasses.

The serving cache keys results on ``fingerprint()`` — a content hash of
``_fingerprint_state()`` — so any instance attribute that can change what
``search`` answers MUST be hashed. An attribute that is assigned but
never hashed is a stale-cache bug waiting for a hot swap: two indexes
that differ only in that attribute hash equal, and the engine serves one
index's cached answers for the other.

For every (non-private) subclass of ``VectorIndex`` this checker
statically diffs three attribute sets:

- **assigned**: ``self.X = ...`` anywhere reachable from ``__init__``,
  ``build`` or ``_load`` — transitively through ``self._helper()`` and
  ``super().__init__()`` calls across the statically resolved MRO;
- **covered**: ``self.X`` reads reachable from ``_fingerprint_state``
  and the ``ntotal`` property (``fingerprint()`` hashes both);
- **exempt**: the class-level ``_fp_exempt`` dict, ``{attr: reason}``,
  accumulated over the MRO. An exemption is a *reviewed claim* that the
  attribute cannot change answers (derived state, build-time hyperparams
  already materialized in hashed arrays, host-only bookkeeping) — the
  reason string is mandatory and shows up here in findings.

Rules:

- ``fingerprint-missing``  assigned, not covered, not exempt
- ``stale-exemption``      exempt but never assigned (typo / dead
                           entry), or exempt *and* hashed (the claim is
                           moot — delete it so it can't mask a future
                           regression)
- ``unknown-exemption``    ``_fp_exempt`` is not a literal
                           ``{str: str}`` dict the checker can read
- ``save-coverage``        hashed but never read in ``save`` — a
                           saved+loaded index would fingerprint
                           differently than the live one that wrote it
- ``child-fingerprint``    composite indexes: ``search`` delegates to
                           child indexes held in ``self.X`` (directly,
                           via ``self.X[i]``, or via a loop alias) but
                           ``_fingerprint_state`` never folds the
                           children's ``fingerprint()`` in — swapping a
                           child would not invalidate the serving cache
                           even though the attribute itself is "read"
- ``mutation-epoch``       mutable indexes: an attribute stored by a
                           mutation method (``add`` / ``delete`` /
                           ``insert`` / ``mark_deleted`` / ``rebuild``)
                           but never hashed — the live index mutates,
                           its fingerprint doesn't move, and the serving
                           cache replays pre-mutation answers. Mutation
                           state (the epoch counter, the tombstone mask,
                           the id map) must be fingerprint state.
- ``tuned-policy``         self-tuning indexes: an attribute stored by a
                           tuning entry point (``set_params`` /
                           ``set_operating_point``) but never hashed —
                           applying a tuned operating point (a different
                           ``nprobe`` / ``ef_search`` / ``rerank_k1``)
                           changes what ``search`` answers, so it must
                           move the fingerprint or the serving cache
                           replays answers computed under the old knobs.
"""
from __future__ import annotations

import ast
from typing import Optional

from .findings import Finding
from .purity import _resolve_class
from .pysrc import ClassInfo, ModuleIndex

CHECKER = "fingerprint"
ROOT_CLASS = "VectorIndex"
#: methods whose reachable ``self.X = ...`` stores define the attr set
ASSIGN_ENTRIES = ("__init__", "build", "_load")
#: methods whose reachable ``self.X`` reads count as hashed
COVER_ENTRIES = ("_fingerprint_state", "ntotal")
#: methods that mutate a live index in place; their reachable stores are
#: mutation state and must be hashed (or exempted), else the serving
#: cache replays pre-mutation answers
MUTATION_ENTRIES = ("add", "delete", "insert", "mark_deleted", "rebuild")
#: entry points that apply a tuned operating point to a live index;
#: their reachable stores are answer-changing knobs and must be hashed
#: (or exempted), else a knob change leaves the fingerprint — and the
#: serving cache — pretending nothing happened
TUNE_ENTRIES = ("set_params", "set_operating_point")


def static_mro(ci: ClassInfo, index: ModuleIndex) -> list[ClassInfo]:
    """Depth-first base-class linearization over analyzed classes (C3 is
    overkill for single-inheritance index hierarchies)."""
    out: list[ClassInfo] = []
    seen: set[int] = set()
    stack = [ci]
    while stack:
        c = stack.pop(0)
        if id(c.node) in seen:
            continue
        seen.add(id(c.node))
        out.append(c)
        for base in c.base_names:
            bc = _resolve_class(c.module, base, index)
            if bc is not None:
                stack.append(bc)
    return out


def _is_vector_index(mro: list[ClassInfo]) -> bool:
    return any(c.name == ROOT_CLASS for c in mro[1:])


def method_attr_flows(mro: list[ClassInfo], entry: str
                      ) -> tuple[set[str], set[str]]:
    """(stores, loads) of ``self.X`` reachable from ``entry``, following
    ``self.m()`` (dispatch from the head of the MRO) and ``super().m()``
    (dispatch past the defining class)."""
    stores: set[str] = set()
    loads: set[str] = set()
    visited: set[int] = set()

    def dispatch(start_idx: int, name: str) -> None:
        for i in range(start_idx, len(mro)):
            if name in mro[i].methods:
                fn = mro[i].methods[name]
                if id(fn) not in visited:
                    visited.add(id(fn))
                    walk(i, fn)
                return

    def walk(def_idx: int, fn: ast.FunctionDef) -> None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Attribute) \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id == "self":
                if isinstance(node.ctx, ast.Store):
                    stores.add(node.attr)
                elif isinstance(node.ctx, ast.Load):
                    loads.add(node.attr)
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute):
                f = node.func
                if isinstance(f.value, ast.Name) and f.value.id == "self":
                    dispatch(0, f.attr)
                elif isinstance(f.value, ast.Call) \
                        and isinstance(f.value.func, ast.Name) \
                        and f.value.func.id == "super":
                    dispatch(def_idx + 1, f.attr)

    dispatch(0, entry)
    return stores, loads


def delegated_attrs(mro: list[ClassInfo], entry: str, method: str
                    ) -> set[str]:
    """Attributes ``self.X`` that ``entry`` delegates ``method`` to,
    reachable through the same ``self.m()`` / ``super().m()`` dispatch as
    :func:`method_attr_flows`. Three shapes count, and a bare
    ``obj.method`` reference (no call) counts too, so handing
    ``child.search`` to an executor is still delegation:

    - ``self.X.method``       direct child
    - ``self.X[i].method``    child container, subscripted
    - ``for c in self.X: c.method`` / ``[c.method() for c in self.X]``
      loop or comprehension alias (plain ``Name`` targets; ``zip`` args
      are matched positionally against tuple targets)
    """
    out: set[str] = set()
    visited: set[int] = set()

    def self_attr(node: ast.expr) -> Optional[str]:
        if isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "self":
            return node.attr
        return None

    def dispatch(start_idx: int, name: str) -> None:
        for i in range(start_idx, len(mro)):
            if name in mro[i].methods:
                fn = mro[i].methods[name]
                if id(fn) not in visited:
                    visited.add(id(fn))
                    walk(i, fn)
                return

    def walk(def_idx: int, fn: ast.FunctionDef) -> None:
        aliases: dict[str, str] = {}
        for node in ast.walk(fn):
            if isinstance(node, (ast.For, ast.comprehension)):
                tgt, it = node.target, node.iter
                iters = list(it.args) if isinstance(it, ast.Call) else [it]
                if isinstance(tgt, ast.Name):
                    for i2 in iters:
                        a = self_attr(i2)
                        if a:
                            aliases[tgt.id] = a
                elif isinstance(tgt, ast.Tuple) \
                        and len(tgt.elts) == len(iters):
                    for e, i2 in zip(tgt.elts, iters):
                        a = self_attr(i2)
                        if isinstance(e, ast.Name) and a:
                            aliases[e.id] = a
        for node in ast.walk(fn):
            if isinstance(node, ast.Attribute) and node.attr == method \
                    and isinstance(node.ctx, ast.Load):
                a = self_attr(node.value)
                if a:
                    out.add(a)
                elif isinstance(node.value, ast.Name) \
                        and node.value.id in aliases:
                    out.add(aliases[node.value.id])
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute):
                f = node.func
                if isinstance(f.value, ast.Name) and f.value.id == "self":
                    dispatch(0, f.attr)
                elif isinstance(f.value, ast.Call) \
                        and isinstance(f.value.func, ast.Name) \
                        and f.value.func.id == "super":
                    dispatch(def_idx + 1, f.attr)

    dispatch(0, entry)
    return out


def _exemptions(mro: list[ClassInfo]
                ) -> tuple[dict[str, str], list[Finding]]:
    """Merge ``_fp_exempt`` over the MRO, subclass entries winning."""
    merged: dict[str, str] = {}
    findings: list[Finding] = []
    for c in reversed(mro):
        node = c.class_attr("_fp_exempt")
        if node is None:
            continue
        ok = isinstance(node, ast.Dict) and all(
            isinstance(k, ast.Constant) and isinstance(k.value, str)
            and isinstance(v, ast.Constant) and isinstance(v.value, str)
            for k, v in zip(node.keys, node.values))
        if not ok:
            findings.append(Finding(
                path=c.module.path, line=node.lineno, checker=CHECKER,
                rule="unknown-exemption",
                message=f"{c.name}._fp_exempt must be a literal "
                        "{attr: reason} dict of strings so the checker "
                        "can audit it",
                detail={"class": c.name}))
            continue
        for k, v in zip(node.keys, node.values):
            merged[k.value] = v.value
    return merged, findings


def check_class(ci: ClassInfo, index: ModuleIndex) -> list[Finding]:
    mro = static_mro(ci, index)
    if not _is_vector_index(mro):
        return []
    findings: list[Finding] = []
    line = ci.node.lineno

    assigned: set[str] = set()
    for entry in ASSIGN_ENTRIES:
        assigned |= method_attr_flows(mro, entry)[0]
    covered: set[str] = set()
    for entry in COVER_ENTRIES:
        covered |= method_attr_flows(mro, entry)[1]
    exempt, ex_findings = _exemptions(mro)
    findings.extend(ex_findings)

    for attr in sorted(assigned - covered - set(exempt)):
        findings.append(Finding(
            path=ci.module.path, line=line, checker=CHECKER,
            rule="fingerprint-missing",
            message=f"{ci.name}.{attr} is assigned in "
                    f"{'/'.join(ASSIGN_ENTRIES)} but neither hashed by "
                    "_fingerprint_state() nor exempted in _fp_exempt — "
                    "two indexes differing only in it would collide in "
                    "the serving cache",
            detail={"class": ci.name, "attr": attr}))

    for attr, reason in sorted(exempt.items()):
        if attr not in assigned:
            findings.append(Finding(
                path=ci.module.path, line=line, checker=CHECKER,
                rule="stale-exemption",
                message=f"{ci.name}._fp_exempt[{attr!r}] exempts an "
                        "attribute this class never assigns "
                        f"(reason given: {reason!r})",
                detail={"class": ci.name, "attr": attr}))
        elif attr in covered:
            findings.append(Finding(
                path=ci.module.path, line=line, checker=CHECKER,
                rule="stale-exemption",
                message=f"{ci.name}._fp_exempt[{attr!r}] is moot: the "
                        "attribute IS hashed by _fingerprint_state(); "
                        "delete the exemption so it can't mask a future "
                        "coverage regression",
                detail={"class": ci.name, "attr": attr}))

    children = delegated_attrs(mro, "search", "search")
    fp_children: set[str] = set()
    for entry in COVER_ENTRIES:
        fp_children |= delegated_attrs(mro, entry, "fingerprint")
    for attr in sorted(children - fp_children - set(exempt)):
        findings.append(Finding(
            path=ci.module.path, line=line, checker=CHECKER,
            rule="child-fingerprint",
            message=f"{ci.name}.{attr} holds child index(es) search() "
                    "delegates to, but _fingerprint_state() never folds "
                    "their fingerprint() in — swapping a child would not "
                    "invalidate the serving cache",
            detail={"class": ci.name, "attr": attr}))

    mut_stores: set[str] = set()
    for entry in MUTATION_ENTRIES:
        mut_stores |= method_attr_flows(mro, entry)[0]
    for attr in sorted(mut_stores - covered - set(exempt)):
        findings.append(Finding(
            path=ci.module.path, line=line, checker=CHECKER,
            rule="mutation-epoch",
            message=f"{ci.name}.{attr} is stored by a mutation method "
                    f"({'/'.join(MUTATION_ENTRIES)}) but never hashed by "
                    "_fingerprint_state() — a live mutation would not "
                    "move the fingerprint and the serving cache would "
                    "replay pre-mutation answers",
            detail={"class": ci.name, "attr": attr}))

    tune_stores: set[str] = set()
    for entry in TUNE_ENTRIES:
        tune_stores |= method_attr_flows(mro, entry)[0]
    for attr in sorted(tune_stores - covered - set(exempt)):
        findings.append(Finding(
            path=ci.module.path, line=line, checker=CHECKER,
            rule="tuned-policy",
            message=f"{ci.name}.{attr} is stored by a tuning entry point "
                    f"({'/'.join(TUNE_ENTRIES)}) but never hashed by "
                    "_fingerprint_state() — applying a tuned operating "
                    "point would not move the fingerprint and the serving "
                    "cache would replay answers computed under the old "
                    "knobs",
            detail={"class": ci.name, "attr": attr}))

    saved = method_attr_flows(mro, "save")[1]
    if saved:
        for attr in sorted((covered & assigned) - saved - set(exempt)):
            findings.append(Finding(
                path=ci.module.path, line=line, checker=CHECKER,
                rule="save-coverage",
                message=f"{ci.name}.{attr} is hashed by "
                        "_fingerprint_state() but never read in save() — "
                        "a saved+loaded index would fingerprint "
                        "differently than the instance that wrote it",
                detail={"class": ci.name, "attr": attr}))
    return findings


def check_fingerprints(index: ModuleIndex) -> list[Finding]:
    findings: list[Finding] = []
    for module in index.modules.values():
        for ci in module.classes.values():
            if ci.name.startswith("_") or ci.name == ROOT_CLASS:
                continue
            findings.extend(check_class(ci, index))
    return sorted(findings)
