"""AST module index shared by the static checkers.

Parses every ``.py`` under the analyzed package roots ONCE into
:class:`ModuleInfo` records — source lines, import alias maps, function
and class tables — so the checkers (``purity``, ``fingerprints``) can
resolve names across modules without importing anything. Static analysis
must never execute repo code: importing ``repro.serve`` to inspect it
would spin up jax, and a broken module under lint would crash the linter
instead of producing a finding.

Name resolution is deliberately *syntactic*: aliases come from import
statements, relative imports are resolved against the module's dotted
path, and calls resolve to functions defined in analyzed modules only.
Anything unresolvable (third-party calls, dynamic dispatch) is skipped,
not guessed at — the checkers are tuned so that "couldn't resolve" is
silent and only positively identified hazards fire.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Iterable, Optional


@dataclass
class ClassInfo:
    name: str
    node: ast.ClassDef
    module: "ModuleInfo"
    base_names: list[str] = field(default_factory=list)
    decorator_names: list[str] = field(default_factory=list)

    @property
    def methods(self) -> dict[str, ast.FunctionDef]:
        out = {}
        for stmt in self.node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out[stmt.name] = stmt
        return out

    def class_attr(self, name: str) -> Optional[ast.expr]:
        """Value expression of a class-level ``name = ...`` assignment."""
        for stmt in self.node.body:
            if isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == name:
                        return stmt.value
            elif isinstance(stmt, ast.AnnAssign):
                if (isinstance(stmt.target, ast.Name)
                        and stmt.target.id == name):
                    return stmt.value
        return None


@dataclass
class ModuleInfo:
    dotted: str                 # e.g. "repro.search.ivf"
    path: str                   # repo-relative path
    tree: ast.Module
    source_lines: list[str]
    is_package: bool            # True for __init__.py
    #: ``import x.y as a`` / ``import x`` -> {local name: dotted module}
    module_aliases: dict[str, str] = field(default_factory=dict)
    #: ``from M import f as g`` -> {local name: (resolved M, f)}
    from_imports: dict[str, tuple[str, str]] = field(default_factory=dict)
    functions: dict[str, ast.FunctionDef] = field(default_factory=dict)
    classes: dict[str, ClassInfo] = field(default_factory=dict)

    @property
    def package(self) -> str:
        """Dotted package this module's relative imports resolve against."""
        if self.is_package:
            return self.dotted
        return self.dotted.rsplit(".", 1)[0] if "." in self.dotted else ""


def _dotted_attr(node: ast.expr) -> Optional[str]:
    """'a.b.c' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def _resolve_relative(package: str, level: int, module: Optional[str]) -> str:
    """Resolve ``from <level dots><module> import ...`` against ``package``."""
    parts = package.split(".") if package else []
    if level > 1:
        parts = parts[: len(parts) - (level - 1)]
    base = ".".join(parts)
    if module:
        return f"{base}.{module}" if base else module
    return base


def _index_module(dotted: str, path: str, rel_path: str) -> ModuleInfo:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    tree = ast.parse(source, filename=path)
    info = ModuleInfo(dotted=dotted, path=rel_path, tree=tree,
                      source_lines=source.splitlines(),
                      is_package=os.path.basename(path) == "__init__.py")

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                local = alias.asname or alias.name.split(".")[0]
                # `import jax.numpy as jnp` binds jnp to the submodule;
                # bare `import jax.numpy` binds `jax`
                target = alias.name if alias.asname else \
                    alias.name.split(".")[0]
                info.module_aliases[local] = target
        elif isinstance(node, ast.ImportFrom):
            src = _resolve_relative(info.package, node.level, node.module) \
                if node.level else (node.module or "")
            for alias in node.names:
                if alias.name == "*":
                    continue
                local = alias.asname or alias.name
                info.from_imports[local] = (src, alias.name)

    for stmt in tree.body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            info.functions[stmt.name] = stmt
        elif isinstance(stmt, ast.ClassDef):
            ci = ClassInfo(name=stmt.name, node=stmt, module=info)
            for b in stmt.bases:
                name = _dotted_attr(b)
                if name:
                    ci.base_names.append(name)
            for dec in stmt.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                name = _dotted_attr(target)
                if name:
                    ci.decorator_names.append(name)
            info.classes[stmt.name] = ci
    return info


class ModuleIndex:
    """All analyzed modules, addressable by dotted name."""

    def __init__(self, modules: dict[str, ModuleInfo]):
        self.modules = modules

    @classmethod
    def build(cls, src_root: str, packages: Iterable[str],
              repo_root: Optional[str] = None) -> "ModuleIndex":
        """Index every module under ``src_root/<pkg_path>`` for each dotted
        package in ``packages`` (e.g. ``["repro.kernels", "repro.api"]``).
        Paths in findings are reported relative to ``repo_root``."""
        repo_root = repo_root or os.path.dirname(src_root)
        modules: dict[str, ModuleInfo] = {}
        for pkg in packages:
            pkg_dir = os.path.join(src_root, *pkg.split("."))
            if os.path.isfile(pkg_dir + ".py"):  # plain module, not package
                path = pkg_dir + ".py"
                modules[pkg] = _index_module(
                    pkg, path, os.path.relpath(path, repo_root))
                continue
            for cur, _dirs, files in os.walk(pkg_dir):
                for fn in sorted(files):
                    if not fn.endswith(".py"):
                        continue
                    path = os.path.join(cur, fn)
                    rel_to_pkg = os.path.relpath(path, pkg_dir)
                    parts = rel_to_pkg[:-len(".py")].split(os.sep)
                    if parts[-1] == "__init__":
                        parts = parts[:-1]
                    dotted = ".".join([pkg] + [p for p in parts if p])
                    modules[dotted] = _index_module(
                        dotted, path, os.path.relpath(path, repo_root))
        return cls(modules)

    def get(self, dotted: str) -> Optional[ModuleInfo]:
        return self.modules.get(dotted)

    def resolve_function(self, module: ModuleInfo, func: ast.expr
                         ) -> Optional[tuple[ModuleInfo, ast.FunctionDef]]:
        """Resolve a call target expression to an analyzed function.

        Handles ``f`` (module-level or from-import) and ``alias.f`` where
        ``alias`` is an imported analyzed module. Returns None for
        anything else (builtins, third-party, methods — methods resolve
        via class context in the purity walker)."""
        if isinstance(func, ast.Name):
            name = func.id
            if name in module.functions:
                return module, module.functions[name]
            if name in module.from_imports:
                src, orig = module.from_imports[name]
                target = self.get(src)
                if target and orig in target.functions:
                    return target, target.functions[orig]
                # `from pkg import submodule` spelled as a from-import
                sub = self.get(f"{src}.{orig}")
                if sub is None:
                    return None
            return None
        if isinstance(func, ast.Attribute) and isinstance(func.value,
                                                          ast.Name):
            alias = func.value.id
            target_name = None
            if alias in module.module_aliases:
                target_name = module.module_aliases[alias]
            elif alias in module.from_imports:
                src, orig = module.from_imports[alias]
                target_name = f"{src}.{orig}"
            if target_name:
                target = self.get(target_name)
                if target and func.attr in target.functions:
                    return target, target.functions[func.attr]
        return None

    def sources(self) -> dict[str, list[str]]:
        return {m.path: m.source_lines for m in self.modules.values()}
