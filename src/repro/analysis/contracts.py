"""Kernel-triple contract checker.

Every kernel under ``src/repro/kernels/<name>/`` is a *triple*:

- ``kernel.py``  — the Pallas kernel, public entry ``<name>_pallas``
- ``ops.py``     — the jitted public wrapper ``<name>`` (platform
                   dispatch, padding, k-overflow)
- ``ref.py``     — the oracle ``<name>_ref`` the parity harness diffs
                   against

The contract this checker enforces, so a triple can't silently rot:

1. all three files (plus ``__init__.py``) exist and define their symbol;
2. the ref oracle's signature is the public wrapper's signature minus
   tuning-only parameters (``impl``, ``interpret``, and block sizes
   matching ``b[a-z]``) — same names, same order, so the parity harness
   can call both with one argument dict;
3. pad sentinels come from ``kernels/common.py``: no local
   ``NEG_INF``/``PAD_PENALTY`` re-definition and no raw ``±1e30``
   literal anywhere else under ``kernels/`` (a kernel that drifts to
   ``-inf`` or its own magic constant breaks bitwise parity of padded
   slots across impls);
4. the triple's ``__init__.py`` and the ``repro.kernels`` package both
   re-export the public wrapper;
5. the kernel is registered where CI can see it: named in
   ``tests/test_kernels.py`` (parity harness) and in the
   ``REQUIRED_KERNELS`` list of ``scripts/ci.sh`` (collect gate) —
   an unregistered kernel is dead weight CI never exercises.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Optional

from .findings import Finding
from .pysrc import ModuleIndex, ModuleInfo

CHECKER = "kernel-contract"
KERNELS_PKG = "repro.kernels"
#: modules under kernels/ that are shared infrastructure, not triples
NON_TRIPLE = {"common"}
_TUNING_RE = re.compile(r"^b[a-z]$")
TUNING_PARAMS = {"impl", "interpret"}
#: the only module allowed to define pad sentinels / use the raw literal
SENTINEL_HOME = f"{KERNELS_PKG}.common"
SENTINEL_NAMES = {"NEG_INF", "PAD_ID", "PAD_PENALTY"}
SENTINEL_MAGNITUDE = 1e30


def _params(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


def _strip_tuning(params: list[str]) -> list[str]:
    return [p for p in params
            if p not in TUNING_PARAMS and not _TUNING_RE.match(p)]


def discover_triples(index: ModuleIndex) -> list[str]:
    names = set()
    prefix = KERNELS_PKG + "."
    for dotted in index.modules:
        if not dotted.startswith(prefix):
            continue
        head = dotted[len(prefix):].split(".")[0]
        mod = index.get(prefix + head)
        # a triple is a subpackage (has __init__); plain modules like
        # common.py are shared infrastructure
        if head not in NON_TRIPLE and (mod is None or mod.is_package):
            names.add(head)
    return sorted(names)


def _file_finding(name: str, rel: str, rule: str, msg: str) -> Finding:
    return Finding(path=f"src/repro/kernels/{name}/{rel}", line=0,
                   checker=CHECKER, rule=rule, message=msg,
                   detail={"kernel": name})


def check_triple(index: ModuleIndex, name: str) -> list[Finding]:
    findings: list[Finding] = []
    base = f"{KERNELS_PKG}.{name}"
    parts: dict[str, Optional[ModuleInfo]] = {
        "__init__.py": index.get(base),
        "kernel.py": index.get(f"{base}.kernel"),
        "ops.py": index.get(f"{base}.ops"),
        "ref.py": index.get(f"{base}.ref"),
    }
    for rel, mod in parts.items():
        if mod is None:
            findings.append(_file_finding(
                name, rel, "missing-file",
                f"kernel triple `{name}` is missing {rel}"))
    expected = {"kernel.py": f"{name}_pallas", "ops.py": name,
                "ref.py": f"{name}_ref"}
    fns: dict[str, Optional[ast.FunctionDef]] = {}
    for rel, symbol in expected.items():
        mod = parts[rel]
        if mod is None:
            fns[rel] = None
            continue
        fn = mod.functions.get(symbol)
        fns[rel] = fn
        if fn is None:
            findings.append(Finding(
                path=mod.path, line=0, checker=CHECKER,
                rule="missing-symbol",
                message=f"{rel} must define `{symbol}` "
                        f"(public entry of the `{name}` triple)",
                detail={"kernel": name, "symbol": symbol}))

    ops_fn, ref_fn = fns["ops.py"], fns["ref.py"]
    if ops_fn is not None and ref_fn is not None:
        want = _strip_tuning(_params(ops_fn))
        got = _params(ref_fn)
        if want != got:
            findings.append(Finding(
                path=parts["ref.py"].path, line=ref_fn.lineno,
                checker=CHECKER, rule="signature-mismatch",
                message=f"`{name}_ref{tuple(got)}` must match the public "
                        f"wrapper minus tuning params: expected "
                        f"{tuple(want)}",
                detail={"kernel": name, "expected": want, "actual": got}))

    init = parts["__init__.py"]
    if init is not None:
        hit = init.from_imports.get(name)
        if hit != (f"{base}.ops", name):
            findings.append(Finding(
                path=init.path, line=0, checker=CHECKER,
                rule="missing-reexport",
                message=f"kernels/{name}/__init__.py must re-export "
                        f"`{name}` from .ops",
                detail={"kernel": name}))
    pkg = index.get(KERNELS_PKG)
    if pkg is not None and name not in pkg.from_imports:
        findings.append(Finding(
            path=pkg.path, line=0, checker=CHECKER,
            rule="missing-reexport",
            message=f"repro.kernels/__init__.py must re-export `{name}`",
            detail={"kernel": name}))
    return findings


def check_sentinels(index: ModuleIndex) -> list[Finding]:
    """Pad sentinels live in kernels/common.py and nowhere else."""
    findings = []
    prefix = KERNELS_PKG + "."
    for dotted, mod in index.modules.items():
        if not dotted.startswith(prefix) or dotted == SENTINEL_HOME:
            continue
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) \
                            and tgt.id in SENTINEL_NAMES:
                        findings.append(Finding(
                            path=mod.path, line=node.lineno,
                            checker=CHECKER, rule="pad-sentinel",
                            message=f"`{tgt.id}` re-defined here; import "
                                    "it from repro.kernels.common so all "
                                    "triples share one pad convention",
                            detail={"name": tgt.id}))
            elif isinstance(node, ast.Constant) \
                    and isinstance(node.value, float) \
                    and abs(node.value) == SENTINEL_MAGNITUDE:
                findings.append(Finding(
                    path=mod.path, line=node.lineno, checker=CHECKER,
                    rule="pad-sentinel",
                    message="raw ±1e30 literal; use "
                            "NEG_INF/PAD_PENALTY from "
                            "repro.kernels.common",
                    detail={"value": node.value}))
    return findings


def check_registration(index: ModuleIndex, repo_root: str
                       ) -> list[Finding]:
    findings = []
    triples = discover_triples(index)

    parity_path = os.path.join(repo_root, "tests", "test_kernels.py")
    ci_path = os.path.join(repo_root, "scripts", "ci.sh")
    parity_src = open(parity_path, encoding="utf-8").read() \
        if os.path.exists(parity_path) else ""
    ci_src = open(ci_path, encoding="utf-8").read() \
        if os.path.exists(ci_path) else ""
    m = re.search(r"REQUIRED_KERNELS=\(([^)]*)\)", ci_src)
    required_block = m.group(1) if m else ""

    for name in triples:
        if parity_src and not re.search(rf'"{name}"', parity_src):
            findings.append(Finding(
                path="tests/test_kernels.py", line=0, checker=CHECKER,
                rule="unregistered-parity",
                message=f"kernel `{name}` has no PARITY_CASES entry in "
                        "tests/test_kernels.py — the parity harness "
                        "never diffs it against its ref",
                detail={"kernel": name}))
        if ci_src and not re.search(rf"\b{name}\b", required_block):
            findings.append(Finding(
                path="scripts/ci.sh", line=0, checker=CHECKER,
                rule="unregistered-ci",
                message=f"kernel `{name}` missing from REQUIRED_KERNELS "
                        "in scripts/ci.sh — CI's collect gate would not "
                        "notice its tests vanishing",
                detail={"kernel": name}))
    return findings


def check_contracts(index: ModuleIndex,
                    repo_root: Optional[str] = None) -> list[Finding]:
    findings: list[Finding] = []
    for name in discover_triples(index):
        findings.extend(check_triple(index, name))
    findings.extend(check_sentinels(index))
    if repo_root is not None:
        findings.extend(check_registration(index, repo_root))
    return sorted(findings)
