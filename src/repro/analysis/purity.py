"""jit-purity checker: no host-side constructs in traced code.

Collects every *jit root* — a function decorated with ``@jax.jit`` /
``@functools.partial(jax.jit, ...)``, passed to a ``jax.jit(...)`` call
site, or handed to ``pl.pallas_call`` — and walks the static call graph
reachable from it (module-level calls, imports, ``self.*`` methods with
statically resolved bases, nested defs, and function-valued arguments).
Inside that traced region the following are hazards, not style nits:

- ``print(...)``            fires once per *trace*, not per call, and
                            silently stops firing on cache hits
- ``time.*`` / ``random.*`` evaluated at trace time — the jitted
                            computation bakes in one stale value
- ``numpy.*`` calls         constant-folded at trace time at best; a
                            tracer crash at worst (np.asarray(tracer))
- ``.item()/.tolist()``     forces a device sync + transfer inside the
                            trace, or fails outright under jit
- ``open``/``os.*``         host I/O inside a trace runs at trace time
- ``for x in set(...)``     iteration order is hash-seed dependent, so
                            two processes can trace different programs
                            from identical inputs

Bare attribute access (``np.float32`` as a dtype) is fine — only *calls*
on a numpy alias fire. Unresolvable calls are skipped silently; the
checker only reports positively identified hazards, and a construct it
can't see through can opt out with ``# lint: ignore[<rule>]``.
"""
from __future__ import annotations

import ast
from collections import deque
from typing import Optional

from .findings import Finding
from .pysrc import ClassInfo, ModuleIndex, ModuleInfo, _dotted_attr

CHECKER = "jit-purity"

#: canonical dotted prefix -> rule id (matched on *calls* only)
_BANNED_PREFIXES = {
    "time.": "host-time",
    "numpy.": "host-numpy",
    "random.": "host-random",
    "os.": "host-io",
}
_BANNED_CALLS = {"print": "host-print", "open": "host-io",
                 "input": "host-io"}
_CONCRETIZERS = {"item": ".item()", "tolist": ".tolist()"}


def _canon(module: ModuleInfo, dotted: str) -> str:
    """Expand the leading import alias: ``np.zeros`` -> ``numpy.zeros``."""
    head, _, rest = dotted.partition(".")
    if head in module.module_aliases:
        head = module.module_aliases[head]
    elif head in module.from_imports:
        src, orig = module.from_imports[head]
        head = f"{src}.{orig}"
    return f"{head}.{rest}" if rest else head


def _is_jax_jit(module: ModuleInfo, node: ast.expr) -> bool:
    dotted = _dotted_attr(node)
    return dotted is not None and _canon(module, dotted) == "jax.jit"


def _is_partial(module: ModuleInfo, node: ast.expr) -> bool:
    dotted = _dotted_attr(node)
    return dotted is not None and \
        _canon(module, dotted) == "functools.partial"


def _is_pallas_call(module: ModuleInfo, node: ast.expr) -> bool:
    dotted = _dotted_attr(node)
    return dotted is not None and \
        _canon(module, dotted) == "jax.experimental.pallas.pallas_call"


def _jit_target(module: ModuleInfo, expr: ast.expr) -> Optional[ast.expr]:
    """The function expression inside ``jax.jit(<target>)`` /
    ``partial(jax.jit, ...)`` -- unwraps one level of functools.partial."""
    if isinstance(expr, ast.Call) and _is_partial(module, expr.func) \
            and expr.args:
        return expr.args[0]
    return expr


class _FnScope:
    """Local name bindings inside one function: nested defs plus
    ``name = functools.partial(f, ...)`` / ``name = f`` aliases."""

    def __init__(self, module: ModuleInfo, fn: ast.AST):
        self.defs: dict[str, ast.FunctionDef] = {}
        self.aliases: dict[str, ast.expr] = {}
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                self.defs[node.name] = node
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                tgt = node.targets[0].id
                val = node.value
                if isinstance(val, ast.Call) and _is_partial(module,
                                                             val.func) \
                        and val.args:
                    self.aliases[tgt] = val.args[0]
                elif isinstance(val, ast.Name):
                    self.aliases[tgt] = val

    def resolve(self, expr: ast.expr, depth: int = 0) -> ast.expr:
        if depth < 4 and isinstance(expr, ast.Name) \
                and expr.id in self.aliases:
            return self.resolve(self.aliases[expr.id], depth + 1)
        return expr


def _resolve_method(ci: ClassInfo, name: str, index: ModuleIndex,
                    _seen: Optional[set] = None
                    ) -> Optional[tuple[ModuleInfo, ast.FunctionDef,
                                        ClassInfo]]:
    """Look up a method through statically resolvable base classes."""
    _seen = _seen or set()
    if ci.name in _seen:
        return None
    _seen.add(ci.name)
    if name in ci.methods:
        return ci.module, ci.methods[name], ci
    for base in ci.base_names:
        base_ci = _resolve_class(ci.module, base, index)
        if base_ci is not None:
            hit = _resolve_method(base_ci, name, index, _seen)
            if hit is not None:
                return hit
    return None


def _resolve_class(module: ModuleInfo, dotted: str,
                   index: ModuleIndex) -> Optional[ClassInfo]:
    head, _, rest = dotted.partition(".")
    if not rest:
        if head in module.classes:
            return module.classes[head]
        if head in module.from_imports:
            src, orig = module.from_imports[head]
            target = index.get(src)
            if target and orig in target.classes:
                return target.classes[orig]
        return None
    # alias.Class
    target_name = module.module_aliases.get(head)
    if head in module.from_imports:
        src, orig = module.from_imports[head]
        target_name = f"{src}.{orig}"
    if target_name:
        target = index.get(target_name)
        if target and rest in target.classes:
            return target.classes[rest]
    return None


def collect_roots(index: ModuleIndex
                  ) -> list[tuple[ModuleInfo, ast.AST,
                                  Optional[ClassInfo], str]]:
    """Every function that jax will trace: (module, node, class, why)."""
    roots = []

    def add_decorated(module, fn, ci):
        for dec in fn.decorator_list:
            target = dec
            if isinstance(dec, ast.Call):
                if _is_partial(module, dec.func) and dec.args:
                    target = dec.args[0]
                else:
                    target = dec.func
            if isinstance(target, (ast.Name, ast.Attribute)) \
                    and _is_jax_jit(module, target):
                roots.append((module, fn, ci, f"@jit {fn.name}"))

    for module in index.modules.values():
        for fn in module.functions.values():
            add_decorated(module, fn, None)
        for ci in module.classes.values():
            for fn in ci.methods.values():
                add_decorated(module, fn, ci)

        # call sites: jax.jit(f) / pl.pallas_call(kernel) anywhere
        enclosing: dict[int, tuple[ast.AST, Optional[ClassInfo]]] = {}

        def _map_scope(node, fn, ci):
            for child in ast.iter_child_nodes(node):
                child_fn, child_ci = fn, ci
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    child_fn = child
                elif isinstance(child, ast.ClassDef):
                    child_ci = module.classes.get(child.name, ci)
                enclosing[id(child)] = (child_fn, child_ci)
                _map_scope(child, child_fn, child_ci)

        enclosing[id(module.tree)] = (None, None)
        _map_scope(module.tree, None, None)

        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            is_jit = _is_jax_jit(module, node.func)
            is_pc = _is_pallas_call(module, node.func)
            if not (is_jit or is_pc) or not node.args:
                continue
            host_fn, host_ci = enclosing.get(id(node), (None, None))
            scope = _FnScope(module, host_fn) if host_fn is not None \
                else _FnScope(module, module.tree)
            target = scope.resolve(_jit_target(module, node.args[0]))
            why = "pl.pallas_call" if is_pc else "jax.jit(...)"
            if isinstance(target, ast.Lambda):
                roots.append((module, target, host_ci, why))
            elif isinstance(target, (ast.Name, ast.Attribute)):
                hit = index.resolve_function(module, target)
                if hit is not None:
                    roots.append((hit[0], hit[1], None, why))
                elif isinstance(target, ast.Name) \
                        and target.id in scope.defs:
                    roots.append((module, scope.defs[target.id],
                                  host_ci, why))
    return roots


def _scan(module: ModuleInfo, fn: ast.AST, ci: Optional[ClassInfo],
          root_desc: str, index: ModuleIndex, queue: deque,
          findings: list[Finding]) -> None:
    scope = _FnScope(module, fn)

    def flag(node, rule, msg):
        findings.append(Finding(
            path=module.path, line=node.lineno, checker=CHECKER,
            rule=rule, message=f"{msg} (traced via {root_desc})",
            detail={"module": module.dotted, "root": root_desc}))

    def enqueue_expr(expr):
        expr = scope.resolve(expr)
        if isinstance(expr, ast.Name) and expr.id in scope.defs:
            return  # nested def: already inside this subtree walk
        if isinstance(expr, (ast.Name, ast.Attribute)):
            hit = index.resolve_function(module, expr)
            if hit is not None:
                queue.append((hit[0], hit[1], None, root_desc))
                return
        if isinstance(expr, ast.Attribute) \
                and isinstance(expr.value, ast.Name) \
                and expr.value.id == "self" and ci is not None:
            hit = _resolve_method(ci, expr.attr, index)
            if hit is not None:
                queue.append((hit[0], hit[1], hit[2], root_desc))

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            dotted = _dotted_attr(node.func)
            if dotted is not None:
                canon = _canon(module, dotted)
                if canon in _BANNED_CALLS:
                    flag(node, _BANNED_CALLS[canon],
                         f"host-side `{dotted}(...)` in jit-traced code")
                    continue
                matched = False
                for prefix, rule in _BANNED_PREFIXES.items():
                    if canon.startswith(prefix) or canon == prefix[:-1]:
                        flag(node, rule,
                             f"`{dotted}(...)` resolves to "
                             f"`{canon}` — host-side in jit-traced code")
                        matched = True
                        break
                if matched:
                    continue
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _CONCRETIZERS \
                    and not node.args and not node.keywords:
                flag(node, "host-concretize",
                     f"`{_CONCRETIZERS[node.func.attr]}` concretizes a "
                     "traced value (device sync or TracerError)")
                continue
            enqueue_expr(node.func)
            for arg in node.args:
                if isinstance(arg, (ast.Name, ast.Attribute)):
                    enqueue_expr(arg)
            for kw in node.keywords:
                if isinstance(kw.value, (ast.Name, ast.Attribute)):
                    enqueue_expr(kw.value)
        elif isinstance(node, (ast.For, ast.comprehension)):
            it = node.iter
            is_set = isinstance(it, ast.Set) or (
                isinstance(it, ast.Call)
                and isinstance(it.func, ast.Name)
                and it.func.id in ("set", "frozenset"))
            if is_set:
                line = getattr(node, "lineno", getattr(it, "lineno", 0))
                findings.append(Finding(
                    path=module.path, line=line, checker=CHECKER,
                    rule="set-iteration",
                    message="iterating a set in jit-traced code: order is "
                            f"hash-seed dependent (traced via {root_desc})",
                    detail={"module": module.dotted, "root": root_desc}))


def check_purity(index: ModuleIndex) -> list[Finding]:
    findings: list[Finding] = []
    queue: deque = deque(collect_roots(index))
    visited: set[tuple] = set()
    while queue:
        module, fn, ci, root_desc = queue.popleft()
        key = (module.dotted, fn.lineno, fn.col_offset)
        if key in visited:
            continue
        visited.add(key)
        _scan(module, fn, ci, root_desc, index, queue, findings)
    # one construct can be reached from several roots; report it once
    seen: set[tuple] = set()
    out = []
    for f in sorted(findings):
        k = (f.path, f.line, f.rule)
        if k in seen:
            continue
        seen.add(k)
        out.append(f)
    return out
