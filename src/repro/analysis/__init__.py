"""Static analysis for the repro codebase: jax/Pallas-aware lints.

Three AST checkers (no repo code is imported or executed):

- ``jit-purity``       no host-side constructs reachable from jit /
                       pallas_call roots (:mod:`.purity`)
- ``kernel-contract``  every ``kernels/<name>/`` triple is complete,
                       signature-consistent, pad-canonical and
                       registered in CI (:mod:`.contracts`)
- ``fingerprint``      every ``VectorIndex`` attribute is hashed,
                       exempted, or flagged (:mod:`.fingerprints`)

plus the *runtime* guards in :mod:`.runtime` (compile-count budgets,
transfer guards) used by the regression tests — imported separately so
the lint CLI never pays a jax import.

Entry points: ``scripts/lint.py`` (CLI, gates CI) and
:func:`run_checks` (what the CLI and the pytest bindings call).
"""
from __future__ import annotations

import os
from typing import Iterable, Optional

from .contracts import check_contracts
from .findings import Finding, apply_suppressions
from .fingerprints import check_fingerprints
from .purity import check_purity
from .pysrc import ModuleIndex

CHECKERS = ("jit-purity", "kernel-contract", "fingerprint")
DEFAULT_PACKAGES = ("repro",)

__all__ = ["CHECKERS", "DEFAULT_PACKAGES", "Finding", "ModuleIndex",
           "run_checks"]


def run_checks(src_root: str, repo_root: Optional[str] = None,
               checkers: Optional[Iterable[str]] = None,
               packages: Iterable[str] = DEFAULT_PACKAGES
               ) -> list[Finding]:
    """Run the selected checkers over ``src_root`` and return the
    surviving (non-suppressed) findings, sorted by location."""
    repo_root = repo_root or os.path.dirname(os.path.abspath(src_root))
    selected = set(checkers) if checkers is not None else set(CHECKERS)
    unknown = selected - set(CHECKERS)
    if unknown:
        raise ValueError(f"unknown checker(s) {sorted(unknown)}; "
                         f"known: {list(CHECKERS)}")
    index = ModuleIndex.build(src_root, packages, repo_root)
    findings: list[Finding] = []
    if "jit-purity" in selected:
        findings.extend(check_purity(index))
    if "kernel-contract" in selected:
        findings.extend(check_contracts(index, repo_root))
    if "fingerprint" in selected:
        findings.extend(check_fingerprints(index))
    return sorted(apply_suppressions(findings, index.sources()))
