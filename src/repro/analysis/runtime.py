"""Runtime guards: compile-count budgets and host<->device transfer traps.

The static checkers can prove a traced function is *pure*; they cannot
prove the serving path is *warm* — that a shape storm never triggers a
retrace, or that the hot path never silently ferries a numpy array to
device per query. Those are dynamic properties, asserted here:

- :func:`no_retrace` — a context manager that counts XLA backend
  compiles (via ``jax.monitoring``'s
  ``/jax/core/compile/backend_compile_duration`` event, which fires per
  compile — including retraces — and never on a cache hit) and raises
  :class:`RetraceError` when the block exceeds its budget. Budget 0 is
  the serving invariant: after ``SearchEngine`` warm-up, a mixed-size
  query storm must compile nothing.

- :func:`no_host_to_device` — wraps
  ``jax.transfer_guard_host_to_device("disallow")``. Inside it, passing
  a numpy array to a jitted function (or mixing a python scalar into a
  jit call's arguments) raises instead of silently inserting a per-call
  h2d copy. Explicit transfers (``jnp.asarray`` outside jit) stay
  legal, so staging inputs is allowed and *implicit* per-call traffic
  is not.

jax.monitoring has no per-listener unregister (only a global
``clear_event_listeners``), so the listener is installed once, lazily,
and counts into a module-global — cheap enough to leave attached for
the life of the process.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax

_COMPILE_EVENT_MARKER = "backend_compile"
_compile_events = 0
_listener_installed = False


class RetraceError(RuntimeError):
    """A guarded block compiled more than its budget allows."""


def _ensure_listener() -> None:
    global _listener_installed
    if _listener_installed:
        return

    def _on_duration(event, duration=0.0, **_kw):
        global _compile_events
        if _COMPILE_EVENT_MARKER in event:
            _compile_events += 1

    jax.monitoring.register_event_duration_secs_listener(_on_duration)
    _listener_installed = True


def compile_count() -> int:
    """Process-lifetime count of XLA backend compiles observed so far
    (monotonic; only deltas are meaningful)."""
    _ensure_listener()
    return _compile_events


@contextmanager
def no_retrace(budget: int = 0, what: str = "guarded block"):
    """Assert the block triggers at most ``budget`` backend compiles.

    Yields a zero-arg callable returning the compiles used so far, for
    mid-block introspection::

        with no_retrace(budget=0, what="warm query storm") as used:
            for q in storm:
                engine.search(q, k=10)
                assert used() == 0
    """
    _ensure_listener()
    start = _compile_events
    yield lambda: _compile_events - start
    used = _compile_events - start
    if used > budget:
        raise RetraceError(
            f"{what}: {used} backend compile(s), budget {budget} — "
            "a shape/dtype/static-arg reached jit that warm-up never saw")


@contextmanager
def no_host_to_device():
    """Raise on IMPLICIT host->device transfers inside the block."""
    with jax.transfer_guard_host_to_device("disallow"):
        yield
