"""Finding model shared by every checker and the ``scripts/lint.py`` CLI.

A finding is one violated invariant at one source location. Checkers
return ``list[Finding]``; the CLI sorts, prints (text or JSON) and exits
1 when any survive. Suppression is per-line and explicit: a source line
whose trailing comment contains ``lint: ignore[<rule>]`` (or a bare
``lint: ignore``) drops findings anchored to it — the escape hatch for
the rare construct a static rule can't see through, kept greppable.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Any, Optional

_IGNORE_RE = re.compile(r"#\s*lint:\s*ignore(?:\[(?P<rules>[\w\-, ]+)\])?")


@dataclass(frozen=True, order=True)
class Finding:
    """One violated invariant at one source location."""

    path: str          # repo-relative file path
    line: int          # 1-indexed; 0 = file-level finding
    checker: str       # "jit-purity" | "kernel-contract" | "fingerprint"
    rule: str          # stable machine-readable rule id, e.g. "host-print"
    message: str       # human-readable explanation
    detail: dict[str, Any] = field(default_factory=dict, compare=False)

    def to_dict(self) -> dict[str, Any]:
        return asdict(self)

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.checker}/{self.rule}] " \
               f"{self.message}"


def suppressed(finding: Finding, source_lines: list[str]) -> bool:
    """True when the finding's source line carries a matching
    ``# lint: ignore[...]`` pragma."""
    if not 1 <= finding.line <= len(source_lines):
        return False
    m = _IGNORE_RE.search(source_lines[finding.line - 1])
    if m is None:
        return False
    rules = m.group("rules")
    if rules is None:
        return True
    return finding.rule in {r.strip() for r in rules.split(",")}


def apply_suppressions(findings: list[Finding],
                       sources: dict[str, list[str]]) -> list[Finding]:
    """Drop findings whose source line opts out; ``sources`` maps the
    finding's ``path`` to its source lines."""
    out = []
    for f in findings:
        lines = sources.get(f.path)
        if lines is not None and suppressed(f, lines):
            continue
        out.append(f)
    return out
