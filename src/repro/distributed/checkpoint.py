"""Sharded, mesh-agnostic, async checkpointing with atomic commits.

Layout per step::

    <dir>/step_00001000/
        manifest.json     # pytree structure, global shapes/dtypes, shard
                          # index windows, crc32 per file, framework version
        <leaf>__shard0.npy ...

Design properties (DESIGN.md §5):
  * **mesh-agnostic restore**: the manifest records global shapes and each
    shard's index window; ``restore`` reassembles the global array and
    re-device_puts it under ANY target sharding — checkpoints written on a
    256-chip pod restore onto 512 chips or onto one CPU (elastic scaling).
  * **atomic**: writes go to ``.tmp-<step>`` and are renamed into place only
    after every file + manifest is fsynced; a crashed save can never shadow
    a good checkpoint.
  * **async**: ``save`` returns after snapshotting device arrays to host;
    serialization runs on a background thread (overlaps the next train
    steps). The next save (or ``wait``) joins the previous one.
  * **integrity**: per-file crc32 checked on restore; corrupt/partial
    checkpoints are skipped by ``restore_latest`` (fault tolerance).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

_SEP = "__"


def _flatten(tree: Any) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any) -> None:
        self.wait()
        flat = _flatten(tree)
        # snapshot to host synchronously (cheap vs serialization)
        host: dict[str, list[tuple[tuple, np.ndarray]]] = {}
        meta: dict[str, Any] = {}
        for key, leaf in flat.items():
            arr = leaf
            shards = []
            if isinstance(arr, jax.Array) and hasattr(arr, "addressable_shards"):
                for sh in arr.addressable_shards:
                    idx = tuple(
                        (sl.start or 0,
                         sl.stop if sl.stop is not None else dim)
                        for sl, dim in zip(sh.index, arr.shape)) \
                        if arr.ndim else ()
                    shards.append((idx, np.asarray(sh.data)))
                # dedupe replicated shards
                seen, uniq = set(), []
                for idx, data in shards:
                    if idx not in seen:
                        seen.add(idx)
                        uniq.append((idx, data))
                shards = uniq
            else:
                shards = [((), np.asarray(arr))]
            host[key] = shards
            meta[key] = {
                "global_shape": list(np.shape(leaf)),
                "dtype": str(np.asarray(shards[0][1]).dtype),
                "shards": [list(map(list, idx)) for idx, _ in shards],
            }

        def serialize():
            try:
                tmp = os.path.join(self.directory, f".tmp-{step}")
                final = os.path.join(self.directory, f"step_{step:08d}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                crcs = {}
                for key, shards in host.items():
                    for si, (_, data) in enumerate(shards):
                        fn = f"{key.replace('/', _SEP)}{_SEP}shard{si}.npy"
                        fp = os.path.join(tmp, fn)
                        np.save(fp, data)
                        with open(fp, "rb") as f:
                            crcs[fn] = zlib.crc32(f.read())
                manifest = {"step": step, "leaves": meta, "crc32": crcs,
                            "version": 1}
                mp = os.path.join(tmp, "manifest.json")
                with open(mp, "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # surfaced on next save/wait
                self._error = e

        if self.async_save:
            self._thread = threading.Thread(target=serialize, daemon=True)
            self._thread.start()
        else:
            serialize()
            if self._error:
                raise self._error

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.match(r"step_(\d+)$", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _valid(self, step: int) -> bool:
        d = os.path.join(self.directory, f"step_{step:08d}")
        mp = os.path.join(d, "manifest.json")
        if not os.path.exists(mp):
            return False
        try:
            manifest = json.load(open(mp))
            for fn, crc in manifest["crc32"].items():
                with open(os.path.join(d, fn), "rb") as f:
                    if zlib.crc32(f.read()) != crc:
                        return False
            return True
        except Exception:
            return False

    def restore(self, step: int, shardings: Any = None) -> dict[str, Any]:
        """Returns {key: np.ndarray | jax.Array}. If ``shardings`` (a pytree
        or flat {key: sharding}) is given, leaves are device_put under it —
        this is where elastic resharding happens."""
        d = os.path.join(self.directory, f"step_{step:08d}")
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        flat_sh = _flatten(shardings) if shardings is not None else {}
        out: dict[str, Any] = {}
        for key, meta in manifest["leaves"].items():
            shape = tuple(meta["global_shape"])
            dtype = np.dtype(meta["dtype"])
            full = np.zeros(shape, dtype)
            for si, window in enumerate(meta["shards"]):
                fn = f"{key.replace('/', _SEP)}{_SEP}shard{si}.npy"
                data = np.load(os.path.join(d, fn))
                if window:
                    sl = tuple(slice(a, b) for a, b in window)
                    full[sl] = data
                else:
                    full = data
            if key in flat_sh:
                full = jax.device_put(full, flat_sh[key])
            out[key] = full
        return out

    def restore_latest(self, shardings: Any = None) -> Optional[dict]:
        for step in reversed(self.all_steps()):
            if self._valid(step):
                r = self.restore(step, shardings)
                r["step"] = step
                return r
        return None

    def restore_into(self, step: int, tree_like: Any, shardings: Any = None):
        """Restore into the structure of ``tree_like`` (unflatten by paths)."""
        flat = self.restore(step, shardings)
        leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        new_leaves = []
        for path, leaf in leaves_paths:
            key = "/".join(_path_str(p) for p in path)
            new_leaves.append(flat.get(key, leaf))
        return jax.tree_util.tree_unflatten(treedef, new_leaves)
