"""Gradient compression for cross-pod data parallelism.

At 512+ chips the pod-boundary gradient all-reduce crosses the slower DCN
links; compressing the payload is the standard mitigation:

* **bf16**: cast grads to bf16 before the all-reduce, accumulate the result
  into fp32 — halves DP traffic at negligible quality cost (the default for
  the ``pod`` axis here).
* **int8 + error feedback**: per-tensor symmetric int8 quantization with a
  local residual carried to the next step (1-bit/8-bit SGD literature:
  Seide'14, Karimireddy'19 EF-SGD) — 4x traffic reduction; the residual
  keeps it convergent.

These helpers are shard_map-level (they wrap an explicit ``psum``); the
supervisor's explicit-DP path uses them, and tests verify the EF estimator
is unbiased-in-the-limit (residual norm stays bounded).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


def psum_bf16(tree: Any, axis_name) -> Any:
    """All-reduce in bf16, return fp32."""
    return jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_name)
        .astype(jnp.float32),
        tree)


class Int8Compressed(NamedTuple):
    q: jax.Array      # int8 payload
    scale: jax.Array  # per-tensor scale


def int8_compress(g: jax.Array) -> Int8Compressed:
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return Int8Compressed(q=q, scale=scale)


def int8_decompress(c: Int8Compressed) -> jax.Array:
    return c.q.astype(jnp.float32) * c.scale


def psum_int8(tree: Any, axis_name) -> Any:
    """Quantize -> sum int32 -> dequantize with the summed scale envelope.

    Per-shard scales differ, so the sum uses the max scale (gathered) —
    conservative but correct."""

    def reduce_one(g):
        c = int8_compress(g)
        smax = jax.lax.pmax(c.scale, axis_name)
        # requantize against the common scale so integer sums align
        q = jnp.clip(jnp.round(g / smax), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, axis_name)
        return total.astype(jnp.float32) * smax

    return jax.tree.map(reduce_one, tree)


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree like grads


def ef_init(grads_like: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                              grads_like))


def ef_compress_psum(grads: Any, state: ErrorFeedbackState, axis_name
                     ) -> tuple[Any, ErrorFeedbackState]:
    """EF-int8: add residual, quantize+reduce, keep the quantization error."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        c = int8_compress(corrected)
        sent = int8_decompress(c)
        new_r = corrected - sent
        smax = jax.lax.pmax(c.scale, axis_name)
        q = jnp.clip(jnp.round(corrected / smax), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, axis_name).astype(jnp.float32) * smax
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return total / n, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(state.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    reduced = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_state = ErrorFeedbackState(
        residual=jax.tree.unflatten(tdef, [o[1] for o in outs]))
    return reduced, new_state
