"""Fault tolerance: supervised training loop, straggler watchdog, restart.

``TrainingSupervisor`` wraps any (params, opt_state, batch) -> ... step
function with:
  * periodic async checkpoints + auto-resume from the newest VALID one
    (corrupt/partial checkpoints are skipped — see checkpoint.py),
  * deterministic step-indexed data (the batch function is pure in step, so
    a resumed run replays the exact stream: no data loss, no duplication),
  * a straggler watchdog (EWMA of step wall-time; steps slower than
    ``threshold`` x EWMA are logged and counted — on a real fleet this is
    the signal that triggers hot-spare re-slicing; here it feeds metrics),
  * crash injection hooks for tests (``fail_at_step``).

Elastic scaling: because checkpoints are mesh-agnostic and data is
step-indexed, a supervisor restarted under a different mesh/shardings
continues bit-compatible training data-wise (optimizer state reshards).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np

from .checkpoint import CheckpointManager


@dataclass
class WatchdogReport:
    slow_steps: list[tuple[int, float]] = field(default_factory=list)
    ewma_s: float = 0.0


class StragglerWatchdog:
    def __init__(self, threshold: float = 3.0, warmup: int = 10,
                 alpha: float = 0.1):
        self.threshold = threshold
        self.warmup = warmup
        self.alpha = alpha
        self.report = WatchdogReport()
        self._n = 0

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler."""
        self._n += 1
        r = self.report
        if self._n <= self.warmup:
            r.ewma_s = dt if r.ewma_s == 0 else (
                (1 - self.alpha) * r.ewma_s + self.alpha * dt)
            return False
        slow = dt > self.threshold * r.ewma_s
        if slow:
            r.slow_steps.append((step, dt))
        else:  # don't poison the EWMA with straggler samples
            r.ewma_s = (1 - self.alpha) * r.ewma_s + self.alpha * dt
        return slow


class SimulatedFailure(RuntimeError):
    pass


class TrainingSupervisor:
    def __init__(
        self,
        step_fn: Callable,                     # (state..., batch) -> state..., metrics
        init_state: tuple,                     # e.g. (params, opt_state)
        batch_fn: Callable[[int], Any],        # step -> device-ready batch
        checkpoint_dir: Optional[str] = None,
        save_every: int = 100,
        keep: int = 3,
        watchdog: Optional[StragglerWatchdog] = None,
        state_shardings: Any = None,
    ):
        self.step_fn = step_fn
        self.state = init_state
        self.batch_fn = batch_fn
        self.save_every = save_every
        self.watchdog = watchdog or StragglerWatchdog()
        self.ckpt = (CheckpointManager(checkpoint_dir, keep=keep)
                     if checkpoint_dir else None)
        self.state_shardings = state_shardings
        self.start_step = 0
        self.metrics_log: list[dict] = []
        if self.ckpt is not None:
            latest = None
            for s in reversed(self.ckpt.all_steps()):
                if self.ckpt._valid(s):
                    latest = s
                    break
            if latest is not None:
                restored = self.ckpt.restore_into(
                    latest, {"state": self.state},
                    {"state": self.state_shardings}
                    if self.state_shardings is not None else None)
                self.state = restored["state"]
                self.start_step = latest

    def run(self, total_steps: int, fail_at_step: Optional[int] = None,
            log_every: int = 50) -> dict:
        import jax

        step = self.start_step
        while step < total_steps:
            if fail_at_step is not None and step == fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            *state, metrics = self.step_fn(*self.state, batch)
            self.state = tuple(state)
            step += 1
            if step % log_every == 0 or step == total_steps:
                jax.block_until_ready(self.state)
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                self.metrics_log.append(m)
            dt = time.perf_counter() - t0
            self.watchdog.observe(step, dt)
            if self.ckpt is not None and step % self.save_every == 0:
                self.ckpt.save(step, {"state": self.state})
        if self.ckpt is not None:
            self.ckpt.save(total_steps, {"state": self.state})
            self.ckpt.wait()
        return {"final_step": step, "watchdog": self.watchdog.report,
                "metrics": self.metrics_log}
