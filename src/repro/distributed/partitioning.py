"""Logical-axis partitioning: one schema drives both init and sharding.

Every model declares its parameters as a pytree of :class:`ParamDef` (shape,
dtype, logical axis names, initializer). From that single schema we derive
  * ``init_from_schema``  — materialized parameter pytree,
  * ``abstract_from_schema`` — ShapeDtypeStructs (dry-run, no allocation),
  * ``pspecs_from_schema`` — PartitionSpecs under a rule table,
so init and sharding can never drift apart.

Rule tables map logical axis names to mesh axes. A logical axis whose size is
not divisible by the product of its mapped mesh axes silently degrades to
replication (recorded in ``ShardingReport`` so the dry-run surfaces it).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

MeshAxes = Union[str, tuple[str, ...], None]


@dataclass(frozen=True)
class ParamDef:
    """Declaration of one parameter tensor."""

    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "fan_in"  # fan_in | normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Default rules for the production mesh (DESIGN.md §5). "batch"-like logical
# axes shard over the data(+pod) axes; feature/expert/vocab axes over model.
def default_rules(multi_pod: bool = False) -> dict[str, MeshAxes]:
    data: MeshAxes = ("pod", "data") if multi_pod else ("data",)
    return {
        # activations
        "batch": data,
        "tokens": data + ("model",),   # flattened batch*seq token streams
        "inbatch_col": "model",        # in-batch softmax negatives dim
        "seq_sp": "model",       # sequence-parallel residual stream / CP q-chunks
        "kv_seq": "model",       # decode KV cache sequence dim
        "kv_seq_all": (data + ("model",)) if isinstance(data, tuple) else ("data", "model"),
        "heads": "model",
        "db_rows": data + ("model",) if isinstance(data, tuple) else ("data", "model"),
        # weights
        "vocab": "model",
        "mlp": "model",
        "experts": "model",
        "qkv_out": "model",      # q/k/v projection output feature dim
        "embed_fsdp": "data",    # ZeRO-3 style weight shard along d_model
        "stack": None,           # scanned layer stack
        # table rows shard over "model" ONLY: the masked-psum lookup reduces
        # over the row axes, which must be disjoint from the ids' batch axes
        # (data); a (data, model) row sharding would psum across batch
        # shards. Billion-row tables that exceed model-axis HBM would need
        # the routed (all-to-all) lookup — documented in DESIGN.md.
        "table_rows": "model",
    }


def _flat_axes(mesh_axes: MeshAxes) -> tuple[str, ...]:
    if mesh_axes is None:
        return ()
    if isinstance(mesh_axes, str):
        return (mesh_axes,)
    return tuple(mesh_axes)


@dataclass
class ShardingReport:
    """Collects divisibility fallbacks so the dry-run can print them."""

    replicated: list[tuple[str, str, int, int]] = field(default_factory=list)

    def note(self, path: str, logical: str, dim: int, divisor: int) -> None:
        self.replicated.append((path, logical, dim, divisor))

    def __str__(self) -> str:
        if not self.replicated:
            return "sharding: all logical axes mapped"
        lines = ["sharding fallbacks (axis replicated, dim % mesh != 0):"]
        for path, logical, dim, div in self.replicated:
            lines.append(f"  {path}: {logical} dim={dim} mesh={div}")
        return "\n".join(lines)


def usable_axes(
    dim: int,
    name: Optional[str],
    rules: dict[str, MeshAxes],
    mesh: Mesh,
    used: Optional[set[str]] = None,
) -> tuple[str, ...]:
    """Mesh axes a logical name actually shards `dim` over, with progressive
    fallback: if the full axis product doesn't divide `dim`, trailing axes are
    dropped one at a time (e.g. batch=128 on ("pod","data","model")=512 ->
    ("pod","data")=32)."""
    if name is None or name not in rules or rules[name] is None:
        return ()
    axes = _flat_axes(rules[name])
    axes = tuple(a for a in axes if a in mesh.shape
                 and (used is None or a not in used))
    while axes:
        divisor = math.prod(mesh.shape[a] for a in axes)
        if divisor > 1 and dim % divisor == 0:
            return axes
        axes = axes[:-1]
    return ()


def spec_for(
    pdef_shape: Sequence[int],
    logical: Sequence[Optional[str]],
    rules: dict[str, MeshAxes],
    mesh: Mesh,
    report: Optional[ShardingReport] = None,
    path: str = "",
) -> P:
    """PartitionSpec for one tensor under a rule table, with divisibility fallback."""
    used: set[str] = set()
    entries: list[MeshAxes] = []
    for dim, name in zip(pdef_shape, logical):
        axes = usable_axes(dim, name, rules, mesh, used)
        if not axes:
            if name is not None and name in rules and rules[name] is not None \
                    and report is not None:
                full = _flat_axes(rules[name])
                div = math.prod(mesh.shape.get(a, 1) for a in full)
                if div > 1:
                    report.note(path, name, dim, div)
            entries.append(None)
            continue
        used.update(axes)
        entries.append(axes if len(axes) > 1 else axes[0])
    # trim trailing Nones for tidy specs
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


# ---------------------------------------------------------------------------
# Schema traversal
# ---------------------------------------------------------------------------

def _is_def(x: Any) -> bool:
    return isinstance(x, ParamDef)


def _tree_map_defs(fn: Callable[[str, ParamDef], Any], schema: Any, prefix: str = "") -> Any:
    if _is_def(schema):
        return fn(prefix, schema)
    if isinstance(schema, dict):
        return {k: _tree_map_defs(fn, v, f"{prefix}/{k}" if prefix else str(k))
                for k, v in schema.items()}
    if isinstance(schema, (list, tuple)):
        return type(schema)(
            _tree_map_defs(fn, v, f"{prefix}/{i}") for i, v in enumerate(schema))
    raise TypeError(f"bad schema node at {prefix}: {type(schema)}")


def abstract_from_schema(schema: Any) -> Any:
    return _tree_map_defs(
        lambda _, d: jax.ShapeDtypeStruct(d.shape, d.dtype), schema)


def init_from_schema(schema: Any, key: jax.Array) -> Any:
    """Materialize parameters. Keys are derived per-leaf from the path hash so
    initialization is order-independent (stable across schema refactors)."""

    def make(path: str, d: ParamDef):
        leaf_key = jax.random.fold_in(key, zlib_crc(path))
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        if d.init == "normal":
            std = d.scale if d.scale is not None else 0.02
            return (jax.random.normal(leaf_key, d.shape) * std).astype(d.dtype)
        if d.init == "embed":
            std = d.scale if d.scale is not None else 0.02
            return (jax.random.normal(leaf_key, d.shape) * std).astype(d.dtype)
        if d.init == "fan_in":
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = d.scale if d.scale is not None else 1.0 / math.sqrt(fan_in)
            return (jax.random.normal(leaf_key, d.shape) * std).astype(d.dtype)
        raise ValueError(f"unknown init {d.init!r} at {path}")

    return _tree_map_defs(make, schema)


def pspecs_from_schema(
    schema: Any, rules: dict[str, MeshAxes], mesh: Mesh,
    report: Optional[ShardingReport] = None,
) -> Any:
    return _tree_map_defs(
        lambda path, d: spec_for(d.shape, d.logical, rules, mesh, report, path),
        schema)


def shardings_from_pspecs(pspecs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def zlib_crc(s: str) -> int:
    import zlib

    return zlib.crc32(s.encode()) & 0x7FFFFFFF


# ---------------------------------------------------------------------------
# Corpus partitioning (sharded serving)
# ---------------------------------------------------------------------------

def partition_rows(n: int, n_shards: int) -> list[np.ndarray]:
    """Contiguous, balanced row ranges: shard i gets ``n // n_shards`` rows
    (+1 for the first ``n % n_shards`` shards), so a ragged corpus never
    drops its tail. Returns int32 global-row-id arrays, ascending within
    each shard (the merge tie-break contract relies on this)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    n_shards = min(n_shards, max(n, 1))
    base, rem = divmod(n, n_shards)
    parts, start = [], 0
    for i in range(n_shards):
        size = base + (1 if i < rem else 0)
        parts.append(np.arange(start, start + size, dtype=np.int32))
        start += size
    return parts


def partition_ivf_cells(corpus: np.ndarray, n_shards: int, n_cells: int = 0,
                        kmeans_iters: int = 10, seed: int = 0
                        ) -> list[np.ndarray]:
    """Cluster the corpus into k-means cells and bin-pack whole cells onto
    shards (largest cell first, onto the lightest shard) so co-located
    vectors land on the same shard while shard sizes stay balanced.
    Row ids ascend within each shard; every row lands on exactly one
    shard (disjoint cover, validated by tests)."""
    from ..search.ivf import kmeans  # local: search imports this package

    n = int(corpus.shape[0])
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    n_shards = min(n_shards, max(n, 1))
    if n_shards == 1:
        return [np.arange(n, dtype=np.int32)]
    n_cells = min(n_cells or 8 * n_shards, n)
    _, assign = kmeans(jnp.asarray(corpus, jnp.float32), n_cells,
                       iters=kmeans_iters, seed=seed)
    assign = np.asarray(assign)
    members = [np.flatnonzero(assign == c) for c in range(n_cells)]
    order = np.argsort([-len(m) for m in members], kind="stable")
    loads = np.zeros(n_shards, np.int64)
    buckets: list[list[np.ndarray]] = [[] for _ in range(n_shards)]
    for c in order:
        s = int(np.argmin(loads))
        buckets[s].append(members[c])
        loads[s] += len(members[c])
    return [np.sort(np.concatenate(b)).astype(np.int32) if b
            else np.empty(0, np.int32) for b in buckets]


# ---------------------------------------------------------------------------
# Activation sharding helpers
# ---------------------------------------------------------------------------

def with_logical(x: jax.Array, logical: tuple[Optional[str], ...],
                 rules: dict[str, MeshAxes], mesh: Mesh) -> jax.Array:
    """``lax.with_sharding_constraint`` by logical axis names."""
    spec = spec_for(x.shape, logical, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_spec(mesh: Mesh, rules: dict[str, MeshAxes], *trailing: Optional[str]) -> P:
    """Spec for an activation whose dim0 is the global batch."""
    axes = _flat_axes(rules.get("batch"))
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(lead, *trailing)
