from . import checkpoint, compression, fault_tolerance, partitioning
from .checkpoint import CheckpointManager
from .fault_tolerance import StragglerWatchdog, TrainingSupervisor
