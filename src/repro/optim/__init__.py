from .adamw import AdamW, AdamWState, global_norm
from .schedule import constant, cosine_annealing

__all__ = ["AdamW", "AdamWState", "constant", "cosine_annealing", "global_norm"]
