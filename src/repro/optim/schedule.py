"""LR schedules. The paper uses cosine annealing 1e-3 -> 1e-5 over 3000 steps."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_annealing(lr_max: float, lr_min: float, total_steps: int,
                     warmup_steps: int = 0):
    """Cosine decay from lr_max to lr_min with optional linear warmup."""

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        if warmup_steps > 0:
            warm = lr_max * step / warmup_steps
        else:
            warm = jnp.asarray(lr_max, jnp.float32)
        denom = max(total_steps - warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / denom, 0.0, 1.0)
        cos = lr_min + 0.5 * (lr_max - lr_min) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn


def constant(lr: float):
    def fn(step):
        return jnp.full((), lr, jnp.float32)

    return fn
