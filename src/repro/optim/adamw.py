"""Pure-JAX AdamW with decoupled weight decay (the paper's lambda).

The paper (Section 4.1) realises the regularization coefficient lambda of
Eq. 7 as Adam weight decay. Decoupled decay `w -= lr * wd * w` is the exact
gradient-descent step of `0.5 * wd * ||W||_F^2` rescaled by lr, so it
implements the Frobenius-norm term without polluting the Adam moments.

Optimizer state is a pytree mirroring params (m, v) + a scalar step, so it
shards with the same PartitionSpecs as the parameters (ZeRO-1 comes for free
wherever the params themselves are sharded).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # int32 scalar
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    # mask: pytree-prefix fn param-path -> bool; None = decay everything 2D+
    decay_mask: Optional[Callable[[Any], Any]] = None
    clip_norm: float = 0.0
    # bf16 moments halve optimizer-state HBM (fp32 master weights retained);
    # needed to fit 235B + Adam on a single 256-chip v5e pod.
    moment_dtype: Optional[str] = None

    def _mdt(self, p):
        return jnp.dtype(self.moment_dtype) if self.moment_dtype else p.dtype

    def init(self, params: Any) -> AdamWState:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, self._mdt(p)), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(
                              lambda p: jnp.zeros(p.shape, self._mdt(p)),
                              params))

    def _lr(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)

    def update(self, grads: Any, state: AdamWState, params: Any
               ) -> tuple[Any, AdamWState, dict[str, jax.Array]]:
        step = state.step + 1
        lr = self._lr(state.step)
        gnorm = global_norm(grads)
        if self.clip_norm > 0:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        b1, b2 = self.b1, self.b2
        m = jax.tree.map(
            lambda mu, g: (b1 * mu.astype(jnp.float32)
                           + (1 - b1) * g.astype(jnp.float32)).astype(mu.dtype),
            state.m, grads)
        v = jax.tree.map(
            lambda nu, g: (b2 * nu.astype(jnp.float32)
                           + (1 - b2) * jnp.square(g.astype(jnp.float32))
                           ).astype(nu.dtype),
            state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        if self.decay_mask is not None:
            mask = self.decay_mask(params)
        else:
            mask = jax.tree.map(lambda p: p.ndim >= 2, params)

        def upd(p, mu, nu, decay_ok):
            mu, nu = mu.astype(jnp.float32), nu.astype(jnp.float32)
            u = (mu / bc1) / (jnp.sqrt(nu / bc2) + self.eps)
            wd = self.weight_decay if self.weight_decay else 0.0
            decay = (wd * p.astype(jnp.float32)) if wd else 0.0
            decay = decay * jnp.asarray(decay_ok, jnp.float32)
            return (p.astype(jnp.float32) - lr * (u + decay)).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v, mask)
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, AdamWState(step=step, m=m, v=v), metrics


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))
