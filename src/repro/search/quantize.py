"""Quantized storage codecs for the search tiers: SQ8 + PQ (with ADC).

Two codecs, both trading bytes-per-vector for a small, bounded recall hit —
the memory-axis complement of the paper's dimensionality reduction (RAE
shrinks d, quantization shrinks bytes/dim; Zouhar et al. 2022 show the two
compressions stack almost independently):

* **SQ8** — per-dim min/max scalar quantization to uint8. Reconstruction
  ``x_hat = vmin + code * step`` is never materialized on the scan path:
  ``||q - x_hat||^2 = ||q||^2 - 2 q.vmin - 2 (q*step).codes + ||x_hat||^2``
  needs only a dot of the *pre-scaled* query against the raw uint8 codes
  plus the per-row ``||x_hat||^2`` term precomputed at encode time
  (dequant-free asymmetric L2). 4x smaller than f32, error <= step/2 per
  dim.

* **PQ{m}x{bits}** — product quantization: split d into m subspaces, run
  k-means (2^bits centroids) per subspace, store one code per subspace.
  Search uses ADC (asymmetric distance computation): a per-query LUT of
  exact query-to-centroid distances, summed via code gather. m bytes per
  vector at bits=8 — 32x smaller than f32 at d=8m.

IVF composition: the coarse layer is unchanged (``search.ivf`` k-means
cells); the padded-dense list payload stores *codes* instead of f32
vectors, and the probe scan runs the same dequant-free forms over the
gathered codes. The flat PQ hot path has a fused Pallas kernel
(``repro.kernels.pq_adc``); everything here is the pure-JAX engine.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .ivf import kmeans


# ---------------------------------------------------------------------------
# SQ8: per-dim min/max scalar quantization
# ---------------------------------------------------------------------------
@dataclass
class ScalarQuantizer:
    """Per-dim affine codebook: ``decode(c) = vmin + c * step``, c in 0..255."""

    vmin: jax.Array   # [d]
    step: jax.Array   # [d], >= tiny eps so constant dims round-trip


def sq8_train(x: jax.Array) -> ScalarQuantizer:
    """Fit per-dim [min, max] on the corpus; 256 uniform levels per dim."""
    x = jnp.asarray(x, jnp.float32)
    vmin = jnp.min(x, axis=0)
    vmax = jnp.max(x, axis=0)
    step = jnp.maximum((vmax - vmin) / 255.0, 1e-12)
    return ScalarQuantizer(vmin=vmin, step=step)


def sq8_encode(sq: ScalarQuantizer, x: jax.Array) -> jax.Array:
    """f32 [N, d] -> uint8 codes [N, d]; round-to-nearest, clipped to range."""
    x = jnp.asarray(x, jnp.float32)
    c = jnp.round((x - sq.vmin[None, :]) / sq.step[None, :])
    return jnp.clip(c, 0, 255).astype(jnp.uint8)


def sq8_decode(sq: ScalarQuantizer, codes: jax.Array) -> jax.Array:
    return sq.vmin[None, :] + codes.astype(jnp.float32) * sq.step[None, :]


def sq8_recon_sq_norms(sq: ScalarQuantizer, codes: jax.Array) -> jax.Array:
    """``||decode(codes)||^2`` per row — the scan-time constant term."""
    return jnp.sum(jnp.square(sq8_decode(sq, codes)), axis=-1)


@functools.partial(jax.jit, static_argnames=("k",))
def sq8_scan(vmin: jax.Array, step: jax.Array, q: jax.Array,
             codes: jax.Array, recon_sq: jax.Array, k: int
             ) -> tuple[jax.Array, jax.Array]:
    """Dequant-free exact asymmetric top-k over SQ8 codes.

    Returns (scores [Q, k], indices [Q, k]); scores = -||q - decode(c)||^2
    (higher = closer, same convention as the flat scan).
    """
    q = jnp.asarray(q, jnp.float32)
    cf = codes.astype(jnp.float32)                     # [N, d]
    # q . x_hat = q . vmin + (q * step) . codes
    qdotmin = q @ vmin                                 # [Q]
    qdotc = (q * step[None, :]) @ cf.T                 # [Q, N]
    s = (2.0 * (qdotmin[:, None] + qdotc)
         - recon_sq[None, :]
         - jnp.sum(q * q, axis=-1, keepdims=True))
    return jax.lax.top_k(s, k)


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def ivf_sq8_search(centroids: jax.Array, lists: jax.Array, codes: jax.Array,
                   recon_sq: jax.Array, mask: jax.Array, vmin: jax.Array,
                   step: jax.Array, q: jax.Array, k: int, nprobe: int
                   ) -> tuple[jax.Array, jax.Array]:
    """IVF probe scan over SQ8 list payloads (padded-dense layout).

    ``codes`` [C, cap, d] uint8, ``recon_sq`` [C, cap], ``lists``/``mask``
    as in :class:`repro.search.ivf.IVFIndex`. Same -1/-inf pad semantics.
    """
    q = jnp.asarray(q, jnp.float32)
    d2c = (jnp.sum(q * q, 1)[:, None] - 2 * q @ centroids.T
           + jnp.sum(centroids * centroids, 1)[None, :])
    _, cells = jax.lax.top_k(-d2c, nprobe)             # [Q, P]
    cf = codes[cells].astype(jnp.float32)              # [Q, P, cap, d]
    ids = lists[cells]                                 # [Q, P, cap]
    m = mask[cells]
    r2 = recon_sq[cells]                               # [Q, P, cap]
    qdotmin = q @ vmin                                 # [Q]
    qdotc = jnp.einsum("qd,qpcd->qpc", q * step[None, :], cf)
    s = (2.0 * (qdotmin[:, None, None] + qdotc)
         - r2 - jnp.sum(q * q, -1)[:, None, None])
    s = jnp.where(m, s, -jnp.inf)
    qn, p, cap = s.shape
    v, flat = jax.lax.top_k(s.reshape(qn, p * cap), k)
    idx = jnp.take_along_axis(ids.reshape(qn, p * cap), flat, axis=1)
    return v, jnp.where(jnp.isfinite(v), idx, -1)


# ---------------------------------------------------------------------------
# PQ: product quantization with ADC
# ---------------------------------------------------------------------------
@dataclass
class ProductQuantizer:
    """``m`` subspace codebooks of ``ksub`` centroids each (dsub = d // m)."""

    codebooks: jax.Array   # [m, ksub, dsub] f32

    @property
    def m(self) -> int:
        return int(self.codebooks.shape[0])

    @property
    def ksub(self) -> int:
        return int(self.codebooks.shape[1])

    @property
    def dsub(self) -> int:
        return int(self.codebooks.shape[2])


def pq_train(x: jax.Array, m: int, bits: int = 8, iters: int = 15,
             seed: int = 0) -> ProductQuantizer:
    """Independent k-means per subspace. ``d % m == 0`` required; the
    centroid count is ``min(2**bits, n)`` so tiny corpora still train."""
    x = jnp.asarray(x, jnp.float32)
    n, d = x.shape
    if d % m:
        raise ValueError(f"PQ: dim {d} not divisible by m={m}")
    if not 1 <= bits <= 8:
        raise ValueError(f"PQ: bits must be in 1..8, got {bits}")
    ksub = min(2 ** bits, n)
    dsub = d // m
    books = []
    for mm in range(m):
        sub = x[:, mm * dsub:(mm + 1) * dsub]
        cent, _ = kmeans(sub, ksub, iters=iters, seed=seed + mm)
        books.append(cent)
    return ProductQuantizer(codebooks=jnp.stack(books))


def pq_encode(pq: ProductQuantizer, x: jax.Array) -> jax.Array:
    """f32 [N, d] -> uint8 codes [N, m] (nearest centroid per subspace)."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    xs = x.reshape(n, pq.m, pq.dsub)
    # d2[n, m, j] = ||x_sub - cb||^2 ; argmin over j
    cb = pq.codebooks
    d2 = (jnp.sum(xs * xs, -1)[:, :, None]
          - 2 * jnp.einsum("nms,mjs->nmj", xs, cb)
          + jnp.sum(cb * cb, -1)[None, :, :])
    return jnp.argmin(d2, axis=-1).astype(jnp.uint8)


def pq_decode(pq: ProductQuantizer, codes: jax.Array) -> jax.Array:
    """codes [N, m] -> reconstructed f32 [N, d]."""
    gathered = jnp.take_along_axis(
        pq.codebooks[None], codes.astype(jnp.int32)[:, :, None, None],
        axis=2)[:, :, 0, :]                            # [N, m, dsub]
    return gathered.reshape(codes.shape[0], pq.m * pq.dsub)


def adc_lut(codebooks: jax.Array, q: jax.Array) -> jax.Array:
    """Exact query-to-centroid distance LUT [Q, m, ksub] from raw arrays:
    ``lut[q, m, j] = ||q_sub_m - codebooks[m, j]||^2``. The ONE place the
    ADC LUT formula lives (the flat scan, the IVF probe scan and the
    public wrapper all call this; kernels/pq_adc/ref.py is a deliberate
    independent oracle)."""
    q = jnp.asarray(q, jnp.float32)
    m, _, dsub = codebooks.shape
    qs = q.reshape(q.shape[0], m, dsub)
    return (jnp.sum(qs * qs, -1)[:, :, None]
            - 2 * jnp.einsum("qms,mjs->qmj", qs, codebooks)
            + jnp.sum(codebooks * codebooks, -1)[None, :, :])


def _code_offsets(codes: jax.Array, ksub: int) -> jax.Array:
    """codes [..., m] -> offsets into a [m*ksub]-flattened LUT row."""
    m = codes.shape[-1]
    return (codes.astype(jnp.int32)
            + jnp.arange(m, dtype=jnp.int32) * ksub)


def pq_adc_lut(pq: ProductQuantizer, q: jax.Array) -> jax.Array:
    """:func:`adc_lut` over a :class:`ProductQuantizer`."""
    return adc_lut(pq.codebooks, q)


def pq_adc_gather(lut: jax.Array, codes: jax.Array) -> jax.Array:
    """Sum the LUT over each row's codes: dist [Q, N] = sum_m lut[q, m, c]."""
    qn, m, ksub = lut.shape
    lut_flat = lut.reshape(qn, m * ksub)
    flat = _code_offsets(codes, ksub).reshape(-1)
    g = jnp.take(lut_flat, flat, axis=1)               # [Q, N*m]
    return g.reshape(qn, codes.shape[0], m).sum(-1)


@functools.partial(jax.jit, static_argnames=("k", "nprobe"))
def ivf_pq_search(centroids: jax.Array, lists: jax.Array, codes: jax.Array,
                  mask: jax.Array, codebooks: jax.Array, q: jax.Array,
                  k: int, nprobe: int) -> tuple[jax.Array, jax.Array]:
    """IVF probe scan over PQ list payloads via per-query ADC LUT.

    ``codes`` [C, cap, m] uint8; LUT built once per query, gathered per
    probed row. Same -1/-inf pad semantics as the flat IVF scan.
    """
    q = jnp.asarray(q, jnp.float32)
    m, ksub, _ = codebooks.shape
    d2c = (jnp.sum(q * q, 1)[:, None] - 2 * q @ centroids.T
           + jnp.sum(centroids * centroids, 1)[None, :])
    _, cells = jax.lax.top_k(-d2c, nprobe)             # [Q, P]
    ids = lists[cells]                                 # [Q, P, cap]
    msk = mask[cells]
    lut_flat = adc_lut(codebooks, q).reshape(q.shape[0], m * ksub)
    offs = _code_offsets(codes[cells], ksub)           # [Q, P, cap, m]
    qn, p, cap, _ = offs.shape
    g = jnp.take_along_axis(lut_flat, offs.reshape(qn, p * cap * m), axis=1)
    dist = g.reshape(qn, p, cap, m).sum(-1)
    s = jnp.where(msk, -dist, -jnp.inf)
    v, flat = jax.lax.top_k(s.reshape(qn, p * cap), k)
    idx = jnp.take_along_axis(ids.reshape(qn, p * cap), flat, axis=1)
    return v, jnp.where(jnp.isfinite(v), idx, -1)


def bytes_per_code(m: int, bits: int) -> int:
    """Stored PQ code size in bytes: one uint8 per subspace. bits < 8
    narrows the codebook (2^bits centroids) but codes are NOT bit-packed —
    report what is actually stored, not the ceil(m*bits/8) a packed layout
    would reach."""
    del bits  # kept in the signature so a future packed layout is non-breaking
    return max(1, m)
