"""HNSW graph search (Malkov & Yashunin 2016) — the sublinear search tier.

Layered navigable-small-world graph: each node draws a top layer from the
geometric distribution ``floor(-ln(U) / ln(M))``; insert runs an
``ef_construction``-bounded beam per layer and connects to at most ``M``
neighbors chosen by the pruning heuristic (Alg. 4: a candidate joins only
if it is closer to the query than to every already-selected neighbor,
which keeps edges spread across directions instead of clustering). Degrees
are capped at ``M`` on upper layers and ``2M`` at layer 0; when a cap
overflows, the overfull list is re-pruned with the same heuristic and the
dropped back-links are removed, so links stay bidirectional (unlike
hnswlib, which leaves asymmetric edges after a shrink — symmetric graphs
are what the invariant suite checks, and pruned slots are refilled with
the nearest rejected candidates to protect connectivity).

Search greedy-descends from the entry point through the upper layers
(ef=1) and runs the ef-bounded best-first beam at layer 0. Two traversal
engines share those semantics:

* :func:`search` — the sequential reference: per-query pointer-chasing on
  host (numpy + heapq), one ``candidate_distances`` dispatch per hop.
* :func:`search_batched` — the array-native serving path: a batched
  frontier loop over the :meth:`HNSWGraph.pack`-ed dense adjacency. Per
  hop it pops the best unexpanded beam entry of EVERY live query at once,
  gathers their neighbor rows, masks visited/pad slots with per-query
  visited stamps, and scores + beam-merges all (query, neighbor) pairs in
  ONE dispatch through the fused ``graph_beam`` kernel triple (Pallas
  gather+L2+merge on TPU, vectorized numpy off-TPU). Expansion order per
  query is identical to the heapq beam — best-first until no in-beam
  candidate is unexpanded — so recall at equal ``ef_search`` matches and
  visited counts agree up to boundary ties (tested within 10%); results
  are bitwise-deterministic and row-independent (a query answers the same
  at q=1 and inside any batch, which the serving cache relies on).

The batched engine optionally traverses *quantized* payloads: attach a
:class:`GraphCodes` (SQ8 or PQ codes trained by :func:`make_graph_codes`)
as ``graph.codec`` and every hop gathers code rows instead of f32 vectors,
scoring via dequant-free asymmetric L2 / a per-query ADC LUT through the
``graph_beam_q`` kernel triple — a 4–20x cut in gather bytes per hop, with
the exact ``Rerank`` stage above recovering full-precision ordering.

Every distance evaluation is counted — both engines return per-query eval
totals, the sublinearity axis the benchmarks report next to recall.

Composes with the paper's RAE exactly like IVF: build the graph over the
*reduced* corpus and rerank in R^n, so beam search pays O(m) per hop
instead of O(n).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

_MAX_LEVEL = 15


def _backend() -> str:
    import jax

    return jax.default_backend()


def _resolve_impl(impl: str) -> str:
    """Collapse ``"auto"`` to a concrete impl ONCE per build/search — the
    backend cannot change mid-traversal and the hot loops issue tens of
    thousands of tiny distance batches."""
    if impl == "auto":
        return "fused" if _backend() == "tpu" else "np"
    return impl


def candidate_distances(q: np.ndarray, vecs: np.ndarray,
                        impl: str = "auto") -> np.ndarray:
    """Squared L2 from one query [d] to a candidate batch [c, d].

    ``impl="fused"`` routes through the fused ``l2_topk`` scan (Pallas on
    TPU, jnp ref elsewhere) with k = c and scatters the sorted output back
    to input order; ``"np"`` is the host ref. ``"auto"`` picks fused only
    on TPU — traversal is host-driven, so device round-trips lose on CPU.
    """
    impl = _resolve_impl(impl)
    if impl == "np":
        diff = vecs - q
        return np.einsum("cd,cd->c", diff, diff)
    import jax.numpy as jnp

    from ..kernels import l2_topk

    c = int(vecs.shape[0])
    scores, idx = l2_topk(jnp.asarray(q)[None, :], jnp.asarray(vecs), c)
    out = np.empty(c, np.float32)
    out[np.asarray(idx[0])] = -np.asarray(scores[0])  # scores = -||q-d||^2
    return out


class _Evals:
    """Mutable distance-evaluation counter threaded through the traversal."""

    __slots__ = ("n",)

    def __init__(self):
        self.n = 0


@dataclass
class PackedHNSW:
    """Traversal-ready compilation of an :class:`HNSWGraph`: C-contiguous
    int32 neighbor tables (the batched frontier loop fancy-indexes whole
    rows of them every hop) plus the per-node squared norms the fused
    ``2 q.v - ||v||^2 - ||q||^2`` scoring form needs — computed once here
    instead of once per search. Built by :meth:`HNSWGraph.pack` and
    persisted alongside the graph so a reloaded index serves the batched
    path without repacking. ``device_arrays`` lazily uploads (and caches)
    the jax-side copies the jitted traversal closes over."""

    nbrs0: np.ndarray    # [N, 2M] int32, -1 = pad (layer 0)
    upper: np.ndarray    # [L, N, M] int32 (layers 1..L)
    vecs_sq: np.ndarray  # [N] float32: ||vecs||^2 per node
    _dev: Optional[tuple] = field(default=None, repr=False, compare=False)

    def device_arrays(self, vecs: np.ndarray) -> tuple:
        """(vecs, vecs_sq, nbrs0, upper) as device arrays, uploaded once."""
        if self._dev is None:
            import jax.numpy as jnp

            self._dev = (jnp.asarray(vecs), jnp.asarray(self.vecs_sq),
                         jnp.asarray(self.nbrs0), jnp.asarray(self.upper))
        return self._dev


@dataclass
class GraphCodes:
    """Quantized traversal payload riding alongside the packed graph:
    per-node SQ8 or PQ codes plus the codec state needed to score them.
    When attached (:func:`make_graph_codes` / ``HNSWGraph.codec``), every
    batched driver's hop gathers *codes* instead of f32 rows — at d=64
    that is 68 bytes per gathered neighbor for SQ8 and 12 for PQ8x8
    versus 260 for the f32 row+norm, which is the bandwidth the graph
    tier pays per hop at scale. Scores stay comparable across the whole
    traversal (entry seed, greedy descent, layer-0 beam all score codes),
    and the exact ``Rerank`` stage on top recovers full-precision
    ordering. Codecs live in :mod:`repro.search.quantize`; this class
    only carries their trained state and builds the per-query hop
    operands (see ``kernels/graph_beam_q`` for the unified affine score
    form)."""

    kind: str                 # "sq8" | "pq"
    codes: np.ndarray         # [N, C] uint8 (sq8: C = d; pq: C = m)
    node_bias: np.ndarray     # [N] f32 (sq8: ||decode(c)||^2; pq: zeros)
    vmin: Optional[np.ndarray] = None        # sq8 [d] f32
    step: Optional[np.ndarray] = None        # sq8 [d] f32
    codebooks: Optional[np.ndarray] = None   # pq [m, ksub, dsub] f32
    _dev: Optional[tuple] = field(default=None, repr=False, compare=False)

    @property
    def ksub(self) -> int:
        """LUT stride (pq codebook width; may be < 2**bits on tiny
        corpora — the actual trained width, never the nominal one)."""
        return 0 if self.codebooks is None else int(self.codebooks.shape[1])

    @property
    def gather_bytes(self) -> int:
        """Bytes the hop streams per gathered neighbor: the uint8 code
        row plus its f32 bias term. The f32 hop's equivalent is
        ``4 d + 4`` (row + norm) — the ratio is the tier's bandwidth
        win, reported by the benches as traversal gather bytes/hop."""
        return int(self.codes.shape[1]) + 4

    def query_operands(self, q: np.ndarray, q_sq: np.ndarray
                       ) -> tuple[np.ndarray, np.ndarray]:
        """Per-query hop operands ``(q_op [Q, Dop], q_bias [Q])`` (numpy,
        hoisted once per search batch). SQ8: the dequant-free asymmetric
        L2 rearrangement (``q_op = 2 q * step``, ``q_bias = 2 q.vmin -
        ||q||^2``) so the hop scores ``-||q - decode(c)||^2``. PQ: the
        NEGATED flattened ADC LUT, zero bias, so the hop scores
        ``-ADC distance``.

        Every reduction here is per-row (elementwise products + axis
        sums, plain un-optimized einsum) on purpose: a BLAS matvec or
        XLA dot picks its blocking from the BATCH shape, so row i's
        operand would differ in the last ulp between a solo and a
        coalesced dispatch — breaking the serving cache's bitwise
        row-independence contract."""
        if self.kind == "sq8":
            q_op = (2.0 * q * self.step[None, :]).astype(np.float32)
            q_bias = (2.0 * (q * self.vmin[None, :]).sum(axis=1)
                      - q_sq).astype(np.float32)
            return q_op, q_bias
        # the same expanded LUT algebra as quantize.adc_lut (which is
        # jnp, hence batch-blocked — see docstring), term for term
        cb = np.asarray(self.codebooks, np.float32)
        m, ksub, dsub = cb.shape
        qs = q.reshape(q.shape[0], m, dsub)
        lut = ((qs * qs).sum(-1)[:, :, None]
               - 2.0 * np.einsum("qms,mjs->qmj", qs, cb)
               + (cb * cb).sum(-1)[None, :, :]).astype(np.float32)
        return -lut.reshape(q.shape[0], -1), np.zeros(q.shape[0],
                                                      np.float32)

    def device_arrays(self) -> tuple:
        """(codes int32, node_bias, c0, c1) as device arrays, uploaded
        once; c0/c1 = (vmin, step) for sq8, (codebooks, None) for pq.
        Codes are widened to int32 here rather than per dispatch (TPU
        tiling — same convention as ``pq_adc``'s ops layer)."""
        if self._dev is None:
            import jax.numpy as jnp

            if self.kind == "sq8":
                c0, c1 = jnp.asarray(self.vmin), jnp.asarray(self.step)
            else:
                c0, c1 = jnp.asarray(self.codebooks), None
            self._dev = (jnp.asarray(self.codes.astype(np.int32)),
                         jnp.asarray(self.node_bias, jnp.float32), c0, c1)
        return self._dev


def make_graph_codes(vecs: np.ndarray, kind: str, m: int = 8, bits: int = 8,
                     iters: int = 15, seed: int = 0) -> GraphCodes:
    """Train a quantized traversal payload over the (already reduced)
    corpus the graph was built on. ``kind`` = "sq8" | "pq"; ``m``/
    ``bits``/``iters``/``seed`` are the PQ training knobs (ignored for
    SQ8). Attach the result as ``graph.codec`` — the f32 vectors stay
    (build, the sequential engine, and connectivity repair still use
    them); the payload changes what the *batched hop gather* reads."""
    from . import quantize as qz

    v = np.asarray(vecs, np.float32)
    if kind == "sq8":
        sq = qz.sq8_train(v)
        codes = np.asarray(qz.sq8_encode(sq, v))
        nb = np.asarray(qz.sq8_recon_sq_norms(sq, codes), np.float32)
        return GraphCodes(kind="sq8", codes=codes, node_bias=nb,
                          vmin=np.asarray(sq.vmin, np.float32),
                          step=np.asarray(sq.step, np.float32))
    if kind != "pq":
        raise ValueError(f"graph codec kind must be 'sq8' or 'pq', "
                         f"got {kind!r}")
    pq = qz.pq_train(v, m, bits=bits, iters=iters, seed=seed)
    codes = np.asarray(qz.pq_encode(pq, v))
    return GraphCodes(kind="pq", codes=codes,
                      node_bias=np.zeros(v.shape[0], np.float32),
                      codebooks=np.asarray(pq.codebooks, np.float32))


@dataclass
class HNSWGraph:
    """Padded-dense adjacency: ``links0`` [N, 2M] is layer 0, ``links``
    [L, N, M] are layers 1..L (-1 = empty slot; rows of nodes absent from
    a layer are all -1). ``codec``, when set, makes every batched driver
    score quantized code payloads instead of f32 rows (see
    :class:`GraphCodes`); the sequential engine always scores f32."""

    vecs: np.ndarray     # [N, d] float32
    levels: np.ndarray   # [N] int32: top layer of each node
    links0: np.ndarray   # [N, 2M] int32
    links: np.ndarray    # [L, N, M] int32
    entry: int
    M: int
    packed: Optional[PackedHNSW] = field(default=None, repr=False,
                                         compare=False)
    codec: Optional[GraphCodes] = field(default=None, repr=False,
                                        compare=False)

    @property
    def ntotal(self) -> int:
        return int(self.vecs.shape[0])

    @property
    def max_level(self) -> int:
        return int(self.levels[self.entry])

    def adjacency(self, layer: int) -> np.ndarray:
        return self.links0 if layer == 0 else self.links[layer - 1]

    def pack(self) -> PackedHNSW:
        """Compile (and cache) the packed traversal form. Idempotent; a
        graph mutated after packing must null ``packed`` itself."""
        if self.packed is None:
            self.packed = PackedHNSW(
                nbrs0=np.ascontiguousarray(self.links0, np.int32),
                upper=np.ascontiguousarray(self.links, np.int32),
                vecs_sq=np.einsum("nd,nd->n", self.vecs,
                                  self.vecs).astype(np.float32))
        return self.packed


def sample_levels(n: int, M: int, seed: int) -> np.ndarray:
    """Geometric level draw: floor(-ln(U) * mL) with mL = 1/ln(M)."""
    rng = np.random.default_rng(seed)
    u = rng.uniform(np.finfo(np.float64).tiny, 1.0, size=n)
    lv = np.floor(-np.log(u) / np.log(max(M, 2))).astype(np.int32)
    return np.minimum(lv, _MAX_LEVEL)


def _greedy_descent(vecs, adj, q, cur, d_cur, evals, impl, alive=None):
    """ef=1 layer traversal: hop to the closest neighbor until no
    neighbor improves. ``alive`` (bool [N]) hides tombstoned nodes: a
    dead neighbor is never hopped to (``alive=None`` = all alive)."""
    while True:
        nbrs = adj[cur]
        nbrs = nbrs[nbrs >= 0]
        if alive is not None and nbrs.size:
            nbrs = nbrs[alive[nbrs]]
        if nbrs.size == 0:
            return cur, d_cur
        ds = candidate_distances(q, vecs[nbrs], impl)
        evals.n += int(nbrs.size)
        j = int(np.argmin(ds))
        if ds[j] >= d_cur:
            return cur, d_cur
        cur, d_cur = int(nbrs[j]), float(ds[j])


def _search_layer(vecs, adj, q, eps, ef, visited, stamp, evals, impl,
                  alive=None):
    """Best-first beam (Alg. 2): returns the ef closest visited nodes as a
    sorted [(dist, node), ...] list. ``eps`` are (dist, node) entry points
    (already counted); ``visited``/``stamp`` implement an O(1)-reset
    visited set shared across calls. ``alive`` (bool [N]) hides
    tombstoned nodes: a dead neighbor never enters the beam, so it can
    never surface in a result (``alive=None`` = all alive)."""
    cand: list[tuple[float, int]] = []   # min-heap on distance
    res: list[tuple[float, int]] = []    # max-heap via negated distance
    for d, e in eps:
        visited[e] = stamp
        heapq.heappush(cand, (d, e))
        heapq.heappush(res, (-d, e))
    while cand:
        d, c = heapq.heappop(cand)
        if d > -res[0][0] and len(res) >= ef:
            break
        nbrs = adj[c]
        nbrs = nbrs[nbrs >= 0]
        if alive is not None and nbrs.size:
            nbrs = nbrs[alive[nbrs]]
        fresh = nbrs[visited[nbrs] != stamp]
        if fresh.size == 0:
            continue
        visited[fresh] = stamp
        ds = candidate_distances(q, vecs[fresh], impl)
        evals.n += int(fresh.size)
        worst = -res[0][0]
        full = len(res) >= ef
        for dj, nj in zip(ds.tolist(), fresh.tolist()):
            if not full or dj < worst:
                heapq.heappush(cand, (dj, nj))
                heapq.heappush(res, (-dj, nj))
                if len(res) > ef:
                    heapq.heappop(res)
                worst = -res[0][0]
                full = len(res) >= ef
    return sorted((-nd, node) for nd, node in res)


def _select_heuristic(cands, vecs, m, evals, impl, keep_pruned=False):
    """Alg. 4 neighbor selection: scan candidates nearest-first, keep one
    only if it is closer to the query than to every kept neighbor. With
    ``keep_pruned`` the remaining slots are refilled nearest-first (used
    on cap overflow, where dropping to << m edges risks disconnection)."""
    sel: list[int] = []
    sel_vecs: list[np.ndarray] = []
    pruned: list[int] = []
    for d_c, c in cands:
        if len(sel) >= m:
            break
        if sel:
            ds = candidate_distances(vecs[c], np.stack(sel_vecs), impl)
            evals.n += len(sel)
            if not np.all(d_c < ds):
                pruned.append(c)
                continue
        sel.append(c)
        sel_vecs.append(vecs[c])
    if keep_pruned:
        sel.extend(pruned[: m - len(sel)])
    return sel


def _bfs_layer0(links0: np.ndarray, entry: int) -> np.ndarray:
    """Boolean reachability mask of the layer-0 graph from ``entry``."""
    seen = np.zeros(links0.shape[0], bool)
    seen[entry] = True
    stack = [entry]
    while stack:
        c = stack.pop()
        for t in links0[c][links0[c] >= 0].tolist():
            if not seen[t]:
                seen[t] = True
                stack.append(t)
    return seen


def _evict_farthest(links0, vecs, node, evals, impl) -> None:
    """Free one slot in a full row by dropping its farthest link (both
    directions, keeping the graph symmetric)."""
    nbrs = links0[node][links0[node] >= 0]
    ds = candidate_distances(vecs[node], vecs[nbrs], impl)
    evals.n += int(nbrs.size)
    t = int(nbrs[np.argmax(ds)])
    links0[t][links0[t] == node] = -1
    links0[node][links0[node] == t] = -1


def _repair_connectivity(vecs, links0, entry, evals, impl) -> int:
    """Symmetric pruning can (rarely) strand a node at layer 0: every
    neighbor that once pointed at it overflowed and evicted it. Stitch each
    stranded component back via its nearest reachable node — an evictee
    keeps its other edges, so the loop makes monotone progress and the
    layer-0 reachability invariant holds unconditionally."""
    stitched = 0
    for _ in range(links0.shape[0]):
        seen = _bfs_layer0(links0, entry)
        miss = np.flatnonzero(~seen)
        if miss.size == 0:
            return stitched
        u = int(miss[0])
        reach = np.flatnonzero(seen)
        ds = candidate_distances(vecs[u], vecs[reach], impl)
        evals.n += int(reach.size)
        r = int(reach[np.argmin(ds)])
        for node in (u, r):
            if not np.any(links0[node] < 0):
                _evict_farthest(links0, vecs, node, evals, impl)
        links0[u][np.flatnonzero(links0[u] < 0)[0]] = r
        links0[r][np.flatnonzero(links0[r] < 0)[0]] = u
        stitched += 1
    return stitched


def _write_row(adj, node, nbrs):
    row = adj[node]
    row[: len(nbrs)] = nbrs
    row[len(nbrs):] = -1


def _insert_node(vecs, levels, links0, links, M, m0, top, i, entry,
                 ef_construction, visited, evals, impl) -> int:
    """Insert node ``i`` (Alg. 1 body, shared verbatim between
    :func:`build` and :func:`insert_batch`): greedy-descend the upper
    layers, beam + heuristic-select per layer, write bidirectional links
    with overflow re-pruning. Returns the (possibly updated) entry."""
    q = vecs[i]
    l_i = int(levels[i])
    l_ep = int(levels[entry])
    cur = entry
    d_cur = float(candidate_distances(q, vecs[entry][None], impl)[0])
    evals.n += 1
    for layer in range(l_ep, l_i, -1):
        cur, d_cur = _greedy_descent(vecs, links[layer - 1], q, cur,
                                     d_cur, evals, impl)
    eps = [(d_cur, cur)]
    for layer in range(min(l_ep, l_i), -1, -1):
        adj = links0 if layer == 0 else links[layer - 1]
        cap = m0 if layer == 0 else M
        found = _search_layer(vecs, adj, q, eps, ef_construction,
                              visited, i * (top + 1) + layer, evals,
                              impl)
        sel = _select_heuristic(found, vecs, M, evals, impl)
        _write_row(adj, i, sel)
        # bidirectional: add the back-link, re-pruning on overflow and
        # dropping the reverse edge of anything the prune evicts
        for s in sel:
            row = adj[s]
            free = np.flatnonzero(row < 0)  # prune leaves holes anywhere
            if free.size:
                row[free[0]] = i
                continue
            nbrs = row[row >= 0]
            ds = candidate_distances(vecs[s], vecs[nbrs], impl)
            evals.n += int(nbrs.size)
            d_i = float(candidate_distances(vecs[s], q[None], impl)[0])
            evals.n += 1
            merged = sorted([*zip(ds.tolist(), nbrs.tolist()),
                             (d_i, i)])
            kept = _select_heuristic(merged, vecs, cap, evals, impl,
                                     keep_pruned=True)
            for t in nbrs:
                if t not in kept:
                    trow = adj[t]
                    trow[trow == s] = -1
            if i not in kept and len(kept) < cap:
                kept.append(i)  # never orphan the node being inserted
            elif i not in kept:
                irow = adj[i]
                irow[irow == s] = -1
            _write_row(adj, s, kept)
        eps = found
    if l_i > int(levels[entry]):
        entry = i
    return entry


def _compact_pads(links0, links) -> None:
    """Compact pad slots left of real links (prune leaves holes).
    Row-local stable argsort: a row with no holes is bitwise untouched."""
    for adj in (links0, *links):
        order = np.argsort(adj < 0, axis=1, kind="stable")
        adj[:] = np.take_along_axis(adj, order, axis=1)


def build(corpus: np.ndarray, M: int = 32, ef_construction: int = 100,
          seed: int = 0, impl: str = "auto") -> HNSWGraph:
    """Sequential heuristic insert of every corpus row (Alg. 1)."""
    vecs = np.ascontiguousarray(np.asarray(corpus, np.float32))
    n = vecs.shape[0]
    if n == 0:
        raise ValueError("empty corpus")
    impl = _resolve_impl(impl)
    m0 = 2 * M
    levels = sample_levels(n, M, seed)
    top = int(levels.max())
    links0 = np.full((n, m0), -1, np.int32)
    links = np.full((top, n, M), -1, np.int32)
    visited = np.full(n, -1, np.int64)
    # the traversal helpers are shared with search(), where the caller
    # consumes the count; at build time it only feeds the helpers
    evals = _Evals()
    entry = 0
    for i in range(1, n):
        entry = _insert_node(vecs, levels, links0, links, M, m0, top, i,
                             entry, ef_construction, visited, evals, impl)
    _repair_connectivity(vecs, links0, entry, evals, impl)
    _compact_pads(links0, links)
    return HNSWGraph(vecs=vecs, levels=levels, links0=links0, links=links,
                     entry=entry, M=M)


def insert_batch(graph: HNSWGraph, new_vecs: np.ndarray,
                 ef_construction: int = 100, seed: int = 0,
                 impl: str = "auto") -> np.ndarray:
    """Incremental insert: append ``new_vecs`` rows to a built graph with
    the SAME per-node machinery as :func:`build` (greedy descent, beam,
    heuristic selection, bidirectional overflow re-pruning), in place.

    Levels for the new nodes are drawn deterministically keyed on
    ``(seed, current size)``, so the same stream of insert batches always
    produces the same graph. New upper layers are allocated when a new
    node out-draws the current top. The packed traversal cache is nulled
    (the :meth:`HNSWGraph.pack` mutation contract) — callers re-pack
    (typically in the background) before the next batched search; the
    re-pack is bitwise-neutral for rows whose adjacency the insert did
    not touch. A :class:`GraphCodes` payload, when attached, is extended
    with codes for the new rows using the already-trained codec (no
    retrain — codec drift is the reducer-drift story, handled above).

    Returns the global ids of the inserted rows.
    """
    nv = np.ascontiguousarray(np.asarray(new_vecs, np.float32))
    b = nv.shape[0]
    if nv.ndim != 2 or (b and nv.shape[1] != graph.vecs.shape[1]):
        raise ValueError(f"insert_batch: expected [b, {graph.vecs.shape[1]}]"
                         f" vectors, got {nv.shape}")
    if b == 0:
        return np.zeros(0, np.int64)
    impl = _resolve_impl(impl)
    n0 = graph.ntotal
    M, m0 = graph.M, 2 * graph.M
    new_levels = sample_levels(b, M, seed + n0)
    vecs = np.ascontiguousarray(np.concatenate([graph.vecs, nv], axis=0))
    levels = np.concatenate([graph.levels, new_levels])
    top_old = graph.links.shape[0]
    top = max(top_old, int(new_levels.max()))
    links0 = np.concatenate(
        [graph.links0, np.full((b, m0), -1, np.int32)], axis=0)
    links = np.full((top, n0 + b, M), -1, np.int32)
    if top_old:
        links[:top_old, :n0] = graph.links
    visited = np.full(n0 + b, -1, np.int64)
    evals = _Evals()
    entry = graph.entry
    for i in range(n0, n0 + b):
        entry = _insert_node(vecs, levels, links0, links, M, m0, top, i,
                             entry, ef_construction, visited, evals, impl)
    _repair_connectivity(vecs, links0, entry, evals, impl)
    _compact_pads(links0, links)
    graph.vecs = vecs
    graph.levels = levels
    graph.links0 = links0
    graph.links = links
    graph.entry = entry
    graph.packed = None  # pack() contract: a mutated graph re-packs
    if graph.codec is not None:
        _extend_codec(graph.codec, nv)
    return np.arange(n0, n0 + b, dtype=np.int64)


def _extend_codec(cdx: GraphCodes, new_vecs: np.ndarray) -> None:
    """Encode ``new_vecs`` with the codec's already-trained state and
    append the code rows (and biases) in place; drops the device cache."""
    from . import quantize as qz

    v = np.asarray(new_vecs, np.float32)
    if cdx.kind == "sq8":
        sq = qz.ScalarQuantizer(vmin=cdx.vmin, step=cdx.step)
        codes = np.asarray(qz.sq8_encode(sq, v))
        nb = np.asarray(qz.sq8_recon_sq_norms(sq, codes), np.float32)
    else:
        pq = qz.ProductQuantizer(codebooks=cdx.codebooks)
        codes = np.asarray(qz.pq_encode(pq, v))
        nb = np.zeros(v.shape[0], np.float32)
    cdx.codes = np.ascontiguousarray(
        np.concatenate([cdx.codes, codes], axis=0))
    cdx.node_bias = np.concatenate([cdx.node_bias, nb])
    cdx._dev = None


def reassign_entry(graph: HNSWGraph, alive: np.ndarray) -> int:
    """Point ``graph.entry`` at the highest-level alive node (ties to the
    lowest id). Deleting the entry node would otherwise seed every
    traversal at a tombstone, which the hop mask turns into an empty
    beam. Returns the new entry id; raises if nothing is alive."""
    alive = np.asarray(alive, bool)
    ids = np.flatnonzero(alive)
    if ids.size == 0:
        raise ValueError("reassign_entry: no alive node to anchor at")
    graph.entry = int(ids[np.argmax(graph.levels[ids])])
    return graph.entry


def search(graph: HNSWGraph, queries: np.ndarray, k: int,
           ef_search: int = 64, impl: str = "auto",
           alive: Optional[np.ndarray] = None
           ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Beam search per query. Returns (scores [Q, k], ids [Q, k], evals
    [Q]): scores = -squared-euclidean (engine convention, higher =
    closer), ids pad with -1 / scores with -inf when the beam holds fewer
    than k nodes, evals = distance computations per query (the visited
    count — the sublinearity metric). ``alive`` (bool [N]) tombstones
    nodes: a dead node never enters a beam or a result; ``graph.entry``
    must point at an alive node (:func:`reassign_entry`)."""
    q = np.asarray(queries, np.float32)
    nq = q.shape[0]
    impl = _resolve_impl(impl)
    if alive is not None:
        alive = np.asarray(alive, bool)
        if not alive[graph.entry]:
            raise ValueError("search: graph.entry is tombstoned — call "
                             "reassign_entry() after deleting it")
    ef = max(ef_search, k)
    scores = np.full((nq, k), -np.inf, np.float32)
    ids = np.full((nq, k), -1, np.int32)
    evals = np.zeros(nq, np.int64)
    visited = np.full(graph.ntotal, -1, np.int64)
    for qi in range(nq):
        cnt = _Evals()
        cur = graph.entry
        d_cur = float(candidate_distances(q[qi], graph.vecs[cur][None],
                                          impl)[0])
        cnt.n += 1
        for layer in range(graph.max_level, 0, -1):
            cur, d_cur = _greedy_descent(graph.vecs, graph.links[layer - 1],
                                         q[qi], cur, d_cur, cnt, impl,
                                         alive)
        found = _search_layer(graph.vecs, graph.links0, q[qi],
                              [(d_cur, cur)], ef, visited, qi, cnt, impl,
                              alive)
        for j, (d, node) in enumerate(found[:k]):
            scores[qi, j] = -d
            ids[qi, j] = node
        evals[qi] = cnt.n
    return scores, ids, evals


def search_batched(graph: HNSWGraph, queries: np.ndarray, k: int,
                   ef_search: int = 64, impl: str = "auto",
                   frontier: int = 8,
                   alive: Optional[np.ndarray] = None
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Array-native batched beam search over the packed adjacency.

    Same semantics as :func:`search` — greedy descent through the upper
    layers, then a best-first beam of width ``ef = max(ef_search, k)`` at
    layer 0 — but the whole batch advances together: per hop, ONE fused
    dispatch scores every live query's frontier neighbors and merges them
    into the per-query beams (heapq and per-query Python loops never
    appear). Visited bookkeeping is a per-query stamp matrix (0 = unseen,
    1 = in beam / seen, 2 = expanded), so a node is scored at most once
    per query and the beam never holds duplicates.

    Drivers (``impl``):

    * ``"np"`` (the ``auto`` default off-TPU) — a host-driven hop loop
      through the vectorized numpy ``graph_beam`` ref, with E-wide
      frontier expansion (``frontier``, default 8) and fresh-candidate
      compaction (see :func:`_search_batched_np`). Host numpy beats XLA
      CPU here — its row gather/scatter primitives are several times
      faster at these shapes — and pays no compile step.
    * ``"fused"`` (the ``auto`` default on TPU) — the ENTIRE frontier
      loop compiles into one XLA ``while_loop`` whose layer-0 hop is the
      ``graph_beam`` Pallas kernel (scalar-prefetch gather + L2 +
      branchless merge on-chip): a search is one dispatch, zero host work
      per hop. The jit cache keys on the batch shape —
      ``SearchEngine.warmup`` pre-compiles every pow2 bucket.
    * ``"jit"`` — the same one-dispatch traversal with an in-jit gather
      and ``lax.top_k`` merge (same first-lowest-index tie rule as the
      kernel's iterative argmax) instead of the Pallas hop; the portable
      in-jit variant for non-TPU accelerators. The jitted drivers always
      run the exact best-first order (``frontier`` is a host-driver
      knob).

    Returns ``(scores [Q, k], ids [Q, k], evals [Q], hops)``: scores are
    -squared-L2 (higher = closer) with -inf/-1 padding like :func:`search`,
    ``evals`` counts fresh distance evaluations per query (same semantics
    as the sequential counter — equal up to beam-boundary ties), ``hops``
    is the number of fused layer-0 dispatches the batch needed (the
    batching win: ~ef hops per BATCH instead of ~ef Python iterations per
    QUERY). Every per-row quantity is independent of the rest of the
    batch, so a query answers identically at q=1 and inside any coalesced
    batch, and repeated searches of a fixed batch are bitwise-
    deterministic (the serving-cache contract).

    ``alive`` (bool [N]) tombstones nodes on every driver: dead
    candidates are masked at the hop (``graph_beam``/``graph_beam_q``'s
    ``db_mask`` operand), so a deleted row can never enter a beam — the
    same never-surfaces contract as the sequential engine. The entry
    node must be alive (:func:`reassign_entry`); ``alive=None`` keeps
    all three drivers bitwise identical to the static graph.
    """
    q = np.ascontiguousarray(np.asarray(queries, np.float32))
    nq = q.shape[0]
    if nq == 0:
        return (np.zeros((0, k), np.float32), np.zeros((0, k), np.int32),
                np.zeros(0, np.int64), 0)
    if impl == "auto":
        impl = "fused" if _backend() == "tpu" else "np"
    if alive is not None:
        alive = np.asarray(alive, bool)
        if not alive[graph.entry]:
            raise ValueError("search_batched: graph.entry is tombstoned — "
                             "call reassign_entry() after deleting it")
    ef = max(ef_search, k)
    if impl in ("jit", "fused"):
        import jax
        import jax.numpy as jnp

        p = graph.pack()
        dv, dsq, dn0, dup = p.device_arrays(graph.vecs)
        cdx = graph.codec
        if cdx is None:
            codes = node_bias = c0 = c1 = None
            mode, ksub = "f32", 0
        else:
            codes, node_bias, c0, c1 = cdx.device_arrays()
            mode, ksub = cdx.kind, cdx.ksub
        scores, ids, evals, hops = _traverse_jit_fn()(
            jnp.asarray(q), dv, dsq, dn0, dup,
            jnp.asarray(graph.entry, jnp.int32), codes, node_bias, c0, c1,
            None if alive is None else jnp.asarray(alive),
            ef=ef, k=k, use_pallas=(impl == "fused"), mode=mode, ksub=ksub)
        jax.block_until_ready((scores, ids, evals, hops))
        return (np.asarray(scores), np.asarray(ids),
                np.asarray(evals, np.int64), int(hops))
    # narrow beams pin E to 1 (exact best-first order): multi-expansion
    # only pays when the beam is wide enough that its top-E barely moves
    # per hop, and a sub-8-wide beam is fast without it
    frontier = max(1, min(frontier, ef // 8))
    return _search_batched_np(graph, q, k, ef, frontier=frontier,
                              alive=alive)


def _search_batched_np(graph: HNSWGraph, q: np.ndarray, k: int, ef: int,
                       frontier: int = 8,
                       alive: Optional[np.ndarray] = None
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Host-driven batched driver: one vectorized-numpy ``graph_beam``
    hop per dispatch (see :func:`search_batched`).

    Two throughput levers on top of the plain frontier loop, both
    result-preserving in the common case and bounded where not:

    * ``frontier`` — expand the best ``E`` unexpanded beam entries of each
      live query per hop instead of 1. Python/numpy per-hop overhead is
      the CPU cost floor, so E-wide expansion cuts the hop count ~E-fold.
      E > 1 can expand a node the strictly best-first order would have
      evicted first, so ``evals`` runs a few percent above the sequential
      counter (documented bound: <= 10% at the default E=8 with ef >= 64;
      measured ~2%. E=1 matches the sequential traversal exactly,
      eval-for-eval — :func:`search_batched` pins E=1 when ef < 16).
    * fresh-candidate *compaction* — adjacency rows average far fewer real
      neighbors than their 2M-slot cap, and most have already been
      visited; hop slots are compacted to just the fresh ids (preserving
      slot order, so the stable merge is unchanged) before the fused
      score+merge, which would otherwise burn >80% of its arithmetic on
      masked slots.
    """
    from ..kernels.graph_beam.ops import NEG_INF, graph_beam
    from ..kernels.graph_beam_q.ops import graph_beam_q

    nq = q.shape[0]
    n = graph.ntotal
    p = graph.pack()
    vecs = graph.vecs
    evals = np.zeros(nq, np.int64)
    q_sq = np.einsum("qd,qd->q", q, q)  # hoisted out of the hop loop
    cdx = graph.codec
    if cdx is None:
        # per-row hop operands: the query rows + their norms
        op_a, op_b = q, q_sq

        def hop(ha, hb, ids, bv, bi):
            return graph_beam(ha, vecs, ids, bv, bi, db_sq=p.vecs_sq,
                              q_sq=hb, db_mask=alive, impl="np")
    else:
        # quantized payload: per-query affine operands hoisted once per
        # search; every hop (seed, descent, layer 0) scores codes
        op_a, op_b = cdx.query_operands(q, q_sq)

        def hop(ha, hb, ids, bv, bi):
            return graph_beam_q(ha, hb, cdx.codes, cdx.node_bias, ids, bv,
                                bi, db_mask=alive, mode=cdx.kind,
                                ksub=cdx.ksub, impl="np")

    # entry seed: a 1-wide merge against the lone entry candidate yields
    # (score, id) of the entry point for every query in one dispatch
    sv, si = hop(op_a, op_b, np.full((nq, 1), graph.entry, np.int32),
                 np.full((nq, 1), NEG_INF, np.float32),
                 np.full((nq, 1), -1, np.int32))
    s_cur = sv[:, 0].copy()
    cur = si[:, 0].copy()
    evals += 1

    # upper layers: batched greedy descent. An ef=1 beam merge picks the
    # best of {current} ∪ neighbors; stable ties keep the current node, so
    # "merge returned the same id" IS the sequential stop condition.
    for layer in range(graph.max_level, 0, -1):
        adj = p.upper[layer - 1]
        live = np.arange(nq)
        while live.size:
            ids = adj[cur[live]]                             # [R, M]
            evals[live] += (ids >= 0).sum(axis=1)
            nv, ni = hop(op_a[live], op_b[live], ids, s_cur[live][:, None],
                         cur[live][:, None])
            moved = ni[:, 0] != cur[live]
            s_cur[live] = nv[:, 0]
            cur[live] = ni[:, 0]
            live = live[moved]

    # layer 0: batched best-first beam. state stamps make the visited set
    # O(1) to query/update for the whole batch at once. The loop body
    # special-cases "every query still live" (the common hop — the batch
    # finishes around the same depth) to skip all row-subset copies.
    state = np.zeros((nq, n), np.uint8)     # 0 unseen / 1 seen / 2 expanded
    rows_all = np.arange(nq)
    col_rows = rows_all[:, None]
    beam_v = np.full((nq, ef), NEG_INF, np.float32)
    beam_i = np.full((nq, ef), -1, np.int32)
    beam_v[:, 0] = s_cur
    beam_i[:, 0] = cur
    state[rows_all, cur] = 1
    hops = 0
    while True:
        in_beam = beam_i >= 0
        safe_beam = np.where(in_beam, beam_i, 0)
        unexp = in_beam & (state[col_rows, safe_beam] == 1)
        live = unexp.any(axis=1)
        if not live.any():
            break
        if live.all():
            rows, rcol = rows_all, col_rows
            hq, hq_sq, ue = op_a, op_b, unexp
            bv, bi = beam_v, beam_i
        else:
            rows = np.flatnonzero(live)
            rcol = rows[:, None]
            hq, hq_sq, ue = op_a[rows], op_b[rows], unexp[rows]
            bv, bi = beam_v[rows], beam_i[rows]
        nr = rows.size
        if frontier == 1:
            j = ue.argmax(axis=1)           # beam sorted desc -> first
            nodes = bi[np.arange(nr), j][:, None]
        else:
            # first `frontier` unexpanded slots per row: nonzero emits
            # True positions row-major, searchsorted ranks them within
            # their row; rows with fewer repeat their best node (a no-op
            # re-expansion)
            rn, cn = np.nonzero(ue)
            rank = np.arange(rn.size) - np.searchsorted(rn, rn)
            keep = rank < frontier
            rn, cn, rank = rn[keep], cn[keep], rank[keep]
            nodes = np.full((nr, frontier), -1, np.int32)
            nodes[rn, rank] = bi[rn, cn]
            nodes = np.where(nodes >= 0, nodes, nodes[:, :1])
        state[rcol, nodes] = 2
        nbrs = p.nbrs0[nodes].reshape(nr, -1)                # [R, E*2M]
        valid = nbrs >= 0
        # compact the real neighbor ids left IMMEDIATELY (slot order
        # preserved -> the stable merge is unchanged): adjacency rows
        # average far fewer links than their 2M cap, so every op below
        # runs at ~mean-degree width instead of E*2M
        cnt = valid.cumsum(axis=1)
        width = max(int(cnt[:, -1].max()), 1)
        cand = np.full((nr, width), -1, np.int32)
        vr, vs = np.nonzero(valid)
        cand[vr, cnt[vr, vs] - 1] = nbrs[vr, vs]
        # pad slots alias the (already-expanded) first frontier node so
        # the stamp scatter below can never collide with a real neighbor
        safe = np.where(cand >= 0, cand, nodes[:, :1])
        fresh = (cand >= 0) & (state[rcol, safe] == 0)
        # NOTE: the stamp scatter uses the PRE-dedup mask — every
        # occurrence of a node writes the same value, so numpy's
        # last-write-wins scatter is deterministic
        state[rcol, safe] |= fresh.astype(np.uint8)
        if frontier > 1:
            # E expansions can name the same fresh neighbor twice inside
            # one hop; keep the first slot (stable), mask the rest
            order = np.argsort(safe, axis=1, kind="stable")
            ss = np.take_along_axis(safe, order, axis=1)
            first = np.ones_like(ss, bool)
            first[:, 1:] = ss[:, 1:] != ss[:, :-1]
            dedup = np.empty_like(first)
            np.put_along_axis(dedup, order, first, axis=1)
            fresh &= dedup
        evals[rows] += fresh.sum(axis=1)
        cand = np.where(fresh, cand, -1)
        nv, ni = hop(hq, hq_sq, cand, bv, bi)
        if rows is rows_all:
            beam_v, beam_i = nv, ni
        else:
            beam_v[rows] = nv
            beam_i[rows] = ni
        hops += 1

    scores = beam_v[:, :k].copy()
    ids = beam_i[:, :k].copy()
    scores[ids < 0] = -np.inf
    return scores, ids, evals, hops


def _traverse_impl(q, vecs, vecs_sq, nbrs0, upper, entry, codes, node_bias,
                   c0, c1, alive=None, *, ef: int, k: int, use_pallas: bool,
                   mode: str = "f32", ksub: int = 0):
    """The whole batched traversal as ONE traceable function: greedy
    descent (one ``lax.while_loop`` per upper layer) then the layer-0
    frontier loop (a single ``lax.while_loop`` whose body is one fused
    hop). Jitted via :func:`_traverse_jit_fn`; a search is one XLA
    dispatch, so per-hop cost is pure compute — no host round-trips.

    ``mode`` (static) selects what the hop scores: ``"f32"`` gathers
    corpus rows (``codes``/``node_bias``/``c0``/``c1`` are None);
    ``"sq8"``/``"pq"`` gather the ``codes`` payload and score via the
    unified affine form (c0/c1 = vmin/step for sq8, codebooks/None for
    pq — see :class:`GraphCodes`). The whole traversal switches space
    uniformly — entry seed, greedy descent, and the layer-0 beam all
    score the same payload, so beam ordering is self-consistent.

    Dead rows (queries whose beam is fully expanded) keep looping with
    all-masked candidates until the whole batch converges; every masked
    merge is a bitwise no-op, which is what makes a row's answer
    independent of who else shares its batch.

    ``alive`` (bool [N], traced) tombstones nodes: dead candidate ids are
    demoted to -1 before every score/hop, so a deleted row never enters a
    beam; ``alive=None`` traces the mask-free graph bitwise unchanged."""
    import jax
    import jax.numpy as jnp

    from ..kernels.graph_beam.kernel import NEG_INF, graph_beam_pallas
    from ..kernels.graph_beam_q.kernel import graph_beam_q_pallas

    nq = q.shape[0]
    n = vecs.shape[0]
    rows = jnp.arange(nq)
    rr = rows[:, None]
    q_sq = jnp.einsum("qd,qd->q", q, q)

    if mode == "sq8":
        q_op = (2.0 * q * c1[None, :]).astype(jnp.float32)
        q_bias = (2.0 * (q @ c0) - q_sq).astype(jnp.float32)
    elif mode == "pq":
        from ..search.quantize import adc_lut  # the ONE LUT formula home

        q_op = -adc_lut(c0, q).reshape(nq, -1)
        q_bias = jnp.zeros((nq,), jnp.float32)

    def demote_dead(cand):
        """-1 out tombstoned candidate ids (no-op when alive is None)."""
        if alive is None:
            return cand
        safe = jnp.where(cand >= 0, cand, 0)
        return jnp.where((cand >= 0) & alive[safe], cand, -1)

    def score(cand):
        """[Q, W] score of candidate ids; -1 slots -> NEG_INF. f32 mode
        scores -squared-L2 on corpus rows; quantized modes score the
        code payload (same algebra as ``graph_beam_q``)."""
        cand = demote_dead(cand)
        safe = jnp.where(cand >= 0, cand, 0)
        if mode == "f32":
            g = vecs[safe]                                   # [Q, W, d]
            s = (2.0 * jnp.einsum("qwd,qd->qw", g, q) - vecs_sq[safe]
                 - q_sq[:, None])
        elif mode == "sq8":
            g = codes[safe].astype(jnp.float32)              # [Q, W, d]
            s = (jnp.einsum("qwd,qd->qw", g, q_op) + q_bias[:, None]
                 - node_bias[safe])
        else:
            m = codes.shape[1]
            offs = codes[safe] + jnp.arange(m, dtype=jnp.int32) * ksub
            w = cand.shape[1]
            g = jnp.take_along_axis(q_op, offs.reshape(nq, w * m), axis=1)
            s = (g.reshape(nq, w, m).sum(-1) + q_bias[:, None]
                 - node_bias[safe])
        return jnp.where(cand >= 0, s, NEG_INF)

    def merge_jnp(bv, bi, cand, out_w):
        """top_k merge: first-lowest-index tie rule == the kernel's
        iterative argmax; pads canonicalized to (NEG_INF, -1)."""
        allv = jnp.concatenate([bv, score(cand)], axis=1)
        alli = jnp.concatenate([bi, demote_dead(cand)], axis=1)
        nv, idx = jax.lax.top_k(allv, out_w)
        ni = jnp.take_along_axis(alli, idx, axis=1)
        ni = jnp.where(nv <= NEG_INF, -1, ni)
        nv = jnp.where(ni >= 0, nv, NEG_INF)
        return nv, ni

    # entry seed (scored in whatever space the traversal runs in)
    s_cur = score(jnp.full((nq, 1), entry, jnp.int32))[:, 0].astype(
        jnp.float32)
    cur = jnp.full((nq,), entry, jnp.int32)
    evals = jnp.ones((nq,), jnp.int32)

    # upper layers: batched greedy descent (ef=1 merge; stable ties keep
    # the current node, which IS the sequential stop condition)
    for layer in range(upper.shape[0], 0, -1):
        adj = upper[layer - 1]

        def desc_body(c, adj=adj):
            cur, s_cur, active, evals = c
            ids = adj[cur]                                   # [Q, M]
            valid = (ids >= 0) & active[:, None]
            evals = evals + valid.sum(axis=1, dtype=jnp.int32)
            nv, ni = merge_jnp(s_cur[:, None], cur[:, None],
                               jnp.where(valid, ids, -1), 1)
            moved = (ni[:, 0] != cur) & active
            cur = jnp.where(active, ni[:, 0], cur)
            s_cur = jnp.where(active, nv[:, 0], s_cur)
            return cur, s_cur, moved, evals

        cur, s_cur, _, evals = jax.lax.while_loop(
            lambda c: c[2].any(), desc_body,
            (cur, s_cur, jnp.ones((nq,), bool), evals))

    # layer 0: batched best-first beam over per-query visited stamps
    beam_v = jnp.full((nq, ef), NEG_INF, jnp.float32).at[:, 0].set(s_cur)
    beam_i = jnp.full((nq, ef), -1, jnp.int32).at[:, 0].set(cur)
    state = jnp.zeros((nq, n), jnp.uint8).at[rows, cur].set(1)

    def unexpanded(beam_i, state):
        in_beam = beam_i >= 0
        safe_b = jnp.where(in_beam, beam_i, 0)
        return in_beam & (jnp.take_along_axis(state, safe_b, axis=1) == 1)

    def hop_body(c):
        beam_v, beam_i, state, evals, hops = c
        unexp = unexpanded(beam_i, state)
        live = unexp.any(axis=1)
        j = jnp.argmax(unexp, axis=1)     # beam sorted desc -> first
        node = jnp.take_along_axis(beam_i, j[:, None], axis=1)[:, 0]
        node = jnp.where(live, node, 0)
        state = state.at[rows, node].max(
            jnp.where(live, jnp.uint8(2), jnp.uint8(0)))
        nbrs = nbrs0[node]                                   # [Q, 2M]
        valid = (nbrs >= 0) & live[:, None]
        # pad slots alias the expanded node: the stamp scatter can never
        # collide with a real neighbor (adjacency has no self-loops)
        safe = jnp.where(valid, nbrs, node[:, None])
        fresh = valid & (jnp.take_along_axis(state, safe, axis=1) == 0)
        state = state.at[rr, safe].max(fresh.astype(jnp.uint8))
        evals = evals + fresh.sum(axis=1, dtype=jnp.int32)
        cand = demote_dead(jnp.where(fresh, nbrs, -1))
        if not use_pallas:
            nv, ni = merge_jnp(beam_v, beam_i, cand, ef)
        elif mode == "f32":
            nv, ni = graph_beam_pallas(q, vecs, vecs_sq, cand,
                                       beam_v, beam_i)
        else:
            nv, ni = graph_beam_q_pallas(q_op, q_bias, codes, node_bias,
                                         cand, beam_v, beam_i, mode=mode,
                                         ksub=ksub)
        return nv, ni, state, evals, hops + 1

    beam_v, beam_i, _, evals, hops = jax.lax.while_loop(
        lambda c: unexpanded(c[1], c[2]).any(), hop_body,
        (beam_v, beam_i, state, evals, jnp.int32(0)))

    scores = beam_v[:, :k]
    ids = beam_i[:, :k]
    return jnp.where(ids >= 0, scores, -jnp.inf), ids, evals, hops


_TRAVERSE_JIT = None


def _traverse_jit_fn():
    """Jitted :func:`_traverse_impl` (lazy: this module must import
    without jax). One compile per (batch, graph, ef, k) shape — the
    serving engine's pow2 warm-up visits exactly these."""
    global _TRAVERSE_JIT
    if _TRAVERSE_JIT is None:
        import jax

        _TRAVERSE_JIT = jax.jit(_traverse_impl,
                                static_argnames=("ef", "k", "use_pallas",
                                                 "mode", "ksub"))
    return _TRAVERSE_JIT


def recall_vs_exact(graph: HNSWGraph, corpus: np.ndarray,
                    queries: np.ndarray, k: int, ef_search: int) -> float:
    import jax.numpy as jnp

    from ..core.metrics import knn_indices, set_overlap

    exact = knn_indices(jnp.asarray(queries), jnp.asarray(corpus), k)
    _, got, _ = search(graph, queries, k, ef_search)
    return float(set_overlap(exact, jnp.asarray(got)))
