"""HNSW graph search (Malkov & Yashunin 2016) — the sublinear search tier.

Layered navigable-small-world graph: each node draws a top layer from the
geometric distribution ``floor(-ln(U) / ln(M))``; insert runs an
``ef_construction``-bounded beam per layer and connects to at most ``M``
neighbors chosen by the pruning heuristic (Alg. 4: a candidate joins only
if it is closer to the query than to every already-selected neighbor,
which keeps edges spread across directions instead of clustering). Degrees
are capped at ``M`` on upper layers and ``2M`` at layer 0; when a cap
overflows, the overfull list is re-pruned with the same heuristic and the
dropped back-links are removed, so links stay bidirectional (unlike
hnswlib, which leaves asymmetric edges after a shrink — symmetric graphs
are what the invariant suite checks, and pruned slots are refilled with
the nearest rejected candidates to protect connectivity).

Search greedy-descends from the entry point through the upper layers
(ef=1) and runs the ef-bounded best-first beam at layer 0. Traversal is
pointer-chasing and stays on host (numpy + heapq); only the inner
candidate-distance batches are vectorized, routed through the fused
Pallas L2 scan on TPU and a numpy ref elsewhere
(:func:`candidate_distances`). Every distance evaluation is counted —
:func:`search` returns per-query eval totals, the sublinearity axis the
benchmarks report next to recall.

Composes with the paper's RAE exactly like IVF: build the graph over the
*reduced* corpus and rerank in R^n, so beam search pays O(m) per hop
instead of O(n).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np

_MAX_LEVEL = 15


def _backend() -> str:
    import jax

    return jax.default_backend()


def _resolve_impl(impl: str) -> str:
    """Collapse ``"auto"`` to a concrete impl ONCE per build/search — the
    backend cannot change mid-traversal and the hot loops issue tens of
    thousands of tiny distance batches."""
    if impl == "auto":
        return "fused" if _backend() == "tpu" else "np"
    return impl


def candidate_distances(q: np.ndarray, vecs: np.ndarray,
                        impl: str = "auto") -> np.ndarray:
    """Squared L2 from one query [d] to a candidate batch [c, d].

    ``impl="fused"`` routes through the fused ``l2_topk`` scan (Pallas on
    TPU, jnp ref elsewhere) with k = c and scatters the sorted output back
    to input order; ``"np"`` is the host ref. ``"auto"`` picks fused only
    on TPU — traversal is host-driven, so device round-trips lose on CPU.
    """
    impl = _resolve_impl(impl)
    if impl == "np":
        diff = vecs - q
        return np.einsum("cd,cd->c", diff, diff)
    import jax.numpy as jnp

    from ..kernels import l2_topk

    c = int(vecs.shape[0])
    scores, idx = l2_topk(jnp.asarray(q)[None, :], jnp.asarray(vecs), c)
    out = np.empty(c, np.float32)
    out[np.asarray(idx[0])] = -np.asarray(scores[0])  # scores = -||q-d||^2
    return out


class _Evals:
    """Mutable distance-evaluation counter threaded through the traversal."""

    __slots__ = ("n",)

    def __init__(self):
        self.n = 0


@dataclass
class HNSWGraph:
    """Padded-dense adjacency: ``links0`` [N, 2M] is layer 0, ``links``
    [L, N, M] are layers 1..L (-1 = empty slot; rows of nodes absent from
    a layer are all -1)."""

    vecs: np.ndarray     # [N, d] float32
    levels: np.ndarray   # [N] int32: top layer of each node
    links0: np.ndarray   # [N, 2M] int32
    links: np.ndarray    # [L, N, M] int32
    entry: int
    M: int

    @property
    def ntotal(self) -> int:
        return int(self.vecs.shape[0])

    @property
    def max_level(self) -> int:
        return int(self.levels[self.entry])

    def adjacency(self, layer: int) -> np.ndarray:
        return self.links0 if layer == 0 else self.links[layer - 1]


def sample_levels(n: int, M: int, seed: int) -> np.ndarray:
    """Geometric level draw: floor(-ln(U) * mL) with mL = 1/ln(M)."""
    rng = np.random.default_rng(seed)
    u = rng.uniform(np.finfo(np.float64).tiny, 1.0, size=n)
    lv = np.floor(-np.log(u) / np.log(max(M, 2))).astype(np.int32)
    return np.minimum(lv, _MAX_LEVEL)


def _greedy_descent(vecs, adj, q, cur, d_cur, evals, impl):
    """ef=1 layer traversal: hop to the closest neighbor until no
    neighbor improves."""
    while True:
        nbrs = adj[cur]
        nbrs = nbrs[nbrs >= 0]
        if nbrs.size == 0:
            return cur, d_cur
        ds = candidate_distances(q, vecs[nbrs], impl)
        evals.n += int(nbrs.size)
        j = int(np.argmin(ds))
        if ds[j] >= d_cur:
            return cur, d_cur
        cur, d_cur = int(nbrs[j]), float(ds[j])


def _search_layer(vecs, adj, q, eps, ef, visited, stamp, evals, impl):
    """Best-first beam (Alg. 2): returns the ef closest visited nodes as a
    sorted [(dist, node), ...] list. ``eps`` are (dist, node) entry points
    (already counted); ``visited``/``stamp`` implement an O(1)-reset
    visited set shared across calls."""
    cand: list[tuple[float, int]] = []   # min-heap on distance
    res: list[tuple[float, int]] = []    # max-heap via negated distance
    for d, e in eps:
        visited[e] = stamp
        heapq.heappush(cand, (d, e))
        heapq.heappush(res, (-d, e))
    while cand:
        d, c = heapq.heappop(cand)
        if d > -res[0][0] and len(res) >= ef:
            break
        nbrs = adj[c]
        nbrs = nbrs[nbrs >= 0]
        fresh = nbrs[visited[nbrs] != stamp]
        if fresh.size == 0:
            continue
        visited[fresh] = stamp
        ds = candidate_distances(q, vecs[fresh], impl)
        evals.n += int(fresh.size)
        worst = -res[0][0]
        full = len(res) >= ef
        for dj, nj in zip(ds.tolist(), fresh.tolist()):
            if not full or dj < worst:
                heapq.heappush(cand, (dj, nj))
                heapq.heappush(res, (-dj, nj))
                if len(res) > ef:
                    heapq.heappop(res)
                worst = -res[0][0]
                full = len(res) >= ef
    return sorted((-nd, node) for nd, node in res)


def _select_heuristic(cands, vecs, m, evals, impl, keep_pruned=False):
    """Alg. 4 neighbor selection: scan candidates nearest-first, keep one
    only if it is closer to the query than to every kept neighbor. With
    ``keep_pruned`` the remaining slots are refilled nearest-first (used
    on cap overflow, where dropping to << m edges risks disconnection)."""
    sel: list[int] = []
    sel_vecs: list[np.ndarray] = []
    pruned: list[int] = []
    for d_c, c in cands:
        if len(sel) >= m:
            break
        if sel:
            ds = candidate_distances(vecs[c], np.stack(sel_vecs), impl)
            evals.n += len(sel)
            if not np.all(d_c < ds):
                pruned.append(c)
                continue
        sel.append(c)
        sel_vecs.append(vecs[c])
    if keep_pruned:
        sel.extend(pruned[: m - len(sel)])
    return sel


def _bfs_layer0(links0: np.ndarray, entry: int) -> np.ndarray:
    """Boolean reachability mask of the layer-0 graph from ``entry``."""
    seen = np.zeros(links0.shape[0], bool)
    seen[entry] = True
    stack = [entry]
    while stack:
        c = stack.pop()
        for t in links0[c][links0[c] >= 0].tolist():
            if not seen[t]:
                seen[t] = True
                stack.append(t)
    return seen


def _evict_farthest(links0, vecs, node, evals, impl) -> None:
    """Free one slot in a full row by dropping its farthest link (both
    directions, keeping the graph symmetric)."""
    nbrs = links0[node][links0[node] >= 0]
    ds = candidate_distances(vecs[node], vecs[nbrs], impl)
    evals.n += int(nbrs.size)
    t = int(nbrs[np.argmax(ds)])
    links0[t][links0[t] == node] = -1
    links0[node][links0[node] == t] = -1


def _repair_connectivity(vecs, links0, entry, evals, impl) -> int:
    """Symmetric pruning can (rarely) strand a node at layer 0: every
    neighbor that once pointed at it overflowed and evicted it. Stitch each
    stranded component back via its nearest reachable node — an evictee
    keeps its other edges, so the loop makes monotone progress and the
    layer-0 reachability invariant holds unconditionally."""
    stitched = 0
    for _ in range(links0.shape[0]):
        seen = _bfs_layer0(links0, entry)
        miss = np.flatnonzero(~seen)
        if miss.size == 0:
            return stitched
        u = int(miss[0])
        reach = np.flatnonzero(seen)
        ds = candidate_distances(vecs[u], vecs[reach], impl)
        evals.n += int(reach.size)
        r = int(reach[np.argmin(ds)])
        for node in (u, r):
            if not np.any(links0[node] < 0):
                _evict_farthest(links0, vecs, node, evals, impl)
        links0[u][np.flatnonzero(links0[u] < 0)[0]] = r
        links0[r][np.flatnonzero(links0[r] < 0)[0]] = u
        stitched += 1
    return stitched


def build(corpus: np.ndarray, M: int = 32, ef_construction: int = 100,
          seed: int = 0, impl: str = "auto") -> HNSWGraph:
    """Sequential heuristic insert of every corpus row (Alg. 1)."""
    vecs = np.ascontiguousarray(np.asarray(corpus, np.float32))
    n = vecs.shape[0]
    if n == 0:
        raise ValueError("empty corpus")
    impl = _resolve_impl(impl)
    m0 = 2 * M
    levels = sample_levels(n, M, seed)
    top = int(levels.max())
    links0 = np.full((n, m0), -1, np.int32)
    links = np.full((top, n, M), -1, np.int32)
    visited = np.full(n, -1, np.int64)
    # the traversal helpers are shared with search(), where the caller
    # consumes the count; at build time it only feeds the helpers
    evals = _Evals()
    entry = 0

    def write_row(adj, node, nbrs):
        row = adj[node]
        row[: len(nbrs)] = nbrs
        row[len(nbrs):] = -1

    for i in range(1, n):
        q = vecs[i]
        l_i = int(levels[i])
        l_ep = int(levels[entry])
        cur = entry
        d_cur = float(candidate_distances(q, vecs[entry][None], impl)[0])
        evals.n += 1
        for layer in range(l_ep, l_i, -1):
            cur, d_cur = _greedy_descent(vecs, links[layer - 1], q, cur,
                                         d_cur, evals, impl)
        eps = [(d_cur, cur)]
        for layer in range(min(l_ep, l_i), -1, -1):
            adj = links0 if layer == 0 else links[layer - 1]
            cap = m0 if layer == 0 else M
            found = _search_layer(vecs, adj, q, eps, ef_construction,
                                  visited, i * (top + 1) + layer, evals,
                                  impl)
            sel = _select_heuristic(found, vecs, M, evals, impl)
            write_row(adj, i, sel)
            # bidirectional: add the back-link, re-pruning on overflow and
            # dropping the reverse edge of anything the prune evicts
            for s in sel:
                row = adj[s]
                free = np.flatnonzero(row < 0)  # prune leaves holes anywhere
                if free.size:
                    row[free[0]] = i
                    continue
                nbrs = row[row >= 0]
                ds = candidate_distances(vecs[s], vecs[nbrs], impl)
                evals.n += int(nbrs.size)
                d_i = float(candidate_distances(vecs[s], q[None], impl)[0])
                evals.n += 1
                merged = sorted([*zip(ds.tolist(), nbrs.tolist()),
                                 (d_i, i)])
                kept = _select_heuristic(merged, vecs, cap, evals, impl,
                                         keep_pruned=True)
                for t in nbrs:
                    if t not in kept:
                        trow = adj[t]
                        trow[trow == s] = -1
                if i not in kept and len(kept) < cap:
                    kept.append(i)  # never orphan the node being inserted
                elif i not in kept:
                    irow = adj[i]
                    irow[irow == s] = -1
                write_row(adj, s, kept)
            eps = found
        if l_i > int(levels[entry]):
            entry = i
    _repair_connectivity(vecs, links0, entry, evals, impl)
    # compact pad slots left of real links (prune leaves holes)
    for adj in (links0, *links):
        order = np.argsort(adj < 0, axis=1, kind="stable")
        adj[:] = np.take_along_axis(adj, order, axis=1)
    return HNSWGraph(vecs=vecs, levels=levels, links0=links0, links=links,
                     entry=entry, M=M)


def search(graph: HNSWGraph, queries: np.ndarray, k: int,
           ef_search: int = 64, impl: str = "auto"
           ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Beam search per query. Returns (scores [Q, k], ids [Q, k], evals
    [Q]): scores = -squared-euclidean (engine convention, higher =
    closer), ids pad with -1 / scores with -inf when the beam holds fewer
    than k nodes, evals = distance computations per query (the visited
    count — the sublinearity metric)."""
    q = np.asarray(queries, np.float32)
    nq = q.shape[0]
    impl = _resolve_impl(impl)
    ef = max(ef_search, k)
    scores = np.full((nq, k), -np.inf, np.float32)
    ids = np.full((nq, k), -1, np.int32)
    evals = np.zeros(nq, np.int64)
    visited = np.full(graph.ntotal, -1, np.int64)
    for qi in range(nq):
        cnt = _Evals()
        cur = graph.entry
        d_cur = float(candidate_distances(q[qi], graph.vecs[cur][None],
                                          impl)[0])
        cnt.n += 1
        for layer in range(graph.max_level, 0, -1):
            cur, d_cur = _greedy_descent(graph.vecs, graph.links[layer - 1],
                                         q[qi], cur, d_cur, cnt, impl)
        found = _search_layer(graph.vecs, graph.links0, q[qi],
                              [(d_cur, cur)], ef, visited, qi, cnt, impl)
        for j, (d, node) in enumerate(found[:k]):
            scores[qi, j] = -d
            ids[qi, j] = node
        evals[qi] = cnt.n
    return scores, ids, evals


def recall_vs_exact(graph: HNSWGraph, corpus: np.ndarray,
                    queries: np.ndarray, k: int, ef_search: int) -> float:
    import jax.numpy as jnp

    from ..core.metrics import knn_indices, set_overlap

    exact = knn_indices(jnp.asarray(queries), jnp.asarray(corpus), k)
    _, got, _ = search(graph, queries, k, ef_search)
    return float(set_overlap(exact, jnp.asarray(got)))
