"""Distributed exact vector search: sharded scan + global top-k merge.

The corpus is row-sharded over every mesh axis ("db_rows"). Each shard runs
the fused distance+top-k kernel (Pallas on TPU; jnp oracle elsewhere) over
its slab; the global merge all-gathers only the per-shard (k values,
k global indices) — k * n_shards scalars — and reduces them with the
deterministic ``topk_merge`` kernel.

Three invariants (regression-tested in tests/test_sharded.py) that the
original version of this module violated:

* **ragged corpora** — when ``n % n_shards != 0`` the corpus is padded up
  to ``n_shards * ceil(n / n_shards)`` rows and pad rows are pinned to
  ``NEG_INF`` / ``PAD_ID`` before they can reach the merge; global ids are
  mapped with the padded slab size, so no tail row is dropped or mislabeled.
* **small shards** — per-shard ``top_k`` is clamped to the slab size and
  padded back to ``k`` with ``(NEG_INF, PAD_ID)`` (the ``l2_topk``
  convention), so ``k > n_loc`` cannot crash ``lax.top_k``.
* **deterministic merge** — score ties break by the smaller global index
  (``topk_merge``), never by gather order, so the result is bitwise
  invariant to the shard count.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from ..distributed.partitioning import _flat_axes
from ..kernels.common import NEG_INF, PAD_ID
from ..kernels.topk_merge.ops import topk_merge
from ..models.common import MeshCtx


def _padded_topk(s: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """``lax.top_k`` along the last axis, clamped to the axis size and
    padded back to ``k`` with ``(NEG_INF, PAD_ID)`` when k overflows it."""
    n = s.shape[-1]
    kl = min(k, n)
    v, i = jax.lax.top_k(s, kl)
    if kl < k:
        pad = k - kl
        v = jnp.concatenate(
            [v, jnp.full((*v.shape[:-1], pad), NEG_INF, v.dtype)], -1)
        i = jnp.concatenate(
            [i, jnp.full((*i.shape[:-1], pad), PAD_ID, i.dtype)], -1)
    return v, i


def local_topk_scores(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    return _padded_topk(scores, k)


def _shard_axes(ctx: MeshCtx, logical: str) -> tuple[tuple[str, ...], int]:
    """Mesh axes a logical name shards over, WITHOUT the divisibility
    filter of ``usable_axes`` — ragged sizes are handled by padding the
    slab, not by silently degrading to replication."""
    if ctx.mesh is None:
        return (), 1
    axes = tuple(a for a in _flat_axes(ctx.rules.get(logical))
                 if a in ctx.mesh.shape and ctx.mesh.shape[a] > 1)
    return axes, math.prod(ctx.mesh.shape[a] for a in axes) if axes else 1


def _linear_shard_index(mesh, axes) -> jax.Array:
    shard = jnp.zeros((), jnp.int32)
    for a in axes:
        shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
    return shard


def distributed_topk(scores: jax.Array, k: int, ctx: MeshCtx,
                     logical: str = "db_rows") -> tuple[jax.Array, jax.Array]:
    """scores [N] (higher=better), row-sharded -> (vals [k], global idx [k])."""
    n = scores.shape[0]
    axes, n_shards = _shard_axes(ctx, logical)
    if n_shards == 1:
        return _padded_topk(scores, k)

    mesh = ctx.mesh
    n_loc = -(-n // n_shards)           # ceil: last shard may be ragged
    n_pad = n_loc * n_shards
    if n_pad > n:
        scores = jnp.pad(scores, (0, n_pad - n), constant_values=NEG_INF)
    kl = min(k, n_loc)
    s_spec = ctx.pspec((n_pad,), logical)
    r_spec = ctx.pspec((k,))

    def f(s_l):
        v, i = jax.lax.top_k(s_l, kl)
        shard = _linear_shard_index(mesh, axes)
        gi = i + shard * n_loc
        v = jnp.where(gi < n, v, NEG_INF)       # pad rows never win
        gi = jnp.where(gi < n, gi, PAD_ID)
        if kl < k:
            v = jnp.concatenate(
                [v, jnp.full((k - kl,), NEG_INF, v.dtype)])
            gi = jnp.concatenate(
                [gi, jnp.full((k - kl,), PAD_ID, gi.dtype)])
        vs = jax.lax.all_gather(v, axes, axis=0, tiled=True)   # [k*n_shards]
        gis = jax.lax.all_gather(gi, axes, axis=0, tiled=True)
        vg, ig = topk_merge(vs[None, :], gis[None, :], k)
        return vg[0], ig[0]

    fn = shard_map(f, mesh=mesh, in_specs=(s_spec,),
                   out_specs=(r_spec, r_spec), check_rep=False)
    return fn(scores)


def sharded_scores(queries: jax.Array, db: jax.Array, metric: str,
                   ctx: MeshCtx) -> jax.Array:
    """[Q, N] similarity scores (higher = closer) with db row-sharded."""
    q32 = queries.astype(jnp.float32)
    db = ctx.constrain(db, "db_rows", None)
    d32 = db.astype(jnp.float32)
    if metric == "cosine":
        qn = q32 / jnp.maximum(jnp.linalg.norm(q32, -1, keepdims=True), 1e-12)
        dn = d32 / jnp.maximum(jnp.linalg.norm(d32, -1, keepdims=True), 1e-12)
        s = qn @ dn.T
    elif metric == "euclidean":
        q2 = jnp.sum(q32 * q32, -1)[:, None]
        d2 = jnp.sum(d32 * d32, -1)[None, :]
        s = -(q2 - 2.0 * q32 @ d32.T + d2)  # negative squared distance
    else:
        raise ValueError(metric)
    return ctx.constrain(s, None, "db_rows")


def search(queries: jax.Array, db: jax.Array, k: int, ctx: MeshCtx,
           metric: str = "euclidean", alive: jax.Array | None = None
           ) -> tuple[jax.Array, jax.Array]:
    """Exact k-NN: returns (scores [Q, k], indices [Q, k]).

    ``alive`` (bool [N]) tombstones db rows: a dead row is pinned to
    ``(NEG_INF, PAD_ID)`` before the local top-k on every shard, so it can
    never surface — same contract as ``l2_topk``'s ``db_mask`` operand.
    ``alive=None`` leaves the static path bitwise untouched."""
    n = db.shape[0]
    axes, n_shards = _shard_axes(ctx, "db_rows")
    if n_shards == 1:
        s = sharded_scores(queries, db, metric, ctx)
        if alive is None:
            return _padded_topk(s, k)
        s = jnp.where(alive[None, :], s, NEG_INF)
        v, i = _padded_topk(s, k)
        i = jnp.where(v <= NEG_INF / 2, PAD_ID, i)
        return jnp.where(i == PAD_ID, NEG_INF, v), i

    mesh = ctx.mesh
    n_loc = -(-n // n_shards)           # ceil: last shard may be ragged
    n_pad = n_loc * n_shards
    if n_pad > n:
        db = jnp.pad(db, ((0, n_pad - n), (0, 0)))
    if alive is not None and n_pad > alive.shape[0]:
        alive = jnp.pad(alive, (0, n_pad - alive.shape[0]))
    kl = min(k, n_loc)
    q_spec = ctx.pspec(queries.shape)          # queries replicated
    db_spec = ctx.pspec((n_pad, db.shape[1]), "db_rows", None)
    out_spec = ctx.pspec((queries.shape[0], k))

    def f(q_l, db_l, *alive_l):
        s = sharded_scores(q_l, db_l, metric, MeshCtx(mesh=None))
        shard = _linear_shard_index(mesh, axes)
        # pin pad rows BEFORE the local top-k: a padded (zero) row must
        # not displace a real candidate inside the shard
        grow = shard * n_loc + jnp.arange(s.shape[1], dtype=jnp.int32)
        keep = grow[None, :] < n
        if alive_l:  # tombstones ride the same never-wins lane as pads
            keep = keep & alive_l[0][None, :]
        s = jnp.where(keep, s, NEG_INF)
        v, i = jax.lax.top_k(s, kl)             # [Q, kl] local
        gi = shard * n_loc + i
        dead = (gi >= n) | (v <= NEG_INF / 2)
        v = jnp.where(dead, NEG_INF, v)
        gi = jnp.where(dead, PAD_ID, gi)
        if kl < k:
            pad = k - kl
            v = jnp.concatenate(
                [v, jnp.full((v.shape[0], pad), NEG_INF, v.dtype)], 1)
            gi = jnp.concatenate(
                [gi, jnp.full((gi.shape[0], pad), PAD_ID, gi.dtype)], 1)
        vs = jax.lax.all_gather(v, axes, axis=1, tiled=True)   # [Q, k*S]
        gis = jax.lax.all_gather(gi, axes, axis=1, tiled=True)
        return topk_merge(vs, gis, k)

    in_specs = (q_spec, db_spec)
    args = (queries, db)
    if alive is not None:
        in_specs += (ctx.pspec((n_pad,), "db_rows"),)
        args += (alive,)
    fn = shard_map(f, mesh=mesh, in_specs=in_specs,
                   out_specs=(out_spec, out_spec), check_rep=False)
    return fn(*args)
