"""Distributed exact vector search: sharded scan + global top-k merge.

The corpus is row-sharded over every mesh axis ("db_rows"). Each shard runs
the fused distance+top-k kernel (Pallas on TPU; jnp oracle elsewhere) over
its slab; the global merge all-gathers only the per-shard (k values,
k global indices) — k * n_shards scalars — and reduces with one final top_k.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from ..models.common import MeshCtx


def local_topk_scores(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    return jax.lax.top_k(scores, k)


def distributed_topk(scores: jax.Array, k: int, ctx: MeshCtx,
                     logical: str = "db_rows") -> tuple[jax.Array, jax.Array]:
    """scores [N] (higher=better), row-sharded -> (vals [k], global idx [k])."""
    n = scores.shape[0]
    if ctx.mesh is None or ctx.shards_for(n, logical) == 1:
        return jax.lax.top_k(scores, k)

    mesh = ctx.mesh
    axes = ctx.used_axes(n, logical)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    n_loc = n // n_shards
    s_spec = ctx.pspec((n,), logical)
    r_spec = ctx.pspec((k,))

    def f(s_l):
        v, i = jax.lax.top_k(s_l, k)
        shard = jnp.zeros((), jnp.int32)
        for a in axes:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        gi = i + shard * n_loc
        vs = jax.lax.all_gather(v, axes, axis=0, tiled=True)   # [k*n_shards]
        gis = jax.lax.all_gather(gi, axes, axis=0, tiled=True)
        vg, sel = jax.lax.top_k(vs, k)
        return vg, jnp.take(gis, sel)

    fn = shard_map(f, mesh=mesh, in_specs=(s_spec,),
                   out_specs=(r_spec, r_spec), check_rep=False)
    return fn(scores)


def sharded_scores(queries: jax.Array, db: jax.Array, metric: str,
                   ctx: MeshCtx) -> jax.Array:
    """[Q, N] similarity scores (higher = closer) with db row-sharded."""
    q32 = queries.astype(jnp.float32)
    db = ctx.constrain(db, "db_rows", None)
    d32 = db.astype(jnp.float32)
    if metric == "cosine":
        qn = q32 / jnp.maximum(jnp.linalg.norm(q32, -1, keepdims=True), 1e-12)
        dn = d32 / jnp.maximum(jnp.linalg.norm(d32, -1, keepdims=True), 1e-12)
        s = qn @ dn.T
    elif metric == "euclidean":
        q2 = jnp.sum(q32 * q32, -1)[:, None]
        d2 = jnp.sum(d32 * d32, -1)[None, :]
        s = -(q2 - 2.0 * q32 @ d32.T + d2)  # negative squared distance
    else:
        raise ValueError(metric)
    return ctx.constrain(s, None, "db_rows")


def search(queries: jax.Array, db: jax.Array, k: int, ctx: MeshCtx,
           metric: str = "euclidean") -> tuple[jax.Array, jax.Array]:
    """Exact k-NN: returns (scores [Q, k], indices [Q, k])."""
    n = db.shape[0]
    if ctx.mesh is None or ctx.shards_for(n, "db_rows") == 1:
        s = sharded_scores(queries, db, metric, ctx)
        return jax.lax.top_k(s, k)

    mesh = ctx.mesh
    axes = ctx.used_axes(n, "db_rows")
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    n_loc = n // n_shards
    q_spec = ctx.pspec(queries.shape)          # queries replicated
    db_spec = ctx.pspec(db.shape, "db_rows", None)
    out_spec = ctx.pspec((queries.shape[0], k))

    def f(q_l, db_l):
        s = sharded_scores(q_l, db_l, metric, MeshCtx(mesh=None))
        v, i = jax.lax.top_k(s, k)  # [Q, k] local
        shard = jnp.zeros((), jnp.int32)
        for a in axes:
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        gi = i + shard * n_loc
        vs = jax.lax.all_gather(v, axes, axis=1, tiled=True)   # [Q, k*S]
        gis = jax.lax.all_gather(gi, axes, axis=1, tiled=True)
        vg, sel = jax.lax.top_k(vs, k)
        return vg, jnp.take_along_axis(gis, sel, axis=1)

    fn = shard_map(f, mesh=mesh, in_specs=(q_spec, db_spec),
                   out_specs=(out_spec, out_spec), check_rep=False)
    return fn(queries, db)
