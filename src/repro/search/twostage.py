"""Two-stage retrieval with RAE (beyond-paper integration, DESIGN.md §2).

Stage 1 scans the *reduced* corpus (R^m, m << n) with the fused
distance+top-k engine for k * rerank_factor candidates — this is where the
paper's compression pays: scan FLOPs and bytes both shrink by n/m.
Stage 2 reranks only the candidates in the original space, recovering the
exact-metric ordering on the shortlist. The paper's k-NN preservation bound
(kappa(W), Eq. 16) governs stage-1 recall, which ``recall_vs_exact``
measures directly.

:func:`rerank_candidates` is the stage-2 engine shared by every two-stage
path (this module and ``api.TwoStageIndex``): it takes the PADDED
candidate matrix any stage-1 tier emits — IVF probes and the batched HNSW
beam both pad short rows with id -1 — gathers the candidate vectors
INSIDE the jit (XLA fuses the gather with the distance compute; the
serving path pays one dispatch, not two), pins pad slots to -inf, and
returns the exact top-k in the original space.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core import rae as rae_lib
from ..models.common import MeshCtx
from . import distributed as ds


def rerank_candidates(queries: jax.Array, db_full: jax.Array,
                      cand: jax.Array, k: int,
                      metric: str = "euclidean"
                      ) -> tuple[jax.Array, jax.Array]:
    """Exact full-space rerank of a padded candidate matrix.

    ``queries`` [Q, n], ``db_full`` [N, n], ``cand`` [Q, k1] int (id -1 =
    pad from a short stage-1 row). Returns (scores [Q, k], indices [Q, k])
    — scores follow the engine convention (higher = closer). Jit-safe with
    ``k`` static; pads keep their -1 id but score -inf so they can never
    outrank a real candidate.
    """
    # gather INSIDE the jit: XLA fuses it with the distance compute (one
    # dispatch per search, and the [Q, k1, n] gather never round-trips)
    cand_vecs = jnp.take(db_full, cand, axis=0)  # [Q, k1, n]
    q32 = queries.astype(jnp.float32)
    c32 = cand_vecs.astype(jnp.float32)
    if metric == "cosine":
        qn = q32 / jnp.maximum(
            jnp.linalg.norm(q32, axis=-1, keepdims=True), 1e-12)
        cn = c32 / jnp.maximum(
            jnp.linalg.norm(c32, axis=-1, keepdims=True), 1e-12)
        s = jnp.einsum("qd,qcd->qc", qn, cn)
    else:
        s = -jnp.sum(jnp.square(c32 - q32[:, None, :]), -1)
    # a padded id (-1, wrapped to the LAST corpus row by jnp.take above)
    # keeps its -1 id but is pinned to -inf so it can never win
    s = jnp.where(cand >= 0, s, -jnp.inf)
    v, sel = jax.lax.top_k(s, k)
    return v, jnp.take_along_axis(cand, sel, axis=1)


def encode_corpus(rae_params, db: jax.Array, ctx: MeshCtx,
                  chunk: int = 65536) -> jax.Array:
    """Encode a (possibly huge) corpus through W_e, preserving row sharding."""
    db = ctx.constrain(db, "db_rows", None)
    z = rae_lib.encode(rae_params, db.astype(jnp.float32))
    return ctx.constrain(z, "db_rows", None)


def two_stage_search(
    queries: jax.Array,       # [Q, n]
    db_full: jax.Array,       # [N, n] row-sharded
    db_reduced: jax.Array,    # [N, m] row-sharded (encode_corpus output)
    rae_params,
    k: int,
    ctx: MeshCtx,
    rerank_factor: int = 4,
    metric: str = "euclidean",
) -> tuple[jax.Array, jax.Array]:
    """Returns (scores [Q, k], indices [Q, k]) in the ORIGINAL space."""
    zq = rae_lib.encode(rae_params, queries.astype(jnp.float32))
    k1 = min(k * rerank_factor, db_reduced.shape[0])
    _, cand = ds.search(zq, db_reduced, k1, ctx, metric=metric)  # [Q, k1]
    return rerank_candidates(queries, db_full, cand, k, metric)


def recall_vs_exact(queries, db_full, db_reduced, rae_params, k, ctx,
                    rerank_factor: int = 4, metric: str = "euclidean") -> float:
    """Recall@k of two-stage search against the exact full-space scan."""
    _, exact_idx = ds.search(queries, db_full, k, ctx, metric=metric)
    _, ts_idx = two_stage_search(queries, db_full, db_reduced, rae_params, k,
                                 ctx, rerank_factor, metric)
    inter = (exact_idx[:, :, None] == ts_idx[:, None, :]).any(-1)
    return float(jnp.mean(inter.astype(jnp.float32)))
