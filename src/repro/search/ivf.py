"""IVF (inverted-file) coarse quantization in JAX — beyond-paper search tier.

FAISS-style two-level index: k-means coarse centroids partition the corpus;
queries probe the ``nprobe`` nearest cells and scan only those lists. On
TPU, ragged inverted lists become a *padded dense* layout ([n_cells,
cell_cap, d] + validity mask) so the probe scan is a fixed-shape gather +
batched matmul — no host-side indirection in the hot path.

Composes with the paper's RAE: build the IVF over the *reduced* corpus
(R^m) and rerank in R^n — compression shrinks both the centroid search and
the list scan, while kappa(W) (Eq. 16) bounds the extra recall loss.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class IVFIndex:
    centroids: jax.Array   # [C, d]
    lists: jax.Array       # [C, cap] int32 corpus row ids (-1 = pad)
    list_vecs: jax.Array   # [C, cap, d] padded member vectors
    list_mask: jax.Array   # [C, cap] bool
    spill: int             # rows dropped by the cap (0 in healthy builds)


def kmeans(x: jax.Array, n_clusters: int, iters: int = 10,
           seed: int = 0) -> jax.Array:
    """Plain Lloyd's k-means (k-means++-lite init via random distinct rows)."""
    n = x.shape[0]
    key = jax.random.PRNGKey(seed)
    idx = jax.random.choice(key, n, (n_clusters,), replace=False)
    cent = x[idx]

    @jax.jit
    def step(cent):
        d2 = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
              + jnp.sum(cent * cent, 1)[None, :])
        assign = jnp.argmin(d2, 1)
        sums = jax.ops.segment_sum(x, assign, num_segments=n_clusters)
        cnt = jax.ops.segment_sum(jnp.ones(n), assign,
                                  num_segments=n_clusters)
        new = sums / jnp.maximum(cnt, 1.0)[:, None]
        # keep empty clusters where they were
        return jnp.where(cnt[:, None] > 0, new, cent), assign

    assign = None
    for _ in range(iters):
        cent, assign = step(cent)
    return cent, assign


def build(corpus: jax.Array, n_cells: int, cell_cap: Optional[int] = None,
          kmeans_iters: int = 10, seed: int = 0) -> IVFIndex:
    corpus = jnp.asarray(corpus, jnp.float32)
    n, d = corpus.shape
    cent, assign = kmeans(corpus, n_cells, kmeans_iters, seed)
    assign = np.asarray(assign)
    cap = cell_cap or int(np.ceil(2.5 * n / n_cells))
    # vectorized list fill (the Python row loop took minutes at 1M rows):
    # stable-sort rows by cell, so each row's slot is its rank within its
    # cell — identical layout to filling in ascending row order
    order = np.argsort(assign, kind="stable")
    sorted_cells = assign[order]
    starts = np.searchsorted(sorted_cells, np.arange(n_cells), side="left")
    pos = np.arange(n) - starts[sorted_cells]
    keep = pos < cap
    lists = np.full((n_cells, cap), -1, np.int32)
    lists[sorted_cells[keep], pos[keep]] = order[keep].astype(np.int32)
    spill = int(n - keep.sum())
    mask = lists >= 0
    safe = np.where(mask, lists, 0)
    vecs = np.asarray(corpus)[safe]
    return IVFIndex(centroids=cent,
                    lists=jnp.asarray(lists),
                    list_vecs=jnp.asarray(vecs),
                    list_mask=jnp.asarray(mask),
                    spill=spill)


def search(index: IVFIndex, queries: jax.Array, k: int, nprobe: int = 8
           ) -> tuple[jax.Array, jax.Array]:
    """Probe the nprobe nearest cells per query. Returns (scores [Q, k],
    corpus row ids [Q, k]); scores = -squared-euclidean (higher = closer)."""
    q = jnp.asarray(queries, jnp.float32)
    cent = index.centroids
    d2c = (jnp.sum(q * q, 1)[:, None] - 2 * q @ cent.T
           + jnp.sum(cent * cent, 1)[None, :])
    _, cells = jax.lax.top_k(-d2c, nprobe)          # [Q, P]
    vecs = index.list_vecs[cells]                   # [Q, P, cap, d]
    ids = index.lists[cells]                        # [Q, P, cap]
    mask = index.list_mask[cells]
    s = (2.0 * jnp.einsum("qd,qpcd->qpc", q, vecs)
         - jnp.sum(vecs * vecs, -1)
         - jnp.sum(q * q, -1)[:, None, None])
    s = jnp.where(mask, s, -jnp.inf)
    qn, p, cap = s.shape
    v, flat = jax.lax.top_k(s.reshape(qn, p * cap), k)
    return v, jnp.take_along_axis(ids.reshape(qn, p * cap), flat, axis=1)


def recall_vs_exact(index: IVFIndex, corpus: jax.Array, queries: jax.Array,
                    k: int, nprobe: int) -> float:
    from ..core.metrics import knn_indices, set_overlap

    exact = knn_indices(jnp.asarray(queries), jnp.asarray(corpus), k)
    _, got = search(index, queries, k, nprobe)
    return float(set_overlap(exact, got))
