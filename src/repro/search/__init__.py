from . import distributed, hnsw, ivf, quantize, twostage
from .distributed import distributed_topk, search, sharded_scores
from .twostage import (encode_corpus, recall_vs_exact, rerank_candidates,
                       two_stage_search)
