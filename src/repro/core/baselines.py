"""Baseline DR methods the paper compares against (Table 1), in JAX/numpy.

The offline container has no sklearn/umap-learn, so these are implemented
from the primary sources:

* PCA            — Pearson 1901 / Wold 1987: SVD of the centered data.
* GaussianRP     — Achlioptas 2001 (JL): data-independent random projection.
* MDS + linreg   — classical (Torgerson) MDS on the training Gram matrix +
                   linear-regression out-of-sample extension, exactly the
                   paper's protocol (Chen 2015; Trosset & Priebe 2008).
* Isomap         — Tenenbaum 2000: k-NN graph -> geodesics (min-plus matrix
                   squaring) -> classical MDS; same linreg extension.
* UMAP-lite      — McInnes & Healy 2018: fuzzy k-NN graph (smooth-kNN sigma
                   search), spectral init, attract/repulse SGD with the
                   standard (a, b) curve; out-of-sample via kNN-weighted
                   average of train embeddings (UMAP is transductive — the
                   limitation the paper calls out in §2.2).

All expose fit(train_X) then transform(X). Shapes: [N, n] -> [N, m].
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# PCA
# ---------------------------------------------------------------------------
@dataclass
class PCA:
    out_dim: int
    mean_: Optional[np.ndarray] = None
    components_: Optional[np.ndarray] = None  # [n, m]
    singular_values_: Optional[np.ndarray] = None

    def fit(self, x: np.ndarray) -> "PCA":
        x = np.asarray(x, np.float32)
        self.mean_ = x.mean(0)
        xc = x - self.mean_
        # economical SVD via jnp (fast enough for n <= 4096)
        u, s, vt = np.linalg.svd(xc, full_matrices=False)
        self.components_ = vt[: self.out_dim].T.astype(np.float32)
        self.singular_values_ = s[: self.out_dim]
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        return (np.asarray(x, np.float32) - self.mean_) @ self.components_


# ---------------------------------------------------------------------------
# Gaussian random projection (JL)
# ---------------------------------------------------------------------------
@dataclass
class GaussianRP:
    out_dim: int
    seed: int = 0
    w_: Optional[np.ndarray] = None

    def fit(self, x: np.ndarray) -> "GaussianRP":
        n = x.shape[1]
        rng = np.random.default_rng(self.seed)
        self.w_ = rng.normal(0.0, 1.0 / np.sqrt(self.out_dim),
                             size=(n, self.out_dim)).astype(np.float32)
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(x, np.float32) @ self.w_


# ---------------------------------------------------------------------------
# Classical MDS + linear out-of-sample extension
# ---------------------------------------------------------------------------
def _classical_mds_from_d2(d2: np.ndarray, m: int) -> np.ndarray:
    """Torgerson MDS: double-center the squared-distance matrix, top-m eig."""
    n = d2.shape[0]
    j = np.eye(n, dtype=np.float64) - np.full((n, n), 1.0 / n)
    b = -0.5 * j @ d2.astype(np.float64) @ j
    w, v = np.linalg.eigh(b)
    order = np.argsort(w)[::-1][:m]
    w = np.maximum(w[order], 0.0)
    return (v[:, order] * np.sqrt(w)[None, :]).astype(np.float32)


@dataclass
class MDSLinear:
    out_dim: int
    max_train: int = 2304  # O(N^3); paper capped MDS at 5000 samples
    w_: Optional[np.ndarray] = None  # [n+1, m] linreg with intercept

    def fit(self, x: np.ndarray) -> "MDSLinear":
        x = np.asarray(x, np.float32)
        if x.shape[0] > self.max_train:
            rng = np.random.default_rng(0)
            x = x[rng.choice(x.shape[0], self.max_train, replace=False)]
        sq = np.sum(x * x, 1)
        d2 = np.maximum(sq[:, None] - 2 * x @ x.T + sq[None, :], 0)
        y = _classical_mds_from_d2(d2, self.out_dim)
        xa = np.concatenate([x, np.ones((x.shape[0], 1), np.float32)], 1)
        self.w_, *_ = np.linalg.lstsq(xa, y, rcond=None)
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float32)
        xa = np.concatenate([x, np.ones((x.shape[0], 1), np.float32)], 1)
        return xa @ self.w_


# ---------------------------------------------------------------------------
# Isomap (geodesic MDS) + linreg extension
# ---------------------------------------------------------------------------
@jax.jit
def _minplus_square(d: jax.Array) -> jax.Array:
    """One tropical-semiring squaring: d'_ij = min_k d_ik + d_kj."""
    return jnp.min(d[:, :, None] + d[None, :, :], axis=1)


def _minplus_square_chunked(d: jax.Array, chunk: int = 256) -> jax.Array:
    rows = []
    for i in range(0, d.shape[0], chunk):
        blk = d[i:i + chunk]  # [c, n]
        rows.append(jnp.min(blk[:, :, None] + d[None, :, :], axis=1))
    return jnp.concatenate(rows, 0)


@dataclass
class Isomap:
    out_dim: int
    n_neighbors: int = 10
    max_train: int = 1536
    w_: Optional[np.ndarray] = None

    def fit(self, x: np.ndarray) -> "Isomap":
        x = np.asarray(x, np.float32)
        if x.shape[0] > self.max_train:
            rng = np.random.default_rng(0)
            x = x[rng.choice(x.shape[0], self.max_train, replace=False)]
        n = x.shape[0]
        sq = np.sum(x * x, 1)
        d = np.sqrt(np.maximum(sq[:, None] - 2 * x @ x.T + sq[None, :], 0))
        # symmetric kNN graph
        idx = np.argpartition(d, self.n_neighbors + 1, axis=1)[:, : self.n_neighbors + 1]
        g = np.full((n, n), np.inf, np.float32)
        rows = np.repeat(np.arange(n), idx.shape[1])
        g[rows, idx.ravel()] = d[rows, idx.ravel()]
        g = np.minimum(g, g.T)
        np.fill_diagonal(g, 0.0)
        # geodesics via repeated min-plus squaring: ceil(log2(n)) rounds
        gd = jnp.asarray(g)
        for _ in range(int(np.ceil(np.log2(max(n, 2))))):
            gd = _minplus_square_chunked(gd)
        gd = np.asarray(gd)
        finite_max = np.nanmax(np.where(np.isfinite(gd), gd, np.nan))
        gd = np.where(np.isfinite(gd), gd, finite_max)  # disconnected comps
        y = _classical_mds_from_d2(gd ** 2, self.out_dim)
        xa = np.concatenate([x, np.ones((n, 1), np.float32)], 1)
        self.w_, *_ = np.linalg.lstsq(xa, y, rcond=None)
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        x = np.asarray(x, np.float32)
        xa = np.concatenate([x, np.ones((x.shape[0], 1), np.float32)], 1)
        return xa @ self.w_


# ---------------------------------------------------------------------------
# UMAP-lite
# ---------------------------------------------------------------------------
@dataclass
class UMAPLite:
    out_dim: int
    n_neighbors: int = 15
    n_epochs: int = 100
    lr: float = 1.0
    neg_samples: int = 5
    a: float = 1.576943  # standard UMAP curve params for min_dist=0.1
    b: float = 0.8950609
    seed: int = 0
    max_train: int = 4096
    train_x_: Optional[np.ndarray] = None
    embedding_: Optional[np.ndarray] = None

    def fit(self, x: np.ndarray) -> "UMAPLite":
        x = np.asarray(x, np.float32)
        if x.shape[0] > self.max_train:
            rng = np.random.default_rng(0)
            x = x[rng.choice(x.shape[0], self.max_train, replace=False)]
        self.train_x_ = x
        n, k = x.shape[0], self.n_neighbors
        sq = np.sum(x * x, 1)
        d = np.sqrt(np.maximum(sq[:, None] - 2 * x @ x.T + sq[None, :], 0))
        np.fill_diagonal(d, np.inf)
        knn_idx = np.argpartition(d, k, axis=1)[:, :k]
        knn_d = np.take_along_axis(d, knn_idx, 1)
        # smooth-kNN: per-point sigma s.t. sum exp(-(d - rho)/sigma) = log2(k)
        rho = knn_d.min(1, keepdims=True)
        target = np.log2(k)
        sigma = np.ones((n, 1), np.float32)
        lo, hi = np.zeros((n, 1), np.float32), np.full((n, 1), 1e4, np.float32)
        for _ in range(32):
            val = np.exp(-np.maximum(knn_d - rho, 0) / sigma).sum(1, keepdims=True)
            hi = np.where(val > target, sigma, hi)
            lo = np.where(val <= target, sigma, lo)
            sigma = np.where(val > target, (lo + sigma) / 2, np.minimum((sigma + hi) / 2, sigma * 2))
        w = np.exp(-np.maximum(knn_d - rho, 0) / sigma)  # [n, k]
        # symmetrize: P = W + W^T - W∘W^T  (probabilistic t-conorm)
        p = np.zeros((n, n), np.float32)
        rows = np.repeat(np.arange(n), k)
        p[rows, knn_idx.ravel()] = w.ravel()
        p = p + p.T - p * p.T
        # spectral init from the symmetric normalized Laplacian
        deg = p.sum(1)
        dinv = 1.0 / np.sqrt(np.maximum(deg, 1e-12))
        lap = np.eye(n, dtype=np.float32) - (dinv[:, None] * p * dinv[None, :])
        ew, ev = np.linalg.eigh(lap)
        y = ev[:, 1: self.out_dim + 1].astype(np.float32)
        y = y / max(np.abs(y).max(), 1e-12) * 10.0
        # edge list for SGD
        ei, ej = np.nonzero(p > 0)
        pw = p[ei, ej]
        pw = pw / pw.max()
        rng = np.random.default_rng(self.seed)
        a_, b_ = self.a, self.b
        for epoch in range(self.n_epochs):
            alpha = self.lr * (1.0 - epoch / self.n_epochs)
            keep = rng.random(len(ei)) < pw
            src, dst = ei[keep], ej[keep]
            diff = y[src] - y[dst]
            d2 = np.sum(diff * diff, 1, keepdims=True)
            # attractive gradient of log(1/(1+a d^{2b}))
            ga = (-2.0 * a_ * b_ * d2 ** (b_ - 1)) / (1.0 + a_ * d2 ** b_)
            grad = np.clip(ga * diff, -4, 4)
            np.add.at(y, src, alpha * grad)
            np.add.at(y, dst, -alpha * grad)
            # repulsive: negative samples
            for _ in range(self.neg_samples):
                neg = rng.integers(0, n, size=len(src))
                diff = y[src] - y[neg]
                d2 = np.sum(diff * diff, 1, keepdims=True) + 1e-3
                gr = (2.0 * b_) / (d2 * (1.0 + a_ * d2 ** b_))
                grad = np.clip(gr * diff, -4, 4)
                np.add.at(y, src, alpha * grad)
        self.embedding_ = y
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        """Out-of-sample: kNN-weighted average of train embeddings."""
        x = np.asarray(x, np.float32)
        t = self.train_x_
        sq = np.sum(x * x, 1)[:, None]
        st = np.sum(t * t, 1)[None, :]
        d = np.sqrt(np.maximum(sq - 2 * x @ t.T + st, 0))
        k = min(self.n_neighbors, t.shape[0])
        idx = np.argpartition(d, k - 1, axis=1)[:, :k]
        dk = np.take_along_axis(d, idx, 1)
        w = 1.0 / np.maximum(dk, 1e-6)
        w = w / w.sum(1, keepdims=True)
        return np.einsum("qk,qkm->qm", w, self.embedding_[idx])


def make_baseline(name: str, out_dim: int, **kw):
    table = {"pca": PCA, "rp": GaussianRP, "mds": MDSLinear,
             "isomap": Isomap, "umap": UMAPLite}
    return table[name](out_dim=out_dim, **kw)
