"""RAE — Regularized Auto-Encoder (the paper's core contribution, Section 3.2).

A *linear* autoencoder:  x_hat = W_d @ W_e @ x  with W_e in R^{m x n},
W_d in R^{n x m}, trained on

    L = ||W_d W_e x - x||_2^2 + lambda * (||W_e||_F^2 + ||W_d||_F^2)   (Eq. 7)

The paper realises lambda as AdamW decoupled weight decay (Section 4.1);
``explicit_frobenius=True`` instead adds the Frobenius term to the loss
(mathematically the plain-SGD-equivalent form of Eq. 7). The trained encoder
is the dimensionality-reduction map f(x) = W_e x.

Parameters live in a plain dict so they compose with the framework's schema /
sharding / checkpoint machinery.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import RAEConfig
from ..distributed.partitioning import ParamDef

Params = dict[str, jax.Array]


def schema(cfg: RAEConfig) -> dict[str, ParamDef]:
    n, m = cfg.in_dim, cfg.out_dim
    dt = jnp.dtype(cfg.param_dtype)
    s: dict[str, ParamDef] = {
        # encoder rows are the learned (possibly non-orthogonal) basis; fan_in
        # init ~ N(0, 1/n) keeps ||W_e x|| ~ ||x|| at init (sigma ~ 1).
        "w_e": ParamDef((n, m), ("embed_fsdp", None), dt, init="fan_in"),
        "w_d": ParamDef((m, n), (None, "embed_fsdp"), dt, init="fan_in"),
    }
    if cfg.use_bias:
        s["b_e"] = ParamDef((m,), (None,), dt, init="zeros")
        s["b_d"] = ParamDef((n,), (None,), dt, init="zeros")
    return s


def init(cfg: RAEConfig, key: jax.Array) -> Params:
    from ..distributed.partitioning import init_from_schema

    return init_from_schema(schema(cfg), key)


def encode(params: Params, x: jax.Array) -> jax.Array:
    """f(x) = x @ W_e (+ b_e). x: [..., n] -> [..., m]."""
    y = x @ params["w_e"]
    if "b_e" in params:
        y = y + params["b_e"]
    return y


def decode(params: Params, z: jax.Array) -> jax.Array:
    y = z @ params["w_d"]
    if "b_d" in params:
        y = y + params["b_d"]
    return y


def reconstruct(params: Params, x: jax.Array) -> jax.Array:
    return decode(params, encode(params, x))


def loss_fn(params: Params, x: jax.Array, cfg: RAEConfig
            ) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Mean-over-batch squared reconstruction error (+ optional Frobenius term)."""
    x = x.astype(jnp.float32)
    x_hat = reconstruct(params, x).astype(jnp.float32)
    recon = jnp.mean(jnp.sum(jnp.square(x_hat - x), axis=-1))
    loss = recon
    frob = frobenius_sq(params)
    if cfg.explicit_frobenius:
        loss = loss + cfg.weight_decay * frob
    return loss, {"recon": recon, "frobenius_sq": frob}


def frobenius_sq(params: Params) -> jax.Array:
    """||W_e||_F^2 + ||W_d||_F^2 (biases excluded, matching Eq. 7)."""
    tot = jnp.zeros((), jnp.float32)
    for k in ("w_e", "w_d"):
        if k in params:
            tot = tot + jnp.sum(jnp.square(params[k].astype(jnp.float32)))
    return tot


def encoder_matrix(params: Params) -> jax.Array:
    """W_e as the paper writes it: [m, n] (maps R^n -> R^m)."""
    return params["w_e"].T
