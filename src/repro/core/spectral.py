"""Singular-spectrum analysis of the encoder (paper Section 3.3 / Figure 1)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SpectralStats(NamedTuple):
    sigma_max: jax.Array
    sigma_min: jax.Array
    condition_number: jax.Array  # kappa(W) = sigma_max / sigma_min  (Eq. 16)
    frobenius: jax.Array         # ||W||_F  (>= sigma_max, Eq. 8)
    effective_rank: jax.Array    # exp(entropy of normalized spectrum)
    singular_values: jax.Array


def singular_values(w: jax.Array) -> jax.Array:
    return jnp.linalg.svd(w.astype(jnp.float32), compute_uv=False)


def analyze(w: jax.Array) -> SpectralStats:
    """Spectral stats of a (m x n) or (n x m) transformation matrix."""
    s = singular_values(w)
    smax = s[0]
    smin = s[-1]
    p = s / (jnp.sum(s) + 1e-30)
    eff_rank = jnp.exp(-jnp.sum(p * jnp.log(p + 1e-30)))
    return SpectralStats(
        sigma_max=smax,
        sigma_min=smin,
        condition_number=smax / jnp.maximum(smin, 1e-30),
        frobenius=jnp.sqrt(jnp.sum(jnp.square(s))),
        effective_rank=eff_rank,
        singular_values=s,
    )


def condition_number(w: jax.Array) -> jax.Array:
    s = singular_values(w)
    return s[0] / jnp.maximum(s[-1], 1e-30)
