"""Operational form of the paper's theory (Section 3.3 + Appendix A).

* Rayleigh quotient R(M, x) and its eigenvalue bounds (Eq. 12-13).
* The singular-value norm bound  sigma_min ||x|| <= ||Wx|| <= sigma_max ||x||
  (Eq. 15), checked empirically.
* The k-NN preservation *certificate* from Eq. 16: for an anchor a with
  neighbor i and non-neighbor j, if  d(a,j) / d(a,i) > kappa(W)  then the
  order d(Wa,Wi) <= d(Wa,Wj) is provably preserved. ``certified_fraction``
  reports how many (i, j) relations the bound certifies — the quantitative
  bridge between kappa(W) and P_overall the paper argues qualitatively.
* :class:`DriftTracker` — the *serving-time* form of Eq. 15: a streaming
  monitor that counts incoming vectors whose norm distortion
  ``||Wx|| / ||x||`` escapes the trained ``[sigma_min, sigma_max]`` band,
  and trips a retrain signal when the violation rate says the live
  distribution has drifted off the manifold the reducer was fitted on.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .spectral import singular_values


def rayleigh_quotient(m: jax.Array, x: jax.Array) -> jax.Array:
    """R(M, x) = x^T M x / x^T x for symmetric M (Eq. 12)."""
    x = x.astype(jnp.float32)
    num = jnp.einsum("...i,ij,...j->...", x, m.astype(jnp.float32), x)
    den = jnp.einsum("...i,...i->...", x, x)
    return num / jnp.maximum(den, 1e-30)


def norm_upper_bound_holds(w: jax.Array, xs: jax.Array, rtol: float = 1e-4) -> jax.Array:
    """||Wx|| <= sigma_max ||x|| (Eq. 15 upper half) — holds for ALL x."""
    s = singular_values(w)
    xs = xs.astype(jnp.float32)
    xn = jnp.linalg.norm(xs, axis=-1)
    wn = jnp.linalg.norm(xs @ w.astype(jnp.float32).T, axis=-1)
    return jnp.all(wn <= s[0] * xn * (1 + rtol) + 1e-6)


def norm_bounds_hold(w: jax.Array, xs: jax.Array, rtol: float = 1e-3) -> jax.Array:
    """Verify Eq. 15 on a batch: sigma_min||x|| <= ||Wx|| <= sigma_max||x||.

    Precision note the paper glosses over: for a wide W in R^{m x n} (m < n)
    the eigenvalues of W^T W are {sigma_i^2} ∪ {0 with multiplicity n-m} —
    W has a nullspace, so the *lower* bound with sigma_min = smallest
    NONZERO singular value only holds for x in row(W) = range(W^T). This
    function therefore checks the lower bound on the row-space projection of
    each x (the component W actually acts on); the upper bound is global.
    Empirically embedding corpora concentrate near the learned row space, so
    the effective distortion stays within [sigma_min, sigma_max] — which is
    what Figure 1 of the paper measures.

    w maps R^n -> R^m as f(x) = W x, i.e. w has shape [m, n]; xs is [B, n].
    """
    w32 = w.astype(jnp.float32)
    s = singular_values(w)
    smax, smin = s[0], s[-1]
    xs = xs.astype(jnp.float32)
    # project onto row(W): P = W^+ W = V_r V_r^T (via SVD)
    _, _, vt = jnp.linalg.svd(w32, full_matrices=False)
    xr = (xs @ vt.T) @ vt
    xn = jnp.linalg.norm(xr, axis=-1)
    wn = jnp.linalg.norm(xr @ w32.T, axis=-1)
    upper_all = norm_upper_bound_holds(w, xs, rtol)
    lower = jnp.all(wn >= smin * xn * (1 - rtol) - 1e-6)
    upper = jnp.all(wn <= smax * xn * (1 + rtol) + 1e-6)
    return upper_all & lower & upper


def empirical_distortion(w: jax.Array, xs: jax.Array) -> dict[str, jax.Array]:
    """Observed ||Wx||/||x|| extremes vs the singular-value bounds."""
    s = singular_values(w)
    xs = xs.astype(jnp.float32)
    ratio = (jnp.linalg.norm(xs @ w.astype(jnp.float32).T, axis=-1)
             / jnp.maximum(jnp.linalg.norm(xs, axis=-1), 1e-30))
    return {
        "ratio_max": ratio.max(),
        "ratio_min": ratio.min(),
        "sigma_max": s[0],
        "sigma_min": s[-1],
        "kappa": s[0] / jnp.maximum(s[-1], 1e-30),
    }


def certified_fraction(w: jax.Array, x: jax.Array, k: int, n_far: int = 32,
                       key: jax.Array | None = None) -> jax.Array:
    """Fraction of (neighbor, non-neighbor) relations certified by Eq. 16.

    For each anchor with k-NN distances d_i and sampled non-neighbor
    distances d_j: the relation is certified iff d_j / d_i > kappa(W).
    """
    x = x.astype(jnp.float32)
    n = x.shape[0]
    d2 = (jnp.sum(x * x, 1)[:, None] - 2 * x @ x.T + jnp.sum(x * x, 1)[None, :])
    d2 = jnp.maximum(d2, 0.0) + jnp.eye(n) * 1e30
    d = jnp.sqrt(d2)
    neg_d, idx = jax.lax.top_k(-d, k)  # k nearest
    d_near = -neg_d  # [n, k], ascending? top_k of -d gives nearest first
    kth = d_near[:, -1:]
    s = singular_values(w)
    kappa = s[0] / jnp.maximum(s[-1], 1e-30)
    # non-neighbors: every column with d > kth
    far_mask = d > kth  # [n, n]
    # certified pairs: d_far / d_near_i > kappa for ALL i -> use the largest
    # near distance (kth) as the binding constraint per anchor
    certified = (d / jnp.maximum(kth, 1e-30) > kappa) & far_mask
    return jnp.sum(certified) / jnp.maximum(jnp.sum(far_mask), 1)


@dataclass
class DriftTracker:
    """Streaming Eq. 15 monitor for live index mutation.

    At fit time the reducer's singular values bound every in-distribution
    vector's norm distortion: ``sigma_min ||x|| <= ||Wx|| <= sigma_max
    ||x||`` (lower half exact on row(W); embedding corpora concentrate
    there — see :func:`norm_bounds_hold`). Streamed inserts that land OFF
    that manifold show up as ratios escaping the band — the cheapest
    observable signal that the fitted reducer no longer matches the live
    distribution and stage-1 recall is silently decaying. ``observe`` is
    pure host-side numpy on per-batch norms: it rides the insert path
    without touching any jitted search function.

    ``tol`` widens the band (fit-time ratios sit strictly inside it;
    drift must clear the slack to count); ``threshold`` is the violation
    rate that trips ``should_retrain``; ``min_observed`` stops a handful
    of early outliers from forcing a retrain.
    """

    sigma_min: float
    sigma_max: float
    tol: float = 0.05
    threshold: float = 0.10
    min_observed: int = 64
    observed: int = 0
    violations: int = 0

    @classmethod
    def from_weights(cls, w: jax.Array, tol: float = 0.05,
                     threshold: float = 0.10,
                     min_observed: int = 64) -> "DriftTracker":
        """Band from the reducer's weight matrix (Eq. 15 verbatim)."""
        s = np.asarray(singular_values(w))
        return cls(sigma_min=float(s[-1]), sigma_max=float(s[0]), tol=tol,
                   threshold=threshold, min_observed=min_observed)

    def observe(self, xs: np.ndarray, zs: np.ndarray) -> float:
        """Fold a batch of (original, reduced) vectors into the monitor.

        Returns this batch's violation fraction; the cumulative rate is
        ``violation_rate``. Zero-norm rows are skipped (no ratio)."""
        xn = np.linalg.norm(np.asarray(xs, np.float32), axis=-1)
        zn = np.linalg.norm(np.asarray(zs, np.float32), axis=-1)
        ok = xn > 1e-12
        ratio = zn[ok] / xn[ok]
        lo = self.sigma_min * (1.0 - self.tol)
        hi = self.sigma_max * (1.0 + self.tol)
        bad = int(np.sum((ratio < lo) | (ratio > hi)))
        self.observed += int(ratio.shape[0])
        self.violations += bad
        return bad / max(ratio.shape[0], 1)

    @property
    def violation_rate(self) -> float:
        return self.violations / max(self.observed, 1)

    @property
    def should_retrain(self) -> bool:
        """True once enough stream has been seen AND the violation rate
        clears the threshold — the reducer-retrain trigger."""
        return (self.observed >= self.min_observed
                and self.violation_rate > self.threshold)

    def reset(self) -> None:
        """Forget the stream (called after a retrain swaps the band)."""
        self.observed = 0
        self.violations = 0
