"""Operational form of the paper's theory (Section 3.3 + Appendix A).

* Rayleigh quotient R(M, x) and its eigenvalue bounds (Eq. 12-13).
* The singular-value norm bound  sigma_min ||x|| <= ||Wx|| <= sigma_max ||x||
  (Eq. 15), checked empirically.
* The k-NN preservation *certificate* from Eq. 16: for an anchor a with
  neighbor i and non-neighbor j, if  d(a,j) / d(a,i) > kappa(W)  then the
  order d(Wa,Wi) <= d(Wa,Wj) is provably preserved. ``certified_fraction``
  reports how many (i, j) relations the bound certifies — the quantitative
  bridge between kappa(W) and P_overall the paper argues qualitatively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .spectral import singular_values


def rayleigh_quotient(m: jax.Array, x: jax.Array) -> jax.Array:
    """R(M, x) = x^T M x / x^T x for symmetric M (Eq. 12)."""
    x = x.astype(jnp.float32)
    num = jnp.einsum("...i,ij,...j->...", x, m.astype(jnp.float32), x)
    den = jnp.einsum("...i,...i->...", x, x)
    return num / jnp.maximum(den, 1e-30)


def norm_upper_bound_holds(w: jax.Array, xs: jax.Array, rtol: float = 1e-4) -> jax.Array:
    """||Wx|| <= sigma_max ||x|| (Eq. 15 upper half) — holds for ALL x."""
    s = singular_values(w)
    xs = xs.astype(jnp.float32)
    xn = jnp.linalg.norm(xs, axis=-1)
    wn = jnp.linalg.norm(xs @ w.astype(jnp.float32).T, axis=-1)
    return jnp.all(wn <= s[0] * xn * (1 + rtol) + 1e-6)


def norm_bounds_hold(w: jax.Array, xs: jax.Array, rtol: float = 1e-3) -> jax.Array:
    """Verify Eq. 15 on a batch: sigma_min||x|| <= ||Wx|| <= sigma_max||x||.

    Precision note the paper glosses over: for a wide W in R^{m x n} (m < n)
    the eigenvalues of W^T W are {sigma_i^2} ∪ {0 with multiplicity n-m} —
    W has a nullspace, so the *lower* bound with sigma_min = smallest
    NONZERO singular value only holds for x in row(W) = range(W^T). This
    function therefore checks the lower bound on the row-space projection of
    each x (the component W actually acts on); the upper bound is global.
    Empirically embedding corpora concentrate near the learned row space, so
    the effective distortion stays within [sigma_min, sigma_max] — which is
    what Figure 1 of the paper measures.

    w maps R^n -> R^m as f(x) = W x, i.e. w has shape [m, n]; xs is [B, n].
    """
    w32 = w.astype(jnp.float32)
    s = singular_values(w)
    smax, smin = s[0], s[-1]
    xs = xs.astype(jnp.float32)
    # project onto row(W): P = W^+ W = V_r V_r^T (via SVD)
    _, _, vt = jnp.linalg.svd(w32, full_matrices=False)
    xr = (xs @ vt.T) @ vt
    xn = jnp.linalg.norm(xr, axis=-1)
    wn = jnp.linalg.norm(xr @ w32.T, axis=-1)
    upper_all = norm_upper_bound_holds(w, xs, rtol)
    lower = jnp.all(wn >= smin * xn * (1 - rtol) - 1e-6)
    upper = jnp.all(wn <= smax * xn * (1 + rtol) + 1e-6)
    return upper_all & lower & upper


def empirical_distortion(w: jax.Array, xs: jax.Array) -> dict[str, jax.Array]:
    """Observed ||Wx||/||x|| extremes vs the singular-value bounds."""
    s = singular_values(w)
    xs = xs.astype(jnp.float32)
    ratio = (jnp.linalg.norm(xs @ w.astype(jnp.float32).T, axis=-1)
             / jnp.maximum(jnp.linalg.norm(xs, axis=-1), 1e-30))
    return {
        "ratio_max": ratio.max(),
        "ratio_min": ratio.min(),
        "sigma_max": s[0],
        "sigma_min": s[-1],
        "kappa": s[0] / jnp.maximum(s[-1], 1e-30),
    }


def certified_fraction(w: jax.Array, x: jax.Array, k: int, n_far: int = 32,
                       key: jax.Array | None = None) -> jax.Array:
    """Fraction of (neighbor, non-neighbor) relations certified by Eq. 16.

    For each anchor with k-NN distances d_i and sampled non-neighbor
    distances d_j: the relation is certified iff d_j / d_i > kappa(W).
    """
    x = x.astype(jnp.float32)
    n = x.shape[0]
    d2 = (jnp.sum(x * x, 1)[:, None] - 2 * x @ x.T + jnp.sum(x * x, 1)[None, :])
    d2 = jnp.maximum(d2, 0.0) + jnp.eye(n) * 1e30
    d = jnp.sqrt(d2)
    neg_d, idx = jax.lax.top_k(-d, k)  # k nearest
    d_near = -neg_d  # [n, k], ascending? top_k of -d gives nearest first
    kth = d_near[:, -1:]
    s = singular_values(w)
    kappa = s[0] / jnp.maximum(s[-1], 1e-30)
    # non-neighbors: every column with d > kth
    far_mask = d > kth  # [n, n]
    # certified pairs: d_far / d_near_i > kappa for ALL i -> use the largest
    # near distance (kth) as the binding constraint per anchor
    certified = (d / jnp.maximum(kth, 1e-30) > kappa) & far_mask
    return jnp.sum(certified) / jnp.maximum(jnp.sum(far_mask), 1)
