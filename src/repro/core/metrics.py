"""k-NN preservation metrics (paper Section 3.1, Definitions 1-2).

P_overall (Eq. 4) = (1/kN) sum_a |N_k^X(a) ∩ N_k^X'(a)|  — the fraction of
original k-nearest neighbors retained after dimensionality reduction.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def pairwise_distances(q: jax.Array, db: jax.Array, metric: str = "euclidean",
                       chunk: int = 1024) -> jax.Array:
    """[Q, N] distance matrix (smaller = closer), chunked over queries."""
    q = q.astype(jnp.float32)
    db = db.astype(jnp.float32)
    if metric == "cosine":
        qn = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
        dn = db / jnp.maximum(jnp.linalg.norm(db, axis=-1, keepdims=True), 1e-12)
        return 1.0 - qn @ dn.T
    if metric == "euclidean":
        q2 = jnp.sum(q * q, -1)[:, None]
        d2 = jnp.sum(db * db, -1)[None, :]
        sq = jnp.maximum(q2 - 2.0 * q @ db.T + d2, 0.0)
        return jnp.sqrt(sq)
    raise ValueError(f"unknown metric {metric!r}")


@functools.partial(jax.jit, static_argnames=("k", "metric", "exclude_self"))
def knn_indices(q: jax.Array, db: jax.Array, k: int, metric: str = "euclidean",
                exclude_self: bool = False) -> jax.Array:
    """Indices of the k nearest db rows for each query row. ``exclude_self``
    masks the diagonal (q and db are the same collection)."""
    d = pairwise_distances(q, db, metric)
    if exclude_self:
        n = d.shape[0]
        d = d + jnp.eye(n, d.shape[1], dtype=d.dtype) * jnp.inf
    _, idx = jax.lax.top_k(-d, k)
    return idx


def preservation_accuracy(
    x_orig: jax.Array | np.ndarray,
    x_red: jax.Array | np.ndarray,
    k: int = 5,
    metric: str = "euclidean",
    metric_reduced: Optional[str] = None,
) -> float:
    """P_overall (Eq. 4): mean fraction of original k-NN retained in reduced space.

    The same collection serves as anchors and database, self excluded —
    matching the paper's evaluation protocol.
    """
    x_orig = jnp.asarray(x_orig)
    x_red = jnp.asarray(x_red)
    mr = metric_reduced or metric
    idx_o = knn_indices(x_orig, x_orig, k, metric, exclude_self=True)
    idx_r = knn_indices(x_red, x_red, k, mr, exclude_self=True)
    return float(set_overlap(idx_o, idx_r))


@jax.jit
def set_overlap(idx_a: jax.Array, idx_b: jax.Array) -> jax.Array:
    """Mean |A_i ∩ B_i| / k for two [N, k] index matrices."""
    inter = (idx_a[:, :, None] == idx_b[:, None, :]).any(-1)  # [N, k]
    return jnp.mean(inter.astype(jnp.float32))


def recall_at_k(pred_idx: jax.Array, true_idx: jax.Array) -> float:
    """Retrieval recall: fraction of true top-k found in predicted top-k."""
    return float(set_overlap(true_idx, pred_idx))
