"""The paper's primary contribution: RAE k-NN-preserving dimensionality
reduction — model, theory, metrics, distributed trainer, and the baselines
the paper compares against."""
from . import baselines, metrics, rae, spectral, theory, trainer

__all__ = ["baselines", "metrics", "rae", "spectral", "theory", "trainer"]
