"""Distributed RAE trainer.

Faithful to the paper (AdamW with weight decay = lambda, batch 128, 3000
steps, cosine annealing 1e-3 -> 1e-5) while being mesh-aware: the batch
shards over every mesh axis and gradients all-reduce automatically under
pjit; parameters are replicated (the model is KB-MB scale — the corpus is
the thing that scales, and it stays sharded in the data pipeline).

Fault tolerance: optional checkpoint manager saves (params, opt_state, step)
every ``save_every`` steps; ``train`` resumes from the newest valid
checkpoint. Batches are drawn with a per-step fold_in seed, so a resumed or
re-sharded run sees the identical batch sequence (elastic-safe).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import RAEConfig
from ..optim import AdamW, cosine_annealing
from . import rae


@dataclass
class TrainResult:
    params: Any
    opt_state: Any
    history: list[dict[str, float]] = field(default_factory=list)
    wall_time_s: float = 0.0
    steps_run: int = 0


def make_optimizer(cfg: RAEConfig) -> AdamW:
    wd = 0.0 if cfg.explicit_frobenius else cfg.weight_decay
    return AdamW(
        lr=cosine_annealing(cfg.lr_max, cfg.lr_min, cfg.steps),
        weight_decay=wd,
    )


def make_train_step(cfg: RAEConfig, opt: AdamW):
    def step_fn(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(rae.loss_fn, has_aux=True)(
            params, batch, cfg)
        params, opt_state, om = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **aux, **om}
        return params, opt_state, metrics

    return step_fn


def _batch_sampler(data: np.ndarray, batch_size: int, seed: int):
    """Deterministic, step-indexed batch sampling (resumable at any step)."""
    n = data.shape[0]
    root = np.random.SeedSequence(seed)

    def batch_at(step: int) -> np.ndarray:
        rng = np.random.default_rng(np.random.SeedSequence(
            entropy=root.entropy, spawn_key=(step,)))
        idx = rng.integers(0, n, size=batch_size)
        return data[idx]

    return batch_at


def train(
    cfg: RAEConfig,
    data: np.ndarray,
    mesh: Optional[Mesh] = None,
    log_every: int = 100,
    checkpoint_manager: Optional[Any] = None,
    save_every: int = 500,
    hooks: tuple[Callable[[int, dict], None], ...] = (),
) -> TrainResult:
    """Train RAE on an embedding corpus ([N, n] float array)."""
    assert data.shape[1] == cfg.in_dim, (data.shape, cfg.in_dim)
    opt = make_optimizer(cfg)
    step_fn = make_train_step(cfg, opt)

    key = jax.random.PRNGKey(cfg.seed)
    params = rae.init(cfg, key)
    opt_state = opt.init(params)
    start_step = 0

    if checkpoint_manager is not None:
        restored = checkpoint_manager.restore_latest()
        if restored is not None:
            params, opt_state, start_step = (
                restored["params"], restored["opt_state"], int(restored["step"]))

    if mesh is not None:
        repl = NamedSharding(mesh, P())
        bspec = NamedSharding(mesh, P(tuple(mesh.axis_names)))
        step_fn = jax.jit(step_fn,
                          in_shardings=(repl, repl, bspec),
                          out_shardings=(repl, repl, repl),
                          donate_argnums=(0, 1))
        params = jax.device_put(params, repl)
        opt_state = jax.device_put(opt_state, repl)
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    sample = _batch_sampler(data, cfg.batch_size, cfg.seed)
    history: list[dict[str, float]] = []
    t0 = time.perf_counter()
    step_times: list[float] = []

    for step in range(start_step, cfg.steps):
        ts = time.perf_counter()
        batch = jnp.asarray(sample(step), jnp.float32)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == cfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            history.append(m)
            for h in hooks:
                h(step, m)
        step_times.append(time.perf_counter() - ts)
        # straggler watchdog: EWMA of step time; log 5x-slow steps
        if len(step_times) > 20:
            ewma = float(np.mean(step_times[-20:]))
            if step_times[-1] > 5 * ewma and step > 20:
                history.append({"step": step, "straggler_step_s": step_times[-1]})
        if checkpoint_manager is not None and save_every and (
                step + 1) % save_every == 0:
            checkpoint_manager.save(
                step + 1, {"params": params, "opt_state": opt_state,
                           "step": jnp.asarray(step + 1)})

    jax.block_until_ready(params)
    wall = time.perf_counter() - t0
    if checkpoint_manager is not None:
        checkpoint_manager.save(
            cfg.steps, {"params": params, "opt_state": opt_state,
                        "step": jnp.asarray(cfg.steps)})
    return TrainResult(params=params, opt_state=opt_state, history=history,
                       wall_time_s=wall, steps_run=cfg.steps - start_step)


def fit_transform(cfg: RAEConfig, train_data: np.ndarray, eval_data: np.ndarray,
                  **kw) -> tuple[np.ndarray, TrainResult]:
    """sklearn-style convenience: train, then encode eval_data."""
    res = train(cfg, train_data, **kw)
    z = rae.encode(res.params, jnp.asarray(eval_data, jnp.float32))
    return np.asarray(z), res
