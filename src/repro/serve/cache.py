"""Thread-safe LRU result cache for the serving engine.

Keys are built by the engine from ``(query bytes, k, index fingerprint,
effective operating point)`` — see
:meth:`repro.serve.engine.SearchEngine._cache_key`. A hot index swap
invalidates implicitly (new fingerprint), and so does a knob change
(``set_operating_point`` / a new ``target_recall`` mapping): the resolved
``SearchParams`` and escalation policy are part of the key, so an answer
computed under one operating point can never be replayed under another.
Old entries stay in the map until evicted but can never match a lookup
made under the new key. Hit/miss counters feed ``engine.stats()``.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Hashable, Optional


class LRUCache:
    """Bounded mapping with least-recently-used eviction.

    ``maxsize=0`` disables caching entirely (every ``get`` is a miss,
    ``put`` is a no-op) — the serving engine exposes that as
    ``cache_size=0``.
    """

    def __init__(self, maxsize: int = 1024):
        if maxsize < 0:
            raise ValueError(f"maxsize must be >= 0, got {maxsize}")
        self.maxsize = maxsize
        self._data: "OrderedDict[Hashable, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    def get(self, key: Hashable) -> Optional[Any]:
        with self._lock:
            try:
                value = self._data[key]
            except KeyError:
                self.misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return value

    def put(self, key: Hashable, value: Any) -> None:
        if self.maxsize == 0:
            return
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self.maxsize:
                self._data.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()

    def stats(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {"size": len(self._data), "maxsize": self.maxsize,
                    "hits": self.hits, "misses": self.misses,
                    "hit_rate": self.hits / total if total else 0.0}
