"""Micro-batched serving engine over any ``repro.api`` VectorIndex.

The fused scan kernels (``l2_topk``, ``pq_adc``) are built for MXU-friendly
query batches; a user request is one query. ``SearchEngine`` closes the
gap: concurrent single-query requests land on an asyncio queue, a
scheduler coalesces up to ``max_batch`` of them (waiting at most
``max_wait_ms`` after the first), pads the stack to a power-of-two bucket
so the jit cache holds a handful of shapes, runs ONE ``index.search``, and
scatters the per-row results back to their callers. Every built-in index
answers a coalesced row independently of its batch-mates; for the scan
tiers that answer is bitwise the lone-query answer (parity-tested in
tests/test_serve.py), while the HNSW tier's lone-query heapq engine and
batched engine agree up to beam-boundary ties and score rounding (see
``api.HNSWIndex``).

On top of the scheduler:

* an :class:`~repro.serve.cache.LRUCache` keyed on ``(query bytes, k,
  index fingerprint)`` — repeat queries skip the index entirely, and a
  hot ``set_index`` swap can never serve stale answers because the
  fingerprint (content hash, see ``VectorIndex.fingerprint``) changes;
* ``warmup()`` — pre-compiles the hot path at every padded bucket size so
  the first real request pays search cost, not XLA compile cost;
* ``stats()`` — QPS (lifetime + windowed), p50/p99 latency, batch-size
  histogram, cache hit rate, ``distance_evals`` passthrough, mutation /
  swap counters (plus the mutable index's own epoch & tombstone stats);
* ``mutate(fn)`` / ``hot_swap(builder)`` — live mutation: ``fn(index)``
  (an ``add``/``delete`` on a ``MutableIndex``) runs on the search
  executor so it can never interleave with an in-flight batch, and
  ``hot_swap`` double-buffers a full replacement (build + warm off-path,
  promote atomically via ``set_index``) — zero queries dropped, zero
  answered stale (fingerprint-keyed cache).

Threading model: the asyncio loop runs on a dedicated daemon thread;
``search_one`` is safe to call from any thread (HTTP handler threads,
closed-loop bench clients) and blocks until its future resolves. The
actual ``index.search`` runs on a single-worker executor so batches
pipeline — batch N+1 coalesces while batch N is on the accelerator — and
the index never sees concurrent calls.
"""
from __future__ import annotations

import asyncio
import contextlib
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Sequence

import numpy as np

from ..api.index import SearchResult, VectorIndex
from .cache import LRUCache
from .metrics import EngineMetrics

_STOP = object()


@dataclass
class _Request:
    q: np.ndarray                 # [d] f32
    k: int
    future: "asyncio.Future[SearchResult]"
    t_enq: float = field(default_factory=time.perf_counter)


def _buckets(max_batch: int) -> list[int]:
    """Padded batch sizes the engine compiles: powers of two up to (and
    always including) ``max_batch``."""
    out, b = [], 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return out


class SearchEngine:
    """Wrap a built ``VectorIndex`` for concurrent single-query serving.

    >>> engine = SearchEngine(index, max_batch=32, max_wait_ms=2.0)
    >>> engine.start().warmup()
    >>> res = engine.search_one(query, k=10)     # from any thread
    >>> engine.stats()["batch_size_mean"]
    >>> engine.stop()

    Also usable as a context manager (``with SearchEngine(index) as e:``).
    """

    def __init__(self, index: VectorIndex, max_batch: int = 32,
                 max_wait_ms: float = 2.0, cache_size: int = 1024):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        index._require_built()
        self.index = index
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.buckets = _buckets(max_batch)
        self.cache = LRUCache(cache_size)
        self.metrics = EngineMetrics()
        self._fingerprint = index.fingerprint()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._queue: Optional[asyncio.Queue] = None
        self._batcher_task: Optional[asyncio.Task] = None
        self._pending: set[asyncio.Task] = set()
        self._inflight: Optional[asyncio.Task] = None
        self._accepting = False
        self._executor = ThreadPoolExecutor(max_workers=1,
                                            thread_name_prefix="engine-search")
        self._start_lock = threading.Lock()
        self._mutations = 0       # mutate() calls applied
        self._swaps = 0           # set_index()/hot_swap() promotions

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    @property
    def loop(self) -> Optional[asyncio.AbstractEventLoop]:
        """The engine's event loop (None before start). Async clients can
        drive :meth:`asearch` on it directly via
        ``asyncio.run_coroutine_threadsafe`` — cheaper per request than one
        OS thread per in-flight call."""
        return self._loop

    def start(self) -> "SearchEngine":
        with self._start_lock:
            if self.running:
                return self
            ready = threading.Event()

            def _main():
                loop = asyncio.new_event_loop()
                asyncio.set_event_loop(loop)
                self._loop = loop
                self._queue = asyncio.Queue()
                self._accepting = True
                self._batcher_task = loop.create_task(self._batcher())
                loop.call_soon(ready.set)
                try:
                    loop.run_forever()
                finally:
                    loop.close()

            self._thread = threading.Thread(target=_main, daemon=True,
                                            name="search-engine")
            self._thread.start()
            ready.wait()
        return self

    def stop(self) -> None:
        with self._start_lock:
            if not self.running:
                return
            asyncio.run_coroutine_threadsafe(self._shutdown(),
                                             self._loop).result()
            self._loop.call_soon_threadsafe(self._loop.stop)
            self._thread.join(timeout=10)
            self._thread = None
            self._loop = None

    async def _shutdown(self):
        # refuse new submissions FIRST (same thread as asearch, which has
        # no await between its accepting-check and its enqueue, so no
        # request can slip in after the drain below and hang its caller)
        self._accepting = False
        await self._queue.put(_STOP)
        await self._batcher_task
        if self._pending:
            await asyncio.gather(*self._pending, return_exceptions=True)
        # requests that raced the sentinel would otherwise hang their
        # callers forever: fail them loudly instead
        while not self._queue.empty():
            item = self._queue.get_nowait()
            if item is not _STOP and not item.future.done():
                item.future.set_exception(
                    RuntimeError("engine stopped before request was served"))

    def __enter__(self) -> "SearchEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # serving paths
    # ------------------------------------------------------------------
    def _cache_key(self, q: np.ndarray, k: int) -> tuple:
        return (self._fingerprint, k, q.shape, q.tobytes())

    async def asearch(self, query: np.ndarray, k: int = 10) -> SearchResult:
        """Single-query path: cache lookup, then the micro-batch queue."""
        q = np.ascontiguousarray(query, np.float32)
        if q.ndim == 2 and q.shape[0] == 1:
            q = q[0]
        if q.ndim != 1:
            raise ValueError("asearch/search_one take ONE query vector "
                             f"([d] or [1, d]); got shape {q.shape}. "
                             "Use engine.search for explicit batches.")
        if q.shape[0] != self.index.dim:
            # reject BEFORE the queue: a wrong-dim request inside a
            # coalesced batch would fail every co-batched request
            raise ValueError(f"query has dim {q.shape[0]} but the index "
                             f"takes {self.index.dim}-d queries")
        if self.cache.maxsize:  # disabled cache: skip the key hash entirely
            t0 = time.perf_counter()
            hit = self.cache.get(self._cache_key(q, k))
            if hit is not None:
                dt = time.perf_counter() - t0
                self.metrics.record_cached(dt)
                # arrays are shared (frozen); latency + stats are this
                # serve's own so a caller mutating them can't leak back
                return replace(hit, latency_s=dt, stats=dict(hit.stats))
        if not self._accepting:
            raise RuntimeError("engine is stopping; request rejected")
        fut = asyncio.get_running_loop().create_future()
        await self._queue.put(_Request(q=q, k=int(k), future=fut))
        return await fut

    def search_one(self, query: np.ndarray, k: int = 10) -> SearchResult:
        """Thread-safe blocking wrapper around :meth:`asearch` (auto-starts
        the engine). This is the path HTTP handlers and threaded clients
        use — N threads calling it concurrently coalesce into shared
        batches."""
        if not self.running:  # fast path: skip the start lock per request
            self.start()
        loop = self._loop  # local capture: a concurrent stop() nulls it
        if loop is None:
            raise RuntimeError("engine stopped while request was submitted")
        return asyncio.run_coroutine_threadsafe(
            self.asearch(query, k), loop).result()

    def search(self, queries: np.ndarray, k: int = 10) -> SearchResult:
        """Explicit-batch passthrough: the caller already batched, so skip
        the queue (and the single-query cache) but keep the metrics."""
        queries = np.asarray(queries, np.float32)
        res = self.index.search(queries, k)
        n = queries.shape[0]
        self.metrics.record_batch(size=n, bucket=n,
                                  latencies_s=[res.latency_s] * n,
                                  distance_evals=res.distance_evals)
        return res

    def set_index(self, index: VectorIndex) -> None:
        """Hot-swap the served index. Runs on the search executor so it
        can never interleave with an in-flight batch; the new fingerprint
        invalidates every cached result implicitly."""
        index._require_built()

        def _swap():
            self.index = index
            self._fingerprint = index.fingerprint()

        if self.running:
            self._executor.submit(_swap).result()
        else:
            _swap()
        self._swaps += 1

    def mutate(self, fn):
        """Apply a mutation to the served index, atomically with respect
        to in-flight batches: ``fn(index)`` runs on the single-worker
        search executor (the only thread that ever calls
        ``index.search``), so no query can observe a half-applied insert
        or delete, and the refreshed fingerprint retires every cached
        pre-mutation answer. Returns whatever ``fn`` returns —
        ``engine.mutate(lambda ix: ix.add(rows))`` hands back the new
        ids. Queries keep coalescing while the mutation waits its turn;
        none are dropped."""

        def _apply():
            out = fn(self.index)
            self._fingerprint = self.index.fingerprint()
            return out

        if self.running:
            result = self._executor.submit(_apply).result()
        else:
            result = _apply()
        self._mutations += 1
        return result

    def hot_swap(self, builder, ks: Sequence[int] = (10,),
                 seed: int = 0) -> VectorIndex:
        """Zero-downtime replacement via double buffering: ``builder()``
        constructs the NEW index entirely off the serving path — queries
        keep flowing against the old one for however long the build takes
        — then the fresh index is warmed at every padded bucket size
        (compile cost paid off-path too) and promoted through
        :meth:`set_index`, which runs on the search executor and is
        therefore atomic with in-flight batches: every query is answered,
        each one entirely by the old or entirely by the new index, and
        the fingerprint change keeps the cache honest. Returns the
        promoted index."""
        new_index = builder()
        new_index._require_built()
        rng = np.random.default_rng(seed)
        for k in ks:
            for b in self.buckets:
                q = rng.standard_normal((b, new_index.dim)).astype(np.float32)
                new_index.search(q, k)
        self.set_index(new_index)
        return new_index

    def warmup(self, dim: Optional[int] = None,
               ks: Sequence[int] = (10,), seed: int = 0) -> "SearchEngine":
        """Compile the hot path at every padded bucket size (x every k the
        deployment serves) so no real request pays XLA compile latency.
        Warm-up queries are seeded random normals, NOT zeros: scan tiers
        only need the shape, but the batched HNSW frontier loop on an
        all-zeros batch collapses after one hop (every query ties at the
        entry point) and would leave the traversal's per-bucket jit cache
        — the ``graph_beam`` hop kernel compiles per pow2 live-row count —
        cold for real traffic. Warm-up searches bypass the metrics —
        stats reflect traffic."""
        dim = dim if dim is not None else self.index.dim
        rng = np.random.default_rng(seed)
        for k in ks:
            for b in self.buckets:
                q = rng.standard_normal((b, dim)).astype(np.float32)
                self.index.search(q, k)
        return self

    # ------------------------------------------------------------------
    # scheduler
    # ------------------------------------------------------------------
    async def _batcher(self):
        loop = asyncio.get_running_loop()
        while True:
            head = await self._queue.get()
            if head is _STOP:
                return
            batch = [head]
            deadline = loop.time() + self.max_wait_ms / 1e3
            stop = False
            while len(batch) < self.max_batch:
                timeout = deadline - loop.time()
                if timeout <= 0:
                    if self._inflight is None or self._inflight.done():
                        break
                    # past the deadline but the search executor is still
                    # chewing the previous batch: flushing now would only
                    # queue behind it, so keep coalescing (batches FILL
                    # under load, at zero added latency) — sleeping until
                    # a request arrives OR the executor frees, no polling
                    get_task = loop.create_task(self._queue.get())
                    await asyncio.wait({get_task, self._inflight},
                                       return_when=asyncio.FIRST_COMPLETED)
                    if not get_task.done():
                        get_task.cancel()
                        with contextlib.suppress(asyncio.CancelledError):
                            await get_task
                        continue  # executor freed: loop breaks above
                    item = get_task.result()
                else:
                    try:
                        item = await asyncio.wait_for(self._queue.get(),
                                                      timeout)
                    except asyncio.TimeoutError:
                        continue  # re-check deadline + executor state
                if item is _STOP:
                    stop = True
                    break
                batch.append(item)
            # same-k requests share one padded search; mixed k (rare in
            # practice) split into per-k flushes, still inside this cycle
            groups: dict[int, list[_Request]] = {}
            for req in batch:
                groups.setdefault(req.k, []).append(req)
            for k, reqs in groups.items():
                task = loop.create_task(self._flush(k, reqs))
                self._pending.add(task)
                task.add_done_callback(self._pending.discard)
                self._inflight = task  # last task: executor is FIFO
            if stop:
                return

    async def _flush(self, k: int, reqs: list[_Request]):
        loop = asyncio.get_running_loop()
        try:
            results = await loop.run_in_executor(
                self._executor, self._run_batch, k, reqs)
        except Exception as e:  # surface to every caller, keep serving
            for req in reqs:
                if not req.future.done():
                    req.future.set_exception(e)
            return
        for req, res in zip(reqs, results):
            if not req.future.done():
                req.future.set_result(res)

    def _run_batch(self, k: int, reqs: list[_Request]) -> list[SearchResult]:
        """Executor-side: pad to the bucket, search once, slice per caller."""
        size = len(reqs)
        bucket = next(b for b in self.buckets if b >= size)
        qs = np.stack([r.q for r in reqs])
        if bucket > size:
            # pad with a REAL query row (not zeros): identical numerics to
            # the unpadded rows, and never a degenerate all-zero distance
            qs = np.concatenate(
                [qs, np.repeat(qs[:1], bucket - size, axis=0)])
        res = self.index.search(qs, k)
        done = time.perf_counter()
        out = []
        for i, req in enumerate(reqs):
            single = SearchResult(scores=res.scores[i:i + 1].copy(),
                                  indices=res.indices[i:i + 1].copy(),
                                  latency_s=res.latency_s,
                                  stats=dict(res.stats))
            if self.cache.maxsize:
                # the cached object IS the returned object: freeze its
                # arrays so a caller mutating its result can't poison
                # every future hit on this key
                single.scores.setflags(write=False)
                single.indices.setflags(write=False)
                self.cache.put(self._cache_key(req.q, k), single)
            out.append(single)
        self.metrics.record_batch(
            size=size, bucket=bucket,
            latencies_s=[done - r.t_enq for r in reqs],
            distance_evals=res.distance_evals)
        return out

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        out = self.metrics.snapshot()
        out["cache"] = self.cache.stats()
        out["index"] = {"kind": self.index.kind,
                        "ntotal": self.index.ntotal,
                        "fingerprint": self._fingerprint,
                        "bytes_per_vector": self.index.bytes_per_vector,
                        "shards": getattr(self.index, "shard_count", None)}
        out["scheduler"] = {"max_batch": self.max_batch,
                            "max_wait_ms": self.max_wait_ms,
                            "buckets": self.buckets,
                            "running": self.running}
        out["mutation"] = {"mutations": self._mutations,
                           "swaps": self._swaps}
        ms = getattr(self.index, "mutation_stats", None)
        if ms is not None:
            out["mutation"]["index"] = ms()
        return out
