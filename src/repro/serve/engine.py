"""Micro-batched serving engine over any ``repro.api`` VectorIndex.

The fused scan kernels (``l2_topk``, ``pq_adc``) are built for MXU-friendly
query batches; a user request is one query. ``SearchEngine`` closes the
gap: concurrent single-query requests land on an asyncio queue, a
scheduler coalesces up to ``max_batch`` of them (waiting at most
``max_wait_ms`` after the first), pads the stack to a power-of-two bucket
so the jit cache holds a handful of shapes, runs ONE ``index.search``, and
scatters the per-row results back to their callers. Every built-in index
answers a coalesced row independently of its batch-mates; for the scan
tiers that answer is bitwise the lone-query answer (parity-tested in
tests/test_serve.py), while the HNSW tier's lone-query heapq engine and
batched engine agree up to beam-boundary ties and score rounding (see
``api.HNSWIndex``).

On top of the scheduler:

* an :class:`~repro.serve.cache.LRUCache` keyed on ``(query bytes, k,
  index fingerprint, operating point)`` — repeat queries skip the index
  entirely; a hot ``set_index`` swap can never serve stale answers
  because the fingerprint (content hash, see ``VectorIndex.fingerprint``)
  changes, and a knob change (``set_operating_point``) can never replay
  answers computed under different knobs because the resolved
  ``SearchParams`` / escalation policy are part of the key;
* **self-tuning** (``repro.tune``): construct with ``target_recall=`` +
  an offline-fitted ``OperatingCurve`` and the engine serves the
  cheapest knob setting that meets the SLO; add an
  ``EscalationPolicy`` and every batch runs a cheap first pass, answers
  the rows whose top-k margin is stable, and re-runs only the unstable
  rows one :data:`~repro.api.index.KNOB_LADDER` rung up — pass-1 +
  pass-2 ``distance_evals`` compose in stats, and both passes stay on
  warmed (bucket, k, rung) shapes so serving is compile-budget-zero;
* ``warmup()`` — pre-compiles the hot path at every padded bucket size so
  the first real request pays search cost, not XLA compile cost;
* ``stats()`` — QPS (lifetime + windowed), p50/p99 latency, batch-size
  histogram, cache hit rate, ``distance_evals`` passthrough, mutation /
  swap counters (plus the mutable index's own epoch & tombstone stats);
* ``mutate(fn)`` / ``hot_swap(builder)`` — live mutation: ``fn(index)``
  (an ``add``/``delete`` on a ``MutableIndex``) runs on the search
  executor so it can never interleave with an in-flight batch, and
  ``hot_swap`` double-buffers a full replacement (build + warm off-path,
  promote atomically via ``set_index``) — zero queries dropped, zero
  answered stale (fingerprint-keyed cache).

Threading model: the asyncio loop runs on a dedicated daemon thread;
``search_one`` is safe to call from any thread (HTTP handler threads,
closed-loop bench clients) and blocks until its future resolves. The
actual ``index.search`` runs on a single-worker executor so batches
pipeline — batch N+1 coalesces while batch N is on the accelerator — and
the index never sees concurrent calls.
"""
from __future__ import annotations

import asyncio
import contextlib
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Sequence

import numpy as np

from ..api.index import SearchParams, SearchResult, VectorIndex
from ..tune.autotune import OperatingCurve
from ..tune.escalate import EscalationPolicy, unstable_rows
from .cache import LRUCache
from .metrics import EngineMetrics

_STOP = object()
_UNSET = object()  # set_operating_point: "leave this field alone"


@dataclass
class _Request:
    q: np.ndarray                 # [d] f32
    k: int
    future: "asyncio.Future[SearchResult]"
    t_enq: float = field(default_factory=time.perf_counter)


def _buckets(max_batch: int) -> list[int]:
    """Padded batch sizes the engine compiles: powers of two up to (and
    always including) ``max_batch``."""
    out, b = [], 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return out


class SearchEngine:
    """Wrap a built ``VectorIndex`` for concurrent single-query serving.

    >>> engine = SearchEngine(index, max_batch=32, max_wait_ms=2.0)
    >>> engine.start().warmup()
    >>> res = engine.search_one(query, k=10)     # from any thread
    >>> engine.stats()["batch_size_mean"]
    >>> engine.stop()

    Also usable as a context manager (``with SearchEngine(index) as e:``).
    """

    def __init__(self, index: VectorIndex, max_batch: int = 32,
                 max_wait_ms: float = 2.0, cache_size: int = 1024,
                 params: Optional[SearchParams] = None,
                 target_recall: Optional[float] = None,
                 curve: Optional[OperatingCurve] = None,
                 escalation: Optional[EscalationPolicy] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        index._require_built()
        self.index = index
        self.max_batch = max_batch
        self.max_wait_ms = max_wait_ms
        self.buckets = _buckets(max_batch)
        self.cache = LRUCache(cache_size)
        self.metrics = EngineMetrics()
        self._fingerprint = index.fingerprint()
        self._explicit_params = params
        self._target_recall = target_recall
        self._curve = curve
        self._escalation = escalation
        self._resolve_operating_point()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._queue: Optional[asyncio.Queue] = None
        self._batcher_task: Optional[asyncio.Task] = None
        self._pending: set[asyncio.Task] = set()
        self._inflight: Optional[asyncio.Task] = None
        self._accepting = False
        self._executor = ThreadPoolExecutor(max_workers=1,
                                            thread_name_prefix="engine-search")
        self._start_lock = threading.Lock()
        self._mutations = 0       # mutate() calls applied
        self._swaps = 0           # set_index()/hot_swap() promotions

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    @property
    def loop(self) -> Optional[asyncio.AbstractEventLoop]:
        """The engine's event loop (None before start). Async clients can
        drive :meth:`asearch` on it directly via
        ``asyncio.run_coroutine_threadsafe`` — cheaper per request than one
        OS thread per in-flight call."""
        return self._loop

    def start(self) -> "SearchEngine":
        with self._start_lock:
            if self.running:
                return self
            ready = threading.Event()

            def _main():
                loop = asyncio.new_event_loop()
                asyncio.set_event_loop(loop)
                self._loop = loop
                self._queue = asyncio.Queue()
                self._accepting = True
                self._batcher_task = loop.create_task(self._batcher())
                loop.call_soon(ready.set)
                try:
                    loop.run_forever()
                finally:
                    loop.close()

            self._thread = threading.Thread(target=_main, daemon=True,
                                            name="search-engine")
            self._thread.start()
            ready.wait()
        return self

    def stop(self) -> None:
        with self._start_lock:
            if not self.running:
                return
            asyncio.run_coroutine_threadsafe(self._shutdown(),
                                             self._loop).result()
            self._loop.call_soon_threadsafe(self._loop.stop)
            self._thread.join(timeout=10)
            self._thread = None
            self._loop = None

    async def _shutdown(self):
        # refuse new submissions FIRST (same thread as asearch, which has
        # no await between its accepting-check and its enqueue, so no
        # request can slip in after the drain below and hang its caller)
        self._accepting = False
        await self._queue.put(_STOP)
        await self._batcher_task
        if self._pending:
            await asyncio.gather(*self._pending, return_exceptions=True)
        # requests that raced the sentinel would otherwise hang their
        # callers forever: fail them loudly instead
        while not self._queue.empty():
            item = self._queue.get_nowait()
            if item is not _STOP and not item.future.done():
                item.future.set_exception(
                    RuntimeError("engine stopped before request was served"))

    def __enter__(self) -> "SearchEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # operating point (repro.tune)
    # ------------------------------------------------------------------
    def _resolve_operating_point(self) -> None:
        """Collapse (target_recall, curve, explicit params, escalation)
        into the concrete per-call knobs every search uses:
        ``self._params`` (pass 1; None = index defaults),
        ``self._esc_params`` (pass 2; None = escalation off) and
        ``self._op_token`` (the cache-key component). Called under
        ``__init__`` and, via the search executor, whenever the index or
        the point changes — never concurrently with a batch."""
        base = SearchParams()
        if self._target_recall is not None:
            if self._curve is None:
                raise ValueError(
                    "target_recall needs an OperatingCurve: run "
                    "repro.tune.sweep offline and pass curve=")
            if self._curve.fingerprint != self._fingerprint:
                raise ValueError(
                    f"operating curve was tuned for fingerprint "
                    f"{self._curve.fingerprint}, live index is "
                    f"{self._fingerprint} — re-run repro.tune.sweep on "
                    f"this build (or set_operating_point(curve=...))")
            # escalation closes small recall gaps, so its recall_slack
            # DISCOUNTS the curve selection: start up to one rung
            # cheaper, let pass 2 recover (the autotune bench gate
            # verifies the SLO on held-out queries)
            slack = (-self._escalation.recall_slack
                     if self._escalation is not None else 0.0)
            base = self._curve.select(self._target_recall, slack=slack).params
        if self._explicit_params is not None:
            base = base.merged(self._explicit_params)
        self._params = base if base.key() != (None, None, None) else None
        if self._escalation is None:
            self._esc_params = None
        else:
            ep = self._escalation.params
            if ep is None and self._params is not None:
                ep = self._params.escalated()
            if ep is None:
                raise ValueError(
                    "escalation needs a pass-2 operating point: give "
                    "EscalationPolicy(params=...), or set params/"
                    "target_recall so the engine can take the next "
                    "ladder rung")
            self._esc_params = ep
        self._op_token = (
            self._target_recall,
            None if self._params is None else self._params.key(),
            None if self._escalation is None else
            (self._escalation.delta, float(self._escalation.threshold),
             self._esc_params.key()))

    def set_operating_point(self, *, params=_UNSET, target_recall=_UNSET,
                            curve=_UNSET, escalation=_UNSET) -> None:
        """Change any part of the operating point on a live engine.
        Omitted keywords keep their current value; pass ``None`` to clear
        one. Runs on the search executor, so the switch is atomic with
        respect to in-flight batches, and the new resolved point enters
        the cache key — a knob change can never replay an answer computed
        under the old knobs (the PR-10 cache bugfix)."""

        def _apply():
            if params is not _UNSET:
                self._explicit_params = params
            if target_recall is not _UNSET:
                self._target_recall = target_recall
            if curve is not _UNSET:
                self._curve = curve
            if escalation is not _UNSET:
                self._escalation = escalation
            self._resolve_operating_point()

        if self.running:
            self._executor.submit(_apply).result()
        else:
            _apply()

    def _warm_points(self, k: int) -> list[tuple[int, Optional[SearchParams]]]:
        """(k_effective, params) pairs a warmup must compile for one
        served ``k``: with escalation on, BOTH passes over-fetch
        ``k + delta`` — pass 1 at the base point, pass 2 one rung up."""
        if self._escalation is None:
            return [(k, self._params)]
        kk = k + self._escalation.delta
        return [(kk, self._params), (kk, self._esc_params)]

    # ------------------------------------------------------------------
    # serving paths
    # ------------------------------------------------------------------
    def _cache_key(self, q: np.ndarray, k: int) -> tuple:
        # fingerprint pins the build, op_token pins the knobs: both can
        # change under a live engine (hot swap / set_operating_point) and
        # either change must retire every prior answer
        return (self._fingerprint, self._op_token, k, q.shape, q.tobytes())

    async def asearch(self, query: np.ndarray, k: int = 10) -> SearchResult:
        """Single-query path: cache lookup, then the micro-batch queue."""
        q = np.ascontiguousarray(query, np.float32)
        if q.ndim == 2 and q.shape[0] == 1:
            q = q[0]
        if q.ndim != 1:
            raise ValueError("asearch/search_one take ONE query vector "
                             f"([d] or [1, d]); got shape {q.shape}. "
                             "Use engine.search for explicit batches.")
        if q.shape[0] != self.index.dim:
            # reject BEFORE the queue: a wrong-dim request inside a
            # coalesced batch would fail every co-batched request
            raise ValueError(f"query has dim {q.shape[0]} but the index "
                             f"takes {self.index.dim}-d queries")
        if self.cache.maxsize:  # disabled cache: skip the key hash entirely
            t0 = time.perf_counter()
            hit = self.cache.get(self._cache_key(q, k))
            if hit is not None:
                dt = time.perf_counter() - t0
                self.metrics.record_cached(dt)
                # arrays are shared (frozen); latency + stats are this
                # serve's own so a caller mutating them can't leak back
                return replace(hit, latency_s=dt, stats=dict(hit.stats))
        if not self._accepting:
            raise RuntimeError("engine is stopping; request rejected")
        fut = asyncio.get_running_loop().create_future()
        await self._queue.put(_Request(q=q, k=int(k), future=fut))
        return await fut

    def search_one(self, query: np.ndarray, k: int = 10) -> SearchResult:
        """Thread-safe blocking wrapper around :meth:`asearch` (auto-starts
        the engine). This is the path HTTP handlers and threaded clients
        use — N threads calling it concurrently coalesce into shared
        batches."""
        if not self.running:  # fast path: skip the start lock per request
            self.start()
        loop = self._loop  # local capture: a concurrent stop() nulls it
        if loop is None:
            raise RuntimeError("engine stopped while request was submitted")
        return asyncio.run_coroutine_threadsafe(
            self.asearch(query, k), loop).result()

    def _escalated_search(self, qs: np.ndarray, k: int
                          ) -> tuple[SearchResult, np.ndarray]:
        """One engine-side search at the resolved operating point,
        returning ([Q, k] result, escalated-row mask).

        Without escalation this is a plain ``index.search`` at the tuned
        params. With it: pass 1 over-fetches ``k + delta`` at the cheap
        point, the normalized top-k tail margin flags unstable rows
        (``repro.tune.escalate``), and ONLY those rows re-run one ladder
        rung up — padded to the engine's smallest covering bucket, so
        pass 2 reuses the same warmed shapes regardless of how many rows
        escalate, and a row escalated solo is bitwise identical to the
        same row escalated inside any batch (the tiers' row-invariance
        contract). Stable rows answer from pass 1 untouched. Stats
        compose: ``distance_evals`` amortizes the pass-2 cost over the
        whole batch; per-row attribution happens in ``_run_batch``."""
        esc = self._escalation
        if esc is None:
            r = self.index.search(qs, k, params=self._params)
            return r, np.zeros(qs.shape[0], bool)
        kk = k + esc.delta
        r1 = self.index.search(qs, kk, params=self._params)
        if r1.scores.shape[1] < kk:
            # corpus smaller than k + delta: a wider search has nothing
            # more to find, and the margin is undefined — serve pass 1,
            # trimmed to the k columns the caller asked for
            return SearchResult(
                scores=np.asarray(r1.scores)[:, :k],
                indices=np.asarray(r1.indices)[:, :k],
                latency_s=r1.latency_s, stats=dict(r1.stats)), \
                np.zeros(qs.shape[0], bool)
        mask = unstable_rows(r1.scores, k, esc.delta, esc.threshold,
                             ntotal=self.index.ntotal)
        scores = np.asarray(r1.scores)[:, :k].copy()
        idx = np.asarray(r1.indices)[:, :k].copy()
        n, n_esc = qs.shape[0], int(mask.sum())
        e1 = r1.stats.get("distance_evals", 0.0)
        e2, latency = 0.0, r1.latency_s
        if n_esc:
            sub = qs[mask]
            bucket = next((b for b in self.buckets if b >= n_esc), n_esc)
            if bucket > n_esc:
                sub = np.concatenate(
                    [sub, np.repeat(sub[:1], bucket - n_esc, axis=0)])
            r2 = self.index.search(sub, kk, params=self._esc_params)
            scores[mask] = np.asarray(r2.scores)[:n_esc, :k]
            idx[mask] = np.asarray(r2.indices)[:n_esc, :k]
            e2 = r2.stats.get("distance_evals", 0.0)
            latency += r2.latency_s
        stats = dict(r1.stats)
        stats.update({
            "distance_evals": e1 + e2 * (n_esc / n),
            "pass1_distance_evals": e1,
            "pass2_distance_evals": e2,
            "escalated_frac": n_esc / n,
        })
        return SearchResult(scores=scores, indices=idx,
                            latency_s=latency, stats=stats), mask

    def search(self, queries: np.ndarray, k: int = 10) -> SearchResult:
        """Explicit-batch passthrough: the caller already batched, so skip
        the queue (and the single-query cache) but keep the metrics. Runs
        at the engine's resolved operating point, escalation included —
        benches measuring the tuned engine go through here."""
        queries = np.asarray(queries, np.float32)
        res, mask = self._escalated_search(queries, k)
        n = queries.shape[0]
        self.metrics.record_batch(size=n, bucket=n,
                                  latencies_s=[res.latency_s] * n,
                                  distance_evals=res.distance_evals,
                                  escalated=int(mask.sum()))
        return res

    def set_index(self, index: VectorIndex) -> None:
        """Hot-swap the served index. Runs on the search executor so it
        can never interleave with an in-flight batch; the new fingerprint
        invalidates every cached result implicitly. Re-resolves the
        operating point against the new build — an engine pinned to a
        ``target_recall`` curve refuses a swap to a build the curve was
        not tuned on (re-sweep first, then ``set_operating_point``)."""
        index._require_built()

        def _swap():
            self.index = index
            self._fingerprint = index.fingerprint()
            self._resolve_operating_point()

        if self.running:
            self._executor.submit(_swap).result()
        else:
            _swap()
        self._swaps += 1

    def mutate(self, fn):
        """Apply a mutation to the served index, atomically with respect
        to in-flight batches: ``fn(index)`` runs on the single-worker
        search executor (the only thread that ever calls
        ``index.search``), so no query can observe a half-applied insert
        or delete, and the refreshed fingerprint retires every cached
        pre-mutation answer. Returns whatever ``fn`` returns —
        ``engine.mutate(lambda ix: ix.add(rows))`` hands back the new
        ids. Queries keep coalescing while the mutation waits its turn;
        none are dropped."""

        def _apply():
            out = fn(self.index)
            self._fingerprint = self.index.fingerprint()
            # re-resolve: a tuned curve is pinned to the pre-mutation
            # fingerprint, so an engine serving a recall SLO fails loudly
            # here rather than serve an SLO its curve no longer certifies
            self._resolve_operating_point()
            return out

        if self.running:
            result = self._executor.submit(_apply).result()
        else:
            result = _apply()
        self._mutations += 1
        return result

    def hot_swap(self, builder, ks: Sequence[int] = (10,),
                 seed: int = 0) -> VectorIndex:
        """Zero-downtime replacement via double buffering: ``builder()``
        constructs the NEW index entirely off the serving path — queries
        keep flowing against the old one for however long the build takes
        — then the fresh index is warmed at every padded bucket size
        (compile cost paid off-path too) and promoted through
        :meth:`set_index`, which runs on the search executor and is
        therefore atomic with in-flight batches: every query is answered,
        each one entirely by the old or entirely by the new index, and
        the fingerprint change keeps the cache honest. Returns the
        promoted index."""
        new_index = builder()
        new_index._require_built()
        rng = np.random.default_rng(seed)
        for k in ks:
            for kw, p in self._warm_points(k):
                for b in self.buckets:
                    q = rng.standard_normal(
                        (b, new_index.dim)).astype(np.float32)
                    new_index.search(q, kw, params=p)
        self.set_index(new_index)
        return new_index

    def warmup(self, dim: Optional[int] = None,
               ks: Sequence[int] = (10,), seed: int = 0) -> "SearchEngine":
        """Compile the hot path at every padded bucket size (x every k the
        deployment serves) so no real request pays XLA compile latency.
        Warm-up queries are seeded random normals, NOT zeros: scan tiers
        only need the shape, but the batched HNSW frontier loop on an
        all-zeros batch collapses after one hop (every query ties at the
        entry point) and would leave the traversal's per-bucket jit cache
        — the ``graph_beam`` hop kernel compiles per pow2 live-row count —
        cold for real traffic. Warm-up searches bypass the metrics —
        stats reflect traffic."""
        dim = dim if dim is not None else self.index.dim
        rng = np.random.default_rng(seed)
        for k in ks:
            # with escalation on, warm BOTH passes' shapes: k + delta at
            # the base rung and at the escalated rung, every bucket —
            # serving then never compiles, however many rows escalate
            for kw, p in self._warm_points(k):
                for b in self.buckets:
                    q = rng.standard_normal((b, dim)).astype(np.float32)
                    self.index.search(q, kw, params=p)
        return self

    # ------------------------------------------------------------------
    # scheduler
    # ------------------------------------------------------------------
    async def _batcher(self):
        loop = asyncio.get_running_loop()
        while True:
            head = await self._queue.get()
            if head is _STOP:
                return
            batch = [head]
            deadline = loop.time() + self.max_wait_ms / 1e3
            stop = False
            while len(batch) < self.max_batch:
                timeout = deadline - loop.time()
                if timeout <= 0:
                    if self._inflight is None or self._inflight.done():
                        break
                    # past the deadline but the search executor is still
                    # chewing the previous batch: flushing now would only
                    # queue behind it, so keep coalescing (batches FILL
                    # under load, at zero added latency) — sleeping until
                    # a request arrives OR the executor frees, no polling
                    get_task = loop.create_task(self._queue.get())
                    await asyncio.wait({get_task, self._inflight},
                                       return_when=asyncio.FIRST_COMPLETED)
                    if not get_task.done():
                        get_task.cancel()
                        with contextlib.suppress(asyncio.CancelledError):
                            await get_task
                        continue  # executor freed: loop breaks above
                    item = get_task.result()
                else:
                    try:
                        item = await asyncio.wait_for(self._queue.get(),
                                                      timeout)
                    except asyncio.TimeoutError:
                        continue  # re-check deadline + executor state
                if item is _STOP:
                    stop = True
                    break
                batch.append(item)
            # same-k requests share one padded search; mixed k (rare in
            # practice) split into per-k flushes, still inside this cycle
            groups: dict[int, list[_Request]] = {}
            for req in batch:
                groups.setdefault(req.k, []).append(req)
            for k, reqs in groups.items():
                task = loop.create_task(self._flush(k, reqs))
                self._pending.add(task)
                task.add_done_callback(self._pending.discard)
                self._inflight = task  # last task: executor is FIFO
            if stop:
                return

    async def _flush(self, k: int, reqs: list[_Request]):
        loop = asyncio.get_running_loop()
        try:
            results = await loop.run_in_executor(
                self._executor, self._run_batch, k, reqs)
        except Exception as e:  # surface to every caller, keep serving
            for req in reqs:
                if not req.future.done():
                    req.future.set_exception(e)
            return
        for req, res in zip(reqs, results):
            if not req.future.done():
                req.future.set_result(res)

    def _run_batch(self, k: int, reqs: list[_Request]) -> list[SearchResult]:
        """Executor-side: pad to the bucket, search once (escalating
        unstable rows at the operating point), slice per caller."""
        size = len(reqs)
        bucket = next(b for b in self.buckets if b >= size)
        qs = np.stack([r.q for r in reqs])
        if bucket > size:
            # pad with a REAL query row (not zeros): identical numerics to
            # the unpadded rows, and never a degenerate all-zero distance
            qs = np.concatenate(
                [qs, np.repeat(qs[:1], bucket - size, axis=0)])
        res, esc_mask = self._escalated_search(qs, k)
        done = time.perf_counter()
        e1 = res.stats.get("pass1_distance_evals",
                           res.stats.get("distance_evals", 0.0))
        e2 = res.stats.get("pass2_distance_evals", 0.0)
        out = []
        for i, req in enumerate(reqs):
            stats = dict(res.stats)
            if self._escalation is not None:
                # per-row attribution: an escalated row paid both passes,
                # a stable row only the first
                stats["distance_evals"] = e1 + (e2 if esc_mask[i] else 0.0)
                stats["escalated"] = bool(esc_mask[i])
            single = SearchResult(scores=res.scores[i:i + 1].copy(),
                                  indices=res.indices[i:i + 1].copy(),
                                  latency_s=res.latency_s,
                                  stats=stats)
            if self.cache.maxsize:
                # the cached object IS the returned object: freeze its
                # arrays so a caller mutating its result can't poison
                # every future hit on this key
                single.scores.setflags(write=False)
                single.indices.setflags(write=False)
                self.cache.put(self._cache_key(req.q, k), single)
            out.append(single)
        self.metrics.record_batch(
            size=size, bucket=bucket,
            latencies_s=[done - r.t_enq for r in reqs],
            distance_evals=res.distance_evals,
            escalated=int(esc_mask[:size].sum()))
        return out

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        out = self.metrics.snapshot()
        out["cache"] = self.cache.stats()
        out["index"] = {"kind": self.index.kind,
                        "ntotal": self.index.ntotal,
                        "fingerprint": self._fingerprint,
                        "bytes_per_vector": self.index.bytes_per_vector,
                        "shards": getattr(self.index, "shard_count", None)}
        out["scheduler"] = {"max_batch": self.max_batch,
                            "max_wait_ms": self.max_wait_ms,
                            "buckets": self.buckets,
                            "running": self.running}
        out["operating_point"] = {
            "target_recall": self._target_recall,
            "params": None if self._params is None
            else self._params.to_dict(),
            "escalation": None if self._escalation is None else {
                "delta": self._escalation.delta,
                "threshold": self._escalation.threshold,
                "params": self._esc_params.to_dict()},
            "tuned": self._curve is not None,
        }
        out["mutation"] = {"mutations": self._mutations,
                           "swaps": self._swaps}
        ms = getattr(self.index, "mutation_stats", None)
        if ms is not None:
            out["mutation"]["index"] = ms()
        return out
