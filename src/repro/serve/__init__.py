"""Serving layer: micro-batched engine + HTTP front-end over ``repro.api``.

``SearchEngine`` turns any factory-built ``VectorIndex`` into a concurrent
service: an asyncio scheduler coalesces single-query requests into padded
batches for the fused kernels, an LRU cache (keyed on query bytes, k, and
the index content fingerprint) absorbs repeats, warm-up pre-compiles every
padded shape, and ``stats()`` reports QPS / latency percentiles /
batch-size histogram / cache hit rate. ``repro.serve.http`` exposes it as
``/search`` + ``/stats`` + ``/healthz`` on the stdlib HTTP server;
``python -m repro.launch.serve --serve`` is the launcher.
"""
from .cache import LRUCache
from .engine import SearchEngine
from .http import make_server, start_http_server
from .metrics import EngineMetrics

__all__ = ["EngineMetrics", "LRUCache", "SearchEngine", "make_server",
           "start_http_server"]
