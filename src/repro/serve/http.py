"""Minimal stdlib HTTP front-end for :class:`~repro.serve.engine.SearchEngine`.

Three endpoints, all JSON:

* ``POST /search`` — body ``{"query": [d floats], "k": 10}`` for a single
  query (rides the micro-batch scheduler + cache), or
  ``{"queries": [[...], ...], "k": 10}`` for an explicit batch (direct
  passthrough). Response: ``{"indices", "scores", "latency_ms",
  "distance_evals"}`` (batch shapes are ``[Q, k]``; single responses are
  flattened to ``[k]``).
* ``GET /stats`` — ``engine.stats()`` verbatim.
* ``GET /healthz`` — ``{"status": "ok", ...}`` once the index is built and
  the scheduler thread is alive (503 otherwise) — the k8s-style liveness
  probe.

``ThreadingHTTPServer`` gives one thread per in-flight request, which is
exactly what the engine wants: concurrent handlers block in
``search_one`` and coalesce into shared batches. Start with
:func:`make_server` + ``serve_forever`` (or ``start_http_server`` for a
background thread, which the tests use).
"""
from __future__ import annotations

import json
import math
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from .engine import SearchEngine


def _json_safe(scores: np.ndarray):
    """Scores -> nested lists with non-finite floats as None: index tiers
    pad short results with -inf (FAISS convention), and ``json.dumps``
    would emit the literal ``-Infinity``, which is not RFC 8259 JSON."""
    return [[s if math.isfinite(s) else None for s in row]
            for row in scores.tolist()]


class _Handler(BaseHTTPRequestHandler):
    engine: SearchEngine  # set by make_server on the handler subclass
    protocol_version = "HTTP/1.1"

    # quiet by default: serving logs belong to the launcher, not stderr
    def log_message(self, fmt, *args):  # noqa: D102
        pass

    def _reply(self, code: int, payload: dict) -> None:
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):  # noqa: N802 (stdlib naming)
        if self.path == "/healthz":
            eng = self.engine
            ok = eng.index.built and eng.running
            self._reply(200 if ok else 503,
                        {"status": "ok" if ok else "unavailable",
                         "ntotal": eng.index.ntotal,
                         "fingerprint": eng.stats()["index"]["fingerprint"]})
        elif self.path == "/stats":
            self._reply(200, self.engine.stats())
        else:
            self._reply(404, {"error": f"unknown path {self.path!r}; "
                                       "try /search /stats /healthz"})

    def _validate(self, q: np.ndarray, ndim: int) -> None:
        """Reject malformed query payloads BEFORE they reach the engine:
        a NaN/inf query would poison the fingerprint-keyed result cache
        (the cache keys on query bytes, so the poisoned entry keeps
        serving), and a wrong-dim or ragged vector would surface as an
        opaque 500 from deep inside a kernel. Raises ValueError — the
        handler's 400 net."""
        if q.ndim != ndim:
            what = "query (one vector)" if ndim == 1 else \
                "queries (a batch of vectors)"
            raise ValueError(f"{what} must have {ndim} dimension(s), got "
                             f"shape {list(q.shape)}")
        want = self.engine.index.dim if self.engine.index.built else None
        if want is not None and q.shape[-1] != want:
            raise ValueError(f"query dim {q.shape[-1]} != index dim {want}")
        if not np.isfinite(q).all():
            raise ValueError("query contains NaN or infinite values")

    def do_POST(self):  # noqa: N802
        if self.path != "/search":
            self._reply(404, {"error": f"unknown path {self.path!r}"})
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            req = json.loads(self.rfile.read(length) or b"{}")
            k = int(req.get("k", 10))
            if k < 1:
                raise ValueError(f"k must be >= 1, got {k}")
            if "query" in req:
                q = np.asarray(req["query"], np.float32)
                self._validate(q, 1)
                res = self.engine.search_one(q, k)
                payload = {"indices": res.indices[0].tolist(),
                           "scores": _json_safe(res.scores)[0]}
            elif "queries" in req:
                q = np.asarray(req["queries"], np.float32)
                self._validate(q, 2)
                res = self.engine.search(q, k)
                payload = {"indices": res.indices.tolist(),
                           "scores": _json_safe(res.scores)}
            else:
                self._reply(400, {"error": 'body needs "query" (one vector) '
                                           'or "queries" (a batch)'})
                return
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            self._reply(400, {"error": str(e)})
            return
        payload["latency_ms"] = round(res.latency_s * 1e3, 3)
        if res.distance_evals is not None:
            payload["distance_evals"] = res.distance_evals
        self._reply(200, payload)


class _Server(ThreadingHTTPServer):
    # concurrent single-query clients are the POINT of the engine: a
    # thundering herd of connects must queue, not bounce off the stdlib
    # default backlog of 5
    request_queue_size = 128
    daemon_threads = True


def make_server(engine: SearchEngine, port: int = 8000,
                host: str = "127.0.0.1") -> ThreadingHTTPServer:
    """Bind (port 0 picks a free one — ``server.server_address`` tells
    which); caller runs ``serve_forever()``."""
    handler = type("BoundHandler", (_Handler,), {"engine": engine})
    return _Server((host, port), handler)


def start_http_server(engine: SearchEngine, port: int = 8000,
                      host: str = "127.0.0.1"
                      ) -> tuple[ThreadingHTTPServer, threading.Thread]:
    """Serve on a daemon thread; ``server.shutdown()`` stops it."""
    server = make_server(engine, port, host)
    thread = threading.Thread(target=server.serve_forever, daemon=True,
                              name="serve-http")
    thread.start()
    return server, thread
