"""Serving metrics: request/batch counters behind one lock.

The engine records from its flush thread; ``snapshot()`` is safe from any
thread and powers both ``engine.stats()`` and the HTTP ``/stats`` page.
Latency percentiles come from a bounded window (the most recent
``window`` requests) so a long-lived server reports current behavior, not
its lifetime average; QPS is reported both lifetime and over the same
window.
"""
from __future__ import annotations

import threading
import time
from collections import Counter, deque
from typing import Optional

import numpy as np


class EngineMetrics:
    def __init__(self, window: int = 8192):
        self._lock = threading.Lock()
        self.t_start = time.perf_counter()
        self.n_requests = 0          # single-query requests through the queue
        self.n_cached = 0            # answered straight from the cache
        self.n_batches = 0           # index.search calls issued by the engine
        self.batch_hist: Counter = Counter()   # actual coalesced sizes
        self.bucket_hist: Counter = Counter()  # padded (compiled) sizes
        self._lat = deque(maxlen=window)       # per-request seconds
        self._done = deque(maxlen=window)      # completion timestamps
        self._evals_sum = 0.0        # distance_evals weighted by requests
        self._evals_n = 0
        self.n_escalated = 0         # rows re-run at the next ladder rung

    def record_batch(self, size: int, bucket: int, latencies_s: list,
                     distance_evals: Optional[float],
                     escalated: int = 0) -> None:
        now = time.perf_counter()
        with self._lock:
            self.n_batches += 1
            self.n_requests += size
            self.batch_hist[size] += 1
            self.bucket_hist[bucket] += 1
            self._lat.extend(latencies_s)
            self._done.extend([now] * size)
            if distance_evals is not None:
                self._evals_sum += distance_evals * size
                self._evals_n += size
            self.n_escalated += escalated

    def record_cached(self, latency_s: float) -> None:
        now = time.perf_counter()
        with self._lock:
            self.n_cached += 1
            self._lat.append(latency_s)
            self._done.append(now)

    def snapshot(self) -> dict:
        with self._lock:
            now = time.perf_counter()
            uptime = now - self.t_start
            served = self.n_requests + self.n_cached
            lat = np.asarray(self._lat, np.float64)
            done = list(self._done)
            out = {
                "uptime_s": round(uptime, 3),
                "requests": served,
                "cached_requests": self.n_cached,
                "batches": self.n_batches,
                "qps": round(served / uptime, 2) if uptime > 0 else 0.0,
                "batch_size_mean": round(self.n_requests / self.n_batches, 2)
                if self.n_batches else 0.0,
                "batch_size_hist": {str(b): c for b, c in
                                    sorted(self.batch_hist.items())},
                "bucket_hist": {str(b): c for b, c in
                                sorted(self.bucket_hist.items())},
            }
            if lat.size:
                out["latency_ms"] = {
                    "p50": round(float(np.percentile(lat, 50)) * 1e3, 3),
                    "p99": round(float(np.percentile(lat, 99)) * 1e3, 3),
                    "mean": round(float(lat.mean()) * 1e3, 3),
                }
                # QPS over the latency window: how fast we are NOW
                if len(done) >= 2 and done[-1] > done[0]:
                    out["qps_window"] = round(
                        (len(done) - 1) / (done[-1] - done[0]), 2)
            if self._evals_n:
                out["distance_evals"] = round(
                    self._evals_sum / self._evals_n, 1)
            if self.n_requests:
                # fraction of queued rows whose top-k margin was unstable
                # and paid a second pass (0.0 when escalation is off)
                out["escalation_rate"] = round(
                    self.n_escalated / self.n_requests, 4)
            return out
