"""``ShardedIndex``: scatter-gather search over N disjoint shards.

The million-vector serving tier (ROADMAP "sharded serving"): the corpus is
partitioned across shards — contiguous row ranges (``partition="rows"``)
or k-means cell assignment (``partition="ivf"``), both via
``distributed.partitioning`` — and each shard is an independent child
:class:`VectorIndex` built from a factory spec (``"Flat"``, ``"IVF256"``,
``"IVF256,PQ8x8"``, ...). ``search`` fans the query batch out to every
shard, maps local hits to global row ids through the shard's row map, and
reduces the gathered ``[Q, k * S]`` candidates with the fused
``topk_merge`` kernel — ties broken by the smaller global id, so the
answer is **bitwise invariant to the shard count** (the contract
docs/sharded_serving.md pins and tests/test_sharded.py asserts).

Two execution modes:

* ``workers="threads"`` (default) — a thread pool searches the S children
  concurrently; each child's scan releases the GIL inside jax, so shards
  overlap even on small hosts. This is the scale-out shape: every shard
  is a self-contained index that could live in its own process.
* ``workers="mesh"`` — with a device mesh in ``ctx`` and flat children,
  the corpus row-shards over the mesh's "db_rows" axes and the (fixed)
  device-parallel scatter-gather in ``search.distributed`` does the
  fan-out + merge on-device (one all-gather of k*S scalars per query).

Composes with the rest of the factory grammar: ``"RAE64,Shard8,IVF256,
Rerank4"`` = reduce once, shard the reduced corpus 8 ways into IVF
children, rerank merged candidates in the full space. ``fingerprint()``
composes over the child fingerprints + row maps, so the serving cache
invalidates when any shard changes.
"""
from __future__ import annotations

import functools
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.partitioning import partition_ivf_cells, partition_rows
from ..kernels.common import PAD_ID
from ..kernels.topk_merge.ops import topk_merge
from ..models.common import NULL_CTX, MeshCtx
from .index import (SearchResult, VectorIndex, _load_arrays, _save_dir,
                    register_index)


@register_index("sharded")
class ShardedIndex(VectorIndex):
    """Partition the corpus across ``n_shards`` child indexes and merge
    per-shard top-k with the deterministic scatter-gather kernel."""

    _fp_exempt = {
        "ctx": "mesh/sharding topology changes where the scan runs, not "
               "what it answers",
        "workers": "thread-pool vs device-mesh fan-out; both produce the "
                   "bitwise-identical merge (shard-count-invariance "
                   "contract) and the built children/row maps are hashed",
        "n_workers": "thread-pool width; execution parallelism only",
        "n_cells": "build-time partitioning hyperparam; materialized in "
                   "the hashed row maps",
        "seed": "build-time partitioning hyperparam; materialized in the "
                "hashed row maps",
        "index_kw": "child constructor knobs; materialized in the hashed "
                    "child fingerprints",
        "_dim": "derived from the built children (hashed via their "
                "fingerprints); cached for the dim property",
    }

    def __init__(self, n_shards: int = 2, child_spec: str = "Flat",
                 partition: str = "rows", metric: str = "euclidean",
                 ctx: MeshCtx = NULL_CTX, workers: str = "threads",
                 n_workers: int = 0, n_cells: int = 0, seed: int = 0,
                 index_kw: Optional[dict[str, Any]] = None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if partition not in ("rows", "ivf"):
            raise ValueError(f"unknown partition {partition!r} "
                             "(rows | ivf)")
        if workers not in ("threads", "mesh"):
            raise ValueError(f"unknown workers {workers!r} (threads | mesh)")
        self.n_shards = n_shards
        self.child_spec = child_spec
        self.partition = partition
        self.metric = metric
        self.ctx = ctx
        self.workers = workers
        self.n_workers = n_workers
        self.n_cells = n_cells
        self.seed = seed
        self.index_kw = dict(index_kw or {})
        self._shards: list[VectorIndex] = []
        self._row_maps: list[np.ndarray] = []
        self._ntotal = 0
        self._dim = 0

    # -- identity ----------------------------------------------------------
    @property
    def ntotal(self) -> int:
        return self._ntotal

    @property
    def built(self) -> bool:
        return bool(self._shards)

    @property
    def shard_count(self) -> int:
        """Shards actually built (<= n_shards: empty partitions collapse)."""
        return len(self._shards)

    @property
    def bytes_per_vector(self) -> float:
        self._require_built()
        return max(c.bytes_per_vector for c in self._shards)

    @property
    def bytes_per_shard(self) -> float:
        """Largest per-shard payload — the number that must fit one
        worker/device, the memory axis the sharded bench budgets."""
        self._require_built()
        return max(c.ntotal * c.bytes_per_vector for c in self._shards)

    @property
    def dim(self) -> int:
        self._require_built()
        return self._dim

    @property
    def stage1_oversample(self) -> int:
        """Under a rerank, inherit the children's oversample (PQ children
        have noisy ordering; the merge preserves, not fixes, that)."""
        if not self._shards:
            return 1
        return max(getattr(c, "stage1_oversample", 1) for c in self._shards)

    def _fingerprint_state(self) -> list:
        state = [f"shards={self.n_shards}:{self.partition}:"
                 f"{self.child_spec}:{self.metric}"]
        for child in self._shards:
            state.append(child.fingerprint())
        for rows in self._row_maps:
            state.append(rows)
        return state

    # -- build -------------------------------------------------------------
    def _make_child(self) -> VectorIndex:
        from .factory import index_factory, parse_index_spec  # cycle: lazy

        parsed = parse_index_spec(self.child_spec)
        if parsed.reducer or parsed.shards or parsed.rerank_factor > 1:
            raise ValueError(
                f"child_spec {self.child_spec!r} must be a storage stack "
                "(base [, quant]); reducers/Shard/Rerank wrap the sharded "
                "index, not its children")
        return index_factory(self.child_spec, metric=self.metric,
                             index_kw=dict(self.index_kw))

    def build(self, corpus: np.ndarray) -> "ShardedIndex":
        corpus = np.asarray(corpus, np.float32)
        n = int(corpus.shape[0])
        if self.workers == "mesh":
            return self._build_mesh(corpus)
        if self.partition == "rows":
            parts = partition_rows(n, self.n_shards)
        else:
            parts = partition_ivf_cells(corpus, self.n_shards,
                                        n_cells=self.n_cells,
                                        seed=self.seed)
        parts = [p for p in parts if len(p)]  # empty shards answer nothing
        self._shards = []
        self._row_maps = []
        for rows in parts:
            self._shards.append(self._make_child().build(corpus[rows]))
            self._row_maps.append(np.asarray(rows, np.int32))
        self._ntotal = n
        self._dim = int(corpus.shape[1])
        return self

    def _build_mesh(self, corpus: np.ndarray) -> "ShardedIndex":
        """Device-parallel mode: one flat child over the whole corpus with
        the mesh ctx — ``search.distributed`` row-shards it over "db_rows"
        and runs the on-device scatter-gather (same merge kernel, same
        tie-break, so the invariance contract holds across modes)."""
        from .index import FlatIndex

        if self.ctx.mesh is None:
            raise ValueError("workers='mesh' needs a device mesh in ctx")
        from .factory import parse_index_spec  # cycle: lazy

        parsed = parse_index_spec(self.child_spec)
        if parsed.base != "flat" or parsed.quant is not None:
            raise ValueError("workers='mesh' supports flat children only "
                             f"(got {self.child_spec!r}); use threads for "
                             "IVF/quantized shards")
        if self.partition != "rows":
            raise ValueError("workers='mesh' implies contiguous row "
                             "partitioning (the mesh's db_rows sharding)")
        child = FlatIndex(metric=self.metric, ctx=self.ctx).build(corpus)
        self._shards = [child]
        self._row_maps = [np.arange(corpus.shape[0], dtype=np.int32)]
        self._ntotal = int(corpus.shape[0])
        self._dim = int(corpus.shape[1])
        return self

    # -- search ------------------------------------------------------------
    @functools.cached_property
    def _pool(self) -> ThreadPoolExecutor:
        return ThreadPoolExecutor(
            max_workers=self.n_workers or max(1, len(self._shards)),
            thread_name_prefix="shard")

    def set_params(self, params) -> None:
        """Broadcast a tuned operating point to every shard — the children
        hold the knobs (and hash them), so the composed fingerprint moves
        through the child-fingerprint chain."""
        self._require_built()
        for child in self._shards:
            child.set_params(params)

    def search(self, queries: np.ndarray, k: int,
               alive: Optional[np.ndarray] = None,
               params=None) -> SearchResult:
        self._require_built()
        t0 = time.perf_counter()
        q = np.asarray(queries, np.float32)
        k_req = min(k, self.ntotal)
        n_sh = len(self._shards)
        # tombstones slice per shard through the row map: each child sees
        # only ITS rows' alive bits, in its local row order
        al = None if alive is None else np.asarray(alive, bool)
        child_alive = [None if al is None else al[rows]
                       for rows in self._row_maps]
        if n_sh == 1:
            results = [self._shards[0].search(
                q, min(k_req, self._shards[0].ntotal),
                alive=child_alive[0], params=params)]
        else:
            futs = [self._pool.submit(self._shards[s].search, q,
                                      min(k_req, self._shards[s].ntotal),
                                      alive=child_alive[s], params=params)
                    for s in range(n_sh)]
            results = [f.result() for f in futs]
        vals = np.concatenate(
            [np.asarray(r.scores, np.float32) for r in results], axis=1)
        local = np.concatenate(
            [np.asarray(r.indices, np.int64) for r in results], axis=1)
        # local -> global ids shard by shard; -1 pads stay -1
        gids = np.empty_like(local, dtype=np.int32)
        off = 0
        for rows, r in zip(self._row_maps, results):
            w = r.indices.shape[1]
            blk = local[:, off:off + w]
            gids[:, off:off + w] = np.where(
                blk >= 0, rows[np.clip(blk, 0, len(rows) - 1)], PAD_ID)
            off += w
        v, i = topk_merge(jnp.asarray(vals), jnp.asarray(gids), k_req)
        jax.block_until_ready((v, i))
        dt = time.perf_counter() - t0
        scores = np.array(v)  # copy: jax buffers are read-only views
        idx = np.asarray(i)
        scores[idx < 0] = -np.inf  # API layer speaks the FAISS pad dialect
        stats = {"distance_evals": float(sum(
            r.stats.get("distance_evals", 0.0) for r in results)),
            "shards": float(n_sh)}
        return SearchResult(scores=scores, indices=idx, latency_s=dt,
                            stats=stats)

    # -- persistence -------------------------------------------------------
    def save(self, directory: str) -> None:
        self._require_built()
        meta = {"kind": self.kind, "n_shards": self.n_shards,
                "partition": self.partition, "child_spec": self.child_spec,
                "metric": self.metric, "ntotal": self._ntotal,
                "dim": self._dim, "built_shards": len(self._shards)}
        _save_dir(directory, meta,
                  {f"rows{i}": rows
                   for i, rows in enumerate(self._row_maps)})
        for i, child in enumerate(self._shards):
            child.save(os.path.join(directory, f"shard{i}"))

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "ShardedIndex":
        from .index import load_index  # sibling import kept local for clarity

        self = cls(n_shards=meta["n_shards"], partition=meta["partition"],
                   child_spec=meta["child_spec"], metric=meta["metric"])
        arrays = _load_arrays(directory)
        n_built = int(meta["built_shards"])
        self._row_maps = [np.asarray(arrays[f"rows{i}"], np.int32)
                          for i in range(n_built)]
        self._shards = [load_index(os.path.join(directory, f"shard{i}"))
                        for i in range(n_built)]
        self._ntotal = int(meta["ntotal"])
        self._dim = int(meta["dim"])
        return self
