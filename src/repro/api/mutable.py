"""``MutableIndex``: streaming inserts + tombstone deletes over any tier.

Every other ``VectorIndex`` in this package is write-once: ``build`` then
``search``. This wrapper is the live-serving form (factory prefix
``Mut``, e.g. ``"Mut,RAE64,IVF256,Rerank4"``): it owns the mutation
state — the appended corpus, the tombstone mask, and a monotonically
bumped **mutation epoch** — and pushes each mutation down the wrapped
stack by the cheapest mechanism the tier supports:

* **insert** — tiers with an ``add`` method take rows incrementally
  (HNSW runs the Alg. 1 insert against the live graph and re-packs, IVF
  appends to the nearest centroid's list, flat concatenates, TwoStage
  encodes once and recurses); anything else is rebuilt over the extended
  corpus. Either way the new rows are searchable the moment ``add``
  returns.
* **delete** — rows are never physically removed on the query path:
  ``delete`` flips bits in the ``alive`` mask that ``search`` threads
  down every tier into the fused kernels' ``db_mask`` operand, so a
  tombstoned row can never surface — not even as a pre-rerank candidate.
  When the HNSW entry point itself is tombstoned the graph entry is
  reassigned to the highest alive node before the next search.
* **rebuild** — compacts tombstones away and re-clusters/re-packs from
  scratch. Triggered explicitly, by IVF cell imbalance after appends
  (fixed centroids + drifting stream = fat cells), or by the RAE drift
  monitor: :class:`repro.core.theory.DriftTracker` watches incoming
  vectors' norm distortion against the reducer's Eq. 15 singular-value
  band and forces a reducer **retrain** (not just an index rebuild) once
  the violation rate says the live distribution left the fitted
  manifold. Reducer and index swap together — a retrained encoder over a
  stale index (or vice versa) would answer garbage.

**Row ids are stable for life.** ``add`` returns monotonically assigned
external ids; ``search`` results and ``delete`` arguments speak those
ids, and a compacting ``rebuild`` remaps internals without changing
them.

**Every mutation bumps ``_epoch``**, and the epoch is fingerprint state
(alongside the alive mask, the id map and the inner fingerprint), so the
serving cache can never replay a pre-mutation answer — the invariant the
``mutation-epoch`` lint rule (``analysis/fingerprints.py``) enforces for
every mutable index class.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from ..core.theory import DriftTracker
from ..search import hnsw as hnsw_lib
from .graph import HNSWIndex
from .index import (SearchResult, VectorIndex, _load_arrays, _save_dir,
                    load_index, register_index)


@register_index("mutable")
class MutableIndex(VectorIndex):
    """Wrap a built (or buildable) index stack with add/delete/rebuild."""

    _fp_exempt = {
        "_corpus": "row content is hashed via the inner index fingerprint "
                   "(rows are inserted into the inner tier verbatim); the "
                   "host copy only feeds rebuilds",
        "_next_id": "derived: _row_ids.max()+1, and _row_ids is hashed",
        "imbalance_trigger": "rebuild policy knob: a triggered rebuild "
                             "reshapes the hashed inner fingerprint and "
                             "bumps the hashed epoch",
        "drift_tol": "drift policy knob; same argument as "
                     "imbalance_trigger",
        "drift_threshold": "drift policy knob; same argument as "
                           "imbalance_trigger",
        "_drift": "monitoring state; changes answers only through a "
                  "rebuild, which bumps the hashed epoch",
        "n_added": "host-side telemetry; the hashed epoch advances with "
                   "every counted mutation",
        "n_deleted": "host-side telemetry; same as n_added",
        "n_rebuilds": "host-side telemetry; same as n_added",
        "n_reducer_retrains": "host-side telemetry; same as n_added",
    }

    def __init__(self, inner: VectorIndex, imbalance_trigger: float = 4.0,
                 drift_tol: float = 0.25, drift_threshold: float = 0.10):
        self._inner = inner
        self.imbalance_trigger = imbalance_trigger
        self.drift_tol = drift_tol
        self.drift_threshold = drift_threshold
        self._corpus: Optional[np.ndarray] = None
        self._alive: Optional[np.ndarray] = None
        self._row_ids: Optional[np.ndarray] = None
        self._next_id = 0
        self._epoch = 0
        self._drift: Optional[DriftTracker] = None
        self.n_added = 0
        self.n_deleted = 0
        self.n_rebuilds = 0
        self.n_reducer_retrains = 0

    # -- identity ----------------------------------------------------------
    @property
    def ntotal(self) -> int:
        """Alive rows — the logical corpus size (tombstoned rows still
        occupy inner slots until a rebuild compacts them)."""
        return 0 if self._alive is None else int(self._alive.sum())

    @property
    def built(self) -> bool:
        return self._corpus is not None and self._inner.built

    @property
    def bytes_per_vector(self) -> float:
        return self._inner.bytes_per_vector

    @property
    def dim(self) -> int:
        return self._inner.dim

    @property
    def epoch(self) -> int:
        """Mutation counter: bumps on every add/delete/rebuild."""
        return self._epoch

    @property
    def stage1_oversample(self) -> int:
        return getattr(self._inner, "stage1_oversample", 1)

    def _fingerprint_state(self) -> list:
        # the epoch makes every mutation a new identity even when the
        # content hash could transiently collide; alive + row_ids pin the
        # tombstone set and the external id mapping; the inner fingerprint
        # pins the searched content
        return [f"epoch={self._epoch}", self._inner.fingerprint(),
                self._alive, self._row_ids]

    def mutation_stats(self) -> dict[str, float]:
        """Host-side mutation telemetry (serve engine folds this into
        ``stats()``)."""
        out = {"epoch": float(self._epoch), "added": float(self.n_added),
               "deleted": float(self.n_deleted),
               "rebuilds": float(self.n_rebuilds),
               "reducer_retrains": float(self.n_reducer_retrains),
               "tombstones": 0.0 if self._alive is None
               else float((~self._alive).sum())}
        if self._drift is not None:
            out["drift_violation_rate"] = self._drift.violation_rate
        return out

    # -- drift monitor -----------------------------------------------------
    def _reducer(self):
        return getattr(self._inner, "reducer", None)

    def _arm_drift(self) -> None:
        """(Re)build the Eq. 15 monitor from the fitted reducer's encoder
        weights; reducers without a weight matrix (or no reducer at all)
        leave drift tracking off."""
        self._drift = None
        r = self._reducer()
        params = getattr(r, "params_", None)
        if params is not None and "w_e" in params:
            from ..core import rae as rae_lib
            self._drift = DriftTracker.from_weights(
                rae_lib.encoder_matrix(params), tol=self.drift_tol,
                threshold=self.drift_threshold)

    def _graph_index(self) -> Optional[HNSWIndex]:
        obj: Any = self._inner
        while obj is not None:
            if isinstance(obj, HNSWIndex):
                return obj
            obj = getattr(obj, "base", None)
        return None

    def _imbalance(self) -> float:
        obj: Any = self._inner
        while obj is not None:
            fn = getattr(obj, "cell_imbalance", None)
            if fn is not None:
                return float(fn())
            obj = getattr(obj, "base", None)
        return 1.0

    # -- lifecycle ---------------------------------------------------------
    def build(self, corpus: np.ndarray) -> "MutableIndex":
        corpus = np.asarray(corpus, np.float32)
        self._inner.build(corpus)
        self._corpus = corpus.copy()
        self._alive = np.ones(corpus.shape[0], bool)
        self._row_ids = np.arange(corpus.shape[0], dtype=np.int64)
        self._next_id = int(corpus.shape[0])
        self._epoch = 0
        self._arm_drift()
        return self

    def add(self, vecs: np.ndarray) -> np.ndarray:
        """Insert rows; returns their external ids. New rows answer the
        very next ``search``. May trigger a synchronous rebuild (IVF
        imbalance / reducer drift) — serving deployments run ``add``
        through ``SearchEngine.mutate`` so queries never observe a
        half-applied state."""
        self._require_built()
        nv = np.atleast_2d(np.asarray(vecs, np.float32))
        if nv.shape[1] != self._corpus.shape[1]:
            raise ValueError(f"add: dim {nv.shape[1]} != index dim "
                             f"{self._corpus.shape[1]}")
        ext = np.arange(self._next_id, self._next_id + nv.shape[0],
                        dtype=np.int64)
        self._next_id += int(nv.shape[0])
        self._corpus = np.concatenate([self._corpus, nv])
        self._alive = np.concatenate(
            [self._alive, np.ones(nv.shape[0], bool)])
        self._row_ids = np.concatenate([self._row_ids, ext])
        r = self._reducer()
        if self._drift is not None and r is not None:
            self._drift.observe(nv, np.asarray(r.transform(nv)))
        if hasattr(self._inner, "add"):
            self._inner.add(nv)
        else:
            # no incremental path (sharded / quantized-flat tiers):
            # rebuild the inner structure over the full slab — tombstones
            # stay masked, ids stay positional
            self._inner.build(self._corpus)
        self._epoch += 1
        self.n_added += int(nv.shape[0])
        if self._drift is not None and self._drift.should_retrain:
            self.rebuild(refit_reducer=True)
        elif self._imbalance() > self.imbalance_trigger:
            self.rebuild()
        return ext

    def delete(self, ids) -> int:
        """Tombstone external ids; returns how many were newly deleted
        (re-deleting is a no-op, unknown ids raise). The rows stop
        surfacing immediately — no rebuild on the delete path."""
        self._require_built()
        ids = np.asarray(ids, np.int64).ravel()
        if ids.size == 0:
            return 0
        pos = np.searchsorted(self._row_ids, ids)
        bad = (pos >= self._row_ids.shape[0]) \
            | (self._row_ids[np.minimum(pos, self._row_ids.shape[0] - 1)]
               != ids)
        if bad.any():
            raise KeyError(f"delete: unknown ids {ids[bad][:8].tolist()}")
        newly = int(self._alive[pos].sum())
        if newly == 0:
            return 0
        self._alive[pos] = False
        self._epoch += 1
        self.n_deleted += newly
        g = self._graph_index()
        if g is not None and self._alive.any() \
                and not self._alive[g._g.entry]:
            # the beam must start somewhere alive; pick the highest alive
            # node so upper-layer routing keeps working
            hnsw_lib.reassign_entry(g._g, self._alive)
        return newly

    def rebuild(self, refit_reducer: bool = False) -> "MutableIndex":
        """Compact tombstones away and rebuild the inner stack from
        scratch (fresh k-means / graph / packing over only the alive
        rows). ``refit_reducer=True`` additionally retrains the reducer
        on the compacted corpus — the drift-retrain path; reducer and
        index always swap together. External ids survive the remap."""
        self._require_built()
        keep = np.flatnonzero(self._alive)
        self._corpus = np.ascontiguousarray(self._corpus[keep])
        self._row_ids = np.ascontiguousarray(self._row_ids[keep])
        self._alive = np.ones(keep.shape[0], bool)
        r = self._reducer()
        if refit_reducer and r is not None \
                and hasattr(r, "params_"):
            r.params_ = None  # TwoStageIndex.build refits unfitted reducers
            self.n_reducer_retrains += 1
        self._inner.build(self._corpus)
        self._arm_drift()
        self._epoch += 1
        self.n_rebuilds += 1
        return self

    # -- search ------------------------------------------------------------
    def set_params(self, params) -> None:
        """Forward a tuned operating point to the wrapped tier — its knob
        attrs are its fingerprint state, and the mutable fingerprint
        composes over the inner one, so the identity moves here too."""
        self._require_built()
        self._inner.set_params(params)

    def search(self, queries: np.ndarray, k: int,
               alive: Optional[np.ndarray] = None,
               params=None) -> SearchResult:
        self._require_built()
        if alive is not None:
            raise ValueError("MutableIndex owns the tombstone mask; "
                             "callers never pass alive")
        q = np.atleast_2d(np.asarray(queries, np.float32))
        n_alive = int(self._alive.sum())
        if n_alive == 0:
            return SearchResult(
                scores=np.full((q.shape[0], 0), -np.inf, np.float32),
                indices=np.full((q.shape[0], 0), -1, np.int64),
                latency_s=0.0, stats={"distance_evals": 0.0})
        # alive=None keeps the inner tiers on their bitwise-static paths
        mask = None if self._alive.all() else self._alive
        r = self._inner.search(q, min(k, n_alive), alive=mask, params=params)
        idx = np.asarray(r.indices)
        safe = np.clip(idx, 0, self._row_ids.shape[0] - 1)
        ext = np.where(idx >= 0, self._row_ids[safe], -1)
        return SearchResult(scores=np.asarray(r.scores), indices=ext,
                            latency_s=r.latency_s, stats=dict(r.stats))

    # -- persistence -------------------------------------------------------
    def save(self, directory: str) -> None:
        import os

        self._require_built()
        meta = {"kind": self.kind, "epoch": self._epoch,
                "next_id": self._next_id,
                "imbalance_trigger": self.imbalance_trigger,
                "drift_tol": self.drift_tol,
                "drift_threshold": self.drift_threshold,
                "n_added": self.n_added, "n_deleted": self.n_deleted,
                "n_rebuilds": self.n_rebuilds,
                "n_reducer_retrains": self.n_reducer_retrains}
        _save_dir(directory, meta,
                  {"corpus": self._corpus, "alive": self._alive,
                   "row_ids": self._row_ids})
        self._inner.save(os.path.join(directory, "inner"))

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "MutableIndex":
        import os

        inner = load_index(os.path.join(directory, "inner"))
        self = cls(inner,
                   imbalance_trigger=float(meta["imbalance_trigger"]),
                   drift_tol=float(meta["drift_tol"]),
                   drift_threshold=float(meta["drift_threshold"]))
        a = _load_arrays(directory)
        self._corpus = np.asarray(a["corpus"], np.float32)
        self._alive = np.asarray(a["alive"], bool)
        self._row_ids = np.asarray(a["row_ids"], np.int64)
        self._epoch = int(meta["epoch"])
        self._next_id = int(meta["next_id"])
        self.n_added = int(meta.get("n_added", 0))
        self.n_deleted = int(meta.get("n_deleted", 0))
        self.n_rebuilds = int(meta.get("n_rebuilds", 0))
        self.n_reducer_retrains = int(meta.get("n_reducer_retrains", 0))
        self._arm_drift()
        return self
