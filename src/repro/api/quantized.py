"""Quantized ``VectorIndex`` tiers: SQ8 and PQ codes, flat or IVF-sharded.

The first index family where *memory*, not FLOPs, is the scaled resource:
every class here stores codes instead of f32 vectors and searches them
asymmetrically (exact f32 query vs quantized corpus), so the recall hit is
bounded by the reconstruction error alone.

=============  =======================================  ==================
factory stage  class                                    bytes / vector
=============  =======================================  ==================
``SQ8``        :class:`SQ8Index` (flat ADC scan)        d + 4
``PQ{m}x{b}``  :class:`PQIndex` (fused ADC kernel)      m (uint8/subspace)
``IVF{c},SQ8`` :class:`IVFSQ8Index` (probe + ADC)       d + 8
``IVF{c},PQ…`` :class:`IVFPQIndex` (probe + LUT ADC)    m + 4
=============  =======================================  ==================

All compose with any reducer through ``TwoStageIndex`` — e.g.
``"RAE64,IVF256,PQ8x8,Rerank4"`` = RAE 256->64, IVF over reduced space, PQ
codes in the lists, full-space rerank. Persistence follows the house
layout (``meta.json`` + ``arrays.npz``); codes round-trip as uint8.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import pq_adc
from ..search import ivf as ivf_lib
from ..search import quantize as qz
from .index import (SearchParams, VectorIndex, _load_arrays, _pad_result,
                    _probed_sizes, _save_dir, _timed, register_index)


def _drop_tombstones(vals, idx, alive: np.ndarray, k_req: int
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Strip tombstoned ids out of an over-fetched top-k.

    The flat quantized scans (``sq8_scan`` / ``pq_adc``) have no mask
    operand, so callers over-fetch ``k + n_dead`` rows — enough that the
    dead rows can never crowd out k alive ones — and this filters them,
    shifting survivors left (stable, so relative order is preserved) and
    padding the tail with the house (-inf, -1) convention."""
    v = np.asarray(vals, np.float32)
    i = np.asarray(idx)
    keep = (i >= 0) & alive[np.where(i >= 0, i, 0)]
    # stable sort on "dead?" moves survivors left without reordering them
    order = np.argsort(~keep, axis=1, kind="stable")[:, :k_req]
    rr = np.arange(v.shape[0])[:, None]
    kept = keep[rr, order]
    out_v = np.where(kept, v[rr, order], -np.inf).astype(np.float32)
    out_i = np.where(kept, i[rr, order], -1)
    return out_v, out_i


def _fold_alive_into_lists(lists, mask, alive):
    """Fold a row-level tombstone mask into IVF list slots: a dead row's
    slot is masked AND its id nulled to -1 — the probe scans keep real ids
    on masked slots (at -inf), which could surface when a probe holds
    fewer than k alive members."""
    al = jnp.asarray(np.asarray(alive, bool))
    mask = mask & al[jnp.where(lists >= 0, lists, 0)]
    return jnp.where(mask, lists, -1), mask


# ---------------------------------------------------------------------------
# SQ8 flat
# ---------------------------------------------------------------------------
@register_index("sq8_flat")
class SQ8Index(VectorIndex):
    """Flat exact-order ADC scan over SQ8 codes (4x smaller than f32).

    ``build`` fits the per-dim [min, max] codebook on the corpus and stores
    uint8 codes + per-row ``||x_hat||^2``; ``search`` never dequantizes —
    the scan is one f32xuint8 matmul (see ``search.quantize``)."""

    # SQ8 ordering is near-exact (error <= step/2/dim); a light oversample
    # under a rerank recovers the borderline swaps.
    stage1_oversample = 2

    _fp_exempt = {
        "_recon_sq": "derived: recomputable from _sq + _codes (both "
                     "hashed)",
    }

    def __init__(self):
        self._sq: Optional[qz.ScalarQuantizer] = None
        self._codes: Optional[jax.Array] = None
        self._recon_sq: Optional[jax.Array] = None

    @property
    def ntotal(self) -> int:
        return 0 if self._codes is None else int(self._codes.shape[0])

    @property
    def built(self) -> bool:
        return self._codes is not None

    @property
    def bytes_per_vector(self) -> float:
        """uint8 per dim + f32 reconstruction norm."""
        self._require_built()
        return float(self._codes.shape[1] + 4)

    @property
    def dim(self) -> int:
        self._require_built()
        return int(self._codes.shape[1])

    def _fingerprint_state(self) -> list:
        return [self._sq.vmin, self._sq.step, self._codes]

    def build(self, corpus: np.ndarray) -> "SQ8Index":
        corpus = jnp.asarray(corpus, jnp.float32)
        self._sq = qz.sq8_train(corpus)
        self._codes = qz.sq8_encode(self._sq, corpus)
        self._recon_sq = qz.sq8_recon_sq_norms(self._sq, self._codes)
        return self

    def search(self, queries: np.ndarray, k: int,
               alive: Optional[np.ndarray] = None,
               params: Optional[SearchParams] = None) -> "SearchResult":
        del params  # flat code scan has no knobs: every row is scored
        self._require_built()
        q = jnp.asarray(queries, jnp.float32)
        k_eff = min(k, self.ntotal)
        if alive is None:
            return _timed(
                lambda: qz.sq8_scan(self._sq.vmin, self._sq.step, q,
                                    self._codes, self._recon_sq, k_eff),
                stats={"distance_evals": float(self.ntotal)})
        al = np.asarray(alive, bool)
        k_fetch = min(self.ntotal, k_eff + int((~al).sum()))

        def run():
            v, i = qz.sq8_scan(self._sq.vmin, self._sq.step, q, self._codes,
                               self._recon_sq, k_fetch)
            return _drop_tombstones(v, i, al, k_eff)

        return _timed(run, stats={"distance_evals": float(self.ntotal)})

    def save(self, directory: str) -> None:
        self._require_built()
        _save_dir(directory, {"kind": self.kind}, {
            "vmin": np.asarray(self._sq.vmin),
            "step": np.asarray(self._sq.step),
            "codes": np.asarray(self._codes),
            "recon_sq": np.asarray(self._recon_sq),
        })

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "SQ8Index":
        a = _load_arrays(directory)
        self = cls()
        self._sq = qz.ScalarQuantizer(vmin=jnp.asarray(a["vmin"]),
                                      step=jnp.asarray(a["step"]))
        self._codes = jnp.asarray(a["codes"])
        self._recon_sq = jnp.asarray(a["recon_sq"])
        return self


# ---------------------------------------------------------------------------
# PQ flat
# ---------------------------------------------------------------------------
@register_index("pq_flat")
class PQIndex(VectorIndex):
    """Flat ADC scan over PQ codes via the fused ``pq_adc`` kernel
    (Pallas on TPU, jnp oracle elsewhere). ``m`` bytes per vector (one
    uint8 code per subspace; bits < 8 narrows the codebook, not the
    storage) — 32x smaller than f32 at d=8m."""

    # ADC ordering is noisy at PQ compression rates: a true neighbor often
    # sits in the ADC top-few-hundred but not the top-k*rerank. Candidate
    # lists cost one LUT gather per row, so over-fetch aggressively and let
    # the exact rerank (TwoStageIndex) sort it out — FAISS refine / SCANN
    # reorder do the same.
    stage1_oversample = 8

    _fp_exempt = {
        "m": "build-time hyperparam; materialized in the hashed "
             "codebooks/codes shapes",
        "bits": "build-time hyperparam; materialized in the hashed "
                "codebooks shape",
        "kmeans_iters": "build-time hyperparam; materialized in the "
                        "hashed codebooks",
        "seed": "build-time hyperparam; materialized in the hashed "
                "codebooks",
    }

    def __init__(self, m: int = 8, bits: int = 8, kmeans_iters: int = 15,
                 seed: int = 0):
        self.m = m
        self.bits = bits
        self.kmeans_iters = kmeans_iters
        self.seed = seed
        self._pq: Optional[qz.ProductQuantizer] = None
        self._codes: Optional[jax.Array] = None

    @property
    def ntotal(self) -> int:
        return 0 if self._codes is None else int(self._codes.shape[0])

    @property
    def built(self) -> bool:
        return self._codes is not None

    @property
    def bytes_per_vector(self) -> float:
        return float(qz.bytes_per_code(self.m, self.bits))

    @property
    def dim(self) -> int:
        self._require_built()
        # codebooks are [m, 2^bits, d/m]
        return int(self._pq.codebooks.shape[0] * self._pq.codebooks.shape[2])

    def _fingerprint_state(self) -> list:
        return [self._pq.codebooks, self._codes]

    def build(self, corpus: np.ndarray) -> "PQIndex":
        corpus = jnp.asarray(corpus, jnp.float32)
        self._pq = qz.pq_train(corpus, self.m, self.bits,
                               iters=self.kmeans_iters, seed=self.seed)
        self._codes = qz.pq_encode(self._pq, corpus)
        return self

    def search(self, queries: np.ndarray, k: int,
               alive: Optional[np.ndarray] = None,
               params: Optional[SearchParams] = None) -> "SearchResult":
        del params  # flat ADC scan has no knobs: every row is scored
        self._require_built()
        q = jnp.asarray(queries, jnp.float32)
        k_eff = min(k, self.ntotal)
        if alive is None:
            return _timed(lambda: pq_adc(q, self._pq.codebooks, self._codes,
                                         k_eff),
                          stats={"distance_evals": float(self.ntotal)})
        al = np.asarray(alive, bool)
        k_fetch = min(self.ntotal, k_eff + int((~al).sum()))

        def run():
            v, i = pq_adc(q, self._pq.codebooks, self._codes, k_fetch)
            return _drop_tombstones(v, i, al, k_eff)

        return _timed(run, stats={"distance_evals": float(self.ntotal)})

    def save(self, directory: str) -> None:
        self._require_built()
        _save_dir(directory, {"kind": self.kind, "m": self.m,
                              "bits": self.bits,
                              "kmeans_iters": self.kmeans_iters,
                              "seed": self.seed},
                  {"codebooks": np.asarray(self._pq.codebooks),
                   "codes": np.asarray(self._codes)})

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "PQIndex":
        a = _load_arrays(directory)
        self = cls(m=meta["m"], bits=meta["bits"],
                   kmeans_iters=meta["kmeans_iters"], seed=meta["seed"])
        self._pq = qz.ProductQuantizer(codebooks=jnp.asarray(a["codebooks"]))
        self._codes = jnp.asarray(a["codes"])
        return self


# ---------------------------------------------------------------------------
# IVF + quantized list payloads (shared coarse layer)
# ---------------------------------------------------------------------------
class _IVFQuantBase(VectorIndex):
    """Shared coarse layer: k-means cells from ``search.ivf`` whose padded
    dense lists store *codes* instead of f32 vectors."""

    _fp_exempt = {
        "n_cells": "build-time hyperparam; materialized in the hashed "
                   "centroids/lists arrays",
        "cell_cap": "build-time hyperparam; materialized in the hashed "
                    "lists shape",
        "kmeans_iters": "build-time hyperparam; materialized in the "
                        "hashed centroids",
        "seed": "build-time hyperparam; materialized in the hashed "
                "centroids/lists",
        "_mask": "derived: exactly (_lists >= 0), and _lists is hashed",
        "_cell_sizes": "derived from _mask; feeds host-side stats only",
        "spill": "build diagnostic; spilled membership is materialized "
                 "in the hashed _lists",
    }

    def __init__(self, n_cells: int = 256, nprobe: int = 0,
                 cell_cap: Optional[int] = None, kmeans_iters: int = 10,
                 seed: int = 0):
        self.n_cells = n_cells
        # ADC scans are cheap, so default to probing 2x the IVF-flat share
        self.nprobe = nprobe or max(8, n_cells // 8)
        self.cell_cap = cell_cap
        self.kmeans_iters = kmeans_iters
        self.seed = seed
        self._centroids: Optional[jax.Array] = None
        self._lists: Optional[jax.Array] = None
        self._mask: Optional[jax.Array] = None
        self._cell_sizes: Optional[np.ndarray] = None  # fixed at build
        self._ntotal = 0
        self.spill = 0

    @property
    def ntotal(self) -> int:
        return self._ntotal

    @property
    def built(self) -> bool:
        return self._lists is not None

    def _build_coarse(self, corpus: jax.Array) -> ivf_lib.IVFIndex:
        n_cells = min(self.n_cells, corpus.shape[0])
        coarse = ivf_lib.build(corpus, n_cells, cell_cap=self.cell_cap,
                               kmeans_iters=self.kmeans_iters, seed=self.seed)
        self._centroids = coarse.centroids
        self._lists = coarse.lists
        self._mask = coarse.list_mask
        self._cell_sizes = np.asarray(coarse.list_mask).sum(axis=1)
        self._ntotal = int(corpus.shape[0])
        self.spill = int(coarse.spill)
        return coarse

    @property
    def dim(self) -> int:
        self._require_built()
        return int(self._centroids.shape[1])

    def _fingerprint_state(self) -> list:
        # coarse layer; subclasses append their code payloads
        return [f"nprobe={self.nprobe}", self._centroids, self._lists]

    def set_params(self, params: SearchParams) -> None:
        """Adopt a tuned ``nprobe`` default (fingerprint state, same as
        :class:`~repro.api.index.IVFFlatIndex`)."""
        if params.nprobe is not None:
            self.nprobe = params.nprobe

    def _probe_budget(self, k: int,
                      params: Optional[SearchParams] = None
                      ) -> tuple[int, int, int]:
        """(k requested, k servable by the probe scan, nprobe).
        ``params.nprobe`` overrides ``self.nprobe`` for this call —
        ladder-snapped, so the static-arg jit caches stay bounded."""
        nprobe = (self.nprobe if params is None or params.nprobe is None
                  else params.nprobe)
        nprobe = min(nprobe, int(self._centroids.shape[0]))
        k_req = min(k, self.ntotal)
        k_eff = min(k_req, nprobe * int(self._lists.shape[1]))
        return k_req, k_eff, nprobe

    def _probe_stats(self, queries: np.ndarray,
                     nprobe: int) -> dict[str, float]:
        return {"distance_evals": _probed_sizes(queries, self._centroids,
                                                self._cell_sizes, nprobe),
                "centroid_evals": float(self._centroids.shape[0])}

    def _coarse_meta(self) -> dict[str, Any]:
        return {"kind": self.kind, "n_cells": self.n_cells,
                "nprobe": self.nprobe, "kmeans_iters": self.kmeans_iters,
                "seed": self.seed, "ntotal": self._ntotal,
                "spill": self.spill}

    def _coarse_arrays(self) -> dict[str, np.ndarray]:
        return {"centroids": np.asarray(self._centroids),
                "lists": np.asarray(self._lists),
                "mask": np.asarray(self._mask)}

    def _load_coarse(self, meta: dict[str, Any],
                     a: dict[str, np.ndarray]) -> None:
        self._centroids = jnp.asarray(a["centroids"])
        self._lists = jnp.asarray(a["lists"])
        self._mask = jnp.asarray(a["mask"])
        self._cell_sizes = a["mask"].sum(axis=1)
        self._ntotal = int(meta["ntotal"])
        self.spill = int(meta.get("spill", 0))


@register_index("ivf_sq8")
class IVFSQ8Index(_IVFQuantBase):
    """IVF cells whose lists hold SQ8 codes: probe ``nprobe`` cells, scan
    their codes dequant-free. Short results pad with -1/-inf like
    ``IVFFlatIndex``."""

    stage1_oversample = 2  # same near-exact ordering as SQ8Index

    _fp_exempt = {
        "_recon_sq": "derived: recomputable from _sq + _codes (both "
                     "hashed)",
    }

    def __init__(self, n_cells: int = 256, nprobe: int = 0,
                 cell_cap: Optional[int] = None, kmeans_iters: int = 10,
                 seed: int = 0):
        super().__init__(n_cells, nprobe, cell_cap, kmeans_iters, seed)
        self._sq: Optional[qz.ScalarQuantizer] = None
        self._codes: Optional[jax.Array] = None      # [C, cap, d] uint8
        self._recon_sq: Optional[jax.Array] = None   # [C, cap]

    @property
    def bytes_per_vector(self) -> float:
        """uint8 per dim + f32 recon norm + int32 row id."""
        self._require_built()
        return float(self._codes.shape[2] + 4 + 4)

    def _fingerprint_state(self) -> list:
        return super()._fingerprint_state() + [self._sq.vmin, self._sq.step,
                                               self._codes]

    def build(self, corpus: np.ndarray) -> "IVFSQ8Index":
        corpus = jnp.asarray(corpus, jnp.float32)
        coarse = self._build_coarse(corpus)
        self._sq = qz.sq8_train(corpus)
        c, cap, d = coarse.list_vecs.shape
        flat = qz.sq8_encode(self._sq, coarse.list_vecs.reshape(c * cap, d))
        self._codes = flat.reshape(c, cap, d)
        self._recon_sq = qz.sq8_recon_sq_norms(
            self._sq, flat).reshape(c, cap)
        return self

    def search(self, queries: np.ndarray, k: int,
               alive: Optional[np.ndarray] = None,
               params: Optional[SearchParams] = None) -> "SearchResult":
        self._require_built()
        q = jnp.asarray(queries, jnp.float32)
        k_req, k_eff, nprobe = self._probe_budget(k, params)
        lists, mask = self._lists, self._mask
        if alive is not None:
            lists, mask = _fold_alive_into_lists(lists, mask, alive)

        def run():
            v, i = qz.ivf_sq8_search(self._centroids, lists,
                                     self._codes, self._recon_sq, mask,
                                     self._sq.vmin, self._sq.step, q,
                                     k_eff, nprobe)
            return _pad_result(v, i, k_req)

        return _timed(run, stats=self._probe_stats(queries, nprobe))

    def save(self, directory: str) -> None:
        self._require_built()
        arrays = self._coarse_arrays()
        arrays.update({"vmin": np.asarray(self._sq.vmin),
                       "step": np.asarray(self._sq.step),
                       "codes": np.asarray(self._codes),
                       "recon_sq": np.asarray(self._recon_sq)})
        _save_dir(directory, self._coarse_meta(), arrays)

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "IVFSQ8Index":
        a = _load_arrays(directory)
        self = cls(n_cells=meta["n_cells"], nprobe=meta["nprobe"],
                   kmeans_iters=meta["kmeans_iters"], seed=meta["seed"])
        self._load_coarse(meta, a)
        self._sq = qz.ScalarQuantizer(vmin=jnp.asarray(a["vmin"]),
                                      step=jnp.asarray(a["step"]))
        self._codes = jnp.asarray(a["codes"])
        self._recon_sq = jnp.asarray(a["recon_sq"])
        return self


@register_index("ivf_pq")
class IVFPQIndex(_IVFQuantBase):
    """IVF cells whose lists hold PQ codes, scanned with a per-query ADC
    LUT — the classic FAISS ``IVFx,PQy`` tier. PQ codebooks are trained on
    the raw corpus (not residuals): one global LUT per query instead of one
    per probed cell, which keeps the scan a single gather."""

    stage1_oversample = 8  # same ADC ordering noise as PQIndex

    _fp_exempt = {
        "m": "build-time hyperparam; materialized in the hashed "
             "codebooks/codes shapes",
        "bits": "build-time hyperparam; materialized in the hashed "
                "codebooks shape",
        "pq_iters": "build-time hyperparam; materialized in the hashed "
                    "codebooks",
    }

    def __init__(self, n_cells: int = 256, m: int = 8, bits: int = 8,
                 nprobe: int = 0, cell_cap: Optional[int] = None,
                 kmeans_iters: int = 10, pq_iters: int = 15, seed: int = 0):
        super().__init__(n_cells, nprobe, cell_cap, kmeans_iters, seed)
        self.m = m
        self.bits = bits
        self.pq_iters = pq_iters
        self._pq: Optional[qz.ProductQuantizer] = None
        self._codes: Optional[jax.Array] = None      # [C, cap, m] uint8

    @property
    def bytes_per_vector(self) -> float:
        """packed code + int32 row id."""
        return float(qz.bytes_per_code(self.m, self.bits) + 4)

    def _fingerprint_state(self) -> list:
        return super()._fingerprint_state() + [self._pq.codebooks,
                                               self._codes]

    def build(self, corpus: np.ndarray) -> "IVFPQIndex":
        corpus = jnp.asarray(corpus, jnp.float32)
        coarse = self._build_coarse(corpus)
        self._pq = qz.pq_train(corpus, self.m, self.bits,
                               iters=self.pq_iters, seed=self.seed)
        c, cap, d = coarse.list_vecs.shape
        flat = qz.pq_encode(self._pq, coarse.list_vecs.reshape(c * cap, d))
        self._codes = flat.reshape(c, cap, self.m)
        return self

    def search(self, queries: np.ndarray, k: int,
               alive: Optional[np.ndarray] = None,
               params: Optional[SearchParams] = None) -> "SearchResult":
        self._require_built()
        q = jnp.asarray(queries, jnp.float32)
        k_req, k_eff, nprobe = self._probe_budget(k, params)
        lists, mask = self._lists, self._mask
        if alive is not None:
            lists, mask = _fold_alive_into_lists(lists, mask, alive)

        def run():
            v, i = qz.ivf_pq_search(self._centroids, lists,
                                    self._codes, mask,
                                    self._pq.codebooks, q, k_eff, nprobe)
            return _pad_result(v, i, k_req)

        return _timed(run, stats=self._probe_stats(queries, nprobe))

    def save(self, directory: str) -> None:
        self._require_built()
        arrays = self._coarse_arrays()
        arrays.update({"codebooks": np.asarray(self._pq.codebooks),
                       "codes": np.asarray(self._codes)})
        meta = self._coarse_meta()
        meta.update({"m": self.m, "bits": self.bits,
                     "pq_iters": self.pq_iters})
        _save_dir(directory, meta, arrays)

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "IVFPQIndex":
        a = _load_arrays(directory)
        self = cls(n_cells=meta["n_cells"], m=meta["m"], bits=meta["bits"],
                   nprobe=meta["nprobe"], kmeans_iters=meta["kmeans_iters"],
                   pq_iters=meta["pq_iters"], seed=meta["seed"])
        self._load_coarse(meta, a)
        self._pq = qz.ProductQuantizer(codebooks=jnp.asarray(a["codebooks"]))
        self._codes = jnp.asarray(a["codes"])
        return self
