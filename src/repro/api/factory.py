"""FAISS-style ``index_factory``: build an index stack from a spec string.

Grammar (comma-separated stages, case-insensitive)::

    spec     := [reducer ","] base ["," rerank]
    reducer  := ("RAE" | "PCA" | "RP" | "MDS" | "ISOMAP" | "UMAP") out_dim
    base     := "Flat" | "IVF" n_cells
    rerank   := "Rerank" factor          # requires a reducer stage

Examples::

    index_factory("Flat")                      # exact scan
    index_factory("IVF256")                    # coarse-quantized, raw space
    index_factory("PCA32,Flat")                # reduce, scan, rerank@1
    index_factory("RAE64,IVF256,Rerank4")      # the full paper stack

Any reducer name registered via :func:`repro.api.register_reducer` is
accepted, so third-party reducers compose for free. ``parse_index_spec``
exposes the parsed form for callers that need to inspect a spec (serving
flags, benchmarks) without building anything.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Optional

from ..models.common import NULL_CTX, MeshCtx
from .index import FlatIndex, IVFFlatIndex, TwoStageIndex, VectorIndex
from .reducer import list_reducers, make_reducer

_TOKEN = re.compile(r"^([A-Za-z_]+?)(\d+)?$")


@dataclass(frozen=True)
class IndexSpec:
    """Parsed form of a factory spec string."""

    reducer: Optional[str] = None     # registry name, e.g. "rae"
    out_dim: int = 0                  # reducer target dim
    base: str = "flat"                # "flat" | "ivf"
    n_cells: int = 0                  # ivf only
    rerank_factor: int = 1


def _fail(spec: str, why: str):
    raise ValueError(f"bad index spec {spec!r}: {why}")


def parse_index_spec(spec: str) -> IndexSpec:
    tokens = [t.strip() for t in spec.split(",")]
    if not spec.strip() or any(not t for t in tokens):
        _fail(spec, "empty stage")
    reducer: Optional[str] = None
    out_dim = 0
    base: Optional[str] = None
    n_cells = 0
    rerank = 0
    for tok in tokens:
        m = _TOKEN.match(tok)
        if not m:
            _fail(spec, f"unparseable stage {tok!r}")
        name, num = m.group(1).lower(), m.group(2)
        if name == "flat":
            if num is not None:
                _fail(spec, "Flat takes no parameter")
            if base is not None:
                _fail(spec, "multiple base stages")
            if rerank:
                _fail(spec, "Rerank must come last")
            base = "flat"
        elif name == "ivf":
            if num is None:
                _fail(spec, "IVF needs a cell count, e.g. IVF256")
            if base is not None:
                _fail(spec, "multiple base stages")
            if rerank:
                _fail(spec, "Rerank must come last")
            base, n_cells = "ivf", int(num)
        elif name == "rerank":
            if num is None:
                _fail(spec, "Rerank needs a factor, e.g. Rerank4")
            if rerank:
                _fail(spec, "multiple Rerank stages")
            rerank = int(num)
        elif name in list_reducers():
            if num is None:
                _fail(spec, f"reducer {name!r} needs a target dim, "
                            f"e.g. {name.upper()}64")
            if reducer is not None:
                _fail(spec, "multiple reducer stages")
            if base is not None:
                _fail(spec, "reducer must come before the base stage")
            reducer, out_dim = name, int(num)
        else:
            _fail(spec, f"unknown stage {tok!r} "
                        f"(reducers: {list_reducers()}; bases: flat, ivf)")
    if base is None:
        _fail(spec, "no base stage (Flat or IVF<n>)")
    if rerank and reducer is None:
        _fail(spec, "Rerank requires a reducer stage to rerank against")
    if out_dim <= 0 and reducer is not None:
        _fail(spec, "reducer target dim must be positive")
    return IndexSpec(reducer=reducer, out_dim=out_dim, base=base,
                     n_cells=n_cells, rerank_factor=rerank or 1)


def index_factory(spec: str, *, metric: str = "euclidean",
                  ctx: MeshCtx = NULL_CTX,
                  reducer_kw: Optional[dict[str, Any]] = None,
                  index_kw: Optional[dict[str, Any]] = None) -> VectorIndex:
    """Build an (unbuilt) index stack from ``spec``.

    ``reducer_kw`` is forwarded to the reducer constructor (e.g. RAE's
    ``steps`` / ``weight_decay`` / ``mesh``); ``index_kw`` to the base index
    (e.g. IVF's ``nprobe``). Call ``.build(corpus)`` on the result.
    """
    parsed = parse_index_spec(spec)
    index_kw = dict(index_kw or {})
    if parsed.base == "ivf":
        if metric != "euclidean":
            raise ValueError("IVF base supports euclidean only")
        base: VectorIndex = IVFFlatIndex(n_cells=parsed.n_cells, **index_kw)
    else:
        base = FlatIndex(metric=metric, ctx=ctx, **index_kw)
    if parsed.reducer is None:
        return base
    reducer = make_reducer(parsed.reducer, parsed.out_dim,
                           **dict(reducer_kw or {}))
    return TwoStageIndex(reducer, base, rerank_factor=parsed.rerank_factor,
                         metric=metric)
