"""FAISS-style ``index_factory``: build an index stack from a spec string.

Grammar (comma-separated stages, case-insensitive)::

    spec     := ["Mut" ","] [reducer ","] [shard ","] stack ["," rerank]
    stack    := base | quant | base "," quant
    reducer  := ("RAE" | "PCA" | "RP" | "MDS" | "ISOMAP" | "UMAP") out_dim
    shard    := "Shard" n_shards            # partition the stack N ways
    base     := "Flat" | "IVF" n_cells | "HNSW" M
    quant    := "SQ8" | "PQ" m "x" bits     # bits in 1..8; any base
    rerank   := "Rerank" factor             # requires a reducer stage

Stage semantics:

* ``Mut`` — wraps the whole stack in :class:`MutableIndex`: streaming
  ``add``/``delete`` with tombstone masks pushed down every tier, plus
  drift-triggered rebuild policy (must come first; ``"Mut,RAE64,IVF256,
  Rerank4"`` is the live-serving form of the paper stack).

* ``reducer`` — any name registered via :func:`repro.api.register_reducer`
  (third-party reducers compose for free); maps the corpus to
  R^``out_dim`` before the base index sees it.
* ``base`` — how candidates are *found*: exact scan (``Flat``), k-means
  coarse cells probed ``nprobe`` at a time (``IVF``), or hierarchical
  graph beam search (``HNSW``, degree cap ``M`` — sublinear per-query
  work).
* ``shard`` — partitions the corpus across ``n_shards`` copies of the
  storage stack (``ShardedIndex``); per-shard top-k merges through the
  deterministic scatter-gather kernel, so results are bitwise invariant
  to the shard count. ``"Shard8"`` alone shards a flat scan 8 ways.
* ``quant`` — how vectors are *stored*: f32 (absent), per-dim int8
  scalar codes (``SQ8``), or m-subspace product codes searched with ADC
  (``PQ8x8`` = 8 subspaces x 8 bits = 8 bytes/vector). Composes with
  every base: scan bases gather codes in their fused scans, and an HNSW
  base gathers codes inside the batched beam hop (``graph_beam_q`` —
  dequant-free asymmetric L2 for SQ8, a per-query ADC LUT for PQ), so
  ``"RAE64,HNSW32,SQ8,Rerank4"`` cuts traversal gather bandwidth ~4x at
  rerank-recovered recall. A quant stage with no explicit base implies
  ``Flat`` storage, so ``"SQ8"`` alone is a flat SQ8 scan. Quantized
  tiers are euclidean-only.
* ``rerank`` — re-scores ``factor * k`` stage-1 candidates with exact
  full-space distances; needs a reducer (that is what defines the "full
  space" to return to).

Examples::

    index_factory("Flat")                       # exact scan
    index_factory("IVF256")                     # coarse-quantized, raw space
    index_factory("HNSW32")                     # graph beam search, raw space
    index_factory("SQ8")                        # flat scan over int8 codes
    index_factory("RAE32,SQ8")                  # reduce, then SQ8 codes
    index_factory("IVF256,PQ8x8")               # FAISS-style IVF-PQ (ADC)
    index_factory("RAE64,IVF256,Rerank4")       # the full paper stack
    index_factory("RAE64,HNSW32,Rerank4")       # graph over reduced space
    index_factory("RAE64,HNSW32,SQ8,Rerank4")   # + SQ8 traversal payload
    index_factory("RAE64,IVF256,PQ8x8,Rerank4") # + PQ list payloads
    index_factory("RAE64,Shard8,IVF256,Rerank4")# sharded serving tier

``parse_index_spec`` exposes the parsed form for callers that need to
inspect a spec (serving flags, benchmarks) without building anything.
"""
from __future__ import annotations

import dataclasses
import re
from dataclasses import dataclass
from typing import Any, Optional

from ..models.common import NULL_CTX, MeshCtx
from .graph import HNSWIndex
from .index import FlatIndex, IVFFlatIndex, TwoStageIndex, VectorIndex
from .quantized import IVFPQIndex, IVFSQ8Index, PQIndex, SQ8Index
from .reducer import list_reducers, make_reducer
from .sharded import ShardedIndex

_TOKEN = re.compile(r"^([A-Za-z_]+?)(\d+)?$")
_PQ = re.compile(r"^pq(\d+)x(\d+)$", re.IGNORECASE)


@dataclass(frozen=True)
class IndexSpec:
    """Parsed form of a factory spec string. ``str(spec)`` renders the
    canonical spec string, so ``parse_index_spec(str(spec)) == spec``
    for every parseable spec (round-trip tested)."""

    reducer: Optional[str] = None     # registry name, e.g. "rae"
    out_dim: int = 0                  # reducer target dim
    base: str = "flat"                # "flat" | "ivf" | "hnsw"
    n_cells: int = 0                  # ivf only
    quant: Optional[str] = None       # None | "sq8" | "pq"
    pq_m: int = 0                     # pq only: subspace count
    pq_bits: int = 0                  # pq only: bits per code
    rerank_factor: int = 1
    hnsw_m: int = 0                   # hnsw only: degree cap M
    shards: int = 0                   # 0 = unsharded
    mutable: bool = False             # Mut prefix: MutableIndex wrapper

    def __str__(self) -> str:
        parts = []
        if self.mutable:
            parts.append("Mut")
        if self.reducer is not None:
            parts.append(f"{self.reducer.upper()}{self.out_dim}")
        if self.shards:
            parts.append(f"Shard{self.shards}")
        if self.base == "ivf":
            parts.append(f"IVF{self.n_cells}")
        elif self.base == "hnsw":
            parts.append(f"HNSW{self.hnsw_m}")
        else:
            parts.append("Flat")
        if self.quant == "sq8":
            parts.append("SQ8")
        elif self.quant == "pq":
            parts.append(f"PQ{self.pq_m}x{self.pq_bits}")
        if self.rerank_factor > 1:
            parts.append(f"Rerank{self.rerank_factor}")
        return ",".join(parts)


def _fail(spec: str, why: str):
    raise ValueError(f"bad index spec {spec!r}: {why}")


def parse_index_spec(spec: str) -> IndexSpec:
    tokens = [t.strip() for t in spec.split(",")]
    if not spec.strip() or any(not t for t in tokens):
        _fail(spec, "empty stage")
    reducer: Optional[str] = None
    out_dim = 0
    base: Optional[str] = None
    n_cells = 0
    quant: Optional[str] = None
    pq_m = pq_bits = 0
    rerank = 0
    hnsw_m = 0
    shards = 0
    mutable = False

    def check_order(stage):
        if rerank:
            _fail(spec, "Rerank must come last")
        if quant is not None and stage in ("base", "quant"):
            _fail(spec, "quantizer must be the last storage stage")

    for tok in tokens:
        pq = _PQ.match(tok)
        if pq:
            check_order("quant")
            m_, bits_ = int(pq.group(1)), int(pq.group(2))
            if m_ <= 0:
                _fail(spec, "PQ needs at least one subspace, e.g. PQ8x8")
            if not 1 <= bits_ <= 8:
                _fail(spec, f"PQ bits must be in 1..8, got {bits_}")
            quant, pq_m, pq_bits = "pq", m_, bits_
            continue
        m = _TOKEN.match(tok)
        if not m:
            _fail(spec, f"unparseable stage {tok!r}")
        name, num = m.group(1).lower(), m.group(2)
        if name == "sq":
            if num != "8":
                _fail(spec, f"only SQ8 is supported, got {tok!r}")
            check_order("quant")
            quant = "sq8"
        elif name == "flat":
            if num is not None:
                _fail(spec, "Flat takes no parameter")
            if base is not None:
                _fail(spec, "multiple base stages")
            check_order("base")
            base = "flat"
        elif name == "ivf":
            if num is None:
                _fail(spec, "IVF needs a cell count, e.g. IVF256")
            if base is not None:
                _fail(spec, "multiple base stages")
            check_order("base")
            base, n_cells = "ivf", int(num)
        elif name == "hnsw":
            if num is None:
                _fail(spec, "HNSW needs a degree cap, e.g. HNSW32")
            if int(num) < 2:
                _fail(spec, f"HNSW needs M >= 2, got {tok!r}")
            if base is not None:
                _fail(spec, "multiple base stages")
            check_order("base")
            base, hnsw_m = "hnsw", int(num)
        elif name == "shard":
            if num is None:
                _fail(spec, "Shard needs a shard count, e.g. Shard8")
            if int(num) < 1:
                _fail(spec, f"Shard needs at least one shard, got {tok!r}")
            if shards:
                _fail(spec, "multiple Shard stages")
            if base is not None or quant is not None:
                _fail(spec, "Shard must come before the base stage "
                            "(it partitions the storage stack)")
            check_order("base")
            shards = int(num)
        elif name == "mut":
            if num is not None:
                _fail(spec, "Mut takes no parameter")
            if mutable:
                _fail(spec, "multiple Mut stages")
            if (reducer is not None or base is not None or quant is not None
                    or shards or rerank):
                _fail(spec, "Mut must come first (it wraps the whole stack)")
            mutable = True
        elif name == "rerank":
            if num is None:
                _fail(spec, "Rerank needs a factor, e.g. Rerank4")
            if rerank:
                _fail(spec, "multiple Rerank stages")
            rerank = int(num)
        elif name in list_reducers():
            if num is None:
                _fail(spec, f"reducer {name!r} needs a target dim, "
                            f"e.g. {name.upper()}64")
            if reducer is not None:
                _fail(spec, "multiple reducer stages")
            if base is not None or quant is not None or shards:
                _fail(spec, "reducer must come before the base stage")
            reducer, out_dim = name, int(num)
        else:
            _fail(spec, f"unknown stage {tok!r} "
                        f"(reducers: {list_reducers()}; bases: flat, ivf, "
                        f"hnsw; quantizers: sq8, pq<m>x<bits>)")
    if base is None and quant is None and not shards:
        _fail(spec, "no base stage (Flat, IVF<n>, HNSW<M>, SQ8 or "
                    "PQ<m>x<bits>)")
    if rerank and reducer is None:
        _fail(spec, "Rerank requires a reducer stage to rerank against")
    if out_dim <= 0 and reducer is not None:
        _fail(spec, "reducer target dim must be positive")
    return IndexSpec(reducer=reducer, out_dim=out_dim, base=base or "flat",
                     n_cells=n_cells, quant=quant, pq_m=pq_m,
                     pq_bits=pq_bits, rerank_factor=rerank or 1,
                     hnsw_m=hnsw_m, shards=shards, mutable=mutable)


def _make_base(parsed: IndexSpec, metric: str, ctx: MeshCtx,
               index_kw: dict[str, Any]) -> VectorIndex:
    """Map (base, quant) to the index class; see the module grammar."""
    if parsed.quant is not None and metric != "euclidean":
        raise ValueError("quantized tiers support euclidean only")
    if parsed.base == "hnsw":
        if metric != "euclidean":
            raise ValueError("HNSW base supports euclidean only")
        if parsed.quant == "sq8":
            index_kw.setdefault("quant", "sq8")
        elif parsed.quant == "pq":
            index_kw.setdefault("quant", "pq")
            index_kw.setdefault("pq_m", parsed.pq_m)
            index_kw.setdefault("pq_bits", parsed.pq_bits)
        return HNSWIndex(m=parsed.hnsw_m, **index_kw)
    if parsed.base == "ivf":
        if metric != "euclidean":
            raise ValueError("IVF base supports euclidean only")
        if parsed.quant == "sq8":
            return IVFSQ8Index(n_cells=parsed.n_cells, **index_kw)
        if parsed.quant == "pq":
            return IVFPQIndex(n_cells=parsed.n_cells, m=parsed.pq_m,
                              bits=parsed.pq_bits, **index_kw)
        return IVFFlatIndex(n_cells=parsed.n_cells, **index_kw)
    if parsed.quant == "sq8":
        return SQ8Index(**index_kw)
    if parsed.quant == "pq":
        return PQIndex(m=parsed.pq_m, bits=parsed.pq_bits, **index_kw)
    return FlatIndex(metric=metric, ctx=ctx, **index_kw)


def index_factory(spec: str, *, metric: str = "euclidean",
                  ctx: MeshCtx = NULL_CTX,
                  reducer_kw: Optional[dict[str, Any]] = None,
                  index_kw: Optional[dict[str, Any]] = None) -> VectorIndex:
    """Build an (unbuilt) index stack from ``spec``.

    ``reducer_kw`` is forwarded to the reducer constructor (e.g. RAE's
    ``steps`` / ``weight_decay`` / ``mesh``); ``index_kw`` to the base index
    (e.g. IVF's ``nprobe``, PQ's ``kmeans_iters``). Call ``.build(corpus)``
    on the result.
    """
    parsed = parse_index_spec(spec)
    if parsed.shards:
        child_spec = str(dataclasses.replace(
            parsed, reducer=None, out_dim=0, shards=0, rerank_factor=1,
            mutable=False))
        # device-parallel fan-out only covers the flat f32 scan; anything
        # fancier gets independent per-shard children on the thread pool
        mesh_ok = (ctx.mesh is not None and parsed.base == "flat"
                   and parsed.quant is None)
        base: VectorIndex = ShardedIndex(
            n_shards=parsed.shards, child_spec=child_spec, metric=metric,
            ctx=ctx, workers="mesh" if mesh_ok else "threads",
            index_kw=dict(index_kw or {}))
    else:
        base = _make_base(parsed, metric, ctx, dict(index_kw or {}))
    stack: VectorIndex = base
    if parsed.reducer is not None:
        reducer = make_reducer(parsed.reducer, parsed.out_dim,
                               **dict(reducer_kw or {}))
        stack = TwoStageIndex(reducer, base,
                              rerank_factor=parsed.rerank_factor,
                              metric=metric)
    if parsed.mutable:
        from .mutable import MutableIndex  # cycle: lazy
        stack = MutableIndex(stack)
    return stack
