"""``VectorIndex``: one build/search/save/load interface for every search tier.

``FlatIndex`` wraps the exact distributed scan (``search.distributed``),
``IVFFlatIndex`` the coarse-quantized probe scan (``search.ivf``), and
``TwoStageIndex`` composes ANY :class:`~repro.api.reducer.Reducer` with ANY
base index — reduced-space candidate generation, full-space rerank (the
paper's deployment story, previously hardwired to RAE + flat scan in
``search.twostage``).

``search`` returns a uniform :class:`SearchResult` with device-synchronized
wall latency. Scores follow the engine convention: higher = closer
(negative squared euclidean / cosine similarity).

Persistence layout mirrors the reducers: ``meta.json`` + ``arrays.npz``
per directory; ``TwoStageIndex`` nests ``reducer/`` and ``base/``
subdirectories. ``load_index(dir)`` dispatches on ``meta.json["kind"]``.
"""
from __future__ import annotations

import functools
import hashlib
import json
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.common import NULL_CTX, MeshCtx
from ..search import distributed as ds
from ..search import ivf as ivf_lib
from ..search import twostage as ts_lib
from .reducer import Reducer, load_reducer

_META = "meta.json"
_ARRAYS = "arrays.npz"


#: Geometric ladder every per-call knob snaps to — each rung ~1.5x the
#: previous (8*2^i interleaved with 12*2^i). The knobs feed jit static
#: arguments (IVF ``nprobe``, HNSW ``ef``, the rerank ``k1``), so an
#: arbitrary integer per call would mint a fresh XLA compile per value;
#: snapping bounds every per-knob jit cache to at most ``len(KNOB_LADDER)``
#: entries, which is what keeps laddered serving compile-budget-zero under
#: ``analysis.runtime.no_retrace`` once each rung is warmed.
KNOB_LADDER = (8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512,
               768, 1024, 1536, 2048)


def snap_knob(value: int) -> int:
    """Round ``value`` UP to its :data:`KNOB_LADDER` rung. Rounding up
    (never down) means a snapped knob always does at least the work the
    caller asked for; values past the top rung clamp to it."""
    v = int(value)
    for rung in KNOB_LADDER:
        if rung >= v:
            return rung
    return KNOB_LADDER[-1]


def next_rung(value: int) -> int:
    """The ladder rung strictly above ``value``'s — the escalation step.
    The top rung escalates to itself (there is nowhere left to go)."""
    snapped = snap_knob(value)
    i = KNOB_LADDER.index(snapped)
    return KNOB_LADDER[min(i + 1, len(KNOB_LADDER) - 1)]


@dataclass(frozen=True)
class SearchParams:
    """Per-call search-knob overrides, threaded through every
    ``VectorIndex.search`` as ``params=``. ``None`` leaves that knob at
    the index's own default; each tier consumes the knobs it understands
    and forwards the rest down its stack (``TwoStageIndex`` applies
    ``rerank_k1`` and hands the whole object to its base; ``Sharded`` /
    ``Mutable`` forward verbatim; ``Flat`` and the flat quantized scans
    have no knobs and ignore it).

    Values are snapped UP to :data:`KNOB_LADDER` at construction, so two
    ``SearchParams`` resolving to the same operating point compare equal
    — the serving cache keys on :meth:`key` — and the jit caches stay
    bounded (see :data:`KNOB_LADDER`). ``set_params`` on an index applies
    the same knobs as its new *defaults*, moving the fingerprint (the
    knobs are fingerprint state), which is what lets the serving cache
    distinguish answers computed under different tuned points."""

    ef_search: Optional[int] = None
    nprobe: Optional[int] = None
    rerank_k1: Optional[int] = None

    def __post_init__(self):
        for name in ("ef_search", "nprobe", "rerank_k1"):
            v = getattr(self, name)
            if v is None:
                continue
            if int(v) < 1:
                raise ValueError(f"SearchParams.{name} must be >= 1, "
                                 f"got {v}")
            object.__setattr__(self, name, snap_knob(v))

    def key(self) -> tuple:
        """Hashable operating-point token (cache keys, curve JSON)."""
        return (self.ef_search, self.nprobe, self.rerank_k1)

    def merged(self, override: "SearchParams") -> "SearchParams":
        """This point with ``override``'s set knobs winning."""
        return SearchParams(
            ef_search=override.ef_search if override.ef_search is not None
            else self.ef_search,
            nprobe=override.nprobe if override.nprobe is not None
            else self.nprobe,
            rerank_k1=override.rerank_k1 if override.rerank_k1 is not None
            else self.rerank_k1)

    def escalated(self) -> "SearchParams":
        """One ladder rung up on every set knob — the pass-2 point of
        per-query adaptive escalation. Unset knobs stay unset."""
        return SearchParams(
            ef_search=None if self.ef_search is None
            else next_rung(self.ef_search),
            nprobe=None if self.nprobe is None else next_rung(self.nprobe),
            rerank_k1=None if self.rerank_k1 is None
            else next_rung(self.rerank_k1))

    def to_dict(self) -> dict[str, Optional[int]]:
        return {"ef_search": self.ef_search, "nprobe": self.nprobe,
                "rerank_k1": self.rerank_k1}

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "SearchParams":
        return cls(ef_search=d.get("ef_search"), nprobe=d.get("nprobe"),
                   rerank_k1=d.get("rerank_k1"))


@dataclass
class SearchResult:
    """Uniform k-NN result: ``scores``/``indices`` are [Q, k]; higher score
    = closer; ``latency_s`` is device-synchronized wall time of the query.

    ``stats`` carries per-query work counters; every built-in index reports
    ``distance_evals`` — the mean number of corpus vectors whose distance
    to the query was evaluated (flat scan = N, IVF = probed list sizes,
    HNSW = beam-visited count) — the sublinearity axis benchmarks report
    next to recall and QPS."""

    scores: np.ndarray
    indices: np.ndarray
    latency_s: float
    stats: dict[str, float] = field(default_factory=dict)

    @property
    def k(self) -> int:
        return self.indices.shape[1]

    @property
    def distance_evals(self) -> Optional[float]:
        """Mean distance evaluations per query (None if not reported)."""
        return self.stats.get("distance_evals")


# ---------------------------------------------------------------------------
# Registry / persistence plumbing
# ---------------------------------------------------------------------------
_INDEXES: dict[str, type] = {}


def register_index(name: str):
    def deco(cls):
        _INDEXES[name.lower()] = cls
        cls.kind = name.lower()
        return cls

    return deco


def load_index(directory: str) -> "VectorIndex":
    with open(os.path.join(directory, _META)) as f:
        meta = json.load(f)
    try:
        cls = _INDEXES[meta["kind"]]
    except KeyError:
        raise KeyError(f"unknown index kind {meta['kind']!r}; "
                       f"known: {sorted(_INDEXES)}") from None
    return cls._load(directory, meta)


def _save_dir(directory: str, meta: dict[str, Any],
              arrays: dict[str, np.ndarray]) -> None:
    os.makedirs(directory, exist_ok=True)
    with open(os.path.join(directory, _META), "w") as f:
        json.dump(meta, f, indent=1)
    np.savez(os.path.join(directory, _ARRAYS), **arrays)


def _load_arrays(directory: str) -> dict[str, np.ndarray]:
    with np.load(os.path.join(directory, _ARRAYS)) as z:
        return {k: z[k] for k in z.files}


class VectorIndex:
    """Base class: ``build(corpus)`` then ``search(queries, k)``."""

    kind: str = "abstract"

    #: When this index serves as stage 1 under a rerank (``TwoStageIndex``),
    #: fetch this multiple of the rerank budget as candidates. Lossy-ranking
    #: tiers (PQ/ADC: candidate lists are cheap, ordering is noisy) override
    #: with > 1 so the exact rerank sees past the quantization noise.
    stage1_oversample: int = 1

    @property
    def ntotal(self) -> int:
        raise NotImplementedError

    @property
    def built(self) -> bool:
        raise NotImplementedError

    @property
    def bytes_per_vector(self) -> float:
        """Per-vector payload of the stored search structure (codes +
        per-vector auxiliaries), the memory axis benchmarks report next to
        recall/QPS. Composite indexes report their stage-1 payload."""
        raise NotImplementedError

    @property
    def dim(self) -> int:
        """Query dimensionality this index accepts (the ORIGINAL space for
        composite indexes — what a client hands ``search``)."""
        raise NotImplementedError

    def _fingerprint_state(self) -> list:
        """Arrays/strings that identify the searchable content. Subclasses
        list whatever distinguishes two builds: the stored vectors, codes,
        or (for composites) the children's fingerprints."""
        raise NotImplementedError

    def fingerprint(self) -> str:
        """Stable content hash of the built index. Two indexes answering
        queries identically hash equal; rebuilding over a different corpus
        (or swapping a stage) changes it — the serving cache keys results
        on it so a hot swap can never serve stale answers."""
        self._require_built()
        h = hashlib.sha1()
        h.update(f"{self.kind}:{self.ntotal}".encode())
        for item in self._fingerprint_state():
            if isinstance(item, str):
                h.update(item.encode())
            else:
                a = np.asarray(item)
                h.update(f"{a.shape}:{a.dtype}".encode())
                h.update(a.tobytes())
        return h.hexdigest()[:16]

    def build(self, corpus: np.ndarray) -> "VectorIndex":
        raise NotImplementedError

    def search(self, queries: np.ndarray, k: int,
               alive: Optional[np.ndarray] = None,
               params: Optional[SearchParams] = None) -> SearchResult:
        """k-NN. ``alive`` (bool [ntotal], optional) tombstones rows: a
        dead row never appears in the result — not even as a pre-rerank
        candidate inside a composite — its slot padding to (-inf, -1).
        ``alive=None`` must answer bitwise identically to the tier's
        static path. Owned and threaded by :class:`MutableIndex`; static
        callers never pass it.

        ``params`` (:class:`SearchParams`, optional) overrides the tier's
        search knobs for THIS call only: each tier consumes what it
        understands (IVF ``nprobe``, HNSW ``ef_search``, TwoStage
        ``rerank_k1``), forwards the object down composite stacks, and
        ignores knobs it has none of. ``params=None`` must answer bitwise
        identically to the pre-params path."""
        raise NotImplementedError

    def set_params(self, params: SearchParams) -> None:
        """Apply ``params``'s set knobs as this index's new DEFAULTS
        (tuned operating point). Knob attributes are fingerprint state on
        every tier that implements this, so applying a tuned point moves
        the fingerprint — the serving cache can never replay an answer
        computed under different knobs. Tiers without knobs ignore it."""
        del params

    def save(self, directory: str) -> None:
        raise NotImplementedError

    def _require_built(self):
        if not self.built:
            raise RuntimeError(f"{self.kind}: search before build")


def _pad_result(v: jax.Array, i: jax.Array, k_req: int
                ) -> tuple[jax.Array, jax.Array]:
    """FAISS pad convention when fewer than k candidates exist: tail rows
    get score -inf / index -1. Shared by every tier that can come up
    short (IVF probes, quantized lists)."""
    pad = k_req - v.shape[1]
    if pad <= 0:
        return v, i
    v = jnp.concatenate([v, jnp.full((v.shape[0], pad), -jnp.inf, v.dtype)], 1)
    i = jnp.concatenate([i, jnp.full((i.shape[0], pad), -1, i.dtype)], 1)
    return v, i


def _timed(fn: Callable[[], tuple[jax.Array, jax.Array]],
           stats: Optional[dict[str, float]] = None) -> SearchResult:
    """Monotonic wall time of the query, blocking on EVERY device output —
    otherwise the clock measures dispatch, not the scan (jax is async)."""
    t0 = time.perf_counter()
    scores, idx = fn()
    jax.block_until_ready((scores, idx))
    dt = time.perf_counter() - t0
    return SearchResult(scores=np.asarray(scores), indices=np.asarray(idx),
                        latency_s=dt, stats=dict(stats or {}))


def _probed_sizes(queries: np.ndarray, centroids: np.ndarray,
                  cell_sizes: np.ndarray, nprobe: int) -> float:
    """Mean members the probe scan evaluates per query — the IVF
    ``distance_evals`` stat. Recomputes the nprobe-nearest cells on host
    (Q x C, negligible next to the scan itself) so the jitted search path
    stays untouched; the centroid scan is reported separately by callers
    as ``centroid_evals``."""
    q = np.asarray(queries, np.float32)
    c = np.asarray(centroids, np.float32)
    d2 = (np.sum(q * q, 1)[:, None] - 2.0 * q @ c.T
          + np.sum(c * c, 1)[None, :])
    p = min(nprobe, c.shape[0])
    cells = np.argpartition(d2, p - 1, axis=1)[:, :p]
    return float(cell_sizes[cells].sum(axis=1).mean())


# ---------------------------------------------------------------------------
# Flat (exact scan)
# ---------------------------------------------------------------------------
@register_index("flat")
class FlatIndex(VectorIndex):
    """Exact k-NN over the raw corpus via the sharded scan + global top-k
    merge. With a mesh in ``ctx`` the corpus row-shards over ``db_rows``."""

    _fp_exempt = {
        "ctx": "mesh/sharding topology changes where the scan runs, not "
               "what it answers",
    }

    def __init__(self, metric: str = "euclidean", ctx: MeshCtx = NULL_CTX):
        self.metric = metric
        self.ctx = ctx
        self._db: Optional[jax.Array] = None

    @property
    def ntotal(self) -> int:
        return 0 if self._db is None else int(self._db.shape[0])

    @property
    def built(self) -> bool:
        return self._db is not None

    @property
    def bytes_per_vector(self) -> float:
        self._require_built()
        return float(self._db.shape[1] * self._db.dtype.itemsize)

    @property
    def dim(self) -> int:
        self._require_built()
        return int(self._db.shape[1])

    def _fingerprint_state(self) -> list:
        return [self.metric, self._db]

    def build(self, corpus: np.ndarray) -> "FlatIndex":
        self._db = jnp.asarray(corpus, jnp.float32)
        return self

    @functools.cached_property
    def _scan(self):
        return jax.jit(
            lambda q, db, alive, k: ds.search(q, db, k, self.ctx,
                                              metric=self.metric,
                                              alive=alive),
            static_argnames=("k",))

    def add(self, vecs: np.ndarray) -> None:
        """Streaming insert: append rows to the scanned corpus. New rows
        are searchable immediately; existing rows keep their ids."""
        self._require_built()
        self._db = jnp.concatenate(
            [self._db, jnp.asarray(vecs, jnp.float32)], axis=0)

    def search(self, queries: np.ndarray, k: int,
               alive: Optional[np.ndarray] = None,
               params: Optional[SearchParams] = None) -> SearchResult:
        del params  # exact scan has no knobs: every row is always scored
        self._require_built()
        q = jnp.asarray(queries, jnp.float32)
        al = None if alive is None else jnp.asarray(np.asarray(alive, bool))
        return _timed(lambda: self._scan(q, self._db, al,
                                         k=min(k, self.ntotal)),
                      stats={"distance_evals": float(self.ntotal)})

    def save(self, directory: str) -> None:
        self._require_built()
        _save_dir(directory, {"kind": self.kind, "metric": self.metric},
                  {"db": np.asarray(self._db)})

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "FlatIndex":
        self = cls(metric=meta["metric"])
        self._db = jnp.asarray(_load_arrays(directory)["db"])
        return self


# ---------------------------------------------------------------------------
# IVF-Flat (coarse quantization)
# ---------------------------------------------------------------------------
@register_index("ivf_flat")
class IVFFlatIndex(VectorIndex):
    """k-means cells + padded-dense probe scan (``search.ivf``). Euclidean
    only (scores = negative squared distance). ``nprobe`` defaults to
    n_cells/16 (min 8): recall-friendly without scanning everything."""

    _fp_exempt = {
        "n_cells": "build-time hyperparam; materialized in the hashed "
                   "centroids/lists arrays",
        "cell_cap": "build-time hyperparam; materialized in the hashed "
                    "lists shape",
        "kmeans_iters": "build-time hyperparam; materialized in the "
                        "hashed centroids",
        "seed": "build-time hyperparam; materialized in the hashed "
                "centroids/lists",
        "_cell_sizes": "derived from _ivf.list_mask (hashed via lists); "
                       "feeds host-side stats only",
    }

    def __init__(self, n_cells: int = 256, nprobe: int = 0,
                 cell_cap: Optional[int] = None, kmeans_iters: int = 10,
                 seed: int = 0):
        self.n_cells = n_cells
        self.nprobe = nprobe or max(8, n_cells // 16)
        self.cell_cap = cell_cap
        self.kmeans_iters = kmeans_iters
        self.seed = seed
        self._ivf: Optional[ivf_lib.IVFIndex] = None
        self._cell_sizes: Optional[np.ndarray] = None  # fixed at build
        self._ntotal = 0

    @property
    def ntotal(self) -> int:
        return self._ntotal

    @property
    def built(self) -> bool:
        return self._ivf is not None

    @property
    def bytes_per_vector(self) -> float:
        """f32 list vector + int32 row id."""
        self._require_built()
        return float(self._ivf.list_vecs.shape[2] * 4 + 4)

    @property
    def dim(self) -> int:
        self._require_built()
        return int(self._ivf.centroids.shape[1])

    def _fingerprint_state(self) -> list:
        # list_vecs is what search actually scores against — centroids +
        # id lists alone could collide across corpora with equal means
        return [f"nprobe={self.nprobe}", self._ivf.centroids,
                self._ivf.lists, self._ivf.list_vecs]

    def build(self, corpus: np.ndarray) -> "IVFFlatIndex":
        corpus = jnp.asarray(corpus, jnp.float32)
        n_cells = min(self.n_cells, corpus.shape[0])
        self._ivf = ivf_lib.build(corpus, n_cells, cell_cap=self.cell_cap,
                                  kmeans_iters=self.kmeans_iters,
                                  seed=self.seed)
        self._cell_sizes = np.asarray(self._ivf.list_mask).sum(axis=1)
        self._ntotal = int(corpus.shape[0])
        return self

    def add(self, vecs: np.ndarray) -> None:
        """Streaming insert: assign each new row to its nearest centroid
        and append into that cell's padded list — the classic IVF append
        (centroids stay FIXED, so a drifting stream skews the cells;
        :meth:`cell_imbalance` exposes the skew and ``MutableIndex``
        re-clusters past its trigger). Touched cells are re-packed
        prefix-dense; list capacity grows when a cell fills."""
        self._require_built()
        nv = np.asarray(vecs, np.float32)
        cent = np.asarray(self._ivf.centroids, np.float32)
        d2 = (np.sum(nv * nv, 1)[:, None] - 2.0 * nv @ cent.T
              + np.sum(cent * cent, 1)[None, :])
        cells = np.argmin(d2, axis=1)
        lists = np.asarray(self._ivf.lists).copy()
        mask = np.asarray(self._ivf.list_mask).copy()
        lvecs = np.asarray(self._ivf.list_vecs).copy()
        need = mask.sum(axis=1)
        np.add.at(need, cells, 1)
        cap = lists.shape[1]
        new_cap = int(max(cap, need.max()))
        if new_cap > cap:
            pad = new_cap - cap
            lists = np.pad(lists, ((0, 0), (0, pad)), constant_values=-1)
            mask = np.pad(mask, ((0, 0), (0, pad)))
            lvecs = np.pad(lvecs, ((0, 0), (0, pad), (0, 0)))
        new_ids = np.arange(self._ntotal, self._ntotal + nv.shape[0],
                            dtype=lists.dtype)
        for c in np.unique(cells):
            sel = cells == c
            old = mask[c]
            ids = np.concatenate([lists[c][old], new_ids[sel]])
            vv = np.concatenate([lvecs[c][old], nv[sel]])
            lists[c] = -1
            mask[c] = False
            lvecs[c, : len(ids)] = vv
            lists[c, : len(ids)] = ids
            mask[c, : len(ids)] = True
        self._ivf = ivf_lib.IVFIndex(
            centroids=self._ivf.centroids, lists=jnp.asarray(lists),
            list_vecs=jnp.asarray(lvecs), list_mask=jnp.asarray(mask),
            spill=self._ivf.spill)
        self._cell_sizes = mask.sum(axis=1)
        self._ntotal += int(nv.shape[0])

    def cell_imbalance(self) -> float:
        """Largest cell over the mean cell size — 1.0 is perfectly
        balanced; appends against fixed centroids push it up, degrading
        probe selectivity (one probe scans the fat cell). The
        re-clustering trigger ``MutableIndex`` watches."""
        self._require_built()
        sizes = np.asarray(self._cell_sizes, np.float64)
        return float(sizes.max() / max(sizes.mean(), 1e-12))

    @functools.cached_property
    def _probe(self):
        """Jitted probe scan (static k/nprobe): one XLA call per search
        instead of an eager op-by-op trace — the q=1 serving path is
        dispatch-bound without this."""
        def fn(q, centroids, lists, list_vecs, list_mask, k, nprobe):
            idx = ivf_lib.IVFIndex(centroids=centroids, lists=lists,
                                   list_vecs=list_vecs, list_mask=list_mask,
                                   spill=0)
            return ivf_lib.search(idx, q, k, nprobe=nprobe)

        return jax.jit(fn, static_argnames=("k", "nprobe"))

    def set_params(self, params: SearchParams) -> None:
        """Adopt a tuned ``nprobe`` default. ``nprobe`` is fingerprint
        state, so the serving cache sees a new index identity."""
        if params.nprobe is not None:
            self.nprobe = params.nprobe

    def search(self, queries: np.ndarray, k: int,
               alive: Optional[np.ndarray] = None,
               params: Optional[SearchParams] = None) -> SearchResult:
        """Like FAISS, a query whose probed cells hold fewer than k members
        pads the tail with index -1 / score -inf. ``alive`` folds into the
        list mask (ids nulled too), so a tombstoned row can neither score
        nor surface — the probe scan's own signature is unchanged.

        ``params.nprobe`` overrides ``self.nprobe`` for this call; it is
        ladder-snapped (``SearchParams`` guarantees it), so repeated
        laddered calls reuse the same cached ``_probe`` jit entries —
        zero recompiles once a rung is warm."""
        self._require_built()
        q = jnp.asarray(queries, jnp.float32)
        nprobe = (self.nprobe if params is None or params.nprobe is None
                  else params.nprobe)
        nprobe = min(nprobe, int(self._ivf.centroids.shape[0]))
        k_req = min(k, self.ntotal)
        # the probe scan can surface at most nprobe * cell_cap rows
        k_eff = min(k_req, nprobe * int(self._ivf.lists.shape[1]))
        lists, mask = self._ivf.lists, self._ivf.list_mask
        if alive is not None:
            al = jnp.asarray(np.asarray(alive, bool))
            mask = mask & al[jnp.where(lists >= 0, lists, 0)]
            lists = jnp.where(mask, lists, -1)

        def run():
            v, i = self._probe(q, self._ivf.centroids, lists,
                               self._ivf.list_vecs, mask,
                               k=k_eff, nprobe=nprobe)
            return _pad_result(v, i, k_req)

        return _timed(run, stats={
            "distance_evals": _probed_sizes(queries, self._ivf.centroids,
                                            self._cell_sizes, nprobe),
            "centroid_evals": float(self._ivf.centroids.shape[0]),
        })

    def save(self, directory: str) -> None:
        self._require_built()
        meta = {"kind": self.kind, "n_cells": self.n_cells,
                "nprobe": self.nprobe, "kmeans_iters": self.kmeans_iters,
                "seed": self.seed, "ntotal": self._ntotal,
                "spill": int(self._ivf.spill)}
        _save_dir(directory, meta, {
            "centroids": np.asarray(self._ivf.centroids),
            "lists": np.asarray(self._ivf.lists),
            "list_vecs": np.asarray(self._ivf.list_vecs),
            "list_mask": np.asarray(self._ivf.list_mask),
        })

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "IVFFlatIndex":
        self = cls(n_cells=meta["n_cells"], nprobe=meta["nprobe"],
                   kmeans_iters=meta["kmeans_iters"], seed=meta["seed"])
        a = _load_arrays(directory)
        self._ivf = ivf_lib.IVFIndex(
            centroids=jnp.asarray(a["centroids"]),
            lists=jnp.asarray(a["lists"]),
            list_vecs=jnp.asarray(a["list_vecs"]),
            list_mask=jnp.asarray(a["list_mask"]),
            spill=int(meta.get("spill", 0)))
        self._cell_sizes = a["list_mask"].sum(axis=1)
        self._ntotal = int(meta["ntotal"])
        return self


# ---------------------------------------------------------------------------
# TwoStage: reducer -> base index -> full-space rerank
# ---------------------------------------------------------------------------
@register_index("two_stage")
class TwoStageIndex(VectorIndex):
    """Compose any reducer with any base index.

    ``build`` fits the reducer on the corpus (skipped if already fitted —
    pre-trained reducers plug straight in), encodes the corpus into R^m,
    and builds the base index over the REDUCED vectors. ``search`` encodes
    queries, fetches ``k * rerank_factor * base.stage1_oversample``
    candidates from the base index (quantized bases oversample: their
    candidate lists are cheap but their ordering is noisy), and reranks
    them with exact distances in the ORIGINAL space — so scores are
    full-space even when stage 1 is approximate twice over (reduced +
    IVF/PQ)."""

    def __init__(self, reducer: Reducer, base_index: VectorIndex,
                 rerank_factor: int = 4, metric: str = "euclidean",
                 rerank_k1: Optional[int] = None):
        self.reducer = reducer
        self.base = base_index
        self.rerank_factor = rerank_factor
        self.metric = metric
        # tuned absolute stage-1 budget; None = the classic
        # k * rerank_factor * stage1_oversample formula
        self.rerank_k1 = None if rerank_k1 is None else snap_knob(rerank_k1)
        self._db_full: Optional[jax.Array] = None

    @property
    def ntotal(self) -> int:
        return 0 if self._db_full is None else int(self._db_full.shape[0])

    @property
    def built(self) -> bool:
        return self._db_full is not None and self.base.built

    @property
    def bytes_per_vector(self) -> float:
        """Stage-1 payload only: the reduced/quantized structure is what
        lives on the accelerator; the full-space rerank store can stay in
        host RAM (the paper's deployment split)."""
        return self.base.bytes_per_vector

    @property
    def dim(self) -> int:
        """Queries arrive in the ORIGINAL space (the reducer encodes them)."""
        self._require_built()
        return int(self._db_full.shape[1])

    def _reducer_fingerprint(self) -> str:
        """Content hash of the query-time encoder. The reducer transforms
        every query before stage 1, so it is part of index identity:
        without it, two stacks differing only in reducer weights would
        collide in the serving cache. Reducers that implement
        ``fingerprint()`` (all built-ins) hash their fitted state;
        anything else is probed — hash its transform of a fixed input."""
        fp = getattr(self.reducer, "fingerprint", None)
        if fp is not None:
            return fp()
        probe = np.random.default_rng(0).standard_normal(
            (4, int(self._db_full.shape[1]))).astype(np.float32)
        z = np.asarray(self.reducer.transform(probe))
        return hashlib.sha1(z.tobytes()).hexdigest()[:16]

    def _fingerprint_state(self) -> list:
        return [f"rerank={self.rerank_factor}:{self.rerank_k1}:{self.metric}",
                f"reducer={self._reducer_fingerprint()}",
                self.base.fingerprint(), self._db_full]

    def build(self, corpus: np.ndarray) -> "TwoStageIndex":
        corpus = np.asarray(corpus, np.float32)
        # absent `fitted` means unknown -> fit (skipping would hand an
        # unfitted reducer to transform on the next line)
        if not getattr(self.reducer, "fitted", False):
            self.reducer.fit(corpus)
        reduced = self.reducer.transform(corpus)
        self.base.build(reduced)
        self._db_full = jnp.asarray(corpus)
        return self

    def add(self, vecs: np.ndarray) -> None:
        """Streaming insert: encode the new rows once, push them down the
        stack — incrementally when the base supports ``add`` (HNSW graph
        insert, IVF cell append, flat concat), else by rebuilding the
        base over the extended reduced corpus — and extend the full-space
        rerank store. The fitted reducer is NOT refit here: drift policy
        (when its Eq. 15 band breaks) belongs to ``MutableIndex``."""
        self._require_built()
        nv = np.asarray(vecs, np.float32)
        z = np.asarray(self.reducer.transform(nv))
        if hasattr(self.base, "add"):
            self.base.add(z)
        else:
            full = np.concatenate(
                [np.asarray(self._db_full, np.float32), nv])
            self.base.build(np.asarray(self.reducer.transform(full)))
        self._db_full = jnp.concatenate(
            [self._db_full, jnp.asarray(nv, jnp.float32)], axis=0)

    @functools.cached_property
    def _rerank(self):
        # the shared stage-2 engine (search.twostage.rerank_candidates):
        # in-jit candidate gather + exact distances, -1 pads from ANY
        # stage-1 tier (IVF probes, batched HNSW beam) pinned to -inf
        return jax.jit(
            functools.partial(ts_lib.rerank_candidates, metric=self.metric),
            static_argnames=("k",))

    def set_params(self, params: SearchParams) -> None:
        """Adopt a tuned stage-1 budget and forward the rest down the
        stack. ``rerank_k1`` is fingerprint state (as are the base's
        knobs), so a tuned point moves the composite fingerprint."""
        if params.rerank_k1 is not None:
            self.rerank_k1 = params.rerank_k1
        self.base.set_params(params)

    def search(self, queries: np.ndarray, k: int,
               alive: Optional[np.ndarray] = None,
               params: Optional[SearchParams] = None) -> SearchResult:
        self._require_built()
        t0 = time.perf_counter()
        zq = self.reducer.transform(np.asarray(queries, np.float32))
        k_eff = min(k, self.ntotal)
        # stage-1 candidate budget: an explicit (tuned / per-call) k1
        # beats the oversample formula; never below k_eff — the rerank
        # cannot return rows stage 1 did not fetch
        pk1 = (self.rerank_k1 if params is None or params.rerank_k1 is None
               else params.rerank_k1)
        if pk1 is not None:
            k1 = min(max(int(pk1), k_eff), self.ntotal)
        else:
            over = getattr(self.base, "stage1_oversample", 1)
            k1 = min(k_eff * self.rerank_factor * over, self.ntotal)
        # tombstones are enforced in stage 1: a deleted row never appears
        # even as a pre-rerank candidate, so the rerank can't resurface it
        stage1 = self.base.search(zq, k1, alive=alive, params=params)
        cand = jnp.asarray(stage1.indices)
        q = jnp.asarray(queries, jnp.float32)
        scores, idx = self._rerank(q, self._db_full, cand, k=k_eff)
        jax.block_until_ready((scores, idx))
        dt = time.perf_counter() - t0
        # total work per query: stage-1 reduced-space evals + the k1
        # full-space rerank distances
        s1_evals = stage1.stats.get("distance_evals", 0.0)
        stats = dict(stage1.stats)
        stats.update({"distance_evals": s1_evals + float(k1),
                      "stage1_distance_evals": s1_evals,
                      "rerank_evals": float(k1)})
        return SearchResult(scores=np.asarray(scores),
                            indices=np.asarray(idx), latency_s=dt,
                            stats=stats)

    def save(self, directory: str) -> None:
        self._require_built()
        _save_dir(directory, {"kind": self.kind,
                              "rerank_factor": self.rerank_factor,
                              "rerank_k1": self.rerank_k1,
                              "metric": self.metric},
                  {"db_full": np.asarray(self._db_full)})
        self.reducer.save(os.path.join(directory, "reducer"))
        self.base.save(os.path.join(directory, "base"))

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "TwoStageIndex":
        reducer = load_reducer(os.path.join(directory, "reducer"))
        base = load_index(os.path.join(directory, "base"))
        self = cls(reducer, base, rerank_factor=meta["rerank_factor"],
                   metric=meta["metric"], rerank_k1=meta.get("rerank_k1"))
        self._db_full = jnp.asarray(_load_arrays(directory)["db_full"])
        return self
