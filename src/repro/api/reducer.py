"""``Reducer``: one fit/transform/save/load interface for every DR method.

The five baselines (``core.baselines``) and the paper's RAE
(``core.trainer`` + ``core.rae``) historically exposed incompatible APIs —
dataclass ``fit/transform`` vs a raw ``TrainResult``. Here they share one
protocol and one string registry, so callers (serving, benchmarks, the
index factory) never special-case the method.

Persistence layout (one directory per reducer)::

    <dir>/meta.json     # {"kind": ..., "state"/"config": json-able fields}
    <dir>/arrays.npz    # fitted numpy state (weights, train embeddings, ...)

``load_reducer(dir)`` dispatches on ``meta.json["kind"]``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Callable, Optional, Protocol, runtime_checkable

import numpy as np

from ..core import baselines

_META = "meta.json"
_ARRAYS = "arrays.npz"


@runtime_checkable
class Reducer(Protocol):
    """Dimensionality reduction map R^n -> R^m."""

    kind: str
    out_dim: int

    @property
    def fitted(self) -> bool: ...

    def fit(self, train_x: np.ndarray) -> "Reducer": ...

    def transform(self, x: np.ndarray) -> np.ndarray: ...

    def save(self, directory: str) -> None: ...


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REDUCERS: dict[str, Callable[..., Reducer]] = {}


def register_reducer(name: str):
    """Class decorator: register under ``name`` (lowercase canonical)."""

    def deco(cls):
        _REDUCERS[name.lower()] = cls
        cls.kind = name.lower()
        return cls

    return deco


def get_reducer(name: str) -> Callable[..., Reducer]:
    try:
        return _REDUCERS[name.lower()]
    except KeyError:
        raise KeyError(
            f"unknown reducer {name!r}; known: {sorted(_REDUCERS)}") from None


def list_reducers() -> list[str]:
    return sorted(_REDUCERS)


def make_reducer(name: str, out_dim: int, **kw) -> Reducer:
    return get_reducer(name)(out_dim=out_dim, **kw)


def load_reducer(directory: str) -> Reducer:
    with open(os.path.join(directory, _META)) as f:
        meta = json.load(f)
    cls = get_reducer(meta["kind"])
    return cls._load(directory, meta)


def _save_meta(directory: str, meta: dict[str, Any]) -> None:
    os.makedirs(directory, exist_ok=True)
    with open(os.path.join(directory, _META), "w") as f:
        json.dump(meta, f, indent=1)


# ---------------------------------------------------------------------------
# Baseline adapters
# ---------------------------------------------------------------------------
class _BaselineReducer:
    """Adapter over a ``core.baselines`` dataclass. Fitted state lives in the
    wrapped dataclass; persistence splits its fields into json scalars and
    npz arrays generically, so every baseline round-trips with no per-class
    code."""

    _impl_cls: type

    def __init__(self, out_dim: int, **kw):
        self._impl = self._impl_cls(out_dim=out_dim, **kw)
        self._fitted = False

    @property
    def out_dim(self) -> int:
        return self._impl.out_dim

    @property
    def fitted(self) -> bool:
        return self._fitted

    def fit(self, train_x: np.ndarray):
        self._impl.fit(np.asarray(train_x, np.float32))
        self._fitted = True
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        if not self._fitted:
            raise RuntimeError(f"{self.kind}: transform before fit")
        return np.asarray(self._impl.transform(np.asarray(x, np.float32)))

    def fingerprint(self) -> str:
        """Content hash of the fitted map — same role as
        ``VectorIndex.fingerprint``: ``TwoStageIndex`` folds it into the
        composite hash so swapping reducer weights changes the serving
        cache key. Hashes every field of the wrapped dataclass with the
        same scalar/array split ``save`` uses."""
        if not self._fitted:
            raise RuntimeError(f"{self.kind}: fingerprint before fit")
        h = hashlib.sha1(self.kind.encode())
        for f in dataclasses.fields(self._impl):
            v = getattr(self._impl, f.name)
            h.update(f.name.encode())
            if v is None or isinstance(v, (bool, int, float, str)):
                h.update(str(v).encode())
            else:
                a = np.asarray(v)
                h.update(f"{a.shape}:{a.dtype}".encode())
                h.update(a.tobytes())
        return h.hexdigest()[:16]

    def save(self, directory: str) -> None:
        scalars: dict[str, Any] = {}
        arrays: dict[str, np.ndarray] = {}
        for f in dataclasses.fields(self._impl):
            v = getattr(self._impl, f.name)
            if isinstance(v, np.ndarray):
                arrays[f.name] = v
            elif v is None or isinstance(v, (bool, int, float, str)):
                scalars[f.name] = v
            else:  # jax arrays etc.
                arrays[f.name] = np.asarray(v)
        _save_meta(directory, {"kind": self.kind, "state": scalars,
                               "fitted": self._fitted})
        np.savez(os.path.join(directory, _ARRAYS), **arrays)

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]):
        self = cls.__new__(cls)
        state = dict(meta["state"])
        with np.load(os.path.join(directory, _ARRAYS)) as z:
            state.update({k: z[k] for k in z.files})
        self._impl = cls._impl_cls(**state)
        self._fitted = bool(meta.get("fitted", True))
        return self


@register_reducer("pca")
class PCAReducer(_BaselineReducer):
    _impl_cls = baselines.PCA


@register_reducer("rp")
class GaussianRPReducer(_BaselineReducer):
    _impl_cls = baselines.GaussianRP


@register_reducer("mds")
class MDSLinearReducer(_BaselineReducer):
    _impl_cls = baselines.MDSLinear


@register_reducer("isomap")
class IsomapReducer(_BaselineReducer):
    _impl_cls = baselines.Isomap


@register_reducer("umap")
class UMAPLiteReducer(_BaselineReducer):
    _impl_cls = baselines.UMAPLite


# ---------------------------------------------------------------------------
# RAE
# ---------------------------------------------------------------------------
@register_reducer("rae")
class RAEReducer:
    """The paper's RAE behind the same interface as the baselines.

    ``fit`` runs the full distributed trainer (mesh-aware batch sharding,
    optional fault-tolerant checkpointing via ``checkpoint_dir``);
    ``transform`` is the trained encoder f(x) = x W_e. ``in_dim`` is taken
    from the training data, so construction needs only ``out_dim`` — same
    ergonomics as PCA.
    """

    def __init__(self, out_dim: int, *, steps: int = 3000,
                 weight_decay: float = 1e-2, seed: int = 0,
                 batch_size: int = 128, lr_max: float = 1e-3,
                 lr_min: float = 1e-5, explicit_frobenius: bool = False,
                 mesh: Any = None, checkpoint_dir: Optional[str] = None,
                 log_every: int = 10 ** 9):
        self.out_dim = out_dim
        self.steps = steps
        self.weight_decay = weight_decay
        self.seed = seed
        self.batch_size = batch_size
        self.lr_max = lr_max
        self.lr_min = lr_min
        self.explicit_frobenius = explicit_frobenius
        self.mesh = mesh
        self.checkpoint_dir = checkpoint_dir
        self.log_every = log_every
        self.params_: Optional[dict] = None
        self.cfg_ = None
        self.history_: list[dict[str, float]] = []

    @property
    def fitted(self) -> bool:
        return self.params_ is not None

    def _make_cfg(self, in_dim: int):
        from ..configs import RAEConfig

        return RAEConfig(in_dim=in_dim, out_dim=self.out_dim,
                         steps=self.steps, weight_decay=self.weight_decay,
                         seed=self.seed, batch_size=self.batch_size,
                         lr_max=self.lr_max, lr_min=self.lr_min,
                         explicit_frobenius=self.explicit_frobenius)

    def fit(self, train_x: np.ndarray) -> "RAEReducer":
        from ..core import trainer

        train_x = np.asarray(train_x, np.float32)
        self.cfg_ = self._make_cfg(train_x.shape[1])
        ckpt = None
        if self.checkpoint_dir is not None:
            from ..distributed.checkpoint import CheckpointManager

            ckpt = CheckpointManager(self.checkpoint_dir)
        res = trainer.train(self.cfg_, train_x, mesh=self.mesh,
                            log_every=self.log_every,
                            checkpoint_manager=ckpt)
        if ckpt is not None:
            ckpt.wait()
        self.params_ = res.params
        self.history_ = res.history
        return self

    def transform(self, x: np.ndarray) -> np.ndarray:
        if self.params_ is None:
            raise RuntimeError("rae: transform before fit")
        import jax.numpy as jnp

        from ..core import rae

        return np.asarray(rae.encode(self.params_,
                                     jnp.asarray(x, jnp.float32)))

    def fingerprint(self) -> str:
        """Content hash of the trained encoder (config + weights)."""
        if self.params_ is None:
            raise RuntimeError("rae: fingerprint before fit")
        h = hashlib.sha1(self.kind.encode())
        if self.cfg_ is not None:
            h.update(json.dumps(dataclasses.asdict(self.cfg_),
                                sort_keys=True).encode())
        for k in sorted(self.params_):
            a = np.asarray(self.params_[k])
            h.update(f"{k}:{a.shape}:{a.dtype}".encode())
            h.update(a.tobytes())
        return h.hexdigest()[:16]

    def save(self, directory: str) -> None:
        if self.params_ is None:
            raise RuntimeError("rae: save before fit")
        cfg = dataclasses.asdict(self.cfg_)
        _save_meta(directory, {"kind": self.kind, "config": cfg,
                               "history_tail": self.history_[-1:]})
        np.savez(os.path.join(directory, _ARRAYS),
                 **{k: np.asarray(v) for k, v in self.params_.items()})

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "RAEReducer":
        import jax.numpy as jnp

        from ..configs import RAEConfig

        cfg = RAEConfig(**meta["config"])
        self = cls(out_dim=cfg.out_dim, steps=cfg.steps,
                   weight_decay=cfg.weight_decay, seed=cfg.seed,
                   batch_size=cfg.batch_size, lr_max=cfg.lr_max,
                   lr_min=cfg.lr_min,
                   explicit_frobenius=cfg.explicit_frobenius)
        self.cfg_ = cfg
        with np.load(os.path.join(directory, _ARRAYS)) as z:
            self.params_ = {k: jnp.asarray(z[k]) for k in z.files}
        self.history_ = list(meta.get("history_tail", []))
        return self
