"""Graph ``VectorIndex`` tier: HNSW beam search behind the factory.

The first index family where per-query work is *sublinear in N* — beam
search visits a few hundred nodes of a 20k corpus instead of scanning all
of it (``SearchResult.stats["distance_evals"]`` reports the visited
count). The engine lives in :mod:`repro.search.hnsw`; this class adapts it
to the ``build / search / save / load`` protocol and the factory grammar::

    index_factory("HNSW32")                  # graph over the raw space
    index_factory("RAE64,HNSW32,Rerank4")    # graph over the reduced space,
                                             # exact full-space rerank

``M`` (the factory numeral) caps per-node degree — ``M`` on upper layers,
``2M`` at layer 0; ``ef_construction`` is the insert-time beam width
(recall of the *graph*), ``ef_search`` the query-time beam width (the
recall/latency knob — search always uses ``max(ef_search, k)``).

Two traversal engines serve queries (same semantics, same ``ef``):
``build``/``load`` compile the packed dense adjacency
(:meth:`HNSWGraph.pack`), and ``search`` routes batches (q > 1) through
the array-native batched frontier loop — one fused ``graph_beam`` dispatch
per hop for the WHOLE batch — while lone queries (q = 1) keep the
sequential heapq beam, which wins when there is no batch to amortize
across. ``batched=True/False`` pins either engine. Within the batched
engine answers are bitwise-deterministic and independent of batch-mates;
ACROSS the two engines neighbor sets agree up to beam-boundary ties
(exactly at ``frontier=1``; >= 99% of queries at the serving default,
asserted in tests) and scores differ only in rounding — so a query served
lone vs coalesced can, rarely, swap its boundary neighbor. Under the
deployment ``Rerank`` stack the exact full-space rerank absorbs exactly
that noise; pin ``batched`` if strict cross-batch-size reproducibility
matters more than lone-query latency. The packed
arrays are persisted and fingerprinted, so a reloaded index serves the
fast path without repacking and the serving cache can never alias the two
forms.

Under a rerank the graph declares ``stage1_oversample=2``: beam search
returns exact reduced-space distances but can *miss* neighbors near the
beam boundary, so ``TwoStageIndex`` widens k1 (which also widens the beam)
and lets the full-space rerank absorb the ordering noise.

**Quantized payloads** (``quant="sq8"`` / ``"pq"``; the factory's
``"RAE64,HNSW32,SQ8,Rerank4"``): the graph is built in f32 as usual, then
a code payload (:func:`repro.search.hnsw.make_graph_codes`) is trained
over the same corpus and attached, and every batched hop gathers *codes*
instead of f32 rows — 68 bytes per gathered neighbor for SQ8 at d=64, 12
for PQ8x8, versus 260 for the f32 row+norm (the
``stats["gather_bytes_per_hop"]`` metric the benches gate). Quantized
scores are approximations, so a quantized index inherits its codec's
oversample (2 for SQ8, 8 for PQ) and leans on the ``Rerank`` stage to
recover exact ordering. All queries — including q=1 — are pinned to the
batched engine: the sequential heapq beam scores f32 and would answer
differently, which the serving cache's row-independence contract forbids.
The codec state is fingerprinted (a quantized graph can never alias its
f32 twin in the serve cache) and persisted, so a reloaded index serves
codes without re-training.

Persistence follows the house layout: ``meta.json`` + ``arrays.npz``
holding the corpus vectors, per-node levels, the padded-dense adjacency of
every layer, the packed form's precomputed norms, and the code payload.
"""
from __future__ import annotations

import time
from typing import Any, Optional, Union

import numpy as np

from ..search import hnsw as hnsw_lib
from .index import (SearchParams, SearchResult, VectorIndex, _load_arrays,
                    _save_dir, register_index)


@register_index("hnsw")
class HNSWIndex(VectorIndex):
    """Hierarchical navigable small-world graph (euclidean only)."""

    stage1_oversample = 2

    _fp_exempt = {
        "m": "build-time degree cap; materialized in the hashed "
             "links0/links shapes",
        "ef_construction": "insert-time beam width; materialized in the "
                           "hashed adjacency",
        "seed": "build-time level draw; materialized in the hashed "
                "levels/adjacency",
        "pq_m": "codec train knob; materialized in the hashed codebook "
                "and code-payload shapes",
        "pq_bits": "codec train knob; materialized in the hashed "
                   "codebook width",
        "kmeans_iters": "codec train knob; materialized in the hashed "
                        "codebooks",
        "stage1_oversample": "stage-composition hint derived from quant "
                             "(hashed); not traversal state",
    }

    def __init__(self, m: int = 32, ef_construction: int = 100,
                 ef_search: int = 64, seed: int = 0,
                 batched: Union[str, bool] = "auto", frontier: int = 8,
                 quant: Optional[str] = None, pq_m: int = 8,
                 pq_bits: int = 8, kmeans_iters: int = 15):
        if m < 2:
            raise ValueError(f"HNSW needs M >= 2, got {m}")
        if batched not in ("auto", True, False):
            raise ValueError(f"batched must be 'auto', True or False, "
                             f"got {batched!r}")
        if frontier < 1:
            raise ValueError(f"frontier must be >= 1, got {frontier}")
        if quant not in (None, "sq8", "pq"):
            raise ValueError(f"quant must be None, 'sq8' or 'pq', "
                             f"got {quant!r}")
        if quant == "pq":
            if pq_m < 1:
                raise ValueError(f"PQ needs at least one subspace, "
                                 f"got pq_m={pq_m}")
            if not 1 <= pq_bits <= 8:
                raise ValueError(f"PQ bits must be in 1..8, got {pq_bits}")
            # approximate ADC hops miss more boundary neighbors than SQ8;
            # inherit the PQ codec's wider oversample so the Rerank stage
            # sees enough candidates (instance override — the class attr
            # stays 2 for f32/SQ8 graphs)
            self.stage1_oversample = 8
        self.m = m
        self.ef_construction = ef_construction
        self.ef_search = ef_search
        self.seed = seed
        self.batched = batched
        self.frontier = frontier
        self.quant = quant
        self.pq_m = pq_m
        self.pq_bits = pq_bits
        self.kmeans_iters = kmeans_iters
        self._g: Optional[hnsw_lib.HNSWGraph] = None

    @property
    def ntotal(self) -> int:
        return 0 if self._g is None else self._g.ntotal

    @property
    def built(self) -> bool:
        return self._g is not None

    @property
    def bytes_per_vector(self) -> float:
        """f32 vector + int32 link slots in every layer the node occupies
        (2M at layer 0, M per upper layer — averaged over the geometric
        level distribution) + int32 level; a quantized payload adds its
        per-node code row + f32 bias on top (the f32 vectors stay — they
        serve build, the sequential engine, and connectivity repair; the
        payload shrinks what the *hop gather* streams, not total RAM)."""
        self._require_built()
        g = self._g
        upper_slots = g.M * float(g.levels.mean())
        codec = 0.0 if g.codec is None else float(g.codec.gather_bytes)
        return float(g.vecs.shape[1] * 4
                     + 4 * (g.links0.shape[1] + upper_slots) + 4 + codec)

    @property
    def dim(self) -> int:
        self._require_built()
        return int(self._g.vecs.shape[1])

    def _fingerprint_state(self) -> list:
        # full traversal state: vectors, EVERY layer's adjacency, levels,
        # entry (upper layers steer the layer-0 beam entry, so two graphs
        # differing only above layer 0 answer differently); ef_search and
        # the engine routing are query-time knobs that change answers
        # (batched scores round differently), so they are part of
        # identity. This also covers the packed form without touching it:
        # its tables share links0/links' bytes and its norms derive from
        # vecs (all hashed here), while the batched/frontier flags make an
        # index serving the packed fast path never alias one pinned to
        # the ragged sequential engine — and packing later (load, save)
        # can't shift the hash.
        g = self._g
        state = [f"ef={self.ef_search}:entry={g.entry}"
                 f":batched={self.batched}:frontier={self.frontier}"
                 f":quant={self.quant}",
                 g.vecs, g.links0, g.links, g.levels]
        if g.codec is not None:
            # codec state is identity: two graphs differing only in their
            # code payload answer differently, so the serve cache must
            # never alias them (nor a quantized graph with its f32 twin)
            c = g.codec
            state += [c.codes, c.node_bias]
            state += [a for a in (c.vmin, c.step, c.codebooks)
                      if a is not None]
        return state

    def build(self, corpus: np.ndarray) -> "HNSWIndex":
        self._g = hnsw_lib.build(corpus, M=self.m,
                                 ef_construction=self.ef_construction,
                                 seed=self.seed)
        if self.quant is not None:
            # graph construction stays f32 (insertion quality); the code
            # payload is trained over the same corpus and swaps what the
            # batched hop gather reads. Raises at build for impossible
            # codecs (e.g. PQ with d % m != 0) — never a broken index.
            self._g.codec = hnsw_lib.make_graph_codes(
                self._g.vecs, self.quant, m=self.pq_m, bits=self.pq_bits,
                iters=self.kmeans_iters, seed=self.seed)
        if self.batched is not False or self.quant is not None:
            self._g.pack()  # compile the dense form once, at build time
        return self

    def _use_batched(self, nq: int) -> bool:
        if self.quant is not None:
            # code payloads only exist on the batched path; routing q=1 to
            # the f32 heapq beam would answer differently lone vs
            # coalesced, which the serving cache contract forbids
            return True
        if self.batched == "auto":
            # the batched frontier loop amortizes per-hop work across the
            # batch; with nothing to amortize (q=1) the heapq beam wins
            return nq > 1
        return bool(self.batched)

    def add(self, vecs: np.ndarray) -> np.ndarray:
        """Incremental insert: run HNSW Alg. 1 for each new row against the
        live graph (same code path as ``build``, so insert order — build
        then add — is the only divergence from a from-scratch build), extend
        the code payload with the already-trained codec, and re-pack so the
        batched drivers see the new rows. Returns the new row ids."""
        self._require_built()
        nv = np.asarray(vecs, np.float32)
        ids = hnsw_lib.insert_batch(self._g, nv,
                                    ef_construction=self.ef_construction,
                                    seed=self.seed)
        if self.batched is not False or self.quant is not None:
            self._g.pack()  # re-pack eagerly: serving must never stall
        return ids

    def set_params(self, params: SearchParams) -> None:
        """Adopt a tuned ``ef_search`` default. ``ef_search`` is
        fingerprint state, so the serving cache sees a new identity."""
        if params.ef_search is not None:
            self.ef_search = params.ef_search

    def search(self, queries: np.ndarray, k: int,
               alive: Optional[np.ndarray] = None,
               params: Optional[SearchParams] = None) -> SearchResult:
        """Beam search with ef = max(ef_search, k). Queries whose beam
        holds fewer than k nodes pad the tail with index -1 / score -inf
        (FAISS convention, same as the IVF tiers). ``alive`` (bool
        [ntotal]) tombstones rows out of BOTH engines — a dead node never
        enters a beam; the entry point must be alive (callers that delete
        it reassign via :func:`repro.search.hnsw.reassign_entry`, which
        ``MutableIndex.delete`` does automatically).

        ``params.ef_search`` overrides ``self.ef_search`` for this call;
        ladder-snapped values keep the ef-dependent trace set bounded, so
        laddered calls stay compile-budget-zero once warm."""
        self._require_built()
        q = np.asarray(queries, np.float32)
        k_req = min(k, self.ntotal)
        ef_base = (self.ef_search if params is None or params.ef_search is None
                   else params.ef_search)
        ef = max(ef_base, k_req)
        t0 = time.perf_counter()
        if self._use_batched(q.shape[0]):
            scores, idx, evals, hops = hnsw_lib.search_batched(
                self._g, q, k_req, ef_search=ef, frontier=self.frontier,
                alive=alive)
            g = self._g
            row_bytes = (g.codec.gather_bytes if g.codec is not None
                         else 4 * g.vecs.shape[1] + 4)
            stats = {"distance_evals": float(evals.mean()),
                     "beam_hops": float(hops),
                     # payload bytes the traversal streamed per fused hop
                     # (each eval gathers one row: codes+bias when
                     # quantized, f32 row+norm otherwise) — the bandwidth
                     # axis the graph bench gates
                     "gather_bytes_per_hop":
                         float(evals.sum() * row_bytes) / max(hops, 1)}
        else:
            scores, idx, evals = hnsw_lib.search(self._g, q, k_req,
                                                 ef_search=ef, alive=alive)
            stats = {"distance_evals": float(evals.mean())}
        dt = time.perf_counter() - t0
        return SearchResult(scores=scores, indices=idx, latency_s=dt,
                            stats=stats)

    def save(self, directory: str) -> None:
        self._require_built()
        g = self._g
        p = g.pack()  # always persist the packed form alongside the graph
        arrays = {"vecs": g.vecs, "levels": g.levels,
                  "links0": g.links0, "links": g.links,
                  "packed_vecs_sq": p.vecs_sq}
        if g.codec is not None:
            # trained codec state rides along so a reloaded index serves
            # codes without re-training (k-means is seed-stable but slow)
            arrays["codec_codes"] = g.codec.codes
            arrays["codec_node_bias"] = g.codec.node_bias
            if g.codec.kind == "sq8":
                arrays["codec_vmin"] = g.codec.vmin
                arrays["codec_step"] = g.codec.step
            else:
                arrays["codec_codebooks"] = g.codec.codebooks
        _save_dir(directory,
                  {"kind": self.kind, "m": self.m,
                   "ef_construction": self.ef_construction,
                   "ef_search": self.ef_search, "seed": self.seed,
                   "entry": int(g.entry), "packed": True,
                   "batched": self.batched, "frontier": self.frontier,
                   "quant": self.quant, "pq_m": self.pq_m,
                   "pq_bits": self.pq_bits,
                   "kmeans_iters": self.kmeans_iters},
                  # the packed adjacency is byte-identical to links0/links
                  # (pack() only makes them contiguous), so persisting it
                  # "alongside" means sharing their bytes: only the
                  # packed-exclusive norms (and codec) are written extra
                  arrays)

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "HNSWIndex":
        self = cls(m=meta["m"], ef_construction=meta["ef_construction"],
                   ef_search=meta["ef_search"], seed=meta["seed"],
                   batched=meta.get("batched", "auto"),
                   frontier=int(meta.get("frontier", 8)),
                   quant=meta.get("quant"),
                   pq_m=int(meta.get("pq_m", 8)),
                   pq_bits=int(meta.get("pq_bits", 8)),
                   kmeans_iters=int(meta.get("kmeans_iters", 15)))
        a = _load_arrays(directory)
        links = a["links"]
        if links.size == 0:  # single-layer graph round-trips as [0, N, M]
            links = links.reshape(0, a["vecs"].shape[0], meta["m"])
        self._g = hnsw_lib.HNSWGraph(
            vecs=a["vecs"], levels=a["levels"], links0=a["links0"],
            links=links, entry=int(meta["entry"]), M=int(meta["m"]))
        if "packed_vecs_sq" in a:  # pre-PR-5 saves: pack() on first batch
            # zero repack work: npz loads are C-contiguous, so the packed
            # tables ARE the loaded adjacency; only the norms come from
            # the file
            self._g.packed = hnsw_lib.PackedHNSW(
                nbrs0=self._g.links0, upper=self._g.links,
                vecs_sq=a["packed_vecs_sq"])
        if self.quant is not None:
            self._g.codec = hnsw_lib.GraphCodes(
                kind=self.quant, codes=a["codec_codes"],
                node_bias=a["codec_node_bias"],
                vmin=a.get("codec_vmin"), step=a.get("codec_step"),
                codebooks=a.get("codec_codebooks"))
        return self
