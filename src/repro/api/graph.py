"""Graph ``VectorIndex`` tier: HNSW beam search behind the factory.

The first index family where per-query work is *sublinear in N* — beam
search visits a few hundred nodes of a 20k corpus instead of scanning all
of it (``SearchResult.stats["distance_evals"]`` reports the visited
count). The engine lives in :mod:`repro.search.hnsw`; this class adapts it
to the ``build / search / save / load`` protocol and the factory grammar::

    index_factory("HNSW32")                  # graph over the raw space
    index_factory("RAE64,HNSW32,Rerank4")    # graph over the reduced space,
                                             # exact full-space rerank

``M`` (the factory numeral) caps per-node degree — ``M`` on upper layers,
``2M`` at layer 0; ``ef_construction`` is the insert-time beam width
(recall of the *graph*), ``ef_search`` the query-time beam width (the
recall/latency knob — search always uses ``max(ef_search, k)``).

Two traversal engines serve queries (same semantics, same ``ef``):
``build``/``load`` compile the packed dense adjacency
(:meth:`HNSWGraph.pack`), and ``search`` routes batches (q > 1) through
the array-native batched frontier loop — one fused ``graph_beam`` dispatch
per hop for the WHOLE batch — while lone queries (q = 1) keep the
sequential heapq beam, which wins when there is no batch to amortize
across. ``batched=True/False`` pins either engine. Within the batched
engine answers are bitwise-deterministic and independent of batch-mates;
ACROSS the two engines neighbor sets agree up to beam-boundary ties
(exactly at ``frontier=1``; >= 99% of queries at the serving default,
asserted in tests) and scores differ only in rounding — so a query served
lone vs coalesced can, rarely, swap its boundary neighbor. Under the
deployment ``Rerank`` stack the exact full-space rerank absorbs exactly
that noise; pin ``batched`` if strict cross-batch-size reproducibility
matters more than lone-query latency. The packed
arrays are persisted and fingerprinted, so a reloaded index serves the
fast path without repacking and the serving cache can never alias the two
forms.

Under a rerank the graph declares ``stage1_oversample=2``: beam search
returns exact reduced-space distances but can *miss* neighbors near the
beam boundary, so ``TwoStageIndex`` widens k1 (which also widens the beam)
and lets the full-space rerank absorb the ordering noise.

Persistence follows the house layout: ``meta.json`` + ``arrays.npz``
holding the corpus vectors, per-node levels, the padded-dense adjacency of
every layer, and the packed form's precomputed norms.
"""
from __future__ import annotations

import time
from typing import Any, Optional, Union

import numpy as np

from ..search import hnsw as hnsw_lib
from .index import (SearchResult, VectorIndex, _load_arrays, _save_dir,
                    register_index)


@register_index("hnsw")
class HNSWIndex(VectorIndex):
    """Hierarchical navigable small-world graph (euclidean only)."""

    stage1_oversample = 2

    _fp_exempt = {
        "m": "build-time degree cap; materialized in the hashed "
             "links0/links shapes",
        "ef_construction": "insert-time beam width; materialized in the "
                           "hashed adjacency",
        "seed": "build-time level draw; materialized in the hashed "
                "levels/adjacency",
    }

    def __init__(self, m: int = 32, ef_construction: int = 100,
                 ef_search: int = 64, seed: int = 0,
                 batched: Union[str, bool] = "auto", frontier: int = 8):
        if m < 2:
            raise ValueError(f"HNSW needs M >= 2, got {m}")
        if batched not in ("auto", True, False):
            raise ValueError(f"batched must be 'auto', True or False, "
                             f"got {batched!r}")
        if frontier < 1:
            raise ValueError(f"frontier must be >= 1, got {frontier}")
        self.m = m
        self.ef_construction = ef_construction
        self.ef_search = ef_search
        self.seed = seed
        self.batched = batched
        self.frontier = frontier
        self._g: Optional[hnsw_lib.HNSWGraph] = None

    @property
    def ntotal(self) -> int:
        return 0 if self._g is None else self._g.ntotal

    @property
    def built(self) -> bool:
        return self._g is not None

    @property
    def bytes_per_vector(self) -> float:
        """f32 vector + int32 link slots in every layer the node occupies
        (2M at layer 0, M per upper layer — averaged over the geometric
        level distribution) + int32 level."""
        self._require_built()
        g = self._g
        upper_slots = g.M * float(g.levels.mean())
        return float(g.vecs.shape[1] * 4
                     + 4 * (g.links0.shape[1] + upper_slots) + 4)

    @property
    def dim(self) -> int:
        self._require_built()
        return int(self._g.vecs.shape[1])

    def _fingerprint_state(self) -> list:
        # full traversal state: vectors, EVERY layer's adjacency, levels,
        # entry (upper layers steer the layer-0 beam entry, so two graphs
        # differing only above layer 0 answer differently); ef_search and
        # the engine routing are query-time knobs that change answers
        # (batched scores round differently), so they are part of
        # identity. This also covers the packed form without touching it:
        # its tables share links0/links' bytes and its norms derive from
        # vecs (all hashed here), while the batched/frontier flags make an
        # index serving the packed fast path never alias one pinned to
        # the ragged sequential engine — and packing later (load, save)
        # can't shift the hash.
        g = self._g
        return [f"ef={self.ef_search}:entry={g.entry}"
                f":batched={self.batched}:frontier={self.frontier}",
                g.vecs, g.links0, g.links, g.levels]

    def build(self, corpus: np.ndarray) -> "HNSWIndex":
        self._g = hnsw_lib.build(corpus, M=self.m,
                                 ef_construction=self.ef_construction,
                                 seed=self.seed)
        if self.batched is not False:
            self._g.pack()  # compile the dense form once, at build time
        return self

    def _use_batched(self, nq: int) -> bool:
        if self.batched == "auto":
            # the batched frontier loop amortizes per-hop work across the
            # batch; with nothing to amortize (q=1) the heapq beam wins
            return nq > 1
        return bool(self.batched)

    def search(self, queries: np.ndarray, k: int) -> SearchResult:
        """Beam search with ef = max(ef_search, k). Queries whose beam
        holds fewer than k nodes pad the tail with index -1 / score -inf
        (FAISS convention, same as the IVF tiers)."""
        self._require_built()
        q = np.asarray(queries, np.float32)
        k_req = min(k, self.ntotal)
        ef = max(self.ef_search, k_req)
        t0 = time.perf_counter()
        if self._use_batched(q.shape[0]):
            scores, idx, evals, hops = hnsw_lib.search_batched(
                self._g, q, k_req, ef_search=ef, frontier=self.frontier)
            stats = {"distance_evals": float(evals.mean()),
                     "beam_hops": float(hops)}
        else:
            scores, idx, evals = hnsw_lib.search(self._g, q, k_req,
                                                 ef_search=ef)
            stats = {"distance_evals": float(evals.mean())}
        dt = time.perf_counter() - t0
        return SearchResult(scores=scores, indices=idx, latency_s=dt,
                            stats=stats)

    def save(self, directory: str) -> None:
        self._require_built()
        g = self._g
        p = g.pack()  # always persist the packed form alongside the graph
        _save_dir(directory,
                  {"kind": self.kind, "m": self.m,
                   "ef_construction": self.ef_construction,
                   "ef_search": self.ef_search, "seed": self.seed,
                   "entry": int(g.entry), "packed": True,
                   "batched": self.batched, "frontier": self.frontier},
                  # the packed adjacency is byte-identical to links0/links
                  # (pack() only makes them contiguous), so persisting it
                  # "alongside" means sharing their bytes: only the
                  # packed-exclusive norms are written in addition
                  {"vecs": g.vecs, "levels": g.levels,
                   "links0": g.links0, "links": g.links,
                   "packed_vecs_sq": p.vecs_sq})

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "HNSWIndex":
        self = cls(m=meta["m"], ef_construction=meta["ef_construction"],
                   ef_search=meta["ef_search"], seed=meta["seed"],
                   batched=meta.get("batched", "auto"),
                   frontier=int(meta.get("frontier", 8)))
        a = _load_arrays(directory)
        links = a["links"]
        if links.size == 0:  # single-layer graph round-trips as [0, N, M]
            links = links.reshape(0, a["vecs"].shape[0], meta["m"])
        self._g = hnsw_lib.HNSWGraph(
            vecs=a["vecs"], levels=a["levels"], links0=a["links0"],
            links=links, entry=int(meta["entry"]), M=int(meta["m"]))
        if "packed_vecs_sq" in a:  # pre-PR-5 saves: pack() on first batch
            # zero repack work: npz loads are C-contiguous, so the packed
            # tables ARE the loaded adjacency; only the norms come from
            # the file
            self._g.packed = hnsw_lib.PackedHNSW(
                nbrs0=self._g.links0, upper=self._g.links,
                vecs_sq=a["packed_vecs_sq"])
        return self
