"""Graph ``VectorIndex`` tier: HNSW beam search behind the factory.

The first index family where per-query work is *sublinear in N* — beam
search visits a few hundred nodes of a 20k corpus instead of scanning all
of it (``SearchResult.stats["distance_evals"]`` reports the visited
count). The engine lives in :mod:`repro.search.hnsw`; this class adapts it
to the ``build / search / save / load`` protocol and the factory grammar::

    index_factory("HNSW32")                  # graph over the raw space
    index_factory("RAE64,HNSW32,Rerank4")    # graph over the reduced space,
                                             # exact full-space rerank

``M`` (the factory numeral) caps per-node degree — ``M`` on upper layers,
``2M`` at layer 0; ``ef_construction`` is the insert-time beam width
(recall of the *graph*), ``ef_search`` the query-time beam width (the
recall/latency knob — search always uses ``max(ef_search, k)``).

Under a rerank the graph declares ``stage1_oversample=2``: beam search
returns exact reduced-space distances but can *miss* neighbors near the
beam boundary, so ``TwoStageIndex`` widens k1 (which also widens the beam)
and lets the full-space rerank absorb the ordering noise.

Persistence follows the house layout: ``meta.json`` + ``arrays.npz``
holding the corpus vectors, per-node levels, and the padded-dense
adjacency of every layer.
"""
from __future__ import annotations

import time
from typing import Any, Optional

import numpy as np

from ..search import hnsw as hnsw_lib
from .index import (SearchResult, VectorIndex, _load_arrays, _save_dir,
                    register_index)


@register_index("hnsw")
class HNSWIndex(VectorIndex):
    """Hierarchical navigable small-world graph (euclidean only)."""

    stage1_oversample = 2

    def __init__(self, m: int = 32, ef_construction: int = 100,
                 ef_search: int = 64, seed: int = 0):
        if m < 2:
            raise ValueError(f"HNSW needs M >= 2, got {m}")
        self.m = m
        self.ef_construction = ef_construction
        self.ef_search = ef_search
        self.seed = seed
        self._g: Optional[hnsw_lib.HNSWGraph] = None

    @property
    def ntotal(self) -> int:
        return 0 if self._g is None else self._g.ntotal

    @property
    def built(self) -> bool:
        return self._g is not None

    @property
    def bytes_per_vector(self) -> float:
        """f32 vector + int32 link slots in every layer the node occupies
        (2M at layer 0, M per upper layer — averaged over the geometric
        level distribution) + int32 level."""
        self._require_built()
        g = self._g
        upper_slots = g.M * float(g.levels.mean())
        return float(g.vecs.shape[1] * 4
                     + 4 * (g.links0.shape[1] + upper_slots) + 4)

    @property
    def dim(self) -> int:
        self._require_built()
        return int(self._g.vecs.shape[1])

    def _fingerprint_state(self) -> list:
        # full traversal state: vectors, EVERY layer's adjacency, levels,
        # entry (upper layers steer the layer-0 beam entry, so two graphs
        # differing only above layer 0 answer differently); ef_search is a
        # query-time knob that changes answers, so it is part of identity
        g = self._g
        return [f"ef={self.ef_search}:entry={g.entry}", g.vecs, g.links0,
                g.links, g.levels]

    def build(self, corpus: np.ndarray) -> "HNSWIndex":
        self._g = hnsw_lib.build(corpus, M=self.m,
                                 ef_construction=self.ef_construction,
                                 seed=self.seed)
        return self

    def search(self, queries: np.ndarray, k: int) -> SearchResult:
        """Beam search with ef = max(ef_search, k). Queries whose beam
        holds fewer than k nodes pad the tail with index -1 / score -inf
        (FAISS convention, same as the IVF tiers)."""
        self._require_built()
        k_req = min(k, self.ntotal)
        t0 = time.perf_counter()
        scores, idx, evals = hnsw_lib.search(
            self._g, queries, k_req, ef_search=max(self.ef_search, k_req))
        dt = time.perf_counter() - t0
        return SearchResult(scores=scores, indices=idx, latency_s=dt,
                            stats={"distance_evals": float(evals.mean())})

    def save(self, directory: str) -> None:
        self._require_built()
        g = self._g
        _save_dir(directory,
                  {"kind": self.kind, "m": self.m,
                   "ef_construction": self.ef_construction,
                   "ef_search": self.ef_search, "seed": self.seed,
                   "entry": int(g.entry)},
                  {"vecs": g.vecs, "levels": g.levels,
                   "links0": g.links0, "links": g.links})

    @classmethod
    def _load(cls, directory: str, meta: dict[str, Any]) -> "HNSWIndex":
        self = cls(m=meta["m"], ef_construction=meta["ef_construction"],
                   ef_search=meta["ef_search"], seed=meta["seed"])
        a = _load_arrays(directory)
        links = a["links"]
        if links.size == 0:  # single-layer graph round-trips as [0, N, M]
            links = links.reshape(0, a["vecs"].shape[0], meta["m"])
        self._g = hnsw_lib.HNSWGraph(
            vecs=a["vecs"], levels=a["levels"], links0=a["links0"],
            links=links, entry=int(meta["entry"]), M=int(meta["m"]))
        return self
