"""Unified retrieval API: ``Reducer`` + ``VectorIndex`` (FAISS-style).

One stable surface over the paper's pipeline ("train an RAE, then search
the reduced space") and every baseline/search tier the repo grew around it:

* :class:`Reducer` — ``fit / transform / save / load`` with a string
  registry (``pca``, ``rp``, ``mds``, ``isomap``, ``umap``, ``rae``). RAE is
  a drop-in peer of the baselines for the first time.
* :class:`VectorIndex` — ``build / search / save / load`` returning a
  uniform :class:`SearchResult`; ``FlatIndex`` (exact distributed scan),
  ``IVFFlatIndex`` (coarse-quantized), ``HNSWIndex`` (graph beam search —
  sublinear per-query work, reported via ``stats["distance_evals"]``),
  the quantized storage tiers (``SQ8Index`` / ``PQIndex`` / ``IVFSQ8Index``
  / ``IVFPQIndex`` — int8 and product codes searched with ADC), the
  composable ``TwoStageIndex(reducer, base_index)`` that unlocks
  RAE -> IVF/HNSW -> rerank, ``ShardedIndex`` — the corpus
  partitioned across N child indexes, searched scatter-gather with a
  deterministic (shard-count-invariant) top-k merge — and
  ``MutableIndex`` (factory prefix ``Mut``), the live-serving wrapper:
  streaming ``add``, tombstone ``delete`` (masks pushed into the fused
  kernels), and drift/imbalance-triggered rebuilds.
* :func:`index_factory` — ``index_factory("RAE64,IVF256,PQ8x8,Rerank4")``
  builds the whole stack from a spec string; ``parse_index_spec`` exposes
  the parsed form, and ``str(spec)`` renders it back canonically.

Everything persists to plain npz + json directories, so serving never
retrains on start (``load_reducer`` / ``load_index``).
"""
from .reducer import (
    RAEReducer,
    Reducer,
    get_reducer,
    list_reducers,
    load_reducer,
    make_reducer,
    register_reducer,
)
from .index import (
    KNOB_LADDER,
    FlatIndex,
    IVFFlatIndex,
    SearchParams,
    SearchResult,
    TwoStageIndex,
    VectorIndex,
    load_index,
    next_rung,
    register_index,
    snap_knob,
)
from .quantized import IVFPQIndex, IVFSQ8Index, PQIndex, SQ8Index
from .graph import HNSWIndex
from .sharded import ShardedIndex
from .mutable import MutableIndex
from .factory import IndexSpec, index_factory, parse_index_spec

__all__ = [
    "FlatIndex",
    "HNSWIndex",
    "IVFFlatIndex",
    "IVFPQIndex",
    "IVFSQ8Index",
    "IndexSpec",
    "KNOB_LADDER",
    "MutableIndex",
    "PQIndex",
    "SQ8Index",
    "RAEReducer",
    "Reducer",
    "SearchParams",
    "SearchResult",
    "ShardedIndex",
    "TwoStageIndex",
    "VectorIndex",
    "get_reducer",
    "index_factory",
    "list_reducers",
    "load_index",
    "load_reducer",
    "make_reducer",
    "next_rung",
    "parse_index_spec",
    "register_index",
    "register_reducer",
    "snap_knob",
]
