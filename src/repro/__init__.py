"""repro — RAEX: k-NN-preserving embedding compression + vector search at pod scale."""
__version__ = "1.0.0"
