"""Offline recall-SLO autotuner: sweep the knob ladder, fit the Pareto
operating curve, persist it keyed by index fingerprint.

Hand-picked knob defaults (``nprobe = n_cells/16``, ``ef_search = 64``,
``k1 = k * rerank_factor * oversample``) encode one global guess about
query difficulty; the tuner replaces the guess with measurement. Given a
built index and held-out queries with exact ground truth:

1. :func:`candidate_params` walks the index stack and enumerates
   :class:`~repro.api.index.SearchParams` along the
   :data:`~repro.api.index.KNOB_LADDER` for the knobs that stack actually
   has — IVF stage-1 sweeps ``nprobe``, HNSW-under-rerank sweeps
   ``ef_search`` and ``rerank_k1`` *together* (the beam width is driven
   by the stage-1 budget, so tuning them independently wastes the sweep).
2. :func:`sweep` measures each candidate — recall@k against the exact
   ground truth, mean ``distance_evals`` from ``SearchResult.stats``, QPS
   — and keeps the Pareto front: recall strictly increasing with cost.
3. The resulting :class:`OperatingCurve` maps a recall SLO to the
   cheapest operating point (:meth:`OperatingCurve.select`); the serving
   engine calls it when given ``target_recall`` and
   :func:`save_curve` / :func:`load_curve` persist it as JSON keyed by
   ``index.fingerprint()`` so a tuned point can never be applied to a
   different (rebuilt, mutated, swapped) index.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from ..api.index import (KNOB_LADDER, SearchParams, VectorIndex, snap_knob)
from ..core.metrics import recall_at_k

_CURVE_VERSION = 1


@dataclass(frozen=True)
class OperatingPoint:
    """One measured (knobs -> quality/cost) sample on the curve."""

    params: SearchParams
    recall: float
    distance_evals: float
    qps: float

    def to_dict(self) -> dict:
        return {"params": self.params.to_dict(), "recall": self.recall,
                "distance_evals": self.distance_evals, "qps": self.qps}

    @classmethod
    def from_dict(cls, d: dict) -> "OperatingPoint":
        return cls(params=SearchParams.from_dict(d["params"]),
                   recall=float(d["recall"]),
                   distance_evals=float(d["distance_evals"]),
                   qps=float(d["qps"]))


@dataclass(frozen=True)
class OperatingCurve:
    """Pareto front of measured operating points, cheapest first.

    ``fingerprint`` pins the curve to the exact index build it was
    measured on; ``k`` to the result size (recall@10 says nothing about
    recall@100). The serving engine refuses a curve whose fingerprint
    does not match its live index."""

    points: tuple[OperatingPoint, ...]
    fingerprint: str
    k: int

    def select(self, target_recall: float,
               slack: float = 0.0) -> OperatingPoint:
        """Cheapest point whose measured recall covers ``target_recall``
        (plus ``slack`` — see ``EscalationPolicy.recall_slack``). Points
        are cost-sorted, so the first hit is the answer; if no point
        reaches the target the most accurate one is returned —
        best-effort, and the bench gate (scripts/check_bench.py) is what
        turns a silently missed SLO into a red build."""
        if not self.points:
            raise ValueError("empty operating curve")
        want = target_recall + slack
        for p in self.points:
            if p.recall >= want:
                return p
        return self.points[-1]

    def to_dict(self) -> dict:
        return {"version": _CURVE_VERSION, "fingerprint": self.fingerprint,
                "k": self.k, "points": [p.to_dict() for p in self.points]}

    @classmethod
    def from_dict(cls, d: dict) -> "OperatingCurve":
        return cls(points=tuple(OperatingPoint.from_dict(p)
                                for p in d["points"]),
                   fingerprint=str(d["fingerprint"]), k=int(d["k"]))


def pareto(points: Sequence[OperatingPoint]) -> tuple[OperatingPoint, ...]:
    """Cost-sorted Pareto front: walking up the cost axis, keep a point
    only if it strictly improves recall — dominated knob settings (more
    evals, no more recall) never make the curve."""
    front: list[OperatingPoint] = []
    for p in sorted(points, key=lambda p: (p.distance_evals, -p.recall)):
        if not front or p.recall > front[-1].recall:
            front.append(p)
    return tuple(front)


def _stage1(index: VectorIndex) -> VectorIndex:
    """The knob-bearing stage-1 tier of an arbitrary stack: unwrap
    Mutable (``_inner``), TwoStage (``base``), and Sharded (shard 0 —
    shards are homogeneous by construction)."""
    seen = 0
    while seen < 8:
        seen += 1
        if hasattr(index, "_inner"):           # MutableIndex
            index = index._inner
        elif hasattr(index, "rerank_factor"):  # TwoStageIndex
            index = index.base
        elif hasattr(index, "_shards"):        # ShardedIndex
            index = index._shards[0]
        else:
            return index
    return index


def candidate_params(index: VectorIndex, k: int,
                     max_rung: int = 512) -> list[SearchParams]:
    """Ladder-walk candidates for the knobs this stack actually has.

    * IVF-family stage 1 (has ``nprobe``): sweep ``nprobe`` over the
      rungs up to the cell count — probing more cells than exist is the
      same operating point twice.
    * HNSW stage 1: sweep ``ef_search`` from ``snap(max(k, 8))`` (a beam
      below k is illegal — search clamps to k anyway) up to ``max_rung``.
      Under a rerank, tie ``rerank_k1`` to the same rung: the beam width
      is ``max(ef, k1)``, so a wide k1 under a narrow ef (or vice versa)
      collapses onto another rung's operating point.
    * Knob-free stacks (flat / flat-quantized): the single default point.
    """
    s1 = _stage1(index)
    reranked = hasattr(index, "rerank_factor") or (
        hasattr(index, "_inner") and hasattr(index._inner, "rerank_factor"))
    if hasattr(s1, "nprobe"):
        n_cells = max(1, getattr(s1, "n_cells", KNOB_LADDER[-1]))
        rungs = [r for r in KNOB_LADDER if r <= n_cells] or [KNOB_LADDER[0]]
        return [SearchParams(nprobe=r) for r in rungs if r <= max_rung]
    if hasattr(s1, "ef_search"):
        lo = snap_knob(max(k, 8))
        rungs = [r for r in KNOB_LADDER if lo <= r <= max_rung]
        if reranked:
            return [SearchParams(ef_search=r, rerank_k1=r) for r in rungs]
        return [SearchParams(ef_search=r) for r in rungs]
    return [SearchParams()]


def sweep(index: VectorIndex, queries: np.ndarray,
          ground_truth: np.ndarray, k: int,
          candidates: Optional[Sequence[SearchParams]] = None
          ) -> OperatingCurve:
    """Measure every candidate on held-out ``queries`` against exact
    ``ground_truth`` ids ([Q, >= k], e.g. from a ``FlatIndex`` over the
    same corpus) and return the Pareto operating curve.

    Each candidate runs twice: a warmup call (absorbs jit compiles for
    that rung — serving will also be warm) and a timed call that supplies
    recall, mean ``distance_evals``, and QPS."""
    if candidates is None:
        candidates = candidate_params(index, k)
    gt = np.asarray(ground_truth)[:, :k]
    measured = []
    for params in candidates:
        index.search(queries[:1], k, params=params)  # warm this rung
        t0 = time.perf_counter()
        r = index.search(queries, k, params=params)
        dt = time.perf_counter() - t0
        measured.append(OperatingPoint(
            params=params,
            recall=recall_at_k(r.indices[:, :k], gt),
            distance_evals=float(r.stats.get("distance_evals", 0.0)),
            qps=float(queries.shape[0] / max(dt, 1e-9))))
    return OperatingCurve(points=pareto(measured),
                          fingerprint=index.fingerprint(), k=k)


def save_curve(curve: OperatingCurve, path: str) -> None:
    """Persist as JSON. The conventional name is
    ``curve_<fingerprint>_k<k>.json`` so one directory holds the tuned
    state of many builds; any path works."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(curve.to_dict(), f, indent=1)


def load_curve(path: str,
               index: Optional[VectorIndex] = None) -> OperatingCurve:
    """Load a persisted curve; with ``index`` given, refuse one measured
    on a different build — a tuned point is only meaningful against the
    exact fingerprint it was swept on."""
    with open(path) as f:
        curve = OperatingCurve.from_dict(json.load(f))
    if index is not None:
        fp = index.fingerprint()
        if curve.fingerprint != fp:
            raise ValueError(
                f"operating curve was tuned for fingerprint "
                f"{curve.fingerprint}, live index is {fp} — re-run "
                f"repro.tune.sweep on this build")
    return curve


def curve_path(directory: str, fingerprint: str, k: int) -> str:
    """The conventional on-disk location for a build's tuned curve."""
    return os.path.join(directory, f"curve_{fingerprint}_k{k}.json")
